"""Speculative decoding: edge-model draft, chunked verify, key-coupled
acceptance.

The acceptance contract is *stream equality*: because verification is
key-coupled (draft and target sample through the same per-(request, step)
folded keys, and a proposal is accepted iff it equals the token the
target samples there), every committed token is a baseline token — so
speculative output must be token-for-token identical to the K=1
non-speculative engine at **every** temperature, on every cache
configuration, under draft-seam chaos, at any acceptance rate. Draft
quality may only move throughput, never a single token.
"""
import jax
import numpy as np
import pytest

from repro.configs.base import ModelConfig, dense_stages
from repro.models.model import LM
from repro.serving import ServingEngine
from repro.serving.faults import FaultPlan
from repro.serving.scheduler import Scheduler


def _cfg(layers, name, vocab=64):
    return ModelConfig(
        name=name, family="dense", source="t", num_layers=layers,
        d_model=32, num_heads=4, num_kv_heads=2, head_dim=8, d_ff=64,
        vocab_size=vocab, stages=dense_stages(layers),
        param_dtype="float32")


@pytest.fixture(scope="module")
def models():
    tgt = LM(_cfg(2, "tgt"), kv_chunk=8)
    tp, _ = tgt.init(jax.random.PRNGKey(0))
    drf = LM(_cfg(1, "drf"), kv_chunk=8)
    dp, _ = drf.init(jax.random.PRNGKey(7))
    return tgt, tp, drf, dp


def _trace(n=8, seed=2, budgets=(3, 24), span=(3, 20)):
    rng = np.random.default_rng(seed)
    return [(rng.integers(0, 60, size=int(rng.integers(*span))),
             int(rng.integers(*budgets))) for _ in range(n)]


def _run(lm, params, trace, temperature=0.0, force_spec=False, eos_id=5,
         **kw):
    eng = ServingEngine(lm, params, max_seq_len=64, min_bucket=4,
                        batch_slots=4, eos_id=eos_id, **kw)
    if force_spec:
        # keep speculating at any acceptance rate: the exactness tests
        # must exercise the rejection-heavy paths the EWMA policy would
        # otherwise (correctly) turn off for a random, unaligned draft
        eng.scheduler.spec_min_commit = 0.0
    for prompt, max_new in trace:
        eng.submit(prompt, max_new_tokens=max_new, temperature=temperature)
    return eng, {rid: r.output for rid, r in eng.run().items()}


def _assert_same(a, b):
    assert set(a) == set(b)
    for rid in a:
        np.testing.assert_array_equal(a[rid], b[rid])


CONFIGS = {
    "ring": {},
    "paged": dict(cache_backend="paged", block_size=8),
    "chunked": dict(chunk_tokens=8),
    "paged_chunked_multistep": dict(cache_backend="paged", block_size=8,
                                    chunk_tokens=8, max_decode_steps=4),
}


# ---------------------------------------------------------------------------
# stream equality: greedy and sampled, every configuration
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", sorted(CONFIGS))
@pytest.mark.parametrize("temperature", [0.0, 0.8])
def test_spec_matches_baseline(models, name, temperature):
    tgt, tp, drf, dp = models
    kw = CONFIGS[name]
    trace = _trace()
    _, base = _run(tgt, tp, trace, temperature, **kw)
    eng, spec = _run(tgt, tp, trace, temperature, force_spec=True,
                     draft_model=drf, draft_params=dp,
                     speculative_tokens=4, **kw)
    _assert_same(base, spec)
    m = eng.speculative_metrics()
    assert m["enabled"] and m["rounds"] > 0 and m["drafted_tokens"] > 0
    # anchors always commit: a speculative dispatch never banks < 1 token
    assert m["committed_per_dispatch"] >= 1.0


def test_spec_exact_under_heavy_rejection(models):
    """Greedy with an unaligned random draft rejects essentially every
    proposal — the worst case for the carry/cache bookkeeping (every
    round rewinds to the anchor) — and must still be stream-exact."""
    tgt, tp, drf, dp = models
    trace = _trace(seed=9)
    _, base = _run(tgt, tp, trace, 0.0)
    eng, spec = _run(tgt, tp, trace, 0.0, force_spec=True,
                     draft_model=drf, draft_params=dp, speculative_tokens=4)
    _assert_same(base, spec)
    assert eng.spec_rounds > 5


def test_self_draft_accepts_everything(models):
    """A draft identical to the target proposes exactly the baseline
    tokens, so every proposal is accepted: acceptance is exactly 1.0 and
    committed tokens per dispatch approach k+1. EOS is disabled — an EOS
    inside the chunk truncates the commit, turning matched proposals
    past it into drafted-but-not-accepted accounting noise."""
    tgt, tp, _, _ = models
    trace = _trace(budgets=(16, 25))
    _, base = _run(tgt, tp, trace, 0.0, eos_id=None)
    eng, spec = _run(tgt, tp, trace, 0.0, eos_id=None, draft_model=tgt,
                     draft_params=tp, speculative_tokens=4)
    _assert_same(base, spec)
    m = eng.speculative_metrics()
    assert m["acceptance_rate"] == 1.0
    assert m["committed_per_dispatch"] > 2.0


# ---------------------------------------------------------------------------
# sampled streams: co-scheduling invariance and distribution sanity
# ---------------------------------------------------------------------------

def test_sampled_spec_invariant_to_coscheduling(models):
    """A sampled request's stream is a pure function of (request_id,
    step): serving the trace all-at-once vs trickled in must produce
    identical outputs even though speculation batches different slot
    sets (and collapses at different plan steps) in the two runs."""
    tgt, tp, drf, dp = models
    trace = _trace(seed=4)
    kw = dict(force_spec=True, draft_model=drf, draft_params=dp,
              speculative_tokens=4)
    _, together = _run(tgt, tp, trace, 0.8, **kw)
    eng = ServingEngine(tgt, tp, max_seq_len=64, min_bucket=4,
                        batch_slots=4, eos_id=5, draft_model=drf,
                        draft_params=dp, speculative_tokens=4)
    eng.scheduler.spec_min_commit = 0.0
    trickled = {}
    for prompt, max_new in trace:
        eng.submit(prompt, max_new_tokens=max_new, temperature=0.8)
        eng.step()            # staggered admission: different co-batching
    trickled.update({rid: r.output for rid, r in eng.run().items()})
    _assert_same(together, trickled)


def test_sampled_spec_first_token_distribution(models):
    """Distribution sanity for the coupled sampler: over many request
    ids, speculative first tokens off a shared prompt follow the
    target's softmax (the coupling commits only target-keyed samples, so
    the draft cannot tilt the distribution — only the key stream varies
    per rid)."""
    tgt, tp, drf, dp = models
    prompt = np.array([3, 11, 7], np.int32)
    eng = ServingEngine(tgt, tp, max_seq_len=64, min_bucket=4,
                        batch_slots=4, draft_model=drf, draft_params=dp,
                        speculative_tokens=4)
    eng.scheduler.spec_min_commit = 0.0
    n = 256
    for _ in range(n):
        eng.submit(prompt, max_new_tokens=2, temperature=1.0)
    firsts = np.array([r.output[0] for r in eng.run().values()])
    logits, _ = tgt.prefill(tp, {"tokens": prompt[None, :]}, cache_width=64)
    p = np.asarray(jax.nn.softmax(np.asarray(logits[0, -1])
                                  .astype(np.float64)))
    # chi-square over 8 equal-mass bins (TV over the full padded vocab is
    # too noisy at this n): a systematically-wrong sampler — wrong
    # temperature, draft-tilted acceptance — lands in the hundreds,
    # while a correct one stays near df = 7
    order = np.argsort(-p)
    left = np.cumsum(p[order]) - p[order]       # mass strictly before token
    tok_bin = np.empty(len(p), np.int64)
    tok_bin[order] = np.minimum((left * 8).astype(np.int64), 7)
    obs = np.bincount(tok_bin[firsts], minlength=8).astype(np.float64)
    exp = np.bincount(tok_bin, weights=p, minlength=8) * n
    chi2 = float(((obs - exp) ** 2 / np.maximum(exp, 1e-9)).sum())
    assert chi2 < 40.0, chi2


# ---------------------------------------------------------------------------
# chaos: the draft seam degrades throughput, never output
# ---------------------------------------------------------------------------

def test_draft_seam_chaos_exact_and_drains(models):
    tgt, tp, drf, dp = models
    trace = _trace(seed=6)
    _, base = _run(tgt, tp, trace, 0.7)
    plan = FaultPlan(seed=3, draft={"prob": 0.5})
    eng, spec = _run(tgt, tp, trace, 0.7, force_spec=True,
                     draft_model=drf, draft_params=dp, speculative_tokens=4,
                     fault_plan=plan)
    _assert_same(base, spec)                 # survivors (= everyone) exact
    assert eng.spec_fallbacks > 0            # chaos actually hit the seam
    assert not eng.pending                   # clean drain
    m = eng.metrics()
    assert m["terminal"] == {"done": len(trace)}
    assert m["faults_injected"].get("draft", 0) == eng.spec_fallbacks
    assert m["speculative"]["fallbacks"] == eng.spec_fallbacks


# ---------------------------------------------------------------------------
# warm_compile: every speculative executable pre-built, none added later
# ---------------------------------------------------------------------------

def test_warm_compile_covers_speculative_and_sampled(models):
    tgt, tp, drf, dp = models
    eng = ServingEngine(tgt, tp, max_seq_len=64, min_bucket=4,
                        batch_slots=4, eos_id=5, chunk_tokens=8,
                        max_decode_steps=4, draft_model=drf,
                        draft_params=dp, speculative_tokens=4)
    eng.scheduler.spec_min_commit = 0.0
    eng.warm_compile()
    sched = eng.scheduler
    fns = {
        "_step_fn": (eng._step_fn, 1),
        "_scan_fn": (eng._scan_fn,
                     len([k for k in sched.k_schedule if k > 1])),
        "_spec_fn": (eng._spec_fn, len(sched.spec_schedule)),
        "_draft_fill_fn": (eng._draft_fill_fn, len(eng.buckets)),
    }
    for name, (fn, expect) in fns.items():
        assert fn._cache_size() == expect, name
    chunk_compiles = eng._chunk_fn._cache_size()
    # sampled traffic (temperature > 0) through every decode path must
    # not compile anything new — the cold-probe cost the open-loop bench
    # used to dodge with a throwaway warm pass
    for prompt, max_new in _trace(seed=11):
        eng.submit(prompt, max_new_tokens=max_new, temperature=0.9)
    eng.run()
    for name, (fn, expect) in fns.items():
        assert fn._cache_size() == expect, f"{name} compiled post-warm"
    assert eng._chunk_fn._cache_size() == chunk_compiles


# ---------------------------------------------------------------------------
# non-speculative engines and validation
# ---------------------------------------------------------------------------

def test_non_speculative_metrics_shape(models):
    tgt, tp, _, _ = models
    eng = ServingEngine(tgt, tp, max_seq_len=64, min_bucket=4)
    m = eng.metrics()["speculative"]
    assert m["enabled"] is False and m["rounds"] == 0
    assert m["acceptance_rate"] == 0.0 and m["per_class"] == {}


def test_speculative_validation(models):
    tgt, tp, drf, dp = models
    with pytest.raises(ValueError, match="needs a draft_model"):
        ServingEngine(tgt, tp, max_seq_len=64, speculative_tokens=2)
    with pytest.raises(ValueError, match="draft_params"):
        ServingEngine(tgt, tp, max_seq_len=64, draft_model=drf,
                      speculative_tokens=2)
    # padded_vocab rounds to a multiple of 256, so the draft's vocab must
    # land in a different 256-bucket than the target's (64 -> 256) for
    # the padded-logit comparison to be genuinely incompatible
    big = LM(_cfg(1, "bigvocab", vocab=300), kv_chunk=8)
    bp, _ = big.init(jax.random.PRNGKey(1))
    with pytest.raises(ValueError, match="vocab"):
        ServingEngine(tgt, tp, max_seq_len=64, draft_model=big,
                      draft_params=bp, speculative_tokens=2)


# ---------------------------------------------------------------------------
# scheduler policy
# ---------------------------------------------------------------------------

def test_spec_schedule_shape():
    s = Scheduler(batch_slots=4, speculative_tokens=6)
    assert s.spec_schedule == [1, 2, 4, 6]
    assert Scheduler(batch_slots=4).spec_schedule == []


def test_spec_horizon_collapses_for_prefill_and_headroom():
    s = Scheduler(batch_slots=4, speculative_tokens=4)
    assert s._spec_horizon(False, 16) == 4
    assert s._spec_horizon(True, 16) == 0        # prefill pending: TTFT wins
    # headroom clamps k so anchor + proposals never overrun the budget
    assert s._spec_horizon(False, 3) == 2
    assert s._spec_horizon(False, 1) == 0        # only the anchor would fit
    assert s._spec_horizon(False, None) == 4


def test_spec_ewma_suppression_and_probe():
    s = Scheduler(batch_slots=4, speculative_tokens=4, spec_probe_every=5)
    # poor acceptance: drafting commits ~1.0/dispatch < spec_min_commit
    for _ in range(8):
        s.observe_speculation(4, 16, 0)
    picks = [s._spec_horizon(False, 16) for _ in range(10)]
    assert picks.count(0) == 8                   # suppressed...
    assert picks.count(4) == 2                   # ...but re-probed on cadence
    # strong acceptance wins speculation back
    for _ in range(8):
        s.observe_speculation(4, 16, 14)
    assert s._spec_horizon(False, 16) == 4
    assert s.speculative_acceptance() > 1.0
