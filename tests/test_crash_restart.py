"""Durable serving (ISSUE 9): snapshot/restore, journal replay, watchdog.

The contract under test: a serving process may die at *any* step — or
hang mid-dispatch — and the recovered incarnation must finish every
acknowledged, non-cancelled request with survivors token-for-token
identical to the crash-free run. Three mechanisms compose to deliver
that: ``ServingEngine.snapshot``/``restore`` (token-exact resumption of
live requests into a cold same-seed engine), the gateway's write-ahead
``RequestJournal`` (acknowledged submits the snapshot missed are
replayed under their original ids; duplicates refused), and the
dispatch watchdog (a late step rolls back in-process via ``note_hang``;
a wedged step escalates to ``EngineWedgedError`` and a supervised
restart from snapshot + journal).
"""
import asyncio
import os
import types

import jax
import numpy as np
import pytest

from repro.configs.base import ModelConfig, dense_stages
from repro.models.model import LM
from repro.serving import (EngineWedgedError, FaultPlan, RequestJournal,
                           ServingEngine, ServingGateway, load_snapshot,
                           recover_engine, save_snapshot)


def _tiny_cfg(layers=2, name="tiny"):
    return ModelConfig(
        name=name, family="dense", source="t", num_layers=layers,
        d_model=32, num_heads=4, num_kv_heads=2, head_dim=8, d_ff=64,
        vocab_size=64, stages=dense_stages(layers), param_dtype="float32")


@pytest.fixture(scope="module")
def tiny():
    lm = LM(_tiny_cfg(), kv_chunk=8)
    params, _ = lm.init(jax.random.PRNGKey(0))
    return lm, params


@pytest.fixture(scope="module")
def draft():
    lm = LM(_tiny_cfg(layers=1, name="drf"), kv_chunk=8)
    params, _ = lm.init(jax.random.PRNGKey(7))
    return lm, params


def _trace(n=6, seed=1, budgets=(3, 12)):
    rng = np.random.default_rng(seed)
    return [(rng.integers(0, 60, size=int(rng.integers(3, 12))),
             int(rng.integers(*budgets))) for _ in range(n)]


# the backend × decode-shape matrix the crash sweep covers (mirrors the
# chaos matrix in test_faults.py): recompute resume on the ring, swap
# resume on the paged pool, the multi-step scan, and chunked prefill
CONFIGS = {
    "ring": dict(cache_backend="ring"),
    "paged": dict(cache_backend="paged", block_size=8, num_pool_blocks=28),
    "paged_multistep": dict(cache_backend="paged", block_size=8,
                            num_pool_blocks=28, max_decode_steps=4),
    "paged_chunked": dict(cache_backend="paged", block_size=8,
                          num_pool_blocks=28, chunk_tokens=8),
}

BASE_KW = dict(batch_slots=3, max_seq_len=64, min_bucket=4)


def _engine(tiny, **kw):
    lm, params = tiny
    base = dict(BASE_KW)
    base.update(kw)
    return ServingEngine(lm, params, **base)


def _baseline(tiny, trace, temperature, **kw):
    eng = _engine(tiny, **kw)
    for prompt, budget in trace:
        eng.submit(prompt, budget, temperature=temperature)
    return eng.run()


def _drain(eng, max_steps=2000):
    steps = 0
    while eng.pending:
        eng.step()
        steps += 1
        assert steps <= max_steps, "engine livelocked after restore"
        if hasattr(eng.backend, "assert_invariants"):
            eng.backend.assert_invariants()
    return eng._done


def _assert_drained_clean(eng):
    assert sorted(eng._free) == list(range(eng.batch_slots))
    be = eng.backend
    if hasattr(be, "assert_invariants"):
        be.assert_invariants()
        assert be._gap_total == 0 and be._ref == {}


def _crash_then_restore(tiny, trace, crash_step, temperature,
                        fault_plan=None, snapshot_dir=None, **kw):
    """Step engine #1 to ``crash_step``, snapshot, abandon it (the
    "crash"), restore into a cold same-construction engine #2 and drain.
    Returns (engine2, merged terminal map)."""
    eng1 = _engine(tiny, fault_plan=fault_plan, **kw)
    for prompt, budget in trace:
        eng1.submit(prompt, budget, temperature=temperature)
    for _ in range(crash_step):
        if not eng1.pending:
            break
        eng1.step()
    snap = eng1.snapshot()
    if snapshot_dir is not None:             # through the .npz envelope
        save_snapshot(snapshot_dir, snap, step=crash_step)
        snap, _ = load_snapshot(snapshot_dir)
    eng2 = _engine(tiny, **kw)
    info = eng2.restore(snap)
    assert info["live"] + info["terminal"] == len(trace)
    if hasattr(eng2.backend, "assert_invariants"):
        eng2.backend.assert_invariants()
    return eng2, _drain(eng2)


# ---------------------------------------------------------------------------
# Engine snapshot/restore: token-exact resumption
# ---------------------------------------------------------------------------

def test_restore_mid_flight_is_token_exact(tiny, tmp_path):
    """Crash at a randomized step, restore through the on-disk envelope:
    every request — already-terminal, mid-decode, mid-queue — finishes
    with the crash-free run's exact tokens."""
    trace = _trace(6, seed=1)
    base = _baseline(tiny, trace, 0.7, **CONFIGS["paged"])
    rng = np.random.default_rng(42)
    for crash_step in rng.integers(1, 14, size=3):
        eng2, done = _crash_then_restore(
            tiny, trace, int(crash_step), 0.7,
            snapshot_dir=str(tmp_path / f"s{crash_step}"),
            **CONFIGS["paged"])
        assert eng2.restores == 1
        assert len(done) == len(trace)
        for rid, r in done.items():
            assert r.status == "done"
            np.testing.assert_array_equal(r.output, base[rid].output)
        _assert_drained_clean(eng2)


@pytest.mark.slow
@pytest.mark.parametrize("name", sorted(CONFIGS))
@pytest.mark.parametrize("temperature", [0.0, 0.7],
                         ids=["greedy", "sampled"])
def test_restore_matrix_under_chaos(tiny, name, temperature):
    """The full matrix, with a chaos schedule running *across* the crash:
    faults before the snapshot leave retry state behind, faults after it
    hit restored requests — survivors stay exact either way."""
    kw = CONFIGS[name]
    trace = _trace(7, seed=2)
    base = _baseline(tiny, trace, temperature, **kw)
    rng = np.random.default_rng(7)
    for crash_step in rng.integers(2, 18, size=2):
        plan = FaultPlan(seed=13, step={"prob": 0.1, "max_fires": 2},
                         swap_in={"prob": 0.3, "max_fires": 2})
        eng2, done = _crash_then_restore(tiny, trace, int(crash_step),
                                         temperature, fault_plan=plan,
                                         max_retries=6, **kw)
        assert len(done) == len(trace)
        survivors = {rid: r for rid, r in done.items()
                     if r.status == "done"}
        assert survivors
        for rid, r in survivors.items():
            np.testing.assert_array_equal(r.output, base[rid].output)
        _assert_drained_clean(eng2)


@pytest.mark.slow
def test_restore_speculative_is_token_exact(tiny, draft):
    """Crash mid-speculation: acceptance is key-coupled, so a restored
    engine — even one whose draft controller state restarted cold —
    recommits the exact baseline stream."""
    lm, params = tiny
    dlm, dparams = draft
    kw = dict(BASE_KW, cache_backend="paged", block_size=8,
              num_pool_blocks=28, draft_model=dlm, draft_params=dparams,
              speculative_tokens=4)
    trace = _trace(5, seed=3, budgets=(4, 10))

    def spec_engine():
        eng = ServingEngine(lm, params, **kw)
        eng.scheduler.spec_min_commit = 0.0   # speculate regardless of EWMA
        return eng

    ref = spec_engine()
    for prompt, budget in trace:
        ref.submit(prompt, budget, temperature=0.7)
    base = ref.run()

    eng1 = spec_engine()
    for prompt, budget in trace:
        eng1.submit(prompt, budget, temperature=0.7)
    for _ in range(5):
        eng1.step()
    eng2 = spec_engine()
    eng2.restore(eng1.snapshot())
    done = _drain(eng2)
    assert len(done) == len(trace)
    for rid, r in done.items():
        assert r.status == "done"
        np.testing.assert_array_equal(r.output, base[rid].output)
    _assert_drained_clean(eng2)


def test_restore_refuses_warm_engine(tiny):
    eng1 = _engine(tiny)
    eng1.submit(np.arange(5), 4)
    snap = eng1.snapshot()
    eng2 = _engine(tiny)
    eng2.submit(np.arange(4), 3)
    with pytest.raises(RuntimeError, match="cold"):
        eng2.restore(snap)


def test_snapshot_directory_rotation(tiny, tmp_path):
    """save_snapshot keeps the newest ``keep`` envelopes; load_snapshot
    picks the latest by default and an explicit step on request."""
    eng = _engine(tiny)
    eng.submit(np.arange(5), 4)
    snap = eng.snapshot()
    for step in (1, 2, 3, 4):
        save_snapshot(str(tmp_path), snap, step=step, keep=3)
    files = sorted(os.listdir(tmp_path))
    assert len(files) == 3 and "step_1.npz" not in files
    latest, step = load_snapshot(str(tmp_path))
    assert step == 4
    explicit, step = load_snapshot(str(tmp_path), step=2)
    assert step == 2
    for loaded in (latest, explicit):
        eng2 = _engine(tiny)
        info = eng2.restore(loaded)
        assert info["live"] == 1
        done = _drain(eng2)
        assert done and all(r.status == "done" for r in done.values())
    with pytest.raises(FileNotFoundError):
        load_snapshot(str(tmp_path / "nope"))


# ---------------------------------------------------------------------------
# Write-ahead journal: replay, duplicates, compaction, torn tail
# ---------------------------------------------------------------------------

def _submit_rec(rid, prompt, max_new=5, temperature=0.7):
    return types.SimpleNamespace(
        request_id=rid, prompt=np.asarray(prompt, np.int32),
        max_new_tokens=max_new, temperature=temperature, priority=0,
        deadline_s=None)


def test_journal_replay_is_exact_and_refuses_duplicates(tiny, tmp_path):
    """Replay re-queues unfinished submits under their original ids —
    so the sampling keys, and therefore the tokens, match the crash-free
    run exactly — and a duplicate submission of a journaled id is
    refused, not double-served."""
    trace = _trace(4, seed=5)
    base = _baseline(tiny, trace, 0.7)

    path = str(tmp_path / "journal.jsonl")
    with RequestJournal(path) as j:
        for rid, (prompt, budget) in enumerate(trace):
            assert j.record_submit(_submit_rec(rid, prompt, budget))
        assert not j.record_submit(_submit_rec(1, trace[1][0]))  # dup
        assert j.duplicates_refused == 1
        j.record_first_token(0)
        j.record_terminal(3, "cancelled", reason="client")
        assert sorted(j.unfinished()) == [0, 1, 2]

    # "restart": a fresh journal instance over the same file drives a
    # cold engine — rids 0..2 replayed, 3 already terminal
    j2 = RequestJournal(path)
    eng = _engine(tiny)
    counts = j2.replay(eng)
    assert counts == {"replayed": 3, "covered": 0, "duplicates": 0}
    assert not j2.record_submit(_submit_rec(2, trace[2][0]))  # still dup
    done = _drain(eng)
    assert sorted(done) == [0, 1, 2]
    for rid, r in done.items():
        assert r.status == "done"
        np.testing.assert_array_equal(r.output, base[rid].output)
    j2.close()


def test_journal_replay_skips_snapshot_covered_ids(tiny, tmp_path):
    """Ids a restored snapshot already owns are left alone — their
    resume checkpoints beat a from-scratch re-queue."""
    trace = _trace(4, seed=6)
    eng1 = _engine(tiny)
    for prompt, budget in trace:
        eng1.submit(prompt, budget, temperature=0.5)
    for _ in range(3):
        eng1.step()
    with RequestJournal(str(tmp_path / "j.jsonl")) as j:
        for rid, (prompt, budget) in enumerate(trace):
            j.record_submit(_submit_rec(rid, prompt, budget))
        j.record_submit(_submit_rec(99, np.arange(4), 3))  # snapshot missed
        eng2 = _engine(tiny)
        eng2.restore(eng1.snapshot())
        counts = j.replay(eng2)
        assert counts["covered"] == len(trace) and counts["replayed"] == 1
    done = _drain(eng2)
    assert sorted(done) == [0, 1, 2, 3, 99]
    assert all(r.status == "done" for r in done.values())


def test_journal_compaction_and_torn_tail(tmp_path):
    path = str(tmp_path / "j.jsonl")
    with RequestJournal(path) as j:
        for rid in range(4):
            j.record_submit(_submit_rec(rid, np.arange(3)))
        j.record_terminal(0, "done")
        out = j.compact(covered_rids={0, 1})
        assert out == {"kept": 2, "dropped": 3}
        assert j.compactions == 1
        assert sorted(j.unfinished()) == [2, 3]
        assert j.stats()["appended"] == 5
    # torn tail: a crash mid-append leaves a half-written line — the
    # scan stops there and everything before it survives
    with open(path, "a", encoding="utf-8") as f:
        f.write('{"kind": "terminal", "rid": 2, "sta')
    j2 = RequestJournal(path)
    assert sorted(j2.unfinished()) == [2, 3]
    assert j2.seen(2) and not j2.seen(0)     # compacted ids forgotten
    j2.close()


# ---------------------------------------------------------------------------
# Watchdog: late hang -> in-process rollback; wedge -> supervised restart
# ---------------------------------------------------------------------------

def _gw_trace(n=6, seed=3):
    rng = np.random.default_rng(seed)
    return [dict(prompt=rng.integers(0, 60, size=int(rng.integers(3, 10))),
                 max_new=int(rng.integers(3, 8))) for _ in range(n)]


async def _gw_clients(gw, trace, out=None):
    out = {} if out is None else out

    async def client(item):
        h = await gw.submit(item["prompt"], max_new_tokens=item["max_new"],
                            temperature=0.7)
        toks = [t async for t in h.stream()]
        r = await h.result()
        out[r.request_id] = (r, toks)

    await asyncio.gather(*(client(it) for it in trace))
    return out


@pytest.mark.slow
def test_watchdog_hang_recovers_in_process(tiny):
    """A dispatch that completes *late* (past the deadline, inside the
    grace window) is detected, rolled back through the retry path, and
    service continues in the same process — streams exact."""
    trace = _gw_trace(5, seed=8)
    ref = _baseline(tiny, [(it["prompt"], it["max_new"]) for it in trace],
                    0.7, **CONFIGS["paged"])
    plan = FaultPlan(seed=0, hang=[2], hang_s=2.6)
    eng = _engine(tiny, fault_plan=plan, **CONFIGS["paged"])

    async def main():
        # wide grace: on a loaded machine an *honest* step can also run
        # past the deadline and complete late — that must stay a benign
        # extra timeout+rollback, never escalate to a wedge
        async with ServingGateway(eng, step_timeout_s=2.0,
                                  hang_grace=3.0) as gw:
            out = await _gw_clients(gw, trace)
            return out, gw.stats()

    out, stats = asyncio.run(main())
    assert stats["watchdog_timeouts"] >= 1
    assert stats["engine"]["hang_recoveries"] >= 1
    assert stats["engine"]["retries_total"] > 0
    assert len(out) == len(trace)
    for rid, (r, toks) in out.items():
        assert r.status == "done"
        np.testing.assert_array_equal(r.output, ref[rid].output)
        np.testing.assert_array_equal(toks, ref[rid].output)
    _assert_drained_clean(eng)


@pytest.mark.slow
def test_wedge_supervised_restart_loses_nothing(tiny, tmp_path):
    """The full crash ladder: a dispatch stalls past grace, the driver
    raises EngineWedgedError, in-flight handles fail fast, and a fresh
    engine recovered from snapshot + journal finishes every acknowledged
    request — snapshot-covered survivors token-exact, journal-replayed
    ones exact too (original ids preserved)."""
    trace = _gw_trace(6, seed=9)
    ref = _baseline(tiny, [(it["prompt"], it["max_new"]) for it in trace],
                    0.7, **CONFIGS["paged"])
    snap_dir = str(tmp_path / "snapshots")
    journal = RequestJournal(str(tmp_path / "journal.jsonl"))
    plan = FaultPlan(seed=0, hang=[4], hang_s=6.0)
    eng = _engine(tiny, fault_plan=plan, **CONFIGS["paged"])

    async def main():
        # ``out`` is mutated in place: the clients all resolve (the crash
        # fails in-flight handles fast), but the EngineWedgedError that
        # surfaces from the gateway's exit would discard a return value
        out = {}
        gw = ServingGateway(eng, journal=journal, snapshot_dir=snap_dir,
                            snapshot_every=2, step_timeout_s=1.5,
                            hang_grace=0.5)
        try:
            async with gw:
                await _gw_clients(gw, trace, out)
            return out, gw.stats(), True
        except EngineWedgedError:
            return out, gw.stats(), False

    out, stats, clean = asyncio.run(main())
    assert not clean, "hang seam never wedged the engine"
    assert len(out) == len(trace)             # every handle resolved fast
    assert stats["watchdog_timeouts"] >= 1
    assert stats["snapshots_taken"] >= 1
    assert stats["journal"]["appended"] >= len(trace)

    # supervised restart: cold engine <- snapshot, then journal replay
    eng2 = _engine(tiny, **CONFIGS["paged"])
    info = recover_engine(eng2, snapshot_dir=snap_dir, journal=journal)
    assert info["restored"]["live"] + info["replayed"]["replayed"] > 0
    done = _drain(eng2)
    _assert_drained_clean(eng2)
    journal.close()

    # zero lost acknowledged requests: every journaled submit reaches a
    # terminal state pre-crash or post-restart, token-exact either way
    resolved = set()
    for rid, (r, _) in out.items():
        if r.status in ("done", "cancelled"):
            resolved.add(rid)
            if r.status == "done":            # finished before the wedge
                np.testing.assert_array_equal(r.output, ref[rid].output)
    for rid in range(len(trace)):
        assert journal.seen(rid)
        assert rid in resolved or rid in done, f"request {rid} lost"
        if rid in done:
            assert done[rid].status == "done"
            np.testing.assert_array_equal(done[rid].output,
                                          ref[rid].output)


# ---------------------------------------------------------------------------
# Cascade engine durability
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_cascade_snapshot_restore_completes(tiny):
    """Cascade snapshot/restore: pending and routed requests (and both
    inner engines) survive the crash; the restored cascade drains every
    request to "done". Replayed lost requests get fresh inner ids, so
    the guarantee here is completion + leg-consistency, with exactness
    carried by the inner engines' own restore tests."""
    from repro.cascade.ecc_infer import CascadeLM, edge_variant
    from repro.cascade.gate import make_thresholds
    from repro.serving import CascadeServingEngine

    cloud_cfg = _tiny_cfg()
    edge_cfg = edge_variant(cloud_cfg, layers=1)
    cloud, edge = LM(cloud_cfg, kv_chunk=8), LM(edge_cfg, kv_chunk=8)
    cp, _ = cloud.init(jax.random.PRNGKey(0))
    ep, _ = edge.init(jax.random.PRNGKey(1))

    def build():
        cascade = CascadeLM(edge, cloud,
                            thresholds=make_thresholds(hi=0.01, lo=0.001))
        return CascadeServingEngine(cascade, ep, cp, batch_slots=2,
                                    max_seq_len=32)

    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, 60, size=4 + i) for i in range(6)]

    ref = build()
    rids = [ref.submit(p, max_new_tokens=3) for p in prompts]
    base = ref.run()

    eng1 = build()
    for p in prompts:
        eng1.submit(p, max_new_tokens=3)
    for _ in range(3):
        eng1.step()
    snap = eng1.snapshot()

    eng2 = build()
    info = eng2.restore(snap)
    assert info["live"] + info["terminal"] == len(prompts)
    assert eng2.restores == 1
    done = eng2.run()
    assert sorted(done) == sorted(rids)
    for rid in rids:
        r = done[rid]
        assert r.status == "done"
        assert r.route == base[rid].route
        np.testing.assert_array_equal(r.output, base[rid].output)


# ---------------------------------------------------------------------------
# Satellite 1: measured deadline outcomes feed the admission margin
# ---------------------------------------------------------------------------

def test_deadline_hit_feedback_widens_admission_margin():
    from repro.core.monitoring import MonitoringService
    from repro.serving.scheduler import Scheduler

    sch = Scheduler(batch_slots=2, admission_policy="reject")
    assert sch.deadline_safety_margin(1) == 1.0   # no evidence yet
    mon = MonitoringService()
    mon.record_serving("eng", {"deadline_hits": {
        1: {"hits": 2, "total": 8, "rate": 0.25},
        0: {"hits": 8, "total": 8, "rate": 1.0}}})
    assert mon.feed_deadline_admission("eng", sch)
    assert sch.deadline_safety_margin(0) == 1.0   # class 0 meets target
    m = sch.deadline_safety_margin(1)             # class 1 misses badly
    assert 1.0 < m <= sch.deadline_margin_cap
    assert m == pytest.approx(sch.deadline_margin_target / 0.25)
    # below min_obs: too little evidence to second-guess the EWMA
    sch.absorb_deadline_hits({2: {"hits": 0, "total": 2}})
    assert sch.deadline_safety_margin(2) == 1.0
    # restart semantics: reset clears the margin with the estimates
    sch.reset_estimates()
    assert sch.deadline_safety_margin(1) == 1.0
    # no snapshot recorded -> feed is a no-op
    assert not mon.feed_deadline_admission("nope", sch)
