"""Pallas kernels vs. ref.py oracles: shape/dtype sweeps in interpret mode
(deliverable c)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.cascade.gate import make_thresholds
from repro.kernels import ref
from repro.kernels.cascade_gate import cascade_gate
from repro.kernels.flash_attention import flash_attention
from repro.kernels.rglru_scan import rglru_scan

ATTN_CASES = [
    # (b, sq, sk, h, kv, hd, window, dtype)
    (1, 64, 64, 4, 4, 32, None, jnp.float32),
    (2, 64, 64, 4, 2, 64, None, jnp.float32),
    (1, 100, 100, 3, 1, 32, None, jnp.float32),   # MQA, ragged seq
    (2, 64, 64, 4, 4, 32, 24, jnp.float32),       # sliding window
    (1, 1, 96, 4, 2, 32, None, jnp.float32),      # decode shape
    (1, 1, 96, 4, 2, 32, 16, jnp.float32),        # windowed decode
    (1, 48, 48, 2, 2, 128, None, jnp.bfloat16),   # bf16
    (1, 32, 32, 8, 8, 256, None, jnp.float32),    # hd=256 (recurrentgemma)
]


@pytest.mark.parametrize("case", ATTN_CASES,
                         ids=[f"{c[:-1]}-{c[-1].__name__}" for c in ATTN_CASES])
def test_flash_attention_sweep(case):
    b, sq, sk, h, kv, hd, window, dtype = case
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, sq, h, hd)).astype(dtype)
    k = jax.random.normal(ks[1], (b, sk, kv, hd)).astype(dtype)
    v = jax.random.normal(ks[2], (b, sk, kv, hd)).astype(dtype)
    out = flash_attention(q, k, v, window=window, block_q=32, block_k=32,
                          interpret=True)
    expect = ref.flash_attention_ref(q, k, v, window=window)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    assert out.dtype == dtype
    assert float(jnp.max(jnp.abs(out.astype(jnp.float32)
                                 - expect.astype(jnp.float32)))) < tol


@settings(max_examples=10, deadline=None)
@given(s=st.integers(3, 80), w=st.integers(8, 70),
       bt=st.sampled_from([8, 16, 32]), seed=st.integers(0, 1000))
def test_rglru_scan_property(s, w, bt, seed):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    a = jax.random.uniform(ks[0], (1, s, w), jnp.float32, 0.3, 0.999)
    b = jax.random.normal(ks[1], (1, s, w), jnp.float32)
    h0 = jax.random.normal(ks[2], (1, w), jnp.float32)
    h, hl = rglru_scan(a, b, h0, block_t=bt, block_w=32, interpret=True)
    hr, hlr = ref.rglru_scan_ref(a, b, h0)
    assert float(jnp.max(jnp.abs(h - hr))) < 1e-4
    assert float(jnp.max(jnp.abs(hl - hlr))) < 1e-4


@pytest.mark.parametrize("t,v,dtype", [
    (64, 512, jnp.float32),
    (100, 500, jnp.float32),       # both dims ragged
    (7, 8000, jnp.float32),        # vocab >> tokens
    (128, 1024, jnp.bfloat16),
])
def test_cascade_gate_sweep(t, v, dtype):
    logits = (jax.random.normal(jax.random.PRNGKey(1), (t, v)) * 3).astype(dtype)
    conf, routes, counts = cascade_gate(logits, block_t=32, block_v=256,
                                        interpret=True)
    expect = ref.cascade_gate_ref(logits, make_thresholds())
    tol = 1e-2 if dtype == jnp.bfloat16 else 1e-5
    assert float(jnp.max(jnp.abs(conf - expect["conf"]))) < tol
    if dtype == jnp.float32:
        assert bool(jnp.all(routes == expect["routes"]))
        assert bool(jnp.all(counts == expect["counts"]))
    assert int(jnp.sum(counts)) == t


@settings(max_examples=15, deadline=None)
@given(t=st.integers(1, 60), v=st.integers(8, 600),
       hi=st.floats(0.5, 0.95), lo=st.floats(0.01, 0.4),
       seed=st.integers(0, 1000))
def test_cascade_gate_property(t, v, hi, lo, seed):
    """Property: kernel counts partition T; routes consistent with conf."""
    logits = jax.random.normal(jax.random.PRNGKey(seed), (t, v)) * 2
    conf, routes, counts = cascade_gate(logits, hi=hi, lo=lo, block_t=16,
                                        block_v=64, interpret=True)
    conf = np.asarray(conf)
    routes = np.asarray(routes)
    assert int(np.sum(np.asarray(counts))) == t
    assert np.all(routes[conf >= hi] == 0)
    assert np.all(routes[conf < lo] == 1)
    assert np.all(routes[(conf >= lo) & (conf < hi)] == 2)
