"""Token-budget scheduler: plan arithmetic (pure unit tests) and the
engine-level acceptance contract — chunked prefill, interleaved with decode
under a token budget, generates token-for-token what the unchunked engine
does, on both cache layouts, including shared-prefix and copy-on-write
admissions; and per-request sampling keys make temperature > 0 streams
independent of co-scheduling."""
import collections

import jax
import numpy as np
import pytest

from repro.configs.base import (MLA, SWIGLU, BlockDef, MLAConfig, ModelConfig,
                                Stage, dense_stages)
from repro.models.model import LM
from repro.serving import ServingEngine
from repro.serving.scheduler import (MONOLITHIC, PrefillProgress, Scheduler,
                                     chunk_buckets)


def _tiny_cfg(layers=2, window=None):
    return ModelConfig(
        name="tiny", family="dense", source="t", num_layers=layers,
        d_model=32, num_heads=4, num_kv_heads=2, head_dim=8, d_ff=64,
        vocab_size=64, stages=dense_stages(layers, window=window),
        param_dtype="float32")


def _mla_cfg():
    return ModelConfig(
        name="tiny-mla", family="mla", source="t", num_layers=2,
        d_model=32, num_heads=4, num_kv_heads=4, head_dim=8, d_ff=64,
        vocab_size=64,
        stages=(Stage(blocks=(BlockDef(mixer=MLA, mlp=SWIGLU),), repeat=2),),
        param_dtype="float32",
        mla=MLAConfig(q_lora_rank=16, kv_lora_rank=8, qk_nope_head_dim=8,
                      qk_rope_head_dim=4, v_head_dim=8))


def _lm(cfg):
    lm = LM(cfg, kv_chunk=8)
    params, _ = lm.init(jax.random.PRNGKey(0))
    return lm, params


def _mixed_trace(n=7, seed=1, lo=3, hi=14):
    rng = np.random.default_rng(seed)
    return [(rng.integers(0, 60, size=int(rng.integers(lo, hi))),
             int(rng.integers(3, 9))) for _ in range(n)]


def _run(lm, params, trace, temperature=0.0, **kw):
    eng = ServingEngine(lm, params, max_seq_len=32, min_bucket=4, **kw)
    for prompt, max_new in trace:
        eng.submit(prompt, max_new_tokens=max_new, temperature=temperature)
    return eng, {rid: r.output for rid, r in eng.run().items()}


def _assert_same(a, b):
    assert set(a) == set(b)
    for rid in a:
        np.testing.assert_array_equal(a[rid], b[rid])


# ---------------------------------------------------------------------------
# Plan arithmetic (no engine, no device)
# ---------------------------------------------------------------------------

def _pp(slot, nxt, total):
    return PrefillProgress(request=None, slot=slot, next=nxt, total=total)


def test_plan_respects_token_budget():
    s = Scheduler(batch_slots=4, chunk_tokens=8, token_budget=12)
    prefilling = collections.OrderedDict(
        [(0, _pp(0, 0, 20)), (1, _pp(1, 4, 6))])
    plan = s.plan_step(n_active=3, prefilling=prefilling,
                       try_admit=lambda: None)
    # 3 decode tokens + chunks within the remaining 9; the leftover token
    # is NOT spent on a runt chunk (a full dispatch for a 1-token sliver)
    spent = 3 + sum(c.length for c in plan.chunks)
    assert spent <= 12
    assert [(c.slot, c.length) for c in plan.chunks] == [(0, 8)]


def test_plan_first_chunk_never_starved():
    """The first chunk of a step always proceeds in full, even when active
    decodes already exceed the budget — prefill cannot be starved."""
    s = Scheduler(batch_slots=4, chunk_tokens=8, token_budget=6)
    prefilling = collections.OrderedDict([(0, _pp(0, 0, 20))])
    plan = s.plan_step(n_active=5, prefilling=prefilling,
                       try_admit=lambda: None)
    assert [(c.slot, c.start, c.length) for c in plan.chunks] == [(0, 0, 8)]


def test_plan_marks_final_chunk_and_splits_long_prompts():
    s = Scheduler(batch_slots=1, chunk_tokens=4, token_budget=64)
    prefilling = collections.OrderedDict([(0, _pp(0, 0, 10))])
    plan = s.plan_step(n_active=0, prefilling=prefilling,
                       try_admit=lambda: None)
    assert [(c.start, c.length) for c in plan.chunks] == \
        [(0, 4), (4, 4), (8, 2)]
    assert [c.final for c in plan.chunks] == [False, False, True]
    # chunk shapes come from the bucketed set
    assert all(c.bucket in s.buckets for c in plan.chunks)


def test_plan_admits_into_leftover_budget():
    s = Scheduler(batch_slots=2, chunk_tokens=8, token_budget=11)
    admitted = [_pp(2, 0, 6), _pp(3, 0, 6)]

    def try_admit():
        return admitted.pop(0) if admitted else None

    plan = s.plan_step(n_active=2, prefilling=collections.OrderedDict(),
                       try_admit=try_admit)
    # 2 decodes + first admission's 6-token prompt leaves 3 tokens: the
    # second admission is still granted its slot, but its prompt (> the
    # leftover) starts as a full chunk next step rather than as a runt now
    assert plan.admitted == 2
    spent = 2 + sum(c.length for c in plan.chunks)
    assert spent <= 11
    assert [(c.slot, c.length, c.final) for c in plan.chunks] == \
        [(2, 6, True)]


def test_unchunked_scheduler_admits_greedily():
    s = Scheduler(batch_slots=2, chunk_tokens=None)
    grants = [MONOLITHIC, MONOLITHIC]

    def try_admit():
        return grants.pop(0) if grants else None

    plan = s.plan_step(n_active=1, prefilling=collections.OrderedDict(),
                       try_admit=try_admit)
    assert plan.admitted == 2 and plan.chunks == ()


def test_scheduler_rejects_starving_budget():
    with pytest.raises(ValueError, match="must exceed batch_slots"):
        Scheduler(batch_slots=8, chunk_tokens=4, token_budget=8)


def test_chunk_buckets_cover_chunk_range():
    assert chunk_buckets(16) == [8, 16]
    assert chunk_buckets(4) == [4]
    assert chunk_buckets(1) == [1]


# ---------------------------------------------------------------------------
# Multi-step decode horizon (plan arithmetic)
# ---------------------------------------------------------------------------

def test_decode_horizon_defaults_to_one():
    s = Scheduler(batch_slots=2, chunk_tokens=8)
    plan = s.plan_step(n_active=2, prefilling=collections.OrderedDict(),
                       try_admit=lambda: None)
    assert plan.decode_steps == 1


def test_decode_horizon_schedule_and_headroom_cap():
    s = Scheduler(batch_slots=2, chunk_tokens=8, max_decode_steps=32)
    assert s.k_schedule == [1, 2, 4, 8, 16, 32]
    none = collections.OrderedDict()

    def plan(headroom):
        return s.plan_step(n_active=2, prefilling=none,
                           try_admit=lambda: None, min_headroom=headroom)

    # unconstrained -> the full horizon; headroom caps it; non-power-of-two
    # headroom rounds *down* to a compiled schedule entry
    assert plan(None).decode_steps == 32
    assert plan(50).decode_steps == 32
    assert plan(8).decode_steps == 8
    assert plan(7).decode_steps == 4
    assert plan(1).decode_steps == 1
    assert plan(0).decode_steps == 1          # budget-0 slot: still sane
    # a non-power-of-two max is itself in the schedule
    s7 = Scheduler(batch_slots=2, max_decode_steps=7)
    assert s7.k_schedule == [1, 2, 4, 7]
    assert s7.plan_step(n_active=1, prefilling=none,
                        try_admit=lambda: None,
                        min_headroom=20).decode_steps == 7


def test_decode_horizon_collapses_under_prefill_work():
    s = Scheduler(batch_slots=2, chunk_tokens=8, max_decode_steps=16)
    # pending prefill (chunks will be planned) -> collapse to 1
    prefilling = collections.OrderedDict([(0, _pp(0, 0, 20))])
    plan = s.plan_step(n_active=1, prefilling=prefilling,
                       try_admit=lambda: None, min_headroom=16)
    assert plan.chunks and plan.decode_steps == 1
    # a fresh admission this step -> collapse (its first token must not
    # wait out a long scan); chunked and legacy admissions alike
    admitted = [_pp(1, 0, 6)]
    plan = s.plan_step(n_active=1, prefilling=collections.OrderedDict(),
                       try_admit=lambda: admitted.pop() if admitted
                       else None, min_headroom=16)
    assert plan.admitted == 1 and plan.decode_steps == 1
    legacy = Scheduler(batch_slots=2, max_decode_steps=16)
    grants = [MONOLITHIC]
    plan = legacy.plan_step(n_active=1, prefilling=collections.OrderedDict(),
                            try_admit=lambda: grants.pop() if grants
                            else None, min_headroom=16)
    assert plan.admitted == 1 and plan.decode_steps == 1
    # nothing pending -> full horizon again
    plan = legacy.plan_step(n_active=1, prefilling=collections.OrderedDict(),
                            try_admit=lambda: None, min_headroom=16)
    assert plan.decode_steps == 16


def test_scheduler_rejects_bad_max_decode_steps():
    with pytest.raises(ValueError, match="max_decode_steps"):
        Scheduler(batch_slots=2, max_decode_steps=0)


# ---------------------------------------------------------------------------
# Engine-level exactness: the acceptance contract
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_chunked_matches_unchunked_ring():
    lm, params = _lm(_tiny_cfg())
    trace = _mixed_trace(n=7, seed=2)
    _, base = _run(lm, params, trace, batch_slots=3)
    for kw in (dict(chunk_tokens=4),
               dict(chunk_tokens=4, token_budget=5),
               dict(chunk_tokens=8, token_budget=32)):
        _, out = _run(lm, params, trace, batch_slots=3, **kw)
        _assert_same(base, out)


@pytest.mark.slow
def test_chunked_matches_unchunked_paged():
    lm, params = _lm(_tiny_cfg())
    trace = _mixed_trace(n=7, seed=3)
    _, base = _run(lm, params, trace, batch_slots=3)
    # ample and starved pools (block pressure delays admission mid-trace)
    for extra in ({}, {"num_pool_blocks": 9}):
        _, out = _run(lm, params, trace, batch_slots=3, chunk_tokens=4,
                      cache_backend="paged", block_size=8, **extra)
        _assert_same(base, out)


@pytest.mark.slow
def test_chunked_matches_unchunked_mla():
    lm, params = _lm(_mla_cfg())
    trace = _mixed_trace(n=5, seed=4)
    _, base = _run(lm, params, trace, batch_slots=2)
    _, out = _run(lm, params, trace, batch_slots=2, chunk_tokens=4,
                  cache_backend="paged", block_size=8)
    _assert_same(base, out)


@pytest.mark.slow
def test_chunked_windowed_paged_matches_oracle():
    """Windowed layers through the paged chunked path: exact against the
    step-by-step full-forward oracle (chunk install is position-addressed,
    so nothing in the window is ever evicted early)."""
    import jax.numpy as jnp
    lm, params = _lm(_tiny_cfg(window=8))
    trace = _mixed_trace(n=4, seed=5)
    _, base = _run(lm, params, trace, batch_slots=2)
    _, out = _run(lm, params, trace, batch_slots=2, chunk_tokens=4,
                  cache_backend="paged", block_size=8)
    _assert_same(base, out)
    # one request against the autoregressive full-forward ground truth
    prompt, budget = trace[0]
    cur = list(prompt)
    for _ in range(budget):
        logits, _, _, _ = lm.forward(
            params, {"tokens": jnp.asarray(np.asarray(cur)[None], jnp.int32)})
        cur.append(int(jnp.argmax(logits[0, -1])))
    np.testing.assert_array_equal(out[0], np.asarray(cur[len(prompt):]))


def test_chunked_refuses_windowed_ring():
    """Ring + window: a window-wide ring evicts tokens the chunk's own
    queries still need — must refuse at construction, not corrupt."""
    lm, params = _lm(_tiny_cfg(window=8))
    with pytest.raises(NotImplementedError, match="paged backend"):
        ServingEngine(lm, params, batch_slots=2, max_seq_len=32,
                      chunk_tokens=4)


def test_chunked_refuses_recurrent_mixers():
    from repro.configs import get_config
    cfg = get_config("recurrentgemma-9b")
    lm = LM(cfg)
    with pytest.raises(NotImplementedError, match="attention mixers"):
        ServingEngine(lm, params=None, batch_slots=2, max_seq_len=32,
                      chunk_tokens=4)


# ---------------------------------------------------------------------------
# Prefix sharing + copy-on-write
# ---------------------------------------------------------------------------

def _templated_trace(n=6, seed=6, template_len=16, include_exact=True):
    rng = np.random.default_rng(seed)
    template = rng.integers(0, 60, size=template_len).astype(np.int32)
    trace = [(np.concatenate([
        template, rng.integers(0, 60, size=int(rng.integers(1, 8)))
        .astype(np.int32)]), int(rng.integers(3, 7))) for _ in range(n - 1)]
    if include_exact:
        # block-aligned full-cover prompt: admission must COW the final
        # shared block before recomputing the last token
        trace.append((template.copy(), 5))
    return trace


@pytest.mark.slow
def test_shared_prefix_exact_and_skips_prefill():
    lm, params = _lm(_tiny_cfg())
    trace = _templated_trace()
    _, base = _run(lm, params, trace, batch_slots=3)
    eng, out = _run(lm, params, trace, batch_slots=3, chunk_tokens=8,
                    cache_backend="paged", block_size=8)
    _assert_same(base, out)
    assert eng.prefill_tokens_skipped > 0
    assert eng.prefill_tokens_skipped < eng.prefill_tokens_total
    be = eng.backend
    assert be.cow_copies >= 1               # the exact-template admission
    # accounting invariant: everything returned, refcounts all zero; the
    # template's blocks are *retained* (indexed, LRU tail of the free
    # list) for cross-run sharing rather than dropped at refcount 0
    assert be.blocks_in_use == 0
    assert be._ref == {}
    assert set(be._index.values()) == set(be._free_cached)
    assert sorted(be._free) == list(range(1, be.num_blocks))
    be.assert_invariants()


@pytest.mark.slow
def test_cow_divergence_matches_solo_runs():
    """Two identical block-aligned prompts with different budgets and
    temperatures share every prompt block; the second admission copies the
    final block (COW) and both decode streams must match their solo runs
    token-for-token — sharing never lets one request's divergence leak
    into another's cache."""
    lm, params = _lm(_tiny_cfg())
    rng = np.random.default_rng(7)
    template = rng.integers(0, 60, size=16).astype(np.int32)
    kw = dict(batch_slots=2, chunk_tokens=8, cache_backend="paged",
              block_size=8)

    def solo(rid, max_new, temperature):
        # same request_id (submission order) so sampling keys line up
        eng = ServingEngine(lm, params, max_seq_len=32, min_bucket=4, **kw)
        for _ in range(rid):
            eng.submit(np.arange(4), max_new_tokens=1)
        eng.submit(template, max_new_tokens=max_new,
                   temperature=temperature)
        return eng.run()[rid].output

    eng = ServingEngine(lm, params, max_seq_len=32, min_bucket=4, **kw)
    # rid 0: owns the template blocks and decodes long enough that rid 2
    # is admitted (into rid 1's freed slot) while they are still live;
    # rid 2's identical block-aligned prompt then shares all of them and
    # must COW the final block before recomputing its last-token logits
    eng.submit(template, max_new_tokens=8, temperature=0.0)
    eng.submit(np.arange(4), max_new_tokens=1)
    eng.submit(template, max_new_tokens=4, temperature=0.9)
    done = eng.run()
    assert eng.backend.cow_copies >= 1
    np.testing.assert_array_equal(done[0].output, solo(0, 8, 0.0))
    np.testing.assert_array_equal(done[2].output, solo(2, 4, 0.9))
    assert eng.backend.blocks_in_use == 0


def test_sharing_disabled_skips_nothing():
    lm, params = _lm(_tiny_cfg())
    trace = _templated_trace(n=4)
    eng, _ = _run(lm, params, trace, batch_slots=2, chunk_tokens=8,
                  cache_backend="paged", block_size=8, prefix_sharing=False)
    assert eng.prefill_tokens_skipped == 0
    assert eng.backend.cow_copies == 0


# ---------------------------------------------------------------------------
# Per-request sampling keys (satellite regression)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_sampled_outputs_independent_of_coscheduling():
    """temperature > 0 outputs are a pure function of (request_id, step):
    the same submissions through different slot counts — and through the
    chunked scheduler — sample identical streams."""
    lm, params = _lm(_tiny_cfg())
    trace = _mixed_trace(n=6, seed=8)
    outs = []
    for kw in (dict(batch_slots=1), dict(batch_slots=4),
               dict(batch_slots=3, chunk_tokens=4),
               dict(batch_slots=3, chunk_tokens=4, cache_backend="paged",
                    block_size=8)):
        _, out = _run(lm, params, trace, temperature=0.8, **kw)
        outs.append(out)
    for other in outs[1:]:
        _assert_same(outs[0], other)


def test_ttft_and_admit_recorded():
    lm, params = _lm(_tiny_cfg())
    from repro.serving import DrainBatchEngine
    for cls, kw in ((ServingEngine, dict(min_bucket=4)),
                    (ServingEngine, dict(min_bucket=4, chunk_tokens=4)),
                    (DrainBatchEngine, {})):
        eng = cls(lm, params, batch_slots=2, max_seq_len=32, **kw)
        for prompt, max_new in _mixed_trace(n=3, seed=9):
            eng.submit(prompt, max_new_tokens=max_new)
        for r in eng.run().values():
            assert r.admit_s >= r.submit_s > 0
            assert 0 < r.ttft_s <= r.latency_s
