"""Multi-step fused decode: the engine scans K decode steps per host sync.

The acceptance contract is *exactness at every K*: greedy and fixed
``(request_id, step)``-keyed sampled outputs must be token-for-token
identical to the step-by-step (K = 1) engine on all four cache
configurations — ring, paged, MLA and windowed-paged — including requests
that finish mid-scan (EOS or budget) and paged slots whose blocks are
granted by look-ahead reservation just ahead of each scan."""
import jax
import numpy as np
import pytest

from repro.configs.base import (MLA, SWIGLU, BlockDef, MLAConfig, ModelConfig,
                                Stage, dense_stages)
from repro.models.model import LM
from repro.serving import ServingEngine


def _tiny_cfg(layers=2, window=None):
    return ModelConfig(
        name="tiny", family="dense", source="t", num_layers=layers,
        d_model=32, num_heads=4, num_kv_heads=2, head_dim=8, d_ff=64,
        vocab_size=64, stages=dense_stages(layers, window=window),
        param_dtype="float32")


def _mla_cfg():
    return ModelConfig(
        name="tiny-mla", family="mla", source="t", num_layers=2,
        d_model=32, num_heads=4, num_kv_heads=4, head_dim=8, d_ff=64,
        vocab_size=64,
        stages=(Stage(blocks=(BlockDef(mixer=MLA, mlp=SWIGLU),), repeat=2),),
        param_dtype="float32",
        mla=MLAConfig(q_lora_rank=16, kv_lora_rank=8, qk_nope_head_dim=8,
                      qk_rope_head_dim=4, v_head_dim=8))


def _lm(cfg):
    lm = LM(cfg, kv_chunk=8)
    params, _ = lm.init(jax.random.PRNGKey(0))
    return lm, params


def _mixed_trace(n=6, seed=1, budgets=(3, 12)):
    rng = np.random.default_rng(seed)
    return [(rng.integers(0, 60, size=int(rng.integers(3, 12))),
             int(rng.integers(*budgets))) for _ in range(n)]


def _run(lm, params, trace, temperature=0.0, **kw):
    eng = ServingEngine(lm, params, max_seq_len=32, min_bucket=4, **kw)
    for prompt, max_new in trace:
        eng.submit(prompt, max_new_tokens=max_new, temperature=temperature)
    return eng, {rid: r.output for rid, r in eng.run().items()}


def _assert_same(a, b):
    assert set(a) == set(b)
    for rid in a:
        np.testing.assert_array_equal(a[rid], b[rid])


K_SWEEP = (1, 2, 7, 32)

CONFIGS = {
    "ring": (_tiny_cfg, {}),
    "paged": (_tiny_cfg, dict(cache_backend="paged", block_size=8)),
    "mla": (_mla_cfg, dict(cache_backend="paged", block_size=8)),
    "windowed_paged": (lambda: _tiny_cfg(window=8),
                       dict(cache_backend="paged", block_size=8)),
}


# ---------------------------------------------------------------------------
# K-sweep equivalence: the acceptance contract
# ---------------------------------------------------------------------------

@pytest.mark.slow
@pytest.mark.parametrize("name", sorted(CONFIGS))
def test_k_sweep_matches_step_by_step_greedy(name):
    cfg_fn, kw = CONFIGS[name]
    lm, params = _lm(cfg_fn())
    trace = _mixed_trace(n=6, seed=2)
    base_eng, base = _run(lm, params, trace, batch_slots=3, **kw)
    for k in K_SWEEP[1:]:
        eng, out = _run(lm, params, trace, batch_slots=3,
                        max_decode_steps=k, **kw)
        _assert_same(base, out)
        # the whole point: fewer host syncs for the same tokens
        assert eng.host_syncs < base_eng.host_syncs, k
        if hasattr(eng.backend, "assert_invariants"):
            eng.backend.assert_invariants()


@pytest.mark.slow
@pytest.mark.parametrize("name", ("ring", "paged"))
def test_k_sweep_matches_step_by_step_sampled(name):
    """temperature > 0: keys fold the carried (request_id, step), so a
    K-scan consumes exactly the keys K single-step rounds would."""
    cfg_fn, kw = CONFIGS[name]
    lm, params = _lm(cfg_fn())
    trace = _mixed_trace(n=6, seed=3)
    _, base = _run(lm, params, trace, temperature=0.8, batch_slots=3, **kw)
    for k in K_SWEEP[1:]:
        _, out = _run(lm, params, trace, temperature=0.8, batch_slots=3,
                      max_decode_steps=k, **kw)
        _assert_same(base, out)


@pytest.mark.slow
def test_k_sweep_with_chunked_prefill_and_sharing():
    """Multi-step decode composes with the token-budget scheduler: the
    horizon collapses to 1 while chunks are pending, then scales back up —
    outputs still match the unchunked K=1 engine, shared prefixes and all."""
    lm, params = _lm(_tiny_cfg())
    rng = np.random.default_rng(4)
    template = rng.integers(0, 60, size=16).astype(np.int32)
    trace = [(np.concatenate([template, rng.integers(0, 60, size=int(
        rng.integers(1, 8))).astype(np.int32)]), int(rng.integers(3, 9)))
        for _ in range(5)]
    _, base = _run(lm, params, trace, batch_slots=3)
    for k in (2, 32):
        eng, out = _run(lm, params, trace, batch_slots=3, chunk_tokens=8,
                        cache_backend="paged", block_size=8,
                        max_decode_steps=k)
        _assert_same(base, out)
        eng.backend.assert_invariants()


# ---------------------------------------------------------------------------
# Mid-scan completion
# ---------------------------------------------------------------------------

def test_eos_mid_scan_stops_exactly():
    """A request hitting EOS *inside* a scan goes inactive on device and
    no-ops through the remaining iterations: output is cut at the EOS
    token, the cache takes no junk writes, and the slot frees at the
    sync."""
    lm, params = _lm(_tiny_cfg())
    probe = ServingEngine(lm, params, batch_slots=1, max_seq_len=32,
                          min_bucket=4)
    probe.submit(np.arange(5), max_new_tokens=8)
    greedy = probe.run()[0].output
    # EOS = the third greedy token: the first round after admission is a
    # collapsed k=1 (freshness), so this EOS lands mid-way through the
    # *second* round's multi-step scan
    eos = int(greedy[2])
    expect = list(greedy[:list(greedy).index(eos) + 1])
    for kw in ({}, dict(cache_backend="paged", block_size=8)):
        eng = ServingEngine(lm, params, batch_slots=1, max_seq_len=32,
                            min_bucket=4, eos_id=eos, max_decode_steps=8,
                            **kw)
        eng.submit(np.arange(5), max_new_tokens=8)
        out = eng.run()[0].output
        assert list(out) == expect
        assert eng.host_syncs <= 2           # k=1 arming round + one scan


def test_budget_exhaustion_mid_scan():
    """Mixed budgets inside one scan: the horizon is capped by the
    *smallest* headroom, so larger-budget slots keep scanning across
    rounds while small ones finish exactly at their budget."""
    lm, params = _lm(_tiny_cfg())
    eng = ServingEngine(lm, params, batch_slots=3, max_seq_len=32,
                        min_bucket=4, max_decode_steps=8)
    base = ServingEngine(lm, params, batch_slots=3, max_seq_len=32,
                         min_bucket=4)
    for e in (eng, base):
        e.submit(np.arange(4), max_new_tokens=3)
        e.submit(np.arange(6), max_new_tokens=8)
        e.submit(np.arange(2), max_new_tokens=5)
    done, ref = eng.run(), base.run()
    for rid, r in ref.items():
        assert len(done[rid].output) == len(r.output)
        np.testing.assert_array_equal(done[rid].output, r.output)
    assert eng.host_syncs < base.host_syncs


# ---------------------------------------------------------------------------
# Paged look-ahead reservation
# ---------------------------------------------------------------------------

def test_lookahead_reservation_returns_unused_blocks():
    """Early EOS leaves committed budget blocks undrawn, and whatever was
    drawn returns at completion: the free list is full after the run and
    the total draw is below the eager worst case."""
    lm, params = _lm(_tiny_cfg())
    probe = ServingEngine(lm, params, batch_slots=1, max_seq_len=32,
                          min_bucket=4)
    probe.submit(np.arange(5), max_new_tokens=1)
    eos = int(probe.run()[0].output[0])
    eng = ServingEngine(lm, params, batch_slots=2, max_seq_len=32,
                        min_bucket=4, cache_backend="paged", block_size=8,
                        eos_id=eos, max_decode_steps=8)
    trace = [(np.arange(5), 24), (np.arange(7), 24)]
    worst = sum(eng.backend.blocks_needed(len(p), mn) for p, mn in trace)
    for prompt, max_new in trace:
        eng.submit(prompt, max_new_tokens=max_new)
    eng.run()
    be = eng.backend
    assert be.blocks_allocated_total < worst          # budget tail undrawn
    assert be.blocks_in_use == 0                      # drawn blocks returned
    assert sorted(be._free) == list(range(1, be.num_blocks))
    assert be._gap_total == 0                         # commitments released
    be.assert_invariants()


def test_lookahead_covers_exactly_the_scan():
    """Block draws track the decode frontier: a long-budget request draws
    blocks as its scans reach them, never all upfront."""
    lm, params = _lm(_tiny_cfg())
    eng = ServingEngine(lm, params, batch_slots=1, max_seq_len=32,
                        min_bucket=4, cache_backend="paged", block_size=8,
                        max_decode_steps=4)
    eng.submit(np.arange(4), max_new_tokens=20)       # 3 blocks worst-case
    eng.step()                                        # admission (+ arming)
    be = eng.backend
    assert be.blocks_allocated_total == 1             # prompt block only
    while eng.pending:
        eng.step()
    assert be.blocks_allocated_total == 3             # drawn by look-ahead
    assert be.lookahead_topups >= 2
    be.assert_invariants()


# ---------------------------------------------------------------------------
# Cross-run prefix retention (ROADMAP item)
# ---------------------------------------------------------------------------

def test_prefix_cache_survives_across_runs():
    """Templated traffic shares across *bursts*: after the engine fully
    drains, a later run with the same template revives the retained
    blocks instead of recomputing the prefix."""
    lm, params = _lm(_tiny_cfg())
    eng = ServingEngine(lm, params, batch_slots=2, max_seq_len=32,
                        min_bucket=4, cache_backend="paged", block_size=8,
                        chunk_tokens=8, max_decode_steps=4)
    template = np.arange(16, dtype=np.int32)
    eng.submit(template, max_new_tokens=4)
    eng.run()                                         # burst 1 drains fully
    assert eng.prefill_tokens_skipped == 0
    assert len(eng.backend._index) == 2
    eng.submit(np.concatenate([template, np.array([3, 4], np.int32)]),
               max_new_tokens=4)
    eng.run()                                         # burst 2, much later
    assert eng.prefill_tokens_skipped == 16           # whole template shared
    assert eng.backend.retained_block_hits == 2
    eng.backend.assert_invariants()


def test_retained_blocks_are_reclaimed_lru_last():
    """Retention never blocks allocation: when fresh traffic needs the
    whole pool, cached blocks are evicted (plain first, then LRU) and the
    run proceeds as if retention were off."""
    lm, params = _lm(_tiny_cfg())
    eng = ServingEngine(lm, params, batch_slots=2, max_seq_len=32,
                        min_bucket=4, cache_backend="paged", block_size=8,
                        chunk_tokens=8, num_pool_blocks=7,  # 6 usable
                        max_decode_steps=4)
    template = np.arange(16, dtype=np.int32)
    eng.submit(template, max_new_tokens=4)
    eng.run()
    assert len(eng.backend._free_cached) == 2
    rng = np.random.default_rng(5)
    outs = {}
    for _ in range(3):                                # 3 x 3 blocks > pool
        rid = eng.submit(rng.integers(0, 60, size=20), max_new_tokens=4)
        outs[rid] = None
    done = eng.run()
    assert set(done) == set(outs)
    eng.backend.assert_invariants()


# ---------------------------------------------------------------------------
# Warm compile
# ---------------------------------------------------------------------------

def test_warm_compile_covers_scan_horizons():
    """``warm_compile`` pre-runs every horizon in the K schedule (and the
    single step) without observable effect: the same trace then produces
    identical outputs with zero new decode compiles mid-traffic."""
    lm, params = _lm(_tiny_cfg())
    trace = _mixed_trace(n=4, seed=6)
    _, base = _run(lm, params, trace, batch_slots=2, max_decode_steps=8)
    eng = ServingEngine(lm, params, batch_slots=2, max_seq_len=32,
                        min_bucket=4, max_decode_steps=8,
                        cache_backend="paged", block_size=8,
                        chunk_tokens=8)
    eng.warm_compile()
    compiles_after_warm = eng._scan_fn._cache_size()
    assert compiles_after_warm == len(
        [k for k in eng.scheduler.k_schedule if k > 1])
    for prompt, max_new in trace:
        eng.submit(prompt, max_new_tokens=max_new)
    out = {rid: r.output for rid, r in eng.run().items()}
    _assert_same(base, out)
    assert eng._scan_fn._cache_size() == compiles_after_warm
