"""Sharding-rule unit tests (mesh-shape logic, no 512 devices needed)."""
import jax
import pytest
from jax.sharding import PartitionSpec as PS

from repro import sharding as sh
from repro.models import param as P


class FakeMesh:
    """Only .shape is consulted by resolve()."""
    def __init__(self, **shape):
        self.shape = shape


RULES = {
    P.EMBED: ("data",),
    P.EMBED_OUT: ("data",),
    P.VOCAB: "model",
    P.HEADS: "model",
    P.MLP: "model",
    P.EXPERT: "model",
    P.STACK: None,
}


def test_resolve_divisibility_guard():
    mesh = FakeMesh(data=16, model=16)
    # 9 heads cannot shard 16 ways -> replicated on that dim
    spec = sh.resolve(RULES, (P.EMBED, P.HEADS, None), shape=(576, 9, 64),
                      mesh=mesh)
    assert spec == PS("data", None, None)
    spec = sh.resolve(RULES, (P.EMBED, P.HEADS, None), shape=(576, 32, 64),
                      mesh=mesh)
    assert spec == PS("data", "model", None)


def test_resolve_no_axis_reuse():
    mesh = FakeMesh(data=16, model=16)
    # deepseek expert weights: EXPERT wins 'model', MLP must not reuse it
    spec = sh.resolve(RULES, (P.EXPERT, P.EMBED, P.MLP),
                      shape=(256, 7168, 2048), mesh=mesh)
    assert spec == PS("model", "data", None)
    # mixtral: EXPERT not divisible -> MLP gets 'model'
    spec = sh.resolve(RULES, (P.EXPERT, P.EMBED, P.MLP),
                      shape=(8, 6144, 16384), mesh=mesh)
    assert spec == PS(None, "data", "model")


def test_resolve_multi_axis():
    mesh = FakeMesh(pod=2, data=16, model=16)
    rules = dict(RULES)
    rules[P.EMBED] = ("pod", "data")
    spec = sh.resolve(rules, (P.VOCAB, P.EMBED), shape=(49152, 576),
                      mesh=mesh)
    assert spec == PS("model", ("pod", "data"))
    # 576 % 32 == 0; a non-divisible dim drops the whole group
    spec = sh.resolve(rules, (P.VOCAB, P.EMBED), shape=(49152, 100),
                      mesh=mesh)
    assert spec == PS("model", None)


def test_decode_param_rules():
    from repro.launch.sharding_rules import param_rules
    mesh = FakeMesh(data=16, model=16)
    train = param_rules(mesh, "train")
    decode = param_rules(mesh, "decode")
    assert train[P.EMBED_OUT] == ("data",)
    assert decode[P.EMBED_OUT] is None
    assert decode[P.EXPERT] == ("data", "model")


def test_hint_noop_without_context():
    import jax.numpy as jnp
    x = jnp.ones((4, 8))
    assert sh.hint(x, (sh.BATCH, None)) is x


def test_hint_applies_constraint_under_mesh():
    import jax.numpy as jnp
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    with sh.use_rules(mesh, {sh.BATCH: ("data",)}):
        y = sh.hint(jnp.ones((4, 8)), (sh.BATCH, None))
    assert y.shape == (4, 8)


def test_abstract_params_have_full_axis_coverage():
    """Every parameter leaf carries logical axes of matching rank."""
    from repro.configs import get_config
    from repro.models.model import LM
    lm = LM(get_config("mixtral-8x22b").reduced())
    params, axes = lm.abstract()
    flat_p = jax.tree.leaves(params)
    flat_a = jax.tree.leaves(axes, is_leaf=lambda x: isinstance(x, tuple))
    assert len(flat_p) == len(flat_a)
    for leaf, ax in zip(flat_p, flat_a):
        assert len(ax) == leaf.ndim, (leaf.shape, ax)
