"""Resource-level services (paper §4.3.2, Fig. 2): topic bridging, control/
data-flow separation, simulated WAN timing."""
import pytest

from repro.core.ids import IdAllocator
from repro.core.network import NetworkModel
from repro.core.pubsub import MessageService
from repro.core.services.file_service import FileService
from repro.core.services.object_store import ObjectStore
from repro.core.sim import SimClock


def _clusters():
    ids = IdAllocator()
    infra = ids.new_infra()
    cc = ids.new_cluster(infra, "cc")
    ec1 = ids.new_cluster(infra, "ec")
    ec2 = ids.new_cluster(infra, "ec")
    return cc, ec1, ec2


def test_local_delivery_and_bridging():
    cc, ec1, ec2 = _clusters()
    clock = SimClock()
    msg = MessageService([cc, ec1, ec2], clock, network=None)
    got = {"cc": [], "ec1": [], "ec2": []}
    msg.broker(cc).subscribe("app/*", lambda m: got["cc"].append(m.topic))
    msg.broker(ec1).subscribe("app/*", lambda m: got["ec1"].append(m.topic))
    msg.broker(ec2).subscribe("app/*", lambda m: got["ec2"].append(m.topic))
    # EC1 publish reaches the CC through the bridge (link (2) of Fig. 2)...
    msg.broker(ec1).publish("app/result", {"v": 1}, src="comp-a")
    assert got["cc"] == ["app/result"]
    assert got["ec1"] == ["app/result"]          # local subscribers too
    # ...and is re-broadcast to the other EC via the CC bridge
    assert got["ec2"] == ["app/result"]


def test_bridge_no_loops():
    cc, ec1, _ = _clusters()
    clock = SimClock()
    msg = MessageService([cc, ec1], clock, network=None)
    count = {"n": 0}
    msg.broker(cc).subscribe("t/*", lambda m: count.__setitem__("n", count["n"] + 1))
    msg.broker(ec1).publish("t/x", 1, src="a")
    assert count["n"] == 1                       # exactly once, no echo storm


def test_wan_timing_on_bridge():
    cc, ec1, _ = _clusters()
    clock = SimClock()
    net = NetworkModel(clock, uplink_mbps=8.0, wan_delay_s=0.05)
    msg = MessageService([cc, ec1], clock, network=net)
    seen = []
    msg.broker(cc).subscribe("big/*", lambda m: seen.append(clock.now))
    msg.broker(ec1).publish("big/blob", b"", nbytes=1_000_000, src="a")
    assert not seen                              # not yet delivered
    clock.run()
    # 1 MB over 8 Mbps = 1.0 s + 50 ms delay
    assert seen and abs(seen[0] - 1.05) < 1e-6


def test_link_serialization_creates_backlog():
    cc, ec1, _ = _clusters()
    clock = SimClock()
    net = NetworkModel(clock, uplink_mbps=8.0)
    arrivals = []
    for _ in range(3):
        net.send(ec1, cc, 1_000_000, lambda: arrivals.append(clock.now))
    clock.run()
    assert [round(a, 3) for a in arrivals] == [1.0, 2.0, 3.0]
    assert net.wan_bytes() == 3_000_000


def test_file_service_control_data_separation():
    cc, ec1, ec2 = _clusters()
    clock = SimClock()
    net = NetworkModel(clock, uplink_mbps=80.0, downlink_mbps=80.0,
                       wan_delay_s=0.01)
    msg = MessageService([cc, ec1, ec2], clock, network=net)
    store = ObjectStore()
    files = FileService(msg, store, net, clock, cc)

    control_msgs = []
    files.on_available(ec2, "models/*", control_msgs.append)
    fetched = []
    files.put("models", "eoc-v1", {"weights": [1, 2, 3]}, nbytes=500_000,
              src_cluster=ec1)
    clock.run()
    # control notification crossed the bridge; data is in the CC store
    assert control_msgs and control_msgs[0]["key"] == "eoc-v1"
    assert store.get("models", "eoc-v1") is not None
    files.get("models", "eoc-v1", ec2, fetched.append)
    clock.run()
    assert fetched == [{"weights": [1, 2, 3]}]


def test_object_store_lifecycle():
    store = ObjectStore()
    store.put("b", "temp1", 1, 10, lifecycle="temporary")
    store.put("b", "final", 2, 10, lifecycle="permanent")
    assert store.gc_temporary("b") == 1
    assert store.keys("b") == ["final"]


def test_missing_object_raises():
    cc, ec1, _ = _clusters()
    clock = SimClock()
    msg = MessageService([cc, ec1], clock, network=None)
    files = FileService(msg, ObjectStore(), None, clock, cc)
    with pytest.raises(KeyError):
        files.get("b", "nope", ec1, lambda d: None)
