"""Paged decode-attention Pallas kernel vs the jnp oracle (interpret mode):
block-table gather, GQA/MQA, sliding window, partially-filled tail blocks,
unallocated table entries, reused-pool fragmentation, freed slots."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.decode_attention import paged_decode_attention

CASES = [
    # (h, kv, hd, bs, window, fills) — fills: live tokens per slot
    (4, 4, 32, 16, None, (64, 64)),       # MHA, full tables
    (4, 2, 32, 16, None, (26, 64)),       # GQA g=2, ragged final block
    (3, 1, 32, 16, None, (48, 5)),        # MQA, short slot
    (4, 4, 32, 16, 24, (64, 64)),         # sliding window
    (8, 2, 64, 32, 16, (96, 40)),         # window + GQA g=4, bs=32
    (4, 2, 16, 8, None, (1, 63)),         # single-token slot, bs=8
]


def _paged_cache(rng, kv, hd, bs, fills, *, dtype=jnp.float32,
                 scatter_seed=None):
    """Build a pool + tables as the engine would: block 0 is trash, each
    slot's tokens [0, fill) land at (table[slot, p // bs], p % bs). With
    ``scatter_seed`` the physical block ids are shuffled (fragmented pool,
    as after many alloc/free cycles)."""
    b = len(fills)
    m = max(-(-f // bs) for f in fills)
    blocks_needed = sum(-(-f // bs) for f in fills)
    n = blocks_needed + 1
    k = jax.random.normal(rng[0], (n, bs, kv, hd)).astype(dtype)
    v = jax.random.normal(rng[1], (n, bs, kv, hd)).astype(dtype)
    order = list(range(1, n))
    if scatter_seed is not None:
        np.random.default_rng(scatter_seed).shuffle(order)
    pos = np.full((n, bs), -1, np.int32)
    bt = np.full((b, m), -1, np.int32)
    it = iter(order)
    for s, fill in enumerate(fills):
        for j in range(-(-fill // bs)):
            blk = next(it)
            bt[s, j] = blk
            for o in range(bs):
                p = j * bs + o
                if p < fill:
                    pos[blk, o] = p
    q_pos = jnp.asarray([f - 1 for f in fills], jnp.int32)
    return k, v, jnp.asarray(pos), jnp.asarray(bt), q_pos


@pytest.mark.parametrize("case", CASES, ids=[str(c) for c in CASES])
def test_paged_kernel_matches_oracle(case):
    h, kv, hd, bs, window, fills = case
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (len(fills), 1, h, hd))
    k, v, pos, bt, q_pos = _paged_cache(ks[1:], kv, hd, bs, fills)
    out = paged_decode_attention(q, k, v, q_pos, pos, bt, window=window,
                                 interpret=True)
    expect = ref.paged_decode_attention_ref(q, k, v, q_pos, pos, bt,
                                            window=window)
    assert out.shape == q.shape
    assert float(jnp.max(jnp.abs(out - expect))) < 1e-4


CHUNK_CASES = [
    # (h, kv, hd, bs, window, fills, chunk)
    (4, 2, 32, 16, None, (40, 64), 8),    # GQA chunk
    (4, 4, 32, 16, None, (26, 64), 5),    # ragged final block
    (8, 2, 64, 32, 16, (96, 40), 8),      # window + GQA g=4
]


@pytest.mark.parametrize("case", CHUNK_CASES,
                         ids=[str(c) for c in CHUNK_CASES])
def test_paged_chunk_queries_match_oracle(case):
    """Chunked prefill through the block-table gather: T-token queries
    whose K/V already sit in their pool blocks."""
    h, kv, hd, bs, window, fills, chunk = case
    ks = jax.random.split(jax.random.PRNGKey(6), 3)
    q = jax.random.normal(ks[0], (len(fills), chunk, h, hd))
    k, v, pos, bt, _ = _paged_cache(ks[1:], kv, hd, bs, fills)
    q_pos = jnp.asarray([f - chunk for f in fills], jnp.int32)  # chunk start
    out = paged_decode_attention(q, k, v, q_pos, pos, bt, window=window,
                                 interpret=True)
    expect = ref.paged_decode_attention_ref(q, k, v, q_pos, pos, bt,
                                            window=window)
    assert out.shape == q.shape
    assert float(jnp.max(jnp.abs(out - expect))) < 1e-4


def test_paged_chunk_matches_gathered_ring_oracle():
    """Chunk attention through tables == the ring oracle over the
    gathered-contiguous equivalent of the same pool."""
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    bs, fills, chunk = 16, (26, 64), 6
    q = jax.random.normal(ks[0], (2, chunk, 4, 32))
    k, v, pos, bt, _ = _paged_cache(ks[1:], 2, 32, bs, fills)
    q_pos = jnp.asarray([f - chunk for f in fills], jnp.int32)
    out = paged_decode_attention(q, k, v, q_pos, pos, bt, interpret=True)
    kc, pc = ref.gather_paged_kv(k, pos, bt)
    vc, _ = ref.gather_paged_kv(v, pos, bt)
    ring = ref.decode_attention_ref(q, kc, vc, q_pos, pc)
    assert float(jnp.max(jnp.abs(out - ring))) < 1e-4


def test_fragmented_pool():
    """Block ids need not be contiguous or ordered — the table is the only
    source of layout truth (the pool state after many alloc/free cycles)."""
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (3, 1, 4, 32))
    k, v, pos, bt, q_pos = _paged_cache(ks[1:], 2, 32, 16, (40, 64, 17),
                                        scatter_seed=7)
    out = paged_decode_attention(q, k, v, q_pos, pos, bt, interpret=True)
    expect = ref.paged_decode_attention_ref(q, k, v, q_pos, pos, bt)
    assert float(jnp.max(jnp.abs(out - expect))) < 1e-4


def test_freed_slot_is_fully_masked():
    """A freed slot's table is all −1: both kernel and oracle must return
    exactly zero (the engine keeps finished slots in the batch until the
    host reaps them)."""
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (2, 1, 4, 32))
    k, v, pos, bt, q_pos = _paged_cache(ks[1:], 2, 32, 16, (32, 32))
    bt = bt.at[1].set(-1)
    out = paged_decode_attention(q, k, v, q_pos, pos, bt, interpret=True)
    expect = ref.paged_decode_attention_ref(q, k, v, q_pos, pos, bt)
    assert bool(jnp.all(out[1] == 0))
    assert float(jnp.max(jnp.abs(out - expect))) < 1e-4


def test_matches_ring_kernel_on_same_context():
    """Paged attention over a gathered-contiguous layout must equal the ring
    oracle over the equivalent (B, W, KV, hd) cache."""
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    bs, fills = 16, (26, 64)
    q = jax.random.normal(ks[0], (2, 1, 4, 32))
    k, v, pos, bt, q_pos = _paged_cache(ks[1:], 2, 32, bs, fills)
    out = paged_decode_attention(q, k, v, q_pos, pos, bt, interpret=True)
    kc, pc = ref.gather_paged_kv(k, pos, bt)
    vc, _ = ref.gather_paged_kv(v, pos, bt)
    ring = ref.decode_attention_ref(q, kc, vc, q_pos, pc)
    assert float(jnp.max(jnp.abs(out - ring))) < 1e-4


def test_bf16_pool():
    ks = jax.random.split(jax.random.PRNGKey(4), 3)
    q = jax.random.normal(ks[0], (2, 1, 4, 32)).astype(jnp.bfloat16)
    k, v, pos, bt, q_pos = _paged_cache(ks[1:], 2, 32, 16, (40, 64),
                                        dtype=jnp.bfloat16)
    out = paged_decode_attention(q, k, v, q_pos, pos, bt, interpret=True)
    expect = ref.paged_decode_attention_ref(q, k, v, q_pos, pos, bt)
    assert out.dtype == jnp.bfloat16
    assert float(jnp.max(jnp.abs(out.astype(jnp.float32)
                                 - expect.astype(jnp.float32)))) < 2e-2


def test_ops_dispatch_wrapper():
    from repro.kernels import ops
    ks = jax.random.split(jax.random.PRNGKey(5), 3)
    q = jax.random.normal(ks[0], (2, 1, 4, 32))
    k, v, pos, bt, q_pos = _paged_cache(ks[1:], 2, 32, 16, (26, 64))
    a = ops.paged_decode_attn(q, k, v, q_pos, pos, bt, use_kernel=True,
                              interpret=True)
    b = ops.paged_decode_attn(q, k, v, q_pos, pos, bt, use_kernel=False)
    assert float(jnp.max(jnp.abs(a - b))) < 1e-4
