"""Chaos recovery: injected faults must never cost correctness.

The contract under test (ISSUE 6): with a seeded ``FaultPlan`` tripping
the serving stack's named seams — poisoned decode dispatches, failed KV
swaps, transient pool exhaustion, mid-flight cancellation, edge outage at
the cascade gate — every request that *survives* the chaos schedule
finishes token-for-token identical to the fault-free run, the paged
allocator's invariants hold after every recovery, the free list is full
after every drain (no block leaks), and the engine never livelocks
(quarantine bounds retries; backoff is measured in engine steps). The
cascade's circuit breaker must demonstrably reroute edge→cloud during an
outage and close again on a successful half-open probe.
"""
import jax
import numpy as np
import pytest

from repro.configs.base import ModelConfig, dense_stages
from repro.models.model import LM
from repro.serving import (CircuitBreaker, FaultError, FaultPlan,
                           ServingEngine)


def _tiny_cfg(layers=2, window=None):
    return ModelConfig(
        name="tiny", family="dense", source="t", num_layers=layers,
        d_model=32, num_heads=4, num_kv_heads=2, head_dim=8, d_ff=64,
        vocab_size=64, stages=dense_stages(layers, window=window),
        param_dtype="float32")


def _lm(cfg):
    lm = LM(cfg, kv_chunk=8)
    params, _ = lm.init(jax.random.PRNGKey(0))
    return lm, params


@pytest.fixture(scope="module")
def tiny():
    return _lm(_tiny_cfg())


def _mixed_trace(n=6, seed=1, budgets=(3, 12)):
    rng = np.random.default_rng(seed)
    return [(rng.integers(0, 60, size=int(rng.integers(3, 12))),
             int(rng.integers(*budgets))) for _ in range(n)]


# engine configurations the chaos sweep covers: recompute resume on the
# ring, swap resume and recompute resume on the paged pool, multi-step
# decode (the scan seam), and chunked prefill (mid-prefill state)
CONFIGS = {
    "ring_recompute": dict(cache_backend="ring"),
    "paged_swap": dict(cache_backend="paged", block_size=8,
                       num_pool_blocks=28),
    "paged_recompute": dict(cache_backend="paged", block_size=8,
                            num_pool_blocks=28, preempt_mode="recompute"),
    "paged_multistep": dict(cache_backend="paged", block_size=8,
                            num_pool_blocks=28, max_decode_steps=4),
    "paged_chunked": dict(cache_backend="paged", block_size=8,
                          num_pool_blocks=28, chunk_tokens=8),
}


def _serve(tiny, *, fault_plan=None, trace=None, temperature=0.7,
           max_steps=2000, **kw):
    """Run a trace to completion; assert allocator invariants after every
    step and bound the step count (the no-livelock guard)."""
    lm, params = tiny
    eng = ServingEngine(lm, params, batch_slots=3, max_seq_len=64,
                        min_bucket=4, fault_plan=fault_plan, **kw)
    for prompt, budget in (trace or _mixed_trace()):
        eng.submit(prompt, budget, temperature=temperature)
    steps = 0
    while eng.pending:
        eng.step()
        steps += 1
        assert steps <= max_steps, "engine livelocked under chaos"
        if hasattr(eng.backend, "assert_invariants"):
            eng.backend.assert_invariants()
    done = eng._done.copy()
    eng._done.clear()
    return eng, done


def _assert_drained_clean(eng):
    assert sorted(eng._free) == list(range(eng.batch_slots))
    be = eng.backend
    if hasattr(be, "assert_invariants"):
        be.assert_invariants()
        assert be._gap_total == 0 and be._ref == {}


def _assert_survivors_exact(done, baseline):
    survivors = {rid: r for rid, r in done.items() if r.status == "done"}
    assert survivors, "chaos killed every request — schedule too harsh"
    for rid, r in survivors.items():
        np.testing.assert_array_equal(r.output, baseline[rid].output)
    return survivors


# ---------------------------------------------------------------------------
# FaultPlan (no engine, no device)
# ---------------------------------------------------------------------------

def test_fault_plan_is_deterministic_per_seed():
    def schedule(seed):
        plan = FaultPlan(seed=seed, step={"prob": 0.3, "max_fires": 5},
                         swap_in=[1, 4])
        return [plan.fire("step") for _ in range(40)] \
            + [plan.fire("swap_in") for _ in range(6)]

    assert schedule(7) == schedule(7)
    assert schedule(7) != schedule(8)


def test_fault_plan_explicit_indices_and_bounds():
    plan = FaultPlan(seed=0, swap_in=[1, 3], step={"prob": 1.0,
                                                   "max_fires": 2})
    assert [plan.fire("swap_in") for i in range(5)] == [
        False, True, False, True, False]
    assert [plan.fire("step") for _ in range(5)] == [
        True, True, False, False, False]      # capped at max_fires
    assert plan.fired("step") == 2 and plan.fired("swap_in") == 2
    assert plan.total_fired() == 4
    assert plan.log == [("swap_in", 1), ("swap_in", 3),
                        ("step", 0), ("step", 1)]
    # unknown seams never fire but still count opportunities
    assert plan.fire("nonexistent") is False
    assert plan.opportunities("nonexistent") == 1


def test_fault_plan_check_raises_with_seam():
    plan = FaultPlan(seed=0, scan=1.0)
    with pytest.raises(FaultError, match="scan") as e:
        plan.check("scan", "decode round")
    assert e.value.seam == "scan"
    plan.check("step")                        # unconfigured seam: no-op


def test_fault_plan_pick_is_deterministic():
    a = FaultPlan(seed=5)
    b = FaultPlan(seed=5)
    items = list(range(10))
    assert [a.pick("cancel", items) for _ in range(8)] == \
        [b.pick("cancel", items) for _ in range(8)]


# ---------------------------------------------------------------------------
# Circuit breaker state machine (no engine)
# ---------------------------------------------------------------------------

def test_circuit_breaker_state_machine():
    br = CircuitBreaker(failure_threshold=2, cooldown=2)
    assert br.allow() and br.state == "closed"
    br.failure()
    assert br.state == "closed"               # one failure: still closed
    br.failure()
    assert br.state == "open" and br.trips == 1
    assert not br.allow()                     # cooldown tick 1: denied
    assert br.allow() and br.state == "half_open"   # tick 2: the probe
    br.failure()                              # probe failed: re-open
    assert br.state == "open" and br.trips == 2
    assert not br.allow()
    assert br.allow() and br.state == "half_open"
    br.success()                              # probe succeeded: closed
    assert br.state == "closed" and br.consecutive_failures == 0
    br.failure()
    br.success()                              # success resets the count
    br.failure()
    assert br.state == "closed"


# ---------------------------------------------------------------------------
# Engine chaos recovery
# ---------------------------------------------------------------------------

def test_step_fault_rolls_back_and_stays_exact(tiny):
    """A poisoned decode dispatch rolls every active slot back to a host
    checkpoint and requeues; survivors finish token-for-token identical
    to the fault-free run, with no block leak."""
    _, base = _serve(tiny, **CONFIGS["paged_swap"])
    plan = FaultPlan(seed=3, step=[2, 5, 9])
    eng, done = _serve(tiny, fault_plan=plan, max_retries=5,
                       **CONFIGS["paged_swap"])
    assert plan.fired("step") == 3
    assert eng.fault_recoveries == 3 and eng.retries_total > 0
    assert all(r.status == "done" for r in done.values())
    _assert_survivors_exact(done, base)
    _assert_drained_clean(eng)


@pytest.mark.slow
@pytest.mark.parametrize("name", sorted(CONFIGS))
def test_chaos_schedule_survivors_exact(tiny, name):
    """The full mixed schedule — step/scan faults, swap_out and swap_in
    faults, transient pool exhaustion — across every backend config:
    survivors exact, invariants after every step, clean drain."""
    kw = CONFIGS[name]
    _, base = _serve(tiny, trace=_mixed_trace(8, seed=2), **kw)
    plan = FaultPlan(seed=11,
                     step={"prob": 0.15, "max_fires": 4},
                     scan={"prob": 0.3, "max_fires": 2},
                     swap_out={"prob": 0.4, "max_fires": 2},
                     swap_in={"prob": 0.4, "max_fires": 2},
                     pool={"prob": 0.1, "max_fires": 3})
    eng, done = _serve(tiny, fault_plan=plan, trace=_mixed_trace(8, seed=2),
                       max_retries=6, **kw)
    assert plan.total_fired() > 0
    assert len(done) == 8                    # nobody wedged or lost
    _assert_survivors_exact(done, base)
    _assert_drained_clean(eng)


def test_swap_in_fault_falls_back_to_recompute(tiny):
    """A failed swap-in mid-resume drops the K/V checkpoint and resumes
    via recompute — same tokens, one retry recorded."""
    lm, params = tiny
    kw = dict(batch_slots=2, max_seq_len=64, min_bucket=4,
              cache_backend="paged", block_size=8, num_pool_blocks=24)
    base = ServingEngine(lm, params, **kw)
    rid0 = base.submit(np.arange(6), 10, temperature=0.5)
    expected = base.run()[rid0].output

    plan = FaultPlan(seed=0, swap_in=[0])    # first swap-in attempt fails
    eng = ServingEngine(lm, params, fault_plan=plan, **kw)
    rid = eng.submit(np.arange(6), 10, temperature=0.5)
    eng.step()
    eng.step()
    eng.preempt(next(iter(eng._slots)))      # swap path: checkpoint has kv
    r = eng._queue[0]
    assert r.resume is not None and r.resume.kv is not None
    done = eng.run()
    assert plan.fired("swap_in") == 1
    assert done[rid].status == "done"
    assert done[rid].retries == 1 and done[rid].last_fault == "swap_in"
    np.testing.assert_array_equal(done[rid].output, expected)
    _assert_drained_clean(eng)


def test_swap_out_fault_degrades_to_recompute(tiny):
    """A failed swap-out during preemption keeps the host checkpoint and
    frees the blocks instead — resume recomputes, output unchanged."""
    lm, params = tiny
    kw = dict(batch_slots=2, max_seq_len=64, min_bucket=4,
              cache_backend="paged", block_size=8, num_pool_blocks=24)
    base = ServingEngine(lm, params, **kw)
    rid0 = base.submit(np.arange(6), 10, temperature=0.5)
    expected = base.run()[rid0].output

    plan = FaultPlan(seed=0, swap_out=[0])
    eng = ServingEngine(lm, params, fault_plan=plan, **kw)
    rid = eng.submit(np.arange(6), 10, temperature=0.5)
    eng.step()
    eng.step()
    eng.preempt(next(iter(eng._slots)))
    r = eng._queue[0]
    assert r.resume is not None and r.resume.kv is None   # degraded path
    assert r.last_fault == "swap_out"
    done = eng.run()
    assert done[rid].status == "done"
    np.testing.assert_array_equal(done[rid].output, expected)
    _assert_drained_clean(eng)


def test_transient_pool_exhaustion_only_delays(tiny):
    """The pool seam makes admission answer "no blocks" for a few steps;
    everything still completes exactly."""
    _, base = _serve(tiny, **CONFIGS["paged_swap"])
    plan = FaultPlan(seed=0, pool=[0, 1, 2, 3])
    eng, done = _serve(tiny, fault_plan=plan, **CONFIGS["paged_swap"])
    assert plan.fired("pool") == 4
    assert all(r.status == "done" for r in done.values())
    _assert_survivors_exact(done, base)
    _assert_drained_clean(eng)


def test_retry_budget_quarantines_instead_of_wedging(tiny):
    """Unbounded step poisoning: every request exhausts its retry budget
    and lands terminally "failed" — the drain loop exits, resources come
    back, reasons are machine-readable."""
    plan = FaultPlan(seed=0, step=1.0)        # every decode round fails
    eng, done = _serve(tiny, fault_plan=plan, max_retries=2,
                       **CONFIGS["paged_swap"])
    assert done and all(r.status == "failed" for r in done.values())
    for r in done.values():
        assert r.failure_reason.startswith("retry_budget_exhausted")
        assert r.retries == 3 and r.last_fault == "step"
    assert eng.metrics()["quarantined"] == len(done)
    _assert_drained_clean(eng)


def test_cancellation_mid_prefill_and_mid_decode(tiny):
    """cancel() frees the victim's slot/blocks wherever it is; everyone
    else finishes exactly as in the undisturbed run."""
    lm, params = tiny
    kw = dict(batch_slots=3, max_seq_len=64, min_bucket=4,
              cache_backend="paged", block_size=8, num_pool_blocks=28,
              chunk_tokens=4, token_budget=7)
    trace = _mixed_trace(5, seed=4, budgets=(6, 12))
    base = ServingEngine(lm, params, **kw)
    base_ids = [base.submit(p, b, temperature=0.3) for p, b in trace]
    base_done = base.run()

    eng = ServingEngine(lm, params, **kw)
    ids = [eng.submit(p, b, temperature=0.3) for p, b in trace]
    eng.step()                                # victim 0 is mid-prefill or
    pf = list(eng._prefilling.values())       # just armed
    mid_prefill = pf[0].request.request_id if pf else None
    if mid_prefill is not None:
        assert eng.cancel(mid_prefill)
    for _ in range(3):
        eng.step()
    mid_decode = next((r.request_id for r in eng._slots.values()), None)
    if mid_decode is not None:
        assert eng.cancel(mid_decode)
    done = eng.run()
    assert not eng.cancel(12345)              # unknown id
    cancelled = {rid for rid, r in done.items() if r.status == "cancelled"}
    assert cancelled == {x for x in (mid_prefill, mid_decode)
                         if x is not None}
    for rid in ids:
        if rid in cancelled:
            continue
        assert done[rid].status == "done"
        np.testing.assert_array_equal(done[rid].output,
                                      base_done[rid].output)
    _assert_drained_clean(eng)


def test_injected_cancellation_is_deterministic(tiny):
    """The cancel seam picks the same victims for the same seed."""
    def victims(seed):
        plan = FaultPlan(seed=seed, cancel=[1, 3])
        _, done = _serve(tiny, fault_plan=plan, **CONFIGS["paged_swap"])
        return sorted(rid for rid, r in done.items()
                      if r.status == "cancelled")

    v = victims(9)
    assert v == victims(9) and len(v) == 2


def test_oversized_request_is_rejected_not_fatal(tiny):
    """Satellite 1: the pool-capacity raise is now a per-request terminal
    rejection — neighbors drain normally (also covered from the SLO side
    in test_slo_scheduling)."""
    lm, params = tiny
    eng = ServingEngine(lm, params, batch_slots=2, max_seq_len=64,
                        min_bucket=4, cache_backend="paged", block_size=8,
                        num_pool_blocks=6)           # 5 usable
    ok1 = eng.submit(np.arange(5), 5)
    big = eng.submit(np.arange(30), 20, priority=9)  # 7 blocks > 5: never
    ok2 = eng.submit(np.arange(4), 4)
    done = eng.run()
    assert done[big].status == "rejected"
    assert done[big].failure_reason.startswith("exceeds_pool_capacity")
    assert len(done[big].output) == 0
    assert done[ok1].status == "done" and done[ok2].status == "done"
    _assert_drained_clean(eng)


def test_deadline_admission_reject_and_downgrade(tiny):
    """Submit-time feasibility: once the class service rate is measured,
    a hopeless deadline is rejected (policy "reject") or stripped
    (policy "downgrade"); feasible deadlines admit normally."""
    lm, params = tiny
    for policy in ("reject", "downgrade"):
        eng = ServingEngine(lm, params, batch_slots=2, max_seq_len=64,
                            min_bucket=4, admission_policy=policy)
        for _ in range(3):                    # train the estimator
            eng.submit(np.arange(6), 6)
        eng.run()
        est = eng.scheduler.service_estimate(0)
        assert est is not None and est > 0
        for _ in range(4):                    # saturation
            eng.submit(np.arange(6), 6)
        tight = eng.submit(np.arange(6), 6, deadline_s=est * 1e-3)
        loose = eng.submit(np.arange(6), 6, deadline_s=600.0)
        if policy == "reject":
            done = eng.run()
            assert done[tight].status == "rejected"
            assert done[tight].failure_reason.startswith(
                "deadline_infeasible")
        else:
            r = next(q for q in eng._queue if q.request_id == tight)
            assert r.downgraded and r.deadline_s is None
            done = eng.run()
            assert done[tight].status == "done"
        assert done[loose].status == "done"


def test_metrics_snapshot_and_monitoring_wiring(tiny):
    """metrics() summarizes dispositions/faults; MonitoringService
    ingests and returns the latest snapshot per component."""
    from repro.core.monitoring import MonitoringService
    plan = FaultPlan(seed=3, step=[1])
    eng, done = _serve(tiny, fault_plan=plan, **CONFIGS["paged_swap"])
    snap = eng.metrics()
    assert snap["terminal"]["done"] == len(done)
    assert snap["faults_injected"] == {"step": 1}
    assert snap["fault_recoveries"] == 1
    assert snap["recovery"]["count"] >= 1
    assert snap["recovery"]["p99_s"] >= snap["recovery"]["p50_s"] >= 0.0
    assert snap["live"] == {"queued": 0, "prefilling": 0, "decoding": 0}
    mon = MonitoringService()
    mon.record_serving("edge-engine", snap)
    assert mon.serving_snapshot("edge-engine") == snap
    assert mon.serving_snapshot("nope") is None


# ---------------------------------------------------------------------------
# Cascade: edge outage -> circuit breaking -> cloud failover
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_cascade_breaker_reroutes_edge_to_cloud(tiny):
    """Edge outage mid-cascade: consecutive gate failures trip the
    breaker open, requests fail over to the cloud engine (route
    "failover", deadline shrunk by observed degradation), and a
    successful half-open probe closes the breaker once the outage ends.
    Acceptance: >= 1 request demonstrably rerouted edge->cloud."""
    from repro.cascade.ecc_infer import CascadeLM, edge_variant
    from repro.cascade.gate import make_thresholds
    from repro.serving import CascadeServingEngine
    cloud_cfg = _tiny_cfg()
    edge_cfg = edge_variant(cloud_cfg, layers=1)
    cloud, edge = LM(cloud_cfg, kv_chunk=8), LM(edge_cfg, kv_chunk=8)
    cp, _ = cloud.init(jax.random.PRNGKey(0))
    ep, _ = edge.init(jax.random.PRNGKey(1))
    cascade = CascadeLM(edge, cloud,
                        thresholds=make_thresholds(hi=0.01, lo=0.001))
    plan = FaultPlan(seed=0, edge=[0, 1, 2])  # outage spans 3 attempts
    eng = CascadeServingEngine(cascade, ep, cp, batch_slots=2,
                               max_seq_len=32, fault_plan=plan,
                               breaker_failure_threshold=2,
                               breaker_cooldown=2)
    rng = np.random.default_rng(0)
    ids = [eng.submit(rng.integers(0, 60, size=4 + i), max_new_tokens=3,
                      deadline_s=30.0) for i in range(8)]
    done = eng.run()
    m = eng.metrics
    assert m.edge_failures >= 2
    assert m.rerouted >= 1                    # the acceptance criterion
    assert eng.breaker.trips >= 1
    assert eng.breaker.state == "closed"      # probe closed it post-outage
    routes = {done[rid].route for rid in ids}
    assert "failover" in routes
    assert routes & {"accept", "escalate", "drop"}   # edge recovered
    for rid in ids:
        r = done[rid]
        assert r.status == "done"
        assert len(r.output) == (0 if r.route == "drop" else 3)
    # failover generations are the cloud engine's: token-exact vs a
    # direct cloud run of the same prompt
    ref = ServingEngine(cloud, cp, batch_slots=2, max_seq_len=32, seed=1)
    for rid in ids:
        if done[rid].route != "failover":
            continue
        rr = ref.submit(done[rid].prompt, 3)
        np.testing.assert_array_equal(ref.run()[rr].output,
                                      done[rid].output)
    snap = eng.engine_metrics()
    assert snap["breaker"]["trips"] == eng.breaker.trips
    assert snap["rerouted"] == m.rerouted


# ---------------------------------------------------------------------------
# Network link faults (sim-level WAN chaos)
# ---------------------------------------------------------------------------

def test_link_wan_spike_and_outage_deterministic():
    from repro.core.network import Link
    from repro.core.sim import SimClock

    def arrivals(seed):
        clock = SimClock()
        plan = FaultPlan(seed=seed, wan_spike=[1], wan_outage=[2])
        link = Link(bandwidth_mbps=8.0, delay_s=0.05, fault_plan=plan,
                    spike_s=0.25, outage_s=1.0)
        return [link.transfer(clock, 100_000) for _ in range(4)], link

    (a, link), (b, _) = arrivals(0), arrivals(0)
    assert a == b                             # deterministic schedule
    tx = 100_000 * 8 / (8.0 * 1e6)            # 0.1 s serialized per transfer
    assert a[0] == pytest.approx(tx + 0.05)
    assert a[1] == pytest.approx(2 * tx + 0.05 + 0.25)      # spike
    assert a[2] == pytest.approx(3 * tx + 0.05 + 1.0)       # outage shifts
    assert a[3] == pytest.approx(4 * tx + 0.05 + 1.0)       # ...the queue
    assert link.spikes == 1 and link.outages == 1


def test_network_model_threads_fault_plan_to_wan_links_only():
    from repro.core.ids import ClusterId, InfraId
    from repro.core.network import NetworkModel
    from repro.core.sim import SimClock
    plan = FaultPlan(seed=0, wan_outage=1.0)
    net = NetworkModel(SimClock(), wan_delay_s=0.05, fault_plan=plan)
    infra = InfraId(0)
    ec = ClusterId(infra, "ec", 0)
    cc = ClusterId(infra, "cc", 0)
    assert net.link(ec, cc).fault_plan is plan        # WAN: chaos applies
    assert net.link(ec, ec).fault_plan is None        # LAN: exempt
