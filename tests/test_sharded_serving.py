"""Mesh-aware serving: tensor-parallel decode on forced host devices.

The exactness matrix runs in a subprocess (``xla_force_host_platform_
device_count`` must be set before ``import jax``; conftest already imported
it): ring/paged × greedy/sampled × multi-step × speculative engines on a
4-device mesh must stream token-for-token identically to the single-device
engine on the same trace — faults included — and snapshots taken on a mesh
must restore token-exact both onto a mesh and onto ``mesh=None``.

The in-process test pins the other half of the contract: ``mesh=None``
compiles exactly the warm executable set (no new variants post-warm), so
the mesh seam costs the single-device path nothing.
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    import json
    import jax
    import numpy as np

    from repro.configs.base import ModelConfig, dense_stages
    from repro.launch.mesh import make_host_mesh
    from repro.models.model import LM
    from repro.serving import FaultPlan, ServingEngine

    def model(layers=2, seed=0):
        cfg = ModelConfig(
            name="shard-test", family="dense", source="test",
            num_layers=layers, d_model=64, num_heads=4, num_kv_heads=4,
            head_dim=16, d_ff=128, vocab_size=256,
            stages=dense_stages(layers), param_dtype="float32")
        lm = LM(cfg, kv_chunk=32)
        params, _ = lm.init(jax.random.PRNGKey(seed))
        return lm, params

    LM_T, P_T = model()
    LM_D, P_D = model(layers=1, seed=1)
    MESH = make_host_mesh(model=4)
    RNG = np.random.default_rng(0)
    REQS = [(RNG.integers(0, 256, size=4 + i % 7), 5 + i % 4,
             0.0 if i % 2 else 0.8) for i in range(6)]

    def mk(mesh, backend, *, spec=False, k=1, faults=None):
        kw = dict(draft_model=LM_D, draft_params=P_D,
                  speculative_tokens=3) if spec else {}
        return ServingEngine(LM_T, P_T, batch_slots=3, max_seq_len=64,
                             cache_backend=backend, mesh=mesh, seed=0,
                             max_decode_steps=k, fault_plan=faults, **kw)

    def run(eng):
        ids = [eng.submit(p, max_new_tokens=m, temperature=t)
               for p, m, t in REQS]
        done = eng.run()
        eng.assert_invariants()
        return {i: done[i].output.tolist() for i in ids
                if done[i].status == "done"}

    results = {}
    # exactness matrix: backends x sampling x decode horizon
    for backend in ("ring", "paged"):
        for k in (1, 4):
            key = f"{backend}_k{k}"
            results[key] = run(mk(None, backend, k=k)) == \\
                run(mk(MESH, backend, k=k))
    # speculative (draft + target both on the mesh)
    results["speculative"] = run(mk(None, "paged", spec=True)) == \\
        run(mk(MESH, "paged", spec=True))
    # faults: same seeded plan both sides; survivors must match
    results["faults"] = \\
        run(mk(None, "paged", faults=FaultPlan(seed=3, step=[1],
                                               swap_out=[0]))) == \\
        run(mk(MESH, "paged", faults=FaultPlan(seed=3, step=[1],
                                               swap_out=[0])))

    # snapshot-on-mesh -> restore-on-mesh and restore-on-mesh=None
    base = run(mk(None, "paged"))
    donor = mk(MESH, "paged")
    for p, m, t in REQS:
        donor.submit(p, max_new_tokens=m, temperature=t)
    for _ in range(4):
        donor.step()
    snap = donor.snapshot()
    for name, tmesh in (("restore_on_mesh", MESH),
                        ("restore_on_none", None)):
        cold = mk(tmesh, "paged")
        cold.restore(snap)
        done = cold.run()
        cold.assert_invariants()
        out = {r.request_id: r.output.tolist() for r in done.values()}
        results[name] = out == base

    # per-device accounting: sharded pool pays 1/4 of the K/V bytes
    eng = mk(MESH, "paged")
    kv = eng.backend
    results["hbm_per_device_shrinks"] = (
        kv.kv_shards == 4
        and kv.hbm_bytes_per_device() < kv.hbm_bytes()
        and kv.block_bytes_per_device() * kv.num_blocks
        == kv.hbm_bytes_per_device())
    print(json.dumps(results))
""")


@pytest.mark.slow
def test_sharded_serving_exactness_matrix():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    assert all(rec.values()), rec


def test_mesh_none_executable_set_unchanged():
    """mesh=None must compile exactly today's executable set: the mesh
    seam adds no jit arguments (mesh/rules ride as trace-time closure
    state), so warm_compile still closes the compile set and a full
    drain adds zero variants."""
    import jax
    import numpy as np
    from repro.configs.base import ModelConfig, dense_stages
    from repro.models.model import LM
    from repro.serving import ServingEngine

    cfg = ModelConfig(
        name="shard-nomesh", family="dense", source="test", num_layers=1,
        d_model=32, num_heads=2, num_kv_heads=2, head_dim=16, d_ff=64,
        vocab_size=128, stages=dense_stages(1), param_dtype="float32")
    lm = LM(cfg, kv_chunk=16)
    params, _ = lm.init(jax.random.PRNGKey(0))
    eng = ServingEngine(lm, params, batch_slots=2, max_seq_len=32,
                        min_bucket=8, cache_backend="paged", block_size=8,
                        max_decode_steps=4)
    assert eng.mesh is None and eng.rules is None
    eng.warm_compile()
    assert eng.warm_compile_s is not None and eng.warm_compile_s > 0
    # decode executables close at warm_compile (admission lawfully
    # retraces per prompt bucket — pre-existing monolithic behavior)
    counts = {name: getattr(eng, name)._cache_size()
              for name in ("_step_fn", "_scan_fn")}
    rng = np.random.default_rng(0)
    for i in range(3):
        eng.submit(rng.integers(0, 128, size=4 + i), max_new_tokens=4,
                   temperature=0.5 * i)
    done = eng.run()
    assert all(r.status == "done" for r in done.values())
    for name, before in counts.items():
        assert getattr(eng, name)._cache_size() == before, name
    # metrics carries the satellite fields
    m = eng.metrics()
    assert m["warm_compile_s"] == eng.warm_compile_s
    assert m["mesh_devices"] == 1
    # per-device accounting degenerates to the global numbers off-mesh
    assert eng.hbm_bytes_per_device() == eng.hbm_bytes()
    eng.assert_invariants()


def test_slots_for_hbm_scaling():
    from repro.serving import slots_for_hbm
    slot = 1000
    per_dev = 8 * slot
    assert slots_for_hbm(per_dev, slot, mesh_size=1) == 8
    assert slots_for_hbm(per_dev, slot, mesh_size=2) == 16
    assert slots_for_hbm(per_dev, slot, mesh_size=4) == 32
    assert slots_for_hbm(per_dev, slot, mesh_size=4, cap=20) == 20
