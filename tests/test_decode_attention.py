"""Decode-attention Pallas kernel vs the jnp oracle (interpret mode):
causal, sliding-window, GQA/MQA, partially-empty and ring-wrapped caches."""
import jax
import jax.numpy as jnp
import pytest

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention
from repro.models import attention as att

CASES = [
    # (b, w, h, kv, hd, window, filled, total_pos)
    (1, 64, 4, 4, 32, None, 64, 64),      # full cache, MHA
    (2, 96, 4, 2, 32, None, 96, 96),      # GQA g=2
    (1, 96, 3, 1, 32, None, 96, 96),      # MQA
    (2, 64, 4, 4, 32, 24, 64, 64),        # sliding window
    (2, 96, 8, 2, 64, 16, 96, 96),        # window + GQA g=4
    (1, 100, 4, 2, 16, None, 100, 100),   # ragged width (block padding)
    (2, 64, 4, 2, 32, None, 40, 40),      # partially-empty cache
    (2, 64, 4, 2, 32, None, 64, 130),     # ring-wrapped cache
    (1, 48, 4, 2, 32, 24, 48, 130),       # ring-wrapped + window
]


def _ring_cache(rng, b, w, kv, hd, filled, total_pos, dtype=jnp.float32):
    """A cache as the engine produces it: positions [total-filled, total)
    at ring slot pos % w; remaining slots empty (-1)."""
    k = jax.random.normal(rng[0], (b, w, kv, hd)).astype(dtype)
    v = jax.random.normal(rng[1], (b, w, kv, hd)).astype(dtype)
    t = jnp.arange(total_pos - filled, total_pos)
    k_pos = jnp.full((b, w), -1, jnp.int32).at[:, t % w].set(
        t.astype(jnp.int32)[None, :])
    q_pos = jnp.full((b,), total_pos, jnp.int32)
    return k, v, k_pos, q_pos


@pytest.mark.parametrize("case", CASES, ids=[str(c) for c in CASES])
def test_decode_kernel_matches_oracle(case):
    b, w, h, kv, hd, window, filled, total_pos = case
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, 1, h, hd))
    k, v, k_pos, q_pos = _ring_cache(ks[1:], b, w, kv, hd, filled, total_pos)
    out = decode_attention(q, k, v, q_pos, k_pos, window=window,
                           block_k=32, interpret=True)
    expect = ref.decode_attention_ref(q, k, v, q_pos, k_pos, window=window)
    assert out.shape == q.shape
    assert float(jnp.max(jnp.abs(out - expect))) < 1e-4


def test_mixed_positions_per_slot():
    """Continuous batching: every batch row sits at a different depth."""
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    b, w, h, kv, hd = 4, 64, 4, 2, 32
    k = jax.random.normal(ks[0], (b, w, kv, hd))
    v = jax.random.normal(ks[1], (b, w, kv, hd))
    q = jax.random.normal(ks[2], (b, 1, h, hd))
    fill = jnp.array([5, 17, 40, 64])
    k_pos = jnp.where(jnp.arange(w)[None, :] < fill[:, None],
                      jnp.arange(w)[None, :], -1).astype(jnp.int32)
    q_pos = fill.astype(jnp.int32)
    out = decode_attention(q, k, v, q_pos, k_pos, window=None,
                           block_k=32, interpret=True)
    expect = ref.decode_attention_ref(q, k, v, q_pos, k_pos, window=None)
    assert float(jnp.max(jnp.abs(out - expect))) < 1e-4


def test_model_dispatch_agrees_with_jnp_path():
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    b, w, h, kv, hd = 2, 64, 4, 2, 32
    q = jax.random.normal(ks[0], (b, 1, h, hd))
    k, v, k_pos, q_pos = _ring_cache(ks[1:], b, w, kv, hd, 64, 100)
    kern = att.decode_attention(q, k, v, q_pos, k_pos, window=16,
                                scale=hd ** -0.5, use_kernel=True,
                                interpret=True)
    ref_out = att.decode_attention(q, k, v, q_pos, k_pos, window=16,
                                   scale=hd ** -0.5, use_kernel=False)
    assert float(jnp.max(jnp.abs(kern - ref_out))) < 1e-4


def test_bf16_cache():
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    b, w, h, kv, hd = 1, 64, 4, 2, 32
    q = jax.random.normal(ks[0], (b, 1, h, hd)).astype(jnp.bfloat16)
    k, v, k_pos, q_pos = _ring_cache(ks[1:], b, w, kv, hd, 64, 64,
                                     dtype=jnp.bfloat16)
    out = decode_attention(q, k, v, q_pos, k_pos, block_k=32, interpret=True)
    expect = ref.decode_attention_ref(q, k, v, q_pos, k_pos)
    assert out.dtype == jnp.bfloat16
    assert float(jnp.max(jnp.abs(out.astype(jnp.float32)
                                 - expect.astype(jnp.float32)))) < 2e-2


def test_ops_dispatch_wrapper():
    from repro.kernels import ops
    ks = jax.random.split(jax.random.PRNGKey(4), 3)
    q = jax.random.normal(ks[0], (2, 1, 4, 32))
    k, v, k_pos, q_pos = _ring_cache(ks[1:], 2, 64, 2, 32, 64, 64)
    a = ops.decode_attn(q, k, v, q_pos, k_pos, use_kernel=True,
                        interpret=True)
    b = ops.decode_attn(q, k, v, q_pos, k_pos, use_kernel=False)
    assert float(jnp.max(jnp.abs(a - b))) < 1e-4
