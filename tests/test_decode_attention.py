"""Decode-attention Pallas kernel vs the jnp oracle (interpret mode):
causal, sliding-window, GQA/MQA, partially-empty and ring-wrapped caches."""
import jax
import jax.numpy as jnp
import pytest

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention
from repro.models import attention as att

CASES = [
    # (b, w, h, kv, hd, window, filled, total_pos)
    (1, 64, 4, 4, 32, None, 64, 64),      # full cache, MHA
    (2, 96, 4, 2, 32, None, 96, 96),      # GQA g=2
    (1, 96, 3, 1, 32, None, 96, 96),      # MQA
    (2, 64, 4, 4, 32, 24, 64, 64),        # sliding window
    (2, 96, 8, 2, 64, 16, 96, 96),        # window + GQA g=4
    (1, 100, 4, 2, 16, None, 100, 100),   # ragged width (block padding)
    (2, 64, 4, 2, 32, None, 40, 40),      # partially-empty cache
    (2, 64, 4, 2, 32, None, 64, 130),     # ring-wrapped cache
    (1, 48, 4, 2, 32, 24, 48, 130),       # ring-wrapped + window
]


def _ring_cache(rng, b, w, kv, hd, filled, total_pos, dtype=jnp.float32):
    """A cache as the engine produces it: positions [total-filled, total)
    at ring slot pos % w; remaining slots empty (-1)."""
    k = jax.random.normal(rng[0], (b, w, kv, hd)).astype(dtype)
    v = jax.random.normal(rng[1], (b, w, kv, hd)).astype(dtype)
    t = jnp.arange(total_pos - filled, total_pos)
    k_pos = jnp.full((b, w), -1, jnp.int32).at[:, t % w].set(
        t.astype(jnp.int32)[None, :])
    q_pos = jnp.full((b,), total_pos, jnp.int32)
    return k, v, k_pos, q_pos


@pytest.mark.parametrize("case", CASES, ids=[str(c) for c in CASES])
def test_decode_kernel_matches_oracle(case):
    b, w, h, kv, hd, window, filled, total_pos = case
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, 1, h, hd))
    k, v, k_pos, q_pos = _ring_cache(ks[1:], b, w, kv, hd, filled, total_pos)
    out = decode_attention(q, k, v, q_pos, k_pos, window=window,
                           block_k=32, interpret=True)
    expect = ref.decode_attention_ref(q, k, v, q_pos, k_pos, window=window)
    assert out.shape == q.shape
    assert float(jnp.max(jnp.abs(out - expect))) < 1e-4


def test_mixed_positions_per_slot():
    """Continuous batching: every batch row sits at a different depth."""
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    b, w, h, kv, hd = 4, 64, 4, 2, 32
    k = jax.random.normal(ks[0], (b, w, kv, hd))
    v = jax.random.normal(ks[1], (b, w, kv, hd))
    q = jax.random.normal(ks[2], (b, 1, h, hd))
    fill = jnp.array([5, 17, 40, 64])
    k_pos = jnp.where(jnp.arange(w)[None, :] < fill[:, None],
                      jnp.arange(w)[None, :], -1).astype(jnp.int32)
    q_pos = fill.astype(jnp.int32)
    out = decode_attention(q, k, v, q_pos, k_pos, window=None,
                           block_k=32, interpret=True)
    expect = ref.decode_attention_ref(q, k, v, q_pos, k_pos, window=None)
    assert float(jnp.max(jnp.abs(out - expect))) < 1e-4


CHUNK_CASES = [
    # (b, w, h, kv, hd, window, filled, chunk)
    (2, 64, 4, 2, 32, None, 40, 8),       # GQA chunk mid-prefill
    (1, 64, 4, 4, 32, None, 5, 5),        # chunk = whole written prefix
    (2, 64, 8, 2, 64, 16, 48, 8),         # sliding window + GQA g=4
    (1, 96, 3, 1, 32, None, 70, 16),      # MQA, bigger chunk
]


@pytest.mark.parametrize("case", CHUNK_CASES, ids=[str(c) for c in CHUNK_CASES])
def test_chunk_queries_match_oracle(case):
    """Chunked prefill: a T-token query block whose own K/V are already in
    the cache (append-then-attend) against the streamed kernel."""
    b, w, h, kv, hd, window, filled, chunk = case
    ks = jax.random.split(jax.random.PRNGKey(5), 3)
    q = jax.random.normal(ks[0], (b, chunk, h, hd))
    k, v, k_pos, _ = _ring_cache(ks[1:], b, w, kv, hd, filled, filled)
    q_pos = jnp.full((b,), filled - chunk, jnp.int32)  # chunk start
    out = decode_attention(q, k, v, q_pos, k_pos, window=window,
                           block_k=32, interpret=True)
    expect = ref.decode_attention_ref(q, k, v, q_pos, k_pos, window=window)
    assert out.shape == q.shape
    assert float(jnp.max(jnp.abs(out - expect))) < 1e-4


def test_chunk_oracle_matches_full_flash_attention():
    """The chunk oracle's causal masking equals dense full attention over
    the same contiguous context (positions are the only mask input)."""
    ks = jax.random.split(jax.random.PRNGKey(6), 3)
    b, s, t, h, kv, hd = 2, 24, 7, 4, 2, 16
    q = jax.random.normal(ks[0], (b, s, h, hd))
    k = jax.random.normal(ks[1], (b, s, kv, hd))
    v = jax.random.normal(ks[2], (b, s, kv, hd))
    full = ref.flash_attention_ref(q, k, v)
    k_pos = jnp.broadcast_to(jnp.arange(s), (b, s)).astype(jnp.int32)
    chunk = ref.decode_attention_ref(q[:, s - t:], k, v,
                                     jnp.full((b,), s - t, jnp.int32), k_pos)
    assert float(jnp.max(jnp.abs(full[:, s - t:] - chunk))) < 1e-5


def test_explicit_per_token_query_positions():
    """(B, T) q_pos is honored as-is (not derived from a start scalar)."""
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    b, w, h, kv, hd, t = 2, 64, 4, 2, 32, 4
    q = jax.random.normal(ks[0], (b, t, h, hd))
    k, v, k_pos, _ = _ring_cache(ks[1:], b, w, kv, hd, 50, 50)
    q_pos = jnp.asarray([[10, 11, 12, 13], [40, 41, 42, 43]], jnp.int32)
    out = decode_attention(q, k, v, q_pos, k_pos, block_k=32, interpret=True)
    expect = ref.decode_attention_ref(q, k, v, q_pos, k_pos)
    assert float(jnp.max(jnp.abs(out - expect))) < 1e-4


def test_model_dispatch_agrees_with_jnp_path():
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    b, w, h, kv, hd = 2, 64, 4, 2, 32
    q = jax.random.normal(ks[0], (b, 1, h, hd))
    k, v, k_pos, q_pos = _ring_cache(ks[1:], b, w, kv, hd, 64, 100)
    kern = att.decode_attention(q, k, v, q_pos, k_pos, window=16,
                                scale=hd ** -0.5, use_kernel=True,
                                interpret=True)
    ref_out = att.decode_attention(q, k, v, q_pos, k_pos, window=16,
                                   scale=hd ** -0.5, use_kernel=False)
    assert float(jnp.max(jnp.abs(kern - ref_out))) < 1e-4


def test_bf16_cache():
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    b, w, h, kv, hd = 1, 64, 4, 2, 32
    q = jax.random.normal(ks[0], (b, 1, h, hd)).astype(jnp.bfloat16)
    k, v, k_pos, q_pos = _ring_cache(ks[1:], b, w, kv, hd, 64, 64,
                                     dtype=jnp.bfloat16)
    out = decode_attention(q, k, v, q_pos, k_pos, block_k=32, interpret=True)
    expect = ref.decode_attention_ref(q, k, v, q_pos, k_pos)
    assert out.dtype == jnp.bfloat16
    assert float(jnp.max(jnp.abs(out.astype(jnp.float32)
                                 - expect.astype(jnp.float32)))) < 2e-2


def test_ops_dispatch_wrapper():
    from repro.kernels import ops
    ks = jax.random.split(jax.random.PRNGKey(4), 3)
    q = jax.random.normal(ks[0], (2, 1, 4, 32))
    k, v, k_pos, q_pos = _ring_cache(ks[1:], 2, 64, 2, 32, 64, 64)
    a = ops.decode_attn(q, k, v, q_pos, k_pos, use_kernel=True,
                        interpret=True)
    b = ops.decode_attn(q, k, v, q_pos, k_pos, use_kernel=False)
    assert float(jnp.max(jnp.abs(a - b))) < 1e-4
