"""Recurrent mixers: chunkwise mLSTM == sequential oracle; RG-LRU scan;
forward == step-by-step decode for all three recurrent families."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import ModelConfig, Stage, BlockDef, MLSTM, SLSTM, RGLRU, NONE, GELU_MLP
from repro.models import recurrent as rec
from repro.models.param import unbox


def _cfg(mixer):
    return ModelConfig(
        name="t", family="ssm", source="t", num_layers=1, d_model=32,
        num_heads=2, num_kv_heads=2, head_dim=8, d_ff=64, vocab_size=128,
        stages=(Stage(blocks=(BlockDef(mixer=mixer, mlp=NONE),), repeat=1),),
        lru_width=48)


def test_mlstm_chunkwise_matches_sequential():
    b, s, h, hd = 2, 50, 2, 8
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    q = jax.random.normal(ks[0], (b, s, h, hd))
    k = jax.random.normal(ks[1], (b, s, h, hd))
    v = jax.random.normal(ks[2], (b, s, h, hd))
    log_i = jax.random.normal(ks[3], (b, s, h))
    log_f = -jax.nn.softplus(-jax.random.normal(ks[4], (b, s, h)) - 1.0)
    h_seq, st_seq = rec.mlstm_cell_ref(q, k, v, log_i, log_f)
    h_chk, st_chk = rec.mlstm_cell_chunkwise(q, k, v, log_i, log_f, chunk=16)
    assert float(jnp.max(jnp.abs(h_seq - h_chk))) < 1e-4
    assert float(jnp.max(jnp.abs(st_seq["C"] - st_chk["C"]))) < 1e-4
    assert float(jnp.max(jnp.abs(st_seq["n"] - st_chk["n"]))) < 1e-4


def test_mlstm_block_forward_matches_decode():
    cfg = _cfg(MLSTM)
    params, _ = unbox(rec.mlstm_block_init(jax.random.PRNGKey(1), cfg,
                                           jnp.float32))
    s = 9
    x = jax.random.normal(jax.random.PRNGKey(2), (2, s, cfg.d_model)) * 0.5
    full, _ = rec.mlstm_block_forward(params, cfg, x, chunk=4)
    state = rec.mlstm_state_init(2, cfg.num_heads, cfg.resolved_head_dim)
    outs = []
    for t in range(s):
        y, state = rec.mlstm_block_decode(params, cfg, x[:, t:t + 1], state)
        outs.append(y)
    stepped = jnp.concatenate(outs, axis=1)
    assert float(jnp.max(jnp.abs(full - stepped))) < 1e-4


def test_slstm_forward_matches_decode():
    cfg = _cfg(SLSTM)
    params, _ = unbox(rec.slstm_block_init(jax.random.PRNGKey(3), cfg,
                                           jnp.float32))
    s = 7
    x = jax.random.normal(jax.random.PRNGKey(4), (2, s, cfg.d_model)) * 0.5
    full, _ = rec.slstm_block_forward(params, cfg, x)
    state = rec.slstm_state_init(2, cfg.num_heads, cfg.resolved_head_dim)
    outs = []
    for t in range(s):
        y, state = rec.slstm_block_decode(params, cfg, x[:, t:t + 1], state)
        outs.append(y)
    stepped = jnp.concatenate(outs, axis=1)
    assert float(jnp.max(jnp.abs(full - stepped))) < 1e-4


def test_rglru_forward_matches_decode():
    cfg = _cfg(RGLRU)
    params, _ = unbox(rec.rglru_block_init(jax.random.PRNGKey(5), cfg,
                                           jnp.float32))
    s = 11
    x = jax.random.normal(jax.random.PRNGKey(6), (2, s, cfg.d_model)) * 0.5
    full, final_state = rec.rglru_block_forward(params, cfg, x)
    state = rec.rglru_state_spec(cfg, 2, jnp.float32)
    outs = []
    for t in range(s):
        y, state = rec.rglru_block_decode(params, cfg, x[:, t:t + 1], state)
        outs.append(y)
    stepped = jnp.concatenate(outs, axis=1)
    assert float(jnp.max(jnp.abs(full - stepped))) < 1e-4
    assert float(jnp.max(jnp.abs(final_state["h"] - state["h"]))) < 1e-4


def test_rglru_state_is_bounded():
    """|h| stays bounded (the sqrt(1-a^2) normalization) — the property that
    makes long_500k native for the hybrid family."""
    cfg = _cfg(RGLRU)
    params, _ = unbox(rec.rglru_block_init(jax.random.PRNGKey(7), cfg,
                                           jnp.float32))
    x = jax.random.normal(jax.random.PRNGKey(8), (1, 500, cfg.d_model))
    _, state = rec.rglru_block_forward(params, cfg, x)
    assert float(jnp.max(jnp.abs(state["h"]))) < 50.0
