"""Async serving gateway (ISSUE 7): streaming, cancellation, backpressure.

Contracts under test: tokens streamed through the gateway are identical
to the closed-loop engine's outputs for the same submission order (greedy
and sampled, ring and paged); an abandoned or cancelled stream frees its
slot and blocks; the bounded inbox's block/reject/shed policies engage
under a saturating burst; TTFT/latency are stamped at the gateway's
stream boundary (queue wait included) rather than the engine's internal
completion; and under a seeded ``FaultPlan`` every stream still reaches a
terminal state while survivors stream exactly.
"""
import asyncio

import jax
import numpy as np
import pytest

from repro.configs.base import ModelConfig, dense_stages
from repro.models.model import LM
from repro.serving import FaultPlan, ServingEngine, ServingGateway


def _tiny_cfg():
    return ModelConfig(
        name="tiny", family="dense", source="t", num_layers=2, d_model=32,
        num_heads=4, num_kv_heads=2, head_dim=8, d_ff=64, vocab_size=64,
        stages=dense_stages(2), param_dtype="float32")


@pytest.fixture(scope="module")
def tiny():
    lm = LM(_tiny_cfg(), kv_chunk=8)
    params, _ = lm.init(jax.random.PRNGKey(0))
    return lm, params


PAGED = dict(cache_backend="paged", block_size=8, num_pool_blocks=24)


def _engine(tiny, **kw):
    lm, params = tiny
    base = dict(batch_slots=2, max_seq_len=48, min_bucket=4)
    base.update(kw)
    return ServingEngine(lm, params, **base)


def _trace(n=6, seed=3, sampled=False):
    rng = np.random.default_rng(seed)
    return [dict(prompt=rng.integers(0, 60, size=int(rng.integers(3, 12))),
                 max_new=int(rng.integers(3, 9)),
                 temperature=0.7 if sampled and i % 2 else 0.0)
            for i in range(n)]


def _reference(tiny, trace, **kw):
    """Closed-loop ground truth; request ids land in submission order,
    the same order the gateway allocates them."""
    eng = _engine(tiny, **kw)
    for it in trace:
        eng.submit(it["prompt"], max_new_tokens=it["max_new"],
                   temperature=it["temperature"])
    return eng.run()


async def _gw_run(eng, trace, **gw_kw):
    """Every trace item as a concurrent streaming client; returns
    {rid: (terminal request, streamed tokens)}."""
    out = {}

    async def client(item):
        h = await gw.submit(item["prompt"], max_new_tokens=item["max_new"],
                            temperature=item["temperature"])
        toks = [t async for t in h.stream()]
        r = await h.result()
        out[r.request_id] = (r, np.asarray(toks, np.int32))

    async with ServingGateway(eng, **gw_kw) as gw:
        await asyncio.gather(*(client(it) for it in trace))
    return out


def _assert_drained_clean(eng):
    assert sorted(eng._free) == list(range(eng.batch_slots))
    be = eng.backend
    if hasattr(be, "assert_invariants"):
        be.assert_invariants()
        assert be._gap_total == 0 and be._ref == {}


# ---------------------------------------------------------------------------
# streaming exactness
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend_kw", [dict(), PAGED],
                         ids=["ring", "paged"])
@pytest.mark.parametrize("sampled", [False, True], ids=["greedy", "sampled"])
def test_stream_matches_closed_loop(tiny, backend_kw, sampled):
    """The gateway is a transport, not a scheduler of its own: every
    stream must deliver exactly the closed-loop output for its rid —
    sampled decoding included (keys fold (request_id, step), so outputs
    are co-scheduling-independent)."""
    trace = _trace(6, sampled=sampled)
    ref = _reference(tiny, trace, **backend_kw)
    eng = _engine(tiny, **backend_kw)
    out = asyncio.run(_gw_run(eng, trace))
    assert set(out) == set(ref)
    for rid, (r, toks) in out.items():
        assert r.status == "done"
        np.testing.assert_array_equal(toks, ref[rid].output)
        np.testing.assert_array_equal(r.output, toks)
        assert r.ttft_s > 0 and r.latency_s >= r.ttft_s
    _assert_drained_clean(eng)


# ---------------------------------------------------------------------------
# cancellation / disconnect
# ---------------------------------------------------------------------------

def test_disconnect_and_cancel_free_slots_and_blocks(tiny):
    """Breaking out of a stream (client disconnect) and explicit
    ``gateway.cancel`` must reach the engine's cancel path in every
    phase — mid-decode, and still queued in the gateway inbox — and
    leave the paged pool clean."""
    eng = _engine(tiny, **PAGED)

    async def main():
        async with ServingGateway(eng, forward_depth=1) as gw:
            # disconnect mid-decode: abandon the iterator after 2 tokens
            h1 = await gw.submit(np.arange(5), max_new_tokens=12)
            got = []
            async for t in h1.stream():
                got.append(t)
                if len(got) == 2:
                    break
            r1 = await h1.result()
            assert r1.status == "cancelled"
            assert len(got) == 2

            # explicit cancel mid-decode
            h2 = await gw.submit(np.arange(4), max_new_tokens=12)
            agen = h2.stream()
            await agen.__anext__()
            assert await gw.cancel(h2.request_id)
            r2 = await h2.result()
            assert r2.status == "cancelled"
            await agen.aclose()

            # cancel while still in the gateway inbox: submits in one
            # coroutine never yield to the driver, so the tail request
            # is still queued gateway-side when the cancel lands
            hs = [await gw.submit(np.arange(4), max_new_tokens=4)
                  for _ in range(4)]
            assert await gw.cancel(hs[-1].request_id)
            r3 = await hs[-1].result()
            assert r3.status == "cancelled"
            assert r3.failure_reason == "cancelled: in gateway queue"
            assert r3.output.shape == (0,)
            for h in hs[:-1]:
                assert (await h.result()).status == "done"
            # cancelling a terminal request is a no-op
            assert not await gw.cancel(hs[-1].request_id)

    asyncio.run(main())
    _assert_drained_clean(eng)


# ---------------------------------------------------------------------------
# backpressure under a saturating burst
# ---------------------------------------------------------------------------

def test_reject_policy_refuses_newcomers_when_full(tiny):
    eng = _engine(tiny)

    async def main():
        # sequential submits never yield to the driver: the burst is
        # guaranteed to hit a full inbox, not race the drain
        async with ServingGateway(eng, max_queue=2, forward_depth=1,
                                  policy="reject") as gw:
            hs = [await gw.submit(np.arange(4), max_new_tokens=3)
                  for _ in range(5)]
            return gw, [await h.result() for h in hs]

    gw, rs = asyncio.run(main())
    statuses = [r.status for r in rs]
    assert statuses == ["done", "done", "rejected", "rejected", "rejected"]
    for r in rs[2:]:
        assert r.failure_reason.startswith("gateway_overload")
    assert gw.reject_count == 3 and gw.shed_count == 0
    _assert_drained_clean(eng)


def test_shed_policy_evicts_worst_ranked_only(tiny):
    eng = _engine(tiny)

    async def main():
        async with ServingGateway(eng, max_queue=2, forward_depth=1,
                                  policy="shed") as gw:
            lo = [await gw.submit(np.arange(4), max_new_tokens=3, priority=0)
                  for _ in range(2)]
            # high-class arrivals displace the queued low-class work...
            hi = [await gw.submit(np.arange(4), max_new_tokens=3, priority=2)
                  for _ in range(2)]
            # ...but a low-class newcomer cannot displace high-class work
            late = await gw.submit(np.arange(4), max_new_tokens=3, priority=0)
            rs = {"lo": [await h.result() for h in lo],
                  "hi": [await h.result() for h in hi],
                  "late": await late.result()}
            return gw, rs

    gw, rs = asyncio.run(main())
    assert [r.status for r in rs["hi"]] == ["done", "done"]
    assert [r.status for r in rs["lo"]] == ["rejected", "rejected"]
    for r in rs["lo"]:
        assert r.failure_reason.startswith("shed_overload")
    assert rs["late"].status == "rejected"
    assert rs["late"].failure_reason.startswith("gateway_overload")
    assert gw.shed_count == 2 and gw.reject_count == 1
    _assert_drained_clean(eng)


def test_block_policy_serves_every_arrival(tiny):
    eng = _engine(tiny)

    async def main():
        async with ServingGateway(eng, max_queue=1, forward_depth=1,
                                  policy="block") as gw:
            async def client(i):
                h = await gw.submit(np.arange(3 + i % 4), max_new_tokens=3)
                return await h.result()
            rs = await asyncio.gather(*(client(i) for i in range(6)))
            return gw, rs

    gw, rs = asyncio.run(main())
    assert all(r.status == "done" for r in rs)
    assert gw.shed_count == 0 and gw.reject_count == 0
    _assert_drained_clean(eng)


def test_drain_finishes_accepted_and_refuses_new(tiny):
    eng = _engine(tiny)

    async def main():
        gw = ServingGateway(eng)
        h = await gw.submit(np.arange(5), max_new_tokens=6)
        await gw.drain()
        r = await h.result()
        assert r.status == "done" and r.output.shape == (6,)
        h2 = await gw.submit(np.arange(5), max_new_tokens=4)
        r2 = await h2.result()
        assert r2.status == "rejected"
        assert r2.failure_reason.startswith("gateway_draining")

    asyncio.run(main())
    _assert_drained_clean(eng)


# ---------------------------------------------------------------------------
# latency accounting at the gateway boundary
# ---------------------------------------------------------------------------

def test_latency_and_ttft_stamped_at_stream_boundary(tiny):
    """Regression (stale-latency accounting): the client-visible TTFT
    and latency are stamped when tokens surface on the loop, strictly
    after the engine's internal host-sync stamps — and queue wait counts:
    on a one-slot engine the queued request's TTFT covers its
    predecessor's whole service time."""
    eng = _engine(tiny, batch_slots=1)
    inner = {}
    orig = eng.take_done

    def spy():
        done = orig()
        for rid, r in done.items():
            inner[rid] = (r.ttft_s, r.latency_s)
        return done

    eng.take_done = spy

    async def main():
        async with ServingGateway(eng, forward_depth=1) as gw:
            ha = await gw.submit(np.arange(6), max_new_tokens=10)
            hb = await gw.submit(np.arange(4), max_new_tokens=4)
            return await ha.result(), await hb.result()

    ra, rb = asyncio.run(main())
    assert ra.status == "done" and rb.status == "done"
    for r in (ra, rb):
        eng_ttft, eng_latency = inner[r.request_id]
        assert r.ttft_s > eng_ttft
        assert r.latency_s > eng_latency
    # one slot: B's first token cannot surface before A fully finishes
    assert rb.ttft_s > ra.latency_s
    _assert_drained_clean(eng)


# ---------------------------------------------------------------------------
# chaos: FaultPlan under the gateway
# ---------------------------------------------------------------------------

def test_gateway_streams_survive_fault_plan(tiny):
    """With seeded faults tripping decode and swap seams, every stream
    still reaches a terminal state (no wedged clients), failures carry a
    machine-readable reason, survivors stream token-for-token the
    fault-free closed-loop outputs, and the pool drains clean."""
    trace = _trace(6, seed=5, sampled=True)
    baseline = _reference(tiny, trace, **PAGED)
    plan = FaultPlan(seed=11, step={"prob": 0.2, "max_fires": 3},
                     swap_out={"prob": 0.3, "max_fires": 2})
    eng = _engine(tiny, fault_plan=plan, **PAGED)
    out = asyncio.run(_gw_run(eng, trace))

    assert set(out) == set(baseline)
    assert {r.status for r, _ in out.values()} <= {"done", "failed"}
    survivors = {rid for rid, (r, _) in out.items() if r.status == "done"}
    assert survivors, "chaos killed every request — schedule too harsh"
    for rid, (r, toks) in out.items():
        if rid in survivors:
            np.testing.assert_array_equal(toks, baseline[rid].output)
            np.testing.assert_array_equal(r.output, toks)
        else:
            assert r.failure_reason
    _assert_drained_clean(eng)
