"""SLO-aware scheduling with preemption.

Policy unit tests (rank arithmetic, chunk-budget ordering, the preempt
seam) plus the engine acceptance contract: **preemption is output-exact**.
Random preempt/resume schedules over mixed-priority traffic must produce
token-for-token the outputs of an uncontended run — greedy and keyed
sampling, on the ring (recompute resume), paged (host K/V swap) and
windowed-paged backends — and ``PagedCache.assert_invariants`` must hold
after every swap, with the free list full and the ledger empty after every
drain.
"""
import collections

import jax
import numpy as np
import pytest

from repro.configs.base import ModelConfig, dense_stages
from repro.models.model import LM
from repro.serving import Request, ServingEngine
from repro.serving.scheduler import (PrefillProgress, Scheduler,
                                     request_rank)


def _tiny_cfg(layers=2, window=None):
    return ModelConfig(
        name="tiny", family="dense", source="t", num_layers=layers,
        d_model=32, num_heads=4, num_kv_heads=2, head_dim=8, d_ff=64,
        vocab_size=64, stages=dense_stages(layers, window=window),
        param_dtype="float32")


def _lm(cfg):
    lm = LM(cfg, kv_chunk=8)
    params, _ = lm.init(jax.random.PRNGKey(0))
    return lm, params


@pytest.fixture(scope="module")
def tiny():
    return _lm(_tiny_cfg())


def _mixed_trace(n=6, seed=1, budgets=(3, 12)):
    rng = np.random.default_rng(seed)
    return [(rng.integers(0, 60, size=int(rng.integers(3, 12))),
             int(rng.integers(*budgets))) for _ in range(n)]


def _assert_same(a, b):
    assert set(a) == set(b)
    for rid in a:
        np.testing.assert_array_equal(a[rid], b[rid])


# ---------------------------------------------------------------------------
# Rank arithmetic (no engine, no device)
# ---------------------------------------------------------------------------

def _req(rid, *, priority=0, deadline_s=None, submit_s=0.0):
    r = Request(rid, np.arange(4), 4, priority=priority,
                deadline_s=deadline_s)
    r.submit_s = submit_s
    return r


def test_request_rank_class_then_deadline_then_fifo():
    lo = _req(0, priority=0, submit_s=1.0)
    hi = _req(1, priority=2, submit_s=5.0)
    # class dominates arrival order
    assert request_rank(hi) < request_rank(lo)
    # EDF within a class: the later submit with the earlier absolute
    # deadline wins
    slack = _req(2, priority=1, deadline_s=9.0, submit_s=1.0)   # abs 10
    tight = _req(3, priority=1, deadline_s=2.0, submit_s=3.0)   # abs 5
    assert request_rank(tight) < request_rank(slack)
    # a deadline beats no deadline in the same class
    none = _req(4, priority=1, submit_s=0.0)
    assert request_rank(slack) < request_rank(none)
    # no tags at all -> submission order (old FIFO)
    a, b = _req(5, submit_s=1.0), _req(6, submit_s=2.0)
    assert request_rank(a) < request_rank(b)
    # None (plan-only tests) ranks constant: stable sorts preserve FIFO
    assert request_rank(None) == request_rank(None)


def test_chunk_budget_ordered_by_class():
    """A higher-class in-flight prefill gets the step's chunk budget ahead
    of an earlier-admitted bulk prefill."""
    s = Scheduler(batch_slots=2, chunk_tokens=8, token_budget=10)
    bulk = PrefillProgress(request=_req(0, priority=0), slot=0, next=0,
                           total=20)
    crit = PrefillProgress(request=_req(1, priority=3), slot=1, next=0,
                           total=6)
    prefilling = collections.OrderedDict([(0, bulk), (1, crit)])
    plan = s.plan_step(n_active=2, prefilling=prefilling,
                       try_admit=lambda: None)
    # 2 decode tokens + the critical 6-token chunk; the bulk prefill's
    # full chunk no longer fits and is NOT planned ahead of it
    assert [(c.slot, c.length, c.final) for c in plan.chunks] == \
        [(1, 6, True)]


def test_plan_retries_admission_after_preempt():
    s = Scheduler(batch_slots=2, chunk_tokens=8)
    granted = []
    state = {"preempted": False}

    def try_admit():
        if not state["preempted"] or granted:
            return None
        pp = PrefillProgress(request=_req(9, priority=5), slot=0, next=0,
                             total=4)
        granted.append(pp)
        return pp

    def try_preempt():
        if state["preempted"]:
            return False
        state["preempted"] = True
        return True

    plan = s.plan_step(n_active=1, prefilling=collections.OrderedDict(),
                       try_admit=try_admit, try_preempt=try_preempt)
    # blocked -> preempt -> admission retried and granted
    assert state["preempted"] and plan.admitted == 1
    assert [c.slot for c in plan.chunks] == [0]


def test_plan_stops_when_preempt_refuses():
    s = Scheduler(batch_slots=2, chunk_tokens=8)
    calls = {"preempt": 0}

    def try_preempt():
        calls["preempt"] += 1
        return False

    plan = s.plan_step(n_active=1, prefilling=collections.OrderedDict(),
                       try_admit=lambda: None, try_preempt=try_preempt)
    assert plan.admitted == 0 and calls["preempt"] == 1


# ---------------------------------------------------------------------------
# Engine-level policy behavior (fast)
# ---------------------------------------------------------------------------

def test_admission_order_is_class_then_deadline(tiny):
    """A 1-slot engine serializes service, so completion order reveals
    admission order: classes first, EDF within a class."""
    lm, params = tiny
    eng = ServingEngine(lm, params, batch_slots=1, max_seq_len=32,
                        min_bucket=4)
    eng.submit(np.arange(4), max_new_tokens=2)                 # rid 0, FIFO
    eng.submit(np.arange(5), max_new_tokens=2, priority=1,
               deadline_s=60.0)                                # rid 1
    eng.submit(np.arange(6), max_new_tokens=2, priority=1,
               deadline_s=1.0)                                 # rid 2, EDF
    eng.submit(np.arange(7), max_new_tokens=2, priority=2)     # rid 3
    done = eng.run()
    finish_order = sorted(done, key=lambda rid: done[rid].finish_s)
    assert finish_order == [3, 2, 1, 0]
    assert eng.preemptions == 0      # ordering alone, nothing was running


def test_no_preemption_within_a_class(tiny):
    """Equal-class pressure never preempts: deadlines order service, they
    don't justify eviction (preemption thrash)."""
    lm, params = tiny
    eng = ServingEngine(lm, params, batch_slots=1, max_seq_len=32,
                        min_bucket=4, cache_backend="paged", block_size=8,
                        num_pool_blocks=5)
    eng.submit(np.arange(4), max_new_tokens=8)
    eng.step()                                   # rid 0 holds the slot
    eng.submit(np.arange(4), max_new_tokens=2, deadline_s=0.001)
    done = eng.run()
    assert eng.preemptions == 0
    assert done[0].finish_s < done[1].finish_s   # FIFO preserved


def test_preemption_timing_sticky_and_counted(tiny):
    """A preempted-then-resumed request keeps its first-admission stamp
    (no fresh TTFT) and counts its preemptions."""
    lm, params = tiny
    eng = ServingEngine(lm, params, batch_slots=1, max_seq_len=32,
                        min_bucket=4, cache_backend="paged", block_size=8)
    eng.submit(np.arange(4), max_new_tokens=6)
    eng.step()                                   # admit (arming round)
    eng.step()                                   # first token exists
    r = eng._slots[0]
    admit0, ttft0 = r.admit_s, r.ttft_s
    assert admit0 > 0 and ttft0 > 0
    eng.preempt(0)
    assert r.preemptions == 1 and eng.preemptions == 1
    done = eng.run()                             # resumes and finishes
    assert done[0].admit_s == admit0             # sticky across swap-out
    assert done[0].ttft_s == ttft0
    assert done[0].preemptions == 1


def test_peak_active_slots_counts_prefill_only_steps(tiny):
    """Steps where requests are prefilling but none are decoding used to
    be invisible to ``peak_active_slots``."""
    lm, params = tiny
    eng = ServingEngine(lm, params, batch_slots=2, max_seq_len=32,
                        min_bucket=4, chunk_tokens=4, token_budget=6)
    eng.submit(np.arange(20), max_new_tokens=2)  # 20 tokens: several chunks
    eng.step()                                   # chunk 1: prefill-only step
    assert not eng._slots and eng._prefilling
    assert eng.peak_active_slots == 1
    eng.run()


def test_batched_lookahead_coalesces_dispatches(tiny):
    """Several slots crossing a block boundary in the same plan share one
    coalesced table update: reservation dispatches < per-slot top-ups."""
    lm, params = tiny
    eng = ServingEngine(lm, params, batch_slots=3, max_seq_len=32,
                        min_bucket=4, cache_backend="paged", block_size=8,
                        max_decode_steps=8)
    # same shape/budget: slots advance in lockstep and cross together
    for _ in range(3):
        eng.submit(np.arange(6), max_new_tokens=20)
    eng.run()
    assert eng.backend.lookahead_topups > eng.lookahead_dispatches >= 1


def test_infeasible_request_never_triggers_eviction_storm(tiny):
    """A high-priority request whose worst case exceeds the whole pool can
    never admit: it must not evict the active lower-class work one swap at
    a time — it is terminally rejected (machine-readable reason) and
    everyone else completes normally; one bad submit never aborts
    ``run()``."""
    lm, params = tiny
    eng = ServingEngine(lm, params, batch_slots=2, max_seq_len=32,
                        min_bucket=4, cache_backend="paged", block_size=8,
                        num_pool_blocks=4)          # 3 usable blocks
    ok = eng.submit(np.arange(4), max_new_tokens=8)  # fits: 2 blocks
    eng.step()
    big = eng.submit(np.arange(8), max_new_tokens=24, priority=5)  # 4 > 3
    done = eng.run()
    assert eng.preemptions == 0                     # nobody was evicted
    assert done[ok].status == "done" and len(done[ok].output) == 8
    assert done[big].status == "rejected"
    assert done[big].failure_reason.startswith("exceeds_pool_capacity")
    eng.backend.assert_invariants()


def test_preempt_refused_when_recovery_cannot_cover_demand(tiny):
    """Eviction only helps if the free list plus every strictly-lower-class
    slot's blocks cover the blocked request — a feasible-in-principle
    request must not evict a small low-class slot whose blocks cannot
    possibly satisfy it (pure waste: the swap costs a host round-trip and
    the victim requeues behind the still-blocked request)."""
    lm, params = tiny
    eng = ServingEngine(lm, params, batch_slots=3, max_seq_len=32,
                        min_bucket=4, cache_backend="paged", block_size=8,
                        num_pool_blocks=7)          # 6 usable
    eng.submit(np.arange(4), max_new_tokens=8)               # pri 0: 2 blk
    eng.submit(np.arange(8), max_new_tokens=20, priority=2)  # pri 2: 4 blk
    eng.step()                                      # pool fully committed
    # pri 1 needs 4 blocks; recoverable = 0 free + 2 (the pri-0 slot) < 4
    eng.submit(np.arange(8), max_new_tokens=20, priority=1)
    done = eng.run()
    assert eng.preemptions == 0                     # waited, no vain evict
    assert len(done) == 3 and all(r.output is not None
                                  for r in done.values())
    eng.backend.assert_invariants()


def test_preempt_mode_validation(tiny):
    lm, params = tiny
    with pytest.raises(ValueError, match="preempt_mode"):
        ServingEngine(lm, params, batch_slots=1, max_seq_len=32,
                      preempt_mode="bogus")
    with pytest.raises(ValueError, match="swap"):
        ServingEngine(lm, params, batch_slots=1, max_seq_len=32,
                      preempt_mode="swap")      # ring has no swap pair


# ---------------------------------------------------------------------------
# Preemption exactness: the acceptance contract
# ---------------------------------------------------------------------------

CONFIGS = {
    "ring_recompute": (lambda: _tiny_cfg(), {}),
    "paged_swap": (lambda: _tiny_cfg(),
                   dict(cache_backend="paged", block_size=8)),
    "paged_recompute": (lambda: _tiny_cfg(),
                        dict(cache_backend="paged", block_size=8,
                             chunk_tokens=4, preempt_mode="recompute")),
    "windowed_paged_swap": (lambda: _tiny_cfg(window=8),
                            dict(cache_backend="paged", block_size=8)),
}


def _run_with_random_preemptions(lm, params, trace, *, seed, temperature=0.0,
                                 **kw):
    """Drive step() and, between steps, preempt a random active slot with
    some probability — a random preempt/resume schedule."""
    rng = np.random.default_rng(seed)
    eng = ServingEngine(lm, params, max_seq_len=32, min_bucket=4,
                        batch_slots=2, **kw)
    for prompt, max_new in trace:
        eng.submit(prompt, max_new_tokens=max_new, temperature=temperature)
    while eng.pending:
        eng.step()
        if eng._slots and rng.random() < 0.4:
            eng.preempt(int(rng.choice(list(eng._slots))))
        if hasattr(eng.backend, "assert_invariants"):
            eng.backend.assert_invariants()       # holds after every swap
    done = eng.run()
    return eng, {rid: r.output for rid, r in done.items()}


@pytest.mark.slow
@pytest.mark.parametrize("name", sorted(CONFIGS))
@pytest.mark.parametrize("temperature", (0.0, 0.8))
def test_random_preemption_schedules_are_exact(name, temperature):
    """Token-for-token vs the uncontended engine under random forced
    preempt/resume schedules, greedy and keyed sampling, all backends."""
    cfg_fn, kw = CONFIGS[name]
    lm, params = _lm(cfg_fn())
    trace = _mixed_trace(n=6, seed=2)
    base_eng = ServingEngine(lm, params, max_seq_len=32, min_bucket=4,
                             batch_slots=6)
    for prompt, max_new in trace:
        base_eng.submit(prompt, max_new_tokens=max_new,
                        temperature=temperature)
    base = {rid: r.output for rid, r in base_eng.run().items()}
    for seed in (0, 1):
        eng, out = _run_with_random_preemptions(
            lm, params, trace, seed=seed, temperature=temperature, **kw)
        _assert_same(base, out)
        assert eng.preemptions > 0, "schedule never preempted — tune seed"
        if hasattr(eng.backend, "assert_invariants"):
            be = eng.backend
            be.assert_invariants()
            # drained: free list full, ledger empty, no leaked refcounts
            assert sorted(be._free) == list(range(1, be.num_blocks))
            assert be._gap_total == 0 and be._ref == {}


@pytest.mark.slow
def test_random_preemption_with_multi_step_decode():
    """Preemption composes with the K-scan: checkpoints are taken at host
    syncs, where the host-side step mirror is exact."""
    lm, params = _lm(_tiny_cfg())
    trace = _mixed_trace(n=6, seed=3)
    base_eng = ServingEngine(lm, params, max_seq_len=32, min_bucket=4,
                             batch_slots=6)
    for prompt, max_new in trace:
        base_eng.submit(prompt, max_new_tokens=max_new)
    base = {rid: r.output for rid, r in base_eng.run().items()}
    for kw in (dict(cache_backend="paged", block_size=8, max_decode_steps=8),
               dict(max_decode_steps=4, chunk_tokens=8)):
        eng, out = _run_with_random_preemptions(lm, params, trace, seed=4,
                                                **kw)
        _assert_same(base, out)
        assert eng.preemptions > 0


@pytest.mark.slow
def test_blocked_high_priority_preempts_and_wins():
    """The end-to-end SLO story: a high-class arrival lands on a starved
    pool, evicts a bulk request's blocks, is served at once, and the bulk
    request resumes token-exactly."""
    lm, params = _lm(_tiny_cfg())
    low = [(np.arange(6), 20), (np.arange(8), 20)]
    hi = (np.arange(4), 4)
    base_eng = ServingEngine(lm, params, max_seq_len=32, min_bucket=4,
                             batch_slots=4)
    for p, mn in low + [hi]:
        base_eng.submit(p, max_new_tokens=mn)
    base = {rid: r.output for rid, r in base_eng.run().items()}

    eng = ServingEngine(lm, params, max_seq_len=32, min_bucket=4,
                        batch_slots=3, cache_backend="paged", block_size=8,
                        num_pool_blocks=9, max_decode_steps=4)
    for p, mn in low:
        eng.submit(p, max_new_tokens=mn)
    for _ in range(3):
        eng.step()                            # bulk fills the pool
    eng.submit(hi[0], max_new_tokens=hi[1], priority=5)
    while eng.pending:
        eng.step()
        eng.backend.assert_invariants()
    done = eng._done
    _assert_same(base, {rid: r.output for rid, r in done.items()})
    assert eng.preemptions >= 1
    assert eng.backend.swap_outs >= 1 and eng.backend.swap_ins >= 1
    # the critical request finished before both bulk requests
    assert done[2].finish_s < min(done[0].finish_s, done[1].finish_s)
    assert done[2].preemptions == 0
    assert max(done[0].preemptions, done[1].preemptions) >= 1
    assert sorted(eng.backend._free) == list(range(1, eng.backend.num_blocks))
