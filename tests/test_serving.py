"""Serving: prefill+decode == full forward; engines; partitioned inference."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import ModelConfig, dense_stages
from repro.models.model import LM


def _tiny_cfg():
    return ModelConfig(
        name="tiny", family="dense", source="t", num_layers=3, d_model=48,
        num_heads=4, num_kv_heads=2, head_dim=12, d_ff=96, vocab_size=128,
        stages=dense_stages(3), param_dtype="float32")


def test_prefill_then_decode_matches_full_forward():
    """The deployment-critical identity: prefill(S) + decode(t) logits must
    equal forward(S+t) at every decoded position."""
    cfg = _tiny_cfg()
    lm = LM(cfg, kv_chunk=8)
    params, _ = lm.init(jax.random.PRNGKey(0))
    total, prompt = 12, 8
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, total), 0, 100)
    full_logits, _, _, _ = lm.forward(params, {"tokens": tokens})
    logits_p, caches = lm.prefill(params, {"tokens": tokens[:, :prompt]},
                                  cache_width=total)
    assert float(jnp.max(jnp.abs(
        logits_p[:, -1] - full_logits[:, prompt - 1]))) < 1e-3
    for t in range(prompt, total):
        step_logits, caches = lm.decode_step(
            params, caches, tokens[:, t:t + 1], jnp.int32(t))
        err = float(jnp.max(jnp.abs(step_logits[:, 0] - full_logits[:, t])))
        assert err < 1e-3, (t, err)


def test_prompt_buckets_and_bucket_for_edge_cases():
    from repro.serving import bucket_for, prompt_buckets
    # powers of two from min_bucket up to (and always including) the max
    assert prompt_buckets(128, 16) == [16, 32, 64, 128]
    # non-power-of-two max is still the top bucket
    assert prompt_buckets(100, 16) == [16, 32, 64, 100]
    # min_bucket == max -> a single bucket
    assert prompt_buckets(16, 16) == [16]
    # min_bucket above max still yields a usable top bucket
    assert prompt_buckets(8, 16) == [8]
    buckets = prompt_buckets(64, 8)
    # boundaries snap to their own bucket, not the next one
    for n, expect in ((1, 8), (8, 8), (9, 16), (16, 16), (17, 32),
                      (63, 64), (64, 64)):
        assert bucket_for(n, buckets) == expect, n
    with pytest.raises(ValueError, match="exceeds the largest"):
        bucket_for(65, buckets)


def test_serving_engine_batches_and_completes():
    from repro.serving import ServingEngine
    cfg = _tiny_cfg()
    lm = LM(cfg, kv_chunk=8)
    params, _ = lm.init(jax.random.PRNGKey(0))
    eng = ServingEngine(lm, params, batch_slots=4, max_seq_len=32)
    ids = [eng.submit(np.arange(3 + i), max_new_tokens=5) for i in range(6)]
    done = eng.run()
    assert set(done) == set(ids)
    for r in done.values():
        assert r.output.shape == (5,)
        assert r.latency_s > 0


@pytest.mark.slow
def test_continuous_matches_drain_batch():
    """Mixed-length prompts with different decode budgets must generate
    exactly the same greedy tokens on the continuous-batching engine as on
    the drain-batch baseline (bucketing/right-padding is output-exact)."""
    from repro.serving import DrainBatchEngine, ServingEngine
    cfg = _tiny_cfg()
    lm = LM(cfg, kv_chunk=8)
    params, _ = lm.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)
    reqs = [(rng.integers(0, 100, size=int(rng.integers(3, 12))),
             int(rng.integers(3, 9))) for _ in range(7)]
    cont = ServingEngine(lm, params, batch_slots=3, max_seq_len=32,
                         min_bucket=4)
    drain = DrainBatchEngine(lm, params, batch_slots=3, max_seq_len=32)
    for prompt, max_new in reqs:
        cont.submit(prompt, max_new_tokens=max_new)
        drain.submit(prompt, max_new_tokens=max_new)
    dc, dd = cont.run(), drain.run()
    assert set(dc) == set(dd)
    for rid in dc:
        assert dc[rid].output.shape == (reqs[rid][1],)
        np.testing.assert_array_equal(dc[rid].output, dd[rid].output)
    # more requests than slots -> slots were reused
    assert cont.decode_steps < sum(mn for _, mn in reqs)
    assert 0.0 < cont.occupancy() <= 1.0


def test_continuous_engine_eos_stops_early():
    from repro.serving import ServingEngine
    cfg = _tiny_cfg()
    lm = LM(cfg, kv_chunk=8)
    params, _ = lm.init(jax.random.PRNGKey(0))
    probe = ServingEngine(lm, params, batch_slots=1, max_seq_len=32,
                          min_bucket=4)
    probe.submit(np.arange(5), max_new_tokens=8)
    first = int(probe.run()[0].output[0])    # greedy first token
    eng = ServingEngine(lm, params, batch_slots=1, max_seq_len=32,
                        min_bucket=4, eos_id=first)
    eng.submit(np.arange(5), max_new_tokens=8)
    out = eng.run()[0].output
    assert len(out) == 1 and int(out[0]) == first


def test_submit_rejects_overlong_prompts():
    """An over-long prompt used to fall into the top bucket and silently
    wrap the ring mid-prefill; every engine must now refuse at submit."""
    from repro.serving import DrainBatchEngine, ServingEngine
    cfg = _tiny_cfg()
    lm = LM(cfg, kv_chunk=8)
    params, _ = lm.init(jax.random.PRNGKey(0))
    for cls in (ServingEngine, DrainBatchEngine):
        eng = cls(lm, params, batch_slots=2, max_seq_len=16)
        with pytest.raises(ValueError, match="max_seq_len"):
            eng.submit(np.arange(20), max_new_tokens=4)
        with pytest.raises(ValueError, match="max_seq_len"):
            eng.submit(np.arange(14), max_new_tokens=4)   # prompt+budget > 16
        with pytest.raises(ValueError, match="no room"):
            eng.submit(np.arange(4), max_new_tokens=16)
    eng = ServingEngine(lm, params, batch_slots=2, max_seq_len=16)
    eng.submit(np.arange(12), max_new_tokens=4)           # exactly fits


def test_submit_truncation_keeps_prompt_tail():
    from repro.serving import ServingEngine
    cfg = _tiny_cfg()
    lm = LM(cfg, kv_chunk=8)
    params, _ = lm.init(jax.random.PRNGKey(0))
    prompt = np.random.default_rng(0).integers(0, 100, size=40)
    trunc = ServingEngine(lm, params, batch_slots=1, max_seq_len=16,
                          min_bucket=4, truncate_prompts=True)
    rid = trunc.submit(prompt, max_new_tokens=4)
    out_t = trunc.run()[rid].output
    assert out_t.shape == (4,)
    # truncation is explicit: same output as submitting the tail directly
    tail = ServingEngine(lm, params, batch_slots=1, max_seq_len=16,
                         min_bucket=4)
    rid2 = tail.submit(prompt[-12:], max_new_tokens=4)
    np.testing.assert_array_equal(out_t, tail.run()[rid2].output)


def test_cascade_submit_validates():
    from repro.cascade.ecc_infer import CascadeLM, edge_variant
    from repro.serving import CascadeServingEngine
    cloud_cfg = _tiny_cfg()
    edge_cfg = edge_variant(cloud_cfg, layers=1)
    cloud, edge = LM(cloud_cfg, kv_chunk=8), LM(edge_cfg, kv_chunk=8)
    cp, _ = cloud.init(jax.random.PRNGKey(0))
    ep, _ = edge.init(jax.random.PRNGKey(1))
    eng = CascadeServingEngine(CascadeLM(edge, cloud), ep, cp,
                               batch_slots=2, max_seq_len=16)
    with pytest.raises(ValueError, match="max_seq_len"):
        eng.submit(np.arange(30), max_new_tokens=4)


def test_cascade_serving_engine_routes_and_generates():
    from repro.cascade.ecc_infer import CascadeLM, edge_variant
    from repro.cascade.gate import make_thresholds
    from repro.serving import CascadeServingEngine
    cloud_cfg = _tiny_cfg()
    edge_cfg = edge_variant(cloud_cfg, layers=1)
    cloud, edge = LM(cloud_cfg, kv_chunk=8), LM(edge_cfg, kv_chunk=8)
    cp, _ = cloud.init(jax.random.PRNGKey(0))
    ep, _ = edge.init(jax.random.PRNGKey(1))
    # mid-band thresholds so an untrained draft exercises several routes
    cascade = CascadeLM(edge, cloud,
                        thresholds=make_thresholds(hi=0.01, lo=0.001))
    eng = CascadeServingEngine(cascade, ep, cp, batch_slots=2,
                               max_seq_len=32)
    rng = np.random.default_rng(0)
    ids = [eng.submit(rng.integers(0, 100, size=4 + i), max_new_tokens=3)
           for i in range(5)]
    done = eng.run()
    assert set(done) == set(ids)
    m = eng.metrics
    assert m.queries == 5
    assert m.accepted + m.dropped + m.escalated == 5
    for r in done.values():
        assert r.route in ("accept", "escalate", "drop")
        expected = 0 if r.route == "drop" else 3
        assert r.output is not None and len(r.output) == expected


def test_cascade_engine_metrics():
    from repro.cascade.ecc_infer import CascadeLM, edge_variant
    from repro.serving import CascadeEngine
    cloud_cfg = _tiny_cfg()
    edge_cfg = edge_variant(cloud_cfg, layers=1)
    cloud, edge = LM(cloud_cfg, kv_chunk=8), LM(edge_cfg, kv_chunk=8)
    cp, _ = cloud.init(jax.random.PRNGKey(0))
    ep, _ = edge.init(jax.random.PRNGKey(1))
    eng = CascadeEngine(CascadeLM(edge, cloud), ep, cp)
    tokens = np.random.default_rng(0).integers(0, 100, size=(8, 10))
    out = eng.query(tokens)
    m = eng.metrics
    assert m.queries == 8
    assert m.accepted + m.dropped + m.escalated == 8
    assert out["pred"].shape == (8,)


def test_partitioned_lm_matches_full():
    """Intra-model ECC inference: edge bottom + cloud top == monolith."""
    from repro.core.patterns.inference import PartitionedLM
    cfg = _tiny_cfg()
    lm = LM(cfg, kv_chunk=8)
    params, _ = lm.init(jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 9), 0, 100)
    full, _, _, _ = lm.forward(params, {"tokens": tokens})
    part = PartitionedLM(lm, split=1)
    hidden, positions = part.edge_forward(params, {"tokens": tokens})
    logits = part.cloud_forward(params, hidden, positions)
    assert float(jnp.max(jnp.abs(full - logits))) < 1e-3


def test_best_partition_tradeoffs():
    from repro.core.patterns.inference import best_partition
    cfg = get_config("smollm-135m")
    # slow WAN -> all-edge or all-cloud beats mid-split (boundary is big)
    k_slow, _ = best_partition(cfg, batch=1, seq_len=128,
                               edge_flops_s=5e10, cloud_flops_s=5e12,
                               uplink_mbps=1.0, delay_s=0.05)
    total = sum(s.repeat for s in cfg.stages)
    assert k_slow in (0, total)
    # free WAN + slow edge -> everything to the cloud
    k_fast, _ = best_partition(cfg, batch=1, seq_len=128,
                               edge_flops_s=1e9, cloud_flops_s=5e13,
                               uplink_mbps=1e6, delay_s=0.0)
    assert k_fast == 0
