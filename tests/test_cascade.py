"""Cascade core: gate properties, routing conservation, compact==lockstep."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.cascade.gate import (ACCEPT, DROP, ESCALATE, adaptive_thresholds,
                                ap_init, basic_gate, confidence_from_logits,
                                gate_counts, make_thresholds)
from repro.cascade.routing import (compact_escalations, gather_compacted,
                                   scatter_back)


@settings(max_examples=30, deadline=None)
@given(n=st.integers(1, 64), hi=st.floats(0.5, 0.99), lo=st.floats(0.0, 0.45),
       seed=st.integers(0, 10_000))
def test_gate_partitions(n, hi, lo, seed):
    conf = jax.random.uniform(jax.random.PRNGKey(seed), (n,))
    routes = np.asarray(basic_gate(conf, make_thresholds(hi, lo)))
    conf = np.asarray(conf)
    assert np.all(routes[conf >= hi] == ACCEPT)
    assert np.all(routes[conf < lo] == DROP)
    assert np.all(routes[(conf >= lo) & (conf < hi)] == ESCALATE)
    counts = gate_counts(jnp.asarray(routes))
    assert int(counts["accept"] + counts["drop"] + counts["escalate"]) == n


def test_gate_monotone_in_confidence():
    """Raising confidence never moves a crop 'down' (drop < escalate < accept)."""
    th = make_thresholds()
    rank = {DROP: 0, ESCALATE: 1, ACCEPT: 2}
    confs = jnp.linspace(0, 1, 101)
    routes = [rank[int(basic_gate(jnp.float32(c), th))] for c in confs]
    assert all(b >= a for a, b in zip(routes, routes[1:]))


@settings(max_examples=30, deadline=None)
@given(b=st.integers(1, 48), cap_frac=st.floats(0.1, 1.0),
       seed=st.integers(0, 10_000))
def test_routing_conservation(b, cap_frac, seed):
    """scatter_back: escalated rows within capacity take the cloud value,
    everything else keeps the edge value; order preserved."""
    cap = max(1, int(b * cap_frac))
    esc = jax.random.bernoulli(jax.random.PRNGKey(seed), 0.4, (b,))
    routing = compact_escalations(esc, cap)
    order = np.asarray(routing.order)
    assert sorted(order.tolist()) == list(range(b))       # a permutation
    edge = jnp.arange(b, dtype=jnp.float32)[:, None] * jnp.ones((1, 3))
    cloud_rows = gather_compacted(edge, routing, cap) + 1000.0
    final = np.asarray(scatter_back(edge, cloud_rows, routing))
    esc_np = np.asarray(esc)
    n_esc = int(esc_np.sum())
    served = set(order[:cap][np.asarray(routing.kept)[:min(cap, b)]].tolist()) \
        if cap <= b else set()
    for i in range(b):
        if esc_np[i] and i in served:
            assert final[i, 0] == i + 1000.0               # cloud result
        else:
            assert final[i, 0] == i                        # edge kept
    # escalations beyond capacity degrade to edge results, never garbage
    assert np.all(np.isfinite(final))
    assert int(routing.num_escalated) == n_esc


def test_escalated_first_stable_order():
    esc = jnp.array([False, True, False, True, True, False])
    routing = compact_escalations(esc, 3)
    assert np.asarray(routing.order)[:3].tolist() == [1, 3, 4]


def test_confidence_from_logits_bounds():
    logits = jax.random.normal(jax.random.PRNGKey(0), (32, 100)) * 5
    conf = confidence_from_logits(logits)
    assert float(conf.min()) >= 1.0 / 100
    assert float(conf.max()) <= 1.0


def test_adaptive_thresholds_shrink_and_recover():
    state = ap_init()
    # sustained deterioration shrinks the band
    for _ in range(5):
        state = adaptive_thresholds(state, jnp.float32(2.0), jnp.float32(0.0),
                                    deteriorate_s=0.3)
    assert float(state.th.hi) < 0.8
    assert float(state.th.lo) > 0.1
    # recovery restores toward BP
    for _ in range(50):
        state = adaptive_thresholds(state, jnp.float32(0.0), jnp.float32(0.0),
                                    deteriorate_s=0.3)
    assert abs(float(state.th.hi) - 0.8) < 1e-3
    assert abs(float(state.th.lo) - 0.1) < 1e-3


def test_cascade_lm_compact_matches_lockstep():
    """Within capacity, the compacted cascade must agree with the
    paper-faithful lockstep on every row."""
    from repro.cascade.ecc_infer import CascadeLM, edge_variant
    from repro.configs import get_config
    from repro.models.model import LM

    cloud_cfg = get_config("smollm-135m").reduced()
    edge_cfg = edge_variant(cloud_cfg, layers=1)
    cloud, edge = LM(cloud_cfg, kv_chunk=16), LM(edge_cfg, kv_chunk=16)
    cp, _ = cloud.init(jax.random.PRNGKey(0))
    ep, _ = edge.init(jax.random.PRNGKey(1))
    cas = CascadeLM(edge, cloud, capacity_frac=1.0)   # capacity == batch
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(2), (6, 12),
                                          0, 100)}
    a = cas.serve_step(ep, cp, batch)
    b = cas.lockstep_step(ep, cp, batch)
    assert np.array_equal(np.asarray(a["routes"]), np.asarray(b["routes"]))
    assert np.array_equal(np.asarray(a["pred"]), np.asarray(b["pred"]))
    # compaction strictly reduces boundary traffic when not everything
    # escalates
    if int(a["escalate"]) < 6:
        assert int(a["wan_bytes"]) < int(b["wan_bytes"])
