"""Attention correctness: blockwise==dense, custom-vjp grads, windows, GQA,
cache fill/write, decode==forward consistency, MLA absorbed==expanded."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import MLAConfig, ModelConfig, dense_stages
from repro.models import attention as att
from repro.kernels.ref import flash_attention_ref


def _cfg(**kw):
    base = dict(name="t", family="dense", source="t", num_layers=2,
                d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
                d_ff=128, vocab_size=256, stages=dense_stages(2))
    base.update(kw)
    return ModelConfig(**base)


def test_blockwise_matches_dense():
    rng = jax.random.PRNGKey(0)
    for window in (None, 10):
        ks = jax.random.split(rng, 3)
        q = jax.random.normal(ks[0], (2, 37, 4, 16))
        k = jax.random.normal(ks[1], (2, 37, 2, 16))
        v = jax.random.normal(ks[2], (2, 37, 2, 16))
        pos = jnp.broadcast_to(jnp.arange(37), (2, 37))
        out = att.blockwise_attention(q, k, v, pos, pos, window=window,
                                      scale=0.25, kv_chunk=8)
        ref = flash_attention_ref(q, k, v, window=window, scale=0.25)
        assert float(jnp.max(jnp.abs(out - ref))) < 2e-5


def test_flash_vjp_matches_autodiff_of_dense():
    rng = jax.random.PRNGKey(1)
    ks = jax.random.split(rng, 3)
    q = jax.random.normal(ks[0], (1, 19, 4, 8))
    k = jax.random.normal(ks[1], (1, 19, 2, 8))
    v = jax.random.normal(ks[2], (1, 19, 2, 8))
    pos = jnp.broadcast_to(jnp.arange(19), (1, 19))
    f1 = lambda q, k, v: jnp.sum(jnp.tanh(att.blockwise_attention(
        q, k, v, pos, pos, window=None, scale=0.3, kv_chunk=4)))
    f2 = lambda q, k, v: jnp.sum(jnp.tanh(flash_attention_ref(
        q, k, v, scale=0.3)))
    g1 = jax.grad(f1, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f2, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        assert float(jnp.max(jnp.abs(a - b))) < 1e-4


def test_decode_matches_forward():
    """Step-by-step decode with a ring cache must equal full-seq forward."""
    cfg = _cfg()
    rng = jax.random.PRNGKey(2)
    params_boxed = att.attn_init(rng, cfg, jnp.float32)
    from repro.models.param import unbox
    params, _ = unbox(params_boxed)
    S = 12
    x = jax.random.normal(jax.random.PRNGKey(3), (2, S, cfg.d_model)) * 0.3
    pos = jnp.broadcast_to(jnp.arange(S), (2, S))
    full, _ = att.attn_forward(params, cfg, x, pos, window=None)
    cache = att.init_kv_cache(2, S, cfg.num_kv_heads, cfg.resolved_head_dim,
                              jnp.float32)
    outs = []
    for t in range(S):
        y, cache = att.attn_decode(params, cfg, x[:, t:t + 1], cache,
                                   jnp.int32(t), window=None)
        outs.append(y)
    stepped = jnp.concatenate(outs, axis=1)
    assert float(jnp.max(jnp.abs(full - stepped))) < 1e-4


def test_windowed_ring_cache_decode():
    """With a ring cache of width W == window, decode equals forward."""
    cfg = _cfg(stages=dense_stages(2, window=6))
    rng = jax.random.PRNGKey(4)
    from repro.models.param import unbox
    params, _ = unbox(att.attn_init(rng, cfg, jnp.float32))
    S, W = 16, 6
    x = jax.random.normal(jax.random.PRNGKey(5), (1, S, cfg.d_model)) * 0.3
    pos = jnp.broadcast_to(jnp.arange(S), (1, S))
    full, _ = att.attn_forward(params, cfg, x, pos, window=W)
    cache = att.init_kv_cache(1, W, cfg.num_kv_heads, cfg.resolved_head_dim,
                              jnp.float32)
    outs = []
    for t in range(S):
        y, cache = att.attn_decode(params, cfg, x[:, t:t + 1], cache,
                                   jnp.int32(t), window=W)
        outs.append(y)
    stepped = jnp.concatenate(outs, axis=1)
    assert float(jnp.max(jnp.abs(full - stepped))) < 1e-4


def test_cache_fill_matches_writes():
    """Prefill cache_fill == sequential cache_write, including ring wrap."""
    for S, W in ((5, 8), (13, 8)):
        k = jax.random.normal(jax.random.PRNGKey(6), (1, S, 2, 4))
        v = jax.random.normal(jax.random.PRNGKey(7), (1, S, 2, 4))
        filled = att.cache_fill(att.init_kv_cache(1, W, 2, 4, jnp.float32),
                                k, v, S)
        step = att.init_kv_cache(1, W, 2, 4, jnp.float32)
        for t in range(S):
            step = att.cache_write(step, k[:, t:t + 1], v[:, t:t + 1],
                                   jnp.int32(t))
        for key in ("k", "v", "pos"):
            assert jnp.allclose(filled[key], step[key]), (S, W, key)


def test_mla_decode_matches_expanded():
    """Absorbed-form MLA decode == expanded-form forward, step by step."""
    cfg = _cfg(num_heads=4, num_kv_heads=4,
               mla=MLAConfig(q_lora_rank=24, kv_lora_rank=16,
                             qk_nope_head_dim=8, qk_rope_head_dim=4,
                             v_head_dim=8))
    from repro.models.param import unbox
    params, _ = unbox(att.mla_init(jax.random.PRNGKey(8), cfg, jnp.float32))
    S = 10
    x = jax.random.normal(jax.random.PRNGKey(9), (2, S, cfg.d_model)) * 0.3
    pos = jnp.broadcast_to(jnp.arange(S), (2, S))
    full, _ = att.mla_forward(params, cfg, x, pos, window=None, kv_chunk=4)
    cache = att.init_mla_cache(cfg, 2, S, jnp.float32)
    outs = []
    for t in range(S):
        y, cache = att.mla_decode(params, cfg, x[:, t:t + 1], cache,
                                  jnp.int32(t), window=None)
        outs.append(y)
    stepped = jnp.concatenate(outs, axis=1)
    assert float(jnp.max(jnp.abs(full - stepped))) < 2e-4
