"""ACE platform integration: registration -> topology -> orchestration ->
deployment -> update -> removal, plus orchestrator constraint properties."""
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.orchestrator import PlanningError
from repro.core.platform import AcePlatform
from repro.core.registry import IMAGES, image
from repro.core.topology import Component, Resources, Topology


@image("test/null")
class NullComponent:
    def __init__(self, **kw):
        self.kw = kw
        self.running = False

    def start(self, ctx):
        self.ctx = ctx
        self.running = True

    def stop(self):
        self.running = False


def _platform():
    ace = AcePlatform()
    ace.register_user("alice")
    infra = ace.register_infrastructure(
        "alice", num_ecs=2, nodes_per_ec=3,
        edge_labels=[["x86"], ["camera"], ["camera"]])
    ace.deploy_services(infra)
    return ace, infra


def _topo(**comps):
    return Topology(app="app", version=1, components=comps)


def test_full_lifecycle():
    ace, infra = _platform()
    topo = _topo(
        worker=Component(name="worker", image="test/null", placement="edge",
                         replicas="per_ec",
                         resources=Resources(cpu=1.0, memory_mb=256)),
        head=Component(name="head", image="test/null", placement="cloud",
                       connections=["worker"]),
    )
    ace.submit_app("alice", infra, topo)
    plan = ace.deploy_app("alice", "app")
    assert len(plan.instances["worker"]) == 2          # one per EC
    assert len(plan.instances["head"]) == 1
    for inst in plan.instances["worker"]:
        assert ".ec-" in inst.node
    assert ".cc-" in plan.instances["head"][0].node
    # agents actually started the components
    assert len(ace.instances(infra, "worker")) == 2
    # resources were allocated on the bound nodes
    node = infra.nodes[plan.instances["worker"][0].node]
    assert node.allocated.cpu == 1.0
    # removal releases them
    ace.remove_app("alice", "app")
    assert len(ace.instances(infra, "worker")) == 0
    assert node.allocated.cpu == 0.0


def test_label_constraint():
    ace, infra = _platform()
    topo = _topo(cam=Component(name="cam", image="test/null",
                               replicas="per_label", labels=["camera"]))
    ace.submit_app("alice", infra, topo)
    plan = ace.deploy_app("alice", "app")
    assert len(plan.instances["cam"]) == 4             # 2 ECs x 2 cam nodes
    for inst in plan.instances["cam"]:
        assert "camera" in infra.nodes[inst.node].labels


def test_unsatisfiable_resources_raise():
    ace, infra = _platform()
    topo = _topo(fat=Component(
        name="fat", image="test/null", placement="edge",
        resources=Resources(cpu=1000.0, memory_mb=1)))
    ace.submit_app("alice", infra, topo)
    with pytest.raises(PlanningError):
        ace.deploy_app("alice", "app")


def test_accelerator_constraint_pins_to_cloud():
    ace, infra = _platform()
    topo = _topo(gpu=Component(
        name="gpu", image="test/null", placement="any",
        resources=Resources(cpu=1.0, memory_mb=64, accelerator=True)))
    ace.submit_app("alice", infra, topo)
    plan = ace.deploy_app("alice", "app")
    assert ".cc-" in plan.instances["gpu"][0].node


def test_incremental_update():
    ace, infra = _platform()
    c = lambda name, cpu: Component(name=name, image="test/null",
                                    resources=Resources(cpu=cpu,
                                                        memory_mb=64))
    ace.submit_app("alice", infra, _topo(a=c("a", 0.1), b=c("b", 0.1)))
    ace.deploy_app("alice", "app")
    new = _topo(a=c("a", 0.1), b=c("b", 0.5), d=c("d", 0.1))  # b changed, d new
    plan = ace.update_app("alice", "app", new, incremental=True)
    assert set(plan.instances) == {"a", "b", "d"}
    assert len(ace.instances(infra, "a")) == 1          # untouched
    assert len(ace.instances(infra, "d")) == 1          # added


def test_node_shielding_redirects_placement():
    ace, infra = _platform()
    ctl = ace._controllers[str(infra.infra_id)]
    # shield every node of the first EC
    first_ec = infra.ecs[0]
    for key, node in infra.nodes.items():
        if node.cluster == first_ec:
            ctl.shield_node(infra, key)
    topo = _topo(w=Component(name="w", image="test/null", placement="edge"))
    ace.submit_app("alice", infra, topo)
    plan = ace.deploy_app("alice", "app")
    assert str(first_ec) not in plan.instances["w"][0].node


def test_topology_yaml_roundtrip():
    topo = _topo(a=Component(name="a", image="test/null",
                             connections=[], params={"x": 1}))
    again = Topology.from_yaml(topo.to_yaml())
    assert again.to_dict() == topo.to_dict()


def test_topology_validates_connections():
    with pytest.raises(ValueError):
        _topo(a=Component(name="a", image="i", connections=["ghost"]))


@settings(max_examples=15, deadline=None)
@given(n_comps=st.integers(1, 6), cpus=st.lists(
    st.floats(0.1, 2.0), min_size=1, max_size=6), seed=st.integers(0, 99))
def test_orchestrator_never_overcommits(n_comps, cpus, seed):
    """Property: any successful plan keeps every node within capacity."""
    ace, infra = _platform()
    comps = {}
    for i in range(n_comps):
        cpu = cpus[i % len(cpus)]
        comps[f"c{i}"] = Component(
            name=f"c{i}", image="test/null", placement="any",
            resources=Resources(cpu=cpu, memory_mb=64))
    ace.submit_app("alice", infra, Topology(app="app", version=1,
                                            components=comps))
    try:
        plan = ace.deploy_app("alice", "app")
    except PlanningError:
        return
    for node in infra.nodes.values():
        assert node.allocated.cpu <= node.capacity.cpu + 1e-9
        assert node.allocated.memory_mb <= node.capacity.memory_mb
