"""MoE dispatch invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs.base import (MOE, ModelConfig, MoEConfig, Stage, BlockDef,
                                ATTN)
from repro.models import moe as moe_lib
from repro.models.param import unbox


def _cfg(e=4, k=2, shared=0):
    return ModelConfig(
        name="t", family="moe", source="t", num_layers=1, d_model=16,
        num_heads=2, num_kv_heads=2, head_dim=8, d_ff=32, vocab_size=64,
        stages=(Stage(blocks=(BlockDef(mixer=ATTN, mlp=MOE),), repeat=1),),
        moe=MoEConfig(num_experts=e, num_experts_per_tok=k, d_ff_expert=32,
                      num_shared_experts=shared, d_ff_shared=32 * shared))


def _dense_reference(params, cfg, x):
    """Compute every expert densely, combine with router weights — the
    semantics moe_forward must match when capacity is unbounded."""
    m = cfg.moe
    b, s, d = x.shape
    x_flat = x.reshape(-1, d)
    idx, w, _ = moe_lib.route(params, cfg, x_flat)
    outs = []
    for e in range(m.num_experts):
        g = x_flat @ params["w_gate"][e]
        u = x_flat @ params["w_up"][e]
        h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
        outs.append(h @ params["w_down"][e])
    outs = jnp.stack(outs, 1)                       # (T, E, D)
    y = jnp.zeros_like(x_flat)
    for j in range(m.num_experts_per_tok):
        y = y + jnp.take_along_axis(
            outs, idx[:, j][:, None, None], axis=1)[:, 0] * w[:, j][:, None]
    if m.num_shared_experts:
        sp = params["shared"]
        g = x_flat @ sp["w_gate"]
        u = x_flat @ sp["w_up"]
        y = y + (jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u) \
            @ sp["w_down"]
    return y.reshape(b, s, d)


@pytest.mark.parametrize("shared", [0, 1])
def test_dispatch_matches_dense_reference(shared):
    cfg = _cfg(e=4, k=2, shared=shared)
    params, _ = unbox(moe_lib.moe_init(jax.random.PRNGKey(0), cfg,
                                       jnp.float32))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 10, cfg.d_model)) * 0.5
    # capacity factor big enough that nothing drops
    y, aux = moe_lib.moe_forward(params, cfg, x, capacity_factor=8.0)
    ref = _dense_reference(params, cfg, x)
    assert float(jnp.max(jnp.abs(y - ref))) < 1e-4
    assert float(aux) > 0.0


def test_capacity_drops_degrade_gracefully():
    cfg = _cfg(e=4, k=1)
    params, _ = unbox(moe_lib.moe_init(jax.random.PRNGKey(2), cfg,
                                       jnp.float32))
    x = jax.random.normal(jax.random.PRNGKey(3), (1, 64, cfg.d_model))
    y_small, _ = moe_lib.moe_forward(params, cfg, x, capacity_factor=0.25)
    y_big, _ = moe_lib.moe_forward(params, cfg, x, capacity_factor=8.0)
    # dropped tokens produce zero update, never NaN
    assert bool(jnp.all(jnp.isfinite(y_small)))
    # with drops, some rows differ from the undropped result
    assert bool(jnp.any(jnp.abs(y_small - y_big) > 1e-6))


@settings(max_examples=20, deadline=None)
@given(t=st.integers(2, 40), e=st.integers(2, 8), k=st.integers(1, 3),
       seed=st.integers(0, 2 ** 16))
def test_slot_assignment_properties(t, e, k, seed):
    """Property: slot ids within each expert are unique and dense (0..n_e-1)
    in token order — the invariant the scatter dispatch relies on."""
    k = min(k, e)
    rng = np.random.default_rng(seed)
    flat_e = rng.integers(0, e, size=t * k)
    onehot = (flat_e[:, None] == np.arange(e)[None, :]).astype(np.int32)
    pos = np.cumsum(onehot, axis=0) - 1
    slot = pos[np.arange(t * k), flat_e]
    for expert in range(e):
        s = np.sort(slot[flat_e == expert])
        assert np.array_equal(s, np.arange(len(s)))


def test_router_aux_loss_balances():
    """Aux loss is ~1 for a perfectly uniform router, > 1 for a collapsed
    one (switch-loss property)."""
    cfg = _cfg(e=4, k=1)
    params, _ = unbox(moe_lib.moe_init(jax.random.PRNGKey(4), cfg,
                                       jnp.float32))
    # collapsed router: all weight on expert 0 (positive inputs guarantee
    # every token picks expert 0)
    collapsed = dict(params)
    collapsed["router"] = jnp.zeros_like(params["router"]).at[:, 0].set(10.0)
    x = jnp.abs(jax.random.normal(jax.random.PRNGKey(5),
                                  (4, 16, cfg.d_model))) + 0.1
    _, _, aux_uniform = moe_lib.route(params, cfg, x.reshape(-1, cfg.d_model))
    _, _, aux_collapsed = moe_lib.route(collapsed, cfg,
                                        x.reshape(-1, cfg.d_model))
    assert float(aux_collapsed) > 2.0
    assert float(aux_uniform) < float(aux_collapsed)
