"""Config-registry invariants: the 10 assigned architectures carry exactly
the assigned hyper-parameters."""
import pytest

from repro.configs import ARCHS, ASSIGNED_ARCHS, get_config
from repro.configs.base import apply_long_context

EXPECTED = {
    # name: (layers, d_model, heads, kv, d_ff, vocab, family)
    "recurrentgemma-9b": (38, 4096, 16, 1, 12288, 256000, "hybrid"),
    "qwen3-4b": (36, 2560, 32, 8, 9728, 151936, "dense"),
    "smollm-135m": (30, 576, 9, 3, 1536, 49152, "dense"),
    "xlstm-125m": (12, 768, 4, 4, 0, 50304, "ssm"),
    "mixtral-8x22b": (56, 6144, 48, 8, 16384, 32768, "moe"),
    "starcoder2-7b": (32, 4608, 36, 4, 18432, 49152, "dense"),
    "deepseek-v3-671b": (61, 7168, 128, 128, 18432, 129280, "moe"),
    "musicgen-medium": (48, 1536, 24, 24, 6144, 2048, "audio"),
    "glm4-9b": (40, 4096, 32, 2, 13696, 151552, "dense"),
    "internvl2-2b": (24, 2048, 16, 8, 8192, 92553, "vlm"),
}


def test_all_assigned_registered():
    assert set(ASSIGNED_ARCHS) == set(EXPECTED)
    for a in ASSIGNED_ARCHS:
        assert a in ARCHS


@pytest.mark.parametrize("arch", sorted(EXPECTED))
def test_exact_assignment(arch):
    cfg = get_config(arch)
    L, d, h, kv, ff, v, fam = EXPECTED[arch]
    assert cfg.num_layers == L
    assert cfg.d_model == d
    assert cfg.num_heads == h
    assert cfg.num_kv_heads == kv
    assert cfg.d_ff == ff
    assert cfg.vocab_size == v
    assert cfg.family == fam
    assert cfg.padded_vocab % 256 == 0 and cfg.padded_vocab >= v
    # stage decomposition covers every layer exactly once
    assert sum(len(s.blocks) * s.repeat for s in cfg.stages) == L


def test_moe_details():
    mix = get_config("mixtral-8x22b").moe
    assert (mix.num_experts, mix.num_experts_per_tok) == (8, 2)
    dsv = get_config("deepseek-v3-671b")
    assert (dsv.moe.num_experts, dsv.moe.num_experts_per_tok) == (256, 8)
    assert dsv.moe.num_shared_experts == 1
    assert dsv.mla is not None and dsv.mla.kv_lora_rank == 512
    assert dsv.mtp_depth == 1


@pytest.mark.parametrize("arch", sorted(EXPECTED))
def test_reduced_bounds(arch):
    r = get_config(arch).reduced()
    assert r.d_model <= 512
    assert sum(s.repeat * len(s.blocks) for s in r.stages) <= 4
    if r.moe:
        assert r.moe.num_experts <= 4


@pytest.mark.parametrize("arch", sorted(EXPECTED))
def test_long_context_policy(arch):
    """Every arch must be runnable at long_500k: natively sub-quadratic or
    via the sliding-window override (DESIGN.md §5)."""
    cfg = get_config(arch)
    lc = apply_long_context(cfg)
    assert lc.sub_quadratic
    if not cfg.sub_quadratic:
        for s in lc.stages:
            for b in s.blocks:
                if b.mixer in ("attn", "mla"):
                    assert b.window is not None


def test_paper_app_config():
    vq = get_config("ace-video-query")
    assert vq.accept_threshold == 0.8 and vq.drop_threshold == 0.1
    assert vq.num_edge_clouds == 3 and vq.nodes_per_ec == 4
    assert (vq.uplink_mbps, vq.downlink_mbps) == (20.0, 40.0)
