"""Per-architecture smoke tests (deliverable f): a REDUCED variant of each
family runs one forward + one train step on CPU; output shapes + no NaNs."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.models.frontend import make_batch
from repro.models.model import LM

B, S = 2, 24


@pytest.fixture(scope="module")
def rng():
    return jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_forward_and_train_step(arch, rng):
    cfg = get_config(arch).reduced()
    lm = LM(cfg, kv_chunk=16)
    params, axes = lm.init(rng)
    # axes tree mirrors params exactly
    assert jax.tree.structure(params) == jax.tree.structure(
        axes, is_leaf=lambda x: isinstance(x, tuple))
    batch = make_batch(rng, cfg, B, S)

    logits, _, aux, _ = lm.forward(params, batch)
    if cfg.frontend.kind == "audio":
        assert logits.shape == (B, S, cfg.frontend.num_codebooks,
                                cfg.padded_vocab)
    elif cfg.frontend.kind == "vision":
        assert logits.shape == (B, S, cfg.padded_vocab)
    else:
        assert logits.shape == (B, S, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))

    (loss, metrics), grads = jax.value_and_grad(
        lambda p: lm.loss(p, batch, train=True), has_aux=True)(params)
    assert bool(jnp.isfinite(loss))
    assert all(bool(jnp.all(jnp.isfinite(g))) for g in jax.tree.leaves(grads))
    # at init, loss is near ln(vocab) for untied models (tied models start
    # higher: the residual stream correlates with the input embedding)
    assert float(loss) < 25.0


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_decode_step(arch, rng):
    cfg = get_config(arch).reduced()
    lm = LM(cfg, kv_chunk=16)
    params, _ = lm.init(rng)
    caches = lm.init_cache(B, 32)
    if cfg.frontend.kind == "audio":
        tok = jnp.zeros((B, 1, cfg.frontend.num_codebooks), jnp.int32)
    else:
        tok = jnp.zeros((B, 1), jnp.int32)
    logits, new_caches = lm.decode_step(params, caches, tok, jnp.int32(0))
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert jax.tree.structure(caches) == jax.tree.structure(new_caches)
    # decode must actually write state: some leaf changed
    changed = any(
        bool(jnp.any(a != b))
        for a, b in zip(jax.tree.leaves(caches), jax.tree.leaves(new_caches)))
    assert changed
