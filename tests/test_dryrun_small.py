"""Dry-run machinery on a small forced-device-count mesh (subprocess: the
512-device production sweep lives in results/dryrun; here we prove the
pipeline end-to-end with 8 fake devices so CI stays fast)."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as PS

    from repro import sharding as sh
    from repro.configs import get_config
    from repro.launch import sharding_rules as sr
    from repro.launch.dryrun import collective_bytes
    from repro.launch.specs import make_step_fn
    from repro.configs.shapes import InputShape
    from repro.models.model import LM

    mesh = jax.make_mesh((4, 2), ("data", "model"))
    cfg = get_config("smollm-135m").reduced()
    lm = LM(cfg, kv_chunk=16)
    shape = InputShape("t", seq_len=32, global_batch=8, mode="train")
    step, abstract_in, axes = make_step_fn(lm, shape)
    pspec = sr.param_pspecs(mesh, abstract_in[0], axes, "train")
    named = lambda t: jax.tree.map(
        lambda s: jax.sharding.NamedSharding(mesh, s), t,
        is_leaf=lambda x: isinstance(x, PS))
    with mesh:
        with sh.use_rules(mesh, sr.act_rules(mesh, "train")):
            jitted = jax.jit(step, in_shardings=(
                named(pspec),
                named(sr.opt_pspecs(mesh, pspec, abstract_in[1])),
                named(sr.batch_pspecs(mesh, abstract_in[2]))))
            lowered = jitted.lower(*abstract_in)
        compiled = lowered.compile()
    cost = compiled.cost_analysis()
    if isinstance(cost, list):          # older jax returns [dict]
        cost = cost[0]
    coll = collective_bytes(compiled.as_text())
    print(json.dumps({
        "flops": cost.get("flops"),
        "collective_bytes": sum(v["bytes"] for v in coll.values()),
        "mem": compiled.memory_analysis().temp_size_in_bytes,
    }))
""")


@pytest.mark.slow
def test_dryrun_pipeline_on_8_fake_devices():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=420)
    assert out.returncode == 0, out.stderr[-2000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    assert rec["flops"] > 0
    assert rec["collective_bytes"] > 0        # FSDP gathers + grad reduces
    assert rec["mem"] > 0


def test_collective_parser():
    from repro.launch.dryrun import collective_bytes
    hlo = """
      %ag = bf16[64,128]{1,0} all-gather(%x), replica_groups={{0,1}}
      %ar.1 = f32[32]{0} all-reduce(%y), to_apply=%add
      %nothing = f32[2]{0} add(%a, %b)
      %a2a = (f32[8,8]{1,0}) all-to-all(%z)
    """
    out = collective_bytes(hlo)
    assert out["all-gather"]["count"] == 1
    assert out["all-gather"]["bytes"] == 64 * 128 * 2
    assert out["all-reduce"]["bytes"] == 32 * 4
    assert out["all-to-all"]["count"] == 1


def test_production_dryrun_results_if_present():
    """When the 512-device sweep has been run, every (arch x shape x mesh)
    record must exist and carry positive flops."""
    d = os.path.join(ROOT, "results", "dryrun")
    if not os.path.isdir(d) or len(os.listdir(d)) < 80:
        pytest.skip("production dry-run sweep not complete")
    from repro.configs import ASSIGNED_ARCHS
    from repro.configs.shapes import INPUT_SHAPES
    for arch in ASSIGNED_ARCHS:
        for shape in INPUT_SHAPES:
            for mesh in ("pod16x16", "pod2x16x16"):
                path = os.path.join(d, f"{arch}__{shape}__{mesh}.json")
                assert os.path.exists(path), path
                with open(path) as f:
                    rec = json.load(f)
                assert rec["cost"].get("flops", 0) > 0, path
