"""KV-cache backend API: the block allocator, HBM accounting, layout
equivalence at the layer level, and the engine-level exactness contract —
paged greedy generations match the ring token-for-token."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import (MLA, SWIGLU, BlockDef, MLAConfig, ModelConfig,
                                Stage, dense_stages)
from repro.models.model import LM
from repro.serving import PagedCache, RingCache, ServingEngine
from repro.serving.kv_cache import RING, PagedLayout


def _tiny_cfg(layers=2):
    return ModelConfig(
        name="tiny", family="dense", source="t", num_layers=layers,
        d_model=32, num_heads=4, num_kv_heads=2, head_dim=8, d_ff=64,
        vocab_size=64, stages=dense_stages(layers), param_dtype="float32")


def _mla_cfg():
    return ModelConfig(
        name="tiny-mla", family="mla", source="t", num_layers=2,
        d_model=32, num_heads=4, num_kv_heads=4, head_dim=8, d_ff=64,
        vocab_size=64,
        stages=(Stage(blocks=(BlockDef(mixer=MLA, mlp=SWIGLU),), repeat=2),),
        param_dtype="float32",
        mla=MLAConfig(q_lora_rank=16, kv_lora_rank=8, qk_nope_head_dim=8,
                      qk_rope_head_dim=4, v_head_dim=8))


def _lm(cfg):
    lm = LM(cfg, kv_chunk=8)
    params, _ = lm.init(jax.random.PRNGKey(0))
    return lm, params


def _mixed_trace(n=7, seed=1):
    rng = np.random.default_rng(seed)
    return [(rng.integers(0, 60, size=int(rng.integers(3, 12))),
             int(rng.integers(3, 9))) for _ in range(n)]


# ---------------------------------------------------------------------------
# Allocator
# ---------------------------------------------------------------------------

def test_allocator_reserves_and_frees():
    lm, params = _lm(_tiny_cfg())
    be = PagedCache(lm, params, batch_slots=4, max_seq_len=32, block_size=8,
                    num_blocks=9)                     # 8 usable, 0 = trash
    assert be.blocks_needed(5, 3) == 1
    assert be.blocks_needed(5, 4) == 2                # 9 tokens, bs=8
    assert be.can_admit(20, 8)                        # 28 tokens -> 4 blocks
    row = be.alloc_slot(0, 20, 8)
    assert row.shape == (be.blocks_per_slot,)
    # lazy draw: only the 3 prompt blocks are physical; the 4th (decode
    # budget) is committed in the ledger and drawn by look-ahead
    assert (row[:3] > 0).all() and (row[3:] == -1).all()
    assert 0 not in row[:3]                           # trash never allocated
    assert be.blocks_in_use == 3
    # admission is still gated by the worst case: a second big request no
    # longer fits (committed, not just drawn, blocks count); a small one does
    assert not be.can_admit(25, 8)
    assert be.can_admit(5, 3)
    # look-ahead tops the table up to cover pos + K and draws the committed
    # block; a covered ask is a no-op
    row2, covered = be.reserve_lookahead(0, 20 + 8)
    assert covered == 3 and (row2[:4] > 0).all() and (row2[4:] == -1).all()
    assert be.blocks_in_use == 4 and be._slot_gap[0] == 0
    assert be.reserve_lookahead(0, 20 + 8) == (None, 0)
    be.assert_invariants()
    state = be.init()
    state = be.free_slot(state, 0)
    assert be.blocks_in_use == 0
    assert be.can_admit(25, 7)
    # freeing an empty slot is a no-op
    assert be.free_slot(state, 0) is state
    be.assert_invariants()


def test_allocator_exhaustion_raises():
    lm, params = _lm(_tiny_cfg())
    be = PagedCache(lm, params, batch_slots=2, max_seq_len=32, block_size=8,
                    num_blocks=3)
    with pytest.raises(RuntimeError, match="exhausted"):
        be.alloc_slot(0, 20, 8)


def test_free_slot_clears_table_row():
    lm, params = _lm(_tiny_cfg())
    be = PagedCache(lm, params, batch_slots=2, max_seq_len=32, block_size=8)
    state = be.init()
    row = be.alloc_slot(1, 10, 4)
    state = {"caches": state["caches"],
             "tables": state["tables"].at[1].set(jnp.asarray(row))}
    state = be.free_slot(state, 1)
    assert bool(jnp.all(state["tables"][1] == -1))


def test_swap_out_in_round_trips_kv_bytes():
    """Backend-level swap checkpoint: swap_out releases the slot's blocks
    through the ordinary ledger/free accounting, a hostile tenant may
    overwrite the physical blocks in between, and swap_in restores the
    K/V byte-for-byte into freshly drawn blocks."""

    def flat(tree):
        return jax.tree.leaves(tree)

    lm, params = _lm(_tiny_cfg())
    be = PagedCache(lm, params, batch_slots=2, max_seq_len=32, block_size=8,
                    num_blocks=7, prefix_sharing=False)
    state = be.init()
    row = be.alloc_slot(0, 16, 8)               # 2 prompt blocks, cap 3
    state = {"caches": state["caches"],
             "tables": state["tables"].at[0].set(jnp.asarray(row))}
    # stamp recognizable content into the slot's blocks
    blocks = list(be._slot_blocks[0])
    marked = jax.tree.map(
        lambda leaf: leaf.at[:, jnp.asarray(blocks)].set(7), state["caches"])
    state = {"caches": marked, "tables": state["tables"]}
    want = [np.array(x[:, blocks]) for x in flat(state["caches"])]

    host, state = be.swap_out(state, 0)
    assert be.swap_outs == 1 and be.blocks_in_use == 0
    assert be._gap_total == 0                   # commitment fully released
    be.assert_invariants()
    # another tenant scribbles over the pool (including the old blocks)
    row1 = be.alloc_slot(1, 30, 2)
    state = {"caches": jax.tree.map(lambda leaf: leaf * 0 - 3,
                                    state["caches"]),
             "tables": state["tables"].at[1].set(jnp.asarray(row1))}
    state = be.free_slot(state, 1)

    assert be.can_resume(16, 8)
    state = be.swap_in(state, 0, host, 16, 8)
    be.assert_invariants()
    assert be.swap_ins == 1
    new_blocks = be._slot_blocks[0]
    assert len(new_blocks) == len(blocks)       # drawn now: the checkpoint
    assert be._slot_gap[0] == 1                 # budget tail re-committed
    got = [np.array(x[:, new_blocks]) for x in flat(state["caches"])]
    for w, g in zip(want, got):
        np.testing.assert_array_equal(w, g)
    # table row points at the restored blocks, tail unallocated
    tab = np.array(state["tables"][0])
    assert list(tab[:len(new_blocks)]) == new_blocks
    assert (tab[len(new_blocks):] == -1).all()
    state = be.free_slot(state, 0)
    assert be.blocks_in_use == 0 and be._gap_total == 0
    be.assert_invariants()


def test_swap_in_refuses_when_pool_spoken_for():
    lm, params = _lm(_tiny_cfg())
    be = PagedCache(lm, params, batch_slots=2, max_seq_len=32, block_size=8,
                    num_blocks=5, prefix_sharing=False)
    state = be.init()
    row = be.alloc_slot(0, 16, 8)
    state = {"caches": state["caches"],
             "tables": state["tables"].at[0].set(jnp.asarray(row))}
    host, state = be.swap_out(state, 0)
    be.alloc_slot(1, 10, 8)                     # commits 3 of 4 blocks
    assert not be.can_resume(16, 8)             # resume needs 3 > 1 left
    with pytest.raises(RuntimeError, match="resume"):
        be.swap_in(state, 0, host, 16, 8)


def test_hbm_accounting():
    lm, params = _lm(_tiny_cfg())
    ring = RingCache(lm, params, batch_slots=4, max_seq_len=32)
    # k + v + pos, per slot: 2 layers x (2x2x8 + 2x2x8 + 2) x 32 pos x 4 B
    assert ring.hbm_bytes_per_slot() == ring.hbm_bytes() / 4
    assert ring.hbm_bytes() > 0

    paged = PagedCache(lm, params, batch_slots=4, max_seq_len=32,
                       block_size=8)
    # ring-equivalent default pool: slots x blocks_per_slot + trash block
    assert paged.num_blocks == 4 * 4 + 1
    # a full table's worth of blocks costs exactly one ring cache line
    assert (paged.block_bytes() * paged.blocks_per_slot
            == ring.hbm_bytes_per_slot())
    assert paged.hbm_bytes() == paged.block_bytes() * paged.num_blocks
    paged.alloc_slot(0, 5, 3)                         # 1 block drawn
    paged.alloc_slot(1, 20, 8)                        # 3 prompt blocks drawn
    # the average counts blocks actually *drawn* (lazy allocation): the
    # second request's 4th block is committed but not yet physical
    assert paged.hbm_bytes_per_slot() == paged.block_bytes() * 2.0
    paged.reserve_lookahead(1, 28)                    # draw the 4th
    assert paged.hbm_bytes_per_slot() == paged.block_bytes() * 2.5


def test_prefix_sharing_refcounts_and_index():
    """Full-block prefix sharing at the allocator level: registration,
    matched shares incrementing refcounts, and refcount-0 *retention* —
    freed prefix blocks keep their index entries and park at the LRU tail
    of the free list for cross-run revival."""
    lm, params = _lm(_tiny_cfg())
    be = PagedCache(lm, params, batch_slots=4, max_seq_len=64, block_size=8)
    state = be.init()
    prompt = np.arange(20, dtype=np.int32)             # 2 full blocks + 4
    row0 = be.alloc_slot(0, prompt, 8)
    assert be.shared_prefill_start(0) == 0             # nothing published yet
    be.register_prefix(0, prompt)
    assert len(be._index) == 2                         # tokens[:8], [:16]

    # same 16-token prefix, different tail: shares 2 blocks, fresh rest
    other = np.concatenate([prompt[:16], np.arange(100, 107,
                                                   dtype=np.int32)])
    free_before = len(be._free)
    row1 = be.alloc_slot(1, other.astype(np.int32), 8)
    assert list(row1[:2]) == list(row0[:2])            # physical sharing
    assert be.shared_prefill_start(1) == 16
    assert be.shared_block_count(1) == 2
    assert be._ref[int(row0[0])] == 2
    assert be.take_pending_copies() == []              # tail diverges: no COW
    # only the non-shared prompt blocks were newly drawn (lazy allocation:
    # 23 prompt tokens = 3 entries, 2 of them shared)
    assert free_before - len(be._free) == 1

    # owner leaves first: shared blocks stay live for slot 1
    state = be.free_slot(state, 0)
    assert be._ref[int(row0[0])] == 1
    assert len(be._index) == 2
    state = be.free_slot(state, 1)
    # cross-run retention: refcounts drop to zero and every block returns
    # to the free list, but indexed prefix blocks keep their entries (LRU
    # tail) so a later matching admission can revive them
    assert be._ref == {}
    assert len(be._index) == 2 and len(be._block_key) == 2
    assert sorted(be._free) == list(range(1, be.num_blocks))
    assert set(be._free_cached) == set(be._block_key)
    be.assert_invariants()

    # revival: a matching admission shares the retained blocks without
    # recomputing them; a non-matching one eventually evicts (plain blocks
    # are reclaimed first, cached blocks LRU-last)
    row2 = be.alloc_slot(2, prompt, 8)
    assert list(row2[:2]) == list(row0[:2])
    assert be.shared_prefill_start(2) == 16
    assert be.retained_block_hits == 2
    be.assert_invariants()


def test_block_aligned_full_cover_schedules_cow():
    """A prompt entirely covered by shared blocks must still recompute its
    final token; the allocator hands the slot a private copy of the last
    shared block (copy-on-write) instead of letting it write shared state."""
    lm, params = _lm(_tiny_cfg())
    be = PagedCache(lm, params, batch_slots=2, max_seq_len=64, block_size=8)
    prompt = np.arange(16, dtype=np.int32)             # exactly 2 blocks
    row0 = be.alloc_slot(0, prompt, 8)
    be.register_prefix(0, prompt)
    row1 = be.alloc_slot(1, prompt.copy(), 4)
    assert be.shared_prefill_start(1) == 15            # recompute last token
    assert row1[0] == row0[0]                          # block 0 shared
    assert row1[1] != row0[1]                          # block 1 went private
    copies = be.take_pending_copies()
    assert copies == [(int(row0[1]), int(row1[1]))]
    assert be.cow_copies == 1
    assert be._ref[int(row0[1])] == 1                  # share was undone


def test_paged_accounting_invariant_after_run():
    """After any ``run()`` — chunked, shared, starved, multi-step — every
    non-reserved block is back in the free list, refcounts, commitments
    and slot maps are empty, and retention keeps exactly the registered
    prefix blocks indexed at the free list's LRU tail (the lazy-reclaim
    path): the structural ``assert_invariants`` plus the drained-state
    specifics."""
    lm, params = _lm(_tiny_cfg())
    rng = np.random.default_rng(11)
    template = rng.integers(0, 60, size=8).astype(np.int32)
    trace = [(np.concatenate([template,
                              rng.integers(0, 60, size=int(rng.integers(
                                  1, 10))).astype(np.int32)]),
              int(rng.integers(2, 7))) for _ in range(6)]
    for kw in ({}, {"chunk_tokens": 4}, {"chunk_tokens": 4,
                                         "num_pool_blocks": 13},
               {"chunk_tokens": 4, "max_decode_steps": 8}):
        eng = ServingEngine(lm, params, batch_slots=3, max_seq_len=32,
                            min_bucket=4, cache_backend="paged",
                            block_size=8, **kw)
        for prompt, max_new in trace:
            eng.submit(prompt, max_new_tokens=max_new)
        eng.run()
        be = eng.backend
        be.assert_invariants()
        assert be.blocks_in_use == 0, kw
        assert be._slot_blocks == {}, kw
        assert be._ref == {}, kw
        assert be._slot_gap == {} and be._gap_total == 0, kw
        # every block is reclaimable and the retained ones are exactly the
        # indexed prefix blocks, parked in the cached tier
        assert sorted(be._free) == list(range(1, be.num_blocks)), kw
        assert set(be._free_cached) == set(be._block_key), kw
        assert set(be._index.values()) == set(be._block_key), kw
        assert be.take_pending_copies() == [], kw
        # retention is an upper bound too: sharing off -> nothing cached
        if not be.prefix_sharing:
            assert be._index == {}, kw


def test_eviction_never_steals_blocks_being_revived():
    """Regression: an admission that both *revives* retained shared blocks
    and must *evict* cached blocks for its fresh draw must not evict the
    very blocks it is reviving — that would hand the same physical block
    out twice in one table row."""
    lm, params = _lm(_tiny_cfg())
    be = PagedCache(lm, params, batch_slots=2, max_seq_len=32, block_size=8,
                    num_blocks=5)                      # 4 usable
    state = be.init()
    other = np.arange(100, 108, dtype=np.int32)        # 1 block
    tmpl = np.arange(16, dtype=np.int32)               # 2 blocks
    be.alloc_slot(0, other, 0)
    be.register_prefix(0, other)
    be.alloc_slot(1, tmpl, 0)
    be.register_prefix(1, tmpl)
    # free the template *first* so its blocks are LRU-oldest in the cached
    # tier — exactly the ones naive eviction would reclaim first
    state = be.free_slot(state, 1)
    state = be.free_slot(state, 0)
    assert len(be._free_cached) == 3 and len(be._free_plain) == 1
    # 32-token prompt: shares (revives) the 2 template blocks, needs 2
    # fresh — 1 plain + 1 evicted. The eviction must take ``other``'s
    # block, not a template block being revived.
    row = be.alloc_slot(0, np.concatenate([tmpl, np.arange(50, 66,
                                                           dtype=np.int32)]),
                        0)
    assert len(set(row[:4].tolist())) == 4             # no duplicate blocks
    assert be.shared_prefill_start(0) == 16
    assert other.tobytes() not in be._index            # the evicted entry
    be.assert_invariants()


def test_paged_retention_disabled_reclaims_index():
    """``retain_prefix_blocks=False`` restores the old reclaim-at-zero
    behavior: freed blocks drop their index entries immediately."""
    lm, params = _lm(_tiny_cfg())
    be = PagedCache(lm, params, batch_slots=2, max_seq_len=32, block_size=8,
                    retain_prefix_blocks=False)
    state = be.init()
    prompt = np.arange(16, dtype=np.int32)
    be.alloc_slot(0, prompt, 4)
    be.register_prefix(0, prompt)
    assert len(be._index) == 2
    be.free_slot(state, 0)
    assert be._index == {} and be._block_key == {} and not be._free_cached
    be.assert_invariants()


def test_paged_rejects_recurrent_mixers():
    from repro.configs import get_config
    cfg = get_config("recurrentgemma-9b")
    lm = LM(cfg)
    with pytest.raises(NotImplementedError, match="attention mixers"):
        PagedCache(lm, params=None, batch_slots=2, max_seq_len=32)


# ---------------------------------------------------------------------------
# Layout-level equivalence: paged append/attend == ring append/attend
# ---------------------------------------------------------------------------

def test_paged_layout_append_then_attend_matches_ring():
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    b, w, kv, hd, h, bs = 2, 32, 2, 16, 4, 8
    m = w // bs
    n = b * m + 1
    ring_cache = {"k": jnp.zeros((b, w, kv, hd)),
                  "v": jnp.zeros((b, w, kv, hd)),
                  "pos": jnp.full((b, w), -1, jnp.int32)}
    paged_cache = {"k": jnp.zeros((n, bs, kv, hd)),
                   "v": jnp.zeros((n, bs, kv, hd)),
                   "pos": jnp.full((n, bs), -1, jnp.int32)}
    tables = jnp.asarray(
        np.arange(1, n).reshape(b, m), jnp.int32)     # slot-major blocks
    paged = PagedLayout(bs)
    steps = 20
    kseq = jax.random.normal(ks[0], (b, steps, kv, hd))
    vseq = jax.random.normal(ks[1], (b, steps, kv, hd))
    for t in range(steps):
        cur = jnp.full((b,), t, jnp.int32)
        upd = {"k": kseq[:, t:t + 1], "v": vseq[:, t:t + 1]}
        ring_cache = RING.append(ring_cache, upd, cur)
        paged_cache = paged.append(paged_cache, upd, cur, tables)
    q = jax.random.normal(ks[2], (b, 1, h, hd))
    q_pos = jnp.full((b,), steps - 1, jnp.int32)
    a = RING.attend(q, ring_cache, q_pos, window=None, scale=hd ** -0.5,
                    use_kernel=False)
    p = paged.attend(q, paged_cache, q_pos, tables, window=None,
                     scale=hd ** -0.5, use_kernel=False)
    assert float(jnp.max(jnp.abs(a - p))) < 1e-5
    # the gathered context view equals the ring arrays token-for-token
    ctx = paged.context(paged_cache, tables)
    for key in ("k", "v"):
        np.testing.assert_allclose(np.asarray(ctx[key][:, :steps]),
                                   np.asarray(ring_cache[key][:, :steps]))


# ---------------------------------------------------------------------------
# Engine-level exactness: the acceptance contract
# ---------------------------------------------------------------------------

def _run_engine(lm, params, trace, **kw):
    eng = ServingEngine(lm, params, **kw)
    for prompt, max_new in trace:
        eng.submit(prompt, max_new_tokens=max_new)
    return eng, {rid: r.output for rid, r in eng.run().items()}


@pytest.mark.slow
def test_paged_engine_matches_ring_token_for_token():
    """The acceptance contract: greedy generations over the mixed-length
    trace are identical between backends, including when the paged pool is
    small enough to force block-limited admission and block reuse."""
    lm, params = _lm(_tiny_cfg())
    trace = _mixed_trace(n=9, seed=3)
    _, ring = _run_engine(lm, params, trace, batch_slots=3, max_seq_len=32,
                          min_bucket=4)
    # ample pool
    _, paged = _run_engine(lm, params, trace, batch_slots=3, max_seq_len=32,
                           min_bucket=4, cache_backend="paged", block_size=8)
    # starved pool: 8 usable blocks of 8 tokens, forces reuse + queueing
    eng, paged_small = _run_engine(
        lm, params, trace, batch_slots=3, max_seq_len=32, min_bucket=4,
        cache_backend="paged", block_size=8, num_pool_blocks=9)
    assert set(ring) == set(paged) == set(paged_small)
    for rid in ring:
        np.testing.assert_array_equal(ring[rid], paged[rid])
        np.testing.assert_array_equal(ring[rid], paged_small[rid])
    be = eng.backend
    assert be.blocks_in_use == 0                      # everything returned
    assert be.peak_blocks_in_use <= be.num_blocks - 1
    assert be.admitted == len(trace)


@pytest.mark.slow
def test_paged_engine_matches_ring_mla():
    lm, params = _lm(_mla_cfg())
    trace = _mixed_trace(n=5, seed=4)
    _, ring = _run_engine(lm, params, trace, batch_slots=2, max_seq_len=32,
                          min_bucket=4)
    _, paged = _run_engine(lm, params, trace, batch_slots=2, max_seq_len=32,
                           min_bucket=4, cache_backend="paged", block_size=8)
    for rid in ring:
        np.testing.assert_array_equal(ring[rid], paged[rid])


def test_pool_too_small_for_single_request_rejects():
    # a request whose worst case exceeds the whole pool is terminally
    # rejected at admission (machine-readable reason) instead of raising
    # and taking every other request down with it
    lm, params = _lm(_tiny_cfg())
    eng = ServingEngine(lm, params, batch_slots=2, max_seq_len=32,
                        min_bucket=4, cache_backend="paged", block_size=8,
                        num_pool_blocks=3)
    rid = eng.submit(np.arange(20, dtype=np.int32), max_new_tokens=8)
    done = eng.run()
    assert done[rid].status == "rejected"
    assert done[rid].failure_reason.startswith("exceeds_pool_capacity")
    assert len(done[rid].output) == 0


def test_unknown_backend_rejected():
    lm, params = _lm(_tiny_cfg())
    with pytest.raises(ValueError, match="unknown cache backend"):
        ServingEngine(lm, params, batch_slots=2, max_seq_len=32,
                      cache_backend="flat")
