"""The paper's Fig. 5 claims, asserted as trend tests on the DES."""
import pytest

from repro.configs.ace_video_query import config
from repro.core.video_query import run_video_query, surrogate_crop_bank


@pytest.fixture(scope="module")
def results():
    cfg = config()
    out = {}
    for iv in (0.5, 0.1):
        for p in ("ci", "ei", "ace", "ace+"):
            out[(p, iv)] = run_video_query(
                cfg, paradigm=p, frame_interval_s=iv, wan_delay_ms=50.0,
                duration_s=20.0)
    return out


def test_f1_ordering(results):
    """Paper: CI highest, EI lowest, ACE/ACE+ in between, at every load."""
    for iv in (0.5, 0.1):
        ci, ei = results[("ci", iv)]["f1"], results[("ei", iv)]["f1"]
        ace, acep = results[("ace", iv)]["f1"], results[("ace+", iv)]["f1"]
        assert ci > ace > ei
        assert ci > acep > ei


def test_bandwidth_ordering(results):
    """Paper: ACE/ACE+ << CI; EI ~ 0; BWC grows with load except EI."""
    for iv in (0.5, 0.1):
        ci = results[("ci", iv)]["bwc_mb"]
        ace = results[("ace", iv)]["bwc_mb"]
        ei = results[("ei", iv)]["bwc_mb"]
        assert ace < 0.5 * ci
        assert ei < 0.1 * ace
    assert results[("ci", 0.1)]["bwc_mb"] > results[("ci", 0.5)]["bwc_mb"]


def test_ace_plus_tradeoff_at_high_load(results):
    """Paper: under high load AP load-balances — more BWC, less EIL."""
    ace, acep = results[("ace", 0.1)], results[("ace+", 0.1)]
    assert acep["bwc_mb"] > ace["bwc_mb"]
    assert acep["eil_s"] < ace["eil_s"]


def test_ci_eil_blows_up_with_load(results):
    """Paper: CI's EIL explodes under load (cloud queue backlog); the
    collaborative paradigms stay bounded."""
    assert results[("ci", 0.1)]["eil_s"] > 10 * results[("ci", 0.5)]["eil_s"]
    assert results[("ace", 0.1)]["eil_s"] < 2.0
    assert results[("ei", 0.1)]["eil_s"] < 2.0


def test_crop_bank_calibration():
    """Surrogate bank matches the paper's reported model qualities."""
    bank = surrogate_crop_bank(20_000, seed=0)
    import numpy as np
    conf = np.array([c.eoc_conf for c in bank])
    correct = np.array([(c.eoc_pred == 1) == c.positive_gt for c in bank])
    # high-confidence error rate ~ the paper's 11.06% +- a few points
    hi = conf >= 0.8
    err = 1 - correct[hi].mean()
    assert 0.03 < err < 0.2
    # escalation band is a meaningful fraction, not degenerate
    esc = ((conf >= 0.1) & (conf < 0.8)).mean()
    assert 0.1 < esc < 0.6


def test_engine_calibrated_servers():
    """The ACE application runs on the serving layer: EOC/COC service rates
    come from a measured continuous-batching engine."""
    import jax
    import numpy as np
    from repro.configs.base import ModelConfig, dense_stages
    from repro.core.video_query import calibrate_server_from_engine
    from repro.models.model import LM
    from repro.serving import ServingEngine

    cfg = ModelConfig(
        name="tiny", family="dense", source="t", num_layers=2, d_model=32,
        num_heads=2, num_kv_heads=2, head_dim=16, d_ff=64, vocab_size=64,
        stages=dense_stages(2), param_dtype="float32")
    lm = LM(cfg, kv_chunk=8)
    params, _ = lm.init(jax.random.PRNGKey(0))
    eng = ServingEngine(lm, params, batch_slots=2, max_seq_len=32,
                        min_bucket=16)
    cal = calibrate_server_from_engine(eng, n_queries=3, prompt_len=8,
                                       max_new=2)
    assert cal["service_s"] > 0 and cal["tokens_s"] > 0
    assert cal["workers"] == 2

    vq = config()
    out = run_video_query(vq, paradigm="ace", frame_interval_s=0.5,
                          wan_delay_ms=50.0, duration_s=5.0, coc_engine=eng)
    assert out["crops"] > 0 and 0.0 <= out["f1"] <= 1.0
