"""Training substrate: loss decreases, checkpoint restore, optimizers,
federated trainer convergence, ECC patterns."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import latest_step, load_checkpoint, save_checkpoint
from repro.configs import get_config
from repro.data.synthetic import TokenStream, synth_crops
from repro.models.model import LM
from repro.optim import adamw_init, adamw_update, linear_warmup_cosine
from repro.training import Trainer


def test_trainer_loss_decreases(tmp_path):
    cfg = get_config("smollm-135m").reduced()
    lm = LM(cfg, kv_chunk=16)
    tr = Trainer(lm, linear_warmup_cosine(3e-3, 2, 40),
                 ckpt_dir=str(tmp_path), log_every=5, ckpt_every=10)
    p, o = tr.init_state(jax.random.PRNGKey(0))
    stream = TokenStream(cfg.vocab_size, seed=0)
    p, o = tr.fit(p, o, stream.batches(4, 32), 12, echo=False)
    first = tr.history[0]["loss"]
    last = tr.history[-1]["loss"]
    assert last < first - 1.0
    # checkpoints were written and restore cleanly
    assert latest_step(str(tmp_path)) == 10
    (p2, o2), step = load_checkpoint(str(tmp_path), (p, o))
    assert step == 10
    assert all(np.allclose(np.asarray(a), np.asarray(b)) for a, b in
               zip(jax.tree.leaves(o2.step), jax.tree.leaves(o2.step)))


def test_adamw_reduces_quadratic():
    target = jnp.array([1.0, -2.0, 3.0])
    params = {"w": jnp.zeros(3)}
    opt = adamw_init(params)
    loss = lambda p: jnp.sum((p["w"] - target) ** 2)
    for _ in range(200):
        g = jax.grad(loss)(params)
        params, opt = adamw_update(params, g, opt, lr=0.05)
    assert float(loss(params)) < 1e-2


def test_adamw_bf16_states():
    params = {"w": jnp.zeros(4, jnp.bfloat16)}
    opt = adamw_init(params, jnp.bfloat16)
    assert opt.mu["w"].dtype == jnp.bfloat16
    g = {"w": jnp.ones(4, jnp.bfloat16)}
    params, opt = adamw_update(params, g, opt, lr=0.1)
    assert bool(jnp.all(jnp.isfinite(params["w"].astype(jnp.float32))))


def test_checkpoint_gc_and_mismatch(tmp_path):
    tree = {"a": np.arange(3), "b": {"c": np.ones(2)}}
    for s in (1, 2, 3, 4, 5):
        save_checkpoint(str(tmp_path), s, tree, keep=2)
    assert latest_step(str(tmp_path)) == 5
    assert not os.path.exists(os.path.join(str(tmp_path), "step_1.npz"))
    with pytest.raises(ValueError):
        load_checkpoint(str(tmp_path), {"different": np.zeros(1)})


def test_token_stream_is_learnable():
    """The synthetic stream has sub-maximal entropy (a model can learn it)."""
    ts = TokenStream(64, seed=0)
    tokens = ts.sample(8, 256, seed=1)
    # empirical bigram predictability: repeated contexts share successors
    from collections import Counter, defaultdict
    succ = defaultdict(Counter)
    for row in tokens:
        for a, b in zip(row[:-1], row[1:]):
            succ[int(a)][int(b)] += 1
    top1 = sum(c.most_common(1)[0][1] for c in succ.values())
    total = sum(sum(c.values()) for c in succ.values())
    assert top1 / total > 2.0 / 64     # far above uniform chance


def test_fedavg_math():
    from repro.core.patterns.training import fedavg
    a = {"w": jnp.array([0.0, 2.0])}
    b = {"w": jnp.array([4.0, 0.0])}
    avg = fedavg([a, b], weights=[1.0, 3.0])
    assert np.allclose(np.asarray(avg["w"]), [3.0, 0.5])


def test_federated_trainer_converges():
    """FedAvg over the data axis of a host mesh reduces a toy loss on
    non-IID shards."""
    from repro.launch.mesh import make_host_mesh
    from repro.training.federated import FederatedTrainer

    mesh = make_host_mesh()
    n_ec = mesh.shape["data"]
    rng = np.random.default_rng(0)
    # each EC sees a different slice of a shared linear problem
    w_true = rng.normal(size=(4,)).astype(np.float32)
    xs = rng.normal(size=(n_ec, 64, 4)).astype(np.float32)
    ys = xs @ w_true

    def loss_fn(params, batch):
        x, y = batch
        pred = x @ params["w"]
        return jnp.mean((pred - y) ** 2)

    ft = FederatedTrainer(loss_fn, mesh, lr=0.1, local_steps=4)
    params = ft.replicate({"w": jnp.zeros(4)})
    opt = ft.init_opt(params)
    batch = (jnp.asarray(xs)[:, None].squeeze(1), jnp.asarray(ys))
    batch = (jnp.asarray(xs), jnp.asarray(ys))
    losses = []
    for _ in range(20):
        params, opt, loss = ft.round(params, opt, batch)
        losses.append(float(loss[0]))
    assert losses[-1] < 0.05 * losses[0]
    final = ft.unreplicate(params)
    assert np.allclose(np.asarray(final["w"]), w_true, atol=0.15)


def test_ecc_processing_pipeline():
    """ECC processing pattern: an edge->cloud pipeline over bridged topics."""
    from repro.core.patterns.processing import pipeline_topology
    from repro.core.platform import AcePlatform

    ace = AcePlatform()
    ace.register_user("u")
    infra = ace.register_infrastructure("u", num_ecs=1, nodes_per_ec=2)
    ace.deploy_services(infra)
    stages = [
        {"name": "filter", "placement": "edge",
         "fn": lambda x: x if x % 2 == 0 else None},
        {"name": "square", "placement": "edge", "fn": lambda x: x * x},
        {"name": "store", "placement": "cloud", "fn": lambda x: x},
    ]
    topo = pipeline_topology("pipe", stages)
    ace.submit_app("u", infra, topo)
    ace.deploy_app("u", "pipe")
    # feed items at the edge broker
    ec = infra.ecs[0]
    broker = ace.message_service(infra).broker(ec)
    for i in range(6):
        broker.publish("pipe/in", i, src="feeder")
    store = ace.instances(infra, "store")[0][1]
    assert sorted(store.outputs) == [0, 4, 16]


def test_hybrid_pattern_teacher_student():
    from repro.core.platform import AcePlatform
    from repro.core.topology import Component, Topology

    ace = AcePlatform()
    ace.register_user("u")
    infra = ace.register_infrastructure("u", num_ecs=1, nodes_per_ec=2)
    ace.deploy_services(infra)
    teacher_infer = lambda item: item * 10
    train_student = lambda params, buf: {"bias": 1}
    student_infer = lambda params, item: (item * 10, 0.9 if item < 5 else 0.1)
    topo = Topology(app="hy", version=1, components={
        "teacher": Component(name="teacher", image="repro/pattern/teacher",
                             placement="cloud", params={"init": {
                                 "teacher_infer": teacher_infer,
                                 "train_student": train_student,
                                 "student_params": {"bias": 0},
                                 "refresh_every": 2}}),
        "student": Component(name="student", image="repro/pattern/student",
                             placement="edge", params={"init": {
                                 "student_infer": student_infer}}),
    })
    ace.submit_app("u", infra, topo)
    ace.deploy_app("u", "hy")
    ec_broker = ace.message_service(infra).broker(infra.ecs[0])
    for i in range(8):
        ec_broker.publish("hybrid/in", i, src="feeder")
    student = ace.instances(infra, "student")[0][1]
    teacher = ace.instances(infra, "teacher")[0][1]
    assert len(student.results) > 0          # confident items kept at edge
    assert student.escalated > 0             # hard items escalated
    assert teacher.version >= 1              # online student refresh happened
