import os
import sys
import types

# src-layout import path (tests run as PYTHONPATH=src pytest tests/)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import pytest

jax.config.update("jax_enable_x64", False)

# Optional-import shim: hypothesis only drives the property tests. When it's
# absent, install a stub so the modules still collect — @given tests become
# skips instead of collection errors for the whole module.
try:
    import hypothesis  # noqa: F401
except ImportError:
    def _given(*_a, **_k):
        def deco(fn):
            return pytest.mark.skip(
                reason="hypothesis not installed")(fn)
        return deco

    def _settings(*_a, **_k):
        return lambda fn: fn

    class _Strategies(types.ModuleType):
        def __getattr__(self, name):            # st.integers(...), etc.
            return lambda *a, **k: None

    _hyp = types.ModuleType("hypothesis")
    _hyp.given = _given
    _hyp.settings = _settings
    _hyp.strategies = _Strategies("hypothesis.strategies")
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _hyp.strategies
