"""Direct unit tests for ``serving/sampler.py::sample_logits_batch``: the
fused decode step samples every slot in one call with per-row temperature,
so greedy rows must be exact argmax, stochastic rows must respect top-k
masking, and the whole thing must stay jit-traceable with mixed rows."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.serving.sampler import sample_logits, sample_logits_batch


def _logits(seed=0, b=8, v=64):
    return jax.random.normal(jax.random.PRNGKey(seed), (b, v)) * 3.0


def test_temperature_zero_rows_match_argmax_exactly():
    logits = _logits()
    temp = jnp.zeros((8,), jnp.float32)
    for seed in range(3):                  # greedy must ignore the rng
        out = sample_logits_batch(jax.random.PRNGKey(seed), logits, temp)
        np.testing.assert_array_equal(
            np.asarray(out), np.asarray(jnp.argmax(logits, axis=-1)))
    assert out.dtype == jnp.int32


def test_mixed_rows_greedy_unaffected_by_stochastic_neighbors():
    """Per-row temperature: greedy rows must stay argmax even when other
    rows in the same call sample stochastically."""
    logits = _logits(1)
    temp = jnp.asarray([0.0, 1.0, 0.0, 2.0, 0.0, 0.5, 0.0, 1.5], jnp.float32)
    out = np.asarray(sample_logits_batch(jax.random.PRNGKey(7), logits, temp))
    greedy = np.asarray(jnp.argmax(logits, axis=-1))
    for row in (0, 2, 4, 6):
        assert out[row] == greedy[row]


def test_stochastic_rows_respect_top_k():
    logits = _logits(2, b=4, v=32)
    temp = jnp.full((4,), 1.5, jnp.float32)
    k = 5
    allowed = np.asarray(jax.lax.top_k(logits, k)[1])
    for seed in range(20):
        out = np.asarray(sample_logits_batch(jax.random.PRNGKey(seed),
                                             logits, temp, top_k=k))
        for row in range(4):
            assert out[row] in allowed[row], (seed, row)


def test_stochastic_rows_cover_more_than_argmax():
    """High temperature must actually sample (not collapse to greedy)."""
    logits = _logits(3, b=2, v=16)
    temp = jnp.full((2,), 5.0, jnp.float32)
    seen = {int(sample_logits_batch(jax.random.PRNGKey(s), logits, temp)[0])
            for s in range(64)}
    assert len(seen) > 1


def test_jit_traceable_with_mixed_rows():
    fn = jax.jit(lambda r, l, t: sample_logits_batch(r, l, t, top_k=4))
    logits = _logits(4)
    temp = jnp.asarray([0.0, 1.0] * 4, jnp.float32)
    out = fn(jax.random.PRNGKey(0), logits, temp)
    assert out.shape == (8,)
    # retrace-free across different row mixes (shapes unchanged)
    out2 = fn(jax.random.PRNGKey(1), logits, jnp.flip(temp))
    assert out2.shape == (8,)


def test_single_stream_sampler_consistency():
    """``sample_logits`` (single-request path) agrees with the batch
    sampler's greedy rows."""
    logits = _logits(5, b=1)[0]
    single = sample_logits(jax.random.PRNGKey(0), logits, temperature=0.0)
    batch = sample_logits_batch(jax.random.PRNGKey(0), logits[None],
                                jnp.zeros((1,), jnp.float32))
    assert int(single) == int(batch[0])
