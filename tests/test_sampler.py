"""Direct unit tests for ``serving/sampler.py``: the fused decode step
samples every slot in one call with per-row temperature, so greedy rows
must be exact argmax, stochastic rows must respect top-k masking, and the
whole thing must stay jit-traceable with mixed rows. The keyed variant
derives per-row keys from (request_id, step), so a request's stream is
independent of batch composition."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.serving.sampler import (request_keys, sample_logits,
                                   sample_logits_batch, sample_logits_keyed)


def _logits(seed=0, b=8, v=64):
    return jax.random.normal(jax.random.PRNGKey(seed), (b, v)) * 3.0


def test_temperature_zero_rows_match_argmax_exactly():
    logits = _logits()
    temp = jnp.zeros((8,), jnp.float32)
    for seed in range(3):                  # greedy must ignore the rng
        out = sample_logits_batch(jax.random.PRNGKey(seed), logits, temp)
        np.testing.assert_array_equal(
            np.asarray(out), np.asarray(jnp.argmax(logits, axis=-1)))
    assert out.dtype == jnp.int32


def test_mixed_rows_greedy_unaffected_by_stochastic_neighbors():
    """Per-row temperature: greedy rows must stay argmax even when other
    rows in the same call sample stochastically."""
    logits = _logits(1)
    temp = jnp.asarray([0.0, 1.0, 0.0, 2.0, 0.0, 0.5, 0.0, 1.5], jnp.float32)
    out = np.asarray(sample_logits_batch(jax.random.PRNGKey(7), logits, temp))
    greedy = np.asarray(jnp.argmax(logits, axis=-1))
    for row in (0, 2, 4, 6):
        assert out[row] == greedy[row]


def test_stochastic_rows_respect_top_k():
    logits = _logits(2, b=4, v=32)
    temp = jnp.full((4,), 1.5, jnp.float32)
    k = 5
    allowed = np.asarray(jax.lax.top_k(logits, k)[1])
    for seed in range(20):
        out = np.asarray(sample_logits_batch(jax.random.PRNGKey(seed),
                                             logits, temp, top_k=k))
        for row in range(4):
            assert out[row] in allowed[row], (seed, row)


def test_stochastic_rows_cover_more_than_argmax():
    """High temperature must actually sample (not collapse to greedy)."""
    logits = _logits(3, b=2, v=16)
    temp = jnp.full((2,), 5.0, jnp.float32)
    seen = {int(sample_logits_batch(jax.random.PRNGKey(s), logits, temp)[0])
            for s in range(64)}
    assert len(seen) > 1


def test_jit_traceable_with_mixed_rows():
    fn = jax.jit(lambda r, l, t: sample_logits_batch(r, l, t, top_k=4))
    logits = _logits(4)
    temp = jnp.asarray([0.0, 1.0] * 4, jnp.float32)
    out = fn(jax.random.PRNGKey(0), logits, temp)
    assert out.shape == (8,)
    # retrace-free across different row mixes (shapes unchanged)
    out2 = fn(jax.random.PRNGKey(1), logits, jnp.flip(temp))
    assert out2.shape == (8,)


def test_request_keys_pure_function_of_rid_and_step():
    base = jax.random.PRNGKey(0)
    a = request_keys(base, jnp.asarray([3, 7]), jnp.asarray([0, 5]))
    b = request_keys(base, jnp.asarray([7, 3, 9]), jnp.asarray([5, 0, 1]))
    # same (rid, step) -> same key, wherever it sits in the batch
    np.testing.assert_array_equal(np.asarray(a[0]), np.asarray(b[1]))
    np.testing.assert_array_equal(np.asarray(a[1]), np.asarray(b[0]))
    # different step or rid -> different key
    assert not np.array_equal(np.asarray(a[0]), np.asarray(a[1]))


def test_keyed_sampling_independent_of_batch_composition():
    """The satellite contract at the sampler level: a row's sample depends
    only on its own (key, logits, temperature), not on its neighbors."""
    logits = _logits(6, b=4, v=32)
    temp = jnp.full((4,), 1.0, jnp.float32)
    base = jax.random.PRNGKey(1)
    rids = jnp.asarray([0, 1, 2, 3])
    steps = jnp.asarray([0, 4, 2, 0])
    keys = request_keys(base, rids, steps)
    full = np.asarray(sample_logits_keyed(keys, logits, temp))
    # the same rows shuffled into a different batch order
    perm = jnp.asarray([2, 0, 3, 1])
    shuf = np.asarray(sample_logits_keyed(
        request_keys(base, rids[perm], steps[perm]), logits[perm],
        temp[perm]))
    for i, p in enumerate(np.asarray(perm)):
        assert shuf[i] == full[p]


def test_keyed_sampling_greedy_rows_exact():
    logits = _logits(7)
    temp = jnp.asarray([0.0, 1.0] * 4, jnp.float32)
    keys = request_keys(jax.random.PRNGKey(2), jnp.arange(8),
                        jnp.zeros((8,), jnp.int32))
    out = np.asarray(sample_logits_keyed(keys, logits, temp))
    greedy = np.asarray(jnp.argmax(logits, axis=-1))
    np.testing.assert_array_equal(out[::2], greedy[::2])


def test_single_stream_sampler_consistency():
    """``sample_logits`` (single-request path) agrees with the batch
    sampler's greedy rows."""
    logits = _logits(5, b=1)[0]
    single = sample_logits(jax.random.PRNGKey(0), logits, temperature=0.0)
    batch = sample_logits_batch(jax.random.PRNGKey(0), logits[None],
                                jnp.zeros((1,), jnp.float32))
    assert int(single) == int(batch[0])
