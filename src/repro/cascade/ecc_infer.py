"""Edge/cloud collaborative LM inference — the video-query cascade
transposed to the LM workloads ACE hosts (inter-model ECC inference, §2).

Requests are one-shot queries (the LM analog of a crop): the *edge* model
(a shallow same-vocab draft) prefills every request and emits a next-token
distribution; requests whose max-softmax confidence falls inside the BP band
are *escalated*: compacted to a fixed-capacity slice and prefilled by the
*cloud* model, whose prediction overrides the edge one. On a mesh, the edge
model lives replicated across ``data`` shards and the cloud model
tensor-parallel across ``model`` — the compaction gather is the WAN hop.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.cascade.gate import (ESCALATE, GateThresholds, basic_gate,
                                confidence_from_logits, gate_counts,
                                make_thresholds)
from repro.cascade.routing import (compact_escalations, gather_compacted,
                                   scatter_back)
from repro.configs.base import ModelConfig, Stage
from repro.models.model import LM


def edge_variant(cfg: ModelConfig, *, layers: int = 4,
                 d_model: Optional[int] = None) -> ModelConfig:
    """A shallow same-vocab draft of ``cfg`` to play EOC against its COC."""
    import dataclasses as dc
    d = d_model or max(256, cfg.d_model // 4)
    heads = max(1, cfg.num_heads // 4)
    ratio = max(1, cfg.num_heads // cfg.num_kv_heads)
    stages = []
    remaining = layers
    for st in cfg.stages:
        if remaining <= 0:
            break
        take = min(remaining, st.repeat)
        stages.append(Stage(blocks=st.blocks, repeat=take))
        remaining -= take
    # pad with the first stage's block type if the model is too shallow
    while remaining > 0:
        stages.append(Stage(blocks=cfg.stages[0].blocks, repeat=remaining))
        remaining = 0
    n_layers = sum(len(s.blocks) * s.repeat for s in stages)
    moe = None
    if cfg.moe is not None:
        moe = dc.replace(cfg.moe, num_experts=min(cfg.moe.num_experts, 8),
                         num_experts_per_tok=min(cfg.moe.num_experts_per_tok, 2),
                         d_ff_expert=max(256, cfg.moe.d_ff_expert // 4),
                         num_shared_experts=min(cfg.moe.num_shared_experts, 1),
                         d_ff_shared=max(256, cfg.moe.d_ff_shared // 4)
                         if cfg.moe.num_shared_experts else 0)
    mla = None
    if cfg.mla is not None:
        mla = dc.replace(cfg.mla, q_lora_rank=256, kv_lora_rank=128)
    return dc.replace(
        cfg, name=cfg.name + "-edge", num_layers=n_layers, d_model=d,
        num_heads=heads, num_kv_heads=max(1, heads // min(ratio, heads)),
        head_dim=64, d_ff=max(256, cfg.d_ff // 4) if cfg.d_ff else 0,
        stages=tuple(stages), moe=moe, mla=mla, mtp_depth=0)


@dataclasses.dataclass
class CascadeLM:
    """The ACE inter-model cascade over two LMs sharing a tokenizer."""
    edge: LM
    cloud: LM
    thresholds: GateThresholds = None
    capacity_frac: float = 0.25     # cloud slice size as a fraction of B

    def __post_init__(self):
        assert self.edge.cfg.padded_vocab == self.cloud.cfg.padded_vocab, \
            "cascade models must share a vocabulary"
        if self.thresholds is None:
            self.thresholds = make_thresholds()

    def capacity(self, batch: int) -> int:
        return max(1, int(batch * self.capacity_frac))

    # -- the jittable serving step (lowered by the dry-run) -------------------
    def serve_step(self, edge_params, cloud_params, batch: dict):
        """batch['tokens']: (B, S) one-shot queries. Returns dict with final
        predictions, per-request route codes, and boundary-traffic bytes."""
        tokens = batch["tokens"]
        b = tokens.shape[0]
        cap = self.capacity(b)

        edge_logits, _, _, _ = self.edge.forward(edge_params, batch)
        edge_last = edge_logits[:, -1, :]                     # (B, V)
        conf = confidence_from_logits(edge_last)
        routes = basic_gate(conf, self.thresholds)
        esc = routes == ESCALATE

        routing = compact_escalations(esc, cap)
        cloud_batch = {"tokens": gather_compacted(tokens, routing, cap)}
        for k, v in batch.items():
            if k not in ("tokens", "labels"):
                cloud_batch[k] = gather_compacted(v, routing, cap)
        cloud_logits, _, _, _ = self.cloud.forward(cloud_params, cloud_batch)
        cloud_last = cloud_logits[:, -1, :]                   # (cap, V)

        final = scatter_back(edge_last, cloud_last, routing)
        pred = jnp.argmax(final, axis=-1)
        counts = gate_counts(routes)
        # boundary traffic: escalated token ids up + logits (or argmax) down
        wan_bytes = (jnp.minimum(counts["escalate"], cap)
                     * (tokens.shape[1] * 4 + 4))
        return {"pred": pred, "conf": conf, "routes": routes,
                "edge_pred": jnp.argmax(edge_last, axis=-1),
                "wan_bytes": wan_bytes, **counts}

    def lockstep_step(self, edge_params, cloud_params, batch: dict):
        """Paper-faithful baseline (no compaction): the cloud model sees the
        full batch; the gate only selects which logits win. Same accuracy,
        strictly more cloud compute + boundary bytes — the §Perf baseline the
        compacted version is measured against."""
        tokens = batch["tokens"]
        edge_logits, _, _, _ = self.edge.forward(edge_params, batch)
        edge_last = edge_logits[:, -1, :]
        conf = confidence_from_logits(edge_last)
        routes = basic_gate(conf, self.thresholds)
        cloud_logits, _, _, _ = self.cloud.forward(cloud_params, batch)
        cloud_last = cloud_logits[:, -1, :]
        esc = (routes == ESCALATE)[:, None]
        final = jnp.where(esc, cloud_last, edge_last)
        counts = gate_counts(routes)
        wan_bytes = jnp.int32(tokens.shape[0] * (tokens.shape[1] * 4 + 4))
        return {"pred": jnp.argmax(final, axis=-1), "conf": conf,
                "routes": routes,
                "edge_pred": jnp.argmax(edge_last, axis=-1),
                "wan_bytes": wan_bytes, **counts}
