"""Confidence gate (paper §5.1.2 BP/AP) on batched tensors.

BP: accept conf >= hi; drop conf < lo; escalate otherwise.
AP: thresholds become *state* updated from EIL estimates with jax control
flow — the tensorized analog of the simulator's AdvancedPolicy, usable
inside a jitted serving step.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

ACCEPT, DROP, ESCALATE = 0, 1, 2


class GateThresholds(NamedTuple):
    hi: jnp.ndarray          # accept threshold (scalar f32)
    lo: jnp.ndarray          # drop threshold


def make_thresholds(hi: float = 0.8, lo: float = 0.1) -> GateThresholds:
    return GateThresholds(jnp.float32(hi), jnp.float32(lo))


def basic_gate(conf: jnp.ndarray, th: GateThresholds) -> jnp.ndarray:
    """conf: (...,) f32 in [0,1] -> route codes (ACCEPT/DROP/ESCALATE)."""
    return jnp.where(conf >= th.hi, ACCEPT,
                     jnp.where(conf < th.lo, DROP, ESCALATE)).astype(jnp.int32)


def gate_counts(routes: jnp.ndarray) -> dict:
    return {
        "accept": jnp.sum(routes == ACCEPT),
        "drop": jnp.sum(routes == DROP),
        "escalate": jnp.sum(routes == ESCALATE),
    }


class APState(NamedTuple):
    th: GateThresholds
    eil_edge: jnp.ndarray    # EWMA of edge latency estimate
    eil_cloud: jnp.ndarray


def ap_init(hi: float = 0.8, lo: float = 0.1) -> APState:
    return APState(make_thresholds(hi, lo), jnp.float32(0.0), jnp.float32(0.0))


def adaptive_thresholds(state: APState, eil_edge: jnp.ndarray,
                        eil_cloud: jnp.ndarray, *, ewma: float = 0.2,
                        deteriorate_s: float = 0.3, shrink: float = 0.1,
                        recover: float = 0.02, hi0: float = 0.8,
                        lo0: float = 0.1) -> APState:
    """One AP update step (pure; lax.cond-free via where)."""
    e = (1 - ewma) * state.eil_edge + ewma * eil_edge
    c = (1 - ewma) * state.eil_cloud + ewma * eil_cloud
    worst = jnp.maximum(e, c)
    band = state.th.hi - state.th.lo
    hi_shrunk = jnp.maximum(0.5, state.th.hi - shrink * band)
    lo_shrunk = jnp.minimum(0.45, state.th.lo + shrink * band)
    hi_rec = jnp.minimum(hi0, state.th.hi + recover)
    lo_rec = jnp.maximum(lo0, state.th.lo - recover)
    bad = worst > deteriorate_s
    th = GateThresholds(jnp.where(bad, hi_shrunk, hi_rec),
                        jnp.where(bad, lo_shrunk, lo_rec))
    return APState(th, e, c)


def confidence_from_logits(logits: jnp.ndarray) -> jnp.ndarray:
    """Max-softmax confidence over the final axis, f32."""
    return jnp.max(jax.nn.softmax(logits.astype(jnp.float32), axis=-1),
                   axis=-1)
