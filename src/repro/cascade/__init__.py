"""The paper's confidence-gated cascade as pure JAX (static shapes).

``gate``      — BP/AP threshold logic on batched confidences.
``routing``   — sort-based compaction of escalated rows (beyond-paper
                optimization: the cloud model touches only a bounded slice).
``ecc_infer`` — edge-model/cloud-model collaborative decode under a mesh.
"""
from repro.cascade.gate import GateThresholds, basic_gate, adaptive_thresholds
from repro.cascade.routing import compact_escalations, scatter_back
from repro.cascade.ecc_infer import CascadeLM

__all__ = ["GateThresholds", "basic_gate", "adaptive_thresholds",
           "compact_escalations", "scatter_back", "CascadeLM"]
