"""Static-shape escalation routing.

JAX needs static shapes, so 'send only escalated crops to the cloud' becomes
sort-based compaction into a fixed-capacity slice: escalated rows are moved
to the front (stable order), the cloud model runs on the first ``capacity``
rows only, and results scatter back. Escalations beyond capacity fall back
to the edge result (graceful degradation — the tensor analog of the
simulator's bounded queues).

With the batch sharded on the data axis and the cloud model on the model
axis, the gather of compacted rows is exactly the edge->cloud WAN transfer;
its bytes are what §Roofline meters for the cascade.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp


class Routing(NamedTuple):
    order: jnp.ndarray        # (B,) permutation: escalated first
    inverse: jnp.ndarray      # (B,) inverse permutation
    num_escalated: jnp.ndarray  # scalar int32
    kept: jnp.ndarray         # (capacity,) bool: slot holds a real escalation


def compact_escalations(escalate_mask: jnp.ndarray,
                        capacity: int) -> Routing:
    """escalate_mask: (B,) bool. Stable-sort escalated rows to the front."""
    b = escalate_mask.shape[0]
    # stable argsort of (not escalated): False (escalated) sorts first
    key = (~escalate_mask).astype(jnp.int32)
    order = jnp.argsort(key, stable=True)
    inverse = jnp.argsort(order)
    num = jnp.sum(escalate_mask.astype(jnp.int32))
    kept = jnp.arange(capacity) < jnp.minimum(num, capacity)
    return Routing(order, inverse, num, kept)


def gather_compacted(x: jnp.ndarray, routing: Routing,
                     capacity: int) -> jnp.ndarray:
    """Rows for the cloud model: first ``capacity`` rows in escalated-first
    order. x: (B, ...) -> (capacity, ...)."""
    return jnp.take(x, routing.order[:capacity], axis=0)


def scatter_back(edge_result: jnp.ndarray, cloud_result: jnp.ndarray,
                 routing: Routing) -> jnp.ndarray:
    """Overlay cloud results onto escalated rows (within capacity).

    edge_result: (B, ...); cloud_result: (capacity, ...)."""
    b = edge_result.shape[0]
    capacity = cloud_result.shape[0]
    padded = jnp.concatenate(
        [cloud_result,
         jnp.zeros((b - capacity,) + cloud_result.shape[1:],
                   cloud_result.dtype)], axis=0) if capacity < b else \
        cloud_result[:b]
    in_order = jnp.take(padded, routing.inverse, axis=0)
    used = jnp.concatenate(
        [routing.kept, jnp.zeros((b - capacity,), bool)], axis=0) \
        if capacity < b else routing.kept[:b]
    used_in_order = jnp.take(used, routing.inverse, axis=0)
    shape = (b,) + (1,) * (edge_result.ndim - 1)
    return jnp.where(used_in_order.reshape(shape), in_order, edge_result)
