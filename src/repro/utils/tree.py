"""Pytree helpers (parameter counting, finiteness checks, flat paths)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def tree_size(tree) -> int:
    """Total number of array elements in a pytree."""
    return int(sum(np.prod(x.shape) for x in jax.tree.leaves(tree) if hasattr(x, "shape")))


def tree_bytes(tree) -> int:
    """Total bytes of a pytree (works on ShapeDtypeStruct too)."""
    total = 0
    for x in jax.tree.leaves(tree):
        if hasattr(x, "shape") and hasattr(x, "dtype"):
            total += int(np.prod(x.shape)) * jnp.dtype(x.dtype).itemsize
    return total


def tree_allfinite(tree) -> bool:
    """True iff every float leaf is finite everywhere."""
    for x in jax.tree.leaves(tree):
        arr = jnp.asarray(x)
        if jnp.issubdtype(arr.dtype, jnp.floating):
            if not bool(jnp.all(jnp.isfinite(arr))):
                return False
    return True


def flat_paths(tree) -> dict:
    """Flatten a pytree into {'a/b/c': leaf} using key paths."""
    out = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(_key_str(k) for k in path)
        out[key] = leaf
    return out


def _key_str(k) -> str:
    if hasattr(k, "key"):
        return str(k.key)
    if hasattr(k, "idx"):
        return str(k.idx)
    return str(k)
