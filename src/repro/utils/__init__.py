"""Shared utilities: registries, pytree helpers, logging, timing."""
from repro.utils.registry import Registry
from repro.utils.tree import tree_size, tree_bytes, tree_allfinite

__all__ = ["Registry", "tree_size", "tree_bytes", "tree_allfinite"]
