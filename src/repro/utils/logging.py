"""Minimal structured logging for platform events and benchmarks."""
from __future__ import annotations

import json
import sys
import time
from typing import Any, Dict, List, Optional


class EventLog:
    """Append-only structured event log (the monitoring substrate)."""

    def __init__(self, name: str = "ace", echo: bool = False):
        self.name = name
        self.echo = echo
        self.events: List[Dict[str, Any]] = []
        self._t0 = time.monotonic()

    def log(self, kind: str, **fields) -> Dict[str, Any]:
        ev = {"t": round(time.monotonic() - self._t0, 6), "kind": kind, **fields}
        self.events.append(ev)
        if self.echo:
            print(f"[{self.name}] {kind}: {fields}", file=sys.stderr)
        return ev

    def query(self, kind: Optional[str] = None, **match) -> List[Dict[str, Any]]:
        out = []
        for ev in self.events:
            if kind is not None and ev["kind"] != kind:
                continue
            if all(ev.get(k) == v for k, v in match.items()):
                out.append(ev)
        return out

    def dump(self, path: str) -> None:
        with open(path, "w") as f:
            for ev in self.events:
                f.write(json.dumps(ev) + "\n")
