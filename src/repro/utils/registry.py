"""A tiny name->factory registry used across the framework.

Used for architecture configs (``--arch <id>``), platform component images
(the "image registry" analog), in-app control policies, and benchmark tables.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Iterator, Optional


class Registry:
    def __init__(self, kind: str):
        self.kind = kind
        self._items: Dict[str, Any] = {}

    def register(self, name: str, obj: Optional[Any] = None) -> Callable:
        """Register ``obj`` under ``name``; usable as a decorator."""
        if obj is not None:
            self._register(name, obj)
            return obj

        def deco(fn):
            self._register(name, fn)
            return fn

        return deco

    def _register(self, name: str, obj: Any) -> None:
        if name in self._items:
            raise KeyError(f"{self.kind} {name!r} already registered")
        self._items[name] = obj

    def get(self, name: str) -> Any:
        if name not in self._items:
            known = ", ".join(sorted(self._items))
            raise KeyError(f"unknown {self.kind} {name!r}; known: {known}")
        return self._items[name]

    def __contains__(self, name: str) -> bool:
        return name in self._items

    def names(self) -> list:
        return sorted(self._items)

    def items(self) -> Iterator:
        return iter(sorted(self._items.items()))

    def __len__(self) -> int:
        return len(self._items)
