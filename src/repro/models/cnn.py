"""Compact conv classifiers for the ACE video-query application (paper §5).

EOC (edge object classifier, MobileNetV2 role) and COC (cloud object
classifier, ResNet152 role) — the capacity *ratio* matters to the cascade,
not the exact backbones (DESIGN.md §2). Residual conv stages, global average
pooling, softmax head. Pure functional JAX, init returns (params, axes).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.ace_video_query import ClassifierConfig
from repro.models import param as P


def _conv_init(rng, cin: int, cout: int, ksize: int, dtype):
    fan_in = cin * ksize * ksize
    return P.box(P.lecun(rng, (ksize, ksize, cin, cout), dtype, fan_in),
                 (None, None, None, P.MLP))


def _conv(params, x, stride: int = 1):
    return jax.lax.conv_general_dilated(
        x, params, window_strides=(stride, stride), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def _gn(x, scale, bias, groups: int = 8, eps: float = 1e-5):
    """GroupNorm (batch-size independent — edge batches are tiny)."""
    b, h, w, c = x.shape
    g = min(groups, c)
    xg = x.reshape(b, h, w, g, c // g).astype(jnp.float32)
    mean = jnp.mean(xg, axis=(1, 2, 4), keepdims=True)
    var = jnp.var(xg, axis=(1, 2, 4), keepdims=True)
    xg = (xg - mean) * jax.lax.rsqrt(var + eps)
    out = xg.reshape(b, h, w, c) * (1.0 + scale) + bias
    return out.astype(x.dtype)


class Classifier:
    def __init__(self, cfg: ClassifierConfig, dtype=jnp.float32):
        self.cfg = cfg
        self.dtype = dtype

    def init_boxed(self, rng):
        cfg = self.cfg
        dtype = self.dtype
        keys = jax.random.split(rng, 2 + len(cfg.widths) * (1 + 2 * cfg.num_blocks_per_stage))
        ki = iter(keys)
        p = {"stem": _conv_init(next(ki), 3, cfg.widths[0], 3, dtype),
             "stem_scale": P.box(P.zeros((cfg.widths[0],), jnp.float32), (None,)),
             "stem_bias": P.box(P.zeros((cfg.widths[0],), jnp.float32), (None,))}
        stages = []
        cin = cfg.widths[0]
        for w in cfg.widths:
            stage = {"down": _conv_init(next(ki), cin, w, 3, dtype),
                     "down_scale": P.box(P.zeros((w,), jnp.float32), (None,)),
                     "down_bias": P.box(P.zeros((w,), jnp.float32), (None,)),
                     "blocks": []}
            for _ in range(cfg.num_blocks_per_stage):
                stage["blocks"].append({
                    "c1": _conv_init(next(ki), w, w, 3, dtype),
                    "s1": P.box(P.zeros((w,), jnp.float32), (None,)),
                    "b1": P.box(P.zeros((w,), jnp.float32), (None,)),
                    "c2": _conv_init(next(ki), w, w, 3, dtype),
                    "s2": P.box(P.zeros((w,), jnp.float32), (None,)),
                    "b2": P.box(P.zeros((w,), jnp.float32), (None,)),
                })
            stages.append(stage)
            cin = w
        p["stages"] = stages
        p["head"] = P.box(P.lecun(next(ki), (cin, cfg.num_classes), dtype, cin),
                          (None, None))
        p["head_bias"] = P.box(P.zeros((cfg.num_classes,), jnp.float32), (None,))
        return p

    def init(self, rng):
        return P.unbox(self.init_boxed(rng))

    def apply(self, params, images):
        """images: (B, H, W, 3) in [0, 1] -> logits (B, num_classes)."""
        x = _conv(params["stem"], images.astype(self.dtype))
        x = jax.nn.relu(_gn(x, params["stem_scale"], params["stem_bias"]))
        for stage in params["stages"]:
            x = _conv(stage["down"], x, stride=2)
            x = jax.nn.relu(_gn(x, stage["down_scale"], stage["down_bias"]))
            for blk in stage["blocks"]:
                h = jax.nn.relu(_gn(_conv(blk["c1"], x), blk["s1"], blk["b1"]))
                h = _gn(_conv(blk["c2"], h), blk["s2"], blk["b2"])
                x = jax.nn.relu(x + h)
        x = jnp.mean(x, axis=(1, 2))
        logits = x @ params["head"] + params["head_bias"]
        return logits

    def predict(self, params, images) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Returns (confidence of argmax, argmax class)."""
        probs = jax.nn.softmax(self.apply(params, images), axis=-1)
        return jnp.max(probs, axis=-1), jnp.argmax(probs, axis=-1)

    def loss(self, params, images, labels):
        logits = self.apply(params, images)
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        nll = -jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
        acc = jnp.mean((jnp.argmax(logits, -1) == labels).astype(jnp.float32))
        return jnp.mean(nll), {"acc": acc}
