"""Mixture-of-Experts channel mixer.

Dispatch is scatter-based (GShard-style capacity, but without materializing
the (T, E, C) one-hot tensor): per-(token, choice) slot ids come from a
cumulative count over the token axis, tokens are scattered into an
(E, C, D) buffer, experts run as one grouped einsum, and results are gathered
back with routing weights. With the expert axis sharded on "model" the
scatter/gather lower to all-to-all — the collective the roofline analysis
tracks for MoE archs.

Routing: softmax top-k (Mixtral) or sigmoid top-k with shared experts
(DeepSeek-V3, inferred from num_shared_experts > 0), plus a switch-style
load-balance auxiliary loss.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro import sharding as sh
from repro.models import param as P


def moe_init(rng, cfg, dtype) -> dict:
    m = cfg.moe
    d = cfg.d_model
    ks = jax.random.split(rng, 5)
    e, f = m.num_experts, m.d_ff_expert
    params = {
        "router": P.box(P.normal(ks[0], (d, e), jnp.float32, d ** -0.5),
                        (P.EMBED, P.EXPERT)),
        "w_gate": P.box(P.lecun(ks[1], (e, d, f), dtype, d), (P.EXPERT, P.EMBED, P.MLP)),
        "w_up": P.box(P.lecun(ks[2], (e, d, f), dtype, d), (P.EXPERT, P.EMBED, P.MLP)),
        "w_down": P.box(P.lecun(ks[3], (e, f, d), dtype, f), (P.EXPERT, P.MLP, P.EMBED_OUT)),
    }
    if m.num_shared_experts > 0:
        fs = m.d_ff_shared
        k1, k2, k3 = jax.random.split(ks[4], 3)
        params["shared"] = {
            "w_gate": P.box(P.lecun(k1, (d, fs), dtype, d), (P.EMBED, P.MLP)),
            "w_up": P.box(P.lecun(k2, (d, fs), dtype, d), (P.EMBED, P.MLP)),
            "w_down": P.box(P.lecun(k3, (fs, d), dtype, fs), (P.MLP, P.EMBED_OUT)),
        }
    return params


def route(params, cfg, x_flat) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """x_flat: (T, D) -> (topk_idx (T,k), topk_w (T,k) f32, aux_loss scalar)."""
    m = cfg.moe
    logits = jnp.einsum("td,de->te", x_flat.astype(jnp.float32),
                        params["router"])
    if m.num_shared_experts > 0:      # DeepSeek-style sigmoid routing
        scores = jax.nn.sigmoid(logits)
        topk_w, topk_idx = jax.lax.top_k(scores, m.num_experts_per_tok)
        topk_w = topk_w / jnp.maximum(jnp.sum(topk_w, -1, keepdims=True), 1e-9)
        probs = scores / jnp.maximum(jnp.sum(scores, -1, keepdims=True), 1e-9)
    else:                             # Mixtral-style softmax routing
        topk_l, topk_idx = jax.lax.top_k(logits, m.num_experts_per_tok)
        topk_w = jax.nn.softmax(topk_l, axis=-1)
        probs = jax.nn.softmax(logits, axis=-1)
    # switch load-balance aux loss: E * sum_e fraction_e * mean_prob_e
    t = x_flat.shape[0]
    onehot = jax.nn.one_hot(topk_idx[:, 0], m.num_experts, dtype=jnp.float32)
    frac = jnp.mean(onehot, axis=0)
    mean_p = jnp.mean(probs, axis=0)
    aux = m.num_experts * jnp.sum(frac * mean_p)
    return topk_idx, topk_w, aux


def moe_forward(params, cfg, x, *, capacity_factor: float = 1.25):
    """x: (B, S, D) -> (y, aux_loss).

    GShard-style *grouped* capacity: each sequence (batch row) is a dispatch
    group with its own per-expert capacity. Groups make the scatter/gather
    shard-local when the batch is sharded on 'data' — with a global (E, C)
    buffer instead, slot ids come from a global cumsum that straddles shard
    boundaries and GSPMD lowers the dispatch into TB-scale resharding
    (measured on deepseek train_4k). The expert axis still shards on 'model'
    (expert parallelism -> all-to-all at the group boundary).
    """
    m = cfg.moe
    b, s, d = x.shape
    k = m.num_experts_per_tok
    e = m.num_experts
    # explicit sequence-parallel boundary: routing/dispatch needs whole
    # sequences per shard (the per-group cumsum is sequential in s); under
    # the SP residual hint GSPMD otherwise thrashes the dispatch across seq
    # shards (+130 s/step collective measured on deepseek train_4k)
    x = sh.hint(x, (sh.BATCH, None, None))
    x_flat = x.reshape(b * s, d)

    topk_idx, topk_w, aux = route(params, cfg, x_flat)

    capacity = max(int(s * k / e * capacity_factor), 1) if s > 1 else 1
    flat_e = topk_idx.reshape(b, s * k)                    # (B, S*k)
    # slot of each (token, choice) within its expert, per group
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)    # (B, S*k, E)
    pos_in_e = jnp.cumsum(onehot, axis=1) - 1              # (B, S*k, E)
    slot = jnp.take_along_axis(
        pos_in_e, flat_e[..., None], axis=2)[..., 0]       # (B, S*k)
    keep = slot < capacity                                 # token dropping
    target = jnp.where(keep, flat_e * capacity + slot, e * capacity)

    # SPMD note: the (.., D)-sized tensors move ONLY through batched gathers
    # (take_along_axis with a leading batch dim) — GSPMD partitions those
    # along 'data'; a direct scatter of (B, E, C, D) is replicated instead
    # (measured: 1 TiB/device on deepseek train_4k). The only scatter left
    # is the int32 slot->source map.
    rows = jnp.arange(b)[:, None]
    src = jnp.full((b, e * capacity + 1), s * k, jnp.int32)
    src = src.at[rows, target].set(
        jnp.broadcast_to(jnp.arange(s * k, dtype=jnp.int32), (b, s * k)),
        mode="drop")
    src = src[:, :e * capacity]                            # (B, E*C)

    tok_of_choice = (jnp.arange(s * k, dtype=jnp.int32) // k)
    x_grp = x.reshape(b, s, d)
    # gather source tokens into expert slots (sentinel row s -> zeros)
    x_pad = jnp.concatenate([x_grp, jnp.zeros((b, 1, d), x.dtype)], axis=1)
    src_tok = jnp.where(src >= s * k, s, jnp.take(tok_of_choice,
                                                  jnp.clip(src, 0, s * k - 1)))
    xe = jnp.take_along_axis(x_pad, src_tok[..., None], axis=1)
    xe = xe.reshape(b, e, capacity, d)
    xe = sh.hint(xe, (sh.BATCH, sh.EXPERT, None, None))

    # grouped expert FFN (SwiGLU)
    g = jnp.einsum("becd,edf->becf", xe, params["w_gate"])
    u = jnp.einsum("becd,edf->becf", xe, params["w_up"])
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    ye = jnp.einsum("becf,efd->becd", h, params["w_down"])
    ye = sh.hint(ye, (sh.BATCH, sh.EXPERT, None, None))

    # combine: batched gather back in (token, choice) order, weight, sum k
    ye_flat = ye.reshape(b, e * capacity, d)
    ye_pad = jnp.concatenate([ye_flat, jnp.zeros((b, 1, d), x.dtype)],
                             axis=1)
    back = jnp.where(keep, target, e * capacity)           # (B, S*k)
    gathered = jnp.take_along_axis(ye_pad, back[..., None], axis=1)
    weighted = gathered * topk_w.reshape(b, s * k, 1).astype(x.dtype)
    y = jnp.sum(weighted.reshape(b, s, k, d), axis=2)

    if m.num_shared_experts > 0:
        sp = params["shared"]
        gs = jnp.einsum("td,df->tf", x_flat, sp["w_gate"])
        us = jnp.einsum("td,df->tf", x_flat, sp["w_up"])
        hs = jax.nn.silu(gs.astype(jnp.float32)).astype(x.dtype) * us
        y = y + jnp.einsum("tf,fd->td", hs, sp["w_down"]).reshape(b, s, d)

    return y, aux
