"""The LM assembly: stages of scanned blocks, train/prefill/decode entry
points, cache management.

Layers are scanned (``jax.lax.scan`` over stacked per-layer params) so the
lowered HLO stays small for the 512-device dry-run, and rematerialized
(``jax.checkpoint``) in training.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro import sharding as sh
from repro.configs.base import (ATTN, GELU_MLP, MLA, MLSTM, MOE, NONE, RGLRU,
                                SLSTM, SWIGLU, BlockDef, ModelConfig, Stage)
from repro.models import attention as att
from repro.models import moe as moe_lib
from repro.models import param as P
from repro.models import recurrent as rec
from repro.models.layers import (embed, embedding_init, gelu_mlp,
                                 gelu_mlp_init, rmsnorm, rmsnorm_init,
                                 softcap, swiglu, swiglu_init, unembed,
                                 unembed_init)

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# Per-block init / forward / decode
# ---------------------------------------------------------------------------

def _block_init(rng, bdef: BlockDef, cfg: ModelConfig, dtype) -> Params:
    ks = jax.random.split(rng, 4)
    p: Params = {}
    if bdef.mixer == ATTN:
        p["norm1"] = rmsnorm_init(cfg.d_model, dtype)
        p["mixer"] = att.attn_init(ks[0], cfg, dtype)
    elif bdef.mixer == MLA:
        p["norm1"] = rmsnorm_init(cfg.d_model, dtype)
        p["mixer"] = att.mla_init(ks[0], cfg, dtype)
    elif bdef.mixer == RGLRU:
        p["norm1"] = rmsnorm_init(cfg.d_model, dtype)
        p["mixer"] = rec.rglru_block_init(ks[0], cfg, dtype)
    elif bdef.mixer == MLSTM:
        p["mixer"] = rec.mlstm_block_init(ks[0], cfg, dtype)
    elif bdef.mixer == SLSTM:
        p["mixer"] = rec.slstm_block_init(ks[0], cfg, dtype)
    else:
        raise ValueError(bdef.mixer)
    if bdef.mlp != NONE:
        p["norm2"] = rmsnorm_init(cfg.d_model, dtype)
        if bdef.mlp == SWIGLU:
            p["mlp"] = swiglu_init(ks[1], cfg.d_model, cfg.d_ff, dtype)
        elif bdef.mlp == GELU_MLP:
            p["mlp"] = gelu_mlp_init(ks[1], cfg.d_model, cfg.d_ff, dtype)
        elif bdef.mlp == MOE:
            p["mlp"] = moe_lib.moe_init(ks[1], cfg, dtype)
        else:
            raise ValueError(bdef.mlp)
    return p


def _mlp_apply(bdef: BlockDef, params, cfg, x, capacity_factor: float):
    if bdef.mlp == NONE:
        return x, jnp.zeros((), jnp.float32)
    h = rmsnorm(params["norm2"], x, cfg.rms_eps)
    if bdef.mlp == SWIGLU:
        return x + swiglu(params["mlp"], h), jnp.zeros((), jnp.float32)
    if bdef.mlp == GELU_MLP:
        return x + gelu_mlp(params["mlp"], h), jnp.zeros((), jnp.float32)
    y, aux = moe_lib.moe_forward(params["mlp"], cfg, h,
                                 capacity_factor=capacity_factor)
    return x + y, aux


def _block_forward(bdef: BlockDef, params, cfg, x, positions, *,
                   want_cache: bool, cache_width: Optional[int],
                   kv_chunk: int, capacity_factor: float, lengths=None):
    """Full-sequence block. Returns (x, cache_or_None, aux). ``lengths``:
    optional (B,) true sequence lengths so cache install never keeps
    right-pad rows (see ``attention._fill_slots``)."""
    b = x.shape[0]
    cache = None
    if bdef.mixer == ATTN:
        h = rmsnorm(params["norm1"], x, cfg.rms_eps)
        y, (k, v) = att.attn_forward(params["mixer"], cfg, h, positions,
                                     window=bdef.window, kv_chunk=kv_chunk)
        x = x + y
        if want_cache:
            width = _attn_width(bdef, cache_width)
            cache = att.init_kv_cache(b, width, cfg.num_kv_heads,
                                      cfg.resolved_head_dim, k.dtype)
            cache = att.cache_fill(cache, k, v, x.shape[1], lengths)
    elif bdef.mixer == MLA:
        h = rmsnorm(params["norm1"], x, cfg.rms_eps)
        y, (ckv, krope) = att.mla_forward(params["mixer"], cfg, h, positions,
                                          window=bdef.window, kv_chunk=kv_chunk)
        x = x + y
        if want_cache:
            width = _attn_width(bdef, cache_width)
            cache = att.init_mla_cache(cfg, b, width, ckv.dtype)
            cache = att.mla_cache_fill(cache, ckv, krope, x.shape[1],
                                       lengths)
    elif bdef.mixer == RGLRU:
        h = rmsnorm(params["norm1"], x, cfg.rms_eps)
        y, state = rec.rglru_block_forward(params["mixer"], cfg, h)
        x = x + y
        cache = state if want_cache else None
    elif bdef.mixer == MLSTM:
        y, state = rec.mlstm_block_forward(params["mixer"], cfg, x)
        x = x + y
        cache = state if want_cache else None
    elif bdef.mixer == SLSTM:
        y, state = rec.slstm_block_forward(params["mixer"], cfg, x)
        x = x + y
        cache = state if want_cache else None
    else:
        raise ValueError(bdef.mixer)
    x, aux = _mlp_apply(bdef, params, cfg, x, capacity_factor)
    return x, cache, aux


def _block_decode(bdef: BlockDef, params, cfg, x1, cache, cur_pos, *,
                  capacity_factor: float, layout=None, block_tables=None,
                  valid=None):
    if bdef.mixer == ATTN:
        h = rmsnorm(params["norm1"], x1, cfg.rms_eps)
        y, cache = att.attn_decode(params["mixer"], cfg, h, cache, cur_pos,
                                   window=bdef.window, layout=layout,
                                   block_tables=block_tables, valid=valid)
        x1 = x1 + y
    elif bdef.mixer == MLA:
        h = rmsnorm(params["norm1"], x1, cfg.rms_eps)
        y, cache = att.mla_decode(params["mixer"], cfg, h, cache, cur_pos,
                                  window=bdef.window, layout=layout,
                                  block_tables=block_tables, valid=valid)
        x1 = x1 + y
    elif bdef.mixer == RGLRU:
        h = rmsnorm(params["norm1"], x1, cfg.rms_eps)
        y, cache = rec.rglru_block_decode(params["mixer"], cfg, h, cache)
        x1 = x1 + y
    elif bdef.mixer == MLSTM:
        y, cache = rec.mlstm_block_decode(params["mixer"], cfg, x1, cache)
        x1 = x1 + y
    elif bdef.mixer == SLSTM:
        y, cache = rec.slstm_block_decode(params["mixer"], cfg, x1, cache)
        x1 = x1 + y
    else:
        raise ValueError(bdef.mixer)
    x1, _ = _mlp_apply(bdef, params, cfg, x1, capacity_factor)
    return x1, cache


def _attn_width(bdef: BlockDef, cache_width: Optional[int]) -> int:
    assert cache_width is not None
    return min(cache_width, bdef.window) if bdef.window else cache_width


def _block_cache_spec(bdef: BlockDef, cfg, batch: int,
                      cache_width: int, dtype):
    if bdef.mixer == ATTN:
        return att.attn_cache_spec(cfg, batch, cache_width, bdef.window, dtype)
    if bdef.mixer == MLA:
        width = _attn_width(bdef, cache_width)
        return att.init_mla_cache(cfg, batch, width, dtype)
    if bdef.mixer == RGLRU:
        return rec.rglru_state_spec(cfg, batch, dtype)
    if bdef.mixer == MLSTM:
        return rec.mlstm_state_init(batch, cfg.num_heads, cfg.resolved_head_dim)
    if bdef.mixer == SLSTM:
        return rec.slstm_state_init(batch, cfg.num_heads, cfg.resolved_head_dim)
    raise ValueError(bdef.mixer)


# ---------------------------------------------------------------------------
# The model
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class LM:
    cfg: ModelConfig
    kv_chunk: int = 512
    capacity_factor: float = 1.25

    @property
    def dtype(self):
        return jnp.dtype(self.cfg.param_dtype)

    # -- init ---------------------------------------------------------------
    def init_boxed(self, rng) -> Params:
        cfg = self.cfg
        dtype = self.dtype
        n_stages = len(cfg.stages)
        keys = jax.random.split(rng, n_stages + 4)
        p: Params = {}
        if cfg.frontend.kind == "audio":
            nb = cfg.frontend.num_codebooks
            tbls = jax.random.split(keys[0], nb)
            tables = jnp.stack([
                P.normal(k, (cfg.padded_vocab, cfg.d_model), dtype, 1.0)
                for k in tbls])
            p["embed"] = {"table": P.box(tables, (None, P.VOCAB, P.EMBED))}
        else:
            p["embed"] = embedding_init(keys[0], cfg.padded_vocab,
                                        cfg.d_model, dtype)
        if cfg.frontend.kind == "vision":
            k1, k2 = jax.random.split(keys[1])
            e = cfg.frontend.embed_dim
            p["vision_proj"] = {
                "w1": P.box(P.lecun(k1, (e, cfg.d_model), dtype, e),
                            (None, P.EMBED)),
                "w2": P.box(P.lecun(k2, (cfg.d_model, cfg.d_model), dtype,
                                    cfg.d_model), (P.EMBED, P.EMBED)),
            }
        stages = []
        for si, stage in enumerate(cfg.stages):
            stage_keys = jax.random.split(keys[2 + si], stage.repeat)

            def one_layer(k, _stage=stage):
                bk = jax.random.split(k, len(_stage.blocks))
                return {f"b{i}": _block_init(bk[i], bdef, cfg, dtype)
                        for i, bdef in enumerate(_stage.blocks)}

            layer_p = jax.vmap(one_layer)(stage_keys)
            # vmap strips Boxed axes metadata -> rebuild with STACK prefix
            proto = jax.eval_shape(one_layer, stage_keys[0])
            _, axes = P.unbox(proto)
            layer_v, _ = P.unbox(layer_p)
            layer_boxed = jax.tree.map(
                lambda v, ax: P.box(v, (P.STACK,) + tuple(ax)),
                layer_v, axes)
            stages.append(layer_boxed)
        p["stages"] = stages
        p["final_norm"] = rmsnorm_init(cfg.d_model, dtype)
        if not cfg.tie_embeddings:
            if cfg.frontend.kind == "audio":
                nb = cfg.frontend.num_codebooks
                tbls = jax.random.split(keys[-2], nb)
                tables = jnp.stack([
                    P.normal(k, (cfg.padded_vocab, cfg.d_model), dtype,
                             cfg.d_model ** -0.5) for k in tbls])
                p["unembed"] = {"table": P.box(tables, (None, P.VOCAB, P.EMBED))}
            else:
                p["unembed"] = unembed_init(keys[-2], cfg.padded_vocab,
                                            cfg.d_model, dtype)
        if cfg.mtp_depth > 0:
            k1, k2 = jax.random.split(keys[-1])
            p["mtp"] = {
                "proj": P.box(P.lecun(k1, (2 * cfg.d_model, cfg.d_model),
                                      dtype, 2 * cfg.d_model),
                              (P.EMBED, P.EMBED)),
                "norm": rmsnorm_init(cfg.d_model, dtype),
                "block": _block_init(
                    k2, BlockDef(mixer=ATTN if cfg.mla is None else MLA,
                                 mlp=SWIGLU), cfg, dtype),
            }
        return p

    def init(self, rng) -> Tuple[Params, Params]:
        """Returns (params, logical_axes) pytrees."""
        return P.unbox(self.init_boxed(rng))

    def abstract(self) -> Tuple[Params, Params]:
        """(ShapeDtypeStruct params, logical axes) without allocating."""
        boxed = jax.eval_shape(self.init_boxed, jax.random.PRNGKey(0))
        return P.unbox(boxed)

    # -- embedding ----------------------------------------------------------
    def _embed_inputs(self, params, batch) -> Tuple[jnp.ndarray, jnp.ndarray]:
        cfg = self.cfg
        tokens = batch["tokens"]
        if cfg.frontend.kind == "audio":
            # tokens (B, S, num_codebooks); sum codebook embeddings
            x = jnp.sum(jax.vmap(
                lambda t, c: jnp.take(params["embed"]["table"][c], t, axis=0),
                in_axes=(2, 0), out_axes=2,
            )(tokens, jnp.arange(cfg.frontend.num_codebooks)), axis=2)
        else:
            x = embed(params["embed"], tokens)
        if cfg.frontend.kind == "vision":
            img = batch["image_embeds"]            # (B, P, E) stubbed ViT out
            vp = params["vision_proj"]
            h = jax.nn.gelu(jnp.einsum("bpe,ed->bpd", img, vp["w1"])
                            .astype(jnp.float32), approximate=True)
            img_tok = jnp.einsum("bpd,dk->bpk", h.astype(x.dtype), vp["w2"])
            x = jnp.concatenate([img_tok, x], axis=1)
        b, s = x.shape[0], x.shape[1]
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
        return x, positions

    def _logits(self, params, x):
        cfg = self.cfg
        table = (params["embed"]["table"] if cfg.tie_embeddings
                 else params["unembed"]["table"])
        if cfg.frontend.kind == "audio":
            logits = jnp.einsum("bsd,cvd->bscv", x, table)
        else:
            logits = unembed(table, x)
        if cfg.tie_embeddings:
            # the tied table is unit-std (embedding-scaled); rescale for logits
            logits = logits * (cfg.d_model ** -0.5)
        logits = sh.hint(logits, (sh.BATCH, None, sh.VOCAB)
                         if cfg.frontend.kind != "audio"
                         else (sh.BATCH, None, None, sh.VOCAB))
        return softcap(logits, cfg.logit_softcap)

    # -- full-sequence forward ---------------------------------------------
    def forward(self, params, batch, *, want_cache: bool = False,
                cache_width: Optional[int] = None, train: bool = False,
                last_only: bool = False, lengths=None,
                mesh=None, rules=None):
        """Returns (logits, caches, aux_loss). ``last_only`` unembeds just
        the final position (serving prefill — §Perf B2); ``lengths`` is the
        optional (B,) true-length vector for pad-free cache install.
        ``mesh``/``rules`` activate logical-axis sharding hints for the
        duration of this trace (mesh-aware serving); ``mesh=None`` leaves
        the trace byte-identical to the hint-free path."""
        with sh.maybe_rules(mesh, rules):
            return self._forward(params, batch, want_cache=want_cache,
                                 cache_width=cache_width, train=train,
                                 last_only=last_only, lengths=lengths)

    def _forward(self, params, batch, *, want_cache: bool = False,
                 cache_width: Optional[int] = None, train: bool = False,
                 last_only: bool = False, lengths=None):
        cfg = self.cfg
        x, positions = self._embed_inputs(params, batch)
        x = sh.hint(x, (sh.BATCH, sh.SEQ, None))
        aux = jnp.zeros((), jnp.float32)
        caches: List[Any] = []
        for stage, stage_params in zip(cfg.stages, params["stages"]):
            x, stage_caches, stage_aux = self._stage_forward(
                stage, stage_params, x, positions,
                want_cache=want_cache, cache_width=cache_width, train=train,
                lengths=lengths)
            caches.append(stage_caches)
            aux = aux + stage_aux
        x = rmsnorm(params["final_norm"], x, cfg.rms_eps)
        logits = self._logits(params, x[:, -1:] if last_only else x)
        return logits, (caches if want_cache else None), aux, x

    def _stage_forward(self, stage: Stage, stage_params, x, positions, *,
                       want_cache: bool, cache_width: Optional[int],
                       train: bool, lengths=None):
        cfg = self.cfg

        def body2(carry, layer_params):
            h, aux = carry
            layer_caches = []
            for i, bdef in enumerate(stage.blocks):
                h, cache, a = _block_forward(
                    bdef, layer_params[f"b{i}"], cfg, h, positions,
                    want_cache=want_cache, cache_width=cache_width,
                    kv_chunk=self.kv_chunk,
                    capacity_factor=self.capacity_factor, lengths=lengths)
                aux = aux + a
                h = sh.hint(h, (sh.BATCH, sh.SEQ, None))
                layer_caches.append(cache)
            ys = tuple(layer_caches) if want_cache else None
            return (h, aux), ys

        fn = jax.checkpoint(body2) if train else body2
        (x, aux), caches = jax.lax.scan(
            fn, (x, jnp.zeros((), jnp.float32)), stage_params)
        return x, caches, aux

    # -- decode -------------------------------------------------------------
    def init_cache(self, batch: int, seq_len: int):
        """Stacked per-stage caches sized for a ``seq_len`` context."""
        cfg = self.cfg
        dtype = self.dtype
        caches = []
        for stage in cfg.stages:
            specs = tuple(
                _block_cache_spec(bdef, cfg, batch, seq_len, dtype)
                for bdef in stage.blocks)
            stacked = jax.tree.map(
                lambda a: jnp.zeros((stage.repeat,) + a.shape, a.dtype), specs)
            # position slots must start at -1 (empty), recurrent m at 0
            stacked = jax.tree_util.tree_map_with_path(
                lambda path, a: (jnp.full_like(a, -1)
                                 if _path_endswith(path, "pos") else a),
                stacked)
            caches.append(stacked)
        return caches

    def chunk_incompatible_mixer(self) -> Optional[str]:
        """First mixer kind that cannot consume multi-token prompt chunks
        (recurrent states fold tokens strictly sequentially), or None when
        every stage is attention. One-token decode — including the serving
        engines' K-step decode scan, which carries recurrent state through
        ``lax.scan`` like any other cache leaf — works for every mixer."""
        for stage in self.cfg.stages:
            for bdef in stage.blocks:
                if bdef.mixer not in (ATTN, MLA):
                    return bdef.mixer
        return None

    def decode_step(self, params, caches, tokens, cur_pos, *,
                    layout=None, block_tables=None, valid=None,
                    mesh=None, rules=None):
        """One-token decode. tokens: (B, 1) (audio: (B, 1, C));
        ``cur_pos``: scalar or (B,) per-request positions (continuous
        batching decodes slots at different depths in one step).
        ``layout``/``block_tables`` select the KV-cache layout
        (``repro.serving.kv_cache``; None = per-slot ring caches);
        ``valid`` is an optional (B, 1) mask — False rows compute logits
        but leave the cache untouched (inactive serving slots).
        Returns (logits (B, 1, V...), new caches).

        Scan-carry clean: the returned cache pytree has exactly the input's
        treedef, shapes and dtypes, and every index the step computes
        derives from traced operands — so engines may ``lax.scan`` K decode
        steps with (caches, sampling state) as the carry and pay one
        dispatch per K tokens (multi-step decode)."""
        return self.prefill_chunk(params, caches, tokens, cur_pos,
                                  layout=layout, block_tables=block_tables,
                                  valid=valid, mesh=mesh, rules=rules)

    def prefill_chunk(self, params, caches, tokens, start_pos, *,
                      layout=None, block_tables=None, valid=None,
                      logits_index=None, mesh=None, rules=None):
        """Resume prefill with a T-token prompt chunk per slot (the chunked
        half of the serving scheduler; T = 1 is exactly ``decode_step``).

        tokens: (B, T); ``start_pos``: scalar or (B,) per-slot positions of
        the chunk's first token — token i sits at ``start_pos + i`` and
        attends to every previously installed position plus the chunk's own
        earlier tokens (K/V are appended before attending, so intra-chunk
        causality is ordinary position masking). ``valid``: (B, T) mask for
        right-padded chunk shapes; invalid tokens never touch the cache and
        their logits are garbage the caller must ignore.
        ``logits_index``: optional (B,) chunk-local index — unembed only
        that position per row (the engine only ever samples from the final
        real token, and the vocab projection would otherwise dominate a
        chunk's cost at production vocab sizes). Returns
        (logits (B, T, V...) or (B, 1, V...) with logits_index, caches).

        Chunks longer than one token require attention mixers (recurrent
        states fold tokens sequentially; their decode path is T = 1 only).
        ``mesh``/``rules``: optional sharding context (see ``forward``).
        """
        with sh.maybe_rules(mesh, rules):
            return self._prefill_chunk(
                params, caches, tokens, start_pos, layout=layout,
                block_tables=block_tables, valid=valid,
                logits_index=logits_index)

    def _prefill_chunk(self, params, caches, tokens, start_pos, *,
                       layout=None, block_tables=None, valid=None,
                       logits_index=None):
        cfg = self.cfg
        t = tokens.shape[1]
        if t > 1:
            bad = self.chunk_incompatible_mixer()
            if bad is not None:
                raise NotImplementedError(
                    f"prefill_chunk needs attention mixers "
                    f"(got {bad!r}); chunk length must be 1")
        start_pos = att.positions_1d(start_pos, tokens.shape[0])
        batch = {"tokens": tokens}
        if cfg.frontend.kind == "vision":
            # decode consumes plain text tokens; vision prefix lives in cache
            x = embed(params["embed"], tokens)
        else:
            x, _ = self._embed_inputs(params, batch)
        x = sh.hint(x, (sh.BATCH, sh.SEQ, None))
        new_caches = []
        for stage, stage_params, stage_cache in zip(
                cfg.stages, params["stages"], caches):
            def body(h, xs, _stage=stage):
                layer_params, layer_cache = xs
                new_layer = []
                for i, bdef in enumerate(_stage.blocks):
                    h, c = _block_decode(
                        bdef, layer_params[f"b{i}"], cfg, h, layer_cache[i],
                        start_pos, capacity_factor=self.capacity_factor,
                        layout=layout, block_tables=block_tables,
                        valid=valid)
                    new_layer.append(c)
                return h, tuple(new_layer)

            x, nc = jax.lax.scan(body, x, (stage_params, stage_cache))
            new_caches.append(nc)
        x = rmsnorm(params["final_norm"], x, cfg.rms_eps)
        if logits_index is not None:
            idx = att.positions_1d(logits_index, x.shape[0])
            x = jnp.take_along_axis(x, idx[:, None, None], axis=1)
        logits = self._logits(params, x)
        return logits, new_caches

    def prefill(self, params, batch, cache_width: int,
                last_only: bool = False, lengths=None,
                mesh=None, rules=None):
        """Full forward that also returns populated caches. ``lengths``:
        optional (B,) true prompt lengths — right-pad rows then never land
        in a ring slot (load-bearing for windowed layers, whose cache is
        narrower than a padded bucket)."""
        logits, caches, aux, _ = self.forward(
            params, batch, want_cache=True, cache_width=cache_width,
            last_only=last_only, lengths=lengths, mesh=mesh, rules=rules)
        return logits, caches

    # -- losses ---------------------------------------------------------------
    def loss(self, params, batch, train: bool = True):
        """Next-token cross entropy (+ MoE aux + optional MTP loss)."""
        cfg = self.cfg
        logits, _, aux, h_final = self.forward(params, batch, train=train)
        labels = batch["labels"]
        if cfg.frontend.kind == "vision":
            # loss only over text positions (prefix is image tokens)
            pad = cfg.frontend.num_prefix_tokens
            logits_txt = logits[:, pad:]
            ce = _xent(logits_txt, labels)
        else:
            ce = _xent(logits, labels)
        total = ce + (cfg.moe.router_aux_loss * aux if cfg.moe else 0.0)
        metrics = {"ce": ce, "aux": aux}
        if cfg.mtp_depth > 0 and train:
            mtp = self._mtp_loss(params, batch, h_final)
            total = total + 0.1 * mtp
            metrics["mtp"] = mtp
        return total, metrics

    def _mtp_loss(self, params, batch, h_final):
        """DeepSeek-V3 multi-token prediction: depth-1 extra head predicting
        token t+2 from [h_t ; embed(token_{t+1})]."""
        cfg = self.cfg
        tokens, labels = batch["tokens"], batch["labels"]
        if cfg.frontend.kind == "vision":
            pad = cfg.frontend.num_prefix_tokens
            h_final = h_final[:, pad:]
        emb_next = embed(params["embed"], tokens[:, 1:])
        h = jnp.concatenate([h_final[:, :-1], emb_next], axis=-1)
        h = jnp.einsum("bsd,dk->bsk", h, params["mtp"]["proj"])
        b, s = h.shape[0], h.shape[1]
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
        bdef = BlockDef(mixer=ATTN if cfg.mla is None else MLA, mlp=SWIGLU)
        h, _, _ = _block_forward(bdef, params["mtp"]["block"], cfg, h,
                                 positions, want_cache=False, cache_width=None,
                                 kv_chunk=self.kv_chunk,
                                 capacity_factor=self.capacity_factor)
        h = rmsnorm(params["mtp"]["norm"], h, cfg.rms_eps)
        logits = self._logits(params, h)
        # positions t=0..S-2 predict token_{t+2} == labels[:, 1:]
        return _xent(logits, labels[:, 1:])


def _path_endswith(path, name: str) -> bool:
    return len(path) > 0 and getattr(path[-1], "key", None) == name


def _xent(logits, labels):
    """Masked softmax cross entropy. labels < 0 are ignored."""
    mask = (labels >= 0)
    safe = jnp.maximum(labels, 0)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, safe[..., None], axis=-1)[..., 0]
    nll = jnp.where(mask, nll, 0.0)
    return jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1)
