"""Modality-frontend stubs (the one allowed carve-out, see DESIGN.md §5).

For VLM archs the InternViT vision tower is stubbed: we generate patch
embeddings with the correct shape/dtype contract ``(B, P, embed_dim)``. For
audio archs the EnCodec conv codec is stubbed: the LM consumes the
``(B, S, num_codebooks)`` token grid directly. The projector / codebook
embeddings that *consume* these are fully implemented in the LM.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


def synth_image_embeds(rng, cfg: ModelConfig, batch: int):
    """Stubbed ViT output: unit-normalized patch embeddings."""
    f = cfg.frontend
    x = jax.random.normal(rng, (batch, f.num_prefix_tokens, f.embed_dim),
                          jnp.float32)
    x = x / jnp.linalg.norm(x, axis=-1, keepdims=True)
    return x.astype(jnp.dtype(cfg.param_dtype))


def synth_audio_tokens(rng, cfg: ModelConfig, batch: int, seq_len: int):
    """Stubbed EnCodec output: token grid over ``num_codebooks`` streams."""
    return jax.random.randint(
        rng, (batch, seq_len, cfg.frontend.num_codebooks), 0, cfg.vocab_size,
        dtype=jnp.int32)


def make_batch(rng, cfg: ModelConfig, batch: int, seq_len: int) -> dict:
    """A synthetic training batch honouring the arch's input contract."""
    k1, k2 = jax.random.split(rng)
    if cfg.frontend.kind == "audio":
        tokens = synth_audio_tokens(k1, cfg, batch, seq_len)
        labels = jnp.concatenate(
            [tokens[:, 1:], jnp.full((batch, 1, tokens.shape[2]), -1,
                                     jnp.int32)], axis=1)
        return {"tokens": tokens, "labels": labels}
    if cfg.frontend.kind == "vision":
        n_txt = seq_len - cfg.frontend.num_prefix_tokens
        assert n_txt > 0, "seq_len must exceed the vision prefix"
        tokens = jax.random.randint(k1, (batch, n_txt), 0, cfg.vocab_size,
                                    dtype=jnp.int32)
        labels = jnp.concatenate(
            [tokens[:, 1:], jnp.full((batch, 1), -1, jnp.int32)], axis=1)
        return {"tokens": tokens, "labels": labels,
                "image_embeds": synth_image_embeds(k2, cfg, batch)}
    tokens = jax.random.randint(k1, (batch, seq_len), 0, cfg.vocab_size,
                                dtype=jnp.int32)
    labels = jnp.concatenate(
        [tokens[:, 1:], jnp.full((batch, 1), -1, jnp.int32)], axis=1)
    return {"tokens": tokens, "labels": labels}
