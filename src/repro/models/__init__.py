"""Workload model zoo: the intelligence applications ACE hosts."""
from repro.models.model import LM
from repro.models.cnn import Classifier

__all__ = ["LM", "Classifier"]
