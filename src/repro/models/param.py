"""Parameter boxing: every parameter carries logical axis names at init.

Init functions build pytrees whose leaves are :class:`Boxed` (value + logical
axes). ``unbox`` splits them into a value pytree and an axes pytree with the
same structure; the launcher maps logical axes onto mesh axes (see
``repro.launch.sharding``). This keeps model code free of mesh knowledge.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

# Logical axis vocabulary (mapped to mesh axes in launch/sharding.py)
EMBED = "embed"        # d_model (contraction-side)
EMBED_OUT = "embed_out"  # d_model as an OUTPUT dim (w_down/wo); decode replicates it
VOCAB = "vocab"
HEADS = "heads"
KV_HEADS = "kv_heads"
HEAD_DIM = "head_dim"
MLP = "mlp"            # d_ff
EXPERT = "expert"
LRU = "lru"            # recurrent width
LORA = "lora"          # MLA low-rank dims
STACK = "stack"        # scan-stacked layer axis (never sharded)
NULL = None


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class Boxed:
    value: jnp.ndarray
    axes: Tuple[Optional[str], ...]

    def tree_flatten(self):
        return (self.value,), self.axes

    @classmethod
    def tree_unflatten(cls, axes, children):
        return cls(children[0], axes)


def box(value, axes) -> Boxed:
    assert len(axes) == value.ndim, (value.shape, axes)
    return Boxed(value, tuple(axes))


def is_boxed(x) -> bool:
    return isinstance(x, Boxed)


def unbox(tree):
    """Split a Boxed tree into (values, axes) trees of identical structure."""
    values = jax.tree.map(lambda b: b.value, tree, is_leaf=is_boxed)
    axes = jax.tree.map(lambda b: b.axes, tree, is_leaf=is_boxed)
    return values, axes


def stacked(axes_tree):
    """Prefix every axes tuple with the scan STACK axis (after vmap-init)."""
    return jax.tree.map(lambda ax: (STACK,) + tuple(ax),
                        axes_tree, is_leaf=lambda x: isinstance(x, tuple))


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------

def normal(rng, shape, dtype, stddev):
    return (stddev * jax.random.normal(rng, shape, jnp.float32)).astype(dtype)


def lecun(rng, shape, dtype, fan_in):
    return normal(rng, shape, dtype, fan_in ** -0.5)


def zeros(shape, dtype):
    return jnp.zeros(shape, dtype)


def ones(shape, dtype):
    return jnp.ones(shape, dtype)
