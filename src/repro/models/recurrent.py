"""Recurrent temporal mixers: RG-LRU (RecurrentGemma/Griffin), sLSTM and
mLSTM (xLSTM).

Training paths are parallel where the math allows it (associative scan for
RG-LRU, stabilized chunkwise form for mLSTM); sLSTM is inherently sequential
(hidden-state feedback into the gates) and uses ``lax.scan`` over time, as in
the xLSTM paper. Decode paths carry O(1) state — this is what makes these
families natively sub-quadratic for ``long_500k``.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import param as P
from repro.models.layers import rmsnorm

# ---------------------------------------------------------------------------
# RG-LRU (Griffin): h_t = a_t h_{t-1} + sqrt(1 - a_t^2) (i_t * x_t)
# ---------------------------------------------------------------------------

_RGLRU_C = 8.0


def rglru_block_init(rng, cfg, dtype) -> dict:
    d, w = cfg.d_model, cfg.resolved_lru_width
    cw = cfg.rglru_conv_width
    ks = jax.random.split(rng, 7)
    # Lambda init so that a = exp(-c*softplus(L)) lands in [0.9, 0.999]
    u = jax.random.uniform(ks[0], (w,), jnp.float32, 0.9 ** 2, 0.999 ** 2)
    lam = jnp.log(jnp.expm1(-jnp.log(u) / _RGLRU_C))
    return {
        "w_in_x": P.box(P.lecun(ks[1], (d, w), dtype, d), (P.EMBED, P.LRU)),
        "w_in_gate": P.box(P.lecun(ks[2], (d, w), dtype, d), (P.EMBED, P.LRU)),
        "conv_w": P.box(P.normal(ks[3], (cw, w), dtype, cw ** -0.5), (None, P.LRU)),
        "conv_b": P.box(P.zeros((w,), jnp.float32), (P.LRU,)),
        "w_rgate": P.box(P.lecun(ks[4], (w, w), dtype, w), (P.LRU, P.LRU)),
        "b_rgate": P.box(P.zeros((w,), jnp.float32), (P.LRU,)),
        "w_igate": P.box(P.lecun(ks[5], (w, w), dtype, w), (P.LRU, P.LRU)),
        "b_igate": P.box(P.zeros((w,), jnp.float32), (P.LRU,)),
        "lam": P.box(lam, (P.LRU,)),
        "w_out": P.box(P.lecun(ks[6], (w, d), dtype, w), (P.LRU, P.EMBED_OUT)),
    }


def _causal_conv(x, w, b):
    """Depthwise causal conv. x: (B, S, W); w: (cw, W)."""
    cw = w.shape[0]
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for i in range(cw):
        shifted = jnp.pad(x, ((0, 0), (i, 0), (0, 0)))[:, :x.shape[1]]
        out = out + shifted.astype(jnp.float32) * w[cw - 1 - i].astype(jnp.float32)
    return (out + b).astype(x.dtype)


def _conv_step(x1, prev, w, b):
    """One-step causal conv. x1: (B, 1, W); prev: (B, cw-1, W) past inputs."""
    cw = w.shape[0]
    buf = jnp.concatenate([prev, x1], axis=1)          # (B, cw, W)
    out = jnp.einsum("bcw,cw->bw", buf.astype(jnp.float32),
                     w.astype(jnp.float32)) + b
    return out.astype(x1.dtype)[:, None, :], buf[:, 1:]


def _rglru_gates(params, xc):
    r = jax.nn.sigmoid(jnp.einsum("bsw,wv->bsv", xc, params["w_rgate"])
                       .astype(jnp.float32) + params["b_rgate"])
    i = jax.nn.sigmoid(jnp.einsum("bsw,wv->bsv", xc, params["w_igate"])
                       .astype(jnp.float32) + params["b_igate"])
    log_a = -_RGLRU_C * jax.nn.softplus(params["lam"]) * r   # (B,S,W) f32
    a = jnp.exp(log_a)
    mult = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    gated_x = mult * i * xc.astype(jnp.float32)
    return a, gated_x


def rglru_scan_ref(a, bx, h0):
    """Oracle linear recurrence h_t = a_t h_{t-1} + bx_t via associative scan.

    a, bx: (B, S, W) f32; h0: (B, W). Returns (h_all (B,S,W), h_last)."""
    # fold h0 into the first step
    bx = bx.at[:, 0].add(a[:, 0] * h0)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    a_s, h = jax.lax.associative_scan(combine, (a, bx), axis=1)
    return h, h[:, -1]


def rglru_block_forward(params, cfg, x, h0=None, conv0=None):
    """Full-sequence Griffin recurrent block. x: (B, S, D)."""
    b, s, _ = x.shape
    w = cfg.resolved_lru_width
    gate = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", x, params["w_in_gate"])
                       .astype(jnp.float32), approximate=True)
    xin = jnp.einsum("bsd,dw->bsw", x, params["w_in_x"])
    xc = _causal_conv(xin, params["conv_w"], params["conv_b"])
    if conv0 is not None:  # resume from cached conv inputs (unused in train)
        pass
    a, bx = _rglru_gates(params, xc)
    h0 = jnp.zeros((b, w), jnp.float32) if h0 is None else h0
    h, h_last = rglru_scan_ref(a, bx, h0)
    y = (h * gate).astype(x.dtype)
    out = jnp.einsum("bsw,wd->bsd", y, params["w_out"])
    cw = cfg.rglru_conv_width
    conv_tail = xin[:, -(cw - 1):] if s >= cw - 1 else jnp.pad(
        xin, ((0, 0), (cw - 1 - s, 0), (0, 0)))
    return out, {"h": h_last, "conv": conv_tail}


def rglru_block_decode(params, cfg, x1, state) -> Tuple[jnp.ndarray, dict]:
    """One-step decode. x1: (B, 1, D); state {'h': (B,W), 'conv': (B,cw-1,W)}."""
    gate = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", x1, params["w_in_gate"])
                       .astype(jnp.float32), approximate=True)
    xin = jnp.einsum("bsd,dw->bsw", x1, params["w_in_x"])
    xc, conv_buf = _conv_step(xin, state["conv"], params["conv_w"],
                              params["conv_b"])
    a, bx = _rglru_gates(params, xc)
    h = a[:, 0] * state["h"] + bx[:, 0]
    y = (h[:, None, :] * gate).astype(x1.dtype)
    out = jnp.einsum("bsw,wd->bsd", y, params["w_out"])
    return out, {"h": h, "conv": conv_buf}


def rglru_state_spec(cfg, batch: int, dtype) -> dict:
    w = cfg.resolved_lru_width
    return {"h": jnp.zeros((batch, w), jnp.float32),
            "conv": jnp.zeros((batch, cfg.rglru_conv_width - 1, w), dtype)}


# ---------------------------------------------------------------------------
# mLSTM (xLSTM matrix memory) — stabilized
# ---------------------------------------------------------------------------

def mlstm_block_init(rng, cfg, dtype) -> dict:
    d, h, hd = cfg.d_model, cfg.num_heads, cfg.resolved_head_dim
    ks = jax.random.split(rng, 7)
    return {
        "norm": rmsnorm_init_(d),
        "wq": P.box(P.lecun(ks[0], (d, h, hd), dtype, d), (P.EMBED, P.HEADS, P.HEAD_DIM)),
        "wk": P.box(P.lecun(ks[1], (d, h, hd), dtype, d), (P.EMBED, P.HEADS, P.HEAD_DIM)),
        "wv": P.box(P.lecun(ks[2], (d, h, hd), dtype, d), (P.EMBED, P.HEADS, P.HEAD_DIM)),
        "w_if": P.box(P.lecun(ks[3], (d, h, 2), dtype, d), (P.EMBED, P.HEADS, None)),
        "b_if": P.box(jnp.concatenate([jnp.zeros((h, 1)),
                                       jnp.full((h, 1), 3.0)], -1).astype(jnp.float32),
                      (P.HEADS, None)),
        "w_ogate": P.box(P.lecun(ks[4], (d, h, hd), dtype, d), (P.EMBED, P.HEADS, P.HEAD_DIM)),
        "gn_scale": P.box(P.zeros((h, hd), jnp.float32), (P.HEADS, P.HEAD_DIM)),
        "w_out": P.box(P.lecun(ks[5], (h, hd, d), dtype, h * hd), (P.HEADS, P.HEAD_DIM, P.EMBED_OUT)),
    }


def rmsnorm_init_(d):
    return {"scale": P.box(P.zeros((d,), jnp.float32), (P.EMBED,))}


def _headnorm(x, scale, eps):
    """Per-head RMS norm. x: (B, S, H, hd)."""
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps) * (1.0 + scale)).astype(dt)


def mlstm_cell_ref(q, k, v, log_i, log_f, state=None):
    """Sequential stabilized mLSTM (oracle + decode path).

    q,k,v: (B, S, H, hd); log_i/log_f: (B, S, H) f32.
    state: {'C': (B,H,hd,hd), 'n': (B,H,hd), 'm': (B,H)} or None.
    Returns h: (B, S, H, hd) f32, final state.
    """
    b, s, h, hd = q.shape
    if state is None:
        state = mlstm_state_init(b, h, hd)
    scale = hd ** -0.5

    def step(carry, xs):
        C, n, m = carry
        qt, kt, vt, li, lf = xs
        m_new = jnp.maximum(lf + m, li)
        i_ = jnp.exp(li - m_new)[..., None]
        f_ = jnp.exp(lf + m - m_new)[..., None]
        C_new = f_[..., None] * C + i_[..., None] * (vt[..., :, None] * kt[..., None, :])
        n_new = f_ * n + i_ * kt
        qs = qt * scale
        num = jnp.einsum("bhvk,bhk->bhv", C_new, qs)
        den = jnp.abs(jnp.einsum("bhk,bhk->bh", n_new, qs))
        den = jnp.maximum(den, jnp.exp(-m_new))
        ht = num / den[..., None]
        return (C_new, n_new, m_new), ht

    xs = (jnp.moveaxis(q, 1, 0).astype(jnp.float32),
          jnp.moveaxis(k, 1, 0).astype(jnp.float32),
          jnp.moveaxis(v, 1, 0).astype(jnp.float32),
          jnp.moveaxis(log_i, 1, 0), jnp.moveaxis(log_f, 1, 0))
    (C, n, m), hs = jax.lax.scan(step, (state["C"], state["n"], state["m"]), xs)
    return jnp.moveaxis(hs, 0, 1), {"C": C, "n": n, "m": m}


def mlstm_cell_chunkwise(q, k, v, log_i, log_f, state=None, chunk: int = 64):
    """Stabilized chunkwise-parallel mLSTM (training path).

    Identical math to :func:`mlstm_cell_ref` (validated in tests); wall-clock
    scales as S/chunk sequential steps of parallel intra-chunk attention-like
    compute — the TPU-friendly formulation (cf. TFLA / xLSTM kernels).
    """
    b, s, h, hd = q.shape
    if state is None:
        state = mlstm_state_init(b, h, hd)
    pad = (-s) % chunk
    if pad:
        zf = lambda x: jnp.pad(x, ((0, 0), (0, pad)) + ((0, 0),) * (x.ndim - 2))
        q, k, v = zf(q), zf(k), zf(v)
        log_i = jnp.pad(log_i, ((0, 0), (0, pad), (0, 0)))
        # padded steps must not decay state: log_f = 0, log_i = -inf
        log_i = log_i.at[:, s:].set(-1e30)
        log_f = jnp.pad(log_f, ((0, 0), (0, pad), (0, 0)))
    nc = q.shape[1] // chunk
    rs = lambda x: jnp.moveaxis(
        x.reshape((b, nc, chunk) + x.shape[2:]), 1, 0)
    qc, kc, vc = rs(q.astype(jnp.float32)), rs(k.astype(jnp.float32)), rs(v.astype(jnp.float32))
    lic, lfc = rs(log_i), rs(log_f)
    scale = hd ** -0.5

    def chunk_step(carry, xs):
        C, n, m = carry                       # (B,H,hd,hd), (B,H,hd), (B,H)
        qt, kt, vt, li, lf = xs               # (B,chunk,H,*)
        li = jnp.moveaxis(li, 1, 2)           # (B,H,T)
        lf = jnp.moveaxis(lf, 1, 2)
        F = jnp.cumsum(lf, axis=-1)           # sum of log_f over (0, t]
        u = li - F                            # (B,H,T)
        cmax = jax.lax.cummax(u, axis=2)
        m_t = F + jnp.maximum(m[..., None], cmax)          # (B,H,T)
        # inter-chunk: q_t . C_prev * exp(m_prev + F_t - m_t)
        qh = jnp.moveaxis(qt, 1, 2) * scale                # (B,H,T,hd)
        kh = jnp.moveaxis(kt, 1, 2)
        vh = jnp.moveaxis(vt, 1, 2)
        inter_w = jnp.exp(m[..., None] + F - m_t)          # (B,H,T)
        num_inter = jnp.einsum("bhtk,bhvk->bhtv", qh, C) * inter_w[..., None]
        den_inter = jnp.einsum("bhtk,bhk->bht", qh, n) * inter_w
        # intra-chunk: w_{t,j} = exp(F_t - F_j + li_j - m_t) for j <= t
        wmat = jnp.exp(u[:, :, None, :] - (m_t - F)[..., None])  # (B,H,T,J)
        tri = jnp.tril(jnp.ones((qt.shape[1], qt.shape[1]), jnp.float32))
        wmat = wmat * tri
        sc = jnp.einsum("bhtk,bhjk->bhtj", qh, kh) * wmat
        num = num_inter + jnp.einsum("bhtj,bhjv->bhtv", sc, vh)
        den_dot = den_inter + jnp.einsum("bhtj,bhjk,bhtk->bht", wmat, kh, qh)
        den_fin = jnp.maximum(jnp.abs(den_dot), jnp.exp(-m_t))
        ht = num / den_fin[..., None]                      # (B,H,T,hd)
        # state update to end of chunk
        T = qt.shape[1]
        m_last = m_t[..., -1]
        carry_decay = jnp.exp(m[..., None] + F[..., -1:] - m_last[..., None])
        wj = jnp.exp(F[..., -1:] - F + li - m_last[..., None])  # (B,H,T)
        C_new = C * carry_decay[..., None] + jnp.einsum(
            "bhj,bhjv,bhjk->bhvk", wj, vh, kh)
        n_new = n * carry_decay + jnp.einsum("bhj,bhjk->bhk", wj, kh)
        return (C_new, n_new, m_last), jnp.moveaxis(ht, 2, 1)

    (C, n, m), hs = jax.lax.scan(
        chunk_step, (state["C"], state["n"], state["m"]), (qc, kc, vc, lic, lfc))
    hs = jnp.moveaxis(hs, 0, 1).reshape(b, nc * chunk, h, hd)
    return hs[:, :s], {"C": C, "n": n, "m": m}


def mlstm_state_init(batch: int, heads: int, head_dim: int) -> dict:
    return {"C": jnp.zeros((batch, heads, head_dim, head_dim), jnp.float32),
            "n": jnp.zeros((batch, heads, head_dim), jnp.float32),
            "m": jnp.full((batch, heads), 0.0, jnp.float32)}


def _mlstm_inputs(params, cfg, x):
    xn = rmsnorm(params["norm"], x, cfg.rms_eps)
    q = jnp.einsum("bsd,dhk->bshk", xn, params["wq"])
    k = jnp.einsum("bsd,dhk->bshk", xn, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", xn, params["wv"])
    gif = jnp.einsum("bsd,dhg->bshg", xn, params["w_if"]).astype(jnp.float32)
    gif = gif + params["b_if"]
    log_i = gif[..., 0]
    log_f = -jax.nn.softplus(-gif[..., 1])   # log sigmoid
    o = jax.nn.sigmoid(jnp.einsum("bsd,dhk->bshk", xn, params["w_ogate"])
                       .astype(jnp.float32))
    return xn, q, k, v, log_i, log_f, o


def mlstm_block_forward(params, cfg, x, state=None, chunk: int = 64):
    _, q, k, v, log_i, log_f, o = _mlstm_inputs(params, cfg, x)
    h, new_state = mlstm_cell_chunkwise(q, k, v, log_i, log_f, state, chunk)
    h = _headnorm(h, params["gn_scale"], cfg.rms_eps) * o.astype(h.dtype)
    out = jnp.einsum("bshk,hkd->bsd", h.astype(x.dtype), params["w_out"])
    return out, new_state


def mlstm_block_decode(params, cfg, x1, state):
    _, q, k, v, log_i, log_f, o = _mlstm_inputs(params, cfg, x1)
    h, new_state = mlstm_cell_ref(q, k, v, log_i, log_f, state)
    h = _headnorm(h, params["gn_scale"], cfg.rms_eps) * o.astype(h.dtype)
    out = jnp.einsum("bshk,hkd->bsd", h.astype(x1.dtype), params["w_out"])
    return out, new_state


# ---------------------------------------------------------------------------
# sLSTM (xLSTM scalar memory, recurrent gate feedback -> sequential)
# ---------------------------------------------------------------------------

def slstm_block_init(rng, cfg, dtype) -> dict:
    d, h, hd = cfg.d_model, cfg.num_heads, cfg.resolved_head_dim
    ks = jax.random.split(rng, 5)
    inner = h * hd          # sLSTM hidden width (may differ from d_model)
    dff = int(2 * d)
    wx = P.normal(ks[0], (d, 4, h, hd), dtype, d ** -0.5)
    rh = P.normal(ks[1], (4, h, hd, hd), dtype, hd ** -0.5)
    bias = jnp.zeros((4, h, hd), jnp.float32).at[2].set(3.0)  # forget-gate bias
    return {
        "norm": rmsnorm_init_(d),
        "wx": P.box(wx, (P.EMBED, None, P.HEADS, P.HEAD_DIM)),
        "rh": P.box(rh, (None, P.HEADS, P.HEAD_DIM, P.HEAD_DIM)),
        "bias": P.box(bias, (None, P.HEADS, P.HEAD_DIM)),
        "gn_scale": P.box(P.zeros((h, hd), jnp.float32), (P.HEADS, P.HEAD_DIM)),
        "w_up1": P.box(P.lecun(ks[2], (inner, dff), dtype, inner), (None, P.MLP)),
        "w_up2": P.box(P.lecun(ks[3], (inner, dff), dtype, inner), (None, P.MLP)),
        "w_down": P.box(P.lecun(ks[4], (dff, d), dtype, dff), (P.MLP, P.EMBED_OUT)),
    }


def slstm_cell(params, zx, state):
    """One sLSTM step. zx: (B, 4, H, hd) pre-activations from x; state dict."""
    c, n, hprev, m = state["c"], state["n"], state["h"], state["m"]
    rec = jnp.einsum("bhk,ghkv->bghv", hprev, params["rh"].astype(jnp.float32))
    pre = zx.astype(jnp.float32) + rec + params["bias"]
    z = jnp.tanh(pre[:, 0])
    i_t = pre[:, 1]
    f_t = pre[:, 2]
    o = jax.nn.sigmoid(pre[:, 3])
    lf = -jax.nn.softplus(-f_t)               # log sigmoid(f)
    m_new = jnp.maximum(lf + m, i_t)
    i_ = jnp.exp(i_t - m_new)
    f_ = jnp.exp(lf + m - m_new)
    c_new = f_ * c + i_ * z
    n_new = f_ * n + i_
    h_new = o * c_new / jnp.maximum(n_new, 1.0)
    return {"c": c_new, "n": n_new, "h": h_new, "m": m_new}, h_new


def slstm_state_init(batch: int, heads: int, head_dim: int) -> dict:
    z = lambda: jnp.zeros((batch, heads, head_dim), jnp.float32)
    return {"c": z(), "n": z(), "h": z(),
            "m": jnp.zeros((batch, heads, head_dim), jnp.float32)}


def slstm_block_forward(params, cfg, x, state=None):
    b, s, d = x.shape
    h, hd = cfg.num_heads, cfg.resolved_head_dim
    xn = rmsnorm(params["norm"], x, cfg.rms_eps)
    zx = jnp.einsum("bsd,dghk->bsghk", xn, params["wx"])
    if state is None:
        state = slstm_state_init(b, h, hd)

    def step(carry, z_t):
        new_state, h_t = slstm_cell(params, z_t, carry)
        return new_state, h_t

    state, hs = jax.lax.scan(step, state, jnp.moveaxis(zx, 1, 0))
    hs = jnp.moveaxis(hs, 0, 1)               # (B, S, H, hd)
    hs = _headnorm(hs, params["gn_scale"], cfg.rms_eps)
    y = hs.reshape(b, s, h * hd).astype(x.dtype)
    # internal GeGLU projection (the sLSTM block's post-FFN; d_ff=0 means
    # no *separate* MLP block in the stack)
    g = jax.nn.gelu(jnp.einsum("bsd,df->bsf", y, params["w_up1"])
                    .astype(jnp.float32), approximate=True)
    u = jnp.einsum("bsd,df->bsf", y, params["w_up2"])
    out = jnp.einsum("bsf,fd->bsd", (g.astype(x.dtype) * u), params["w_down"])
    return out, state


def slstm_block_decode(params, cfg, x1, state):
    out, new_state = slstm_block_forward(params, cfg, x1, state)
    return out, new_state
