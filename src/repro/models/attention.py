"""Attention mixers: GQA (optionally sliding-window, qk-norm) and MLA.

Full-sequence attention uses a blockwise streaming-softmax formulation
(flash-attention semantics) so that S x S score matrices are never
materialized — required for ``prefill_32k`` to fit. On TPU the inner loop is
replaced by the Pallas kernel (``repro.kernels``); this jnp version is the
oracle and the CPU/dry-run path.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro import sharding as sh
from repro.models import param as P
from repro.models.layers import norm_only, rope

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Blockwise (flash) attention core
# ---------------------------------------------------------------------------

def blockwise_attention(q, k, v, q_pos, k_pos, *, window: Optional[int],
                        scale: float, kv_chunk: int = 1024):
    """Causal (optionally windowed) attention with streaming softmax.

    q: (B, Sq, H, hd); k, v: (B, Sk, KV, hd); q_pos: (B, Sq); k_pos: (B, Sk).
    KV positions < 0 mark empty cache slots. H % KV == 0 (GQA groups).
    Returns (B, Sq, H, hd).

    Flash-attention memory semantics in BOTH directions: forward keeps only
    the (m, l) streaming stats; backward (custom_vjp) recomputes the score
    chunks instead of saving per-chunk softmax tensors — without this, the
    scan's default vjp stashes O(S * chunk) f32 intermediates per layer and
    the train_4k dry-runs blow past HBM.
    """
    b, sq, h, hd = q.shape
    sk, kv = k.shape[1], k.shape[2]
    g = h // kv
    qg = q.reshape(b, sq, kv, g, hd)
    if (window is not None and sq == sk and sk >= 4 * window
            and sq % _band_qchunk(window) == 0):
        # banded path: O(S*window) instead of O(S^2) flops/HBM — the
        # kv-chunk scan below cannot skip fully-masked chunks (§Perf B1)
        out = _banded(qg, k, v, q_pos, k_pos, window, scale)
    else:
        out = _flash(qg, k, v, q_pos, k_pos, window, scale,
                     min(kv_chunk, k.shape[1]))
    return out.reshape(b, sq, h, hd).astype(q.dtype)


def _band_qchunk(window: int) -> int:
    return min(window, 512)


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6))
def _banded(qg, k, v, q_pos, k_pos, window, scale):
    out, _ = _banded_fwd_impl(qg, k, v, q_pos, k_pos, window, scale)
    return out


def _banded_chunks(qg, k, v, q_pos, k_pos, window):
    """Per-q-chunk views plus the KV band start index for each chunk."""
    b, sq, kvh, g, hd = qg.shape
    cq = _band_qchunk(window)
    nq = sq // cq
    band = window + cq           # covers [first_q - window + 1, last_q]
    starts = jnp.maximum(jnp.arange(nq) * cq + cq - band, 0)  # clamp at 0
    return cq, nq, band, starts


def _banded_fwd_impl(qg, k, v, q_pos, k_pos, window, scale):
    b, sq, kvh, g, hd = qg.shape
    cq, nq, band, starts = _banded_chunks(qg, k, v, q_pos, k_pos, window)
    qc = jnp.moveaxis(qg.reshape(b, nq, cq, kvh, g, hd), 1, 0)
    qpc = jnp.moveaxis(q_pos.reshape(b, nq, cq), 1, 0)

    def one(args):
        qb, qpb, start = args
        kb = jax.lax.dynamic_slice_in_dim(k, start, band, axis=1)
        vb = jax.lax.dynamic_slice_in_dim(v, start, band, axis=1)
        pb = jax.lax.dynamic_slice_in_dim(k_pos, start, band, axis=1)
        s = jnp.einsum("bqkgd,bckd->bqkgc", qb, kb,
                       preferred_element_type=jnp.float32) * scale
        valid = (pb[:, None, :] <= qpb[:, :, None]) & (pb[:, None, :] >= 0)
        valid &= pb[:, None, :] > (qpb[:, :, None] - window)
        s = jnp.where(valid[:, :, None, None, :], s, NEG_INF)
        m = jnp.max(s, axis=-1)
        p = jnp.exp(s - m[..., None])
        l = jnp.sum(p, axis=-1)
        o = jnp.einsum("bqkgc,bckd->bqkgd", p.astype(vb.dtype), vb,
                       preferred_element_type=jnp.float32)
        o = o / jnp.maximum(l[..., None], 1e-30)
        return o.astype(qg.dtype), m + jnp.log(jnp.maximum(l, 1e-30))

    outs, lses = jax.lax.map(one, (qc, qpc, starts))
    out = jnp.moveaxis(outs, 0, 1).reshape(b, sq, kvh, g, hd)
    lse = jnp.moveaxis(lses, 0, 1).reshape(b, sq, kvh, g)
    return out, lse


def _banded_fwd(qg, k, v, q_pos, k_pos, window, scale):
    out, lse = _banded_fwd_impl(qg, k, v, q_pos, k_pos, window, scale)
    return out, (qg, k, v, q_pos, k_pos, out, lse)


def _banded_bwd(window, scale, res, do):
    qg, k, v, q_pos, k_pos, out, lse = res
    b, sq, kvh, g, hd = qg.shape
    sk = k.shape[1]
    cq, nq, band, starts = _banded_chunks(qg, k, v, q_pos, k_pos, window)
    do32 = do.astype(jnp.float32)
    delta = jnp.sum(do32 * out.astype(jnp.float32), axis=-1)
    qc = jnp.moveaxis(qg.reshape(b, nq, cq, kvh, g, hd), 1, 0)
    qpc = jnp.moveaxis(q_pos.reshape(b, nq, cq), 1, 0)
    doc = jnp.moveaxis(do32.reshape(b, nq, cq, kvh, g, hd), 1, 0)
    lsec = jnp.moveaxis(lse.reshape(b, nq, cq, kvh, g), 1, 0)
    dc = jnp.moveaxis(delta.reshape(b, nq, cq, kvh, g), 1, 0)

    def step(carry, xs):
        dk_acc, dv_acc = carry
        qb, qpb, dob, lseb, db, start = xs
        kb = jax.lax.dynamic_slice_in_dim(k, start, band, axis=1)
        vb = jax.lax.dynamic_slice_in_dim(v, start, band, axis=1)
        pb = jax.lax.dynamic_slice_in_dim(k_pos, start, band, axis=1)
        s = jnp.einsum("bqkgd,bckd->bqkgc", qb, kb,
                       preferred_element_type=jnp.float32) * scale
        valid = (pb[:, None, :] <= qpb[:, :, None]) & (pb[:, None, :] >= 0)
        valid &= pb[:, None, :] > (qpb[:, :, None] - window)
        s = jnp.where(valid[:, :, None, None, :], s, NEG_INF)
        p = jnp.exp(s - lseb[..., None])
        dv_c = jnp.einsum("bqkgc,bqkgd->bckd", p, dob)
        dp = jnp.einsum("bqkgd,bckd->bqkgc", dob, vb.astype(jnp.float32))
        ds = p * (dp - db[..., None]) * scale
        dq_c = jnp.einsum("bqkgc,bckd->bqkgd", ds, kb.astype(jnp.float32))
        dk_c = jnp.einsum("bqkgc,bqkgd->bckd", ds, qb.astype(jnp.float32))
        dk_acc = jax.lax.dynamic_update_slice_in_dim(
            dk_acc, jax.lax.dynamic_slice_in_dim(dk_acc, start, band, 1)
            + dk_c, start, axis=1)
        dv_acc = jax.lax.dynamic_update_slice_in_dim(
            dv_acc, jax.lax.dynamic_slice_in_dim(dv_acc, start, band, 1)
            + dv_c, start, axis=1)
        return (dk_acc, dv_acc), dq_c

    dk0 = jnp.zeros(k.shape, jnp.float32)
    dv0 = jnp.zeros(v.shape, jnp.float32)
    (dk, dv), dqs = jax.lax.scan(step, (dk0, dv0),
                                 (qc, qpc, doc, lsec, dc, starts))
    dq = jnp.moveaxis(dqs, 0, 1).reshape(b, sq, kvh, g, hd)
    return (dq.astype(qg.dtype), dk.astype(k.dtype), dv.astype(v.dtype),
            None, None)


_banded.defvjp(_banded_fwd, _banded_bwd)


def _chunked(k, v, k_pos, kv_chunk: int):
    b = k.shape[0]
    sk, kv, hd = k.shape[1], k.shape[2], k.shape[3]
    nchunks = -(-sk // kv_chunk)
    pad = nchunks * kv_chunk - sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, ((0, 0), (0, pad)), constant_values=-1)
    kc = jnp.moveaxis(k.reshape(b, nchunks, kv_chunk, kv, hd), 1, 0)
    vc = jnp.moveaxis(v.reshape(b, nchunks, kv_chunk, kv, hd), 1, 0)
    pc = jnp.moveaxis(k_pos.reshape(b, nchunks, kv_chunk), 1, 0)
    return kc, vc, pc, pad


def _mask(pb, q_pos, window):
    valid = (pb[:, None, :] <= q_pos[:, :, None]) & (pb[:, None, :] >= 0)
    if window is not None:
        valid &= pb[:, None, :] > (q_pos[:, :, None] - window)
    return valid[:, :, None, None, :]


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6, 7))
def _flash(qg, k, v, q_pos, k_pos, window, scale, kv_chunk):
    out, _ = _flash_fwd_impl(qg, k, v, q_pos, k_pos, window, scale, kv_chunk)
    return out


def _flash_fwd_impl(qg, k, v, q_pos, k_pos, window, scale, kv_chunk):
    b, sq, kv, g, hd = qg.shape
    kc, vc, pc, _ = _chunked(k, v, k_pos, kv_chunk)

    def step(carry, inputs):
        acc, m, l = carry
        kb, vb, pb = inputs
        s = jnp.einsum("bqkgd,bckd->bqkgc", qg, kb,
                       preferred_element_type=jnp.float32) * scale
        s = jnp.where(_mask(pb, q_pos, window), s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bqkgc,bckd->bqkgd", p.astype(vb.dtype), vb,
                        preferred_element_type=jnp.float32)
        acc_new = acc * alpha[..., None] + pv
        return (acc_new, m_new, l_new), None

    acc0 = jnp.zeros((b, sq, kv, g, hd), jnp.float32)
    m0 = jnp.full((b, sq, kv, g), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, sq, kv, g), jnp.float32)
    (acc, m, l), _ = jax.lax.scan(step, (acc0, m0, l0), (kc, vc, pc))
    lsafe = jnp.maximum(l, 1e-30)
    out = (acc / lsafe[..., None]).astype(qg.dtype)
    lse = m + jnp.log(lsafe)                      # (B, Sq, KV, G)
    return out, lse


def _flash_fwd(qg, k, v, q_pos, k_pos, window, scale, kv_chunk):
    out, lse = _flash_fwd_impl(qg, k, v, q_pos, k_pos, window, scale,
                               kv_chunk)
    return out, (qg, k, v, q_pos, k_pos, out, lse)


def _flash_bwd(window, scale, kv_chunk, res, do):
    qg, k, v, q_pos, k_pos, out, lse = res
    b, sq, kv, g, hd = qg.shape
    sk = k.shape[1]
    kc, vc, pc, pad = _chunked(k, v, k_pos, kv_chunk)
    do32 = do.astype(jnp.float32)
    delta = jnp.sum(do32 * out.astype(jnp.float32), axis=-1)  # (B,Sq,KV,G)

    def step(dq, inputs):
        kb, vb, pb = inputs
        s = jnp.einsum("bqkgd,bckd->bqkgc", qg, kb,
                       preferred_element_type=jnp.float32) * scale
        s = jnp.where(_mask(pb, q_pos, window), s, NEG_INF)
        p = jnp.exp(s - lse[..., None])                        # (B,Sq,KV,G,C)
        dv_c = jnp.einsum("bqkgc,bqkgd->bckd", p, do32)
        dp = jnp.einsum("bqkgd,bckd->bqkgc", do32,
                        vb.astype(jnp.float32))
        ds = p * (dp - delta[..., None]) * scale
        dq = dq + jnp.einsum("bqkgc,bckd->bqkgd", ds,
                             kb.astype(jnp.float32))
        dk_c = jnp.einsum("bqkgc,bqkgd->bckd", ds,
                          qg.astype(jnp.float32))
        return dq, (dk_c, dv_c)

    dq0 = jnp.zeros((b, sq, kv, g, hd), jnp.float32)
    dq, (dk_c, dv_c) = jax.lax.scan(step, dq0, (kc, vc, pc))
    nchunks = dk_c.shape[0]
    dk = jnp.moveaxis(dk_c, 0, 1).reshape(b, nchunks * kv_chunk, kv, hd)
    dv = jnp.moveaxis(dv_c, 0, 1).reshape(b, nchunks * kv_chunk, kv, hd)
    if pad:
        dk, dv = dk[:, :sk], dv[:, :sk]
    return (dq.astype(qg.dtype), dk.astype(k.dtype), dv.astype(v.dtype),
            None, None)


_flash.defvjp(_flash_fwd, _flash_bwd)


def decode_attention(q, k, v, q_pos, k_pos, *, window: Optional[int],
                     scale: float, use_kernel: Optional[bool] = None,
                     interpret: Optional[bool] = None):
    """Single-step attention: q (B, 1, H, hd) against the whole cache.

    q_pos: (B,) per-request positions; k_pos: (B, W) ring-slot positions
    (−1 = empty). Dispatch (Pallas kernel on TPU, jnp oracle on CPU,
    ``use_kernel=True`` + ``interpret=True`` for kernel-body tests) lives
    in ``repro.kernels.ops.decode_attn``; imported lazily because
    ``kernels.ref`` imports this module for the flash oracle.
    """
    from repro.kernels.ops import decode_attn
    return decode_attn(q, k, v, q_pos, k_pos, window=window, scale=scale,
                       use_kernel=use_kernel, interpret=interpret)


# ---------------------------------------------------------------------------
# KV cache
# ---------------------------------------------------------------------------

def init_kv_cache(batch: int, width: int, kv_heads: int, head_dim: int,
                  dtype) -> dict:
    return {
        "k": jnp.zeros((batch, width, kv_heads, head_dim), dtype),
        "v": jnp.zeros((batch, width, kv_heads, head_dim), dtype),
        "pos": jnp.full((batch, width), -1, jnp.int32),
    }


def positions_1d(cur_pos, batch: int) -> jnp.ndarray:
    """Normalize a scalar-or-(B,) decode position to (B,) int32.

    Continuous batching gives every slot its own position; the single-stream
    callers (tests, dry-run lowering) still pass a scalar.
    """
    return jnp.broadcast_to(jnp.asarray(cur_pos, jnp.int32), (batch,))


def _ring_layout():
    """The default cache layout. Imported lazily: the layout/backend API
    lives in ``repro.serving.kv_cache`` (engine-facing), and this module is
    imported while that package initializes."""
    from repro.serving.kv_cache import RING
    return RING


def cache_write(cache: dict, k1, v1, cur_pos) -> dict:
    """Write one step (B, 1, KV, hd) at per-request ring slot
    ``cur_pos % width``. ``cur_pos``: scalar or (B,)."""
    return _ring_layout().append(cache, {"k": k1, "v": v1}, cur_pos)


def _fill_slots(width: int, b: int, s: int, lengths):
    """Ring-fill bookkeeping shared by GQA and MLA prefill caches.

    Keeps each row's trailing ``width`` *real* positions
    (``[length - width, length)``), ring-ordered by ``t % width``; everything
    else — right-pads and evicted older tokens — routes to out-of-bounds
    index ``width`` so the scatter drops it. Without per-row lengths a
    bucket-padded prompt through a ``window``-wide cache used to keep the
    trailing window of the *padded* sequence: real in-window tokens were
    evicted by pad rows, silently corrupting windowed decode.
    Returns (rows (B, 1), slot (B, S), pos_val (B, S))."""
    t = jnp.arange(s, dtype=jnp.int32)[None, :]
    if lengths is None:
        length = jnp.full((b, 1), s, jnp.int32)
    else:
        length = jnp.asarray(lengths, jnp.int32).reshape(b, 1)
    keep = (t >= length - width) & (t < length)
    slot = jnp.where(keep, t % width, width)           # width = dropped
    rows = jnp.arange(b)[:, None]
    return rows, slot, jnp.broadcast_to(t, (b, s))


def cache_fill(cache: dict, k, v, seq_len: int, lengths=None) -> dict:
    """Populate a cache from prefill outputs k, v: (B, S, KV, hd).
    ``lengths``: optional (B,) true prompt lengths — positions ≥ length are
    right-pad and must never occupy a ring slot (see ``_fill_slots``).
    Callers pass lengths when any layer is windowed (width < padded
    sequence); unwindowed installs keep the cheaper contiguous write, whose
    pad entries the decode stream provably overwrites before visibility."""
    width = cache["k"].shape[1]
    b, s = k.shape[0], k.shape[1]
    if lengths is None and s <= width:
        kw = cache["k"].at[:, :s].set(k)
        vw = cache["v"].at[:, :s].set(v)
        pos = cache["pos"].at[:, :s].set(jnp.arange(s)[None, :])
        return {"k": kw, "v": vw, "pos": pos}
    rows, slot, pos_val = _fill_slots(width, b, s, lengths)
    kw = jnp.zeros_like(cache["k"]).at[rows, slot].set(k)
    vw = jnp.zeros_like(cache["v"]).at[rows, slot].set(v)
    pos = jnp.full((b, width), -1, jnp.int32).at[rows, slot].set(pos_val)
    return {"k": kw, "v": vw, "pos": pos}


# ---------------------------------------------------------------------------
# GQA attention layer
# ---------------------------------------------------------------------------

def attn_init(rng, cfg, dtype) -> dict:
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    k1, k2, k3, k4 = jax.random.split(rng, 4)
    params = {
        "wq": P.box(P.lecun(k1, (d, h, hd), dtype, d), (P.EMBED, P.HEADS, P.HEAD_DIM)),
        "wk": P.box(P.lecun(k2, (d, kv, hd), dtype, d), (P.EMBED, P.KV_HEADS, P.HEAD_DIM)),
        "wv": P.box(P.lecun(k3, (d, kv, hd), dtype, d), (P.EMBED, P.KV_HEADS, P.HEAD_DIM)),
        "wo": P.box(P.lecun(k4, (h, hd, d), dtype, h * hd), (P.HEADS, P.HEAD_DIM, P.EMBED_OUT)),
    }
    if cfg.use_qk_norm:
        params["q_scale"] = P.box(P.zeros((hd,), jnp.float32), (P.HEAD_DIM,))
        params["k_scale"] = P.box(P.zeros((hd,), jnp.float32), (P.HEAD_DIM,))
    return params


def _qkv(params, cfg, x, positions):
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
    if cfg.use_qk_norm:
        q = norm_only(q, cfg.rms_eps) * (1.0 + params["q_scale"]).astype(q.dtype)
        k = norm_only(k, cfg.rms_eps) * (1.0 + params["k_scale"]).astype(k.dtype)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def attn_forward(params, cfg, x, positions, *, window: Optional[int],
                 kv_chunk: int = 1024):
    """Full-sequence causal attention. x: (B, S, D); positions: (B, S)."""
    q, k, v = _qkv(params, cfg, x, positions)
    # attention wants full sequences and sharded heads — SEQ deliberately
    # absent (under sequence parallelism the AG/RS boundary sits here)
    q = sh.hint(q, (sh.BATCH, None, sh.HEADS, None))
    k = sh.hint(k, (sh.BATCH, None, sh.KV, None))
    scale = cfg.resolved_head_dim ** -0.5
    out = blockwise_attention(q, k, v, positions, positions, window=window,
                              scale=scale, kv_chunk=kv_chunk)
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"])
    return y, (k, v)


def attn_decode(params, cfg, x, cache, cur_pos, *, window: Optional[int],
                layout=None, block_tables=None, valid=None):
    """Cached-attention step: one decode token or a T-token prompt chunk.
    x: (B, T, D); ``cur_pos``: scalar or (B,) per-request *start* positions
    (token i of the chunk sits at position ``cur_pos + i``); ``valid``:
    optional (B, T) write mask (False = right-pad / inactive slot — the
    token neither lands in the cache nor matters downstream). ``layout`` is
    a KV-cache layout from ``repro.serving.kv_cache`` (None = ring); for
    the paged layout ``cache`` is the (N, bs, ...) block pool and
    ``block_tables`` (B, M) maps each request's logical blocks to pool
    blocks. Append happens *before* attend, so intra-chunk causality is
    ordinary position masking."""
    layout = _ring_layout() if layout is None else layout
    b, t = x.shape[0], x.shape[1]
    start = positions_1d(cur_pos, b)
    positions = start[:, None] + jnp.arange(t, dtype=jnp.int32)[None, :]
    q, k1, v1 = _qkv(params, cfg, x, positions)
    # decode shards along heads (model axis) — the KV pool carries the same
    # split on its kv-head dim, so append/attend stay shard-local per head
    q = sh.hint(q, (sh.BATCH, None, sh.HEADS, None))
    k1 = sh.hint(k1, (sh.BATCH, None, sh.KV, None))
    v1 = sh.hint(v1, (sh.BATCH, None, sh.KV, None))
    cache = layout.append(cache, {"k": k1, "v": v1}, start, block_tables,
                          valid=valid)
    out = layout.attend(q, cache, positions, block_tables,
                        window=window, scale=cfg.resolved_head_dim ** -0.5)
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"])
    return y, cache


def attn_cache_spec(cfg, batch: int, seq_len: int, window: Optional[int],
                    dtype):
    width = min(seq_len, window) if window else seq_len
    return init_kv_cache(batch, width, cfg.num_kv_heads,
                         cfg.resolved_head_dim, dtype)


# ---------------------------------------------------------------------------
# MLA (DeepSeek multi-head latent attention)
# ---------------------------------------------------------------------------

def mla_init(rng, cfg, dtype) -> dict:
    m = cfg.mla
    d, h = cfg.d_model, cfg.num_heads
    qk = m.qk_nope_head_dim + m.qk_rope_head_dim
    ks = jax.random.split(rng, 7)
    return {
        "w_dq": P.box(P.lecun(ks[0], (d, m.q_lora_rank), dtype, d), (P.EMBED, P.LORA)),
        "q_norm": P.box(P.zeros((m.q_lora_rank,), jnp.float32), (P.LORA,)),
        "w_uq": P.box(P.lecun(ks[1], (m.q_lora_rank, h, qk), dtype, m.q_lora_rank),
                      (P.LORA, P.HEADS, P.HEAD_DIM)),
        "w_dkv": P.box(P.lecun(ks[2], (d, m.kv_lora_rank), dtype, d), (P.EMBED, P.LORA)),
        "kv_norm": P.box(P.zeros((m.kv_lora_rank,), jnp.float32), (P.LORA,)),
        "w_krope": P.box(P.lecun(ks[3], (d, m.qk_rope_head_dim), dtype, d),
                         (P.EMBED, P.HEAD_DIM)),
        "w_uk": P.box(P.lecun(ks[4], (m.kv_lora_rank, h, m.qk_nope_head_dim),
                              dtype, m.kv_lora_rank), (P.LORA, P.HEADS, P.HEAD_DIM)),
        "w_uv": P.box(P.lecun(ks[5], (m.kv_lora_rank, h, m.v_head_dim),
                              dtype, m.kv_lora_rank), (P.LORA, P.HEADS, P.HEAD_DIM)),
        "wo": P.box(P.lecun(ks[6], (h, m.v_head_dim, d), dtype, h * m.v_head_dim),
                    (P.HEADS, P.HEAD_DIM, P.EMBED_OUT)),
    }


def _mla_q(params, cfg, x, positions):
    from repro.models.layers import rmsnorm
    m = cfg.mla
    cq = jnp.einsum("bsd,dr->bsr", x, params["w_dq"])
    cq = rmsnorm({"scale": params["q_norm"]}, cq, cfg.rms_eps)
    q = jnp.einsum("bsr,rhk->bshk", cq, params["w_uq"])
    q = sh.hint(q, (sh.BATCH, None, sh.HEADS, None))
    q_nope = q[..., :m.qk_nope_head_dim]
    q_rope = rope(q[..., m.qk_nope_head_dim:], positions, cfg.rope_theta)
    return q_nope, q_rope


def _mla_kv_latent(params, cfg, x, positions):
    from repro.models.layers import rmsnorm
    ckv = jnp.einsum("bsd,dr->bsr", x, params["w_dkv"])
    ckv = rmsnorm({"scale": params["kv_norm"]}, ckv, cfg.rms_eps)
    krope = jnp.einsum("bsd,dk->bsk", x, params["w_krope"])
    krope = rope(krope, positions, cfg.rope_theta)
    return ckv, krope


def mla_forward(params, cfg, x, positions, *, window: Optional[int],
                kv_chunk: int = 1024):
    """Expanded-form MLA for train/prefill (heads sharded)."""
    m = cfg.mla
    q_nope, q_rope = _mla_q(params, cfg, x, positions)
    ckv, krope = _mla_kv_latent(params, cfg, x, positions)
    k_nope = jnp.einsum("bsr,rhk->bshk", ckv, params["w_uk"])
    v = jnp.einsum("bsr,rhk->bshk", ckv, params["w_uv"])
    k_nope = sh.hint(k_nope, (sh.BATCH, None, sh.HEADS, None))
    v = sh.hint(v, (sh.BATCH, None, sh.HEADS, None))
    h = cfg.num_heads
    k_rope_b = jnp.broadcast_to(krope[:, :, None, :],
                                krope.shape[:2] + (h, m.qk_rope_head_dim))
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope, k_rope_b], axis=-1)
    # pad v to qk dim so the blockwise core can share shapes
    qk = m.qk_nope_head_dim + m.qk_rope_head_dim
    vpad = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, qk - m.v_head_dim)))
    scale = qk ** -0.5
    out = blockwise_attention(q, k, vpad, positions, positions, window=window,
                              scale=scale, kv_chunk=kv_chunk)
    out = out[..., :m.v_head_dim]
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"])
    return y, (ckv, krope)


def init_mla_cache(cfg, batch: int, width: int, dtype) -> dict:
    m = cfg.mla
    return {
        "ckv": jnp.zeros((batch, width, m.kv_lora_rank), dtype),
        "krope": jnp.zeros((batch, width, m.qk_rope_head_dim), dtype),
        "pos": jnp.full((batch, width), -1, jnp.int32),
    }


def mla_cache_fill(cache: dict, ckv, krope, seq_len: int,
                   lengths=None) -> dict:
    width = cache["ckv"].shape[1]
    b, s = ckv.shape[0], ckv.shape[1]
    if lengths is None and s <= width:
        ckw = cache["ckv"].at[:, :s].set(ckv)
        krw = cache["krope"].at[:, :s].set(krope)
        pos = cache["pos"].at[:, :s].set(jnp.arange(s)[None, :])
        return {"ckv": ckw, "krope": krw, "pos": pos}
    rows, slot, pos_val = _fill_slots(width, b, s, lengths)
    ckw = jnp.zeros_like(cache["ckv"]).at[rows, slot].set(ckv)
    krw = jnp.zeros_like(cache["krope"]).at[rows, slot].set(krope)
    pos = jnp.full((b, width), -1, jnp.int32).at[rows, slot].set(pos_val)
    return {"ckv": ckw, "krope": krw, "pos": pos}


def mla_decode(params, cfg, x, cache, cur_pos, *, window: Optional[int],
               layout=None, block_tables=None, valid=None):
    """Absorbed-form MLA decode: score/value math in the latent space, so the
    cache stays compressed (kv_lora + rope dims) — the paper-relevant memory
    saving of MLA. The attend runs over ``layout.context`` (identity for the
    ring; a block-table gather for the paged layout), so both cache layouts
    share one attention formulation. Like ``attn_decode``, x may carry a
    T-token prompt chunk starting at ``cur_pos`` with an optional (B, T)
    write-validity mask."""
    layout = _ring_layout() if layout is None else layout
    m = cfg.mla
    b, t = x.shape[0], x.shape[1]
    start = positions_1d(cur_pos, b)
    positions = start[:, None] + jnp.arange(t, dtype=jnp.int32)[None]
    q_nope, q_rope = _mla_q(params, cfg, x, positions)          # (B,T,H,*)
    ckv1, krope1 = _mla_kv_latent(params, cfg, x, positions)    # (B,T,r)
    cache = layout.append(cache, {"ckv": ckv1, "krope": krope1}, start,
                          block_tables, valid=valid)
    ctx = layout.context(cache, block_tables)   # (B, C, ...) per-slot view
    ckv_c, krope_c, pos_c = ctx["ckv"], ctx["krope"], ctx["pos"]
    # absorb W_uk into q: q_lat (B,T,H,r)
    q_lat = jnp.einsum("bthk,rhk->bthr", q_nope, params["w_uk"])
    s_nope = jnp.einsum("bthr,bcr->bthc", q_lat,
                        ckv_c.astype(q_lat.dtype),
                        preferred_element_type=jnp.float32)
    s_rope = jnp.einsum("bthk,bck->bthc", q_rope,
                        krope_c.astype(q_rope.dtype),
                        preferred_element_type=jnp.float32)
    qk = m.qk_nope_head_dim + m.qk_rope_head_dim
    s = (s_nope + s_rope) * (qk ** -0.5)
    ok = (pos_c[:, None, :] <= positions[:, :, None]) & \
        (pos_c[:, None, :] >= 0)
    if window is not None:
        ok &= pos_c[:, None, :] > (positions[:, :, None] - window)
    s = jnp.where(ok[:, :, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o_lat = jnp.einsum("bthc,bcr->bthr", p.astype(ckv_c.dtype),
                       ckv_c, preferred_element_type=jnp.float32)
    out = jnp.einsum("bthr,rhk->bthk", o_lat.astype(x.dtype), params["w_uv"])
    y = jnp.einsum("bthk,hkd->btd", out, params["wo"])
    return y, cache
