"""Core layers: RMSNorm, MLPs, embeddings, RoPE."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import param as P


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------

def rmsnorm_init(d: int, dtype) -> dict:
    return {"scale": P.box(P.zeros((d,), jnp.float32), (P.EMBED,))}


def rmsnorm(params, x, eps: float):
    """(1+scale) RMSNorm computed in f32 (Gemma-style zero-centred scale)."""
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps) * (1.0 + params["scale"].astype(jnp.float32))
    return y.astype(dtype)


def norm_only(x, eps: float):
    """Scale-free RMS normalization (used by qk-norm variants w/o params)."""
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps)).astype(dtype)


# ---------------------------------------------------------------------------
# Dense MLPs
# ---------------------------------------------------------------------------

def swiglu_init(rng, d_model: int, d_ff: int, dtype) -> dict:
    k1, k2, k3 = jax.random.split(rng, 3)
    return {
        "w_gate": P.box(P.lecun(k1, (d_model, d_ff), dtype, d_model), (P.EMBED, P.MLP)),
        "w_up": P.box(P.lecun(k2, (d_model, d_ff), dtype, d_model), (P.EMBED, P.MLP)),
        "w_down": P.box(P.lecun(k3, (d_ff, d_model), dtype, d_ff), (P.MLP, P.EMBED_OUT)),
    }


def swiglu(params, x):
    g = jnp.einsum("...d,df->...f", x, params["w_gate"])
    u = jnp.einsum("...d,df->...f", x, params["w_up"])
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    return jnp.einsum("...f,fd->...d", h, params["w_down"])


def gelu_mlp_init(rng, d_model: int, d_ff: int, dtype) -> dict:
    # GeGLU (gated GELU) — used by recurrentgemma / starcoder2 / musicgen.
    k1, k2, k3 = jax.random.split(rng, 3)
    return {
        "w_gate": P.box(P.lecun(k1, (d_model, d_ff), dtype, d_model), (P.EMBED, P.MLP)),
        "w_up": P.box(P.lecun(k2, (d_model, d_ff), dtype, d_model), (P.EMBED, P.MLP)),
        "w_down": P.box(P.lecun(k3, (d_ff, d_model), dtype, d_ff), (P.MLP, P.EMBED_OUT)),
    }


def gelu_mlp(params, x):
    g = jnp.einsum("...d,df->...f", x, params["w_gate"])
    u = jnp.einsum("...d,df->...f", x, params["w_up"])
    h = jax.nn.gelu(g.astype(jnp.float32), approximate=True).astype(x.dtype) * u
    return jnp.einsum("...f,fd->...d", h, params["w_down"])


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------

def embedding_init(rng, vocab: int, d_model: int, dtype) -> dict:
    return {"table": P.box(P.normal(rng, (vocab, d_model), dtype, 1.0),
                           (P.VOCAB, P.EMBED))}


def embed(params, tokens):
    return jnp.take(params["table"], tokens, axis=0)


def unembed(table, x):
    """x (..., D) @ table^T (V, D) -> (..., V) logits."""
    return jnp.einsum("...d,vd->...v", x, table)


def unembed_init(rng, vocab: int, d_model: int, dtype) -> dict:
    return {"table": P.box(P.normal(rng, (vocab, d_model), dtype,
                                    d_model ** -0.5), (P.VOCAB, P.EMBED))}


# ---------------------------------------------------------------------------
# Rotary position embedding
# ---------------------------------------------------------------------------

def rope(x, positions, theta: float):
    """Apply RoPE. x: (..., S, H, hd) or (..., S, hd); positions: (..., S)."""
    hd = x.shape[-1]
    half = hd // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., None].astype(jnp.float32) * freq  # (..., S, half)
    if x.ndim == angles.ndim + 1:          # head axis present
        angles = angles[..., None, :]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def softcap(logits, cap: float):
    """Gemma-style logit soft-capping; no-op when cap == 0."""
    if cap and cap > 0:
        return (cap * jnp.tanh(logits.astype(jnp.float32) / cap)).astype(logits.dtype)
    return logits
