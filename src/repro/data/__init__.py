"""Data pipelines: synthetic token streams, synthetic video crops, sharded
host loading."""
from repro.data.synthetic import TokenStream, synth_crops
from repro.data.loader import ShardedLoader

__all__ = ["TokenStream", "synth_crops", "ShardedLoader"]
