"""Model-backed crop bank: real JAX classifiers behind the video-query DES.

The full end-to-end path of paper §5.1.2: COC trained on all 10 classes;
EOC trained *on the fly* as a binary (target vs rest) classifier on crops
labelled by COC (the paper's hybrid-collaboration detail); then every crop's
(EOC confidence, EOC prediction, COC top-5 hit, COC post-hoc label) is
precomputed in one batched pass and replayed by the simulator.
"""
from __future__ import annotations

from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.ace_video_query import VideoQueryConfig
from repro.core.video_query import Crop
from repro.data.synthetic import synth_crops
from repro.models.cnn import Classifier
from repro.optim import adamw_init, adamw_update

TARGET_CLASS = 1    # plays 'motorcycle'


def train_classifier(model: Classifier, images, labels, *, steps: int,
                     batch: int = 128, lr: float = 3e-3, seed: int = 0):
    params, _ = model.init(jax.random.PRNGKey(seed))
    opt = adamw_init(params)

    @jax.jit
    def step(params, opt, x, y):
        (loss, aux), g = jax.value_and_grad(model.loss, has_aux=True)(
            params, x, y)
        params, opt = adamw_update(params, g, opt, lr=lr)
        return params, opt, loss, aux["acc"]

    rng = np.random.default_rng(seed)
    n = len(images)
    loss = acc = 0.0
    for i in range(steps):
        idx = rng.integers(0, n, size=batch)
        params, opt, loss, acc = step(params, opt, jnp.asarray(images[idx]),
                                      jnp.asarray(labels[idx]))
    return params, {"loss": float(loss), "acc": float(acc)}


def model_crop_bank(cfg: VideoQueryConfig, *, n_train: int = 4096,
                    n_bank: int = 2048, coc_steps: int = 300,
                    eoc_steps: int = 120, seed: int = 0,
                    confidence_threshold: float = 0.8,
                    batch: int = 128
                    ) -> Tuple[List[Crop], dict]:
    """Returns (crop bank, training report)."""
    # 1. 'historical video data' -> crops (the YOLO extraction stub:
    #    synth_crops plays the cropped objects directly)
    train_imgs, train_lbls = synth_crops(n_train, seed=seed)
    bank_imgs, bank_lbls = synth_crops(n_bank, seed=seed + 1)

    # 2. COC: multi-class cloud classifier
    coc = Classifier(cfg.coc)
    coc_params, coc_rep = train_classifier(coc, train_imgs, train_lbls,
                                           steps=coc_steps, seed=seed,
                                           batch=batch)

    # 3. COC labels the historical crops; EOC trains on-the-fly against them
    coc_labels = np.asarray(
        jax.jit(lambda x: jnp.argmax(coc.apply(coc_params, x), -1))(
            jnp.asarray(train_imgs)))
    eoc_targets = (coc_labels == TARGET_CLASS).astype(np.int32)
    eoc = Classifier(cfg.eoc)
    eoc_params, eoc_rep = train_classifier(eoc, train_imgs, eoc_targets,
                                           steps=eoc_steps, seed=seed + 2,
                                           batch=batch)

    # 4. batched precomputation over the bank
    @jax.jit
    def bank_pass(eoc_p, coc_p, x):
        eoc_logits = eoc.apply(eoc_p, x)
        eoc_probs = jax.nn.softmax(eoc_logits, -1)
        # the paper's 'object identification confidence' is p(target),
        # not max-softmax (for a binary head the latter never drops
        # below 0.5, so nothing would ever be dropped or escalated)
        conf = eoc_probs[:, 1]
        pred = (conf >= 0.5).astype(jnp.int32)
        coc_logits = coc.apply(coc_p, x)
        # paper uses top-5 of 1000 ImageNet classes; with 10 synthetic
        # classes the proportional analogue is top-2
        top2 = jax.lax.top_k(coc_logits, 2)[1]
        hit = jnp.any(top2 == TARGET_CLASS, axis=-1)
        posthoc = jnp.argmax(coc_logits, -1) == TARGET_CLASS
        return conf, pred, hit, posthoc

    conf, pred, hit, posthoc = (np.asarray(a) for a in bank_pass(
        eoc_params, coc_params, jnp.asarray(bank_imgs)))
    crops = [Crop(i, bool(posthoc[i]), float(conf[i]), int(pred[i]),
                  bool(hit[i]), cfg.crop_bytes) for i in range(n_bank)]
    decided = (conf >= confidence_threshold) | (conf < 0.1)
    eoc_err = float(np.mean((pred != (bank_lbls == TARGET_CLASS))[decided])) \
        if np.any(decided) else 1.0
    report = {
        "coc": coc_rep, "eoc": eoc_rep,
        "eoc_error_at_conf": eoc_err,
        "escalation_rate": float(np.mean((conf < confidence_threshold)
                                         & (conf >= 0.1))),
    }
    return crops, report
