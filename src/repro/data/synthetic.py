"""Synthetic data generators.

Token streams: a learnable Markov-ish process (not uniform noise) so that
training ~100M models for a few hundred steps shows a *falling* loss curve —
the end-to-end driver's acceptance signal.

Video crops: class-conditional structured images for the EOC/COC classifiers
of the video-query application (10 classes; class 1 is the query target,
playing 'motorcycle').
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Tuple

import numpy as np


@dataclasses.dataclass
class TokenStream:
    """Order-1 Markov chain over the vocab with a low-rank transition
    structure; entropy well below log(V) so models can learn it."""
    vocab_size: int
    seed: int = 0
    rank: int = 16
    temp: float = 0.7

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        v, r = self.vocab_size, self.rank
        self._a = rng.normal(size=(v, r)).astype(np.float32)
        self._b = rng.normal(size=(r, v)).astype(np.float32)

    def _probs(self, tok: np.ndarray) -> np.ndarray:
        logits = (self._a[tok] @ self._b) / self.temp
        logits -= logits.max(axis=-1, keepdims=True)
        p = np.exp(logits)
        return p / p.sum(axis=-1, keepdims=True)

    def sample(self, batch: int, seq_len: int, seed: int = 0) -> np.ndarray:
        rng = np.random.default_rng(seed)
        out = np.empty((batch, seq_len), np.int32)
        tok = rng.integers(0, self.vocab_size, size=batch)
        for t in range(seq_len):
            p = self._probs(tok)
            # vectorized categorical sampling via inverse CDF
            u = rng.random(batch)[:, None]
            tok = (p.cumsum(axis=-1) < u).sum(axis=-1)
            tok = np.minimum(tok, self.vocab_size - 1)
            out[:, t] = tok
        return out

    def batches(self, batch: int, seq_len: int,
                seed: int = 0) -> Iterator[dict]:
        i = 0
        while True:
            tokens = self.sample(batch, seq_len, seed=seed + i)
            labels = np.concatenate(
                [tokens[:, 1:], np.full((batch, 1), -1, np.int32)], axis=1)
            yield {"tokens": tokens, "labels": labels}
            i += 1


def synth_crops(n: int, *, num_classes: int = 10, image_size: int = 32,
                seed: int = 0) -> Tuple[np.ndarray, np.ndarray]:
    """Class-conditional crops: each class is a distinct oriented grating +
    colour tint + noise. Learnable by small conv nets within a few hundred
    steps, with enough overlap that classifiers stay imperfect (the cascade
    needs a confidence distribution, not a solved task)."""
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, num_classes, size=n).astype(np.int32)
    yy, xx = np.mgrid[0:image_size, 0:image_size].astype(np.float32)
    images = np.empty((n, image_size, image_size, 3), np.float32)
    for c in range(num_classes):
        idx = np.where(labels == c)[0]
        if len(idx) == 0:
            continue
        theta = np.pi * c / num_classes
        freq = 0.25 + 0.06 * c
        base = np.sin(freq * (np.cos(theta) * xx + np.sin(theta) * yy))
        tint = np.array([np.cos(2.1 * c), np.sin(1.3 * c), np.cos(0.7 * c)])
        tint = 0.5 + 0.35 * tint
        # grating (second-order cue) + DC colour tint (first-order cue)
        img = (0.5 + 0.4 * base[..., None] * tint[None, None, :]
               + 0.18 * (tint[None, None, :] - 0.5))
        noise = rng.normal(scale=0.55, size=(len(idx), image_size,
                                             image_size, 3))
        # small jitter only: full wraparound shifts made the task
        # unlearnable for CPU-scale training budgets
        shift = rng.integers(0, 4, size=(len(idx), 2))
        batch = np.clip(img[None] + noise, 0, 1).astype(np.float32)
        for k, i in enumerate(idx):
            images[i] = np.roll(batch[k], tuple(shift[k]), axis=(0, 1))
    return images, labels
