"""Host-side sharded loading: numpy batches -> device arrays laid out to the
active mesh (batch sharded along the data/pod axes)."""
from __future__ import annotations

from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as PS


class ShardedLoader:
    def __init__(self, it: Iterator[Dict[str, np.ndarray]],
                 mesh: Optional[Mesh] = None,
                 batch_axes: tuple = ("data",)):
        self.it = it
        self.mesh = mesh
        self.batch_axes = batch_axes

    def __iter__(self):
        return self

    def __next__(self) -> Dict[str, jnp.ndarray]:
        host = next(self.it)
        if self.mesh is None:
            return {k: jnp.asarray(v) for k, v in host.items()}
        sharding = {}
        for k, v in host.items():
            axes = [a for a in self.batch_axes if a in self.mesh.shape]
            size = int(np.prod([self.mesh.shape[a] for a in axes])) or 1
            spec = (tuple(axes),) + (None,) * (v.ndim - 1) \
                if v.shape[0] % size == 0 else (None,) * v.ndim
            sharding[k] = NamedSharding(self.mesh, PS(*spec))
        return {k: jax.device_put(v, sharding[k]) for k, v in host.items()}
