"""Production training launcher.

On a real TPU slice this builds the production mesh, shards params/optimizer
by the §4 rules, and runs the same Trainer the examples use. On CPU it runs
the reduced config over the host mesh — same code path.

    PYTHONPATH=src python -m repro.launch.train --arch smollm-135m \
        [--production] [--multi-pod] --steps 100
"""
from __future__ import annotations

import argparse

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as PS

from repro import sharding as sh
from repro.configs import get_config
from repro.data.loader import ShardedLoader
from repro.data.synthetic import TokenStream
from repro.launch import sharding_rules as sr
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models.model import LM
from repro.optim import adamw_init, linear_warmup_cosine
from repro.training.train_loop import make_train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--production", action="store_true",
                    help="use make_production_mesh (needs >= 256 devices)")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--reduced", action="store_true", default=None)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-4)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    reduced = (not args.production) if args.reduced is None else args.reduced
    if reduced:
        cfg = cfg.reduced()
    lm = LM(cfg)
    mesh = (make_production_mesh(multi_pod=args.multi_pod)
            if args.production else make_host_mesh())
    print(f"mesh={dict(mesh.shape)} arch={cfg.name}")

    params_abs, axes = lm.abstract()
    pspec = sr.param_pspecs(mesh, params_abs, axes, "train")
    named = lambda t: jax.tree.map(lambda s: NamedSharding(mesh, s), t,
                                   is_leaf=lambda x: isinstance(x, PS))

    step_fn = make_train_step(lm, linear_warmup_cosine(args.lr, 10,
                                                       args.steps))
    with mesh:
        with sh.use_rules(mesh, sr.act_rules(mesh, "train")):
            params, _ = lm.init(jax.random.PRNGKey(0))
            params = jax.device_put(params, named(pspec))
            opt = adamw_init(params)
            opt = jax.device_put(opt, named(
                sr.opt_pspecs(mesh, pspec, opt)))
            jitted = jax.jit(step_fn, donate_argnums=(0, 1))
            stream = TokenStream(cfg.vocab_size, seed=0)
            loader = ShardedLoader(stream.batches(args.batch, args.seq),
                                   mesh=mesh)
            for i, batch in zip(range(args.steps), loader):
                params, opt, metrics = jitted(params, opt, batch)
                if i % 10 == 0 or i == args.steps - 1:
                    print(f"step {i:4d} loss {float(metrics['loss']):.4f}")


if __name__ == "__main__":
    main()
