"""ShapeDtypeStruct stand-ins for every model input (no allocation), per
(architecture x input shape), plus the step functions the dry-run lowers.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.configs.base import ModelConfig, apply_long_context
from repro.configs.shapes import InputShape, get_shape
from repro.models.model import LM

SDS = jax.ShapeDtypeStruct


def resolved_config(arch: str, shape_name: str) -> ModelConfig:
    cfg = get_config(arch)
    if shape_name == "long_500k":
        cfg = apply_long_context(cfg)
    return cfg


def input_specs(cfg: ModelConfig, shape: InputShape) -> Dict[str, Any]:
    """Inputs for the step that this shape lowers (see shapes.py)."""
    b, s = shape.global_batch, shape.seq_len
    tok_shape: Tuple[int, ...]
    if cfg.frontend.kind == "audio":
        tok = (b, s, cfg.frontend.num_codebooks)
        tok1 = (b, 1, cfg.frontend.num_codebooks)
    else:
        tok = (b, s - (cfg.frontend.num_prefix_tokens
                       if cfg.frontend.kind == "vision" else 0))
        tok1 = (b, 1)
    if shape.mode in ("train", "prefill"):
        batch = {"tokens": SDS(tok, jnp.int32)}
        if shape.mode == "train":
            batch["labels"] = SDS(tok, jnp.int32)
        if cfg.frontend.kind == "vision":
            batch["image_embeds"] = SDS(
                (b, cfg.frontend.num_prefix_tokens, cfg.frontend.embed_dim),
                jnp.dtype(cfg.param_dtype))
        return batch
    # decode: ONE new token + a seq_len-context cache + current position
    return {"tokens": SDS(tok1, jnp.int32),
            "cur_pos": SDS((), jnp.int32)}


def abstract_cache(lm: LM, shape: InputShape):
    return jax.eval_shape(
        lambda: lm.init_cache(shape.global_batch, shape.seq_len))


def make_step_fn(lm: LM, shape: InputShape, lr_schedule=None):
    """The callable the dry-run lowers, plus its abstract inputs."""
    cfg = lm.cfg
    if shape.mode == "train":
        from repro.training.train_loop import make_train_step
        from repro.optim import adamw_init, linear_warmup_cosine
        sched = lr_schedule or linear_warmup_cosine(3e-4, 100, 10_000)
        step = make_train_step(lm, sched)
        params_abs = jax.eval_shape(
            lambda: lm.init_boxed(jax.random.PRNGKey(0)))
        from repro.models import param as P
        params_abs, axes = P.unbox(params_abs)
        # moments in bf16 for the XXL MoE archs (see DESIGN.md / §Roofline)
        opt_dtype = jnp.bfloat16 if cfg.name.startswith("deepseek") \
            else jnp.float32
        opt_abs = jax.eval_shape(lambda p: adamw_init(p, opt_dtype),
                                 params_abs)
        batch_abs = input_specs(cfg, shape)
        return step, (params_abs, opt_abs, batch_abs), axes

    params_abs = jax.eval_shape(lambda: lm.init_boxed(jax.random.PRNGKey(0)))
    from repro.models import param as P
    params_abs, axes = P.unbox(params_abs)

    if shape.mode == "prefill":
        def prefill_step(params, batch):
            # §Perf B2: unembed only the last position — computing the full
            # (B, S, V) logits tensor and slicing afterwards wastes
            # B*S*V flops + traffic
            logits, caches = lm.prefill(params, batch,
                                        cache_width=shape.seq_len,
                                        last_only=True)
            return logits[:, -1, :], caches
        batch_abs = input_specs(cfg, shape)
        return prefill_step, (params_abs, batch_abs), axes

    assert shape.mode == "decode"
    def serve_step(params, caches, tokens, cur_pos):
        return lm.decode_step(params, caches, tokens, cur_pos)

    cache_abs = abstract_cache(lm, shape)
    ins = input_specs(cfg, shape)
    return serve_step, (params_abs, cache_abs, ins["tokens"],
                        ins["cur_pos"]), axes
