"""Production serving launcher: batched decode against a KV cache under the
production sharding rules, or the ACE cascade with --cascade.

    PYTHONPATH=src python -m repro.launch.serve --arch glm4-9b --reduced
    PYTHONPATH=src python -m repro.launch.serve --arch glm4-9b --cascade
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.cascade.ecc_infer import CascadeLM, edge_variant
from repro.configs import get_config
from repro.launch.mesh import make_host_mesh
from repro.models.model import LM
from repro.serving import CascadeEngine, ServingEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--cascade", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=8)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    rng = np.random.default_rng(0)

    if args.cascade:
        edge_cfg = edge_variant(cfg, layers=1)
        cloud, edge = LM(cfg, kv_chunk=32), LM(edge_cfg, kv_chunk=32)
        cp, _ = cloud.init(jax.random.PRNGKey(0))
        ep, _ = edge.init(jax.random.PRNGKey(1))
        eng = CascadeEngine(CascadeLM(edge, cloud), ep, cp)
        tokens = rng.integers(0, cfg.vocab_size,
                              size=(args.requests, 24))
        out = eng.query(tokens)
        m = eng.metrics
        print(f"cascade: {m.queries} queries, escalated {m.escalated}, "
              f"wan {m.wan_bytes} B, latency {out['latency_s']*1e3:.0f} ms")
        return

    lm = LM(cfg, kv_chunk=32)
    params, _ = lm.init(jax.random.PRNGKey(0))
    eng = ServingEngine(lm, params, batch_slots=4, max_seq_len=96)
    for i in range(args.requests):
        eng.submit(rng.integers(0, min(1000, cfg.vocab_size),
                                size=4 + i % 5),
                   max_new_tokens=args.max_new)
    done = eng.run()
    for rid, r in sorted(done.items()):
        print(f"req {rid}: {r.output.tolist()}  ({r.latency_s*1e3:.0f} ms)")


if __name__ == "__main__":
    main()
