"""Production serving launcher: open-loop traffic through the async
gateway — streamed tokens, backpressure, SLO classes — on the dense
engine or the ACE edge/cloud cascade with --cascade.

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m --reduced
    PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m --cascade
    PYTHONPATH=src python -m repro.launch.serve --rate 40 --policy shed

Arrivals are an open-loop Poisson process (``--rate`` req/s, independent
of service rate), each request streamed as its tokens land; under
``--rate`` beyond capacity the gateway's bounded queue and backpressure
policy decide who waits, who is shed, and who is refused.

Durability (--supervise): the gateway runs with a write-ahead request
journal, periodic engine snapshots, and a wall-clock watchdog on every
dispatch. Two demo fault modes exercise the recovery ladder end to end:

    --hang-demo    a dispatch stalls briefly — the watchdog times out,
                   the late step is rolled back through the retry path
                   (note_hang), and service continues in-process
    --wedge-demo   a dispatch stalls past the grace window — the driver
                   raises EngineWedgedError, and the supervisor restarts
                   from snapshot + journal; recovered requests finish
                   token-exact, crash-lost ones are replayed
"""
from __future__ import annotations

import argparse
import asyncio
import os
import tempfile
from collections import Counter

import jax
import numpy as np

from repro.cascade.ecc_infer import CascadeLM, edge_variant
from repro.cascade.gate import make_thresholds
from repro.configs import get_config
from repro.core.monitoring import MonitoringService
from repro.launch.mesh import make_host_mesh
from repro.models.model import LM
from repro.serving import (CascadeServingEngine, EngineWedgedError,
                           FaultPlan, RequestJournal, ServingEngine,
                           ServingGateway, enable_compile_cache,
                           recover_engine)


def _mesh_from_args(args):
    """--mesh N -> a (data, model) host mesh with an N-way model axis
    (tensor-parallel decode: params and KV pools shard over KV heads)."""
    ways = int(args.mesh or 1)
    if ways <= 1:
        return None
    n = len(jax.devices())
    if n % ways != 0:
        raise SystemExit(
            f"--mesh {ways} needs a device count divisible by {ways} "
            f"(found {n}; on CPU export XLA_FLAGS="
            f"--xla_force_host_platform_device_count={ways} before launch)")
    return make_host_mesh(model=ways)


def _build_engine(cfg, args, fault_plan=None):
    mesh = _mesh_from_args(args)
    if args.cascade:
        edge_cfg = edge_variant(cfg, layers=1)
        cloud, edge = LM(cfg, kv_chunk=32), LM(edge_cfg, kv_chunk=32)
        cp, _ = cloud.init(jax.random.PRNGKey(0))
        ep, _ = edge.init(jax.random.PRNGKey(1))
        cascade = CascadeLM(edge, cloud,
                            thresholds=make_thresholds(hi=0.01, lo=0.001))
        return CascadeServingEngine(cascade, ep, cp, batch_slots=4,
                                    max_seq_len=96, fault_plan=fault_plan,
                                    mesh=mesh)
    lm = LM(cfg, kv_chunk=32)
    params, _ = lm.init(jax.random.PRNGKey(0))
    return ServingEngine(lm, params, batch_slots=4, max_seq_len=96,
                         fault_plan=fault_plan, mesh=mesh)


async def _client(gw: ServingGateway, prompt, max_new: int,
                  priority: int, deadline_s, quiet: bool) -> dict:
    """One open-loop client: submit, consume the stream, report."""
    h = await gw.submit(prompt, max_new_tokens=max_new, priority=priority,
                        deadline_s=deadline_s)
    toks = []
    async for t in h.stream():
        toks.append(t)
    r = await h.result()
    if not quiet:
        route = getattr(r, "route", "")
        extra = f" route={route}" if route else ""
        print(f"req {r.request_id}: status={r.status}{extra} "
              f"tokens={toks} ttft={r.ttft_s * 1e3:.0f}ms "
              f"latency={r.latency_s * 1e3:.0f}ms")
    return {"status": r.status, "streamed": len(toks)}


def _demo_fault_plan(args):
    """The two watchdog demos differ only in stall length relative to the
    watchdog deadline: a hang completes late (in-process rollback via
    note_hang), a wedge never completes within grace (supervised
    restart)."""
    if args.wedge_demo:
        return FaultPlan(hang=[2],
                         hang_s=args.step_timeout * (1.0 + args.hang_grace)
                         + 2.0)
    if args.hang_demo:
        return FaultPlan(hang=[2], hang_s=args.step_timeout * 1.5)
    return None


async def _serve(args) -> None:
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    rng = np.random.default_rng(0)
    monitor = MonitoringService()

    journal = None
    gw_kw = {}
    if args.compile_cache:
        # persistent executable cache keyed under the state dir: a
        # supervised restart-from-snapshot replays warm_compile from disk
        state_dir = args.state_dir or tempfile.mkdtemp(prefix="repro_serve_")
        args.state_dir = state_dir
        enable_compile_cache(os.path.join(state_dir, "compile_cache"))
        print(f"compile cache: {os.path.join(state_dir, 'compile_cache')}")
    if args.supervise:
        state_dir = args.state_dir or tempfile.mkdtemp(
            prefix="repro_serve_")
        journal = RequestJournal(os.path.join(state_dir, "journal.jsonl"))
        gw_kw = dict(journal=journal,
                     snapshot_dir=os.path.join(state_dir, "snapshots"),
                     snapshot_every=args.snapshot_every,
                     step_timeout_s=args.step_timeout,
                     hang_grace=args.hang_grace)
        print(f"supervised: state in {state_dir}")
    eng = _build_engine(cfg, args, fault_plan=_demo_fault_plan(args))

    results, wedged = [], None
    gw = ServingGateway(eng, max_queue=args.max_queue,
                        policy=args.policy, **gw_kw)
    try:
        async with gw:
            clients = []
            for i in range(args.requests):
                prompt = rng.integers(0, min(1000, cfg.vocab_size),
                                      size=4 + i % 5)
                priority = i % 2 if args.classes > 1 else 0
                clients.append(asyncio.create_task(_client(
                    gw, prompt, args.max_new, priority,
                    args.deadline if priority else None, args.quiet)))
                # open loop: exponential inter-arrivals at --rate req/s,
                # drawn independently of how fast the engine is serving
                await asyncio.sleep(float(rng.exponential(1.0 / args.rate)))
            results = await asyncio.gather(*clients)
    except EngineWedgedError as e:
        wedged = e
        monitor.record_hang("serve", detail=str(e))

    by_status = Counter(res["status"] for res in results)
    print(f"served {len(results)} arrivals at {args.rate:.0f} req/s: "
          f"{dict(by_status)}  gateway={gw.stats()}")

    if wedged is not None:
        if not args.supervise:
            raise wedged
        # supervised restart: the wedged engine's thread is a write-off —
        # recover a *fresh* engine from the last snapshot + journal and
        # drain the surviving work synchronously (token-exact resumes;
        # crash-lost acknowledged submits restart from their prompts)
        print(f"engine wedged ({wedged}); restarting from snapshot")
        eng2 = _build_engine(cfg, args)
        info = recover_engine(eng2, snapshot_dir=gw_kw["snapshot_dir"],
                              journal=journal)
        monitor.record_restart("serve", info)
        monitor.record_journal("serve", info["replayed"])
        done = eng2.run()
        statuses = Counter(r.status for r in done.values())
        print(f"recovered {info['restored']} + replayed "
              f"{info['replayed']}; post-restart drain: {dict(statuses)}")
        print(f"durability: {monitor.durability_counters()}")
    if journal is not None:
        journal.close()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--cascade", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--rate", type=float, default=20.0,
                    help="offered load, requests/s (open loop)")
    ap.add_argument("--policy", default="block",
                    choices=["block", "reject", "shed",
                             "reject-overload", "shed-lowest-class"])
    ap.add_argument("--max-queue", type=int, default=16)
    ap.add_argument("--classes", type=int, default=2,
                    help="SLO classes to alternate arrivals over")
    ap.add_argument("--deadline", type=float, default=None,
                    help="relative deadline (s) for class-1 arrivals")
    ap.add_argument("--quiet", action="store_true")
    # durability (ISSUE 9)
    ap.add_argument("--supervise", action="store_true",
                    help="journal + periodic snapshots + watchdog; on "
                         "EngineWedgedError, restart from snapshot")
    ap.add_argument("--state-dir", default=None,
                    help="journal/snapshot directory (default: tmpdir)")
    # mesh-aware serving (ISSUE 10)
    ap.add_argument("--mesh", type=int, default=1,
                    help="tensor-parallel ways on the 'model' mesh axis "
                         "(device count must divide; on CPU export "
                         "XLA_FLAGS=--xla_force_host_platform_device_count"
                         "=N first). 1 = single-device (default)")
    ap.add_argument("--compile-cache", action="store_true",
                    help="persist compiled executables under --state-dir/"
                         "compile_cache so restarts skip recompilation")
    ap.add_argument("--step-timeout", type=float, default=5.0,
                    help="watchdog wall-clock deadline per dispatch (s)")
    ap.add_argument("--hang-grace", type=float, default=1.0,
                    help="grace window as a multiple of --step-timeout")
    ap.add_argument("--snapshot-every", type=int, default=8,
                    help="engine steps between periodic snapshots")
    ap.add_argument("--hang-demo", action="store_true",
                    help="inject a recoverable dispatch stall")
    ap.add_argument("--wedge-demo", action="store_true",
                    help="inject a stall past grace (supervised restart)")
    args = ap.parse_args()
    if args.hang_demo or args.wedge_demo:
        args.supervise = True
    asyncio.run(_serve(args))


if __name__ == "__main__":
    main()
