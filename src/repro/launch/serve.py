"""Production serving launcher: open-loop traffic through the async
gateway — streamed tokens, backpressure, SLO classes — on the dense
engine or the ACE edge/cloud cascade with --cascade.

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m --reduced
    PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m --cascade
    PYTHONPATH=src python -m repro.launch.serve --rate 40 --policy shed

Arrivals are an open-loop Poisson process (``--rate`` req/s, independent
of service rate), each request streamed as its tokens land; under
``--rate`` beyond capacity the gateway's bounded queue and backpressure
policy decide who waits, who is shed, and who is refused.
"""
from __future__ import annotations

import argparse
import asyncio

import jax
import numpy as np

from repro.cascade.ecc_infer import CascadeLM, edge_variant
from repro.cascade.gate import make_thresholds
from repro.configs import get_config
from repro.models.model import LM
from repro.serving import CascadeServingEngine, ServingEngine, ServingGateway


def _build_engine(cfg, args):
    if args.cascade:
        edge_cfg = edge_variant(cfg, layers=1)
        cloud, edge = LM(cfg, kv_chunk=32), LM(edge_cfg, kv_chunk=32)
        cp, _ = cloud.init(jax.random.PRNGKey(0))
        ep, _ = edge.init(jax.random.PRNGKey(1))
        cascade = CascadeLM(edge, cloud,
                            thresholds=make_thresholds(hi=0.01, lo=0.001))
        return CascadeServingEngine(cascade, ep, cp, batch_slots=4,
                                    max_seq_len=96)
    lm = LM(cfg, kv_chunk=32)
    params, _ = lm.init(jax.random.PRNGKey(0))
    return ServingEngine(lm, params, batch_slots=4, max_seq_len=96)


async def _client(gw: ServingGateway, prompt, max_new: int,
                  priority: int, deadline_s, quiet: bool) -> dict:
    """One open-loop client: submit, consume the stream, report."""
    h = await gw.submit(prompt, max_new_tokens=max_new, priority=priority,
                        deadline_s=deadline_s)
    toks = []
    async for t in h.stream():
        toks.append(t)
    r = await h.result()
    if not quiet:
        route = getattr(r, "route", "")
        extra = f" route={route}" if route else ""
        print(f"req {r.request_id}: status={r.status}{extra} "
              f"tokens={toks} ttft={r.ttft_s * 1e3:.0f}ms "
              f"latency={r.latency_s * 1e3:.0f}ms")
    return {"status": r.status, "streamed": len(toks)}


async def _serve(args) -> None:
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    rng = np.random.default_rng(0)
    eng = _build_engine(cfg, args)

    async with ServingGateway(eng, max_queue=args.max_queue,
                              policy=args.policy) as gw:
        clients = []
        for i in range(args.requests):
            prompt = rng.integers(0, min(1000, cfg.vocab_size),
                                  size=4 + i % 5)
            priority = i % 2 if args.classes > 1 else 0
            clients.append(asyncio.create_task(_client(
                gw, prompt, args.max_new, priority,
                args.deadline if priority else None, args.quiet)))
            # open loop: exponential inter-arrivals at --rate req/s,
            # drawn independently of how fast the engine is serving
            await asyncio.sleep(float(rng.exponential(1.0 / args.rate)))
        results = await asyncio.gather(*clients)

    by_status: dict = {}
    for res in results:
        by_status[res["status"]] = by_status.get(res["status"], 0) + 1
    print(f"served {len(results)} arrivals at {args.rate:.0f} req/s: "
          f"{by_status}  gateway={gw.stats()}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--cascade", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--rate", type=float, default=20.0,
                    help="offered load, requests/s (open loop)")
    ap.add_argument("--policy", default="block",
                    choices=["block", "reject", "shed",
                             "reject-overload", "shed-lowest-class"])
    ap.add_argument("--max-queue", type=int, default=16)
    ap.add_argument("--classes", type=int, default=2,
                    help="SLO classes to alternate arrivals over")
    ap.add_argument("--deadline", type=float, default=None,
                    help="relative deadline (s) for class-1 arrivals")
    ap.add_argument("--quiet", action="store_true")
    args = ap.parse_args()
    asyncio.run(_serve(args))


if __name__ == "__main__":
    main()
