import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (deliverable e).

For every (architecture x input shape), lower + compile the corresponding
step function on the production meshes (single-pod 16x16 and multi-pod
2x16x16) and record memory analysis, cost analysis, and the collective-op
byte inventory parsed from the optimized HLO — the inputs to §Roofline.

The XLA_FLAGS line above MUST precede any jax import: jax locks the device
count on first initialization. Results are cached as JSON per run:

    PYTHONPATH=src python -m repro.launch.dryrun --arch smollm-135m \
        --shape train_4k [--multi-pod] [--force]
    PYTHONPATH=src python -m repro.launch.dryrun --all
"""
import argparse
import json
import re
import sys
import time
import traceback
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro import sharding as sh
from repro.configs import ASSIGNED_ARCHS
from repro.configs.shapes import INPUT_SHAPES, get_shape
from repro.launch import sharding_rules as sr
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import make_step_fn, resolved_config
from repro.models.model import LM

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_DTYPE_BYTES = {
    "pred": 0.125, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2,
    "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"=\s*(?:\([^)]*\)|tuple\([^)]*\)|[\w\[\],{}:# ]+?)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")


def _shape_bytes(text: str) -> int:
    """Sum byte sizes of every shape literal in an HLO result clause."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(text):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += int(n * _DTYPE_BYTES[dtype])
    return total


def collective_bytes(hlo_text: str) -> Dict[str, Dict[str, float]]:
    """Per-collective-kind op counts and (per-device) result bytes."""
    out = {k: {"count": 0, "bytes": 0} for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = _OP_RE.search(stripped)
        if not m:
            continue
        kind = m.group(1)
        if kind + "-done(" in stripped:
            continue  # don't double count start/done pairs
        lhs = stripped.split(" = ", 1)
        if len(lhs) != 2:
            continue
        result_clause = lhs[1].split(kind)[0]
        out[kind]["count"] += 1
        out[kind]["bytes"] += _shape_bytes(result_clause)
    return out


def run_one(arch: str, shape_name: str, *, multi_pod: bool = False,
            out_dir: str = "results/dryrun", force: bool = False,
            verbose: bool = True) -> Optional[dict]:
    mesh_tag = "pod2x16x16" if multi_pod else "pod16x16"
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"{arch}__{shape_name}__{mesh_tag}.json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)

    shape = get_shape(shape_name)
    cfg = resolved_config(arch, shape_name)
    lm = LM(cfg)
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()

    step, abstract_in, axes = make_step_fn(lm, shape)
    pspec = sr.param_pspecs(mesh, abstract_in[0], axes, shape.mode)
    if shape.mode == "train":
        params_abs, opt_abs, batch_abs = abstract_in
        in_shardings = (pspec, sr.opt_pspecs(mesh, pspec, opt_abs),
                        sr.batch_pspecs(mesh, batch_abs))
        out_shardings = (pspec, sr.opt_pspecs(mesh, pspec, opt_abs), None)
    elif shape.mode == "prefill":
        params_abs, batch_abs = abstract_in
        in_shardings = (pspec, sr.batch_pspecs(mesh, batch_abs))
        out_shardings = None
    else:
        params_abs, cache_abs, tok_abs, pos_abs = abstract_in
        cache_spec = sr.cache_pspecs(mesh, cfg, cache_abs)
        # decode inputs are replicated (see act_rules decode note)
        in_shardings = (pspec, cache_spec,
                        jax.sharding.PartitionSpec(),
                        jax.sharding.PartitionSpec())
        out_shardings = (None, cache_spec)

    def to_named(tree):
        if tree is None:
            return None
        return jax.tree.map(
            lambda s: jax.sharding.NamedSharding(mesh, s), tree,
            is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))

    with mesh:
        with sh.use_rules(mesh, sr.act_rules(
                mesh, shape.mode,
                # SP is a measured win only for plain dense stacks: grouped
                # MoE dispatch, tied unembeddings and multi-head frontends
                # all trigger pathological GSPMD resharding (§Perf T1)
                seq_parallel=(cfg.moe is None and not cfg.tie_embeddings
                              and cfg.frontend.kind == "none"))):
            jitted = jax.jit(
                step,
                in_shardings=to_named(in_shardings),
                out_shardings=(None if out_shardings is None else tuple(
                    to_named(t) for t in out_shardings)))
            lowered = jitted.lower(*abstract_in)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if verbose:
        print(f"--- {arch} x {shape_name} x {mesh_tag}")
        print(mem)       # proves it fits (bytes per device)
        print({k: v for k, v in sorted(cost.items())
               if k in ("flops", "bytes accessed", "optimal_seconds")})

    hlo = compiled.as_text()
    coll = collective_bytes(hlo)
    # trip-count-weighted costs (XLA's cost_analysis counts scan bodies
    # once; see repro.analysis.hlo_cost)
    from repro.analysis.hlo_cost import analyze
    try:
        weighted = analyze(hlo)
    except Exception as e:  # noqa: BLE001 - keep the record either way
        weighted = {"error": repr(e)}
    n_dev = mesh.size

    record = {
        "arch": arch, "shape": shape_name, "mesh": mesh_tag,
        "devices": n_dev,
        "mode": shape.mode,
        "seq_len": shape.seq_len, "global_batch": shape.global_batch,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes_per_device": getattr(
                mem, "argument_size_in_bytes", None),
            "output_bytes_per_device": getattr(
                mem, "output_size_in_bytes", None),
            "temp_bytes_per_device": getattr(
                mem, "temp_size_in_bytes", None),
            "generated_code_bytes": getattr(
                mem, "generated_code_size_in_bytes", None),
        },
        "cost": {k: cost.get(k) for k in
                 ("flops", "bytes accessed", "transcendentals",
                  "optimal_seconds") if k in cost},
        "collectives": coll,
        "weighted": weighted,
        "hlo_lines": hlo.count("\n"),
    }
    with open(path, "w") as f:
        json.dump(record, f, indent=1)
    import gzip
    with gzip.open(path.replace(".json", ".hlo.gz"), "wt") as f:
        f.write(hlo)
    if verbose:
        tot = sum(v["bytes"] for v in coll.values())
        print(f"collectives: { {k: v for k, v in coll.items() if v['count']} }")
        print(f"total collective bytes/device: {tot/1e6:.1f} MB; "
              f"lower {t_lower:.0f}s compile {t_compile:.0f}s")
    return record


def run_cascade(variant: str = "compact", *, cloud_arch: str = "glm4-9b",
                batch: int = 128, seq: int = 2048, multi_pod: bool = False,
                capacity_frac: float = 0.25,
                out_dir: str = "results/dryrun", force: bool = False,
                verbose: bool = True) -> dict:
    """Lower the ACE cascade serving step (the paper's technique on LM
    workloads): 'lockstep' = paper-faithful (cloud sees the full batch),
    'compact' = beyond-paper sorted-compaction (cloud sees only the
    escalated slice). Recorded separately in §Perf."""
    from repro.cascade.ecc_infer import CascadeLM, edge_variant
    from repro.models import param as P

    mesh_tag = "pod2x16x16" if multi_pod else "pod16x16"
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"cascade-{variant}__b{batch}s{seq}__{mesh_tag}.json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)

    from repro.configs import get_config
    from repro.models.model import LM
    cloud_cfg = get_config(cloud_arch)
    edge_cfg = edge_variant(cloud_cfg, layers=4)
    cloud, edge = LM(cloud_cfg), LM(edge_cfg)
    cascade = CascadeLM(edge, cloud, capacity_frac=capacity_frac)
    mesh = make_production_mesh(multi_pod=multi_pod)

    ep_abs = jax.eval_shape(lambda: edge.init_boxed(jax.random.PRNGKey(0)))
    cp_abs = jax.eval_shape(lambda: cloud.init_boxed(jax.random.PRNGKey(1)))
    ep_abs, e_axes = P.unbox(ep_abs)
    cp_abs, c_axes = P.unbox(cp_abs)
    batch_abs = {"tokens": jax.ShapeDtypeStruct((batch, seq), jnp.int32)}

    e_spec = sr.param_pspecs(mesh, ep_abs, e_axes, "prefill")
    c_spec = sr.param_pspecs(mesh, cp_abs, c_axes, "prefill")
    b_spec = sr.batch_pspecs(mesh, batch_abs)
    named = lambda t: jax.tree.map(
        lambda s: jax.sharding.NamedSharding(mesh, s), t,
        is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
    step = cascade.serve_step if variant == "compact" \
        else cascade.lockstep_step

    t0 = time.time()
    with mesh:
        with sh.use_rules(mesh, sr.act_rules(mesh, "prefill")):
            jitted = jax.jit(step, in_shardings=(
                named(e_spec), named(c_spec), named(b_spec)))
            lowered = jitted.lower(ep_abs, cp_abs, batch_abs)
        compiled = lowered.compile()
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    from repro.analysis.hlo_cost import analyze
    try:
        weighted = analyze(hlo)
    except Exception as e:  # noqa: BLE001
        weighted = {"error": repr(e)}
    record = {
        "arch": f"cascade-{variant}({cloud_arch})",
        "shape": f"query_b{batch}s{seq}", "mesh": mesh_tag,
        "devices": mesh.size, "mode": "prefill",
        "seq_len": seq, "global_batch": batch,
        "lower_s": round(time.time() - t0, 1),
        "compile_s": 0.0,
        "memory": {
            "argument_bytes_per_device": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes_per_device": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes_per_device": getattr(mem, "temp_size_in_bytes", None),
        },
        "cost": {k: cost.get(k) for k in ("flops", "bytes accessed")
                 if k in cost},
        "collectives": collective_bytes(hlo),
        "weighted": weighted,
    }
    with open(path, "w") as f:
        json.dump(record, f, indent=1)
    if verbose:
        w = record["weighted"]
        print(f"--- cascade {variant} x {mesh_tag}: "
              f"dot_flops={w.get('dot_flops', 0):.3e} "
              f"coll={w.get('collective_bytes_total', 0)/2**30:.2f} GiB "
              f"temp={record['memory']['temp_bytes_per_device']/2**30:.1f} GiB")
    return record


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--cascade", default=None,
                    choices=["lockstep", "compact"],
                    help="lower the ACE cascade step instead of an arch")
    ap.add_argument("--out", default="results/dryrun")
    args = ap.parse_args()

    if args.cascade:
        run_cascade(args.cascade, multi_pod=args.multi_pod,
                    out_dir=args.out, force=args.force)
        return 0

    archs = ASSIGNED_ARCHS if (args.all or args.arch is None) \
        else [args.arch]
    shapes = list(INPUT_SHAPES) if (args.all or args.shape is None) \
        else [args.shape]
    meshes = [False, True] if (args.all or args.both_meshes) \
        else [args.multi_pod]

    failures = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                try:
                    run_one(arch, shape, multi_pod=mp, out_dir=args.out,
                            force=args.force)
                except Exception as e:  # noqa: BLE001 - report and continue
                    traceback.print_exc()
                    failures.append((arch, shape, mp, repr(e)))
    if failures:
        print(f"\nFAILURES ({len(failures)}):")
        for f in failures:
            print(" ", f)
        return 1
    print("\nall dry-runs passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
