"""Production meshes (TPU v5e numbers).

A function, not a module constant — importing this module never touches jax
device state. Single pod: 16x16 = 256 chips, axes (data, model); multi-pod:
2x16x16 = 512 chips, axes (pod, data, model). The 'pod' axis joins 'data'
for batch/FSDP sharding; 'model' stays within a pod (tensor/expert
parallelism over ICI, never over the cross-pod DCN).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """A small mesh over the actually-present devices (tests, examples)."""
    n = len(jax.devices())
    assert n % model == 0
    return jax.make_mesh((n // model, model), ("data", "model"))


# TPU v5e hardware constants used by the roofline analysis
PEAK_FLOPS_BF16 = 197e12      # per chip
HBM_BW = 819e9                # bytes/s per chip
ICI_BW = 50e9                 # bytes/s per link (per chip, one direction)
HBM_PER_CHIP = 16 * 2 ** 30   # 16 GiB
