"""Mesh sharding rules: logical axes -> mesh axes, spec trees for params,
optimizer states, caches, and input batches.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as PS

from repro import sharding as sh
from repro.models import param as P


def param_rules(mesh: Mesh, mode: str = "train") -> Dict[str, object]:
    """FSDP on the batch axes, tensor/expert parallel on 'model'.

    Decode differs in two ways (both memory/collective driven, see
    EXPERIMENTS.md §Dry-run): output-side embed dims (EMBED_OUT) are
    replicated — sharding an *output* dim over 'data' makes XLA all-gather
    the weight (GBs) instead of the (KB-sized) decode activation — and the
    expert axis spreads over BOTH mesh axes (1 expert/chip for deepseek's
    256) since decode has no optimizer states to co-shard."""
    fsdp = ("pod", "data") if "pod" in mesh.shape else ("data",)
    decode = mode == "decode"
    return {
        P.EMBED: fsdp,
        P.EMBED_OUT: None if decode else fsdp,
        P.VOCAB: "model",
        P.HEADS: "model",
        P.KV_HEADS: "model",
        P.MLP: "model",
        P.EXPERT: fsdp + ("model",) if decode else "model",
        P.LRU: "model",
        P.LORA: None,
        P.HEAD_DIM: None,
        P.STACK: None,
    }


def act_rules(mesh: Mesh, mode: str = "train",
              seq_parallel: bool = True) -> Dict[str, object]:
    """Activation hints. Decode replicates the (tiny) per-step activations
    across the batch axes: with weights 2D-sharded (FSDP x TP), batch-sharded
    decode would force a full weight all-gather per token (measured 15 GB/step
    on mixtral decode_32k); replicated-batch compute instead pays partial-sum
    all-reduces on (B, 1, D) activations — MBs, not GBs. KV caches stay
    batch-sharded (they carry the memory)."""
    batch = ("pod", "data") if "pod" in mesh.shape else ("data",)
    return {
        sh.BATCH: None if mode == "decode" else batch,
        # Megatron-style sequence parallelism between blocks (train/prefill):
        # the residual stream's seq dim shards on 'model', turning the
        # 2x(B,S,D) all-reduces per TP boundary into RS+AG pairs and keeping
        # norms/MLP fully sharded. Dense archs only: GSPMD thrashes the
        # grouped-MoE dispatch under a seq-sharded residual (mixtral train
        # collectives 28 -> 240 s/step measured) — §Perf T1
        sh.SEQ: "model" if (seq_parallel and mode != "decode") else None,
        sh.EMBED: None,
        sh.HEADS: "model",
        sh.KV: "model",
        sh.VOCAB: "model",
        # decode shards experts over BOTH axes to match the decode weight
        # sharding (1 expert/chip for deepseek) — with the activations on
        # 'model' only, GSPMD all-gathered the full f32 expert stack every
        # layer (28 GiB x 58 layers/step measured)
        sh.EXPERT: batch + ("model",) if mode == "decode" else "model",
        # expert-capacity slots shard over the batch axes: without this the
        # (E, C, D) dispatch buffer is replicated (336 GiB/device measured
        # on mixtral train_4k)
        sh.EXP_SLOT: None if mode == "decode" else batch,
        sh.MLP: "model",
    }


def param_pspecs(mesh: Mesh, abstract_params, axes_tree,
                 mode: str = "train"):
    """PartitionSpec tree matching the params structure."""
    rules = param_rules(mesh, mode)
    return jax.tree.map(
        lambda leaf, ax: sh.resolve(rules, ax, shape=leaf.shape, mesh=mesh),
        abstract_params, axes_tree)


def opt_pspecs(mesh: Mesh, param_specs, opt_state_abstract):
    """Optimizer states shard exactly like their parameters (ZeRO)."""
    from repro.optim.adamw import AdamWState
    return AdamWState(step=PS(), mu=param_specs, nu=param_specs)


def batch_pspecs(mesh: Mesh, batch_abstract):
    """Input batches: leading dim sharded over the batch axes if divisible."""
    batch_axes = ("pod", "data") if "pod" in mesh.shape else ("data",)
    size = 1
    for a in batch_axes:
        size *= mesh.shape[a]

    def spec(leaf):
        if leaf.ndim == 0 or leaf.shape[0] % size != 0:
            return PS()
        ba = batch_axes if len(batch_axes) > 1 else batch_axes[0]
        return PS(ba, *([None] * (leaf.ndim - 1)))

    return jax.tree.map(spec, batch_abstract)


def cache_pspecs(mesh: Mesh, cfg, cache_abstract):
    """Decode caches: (stack, batch, ...) with KV-head dims on 'model'.

    KV caches are (R, B, W, KV, hd): batch on the data axes, kv-heads on
    'model' when divisible. Recurrent states (R, B, ...) shard batch, and
    RG-LRU width on 'model'.
    """
    batch_axes = ("pod", "data") if "pod" in mesh.shape else ("data",)
    bsize = 1
    for a in batch_axes:
        bsize *= mesh.shape[a]
    msize = mesh.shape["model"]
    ba = batch_axes if len(batch_axes) > 1 else batch_axes[0]

    def spec(path, leaf):
        dims: list = [None] * leaf.ndim
        if leaf.ndim >= 2 and leaf.shape[1] % bsize == 0:
            dims[1] = ba
        name = path[-1].key if hasattr(path[-1], "key") else ""
        # KV caches (R, B, W, KV, hd): no assigned arch has >= 16 kv heads,
        # so shard the *window* dim on 'model' instead — sequence-parallel
        # decode (sharded-softmax reductions are tiny vs. gathering caches)
        if name in ("k", "v") and leaf.ndim == 5:
            if leaf.shape[3] % msize == 0:
                dims[3] = "model"
            elif leaf.shape[2] % msize == 0:
                dims[2] = "model"
        if name in ("pos", "ckv", "krope") and leaf.ndim >= 3 \
                and leaf.shape[2] % msize == 0:
            dims[2] = "model"      # window dim of MLA caches / pos slots
        if name == "h" and leaf.ndim == 3 and leaf.shape[2] % msize == 0:
            dims[2] = "model"      # RG-LRU state width
        if name == "conv" and leaf.ndim == 4 and leaf.shape[3] % msize == 0:
            dims[3] = "model"
        return PS(*dims)

    return jax.tree_util.tree_map_with_path(spec, cache_abstract)


def named(mesh: Mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, PS))
