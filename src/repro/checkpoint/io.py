"""Pytree checkpointing to .npz (no orbax offline).

Flat key-path encoding keeps the format structure-agnostic: a checkpoint can
be restored into any pytree with the same key paths (used by the federated
trainer and the serving engine alike). Atomic rename guards against torn
writes; ``keep`` bounds disk usage.

Two load shapes:

- ``load_checkpoint(dir, template)`` — restore into a known structure
  (exact key-path match, dtypes coerced to the template's). The training
  path: the caller always holds a params pytree of the right shape.
- ``load_checkpoint_tree(dir)`` — reconstruct nested string-keyed dicts
  straight from the flat key paths, no template. The serving-durability
  path: an engine snapshot's structure (which requests were live, which
  carried a K/V checkpoint) is data, so a cold restart cannot know it in
  advance. Non-array metadata rides as a JSON-encoded ``uint8`` leaf
  (``json_leaf``/``json_unleaf``).
"""
from __future__ import annotations

import json
import os
import re
import tempfile
from typing import Any, Optional, Tuple

import jax
import numpy as np

from repro.utils.tree import flat_paths

_STEP_RE = re.compile(r"step_(\d+)\.npz$")


def save_checkpoint(directory: str, step: int, tree: Any,
                    keep: int = 3) -> str:
    os.makedirs(directory, exist_ok=True)
    flat = flat_paths(tree)
    arrays = {k: np.asarray(v) for k, v in flat.items()}
    path = os.path.join(directory, f"step_{step}.npz")
    fd, tmp = tempfile.mkstemp(dir=directory, suffix=".tmp")
    with os.fdopen(fd, "wb") as f:
        np.savez(f, **arrays)
    os.replace(tmp, path)
    _gc(directory, keep)
    return path


def load_checkpoint(directory: str, template: Any,
                    step: Optional[int] = None) -> Tuple[Any, int]:
    """Restore into the structure of ``template`` (shapes must match)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {directory}")
    path = os.path.join(directory, f"step_{step}.npz")
    with np.load(path) as data:
        flat = {k: data[k] for k in data.files}
    paths = flat_paths(template)
    missing = set(paths) - set(flat)
    extra = set(flat) - set(paths)
    if missing or extra:
        raise ValueError(f"checkpoint mismatch: missing={sorted(missing)[:5]} "
                         f"extra={sorted(extra)[:5]}")
    leaves_in_order = [flat[k] for k in paths]
    treedef = jax.tree.structure(template)
    restored = jax.tree.unflatten(treedef, [
        np.asarray(v, dtype=np.asarray(t).dtype)
        for v, t in zip(leaves_in_order, jax.tree.leaves(template))])
    return restored, step


def load_checkpoint_tree(directory: str,
                         step: Optional[int] = None) -> Tuple[Any, int]:
    """Restore a checkpoint as plain nested dicts, no template: each flat
    key path ``a/b/c`` becomes ``tree["a"]["b"]["c"]``. Dict keys must not
    contain ``/`` (``save_checkpoint`` writers that intend template-free
    restore own that constraint)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {directory}")
    path = os.path.join(directory, f"step_{step}.npz")
    tree: dict = {}
    with np.load(path) as data:
        for key in data.files:
            parts = key.split("/")
            node = tree
            for p in parts[:-1]:
                node = node.setdefault(p, {})
            node[parts[-1]] = data[key]
    return tree, step


def json_leaf(obj: Any) -> np.ndarray:
    """Encode a JSON-able object as a ``uint8`` array leaf, so variable
    host-side metadata (request fields, counters) can ride the same .npz
    envelope as the numeric state."""
    return np.frombuffer(json.dumps(obj).encode("utf-8"),
                         np.uint8).copy()


def json_unleaf(arr: np.ndarray) -> Any:
    return json.loads(np.asarray(arr, np.uint8).tobytes().decode("utf-8"))


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = []
    for name in os.listdir(directory):
        m = _STEP_RE.search(name)
        if m:
            steps.append(int(m.group(1)))
    return max(steps) if steps else None


def _gc(directory: str, keep: int) -> None:
    steps = []
    for name in os.listdir(directory):
        m = _STEP_RE.search(name)
        if m:
            steps.append(int(m.group(1)))
    for s in sorted(steps)[:-keep]:
        os.remove(os.path.join(directory, f"step_{s}.npz"))
