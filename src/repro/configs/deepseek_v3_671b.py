"""deepseek-v3-671b [moe] — MLA, 1 shared + 256 routed experts top-8, MTP
[arXiv:2412.19437].

61L, d_model 7168, 128 heads (MLA: kv_lora 512 + rope 64 compressed cache),
first 3 layers dense (d_ff 18432), remaining 58 MoE (expert d_ff 2048,
256 routed top-8 + 1 shared). vocab 129280. MTP implemented as an optional
depth-1 extra prediction head (mtp_depth=1).

``long_500k`` uses the sliding-window override (MLA cache is compressed but
attention itself is full) — recorded per DESIGN.md §Arch-applicability.
"""
from repro.configs import base as b


def config() -> b.ModelConfig:
    dense = b.BlockDef(mixer=b.MLA, mlp=b.SWIGLU)
    moe = b.BlockDef(mixer=b.MLA, mlp=b.MOE)
    return b.ModelConfig(
        name="deepseek-v3-671b",
        family="moe",
        source="arXiv:2412.19437 (DeepSeek-V3)",
        num_layers=61,
        d_model=7168,
        num_heads=128,
        num_kv_heads=128,
        head_dim=128,
        d_ff=18432,                      # dense layers
        vocab_size=129280,
        stages=(
            b.Stage(blocks=(dense,), repeat=3),
            b.Stage(blocks=(moe,), repeat=58),
        ),
        rope_theta=10000.0,
        moe=b.MoEConfig(num_experts=256, num_experts_per_tok=8,
                        d_ff_expert=2048, num_shared_experts=1,
                        d_ff_shared=2048, router_aux_loss=0.001),
        mla=b.MLAConfig(q_lora_rank=1536, kv_lora_rank=512,
                        qk_nope_head_dim=128, qk_rope_head_dim=64,
                        v_head_dim=128),
        long_context_window=8192,
        mtp_depth=1,
    )


def register():
    from repro.configs import ARCHS
    ARCHS.register("deepseek-v3-671b", config)


register()
