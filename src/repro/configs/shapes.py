"""Assigned input shapes.

``mode`` selects which step gets lowered in the dry-run:
  train   -> train_step (forward + backward + optimizer update)
  prefill -> prefill_step (full-sequence forward, cache populated)
  decode  -> serve_step (ONE new token against a seq_len KV cache)
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    mode: str  # train | prefill | decode

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


def get_shape(name: str) -> InputShape:
    if name not in INPUT_SHAPES:
        raise KeyError(f"unknown input shape {name!r}; known: {sorted(INPUT_SHAPES)}")
    return INPUT_SHAPES[name]
