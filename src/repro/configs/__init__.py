"""Architecture configs (``--arch <id>``) and input-shape registry."""
from __future__ import annotations

from repro.configs.base import (ATTN, GELU_MLP, MLA, MLSTM, MOE, NONE, RGLRU,
                                SLSTM, SWIGLU, BlockDef, FrontendConfig,
                                MLAConfig, ModelConfig, MoEConfig, Stage,
                                dense_stages)
from repro.configs.shapes import INPUT_SHAPES, InputShape, get_shape
from repro.utils.registry import Registry

ARCHS = Registry("architecture")

# import side-effect registration
from repro.configs import (ace_video_query, deepseek_v3_671b, glm4_9b,   # noqa: E402,F401
                           internvl2_2b, mixtral_8x22b, musicgen_medium,
                           qwen3_4b, recurrentgemma_9b, smollm_135m,
                           starcoder2_7b, xlstm_125m)

ASSIGNED_ARCHS = (
    "recurrentgemma-9b", "qwen3-4b", "smollm-135m", "xlstm-125m",
    "mixtral-8x22b", "starcoder2-7b", "deepseek-v3-671b", "musicgen-medium",
    "glm4-9b", "internvl2-2b",
)


def get_config(name: str) -> ModelConfig:
    return ARCHS.get(name)()


__all__ = [
    "ARCHS", "ASSIGNED_ARCHS", "get_config", "ModelConfig", "ModelConfig",
    "MoEConfig", "MLAConfig", "FrontendConfig", "Stage", "BlockDef",
    "INPUT_SHAPES", "InputShape", "get_shape", "dense_stages",
    "ATTN", "MLA", "RGLRU", "SLSTM", "MLSTM", "SWIGLU", "GELU_MLP", "MOE",
    "NONE",
]
