"""internvl2-2b [vlm] — InternViT + InternLM2 backbone [arXiv:2404.16821].

The implemented model is the InternLM2-1.8B language decoder: 24L, d_model
2048, 16 heads (GQA kv=8, head_dim 128), SwiGLU d_ff 8192, vocab 92553
(padded to 92672 for sharding).

Frontend carve-out: the InternViT-300M vision encoder is a stub —
``input_specs`` provides (B, 256, 1024) patch embeddings; a learned 2-layer
projector maps them to d_model and they prefix the text sequence.
``long_500k`` uses the sliding-window override.
"""
from repro.configs import base as b


def config() -> b.ModelConfig:
    return b.ModelConfig(
        name="internvl2-2b",
        family="vlm",
        source="arXiv:2404.16821 (InternVL2; InternLM2-1.8B LM)",
        num_layers=24,
        d_model=2048,
        num_heads=16,
        num_kv_heads=8,
        head_dim=128,
        d_ff=8192,
        vocab_size=92553,
        stages=b.dense_stages(24, mlp=b.SWIGLU),
        rope_theta=1_000_000.0,
        frontend=b.FrontendConfig(kind="vision", embed_dim=1024,
                                  num_prefix_tokens=256),
        long_context_window=8192,
    )


def register():
    from repro.configs import ARCHS
    ARCHS.register("internvl2-2b", config)


register()
