"""smollm-135m [dense] — llama-arch small model
[hf:HuggingFaceTB/SmolLM-135M].

30L, d_model 576, 9 heads (GQA kv=3), SwiGLU d_ff 1536, vocab 49152.
Also the default *edge* model of the ACE inter-model cascade (the
MobileNetV2-role of the paper's video query, transposed to LM serving).
"""
from repro.configs import base as b


def config() -> b.ModelConfig:
    return b.ModelConfig(
        name="smollm-135m",
        family="dense",
        source="hf:HuggingFaceTB/SmolLM-135M",
        num_layers=30,
        d_model=576,
        num_heads=9,
        num_kv_heads=3,
        head_dim=64,
        d_ff=1536,
        vocab_size=49152,
        stages=b.dense_stages(30, mlp=b.SWIGLU),
        rope_theta=10000.0,
        tie_embeddings=True,
        long_context_window=8192,
    )


def register():
    from repro.configs import ARCHS
    ARCHS.register("smollm-135m", config)


register()
