"""Paper §5 — the ACE intelligent video query application config.

The paper deploys:
  OD   frame-differencing object detector (per edge node, not a DNN),
  EOC  MobileNetV2-class binary classifier trained on-the-fly (edge),
  COC  ResNet152-class multi-class classifier (cloud),
with the Basic Policy thresholds (accept >= 0.8, drop < 0.1) and the
Advanced Policy (EIL-driven load balancing + threshold shrinking).

We keep the roles and capacity *ratio* (COC ~40x EOC params, matching
ResNet152:MobileNetV2 ~58M:3.5M) with compact conv classifiers; the paper's
claims are about the cascade, not the specific CNNs (DESIGN.md §2).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple


@dataclasses.dataclass(frozen=True)
class ClassifierConfig:
    name: str
    image_size: int            # input crops are (size, size, 3)
    widths: Tuple[int, ...]    # conv channel widths (stride-2 stages)
    num_classes: int
    num_blocks_per_stage: int = 1


@dataclasses.dataclass(frozen=True)
class VideoQueryConfig:
    """The full application (paper §5.1.2 component set + §5.1.1 infra)."""
    # models
    eoc: ClassifierConfig = ClassifierConfig(
        name="eoc", image_size=32, widths=(16, 32, 64), num_classes=2)
    coc: ClassifierConfig = ClassifierConfig(
        name="coc", image_size=32, widths=(64, 128, 256, 512), num_classes=10,
        num_blocks_per_stage=2)
    # Basic Policy thresholds (paper: 80% accept, 10% drop)
    accept_threshold: float = 0.80
    drop_threshold: float = 0.10
    # infrastructure (paper §5.1.1)
    num_edge_clouds: int = 3
    nodes_per_ec: int = 4              # 1 x86 mini-PC + 3 Raspberry Pi
    uplink_mbps: float = 20.0
    downlink_mbps: float = 40.0
    wan_delay_ms: float = 50.0         # "practical"; 0.0 = "ideal"
    lan_mbps: float = 100.0
    # workload (paper §5.2)
    crop_bytes: int = 12_000           # JPEG crop ~12 KB
    eoc_infer_ms: float = 44.0         # paper: ">44ms on edge node"
    coc_infer_ms: float = 32.3         # paper: "about 32.3ms on CC"
    frame_interval_s: float = 0.5      # sampling interval, swept 0.5 -> 0.1


def config() -> VideoQueryConfig:
    return VideoQueryConfig()


def register():
    from repro.configs import ARCHS
    ARCHS.register("ace-video-query", config)


register()
