"""starcoder2-7b [dense] — GQA, RoPE, sliding-window 4096
[arXiv:2402.19173].

32L, d_model 4608, 36 heads (GQA kv=4, head_dim 128), gelu MLP d_ff 18432,
vocab 49152. StarCoder2 trains with SWA-4096 -> ``long_500k`` native.
"""
from repro.configs import base as b


def config() -> b.ModelConfig:
    return b.ModelConfig(
        name="starcoder2-7b",
        family="dense",
        source="arXiv:2402.19173 (StarCoder2)",
        num_layers=32,
        d_model=4608,
        num_heads=36,
        num_kv_heads=4,
        head_dim=128,
        d_ff=18432,
        vocab_size=49152,
        stages=b.dense_stages(32, mlp=b.GELU_MLP, window=4096),
        rope_theta=100_000.0,
        sub_quadratic=True,
    )


def register():
    from repro.configs import ARCHS
    ARCHS.register("starcoder2-7b", config)


register()
