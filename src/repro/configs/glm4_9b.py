"""glm4-9b [dense] — RoPE, GQA kv=2 [hf:THUDM/glm-4-9b].

40L, d_model 4096, 32 heads (head_dim 128), GQA kv=2, SwiGLU d_ff 13696,
vocab 151552. Full attention; ``long_500k`` uses the sliding-window override.
"""
from repro.configs import base as b


def config() -> b.ModelConfig:
    return b.ModelConfig(
        name="glm4-9b",
        family="dense",
        source="hf:THUDM/glm-4-9b",
        num_layers=40,
        d_model=4096,
        num_heads=32,
        num_kv_heads=2,
        head_dim=128,
        d_ff=13696,
        vocab_size=151552,
        stages=b.dense_stages(40, mlp=b.SWIGLU),
        rope_theta=10000.0,
        long_context_window=8192,
    )


def register():
    from repro.configs import ARCHS
    ARCHS.register("glm4-9b", config)


register()
