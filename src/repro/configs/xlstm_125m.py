"""xlstm-125m [ssm] — alternating sLSTM + mLSTM blocks [arXiv:2405.04517].

12L, d_model 768, 4 heads, d_ff=0 (xLSTM blocks carry their own up/down
projections; no separate MLP). Recurrent state is bounded -> native
``long_500k``. vocab 50304 (GPT-NeoX tokenizer, already 256-aligned).
"""
from repro.configs import base as b


def config() -> b.ModelConfig:
    slstm = b.BlockDef(mixer=b.SLSTM, mlp=b.NONE)
    mlstm = b.BlockDef(mixer=b.MLSTM, mlp=b.NONE)
    return b.ModelConfig(
        name="xlstm-125m",
        family="ssm",
        source="arXiv:2405.04517 (xLSTM)",
        num_layers=12,
        d_model=768,
        num_heads=4,
        num_kv_heads=4,
        head_dim=192,
        d_ff=0,
        vocab_size=50304,
        stages=(b.Stage(blocks=(mlstm, slstm), repeat=6),),
        sub_quadratic=True,
    )


def register():
    from repro.configs import ARCHS
    ARCHS.register("xlstm-125m", config)


register()
