"""qwen3-4b [dense] — qk-norm, GQA kv=8 [hf:Qwen/Qwen3-8B family].

36L, d_model 2560, 32 heads (head_dim 128, decoupled from d_model), GQA kv=8,
SwiGLU d_ff 9728, vocab 151936. Full attention; ``long_500k`` uses the
sliding-window override (window 8192) recorded here.
"""
from repro.configs import base as b


def config() -> b.ModelConfig:
    return b.ModelConfig(
        name="qwen3-4b",
        family="dense",
        source="hf:Qwen/Qwen3-8B (4B sibling config)",
        num_layers=36,
        d_model=2560,
        num_heads=32,
        num_kv_heads=8,
        head_dim=128,
        d_ff=9728,
        vocab_size=151936,
        stages=b.dense_stages(36, mlp=b.SWIGLU),
        rope_theta=1_000_000.0,
        use_qk_norm=True,
        tie_embeddings=True,
        long_context_window=8192,
    )


def register():
    from repro.configs import ARCHS
    ARCHS.register("qwen3-4b", config)


register()
