"""mixtral-8x22b [moe] — 8 experts top-2, sliding-window attention
[arXiv:2401.04088].

56L, d_model 6144, 48 heads (GQA kv=8, head_dim 128), expert d_ff 16384,
vocab 32768. SWA window 4096 -> sub-quadratic decode, ``long_500k`` native.
"""
from repro.configs import base as b

SWA_WINDOW = 4096


def config() -> b.ModelConfig:
    blk = b.BlockDef(mixer=b.ATTN, mlp=b.MOE, window=SWA_WINDOW)
    return b.ModelConfig(
        name="mixtral-8x22b",
        family="moe",
        source="arXiv:2401.04088 (Mixtral of Experts)",
        num_layers=56,
        d_model=6144,
        num_heads=48,
        num_kv_heads=8,
        head_dim=128,
        d_ff=16384,
        vocab_size=32768,
        stages=(b.Stage(blocks=(blk,), repeat=56),),
        rope_theta=1_000_000.0,
        moe=b.MoEConfig(num_experts=8, num_experts_per_tok=2,
                        d_ff_expert=16384),
        sub_quadratic=True,
    )


def register():
    from repro.configs import ARCHS
    ARCHS.register("mixtral-8x22b", config)


register()
