"""recurrentgemma-9b [hybrid] — RG-LRU + local attention, 2:1 recurrent:attn
pattern [arXiv:2402.19427].

38 layers = 12 × (rec, rec, local-attn) + 2 trailing recurrent blocks.
Local attention window 2048; GQA kv=1 (MQA); GeGLU MLP; logit soft-cap 30.
Sub-quadratic by construction (bounded recurrent state + windowed cache), so
``long_500k`` runs natively.
"""
from repro.configs import base as b


def config() -> b.ModelConfig:
    rec = b.BlockDef(mixer=b.RGLRU, mlp=b.GELU_MLP)
    attn = b.BlockDef(mixer=b.ATTN, mlp=b.GELU_MLP, window=2048)
    return b.ModelConfig(
        name="recurrentgemma-9b",
        family="hybrid",
        source="arXiv:2402.19427 (RecurrentGemma / Griffin)",
        num_layers=38,
        d_model=4096,
        num_heads=16,
        num_kv_heads=1,
        head_dim=256,
        d_ff=12288,
        vocab_size=256000,
        stages=(
            b.Stage(blocks=(rec, rec, attn), repeat=12),
            b.Stage(blocks=(rec,), repeat=2),
        ),
        rope_theta=10000.0,
        logit_softcap=30.0,
        rglru_conv_width=4,
        sub_quadratic=True,
    )


def register():
    from repro.configs import ARCHS
    ARCHS.register("recurrentgemma-9b", config)


register()
