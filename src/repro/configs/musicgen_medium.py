"""musicgen-medium [audio] — decoder-only LM over EnCodec tokens
[arXiv:2306.05284].

48L, d_model 1536, 24 heads (MHA, kv=24, head_dim 64), gelu MLP d_ff 6144,
vocab 2048 per codebook, 4 codebooks with the MusicGen delay pattern
(embeddings summed, one LM head per codebook).

Frontend carve-out: the EnCodec conv codec producing the token streams is a
stub — ``input_specs`` provides the (B, S, 4) token grid directly.
``long_500k`` uses the sliding-window override.
"""
from repro.configs import base as b


def config() -> b.ModelConfig:
    return b.ModelConfig(
        name="musicgen-medium",
        family="audio",
        source="arXiv:2306.05284 (MusicGen)",
        num_layers=48,
        d_model=1536,
        num_heads=24,
        num_kv_heads=24,
        head_dim=64,
        d_ff=6144,
        vocab_size=2048,
        stages=b.dense_stages(48, mlp=b.GELU_MLP),
        rope_theta=10000.0,
        frontend=b.FrontendConfig(kind="audio", num_codebooks=4),
        long_context_window=8192,
    )


def register():
    from repro.configs import ARCHS
    ARCHS.register("musicgen-medium", config)


register()
