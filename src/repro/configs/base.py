"""Architecture configuration schema.

Every assigned architecture is expressed as a :class:`ModelConfig` composed of
*stages*: a stage is a short sequence of block definitions repeated ``repeat``
times via ``jax.lax.scan`` (keeping lowered HLO small for the multi-pod
dry-run).  A block pairs a temporal mixer (attention / RG-LRU / sLSTM / mLSTM
/ MLA) with a channel mixer (SwiGLU / GELU-MLP / MoE / none).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Block / stage definitions
# ---------------------------------------------------------------------------

# temporal mixer kinds
ATTN = "attn"          # (GQA/MHA) softmax attention, optional sliding window
MLA = "mla"            # DeepSeek multi-head latent attention
RGLRU = "rglru"        # RecurrentGemma real-gated linear recurrent unit
SLSTM = "slstm"        # xLSTM scalar-memory LSTM
MLSTM = "mlstm"        # xLSTM matrix-memory LSTM

# channel mixer kinds
SWIGLU = "swiglu"
GELU_MLP = "gelu_mlp"
MOE = "moe"
NONE = "none"          # block has no separate MLP (xLSTM blocks)


@dataclasses.dataclass(frozen=True)
class BlockDef:
    mixer: str = ATTN
    mlp: str = SWIGLU
    window: Optional[int] = None   # sliding-window size for ATTN (None = full)

    def __post_init__(self):
        assert self.mixer in (ATTN, MLA, RGLRU, SLSTM, MLSTM), self.mixer
        assert self.mlp in (SWIGLU, GELU_MLP, MOE, NONE), self.mlp


@dataclasses.dataclass(frozen=True)
class Stage:
    """``blocks`` repeated ``repeat`` times (scanned when repeat > 1)."""
    blocks: Tuple[BlockDef, ...]
    repeat: int


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    num_experts_per_tok: int
    d_ff_expert: int
    num_shared_experts: int = 0
    d_ff_shared: int = 0
    router_aux_loss: float = 0.01   # load-balance loss coefficient


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class FrontendConfig:
    """Modality frontend stub (the one allowed carve-out).

    kind="vision": ``input_specs`` provides patch embeddings
    ``(B, num_prefix_tokens, embed_dim)`` from a stubbed ViT; a learned
    projector maps them to d_model and they prefix the text tokens.
    kind="audio": tokens carry ``num_codebooks`` parallel EnCodec streams;
    the conv codec producing them is the stub.
    """
    kind: str = "none"              # none | vision | audio
    embed_dim: int = 0              # vision encoder output dim
    num_prefix_tokens: int = 0      # vision tokens prepended to the sequence
    num_codebooks: int = 1          # audio codebook streams


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | audio | vlm
    source: str                     # citation for the config
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    stages: Tuple[Stage, ...]
    head_dim: int = 0               # 0 -> d_model // num_heads
    rope_theta: float = 10000.0
    use_qk_norm: bool = False
    rms_eps: float = 1e-6
    tie_embeddings: bool = False
    logit_softcap: float = 0.0      # 0 = disabled (recurrentgemma uses 30)
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    frontend: FrontendConfig = FrontendConfig()
    # recurrent hyper-params
    rglru_conv_width: int = 4       # temporal conv1d preceding the RG-LRU
    lru_width: int = 0              # 0 -> d_model
    # decode behaviour
    sub_quadratic: bool = False     # True if decode state is bounded (SSM/SWA)
    long_context_window: int = 0    # >0: window override used for long_500k
    # multi-token prediction (DeepSeek-V3); extra depth-1 MTP head when > 0
    mtp_depth: int = 0
    param_dtype: str = "bfloat16"

    # ------------------------------------------------------------------
    def __post_init__(self):
        n = sum(len(s.blocks) * s.repeat for s in self.stages)
        assert n == self.num_layers, (
            f"{self.name}: stages define {n} blocks != num_layers={self.num_layers}")
        assert self.num_heads % self.num_kv_heads == 0 or self.mla is not None

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.num_heads)

    @property
    def resolved_lru_width(self) -> int:
        return self.lru_width or self.d_model

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a multiple of 256 for MXU alignment / sharding."""
        return ((self.vocab_size + 255) // 256) * 256

    def with_overrides(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # ------------------------------------------------------------------
    def reduced(self) -> "ModelConfig":
        """Smoke-test variant: ≤2 layers worth of stages, d_model ≤ 512,
        ≤4 experts — same family, runnable on one CPU device."""
        d_model = min(self.d_model, 256)
        n_heads = min(self.num_heads, 4)
        ratio = max(1, self.num_heads // self.num_kv_heads)
        n_kv = max(1, n_heads // min(ratio, n_heads))
        head_dim = 64
        stages = _reduce_stages(self.stages)
        n_layers = sum(len(s.blocks) * s.repeat for s in stages)
        moe = None
        if self.moe is not None:
            moe = dataclasses.replace(
                self.moe,
                num_experts=min(self.moe.num_experts, 4),
                num_experts_per_tok=min(self.moe.num_experts_per_tok, 2),
                d_ff_expert=128,
                num_shared_experts=min(self.moe.num_shared_experts, 1),
                d_ff_shared=128 * max(1, min(self.moe.num_shared_experts, 1)),
            )
        mla = None
        if self.mla is not None:
            mla = MLAConfig(q_lora_rank=64, kv_lora_rank=32,
                            qk_nope_head_dim=32, qk_rope_head_dim=16,
                            v_head_dim=32)
        frontend = self.frontend
        if frontend.kind == "vision":
            frontend = dataclasses.replace(frontend, embed_dim=64,
                                           num_prefix_tokens=8)
        return dataclasses.replace(
            self,
            name=self.name + "-reduced",
            num_layers=n_layers,
            d_model=d_model,
            num_heads=n_heads,
            num_kv_heads=n_kv,
            head_dim=head_dim,
            d_ff=min(self.d_ff, 512) if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 512),
            stages=stages,
            moe=moe,
            mla=mla,
            lru_width=0,
            frontend=frontend,
            param_dtype="float32",
        )


def _reduce_stages(stages: Tuple[Stage, ...]) -> Tuple[Stage, ...]:
    """Keep one repetition of each distinct stage (window shrunk)."""
    out = []
    for s in stages:
        blocks = tuple(
            dataclasses.replace(b, window=min(b.window, 16) if b.window else None)
            for b in s.blocks)
        out.append(Stage(blocks=blocks, repeat=1))
    return tuple(out)


def dense_stages(n_layers: int, mlp: str = SWIGLU,
                 window: Optional[int] = None) -> Tuple[Stage, ...]:
    return (Stage(blocks=(BlockDef(mixer=ATTN, mlp=mlp, window=window),),
                  repeat=n_layers),)


def apply_long_context(cfg: ModelConfig) -> ModelConfig:
    """Variant used for ``long_500k`` on otherwise-quadratic archs: every
    full-attention block gets the config's sliding-window override. Archs
    that are already sub-quadratic are returned unchanged (DESIGN.md §5)."""
    if cfg.sub_quadratic:
        return cfg
    assert cfg.long_context_window > 0, (
        f"{cfg.name}: long_500k needs sub_quadratic or long_context_window")
    w = cfg.long_context_window
    stages = tuple(
        Stage(blocks=tuple(
            dataclasses.replace(
                b, window=min(b.window, w) if b.window else w)
            if b.mixer in (ATTN, MLA) else b
            for b in s.blocks), repeat=s.repeat)
        for s in cfg.stages)
    return dataclasses.replace(cfg, name=cfg.name + "-swa",
                               stages=stages, sub_quadratic=True)
