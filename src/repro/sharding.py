"""Logical activation sharding hints.

Model code is mesh-agnostic: it annotates activations with *logical* axis
names via :func:`hint`. The launcher activates a mesh + rule set with
:func:`use_rules`; outside that context hints are no-ops (single-device smoke
tests never touch device state).
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as PS

# logical activation axes
BATCH = "act_batch"
SEQ = "act_seq"
EMBED = "act_embed"
HEADS = "act_heads"
KV = "act_kv"
VOCAB = "act_vocab"
EXPERT = "act_expert"
EXP_SLOT = "act_exp_slot"
MLP = "act_mlp"

_state = threading.local()


def _current():
    return getattr(_state, "ctx", None)


@contextlib.contextmanager
def use_rules(mesh: Mesh, rules: Dict[str, object]):
    """Activate (mesh, logical-axis -> mesh-axis rules) for hints."""
    prev = _current()
    _state.ctx = (mesh, dict(rules))
    try:
        yield
    finally:
        _state.ctx = prev


def resolve(rules: Dict[str, object], axes: Sequence[Optional[str]],
            shape: Optional[Tuple[int, ...]] = None,
            mesh: Optional[Mesh] = None) -> PS:
    """Map logical axes to a PartitionSpec.

    Two pragmatic guards: a sharding is dropped when the dim is not divisible
    by the mesh-axis product (e.g. 9 heads over a 16-way model axis -> the
    projection is replicated on 'model'), and a mesh axis is used at most
    once per spec in logical-axis order (e.g. deepseek expert weights
    (E, D, F): EXPERT wins 'model', so MLP falls back to replicated; mixtral
    (8 experts, non-divisible) instead gives 'model' to MLP — tensor
    parallelism inside each expert)."""
    spec = []
    used = set()
    for i, ax in enumerate(axes):
        mesh_axes = rules.get(ax) if ax is not None else None
        if mesh_axes is None:
            spec.append(None)
            continue
        if isinstance(mesh_axes, str):
            mesh_axes = (mesh_axes,)
        mesh_axes = tuple(m for m in mesh_axes if m not in used)
        if not mesh_axes:
            spec.append(None)
            continue
        if shape is not None and mesh is not None:
            size = 1
            for m in mesh_axes:
                size *= mesh.shape[m]
            if shape[i] % size != 0:
                spec.append(None)
                continue
        used.update(mesh_axes)
        spec.append(tuple(mesh_axes) if len(mesh_axes) > 1 else mesh_axes[0])
    return PS(*spec)


def hint(x, axes: Sequence[Optional[str]]):
    """Constrain activation sharding if a mesh context is active."""
    ctx = _current()
    if ctx is None:
        return x
    mesh, rules = ctx
    spec = resolve(rules, axes, shape=x.shape, mesh=mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


@contextlib.contextmanager
def maybe_rules(mesh: Optional[Mesh], rules: Optional[Dict[str, object]]):
    """:func:`use_rules` when a mesh is given, a literal no-op otherwise.

    The serving/model entry points take ``mesh=None, rules=None`` and wrap
    their bodies in this: with ``mesh=None`` every trace is byte-identical
    to the pre-mesh code path (hints never fire, no new jit arguments), so
    the single-device executable set is provably unchanged."""
    if mesh is None:
        yield
        return
    with use_rules(mesh, rules or {}):
        yield
