"""The reusable in-app controller (paper §4.4.2).

ACE 'constructs a series of general in-app control operations (e.g., start,
filter, aggregate, and terminate), component monitoring operations, and a
basic control policy. ... The CC controller conducts global coordination
related operations, and the EC controller coordinates components within the
EC. Resource-level services support interactions between CC and EC
controllers.'

Developers inherit :class:`InAppController` and override the policy for
customized optimizations — exactly how the video query's AP is built.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional

from repro.core.inapp.policies import BasicPolicy


class InAppController:
    """Control-plane component (deployable like any workload component)."""

    def __init__(self, policy: Optional[BasicPolicy] = None,
                 scope: str = "ec"):
        self.policy = policy or BasicPolicy()
        self.scope = scope          # "ec" (local) | "cc" (global)
        self.ctx = None
        self._filters: Dict[str, Callable[[Any], bool]] = {}
        self._aggregates: Dict[str, list] = {}
        self.started = False

    # -- component lifecycle ----------------------------------------------------
    def start(self, ctx) -> None:
        self.ctx = ctx
        self.started = True
        # component monitoring: EIL reports flow in over the local broker
        ctx.subscribe("app/*/eil", self._on_eil)
        ctx.log("controller_started", scope=self.scope)

    def stop(self) -> None:
        self.started = False

    # -- general control operations (paper: start/filter/aggregate/terminate) --
    def op_start(self, component: str, payload=None) -> None:
        self.ctx.publish(f"app/{component}/start", payload or {})

    def op_terminate(self, component: str) -> None:
        self.ctx.publish(f"app/{component}/terminate", {})

    def op_filter(self, stream: str, pred: Callable[[Any], bool]) -> None:
        self._filters[stream] = pred

    def passes(self, stream: str, item) -> bool:
        pred = self._filters.get(stream)
        return True if pred is None else bool(pred(item))

    def op_aggregate(self, stream: str, item) -> list:
        self._aggregates.setdefault(stream, []).append(item)
        return self._aggregates[stream]

    # -- monitoring feedback -----------------------------------------------------
    def _on_eil(self, msg) -> None:
        comp = msg.topic.split("/")[1]
        self.policy.observe_eil(comp, float(msg.payload))

    # -- the decision surface used by workload components -----------------------
    def decide(self, confidence: float):
        return self.policy.classify_decision(confidence)

    def upload_target(self) -> str:
        return self.policy.upload_target()


class ECController(InAppController):
    """Local (per-EC) coordination; forwards summaries to the CC controller
    through the bridged message service."""

    def __init__(self, policy=None):
        super().__init__(policy, scope="ec")

    def report_to_cc(self, kind: str, payload) -> None:
        self.ctx.publish(f"app/cc/{kind}", payload)


class CCController(InAppController):
    """Global coordination: receives EC summaries, may push policy updates."""

    def __init__(self, policy=None):
        super().__init__(policy, scope="cc")

    def start(self, ctx) -> None:
        super().start(ctx)
        ctx.subscribe("app/cc/*", self._on_report)
        self.reports = []

    def _on_report(self, msg) -> None:
        self.reports.append((msg.topic, msg.payload))

    def broadcast_policy(self, update: dict) -> None:
        """Push new thresholds to every EC controller (bridged topic)."""
        self.ctx.publish("app/policy/update", update)
