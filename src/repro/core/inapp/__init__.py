"""Reusable in-app controller (paper §4.4.2): control/workload plane
separation, general control operations, BP/AP policies."""
from repro.core.inapp.controller import InAppController, ECController, CCController
from repro.core.inapp.policies import BasicPolicy, AdvancedPolicy

__all__ = ["InAppController", "ECController", "CCController",
           "BasicPolicy", "AdvancedPolicy"]
