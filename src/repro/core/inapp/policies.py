"""In-app control policies for the cascade (paper §5.1.2).

Basic Policy (BP): pure confidence thresholds —
  conf >= accept_threshold  -> identified at the edge (metadata to RS)
  conf <  drop_threshold    -> dropped
  otherwise                 -> escalated to COC on the CC.

Advanced Policy (AP), inheriting BP (the paper's customization mechanism):
  * collects and EWMA-estimates the E2E inference latencies (EIL) of EOC and
    COC from monitoring reports;
  * load-balances OD crop uploads toward the lower-EIL classifier
    ('always sent to the one with a lower estimated EIL');
  * shrinks the confidence band when either EIL deteriorates, reducing
    EOC->COC escalations.
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass
class Decision:
    route: str                 # "accept" | "drop" | "escalate"
    target: str = "eoc"        # initial upload target: "eoc" | "coc"


class BasicPolicy:
    def __init__(self, accept_threshold: float = 0.8,
                 drop_threshold: float = 0.1):
        self.accept0 = accept_threshold
        self.drop0 = drop_threshold
        self.accept = accept_threshold
        self.drop = drop_threshold

    # -- crop scheduling at the edge classifier --------------------------------
    def classify_decision(self, confidence: float) -> Decision:
        if confidence >= self.accept:
            return Decision("accept")
        if confidence < self.drop:
            return Decision("drop")
        return Decision("escalate")

    # -- OD upload target (BP always uses the edge classifier) -----------------
    def upload_target(self, now: float = 0.0) -> str:
        return "eoc"

    def observe_eil(self, component: str, eil_s: float,
                    now: float = 0.0) -> None:
        pass  # BP is static


class AdvancedPolicy(BasicPolicy):
    def __init__(self, accept_threshold: float = 0.8,
                 drop_threshold: float = 0.1, *, ewma: float = 0.2,
                 deteriorate_s: float = 0.3, shrink: float = 0.25,
                 recover: float = 0.05, stale_tau_s: float = 3.0):
        super().__init__(accept_threshold, drop_threshold)
        self.ewma = ewma
        self.deteriorate_s = deteriorate_s
        self.shrink = shrink
        self.recover = recover
        self.stale_tau_s = stale_tau_s
        self.eil: dict = {"eoc": None, "coc": None}
        self.last_obs: dict = {"eoc": 0.0, "coc": 0.0}
        self.adapt_interval_s = 1.0
        self._last_adapt = -1e9

    def observe_eil(self, component: str, eil_s: float,
                    now: float = 0.0) -> None:
        prev = self.eil.get(component)
        self.eil[component] = (eil_s if prev is None
                               else (1 - self.ewma) * prev + self.ewma * eil_s)
        self.last_obs[component] = now
        # rate-limit threshold adaptation: one step per adapt interval,
        # otherwise per-crop observations compound the shrink within ms
        if now - self._last_adapt >= self.adapt_interval_s:
            self._last_adapt = now
            self._adapt()

    def _estimate(self, component: str, now: float = 0.0) -> float:
        """EWMA estimate, decayed when stale — an unobserved classifier is
        re-probed rather than starved forever."""
        v = self.eil.get(component)
        if v is None:
            return 0.0
        import math
        age = max(0.0, now - self.last_obs.get(component, 0.0))
        return v * math.exp(-age / self.stale_tau_s)

    def upload_target(self, now: float = 0.0) -> str:
        """Load balancing (paper: 'always sent to the one with a lower
        estimated EIL')."""
        return ("eoc" if self._estimate("eoc", now) <=
                self._estimate("coc", now) else "coc")

    def _adapt(self) -> None:
        """Shrink the (drop, accept) band when either EIL deteriorates —
        fewer EOC->COC escalations; relax back toward BP when healthy."""
        worst = max(self._estimate("eoc"), self._estimate("coc"))
        if worst > self.deteriorate_s:
            band = self.accept - self.drop
            self.accept = max(0.5, self.accept - self.shrink * band)
            self.drop = min(0.45, self.drop + self.shrink * band)
        else:
            self.accept = min(self.accept0, self.accept + self.recover)
            self.drop = max(self.drop0, self.drop - self.recover)
