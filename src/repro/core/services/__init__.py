"""Resource-level services (paper §4.3.2): message, object store, file."""
from repro.core.services.object_store import ObjectStore
from repro.core.services.file_service import FileService

__all__ = ["ObjectStore", "FileService"]
