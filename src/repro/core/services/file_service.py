"""Resource-level file service (paper §4.3.2, Fig. 2 links ③—⑥).

Control flow (offers, requests, completions) is *separated from the data
flow* and carried by the resource-level message service over its bridged
links; the data flow goes through the object store across the network model.
This is exactly the paper's design: directly bridging file services (e.g.
by file synchronization) would be expensive, so the message service carries
control and object storage carries data.

Typical use: an EC component ``put``s a locally-trained model; the CC (or
another EC) is notified via the bridged ``ace/file/*`` topic and ``get``s it.
"""
from __future__ import annotations

import itertools
from typing import Any, Callable, Dict, Optional

from repro.core.ids import ClusterId
from repro.core.network import NetworkModel
from repro.core.pubsub import MessageService
from repro.core.services.object_store import ObjectStore
from repro.core.sim import SimClock


class FileService:
    def __init__(self, msg: MessageService, store: ObjectStore,
                 network: Optional[NetworkModel], clock: SimClock,
                 cc_cluster: ClusterId):
        self.msg = msg
        self.store = store
        self.network = network
        self.clock = clock
        self.cc = cc_cluster
        self._seq = itertools.count()

    # -- write path (Fig. 2: ③ control, ⑤ data) ------------------------------
    def put(self, bucket: str, key: str, data: Any, nbytes: int,
            src_cluster: ClusterId, *, lifecycle: str = "temporary",
            on_done: Optional[Callable[[], None]] = None) -> None:
        """Upload an object; control message announces availability after the
        (simulated) data transfer to the CC-hosted store completes."""
        def complete():
            self.store.put(bucket, key, data, nbytes, lifecycle)
            # control-plane notification on the bridged message service
            self.msg.broker(src_cluster).publish(
                f"ace/file/available/{bucket}/{key}",
                {"bucket": bucket, "key": key, "nbytes": nbytes},
                nbytes=200, src="file-service")
            if on_done:
                on_done()

        if self.network is None or src_cluster == self.cc:
            complete()
        else:
            self.network.send(src_cluster, self.cc, nbytes, complete)

    # -- read path (Fig. 2: ④ control, ⑥ data) -------------------------------
    def get(self, bucket: str, key: str, dst_cluster: ClusterId,
            callback: Callable[[Any], None]) -> None:
        """Fetch an object to ``dst_cluster``; callback fires when the data
        transfer lands (control request + object download)."""
        obj = self.store.get(bucket, key)
        if obj is None:
            raise KeyError(f"{bucket}/{key} not in object store")

        def deliver():
            callback(obj.data)

        if self.network is None or dst_cluster == self.cc:
            deliver()
        else:
            self.network.send(self.cc, dst_cluster, obj.nbytes, deliver)

    def on_available(self, cluster: ClusterId, pattern: str,
                     fn: Callable[[dict], None]) -> None:
        """Subscribe to availability notifications (control plane)."""
        self.msg.broker(cluster).subscribe(
            f"ace/file/available/{pattern}", lambda m: fn(m.payload))
