"""Object storage service — the data plane used by the file service
(paper Fig. 2 links ⑤/⑥: 'the proverbial object storage service is used to
handle the data flow for transmission simplification').
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, Optional


@dataclasses.dataclass
class StoredObject:
    key: str
    data: Any
    nbytes: int
    lifecycle: str = "temporary"    # temporary | permanent (paper §4.3.2)
    created_at: float = 0.0


class ObjectStore:
    """A bucketed key-value object store hosted on the CC."""

    def __init__(self):
        self._buckets: Dict[str, Dict[str, StoredObject]] = {}

    def put(self, bucket: str, key: str, data: Any, nbytes: int,
            lifecycle: str = "temporary") -> StoredObject:
        obj = StoredObject(key, data, nbytes, lifecycle, time.monotonic())
        self._buckets.setdefault(bucket, {})[key] = obj
        return obj

    def get(self, bucket: str, key: str) -> Optional[StoredObject]:
        return self._buckets.get(bucket, {}).get(key)

    def delete(self, bucket: str, key: str) -> bool:
        return self._buckets.get(bucket, {}).pop(key, None) is not None

    def gc_temporary(self, bucket: str) -> int:
        """Drop temporary objects (end-of-application cleanup)."""
        b = self._buckets.get(bucket, {})
        victims = [k for k, o in b.items() if o.lifecycle == "temporary"]
        for k in victims:
            del b[k]
        return len(victims)

    def keys(self, bucket: str):
        return sorted(self._buckets.get(bucket, {}))
