"""Platform-layer controller (paper §4.2.1, Fig. 4 step ②): transforms the
orchestrator's deployment plan into per-node deployment instructions
(the docker-compose analog) and distributes them to node agents through the
Pub/Sub service. Also executes thorough and incremental updates (§4.4.3) and
shields failed nodes.
"""
from __future__ import annotations

from typing import Dict, List

from repro.core.api_server import ApiServer, AppRecord, InfraRecord
from repro.core.orchestrator import DeploymentPlan, Instance, Orchestrator
from repro.core.pubsub import MessageService
from repro.core.topology import Topology
from repro.utils.logging import EventLog


class Controller:
    def __init__(self, api: ApiServer, msg: MessageService,
                 orchestrator: Orchestrator, monitor: EventLog):
        self.api = api
        self.msg = msg
        self.orchestrator = orchestrator
        self.monitor = monitor

    # -- deployment (Fig. 4) --------------------------------------------------
    def deploy(self, app: AppRecord, infra: InfraRecord) -> DeploymentPlan:
        plan = self.orchestrator.plan(app.topology, infra)
        app.plan = plan
        app.status = "planned"
        # deploy in dependency order: a component's 'connections' (the
        # components it talks to) come up before it does, so no message from
        # a fresh component is lost on a not-yet-subscribed peer
        for name in self._dependency_order(app.topology):
            for inst in plan.instances.get(name, []):
                self._send_deploy(infra, inst)
        app.status = "deployed"
        self.monitor.log("app_deployed", app=app.app,
                         instances=len(plan.all_instances()))
        return plan

    @staticmethod
    def _dependency_order(topo: Topology) -> List[str]:
        """Topological order with dependencies (connections) first."""
        order: List[str] = []
        seen: set = set()

        def visit(name: str, stack: tuple) -> None:
            if name in seen or name in stack:
                return          # already placed, or a cycle -> stable order
            for dep in topo.components[name].connections:
                visit(dep, stack + (name,))
            seen.add(name)
            order.append(name)

        for name in topo.components:
            visit(name, ())
        return order

    def remove(self, app: AppRecord, infra: InfraRecord) -> None:
        if app.plan is None:
            return
        for inst in app.plan.all_instances():
            self._send_remove(infra, inst)
        app.status = "removed"
        self.monitor.log("app_removed", app=app.app)

    # -- updates (paper §4.4.3) -----------------------------------------------
    def thorough_update(self, app: AppRecord, infra: InfraRecord,
                        new_topo: Topology) -> DeploymentPlan:
        """Delete the previous application and repeat the entire deployment."""
        self.remove(app, infra)
        app.topology = new_topo
        return self.deploy(app, infra)

    def incremental_update(self, app: AppRecord, infra: InfraRecord,
                           new_topo: Topology) -> DeploymentPlan:
        """Deploy only updated components according to the new topology."""
        assert app.plan is not None
        diff = app.topology.diff(new_topo)
        old_plan = app.plan
        for name in diff["removed"] + diff["changed"]:
            for inst in old_plan.instances.get(name, []):
                self._send_remove(infra, inst)
        partial = Topology(
            app=new_topo.app, version=new_topo.version,
            components={n: c for n, c in new_topo.components.items()
                        if n in diff["added"] + diff["changed"]})
        new_part = self.orchestrator.plan(partial, infra) if \
            partial.components else DeploymentPlan(new_topo.app,
                                                   new_topo.version, {})
        for inst in new_part.all_instances():
            self._send_deploy(infra, inst)
        merged: Dict[str, List[Instance]] = {
            n: insts for n, insts in old_plan.instances.items()
            if n not in diff["removed"] + diff["changed"]}
        merged.update(new_part.instances)
        app.plan = DeploymentPlan(new_topo.app, new_topo.version, merged)
        app.topology = new_topo
        self.monitor.log("app_updated", app=app.app, **{
            k: len(v) for k, v in diff.items()})
        return app.plan

    # -- node failure ---------------------------------------------------------
    def shield_node(self, infra: InfraRecord, node_id: str) -> None:
        self.api.shield_node(infra, node_id)
        self.monitor.log("node_shielded", node=node_id)

    # -- wire format ----------------------------------------------------------
    def _send_deploy(self, infra: InfraRecord, inst: Instance) -> None:
        node = infra.nodes[inst.node]
        broker = self.msg.broker(node.cluster)
        broker.publish(f"ace/deploy/{inst.node}", {
            "instance_id": inst.instance_id, "image": inst.image,
            "params": inst.params, "resources": inst.resources,
        }, nbytes=1024, src="ace-controller")

    def _send_remove(self, infra: InfraRecord, inst: Instance) -> None:
        node = infra.nodes[inst.node]
        broker = self.msg.broker(node.cluster)
        broker.publish(f"ace/remove/{inst.node}",
                       {"instance_id": inst.instance_id},
                       nbytes=256, src="ace-controller")
