"""Platform-layer orchestrator (paper §4.2.1, §4.4.3): binds every component
of a topology to concrete nodes such that resource (cpu/memory/accelerator),
user (edge/cloud placement), and label requirements are all satisfied.

The deployment plan is 'a topology replica modified by the orchestrator'
(Fig. 4): the same structure extended with ``instances``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

from repro.core.api_server import InfraRecord, NodeRecord
from repro.core.topology import Component, Resources, Topology


class PlanningError(Exception):
    pass


@dataclasses.dataclass
class Instance:
    instance_id: str
    component: str
    image: str
    node: str                       # NodeId string
    cluster: str                    # ClusterId string
    resources: Resources
    params: Dict = dataclasses.field(default_factory=dict)

    def to_dict(self) -> dict:
        return {"instance_id": self.instance_id, "component": self.component,
                "image": self.image, "node": self.node,
                "cluster": self.cluster, "params": self.params}


@dataclasses.dataclass
class DeploymentPlan:
    app: str
    version: int
    instances: Dict[str, List[Instance]]   # component -> instances

    def all_instances(self) -> List[Instance]:
        return [i for insts in self.instances.values() for i in insts]

    def to_dict(self) -> dict:
        return {"app": self.app, "version": self.version,
                "instances": {c: [i.to_dict() for i in insts]
                              for c, insts in self.instances.items()}}


class Orchestrator:
    """Best-fit binder with EC-delegation support (paper §5.1.3: 'ACE can
    delegate node-level orchestration to the EC')."""

    def __init__(self, api):
        self.api = api

    def plan(self, topo: Topology, infra: InfraRecord) -> DeploymentPlan:
        # free capacity is tracked against a scratch copy so a failed plan
        # leaves the infrastructure untouched
        scratch: Dict[str, Resources] = {
            k: n.free() for k, n in infra.nodes.items()}
        plan = DeploymentPlan(topo.app, topo.version, {})
        for name, comp in topo.components.items():
            plan.instances[name] = self._bind(comp, infra, scratch)
        return plan

    # -- binding -------------------------------------------------------------
    def _bind(self, comp: Component, infra: InfraRecord,
              scratch: Dict[str, Resources]) -> List[Instance]:
        targets = self._target_sets(comp, infra)
        instances = []
        for idx, candidates in enumerate(targets):
            node = self._pick(comp, candidates, scratch)
            if node is None:
                raise PlanningError(
                    f"component {comp.name!r}: no node satisfies "
                    f"placement={comp.placement} labels={comp.labels} "
                    f"resources=(cpu={comp.resources.cpu},"
                    f"mem={comp.resources.memory_mb})")
            free = scratch[str(node.node_id)]
            scratch[str(node.node_id)] = Resources(
                cpu=free.cpu - comp.resources.cpu,
                memory_mb=free.memory_mb - comp.resources.memory_mb,
                accelerator=free.accelerator)
            instances.append(Instance(
                instance_id=f"{comp.name}-{idx}", component=comp.name,
                image=comp.image, node=str(node.node_id),
                cluster=str(node.cluster), resources=comp.resources,
                params=dict(comp.params)))
        return instances

    def _target_sets(self, comp: Component,
                     infra: InfraRecord) -> List[List[NodeRecord]]:
        """One candidate set per required replica."""
        ready = [n for n in infra.nodes.values() if n.status == "ready"]
        if comp.placement == "edge":
            ready = [n for n in ready if not n.cluster.is_cloud]
        elif comp.placement == "cloud":
            ready = [n for n in ready if n.cluster.is_cloud]
        if comp.replicas == "one":
            return [ready]
        if comp.replicas == "per_ec":
            return [[n for n in ready if n.cluster == ec]
                    for ec in infra.ecs]
        if comp.replicas == "per_label":
            # one replica on every node carrying all required labels
            labelled = [n for n in ready
                        if set(comp.labels).issubset(set(n.labels))]
            if not labelled:
                raise PlanningError(
                    f"component {comp.name!r}: no node has labels {comp.labels}")
            return [[n] for n in labelled]
        raise PlanningError(f"unknown replicas mode {comp.replicas!r}")

    def _pick(self, comp: Component, candidates: List[NodeRecord],
              scratch: Dict[str, Resources]) -> Optional[NodeRecord]:
        best, best_free = None, None
        for n in candidates:
            if comp.labels and not set(comp.labels).issubset(set(n.labels)):
                continue
            free = scratch[str(n.node_id)]
            if not comp.resources.fits(free):
                continue
            # best fit: most free cpu after allocation (load spreading)
            if best is None or free.cpu > best_free:
                best, best_free = n, free.cpu
        return best
