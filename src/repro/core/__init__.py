"""ACE platform core — the paper's primary contribution.

Three layers (paper §4): platform layer (controller, orchestrator, API
server, pub/sub, monitoring), resource layer (EC/CC infrastructure, node
agents, resource-level services), application layer (topology-driven
deployment automation, reusable in-app controller, the four ECCI patterns).
"""
from repro.core.platform import AcePlatform
from repro.core.topology import Topology, Component
from repro.core.orchestrator import Orchestrator, DeploymentPlan
from repro.core.pubsub import Broker

__all__ = ["AcePlatform", "Topology", "Component", "Orchestrator",
           "DeploymentPlan", "Broker"]
