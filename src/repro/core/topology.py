"""Application topology files (paper §4.4.3, Figure 4).

A topology is 'an extended YAML file containing meta information of both the
application and all components': component clarifications, parameters,
relations (``connections``), and deployment requirements (``resources``,
``labels``, ``placement``). The orchestrator turns it into a deployment plan
(a topology replica extended with ``instances``).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

import yaml


@dataclasses.dataclass
class Resources:
    cpu: float = 0.1            # cores
    memory_mb: int = 64
    accelerator: bool = False   # needs a GPU/TPU-class node

    def fits(self, other: "Resources") -> bool:
        return (self.cpu <= other.cpu and self.memory_mb <= other.memory_mb
                and (not self.accelerator or other.accelerator))


@dataclasses.dataclass
class Component:
    name: str
    image: str                              # component image in the registry
    placement: str = "edge"                 # edge | cloud | any
    replicas: str = "one"                   # one | per_ec | per_label
    labels: List[str] = dataclasses.field(default_factory=list)
    resources: Resources = dataclasses.field(default_factory=Resources)
    connections: List[str] = dataclasses.field(default_factory=list)
    params: Dict[str, Any] = dataclasses.field(default_factory=dict)

    @classmethod
    def from_dict(cls, name: str, d: Dict[str, Any]) -> "Component":
        res = d.get("resources", {})
        return cls(
            name=name,
            image=d["image"],
            placement=d.get("placement", "edge"),
            replicas=d.get("replicas", "one"),
            labels=list(d.get("labels", [])),
            resources=Resources(cpu=float(res.get("cpu", 0.1)),
                                memory_mb=int(res.get("memory_mb", 64)),
                                accelerator=bool(res.get("accelerator", False))),
            connections=list(d.get("connections", [])),
            params=dict(d.get("params", {})),
        )

    def to_dict(self) -> Dict[str, Any]:
        return {
            "image": self.image, "placement": self.placement,
            "replicas": self.replicas, "labels": self.labels,
            "resources": {"cpu": self.resources.cpu,
                          "memory_mb": self.resources.memory_mb,
                          "accelerator": self.resources.accelerator},
            "connections": self.connections, "params": self.params,
        }


@dataclasses.dataclass
class Topology:
    app: str
    version: int
    components: Dict[str, Component]
    services: List[str] = dataclasses.field(default_factory=lambda: ["message"])

    def __post_init__(self):
        self.validate()

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "Topology":
        comps = {name: Component.from_dict(name, cd)
                 for name, cd in d.get("components", {}).items()}
        topo = cls(app=d["app"], version=int(d.get("version", 1)),
                   components=comps,
                   services=list(d.get("services", ["message"])))
        topo.validate()
        return topo

    @classmethod
    def from_yaml(cls, text: str) -> "Topology":
        return cls.from_dict(yaml.safe_load(text))

    @classmethod
    def load(cls, path: str) -> "Topology":
        with open(path) as f:
            return cls.from_yaml(f.read())

    def to_dict(self) -> Dict[str, Any]:
        return {"app": self.app, "version": self.version,
                "services": self.services,
                "components": {n: c.to_dict()
                               for n, c in self.components.items()}}

    def to_yaml(self) -> str:
        return yaml.safe_dump(self.to_dict(), sort_keys=False)

    def validate(self) -> None:
        for name, comp in self.components.items():
            assert comp.placement in ("edge", "cloud", "any"), (
                f"{name}: bad placement {comp.placement}")
            assert comp.replicas in ("one", "per_ec", "per_label"), (
                f"{name}: bad replicas {comp.replicas}")
            for conn in comp.connections:
                if conn not in self.components:
                    raise ValueError(
                        f"component {name!r} connects to unknown {conn!r}")

    def diff(self, other: "Topology") -> Dict[str, List[str]]:
        """Incremental-update support (paper §4.4.3): which components were
        added / removed / changed between two topology versions."""
        mine, theirs = self.components, other.components
        added = [n for n in theirs if n not in mine]
        removed = [n for n in mine if n not in theirs]
        changed = [n for n in mine if n in theirs
                   and mine[n].to_dict() != theirs[n].to_dict()]
        return {"added": added, "removed": removed, "changed": changed}
