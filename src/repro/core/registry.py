"""Image registry (paper §4.2.2): hosts ACE-provided and user-provided
component images. An 'image' here is a named factory producing a component
instance — the containerization analog (DESIGN.md §2 assumption change (i)).

A component instance implements the runtime contract:

    class MyComponent:
        def start(self, ctx): ...            # ctx: repro.core.agent.Context
        def stop(self): ...                  # optional

Components communicate only through resource-level services reachable from
``ctx`` (message service, file service) — never by direct reference. This is
what makes them relocatable between edge and cloud.
"""
from __future__ import annotations

from repro.utils.registry import Registry

IMAGES = Registry("component image")


def image(name: str):
    """Decorator: register a component class under an image name."""
    return IMAGES.register(name)


def instantiate(name: str, params: dict):
    factory = IMAGES.get(name)
    return factory(**params)
