"""Node agents (paper §4.3.1): deployed on every node, they inform ACE of
node status, execute deployment instructions from the platform controller,
and collect application status for the monitoring service.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

from repro.core import registry
from repro.core.api_server import NodeRecord
from repro.core.ids import ClusterId, NodeId
from repro.core.pubsub import Broker, MessageService
from repro.core.sim import SimClock
from repro.utils.logging import EventLog


@dataclasses.dataclass
class Context:
    """Everything a deployed component may touch at runtime."""
    node: NodeRecord
    clock: SimClock
    broker: Broker                   # the node's *local* cluster broker
    services: Dict[str, Any]         # resource-level services by name
    monitor: EventLog
    params: Dict[str, Any]
    instance_id: str = ""

    @property
    def cluster(self) -> ClusterId:
        return self.node.cluster

    def publish(self, topic: str, payload, nbytes: int = 256) -> None:
        self.broker.publish(topic, payload, nbytes=nbytes,
                            src=self.instance_id)

    def subscribe(self, pattern: str, fn) -> None:
        self.broker.subscribe(pattern, fn)

    def log(self, kind: str, **fields) -> None:
        self.monitor.log(kind, instance=self.instance_id,
                         node=str(self.node.node_id), **fields)


class NodeAgent:
    """Executes deploy/remove instructions (the docker-compose analog of
    paper Fig. 4 step ②) and reports node/app status."""

    def __init__(self, node: NodeRecord, clock: SimClock,
                 msg: MessageService, monitor: EventLog,
                 services: Optional[Dict[str, Any]] = None):
        self.node = node
        self.clock = clock
        self.msg = msg
        self.monitor = monitor
        self.services = services or {}
        self.instances: Dict[str, Any] = {}
        # the agent listens for controller instructions on its own topic
        self.broker = msg.broker(node.cluster)
        self.broker.subscribe(f"ace/deploy/{node.node_id}", self._on_deploy)
        self.broker.subscribe(f"ace/remove/{node.node_id}", self._on_remove)

    # -- instruction handlers -------------------------------------------------
    def _on_deploy(self, msg) -> None:
        inst = msg.payload
        self.deploy(inst["instance_id"], inst["image"], inst["params"],
                    inst.get("resources"))

    def _on_remove(self, msg) -> None:
        self.remove(msg.payload["instance_id"])

    # -- direct API (used by controller in instant mode) ---------------------
    def deploy(self, instance_id: str, image: str, params: dict,
               resources=None) -> Any:
        comp = registry.instantiate(image, params.get("init", {}))
        ctx = Context(node=self.node, clock=self.clock, broker=self.broker,
                      services=self.services, monitor=self.monitor,
                      params=params, instance_id=instance_id)
        if resources is not None:
            self.node.allocate(resources)
        comp_ctx = (comp, ctx, resources)
        self.instances[instance_id] = comp_ctx
        comp.start(ctx)
        self.monitor.log("deployed", instance=instance_id, image=image,
                         node=str(self.node.node_id))
        return comp

    def remove(self, instance_id: str) -> None:
        comp, _, resources = self.instances.pop(instance_id)
        if hasattr(comp, "stop"):
            comp.stop()
        if resources is not None:
            self.node.release(resources)
        self.monitor.log("removed", instance=instance_id,
                         node=str(self.node.node_id))

    def status(self) -> dict:
        return {"node": str(self.node.node_id),
                "instances": sorted(self.instances),
                "cpu_allocated": self.node.allocated.cpu,
                "mem_allocated": self.node.allocated.memory_mb}
