"""The ACE intelligent video query application (paper §5).

Components (§5.1.2): DG (data generator), OD (frame-differencing object
detector), EOC (edge object classifier), COC (cloud object classifier),
IC (in-app controller with BP/AP), RS (result storage). Deployed through the
regular ACE pipeline: topology file -> orchestrator -> controller -> agents.

Crops are produced by a *crop bank*: either a statistical surrogate
calibrated to the paper's model qualities (EOC 11.06% error @ 0.8
confidence, COC 4.49% top-5 error) for the Fig. 5 sweep, or real JAX
CNN predictions precomputed in one batched pass
(``repro.data.video.model_crop_bank``) for the end-to-end example. Ground
truth for F1 follows the paper's footnote: COC's post-hoc classification of
every extracted crop.

Implementation paradigms compared (§5.2): CI (COC only), EI (EOC only),
ACE (cascade + BP), ACE+ (cascade + AP).
"""
from __future__ import annotations

import dataclasses
import random
import time
from typing import Dict, List, Optional

from repro.configs.ace_video_query import VideoQueryConfig
from repro.core.inapp.policies import AdvancedPolicy, BasicPolicy
from repro.core.registry import image
from repro.core.sim import SimClock
from repro.core.topology import Component, Resources, Topology


# ---------------------------------------------------------------------------
# Crop bank
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Crop:
    crop_id: int
    positive_gt: bool       # COC post-hoc label (the paper's F1 ground truth)
    eoc_conf: float         # EOC max-softmax confidence
    eoc_pred: int           # EOC binary prediction (1 = target class)
    coc_hit: bool           # COC online top-5 contains the target label
    nbytes: int = 12_000


def surrogate_crop_bank(n: int, *, seed: int = 0, positive_rate: float = 0.12,
                        eoc_error: float = 0.1106, coc_top5_err: float = 0.0449,
                        online_flip: float = 0.02,
                        crop_bytes: int = 12_000) -> List[Crop]:
    """Statistical surrogate calibrated to paper §5.1.2 model qualities."""
    rng = random.Random(seed)
    crops = []
    for i in range(n):
        true_pos = rng.random() < positive_rate
        # COC online agrees with its own post-hoc labelling up to small
        # input-pipeline variation (resize/JPEG), which is what keeps CI's
        # F1 slightly below 1.0 in the paper.
        coc_correct = rng.random() >= coc_top5_err
        coc_posthoc_pos = true_pos if coc_correct else not true_pos
        coc_hit = (coc_posthoc_pos if rng.random() >= online_flip
                   else not coc_posthoc_pos)
        # EOC confidence: correct crops skew high, wrong crops mid-band
        eoc_correct = rng.random() >= eoc_error
        eoc_pred = int(true_pos if eoc_correct else not true_pos)
        if eoc_correct:
            conf = min(0.999, max(0.02, rng.betavariate(8.0, 1.0)))
        else:
            conf = min(0.999, max(0.02, rng.betavariate(2.5, 2.5)))
        crops.append(Crop(i, coc_posthoc_pos, conf, eoc_pred, coc_hit,
                          crop_bytes))
    return crops


# ---------------------------------------------------------------------------
# Serving-engine-backed classifier calibration
# ---------------------------------------------------------------------------

def calibrate_server_from_engine(engine, *, n_queries: int = 8,
                                 prompt_len: int = 12, max_new: int = 4,
                                 seed: int = 0) -> dict:
    """Measure a continuous-batching ``ServingEngine``'s service profile so
    the simulated EOC/COC servers run at the rate the real engine delivers
    (the ACE cascade application "running on" the serving layer).

    Returns {"service_s", "workers", "tokens_s"}: mean per-query seconds at
    the offered concurrency, the engine's slot count (simulated as FIFO
    workers), and raw decode throughput.
    """
    import numpy as np
    rng = np.random.default_rng(seed)
    vocab = engine.lm.cfg.vocab_size
    # warm the compile caches so calibration measures steady-state service
    engine.submit(rng.integers(0, vocab, size=prompt_len), max_new)
    engine.run()
    t0 = time.perf_counter()
    for _ in range(n_queries):
        engine.submit(rng.integers(0, vocab, size=prompt_len), max_new)
    done = engine.run()
    wall = time.perf_counter() - t0
    toks = sum(len(r.output) for r in done.values())
    # wall is measured at full slot concurrency; service_s is per *worker*
    # so that a Server with ``workers`` slots reproduces the engine's
    # aggregate throughput (n_queries / wall), not ``workers``× it
    return {"service_s": wall * engine.batch_slots / n_queries,
            "workers": engine.batch_slots,
            "tokens_s": toks / max(wall, 1e-9)}


# ---------------------------------------------------------------------------
# A multi-worker FIFO server (classifier compute model)
# ---------------------------------------------------------------------------

class Server:
    def __init__(self, clock: SimClock, service_s: float, workers: int = 1,
                 max_backlog_s: Optional[float] = None):
        self.clock = clock
        self.service_s = service_s
        self.workers = workers
        self.max_backlog_s = max_backlog_s
        self._free_at = [0.0] * workers
        self.served = 0
        self.dropped = 0

    def submit(self, fn, on_drop=None) -> Optional[float]:
        """Queue one item; run ``fn`` at completion. Items past the backlog
        bound are dropped (the paper's 'queue backlog at EOC' under BP)."""
        if (self.max_backlog_s is not None
                and self.backlog_s > self.max_backlog_s):
            self.dropped += 1
            if on_drop is not None:
                on_drop()
            return None
        i = min(range(self.workers), key=lambda j: self._free_at[j])
        start = max(self.clock.now, self._free_at[i])
        done = start + self.service_s
        self._free_at[i] = done
        self.served += 1
        self.clock.schedule_at(done, fn)
        return done

    @property
    def backlog_s(self) -> float:
        return max(0.0, min(self._free_at) - self.clock.now)


# ---------------------------------------------------------------------------
# Components
# ---------------------------------------------------------------------------

@image("repro/video-query/dg")
class DataGenerator:
    """Provides the real-time video stream to its edge node (paper DG)."""

    def __init__(self, frame_interval_s: float = 0.5, duration_s: float = 60.0,
                 camera: str = "cam"):
        self.frame_interval_s = frame_interval_s
        self.duration_s = duration_s
        self.camera = camera

    def start(self, ctx) -> None:
        self.ctx = ctx
        # desynchronize cameras: deterministic per-instance phase offset
        import hashlib
        h = int(hashlib.md5(ctx.instance_id.encode()).hexdigest()[:8], 16)
        self.phase = (h % 9973) / 9973.0 * self.frame_interval_s
        self._emit(0)

    def _emit(self, idx: int) -> None:
        t = self.phase + idx * self.frame_interval_s
        if t >= self.duration_s:
            return
        self.ctx.clock.schedule_at(t, lambda: self._frame(idx))

    def _frame(self, idx: int) -> None:
        self.ctx.publish(f"vq/frames/{self.camera}",
                         {"camera": self.camera, "idx": idx}, nbytes=64)
        self._emit(idx + 1)


@image("repro/video-query/od")
class ObjectDetector:
    """Frame differencing: rapidly extracts crops with salient pixel
    differences (paper OD). Crop count per frame follows the bank."""

    def __init__(self, camera: str = "cam", crops_per_frame: float = 1.0,
                 proc_s: float = 0.005, seed: int = 0):
        self.camera = camera
        self.crops_per_frame = crops_per_frame
        self.proc_s = proc_s
        self.rng = random.Random(seed)
        self.emitted = 0

    def start(self, ctx) -> None:
        self.ctx = ctx
        self.app = ctx.params.get("app")
        ctx.subscribe(f"vq/frames/{self.camera}", self._on_frame)

    def _on_frame(self, msg) -> None:
        # 1 crop per sampled frame + Bernoulli extra -> mean crops_per_frame
        n = 1 + (1 if self.rng.random() < (self.crops_per_frame - 1.0) else 0)

        def emit():
            for _ in range(n):
                self.emitted += 1
                self.app.submit_crop(self.camera, self.ctx)
        self.ctx.clock.schedule(self.proc_s, emit)


@image("repro/video-query/rs")
class ResultStorage:
    def __init__(self):
        self.results: Dict[int, dict] = {}

    def start(self, ctx) -> None:
        ctx.subscribe("vq/results", self._on_result)

    def _on_result(self, msg) -> None:
        self.results[msg.payload["crop_id"]] = msg.payload


# ---------------------------------------------------------------------------
# The application driver
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class QueryMetrics:
    tp: int = 0
    fp: int = 0
    fn: int = 0
    crops: int = 0
    eils: List[float] = dataclasses.field(default_factory=list)

    def f1(self) -> float:
        p = self.tp / max(self.tp + self.fp, 1)
        r = self.tp / max(self.tp + self.fn, 1)
        return 2 * p * r / max(p + r, 1e-9)

    def mean_eil(self) -> float:
        return sum(self.eils) / max(len(self.eils), 1)


class VideoQueryApp:
    """Wires the deployed components with the paradigm-specific data path.

    paradigm: 'ci' | 'ei' | 'ace' | 'ace+'  (paper §5.2)
    """

    def __init__(self, cfg: VideoQueryConfig, platform, infra, *,
                 paradigm: str, crop_bank: List[Crop], seed: int = 0,
                 eoc_service: Optional[dict] = None,
                 coc_service: Optional[dict] = None):
        self.cfg = cfg
        self.platform = platform
        self.infra = infra
        self.paradigm = paradigm
        self.bank = crop_bank
        self.rng = random.Random(seed)
        self.clock = platform.clock
        self.network = platform.network(infra)
        self.metrics = QueryMetrics()
        self._crop_ptr = 0
        # classifier servers: one EOC per EC (its x86 node), one COC at CC.
        # Service profiles default to the paper's measured ms; when a
        # serving-engine calibration dict is given (see
        # ``calibrate_server_from_engine``), the classifiers run at the
        # continuous-batching engine's measured rate and concurrency.
        eoc_s = (eoc_service or {}).get("service_s", cfg.eoc_infer_ms / 1e3)
        eoc_w = (eoc_service or {}).get("workers", 1)
        coc_s = (coc_service or {}).get("service_s", cfg.coc_infer_ms / 1e3)
        coc_w = (coc_service or {}).get("workers", 1)
        self.eoc: Dict[str, Server] = {}
        for ec in infra.ecs:
            # one x86 mini PC per EC runs EOC (paper §5.1.1); bounded queue
            self.eoc[str(ec)] = Server(self.clock, eoc_s, workers=eoc_w,
                                       max_backlog_s=1.0)
        self.coc = Server(self.clock, coc_s, workers=coc_w)
        if paradigm == "ace+":
            self.policy = AdvancedPolicy(cfg.accept_threshold,
                                         cfg.drop_threshold,
                                         deteriorate_s=0.6, shrink=0.08)
        else:
            self.policy = BasicPolicy(cfg.accept_threshold,
                                      cfg.drop_threshold)

    # -- crop path ------------------------------------------------------------
    def submit_crop(self, camera: str, ctx) -> None:
        crop = self.bank[self._crop_ptr % len(self.bank)]
        self._crop_ptr += 1
        self.metrics.crops += 1
        born = self.clock.now
        ec = ctx.cluster
        if self.paradigm == "ci":
            self._to_coc(crop, ec, born)
            return
        if self.paradigm == "ace+" and self.policy.upload_target(self.clock.now) == "coc":
            self._to_coc(crop, ec, born)     # AP load balancing OD->COC
            return
        self._to_eoc(crop, ec, born)

    def _to_eoc(self, crop: Crop, ec, born: float) -> None:
        # LAN hop camera-node -> x86 node, then EOC queue (bounded: crops
        # past the backlog limit are dropped, the paper's BP failure mode)
        def arrived():
            server = self.eoc[str(ec)]

            def done():
                self._after_eoc(crop, ec, born)

            def dropped():
                # a drop is the strongest deterioration signal
                self.policy.observe_eil("eoc", 2.0 * server.backlog_s,
                                        now=self.clock.now)
                # dropped crops never receive a label -> no EIL sample
                self._finish(crop, False, born, count_eil=False)
            server.submit(done, on_drop=dropped)
        self.network.send(ec, ec, crop.nbytes, arrived)

    def _after_eoc(self, crop: Crop, ec, born: float) -> None:
        self.policy.observe_eil("eoc", self.clock.now - born,
                                now=self.clock.now)
        d = self.policy.classify_decision(crop.eoc_conf)
        if self.paradigm == "ei":
            # EI has no cloud: the escalation band is dropped (paper §5.2)
            positive = (d.route == "accept" and crop.eoc_pred == 1)
            self._finish(crop, positive, born)
            return
        if d.route == "accept":
            positive = crop.eoc_pred == 1
            if positive:
                self._send_metadata(ec)
            self._finish(crop, positive, born)
        elif d.route == "drop":
            self._finish(crop, False, born)
        else:
            self._to_coc(crop, ec, born, escalated=True)

    def _to_coc(self, crop: Crop, ec, born: float,
                escalated: bool = False) -> None:
        def arrived():
            def done():
                self.policy.observe_eil("coc", self.clock.now - born,
                                        now=self.clock.now)
                self._finish(crop, crop.coc_hit, born)
            self.coc.submit(done)
        self.network.send(ec, self.infra.cc, crop.nbytes, arrived)

    def _send_metadata(self, ec) -> None:
        self.network.send(ec, self.infra.cc, 200, lambda: None)

    def _finish(self, crop: Crop, predicted_positive: bool,
                born: float, count_eil: bool = True) -> None:
        if count_eil:
            self.metrics.eils.append(self.clock.now - born)
        if predicted_positive and crop.positive_gt:
            self.metrics.tp += 1
        elif predicted_positive:
            self.metrics.fp += 1
        elif crop.positive_gt:
            self.metrics.fn += 1


def video_query_topology(cfg: VideoQueryConfig, app_obj: VideoQueryApp,
                         duration_s: float,
                         frame_interval_s: float) -> Topology:
    """The topology file of paper Fig. 4, parameterized by the experiment."""
    comps = {
        "dg": Component(
            name="dg", image="repro/video-query/dg", placement="edge",
            replicas="per_label", labels=["camera"],
            resources=Resources(cpu=0.2, memory_mb=128),
            connections=["od"],
            params={"init": {"frame_interval_s": frame_interval_s,
                             "duration_s": duration_s}}),
        "od": Component(
            name="od", image="repro/video-query/od", placement="edge",
            replicas="per_label", labels=["camera"],
            resources=Resources(cpu=0.5, memory_mb=256),
            connections=["eoc", "coc", "ic"],
            params={"init": {}, "app": app_obj}),
        "eoc": Component(
            name="eoc", image="repro/video-query/rs", placement="edge",
            replicas="per_ec", resources=Resources(cpu=2.0, memory_mb=1024),
            connections=["ic", "coc"], params={"init": {}}),
        "coc": Component(
            name="coc", image="repro/video-query/rs", placement="cloud",
            resources=Resources(cpu=8.0, memory_mb=8192, accelerator=True),
            connections=["rs"], params={"init": {}}),
        "ic": Component(
            name="ic", image="repro/video-query/rs", placement="edge",
            replicas="per_ec", resources=Resources(cpu=0.2, memory_mb=128),
            connections=[], params={"init": {}}),
        "rs": Component(
            name="rs", image="repro/video-query/rs", placement="cloud",
            resources=Resources(cpu=0.5, memory_mb=512),
            connections=[], params={"init": {}}),
    }
    return Topology(app="video-query", version=1, components=comps)


def run_video_query(cfg: VideoQueryConfig, *, paradigm: str,
                    frame_interval_s: float, wan_delay_ms: float,
                    duration_s: float = 60.0, crop_bank=None,
                    seed: int = 0, eoc_engine=None, coc_engine=None) -> dict:
    """Deploy and run one (paradigm, load, delay) cell of Fig. 5.

    ``eoc_engine``/``coc_engine``: optional continuous-batching
    ``ServingEngine`` instances; when given, the simulated classifiers are
    calibrated to the engines' measured throughput and slot concurrency.
    """
    from repro.core.network import NetworkModel
    from repro.core.platform import AcePlatform

    clock = SimClock()
    platform = AcePlatform(
        clock,
        network_factory=lambda c: NetworkModel(
            c, lan_mbps=cfg.lan_mbps, uplink_mbps=cfg.uplink_mbps,
            downlink_mbps=cfg.downlink_mbps,
            wan_delay_s=wan_delay_ms / 1e3, seed=seed))
    platform.register_user("paper")
    # paper §5.1.1: 3 ECs x (1 x86 + 3 RPis with cameras), 1 GPU CC
    labels = [["x86"], ["camera"], ["camera"], ["camera"]]
    infra = platform.register_infrastructure(
        "paper", num_ecs=cfg.num_edge_clouds, nodes_per_ec=cfg.nodes_per_ec,
        edge_labels=labels)
    # only app control topics bridge the WAN; frame streams stay on
    # the EC LAN (the developer-configured service scope)
    platform.deploy_services(infra, bridged_topics=["vq/results", "app/*"])

    bank = crop_bank if crop_bank is not None else surrogate_crop_bank(
        20_000, seed=seed, crop_bytes=cfg.crop_bytes)
    eoc_service = (calibrate_server_from_engine(eoc_engine)
                   if eoc_engine is not None else None)
    coc_service = (calibrate_server_from_engine(coc_engine)
                   if coc_engine is not None else None)
    app = VideoQueryApp(cfg, platform, infra, paradigm=paradigm,
                        crop_bank=bank, seed=seed,
                        eoc_service=eoc_service, coc_service=coc_service)
    topo = video_query_topology(cfg, app, duration_s, frame_interval_s)
    rec = platform.submit_app("paper", infra, topo)
    platform.deploy_app("paper", "video-query")

    # per-camera OD/DG pairing: match instance params to their camera id
    for iid, comp, ctx in platform.instances(infra, "od"):
        comp.camera = iid.replace("od-", "cam-")
        comp.app = app
        ctx.subscribe(f"vq/frames/{comp.camera}", comp._on_frame)
    for iid, comp, ctx in platform.instances(infra, "dg"):
        comp.camera = iid.replace("dg-", "cam-")

    clock.run(until=duration_s + 120.0)
    m = app.metrics
    wan_mb = platform.network(infra).wan_bytes() / 1e6
    return {
        "paradigm": paradigm, "interval_s": frame_interval_s,
        "delay_ms": wan_delay_ms, "crops": m.crops, "f1": m.f1(),
        "bwc_mb": wan_mb, "eil_s": m.mean_eil(),
        "coc_backlog_s": app.coc.backlog_s,
        "duration_s": duration_s,
    }
