"""The ACE platform facade (paper §4.1): user registration, infrastructure
organization, service deployment, application development & deployment.

    ace = AcePlatform()                               # instant mode
    user = ace.register_user("alice")
    infra = ace.register_infrastructure(
        "alice", num_ecs=3, nodes_per_ec=4, cc_nodes=1,
        edge_labels=[["camera"], [], [], []])
    ace.deploy_services(infra)                        # message/file services
    app = ace.submit_app("alice", infra, topology)
    plan = ace.deploy_app("alice", topology.app)

For the Fig. 5 experiment the platform runs on a :class:`SimClock` with a
:class:`NetworkModel` so transmissions and queues occupy simulated time.
"""
from __future__ import annotations

from typing import Dict, List, Optional

from repro.core import patterns as _patterns  # noqa: F401 (registers images)
from repro.core.agent import NodeAgent
from repro.core.api_server import ApiServer, AppRecord, InfraRecord
from repro.core.controller import Controller
from repro.core.monitoring import MonitoringService
from repro.core.network import NetworkModel
from repro.core.orchestrator import Orchestrator
from repro.core.pubsub import MessageService
from repro.core.services.file_service import FileService
from repro.core.services.object_store import ObjectStore
from repro.core.sim import InstantClock, SimClock
from repro.core.topology import Resources, Topology


class AcePlatform:
    def __init__(self, clock: Optional[SimClock] = None,
                 network_factory=None):
        """``network_factory(clock) -> NetworkModel`` enables the validation
        testbed; None means instant (zero-latency) links."""
        self.clock = clock or InstantClock()
        self.network_factory = network_factory
        self.api = ApiServer()
        self.monitor = MonitoringService()
        self.orchestrator = Orchestrator(self.api)
        # per-infrastructure runtime state
        self._msg: Dict[str, MessageService] = {}
        self._net: Dict[str, Optional[NetworkModel]] = {}
        self._agents: Dict[str, Dict[str, NodeAgent]] = {}
        self._controllers: Dict[str, Controller] = {}
        self._services: Dict[str, dict] = {}

    # -- phase 1: user registration (paper §4.1) -------------------------------
    def register_user(self, name: str) -> dict:
        return self.api.register_user(name)

    def register_infrastructure(
            self, user: str, *, num_ecs: int, nodes_per_ec: int,
            cc_nodes: int = 1,
            edge_labels: Optional[List[List[str]]] = None,
            edge_capacity: Optional[Resources] = None,
            cloud_capacity: Optional[Resources] = None) -> InfraRecord:
        """Organize the user's nodes into ECs + one CC (paper §4.3.1)."""
        infra = self.api.register_infra(user)
        cc = self.api.register_cluster(infra, "cc")
        for _ in range(cc_nodes):
            self.api.register_node(
                infra, cc, labels=["gpu"],
                capacity=cloud_capacity or Resources(
                    cpu=32.0, memory_mb=131072, accelerator=True))
        for _ in range(num_ecs):
            ec = self.api.register_cluster(infra, "ec")
            for j in range(nodes_per_ec):
                labels = (edge_labels[j] if edge_labels and j < len(edge_labels)
                          else [])
                self.api.register_node(
                    infra, ec, labels=labels,
                    capacity=edge_capacity or Resources(cpu=4.0,
                                                        memory_mb=4096))
        self.monitor.log("infra_registered", infra=str(infra.infra_id),
                         ecs=num_ecs, nodes=len(infra.nodes))
        return infra

    # -- resource-level services ------------------------------------------------
    def deploy_services(self, infra: InfraRecord,
                        bridged_topics: Optional[List[str]] = None) -> dict:
        iid = str(infra.infra_id)
        network = (self.network_factory(self.clock)
                   if self.network_factory else None)
        msg = MessageService(infra.clusters, self.clock, network,
                             bridged_topics)
        store = ObjectStore()
        files = FileService(msg, store, network, self.clock, infra.cc)
        services = {"message": msg, "object_store": store, "file": files,
                    "monitor": self.monitor}
        self._msg[iid] = msg
        self._net[iid] = network
        self._services[iid] = services
        # node agents come up with the services in reach
        agents = {}
        for key, node in infra.nodes.items():
            agents[key] = NodeAgent(node, self.clock, msg, self.monitor,
                                    services)
        self._agents[iid] = agents
        self._controllers[iid] = Controller(self.api, msg, self.orchestrator,
                                            self.monitor)
        self.monitor.log("services_deployed", infra=iid)
        return services

    # -- phase 2/3: application development & deployment ------------------------
    def submit_app(self, user: str, infra: InfraRecord,
                   topo: Topology) -> AppRecord:
        return self.api.submit_app(user, str(infra.infra_id), topo)

    def deploy_app(self, user: str, app_name: str):
        rec = self.api.get_app(user, app_name)
        infra = self.api.infras[str(rec.infra_id)]
        controller = self._controllers[str(rec.infra_id)]
        return controller.deploy(rec, infra)

    def remove_app(self, user: str, app_name: str) -> None:
        rec = self.api.get_app(user, app_name)
        infra = self.api.infras[str(rec.infra_id)]
        self._controllers[str(rec.infra_id)].remove(rec, infra)

    def update_app(self, user: str, app_name: str, new_topo: Topology,
                   incremental: bool = False):
        rec = self.api.get_app(user, app_name)
        infra = self.api.infras[str(rec.infra_id)]
        ctl = self._controllers[str(rec.infra_id)]
        if incremental:
            return ctl.incremental_update(rec, infra, new_topo)
        return ctl.thorough_update(rec, infra, new_topo)

    # -- runtime access -----------------------------------------------------------
    def agents(self, infra: InfraRecord) -> Dict[str, NodeAgent]:
        return self._agents[str(infra.infra_id)]

    def message_service(self, infra: InfraRecord) -> MessageService:
        return self._msg[str(infra.infra_id)]

    def network(self, infra: InfraRecord) -> Optional[NetworkModel]:
        return self._net[str(infra.infra_id)]

    def services(self, infra: InfraRecord) -> dict:
        return self._services[str(infra.infra_id)]

    def instances(self, infra: InfraRecord, component: str) -> list:
        """All live instances of a component across agents."""
        out = []
        for agent in self._agents[str(infra.infra_id)].values():
            for iid, (comp, ctx, _res) in agent.instances.items():
                if iid.startswith(component + "-"):
                    out.append((iid, comp, ctx))
        return out

    def run(self, until: Optional[float] = None) -> int:
        return self.clock.run(until)
