"""Monitoring service (paper §4.2.1): collects status, performance metrics,
and runtime logs of ACE, user nodes and applications; queried by users and by
in-app controllers (the AP policy reads EIL estimates from here).
"""
from __future__ import annotations

import statistics
from typing import Dict, List, Optional

from repro.utils.logging import EventLog


class MonitoringService(EventLog):
    def __init__(self):
        super().__init__(name="ace-monitor")

    # -- metric helpers --------------------------------------------------------
    def record_latency(self, component: str, latency_s: float, **fields):
        self.log("latency", component=component, latency_s=latency_s, **fields)

    def latency_stats(self, component: str,
                      since: float = 0.0) -> Optional[dict]:
        vals = [e["latency_s"] for e in self.query("latency", component=component)
                if e["t"] >= since]
        if not vals:
            return None
        return {"n": len(vals), "mean": statistics.fmean(vals),
                "p50": statistics.median(vals), "max": max(vals)}

    def counters(self, kind: str) -> int:
        return len(self.query(kind))

    # -- serving-engine snapshots ---------------------------------------------
    def record_serving(self, component: str, snapshot: Dict) -> None:
        """Ingest a ``ServingEngine.metrics()`` (or
        ``CascadeServingEngine.engine_metrics()``) snapshot for
        ``component`` — the serving stack's health feed (terminal request
        dispositions, fault/retry accounting, breaker state)."""
        self.log("serving_metrics", component=component, snapshot=snapshot)

    def serving_snapshot(self, component: str) -> Optional[Dict]:
        """Latest serving snapshot recorded for ``component``."""
        evs = self.query("serving_metrics", component=component)
        return evs[-1]["snapshot"] if evs else None

    def feed_deadline_admission(self, component: str, scheduler) -> bool:
        """Close the admission loop (ISSUE 9): push the latest *measured*
        per-class deadline-hit table back into the scheduler's admission
        estimator (``Scheduler.absorb_deadline_hits``), where it widens
        the feasibility safety margin for classes that are missing in
        practice. Call after ``record_serving``; after a crash-restart,
        call it again once the recovered engine has fresh observations —
        ``restore()`` resets the estimator (pre-crash rates describe a
        dead process), so the margin re-learns from the monitor's feed.
        Returns False when no snapshot exists yet for ``component``."""
        table = self.deadline_hit_rates(component)
        if not table:
            return False
        scheduler.absorb_deadline_hits(table)
        return True

    # -- durability events ----------------------------------------------------
    def record_restart(self, component: str, info: Dict) -> None:
        """One supervised crash-restart: ``info`` is what
        ``serving.recover_engine`` returned (snapshot counts + journal
        replay counts)."""
        self.log("restart", component=component, info=info)

    def record_hang(self, component: str, detail: str = "") -> None:
        """One watchdog-detected hang (timeout fired, whether the step
        later completed or the engine was declared wedged)."""
        self.log("hang", component=component, detail=detail)

    def record_journal(self, component: str, counts: Dict) -> None:
        """A journal replay's outcome (``RequestJournal.replay``)."""
        self.log("journal_replay", component=component, counts=counts)

    def durability_counters(self) -> Dict[str, int]:
        """Fleet-wide durability tallies for dashboards/tests."""
        return {"restarts": self.counters("restart"),
                "hangs": self.counters("hang"),
                "journal_replays": self.counters("journal_replay")}

    def deadline_hit_rates(self, component: str) -> Optional[Dict]:
        """Per-class deadline-hit rates from the latest serving snapshot:
        ``{priority: {"hits", "total", "rate"}}`` — the feedback signal
        closing the loop on deadline-feasibility admission (does the
        estimator's 'feasible' actually finish in time?). For cascade
        snapshots the inner engines' tables are merged."""
        snap = self.serving_snapshot(component)
        if snap is None:
            return None
        if "deadline_hits" in snap:
            return snap["deadline_hits"]
        merged: Dict = {}
        for side in ("edge", "cloud"):
            for p, row in snap.get(side, {}).get("deadline_hits",
                                                 {}).items():
                m = merged.setdefault(p, {"hits": 0, "total": 0})
                m["hits"] += row["hits"]
                m["total"] += row["total"]
        for m in merged.values():
            m["rate"] = m["hits"] / m["total"] if m["total"] else 0.0
        return merged or None

    def speculative_acceptance(self, component: str) -> Optional[Dict]:
        """Per-class speculative acceptance from the latest serving
        snapshot: ``{priority: {"drafted", "accepted", "rate"}}`` — how
        well the draft model is earning its FLOPs per SLO class. For
        cascade snapshots the inner engines' tables are merged (in
        practice only the cloud engine drafts, but the merge keeps the
        accessor shape-agnostic like ``deadline_hit_rates``)."""
        snap = self.serving_snapshot(component)
        if snap is None:
            return None
        if "speculative" in snap:
            return snap["speculative"].get("per_class", {})
        merged: Dict = {}
        for side in ("edge", "cloud"):
            table = snap.get(side, {}).get("speculative", {})
            for p, row in table.get("per_class", {}).items():
                m = merged.setdefault(p, {"drafted": 0, "accepted": 0})
                m["drafted"] += row["drafted"]
                m["accepted"] += row["accepted"]
        for m in merged.values():
            m["rate"] = (m["accepted"] / m["drafted"]
                         if m["drafted"] else 0.0)
        return merged or None

    def component_status(self) -> Dict[str, str]:
        status: Dict[str, str] = {}
        for ev in self.events:
            if ev["kind"] == "deployed":
                status[ev["instance"]] = "running"
            elif ev["kind"] == "removed":
                status[ev["instance"]] = "removed"
        return status
