"""ECC processing pattern (paper §2): collaborative data-processing
pipelines / DAGs (the Steel-style streaming analytics example).

Each :class:`PipelineStage` is an ACE component: it subscribes to its input
topic(s) on the *local* broker, applies a user function with a simulated
processing time, and publishes downstream. Because topics are bridged
EC<->CC, a pipeline can span edge and cloud without the developer handling
any edge-cloud interaction — the paper's user-transparency claim.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, List, Optional, Sequence

from repro.core.registry import image
from repro.core.topology import Topology, Component, Resources


@image("repro/pattern/pipeline-stage")
class PipelineStage:
    def __init__(self, fn: Optional[Callable[[Any], Any]] = None,
                 in_topics: Sequence[str] = (), out_topic: str = "",
                 proc_time_s: float = 0.0, out_bytes: int = 256):
        self.fn = fn or (lambda x: x)
        self.in_topics = list(in_topics)
        self.out_topic = out_topic
        self.proc_time_s = proc_time_s
        self.out_bytes = out_bytes
        self.processed = 0
        self.outputs: List[Any] = []

    def start(self, ctx) -> None:
        self.ctx = ctx
        for t in self.in_topics:
            ctx.subscribe(t, self._on_item)

    def _on_item(self, msg) -> None:
        def finish():
            result = self.fn(msg.payload)
            self.processed += 1
            if result is None:
                return                      # filtered out
            self.outputs.append(result)
            if self.out_topic:
                self.ctx.publish(self.out_topic, result,
                                 nbytes=self.out_bytes)
        self.ctx.clock.schedule(self.proc_time_s, finish)


def pipeline_topology(app: str, stages: List[dict]) -> Topology:
    """Build a linear-pipeline topology. Each stage dict:
    {name, placement, fn?, proc_time_s?, resources?}. Topics are wired
    ``<app>/s0 -> <app>/s1 -> ...`` automatically."""
    comps: Dict[str, Component] = {}
    for i, st in enumerate(stages):
        in_topics = [f"{app}/s{i - 1}"] if i > 0 else [f"{app}/in"]
        out_topic = f"{app}/s{i}" if i < len(stages) - 1 else f"{app}/out"
        comps[st["name"]] = Component(
            name=st["name"],
            image="repro/pattern/pipeline-stage",
            placement=st.get("placement", "edge"),
            resources=st.get("resources", Resources()),
            connections=[stages[i - 1]["name"]] if i > 0 else [],
            params={"init": {
                "fn": st.get("fn"),
                "in_topics": in_topics,
                "out_topic": out_topic,
                "proc_time_s": st.get("proc_time_s", 0.0),
                "out_bytes": st.get("out_bytes", 256),
            }},
        )
    return Topology(app=app, version=1, components=comps)
