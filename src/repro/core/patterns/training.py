"""ECC training pattern (paper §2): federated-style collaborative training.

ECs train locally on private data; model updates cross the WAN through the
file service (data plane) announced over the bridged message service
(control plane); the CC aggregates (FedAvg) and redistributes. The JAX math
(``fedavg``) is shared with the tensor-level federated trainer in
``repro.training.federated`` — here it is wired into ACE components.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from repro.core.registry import image


def fedavg(param_sets: List[Any], weights: Optional[List[float]] = None):
    """Weighted average of parameter pytrees."""
    n = len(param_sets)
    assert n > 0
    w = np.asarray(weights if weights is not None else [1.0] * n, np.float64)
    w = w / w.sum()
    return jax.tree.map(
        lambda *leaves: sum(wi * l for wi, l in zip(w, leaves)), *param_sets)


@image("repro/pattern/fed-worker")
class FedWorker:
    """EC-side trainer: local steps on local data, then upload."""

    def __init__(self, local_train: Callable = None, data=None,
                 model_bytes: int = 1_000_000, rounds: int = 1):
        self.local_train = local_train
        self.data = data
        self.model_bytes = model_bytes
        self.rounds_left = rounds
        self.params = None
        self.history: List[float] = []

    def start(self, ctx) -> None:
        self.ctx = ctx
        files = ctx.services["file"]
        files.on_available(ctx.cluster, "fed/global-*",
                           lambda meta: self._on_global(meta))

    def _on_global(self, meta: dict) -> None:
        files = self.ctx.services["file"]
        files.get(meta["bucket"], meta["key"], self.ctx.cluster,
                  self._train_round)

    def _train_round(self, global_params) -> None:
        if self.rounds_left <= 0:
            return
        self.rounds_left -= 1
        params, loss = self.local_train(global_params, self.data)
        self.params = params
        self.history.append(float(loss))
        files = self.ctx.services["file"]
        files.put("fed", f"update-{self.ctx.instance_id}-{self.rounds_left}",
                  (params, len(self.data[0]) if self.data else 1),
                  self.model_bytes, self.ctx.cluster)


@image("repro/pattern/fed-aggregator")
class FedAvgAggregator:
    """CC-side aggregator: collects EC updates, FedAvgs, redistributes."""

    def __init__(self, init_params=None, num_workers: int = 1,
                 rounds: int = 1, model_bytes: int = 1_000_000):
        self.global_params = init_params
        self.num_workers = num_workers
        self.rounds_left = rounds
        self.model_bytes = model_bytes
        self.pending: List = []
        self.round_idx = 0

    def start(self, ctx) -> None:
        self.ctx = ctx
        files = ctx.services["file"]
        files.on_available(ctx.cluster, "fed/update-*", self._on_update)
        self._broadcast()

    def _broadcast(self) -> None:
        files = self.ctx.services["file"]
        files.put("fed", f"global-{self.round_idx}",
                  self.global_params, self.model_bytes, self.ctx.cluster,
                  lifecycle="temporary")

    def _on_update(self, meta: dict) -> None:
        files = self.ctx.services["file"]
        files.get(meta["bucket"], meta["key"], self.ctx.cluster,
                  self._collect)

    def _collect(self, payload) -> None:
        params, nsamples = payload
        self.pending.append((params, nsamples))
        if len(self.pending) >= self.num_workers:
            sets = [p for p, _ in self.pending]
            weights = [float(n) for _, n in self.pending]
            self.global_params = fedavg(sets, weights)
            self.pending = []
            self.round_idx += 1
            self.rounds_left -= 1
            self.ctx.log("fed_round", round=self.round_idx)
            if self.rounds_left > 0:
                self._broadcast()
