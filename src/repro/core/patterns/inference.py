"""ECC inference pattern (paper §2): intra-model partitioning and
inter-model cascades.

Intra-model (Neurosurgeon/SPINN/JointDNN class): a single model is split by
layers; the edge runs the bottom, ships the boundary activation across the
WAN, the cloud finishes. :func:`best_partition` is the in-app control policy
deciding the split point from napkin latency math — the paper's Principle
Four example.

Inter-model (VideoEdge/SurveilEdge class): a small edge model and a large
cloud model collaborate through a confidence gate — :class:`CascadePair`
(the tensor-level LM version lives in ``repro.cascade``).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.model import LM
from repro.models.layers import rmsnorm


# ---------------------------------------------------------------------------
# Intra-model partitioning
# ---------------------------------------------------------------------------

def _stage_layer_spans(cfg: ModelConfig) -> List[Tuple[int, int]]:
    spans, start = [], 0
    for st in cfg.stages:
        spans.append((start, start + st.repeat))
        start += st.repeat
    return spans


@dataclasses.dataclass
class PartitionedLM:
    """Split an LM at a scanned-layer boundary: layers [0, split) on the
    edge, [split, L_scan) plus head on the cloud."""
    lm: LM
    split: int           # in scanned-layer units (stage repeats)

    def _sliced(self, params, lo_hi):
        lo, hi = lo_hi
        spans = _stage_layer_spans(self.lm.cfg)
        out = []
        for (s0, s1), stage_params in zip(spans, params["stages"]):
            a, b = max(lo, s0), min(hi, s1)
            if a >= b:
                out.append(None)
                continue
            out.append(jax.tree.map(lambda x: x[a - s0:b - s0], stage_params))
        return out

    def edge_forward(self, params, batch):
        """Bottom of the network on the edge; returns the boundary tensor."""
        lm = self.lm
        x, positions = lm._embed_inputs(params, batch)
        for stage, sp in zip(lm.cfg.stages, self._sliced(params, (0, self.split))):
            if sp is None:
                continue
            x, _, _ = lm._stage_forward(stage, sp, x, positions,
                                        want_cache=False, cache_width=None,
                                        train=False)
        return x, positions

    def cloud_forward(self, params, hidden, positions):
        lm = self.lm
        total = sum(st.repeat for st in lm.cfg.stages)
        x = hidden
        for stage, sp in zip(lm.cfg.stages,
                             self._sliced(params, (self.split, total))):
            if sp is None:
                continue
            x, _, _ = lm._stage_forward(stage, sp, x, positions,
                                        want_cache=False, cache_width=None,
                                        train=False)
        x = rmsnorm(params["final_norm"], x, lm.cfg.rms_eps)
        return lm._logits(params, x)

    def boundary_bytes(self, batch_size: int, seq_len: int) -> int:
        d = self.lm.cfg.d_model
        itemsize = jnp.dtype(self.lm.cfg.param_dtype).itemsize
        return batch_size * seq_len * d * itemsize


def layer_flops(cfg: ModelConfig, seq_len: int) -> float:
    """Per-scanned-layer forward FLOPs estimate (weights-dominated)."""
    d, hd = cfg.d_model, cfg.resolved_head_dim
    h, kv = cfg.num_heads, cfg.num_kv_heads
    attn_proj = 2 * seq_len * d * (h + 2 * kv) * hd + 2 * seq_len * h * hd * d
    attn_score = 4 * seq_len * seq_len * h * hd
    if cfg.moe is not None:
        f = cfg.moe.d_ff_expert * cfg.moe.num_experts_per_tok
        f += cfg.moe.d_ff_shared
    else:
        f = cfg.d_ff
    mlp = 6 * seq_len * d * f
    return float(attn_proj + attn_score + mlp)


def best_partition(cfg: ModelConfig, *, batch: int, seq_len: int,
                   edge_flops_s: float, cloud_flops_s: float,
                   uplink_mbps: float, delay_s: float) -> Tuple[int, float]:
    """Neurosurgeon-style split search: argmin_k edge(k) + wan(k) + cloud(k).

    Returns (best split in scanned layers, estimated E2E seconds)."""
    total = sum(st.repeat for st in cfg.stages)
    per_layer = layer_flops(cfg, seq_len) * batch
    d = cfg.d_model
    itemsize = jnp.dtype(cfg.param_dtype).itemsize
    hidden_bytes = batch * seq_len * d * itemsize
    token_bytes = batch * seq_len * 4
    best_k, best_t = 0, float("inf")
    for k in range(total + 1):
        edge_t = k * per_layer / edge_flops_s
        cloud_t = (total - k) * per_layer / cloud_flops_s
        wire = token_bytes if k == 0 else (0 if k == total else hidden_bytes)
        wan_t = (wire * 8 / (uplink_mbps * 1e6)) + (delay_s if wire else 0.0)
        t = edge_t + wan_t + cloud_t
        if t < best_t:
            best_k, best_t = k, t
    return best_k, best_t


# ---------------------------------------------------------------------------
# Inter-model cascade over classifiers (paper §5 EOC/COC shape)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class CascadePair:
    """Edge/cloud classifier cascade with the BP confidence gate."""
    edge_apply: object          # params, images -> logits
    cloud_apply: object
    accept: float = 0.8
    drop: float = 0.1

    def edge_step(self, edge_params, images):
        logits = self.edge_apply(edge_params, images)
        probs = jax.nn.softmax(logits, axis=-1)
        conf = jnp.max(probs, axis=-1)
        pred = jnp.argmax(probs, axis=-1)
        accept = (conf >= self.accept) & (pred == 1)
        drop = conf < self.drop
        escalate = ~accept & ~drop
        # crops predicted 'negative' confidently are also drops
        neg = (conf >= self.accept) & (pred != 1)
        return {"pred": pred, "conf": conf, "accept": accept,
                "drop": drop | neg, "escalate": escalate & ~neg}

    def cloud_step(self, cloud_params, images, target_class: int):
        logits = self.cloud_apply(cloud_params, images)
        top5 = jax.lax.top_k(logits, min(5, logits.shape[-1]))[1]
        hit = jnp.any(top5 == target_class, axis=-1)
        return {"hit": hit}
