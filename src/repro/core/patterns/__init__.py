"""The four ECCI application patterns (paper §2): ECC processing, ECC
training, ECC inference, hybrid collaboration."""
from repro.core.patterns.processing import PipelineStage, pipeline_topology
from repro.core.patterns.inference import CascadePair, PartitionedLM, best_partition
from repro.core.patterns.training import FedAvgAggregator, FedWorker, fedavg
from repro.core.patterns.hybrid import TeacherComponent, StudentComponent

__all__ = ["PipelineStage", "pipeline_topology", "CascadePair",
           "PartitionedLM", "best_partition", "FedAvgAggregator", "FedWorker", "fedavg",
           "TeacherComponent", "StudentComponent"]
