"""Hybrid collaboration pattern (paper §2): combine >= 2 ECCI patterns.

The ShadowTutor shape: the CC runs a heavy *teacher* for inference AND
trains a lightweight *student* online (ECC inference + ECC training); edges
run student inference and periodically fetch refreshed student weights via
the file service. The video query application itself is a hybrid instance
(COC labels training data for EOC, which is trained on the CC and deployed
to edges — paper §5.1.2).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, List, Optional

from repro.core.registry import image


@image("repro/pattern/teacher")
class TeacherComponent:
    """CC: heavy inference + online student training on hard items."""

    def __init__(self, teacher_infer: Callable = None,
                 train_student: Callable = None, student_params=None,
                 refresh_every: int = 8, student_bytes: int = 500_000):
        self.teacher_infer = teacher_infer
        self.train_student = train_student
        self.student_params = student_params
        self.refresh_every = refresh_every
        self.student_bytes = student_bytes
        self.buffer: List = []
        self.version = 0

    def start(self, ctx) -> None:
        self.ctx = ctx
        ctx.subscribe("hybrid/hard", self._on_hard)
        self._publish_student()

    def _on_hard(self, msg) -> None:
        item = msg.payload
        label = self.teacher_infer(item)
        self.ctx.publish("hybrid/teacher-out", {"item": item, "label": label},
                         nbytes=64)
        self.buffer.append((item, label))
        if len(self.buffer) >= self.refresh_every and self.train_student:
            self.student_params = self.train_student(
                self.student_params, self.buffer)
            self.buffer = []
            self.version += 1
            self._publish_student()

    def _publish_student(self) -> None:
        files = self.ctx.services["file"]
        files.put("hybrid", f"student-{self.version}", self.student_params,
                  self.student_bytes, self.ctx.cluster)


@image("repro/pattern/student")
class StudentComponent:
    """Edge: student inference; escalates low-confidence items; hot-swaps
    refreshed student weights announced on the bridged control plane."""

    def __init__(self, student_infer: Callable = None, threshold: float = 0.8):
        self.student_infer = student_infer
        self.threshold = threshold
        self.params = None
        self.results: List = []
        self.escalated = 0

    def start(self, ctx) -> None:
        self.ctx = ctx
        files = ctx.services["file"]
        files.on_available(ctx.cluster, "hybrid/student-*", self._fetch)
        ctx.subscribe("hybrid/in", self._on_item)

    def _fetch(self, meta: dict) -> None:
        files = self.ctx.services["file"]
        files.get(meta["bucket"], meta["key"], self.ctx.cluster,
                  self._swap)

    def _swap(self, params) -> None:
        self.params = params
        self.ctx.log("student_refreshed")

    def _on_item(self, msg) -> None:
        if self.params is None:
            self.ctx.publish("hybrid/hard", msg.payload, nbytes=msg.nbytes)
            self.escalated += 1
            return
        label, conf = self.student_infer(self.params, msg.payload)
        if conf >= self.threshold:
            self.results.append((msg.payload, label))
        else:
            self.escalated += 1
            self.ctx.publish("hybrid/hard", msg.payload, nbytes=msg.nbytes)
