"""Resource-level message service: topic pub/sub with EC<->CC bridging
(paper §4.3.2, Figure 2).

Each cluster (every EC and the CC) runs a local :class:`Broker`; application
clients only ever talk to their *local* broker with a dedicated interface
(link ① in Fig. 2). A long-lasting :class:`Bridge` — the MQTT topic-bridging
analog (link ②) — forwards matching topics between an EC broker and the CC
broker across the WAN model, so edge-cloud interactions are user-transparent.
"""
from __future__ import annotations

import dataclasses
import fnmatch
import itertools
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.core.ids import ClusterId
from repro.core.network import NetworkModel
from repro.core.sim import SimClock


@dataclasses.dataclass
class Message:
    topic: str
    payload: Any
    nbytes: int
    src: str                 # node or component id
    msg_id: int = 0


class Broker:
    """A per-cluster topic broker (Mosquitto analog)."""

    def __init__(self, cluster: ClusterId, clock: SimClock):
        self.cluster = cluster
        self.clock = clock
        self._subs: List[Tuple[str, Callable[[Message], None]]] = []
        self._seq = itertools.count()
        self.delivered = 0

    def subscribe(self, pattern: str, fn: Callable[[Message], None]) -> None:
        """``pattern`` supports MQTT-ish wildcards via fnmatch ('*', '?')."""
        self._subs.append((pattern, fn))

    def unsubscribe(self, pattern: str, fn) -> None:
        self._subs = [(p, f) for (p, f) in self._subs
                      if not (p == pattern and f is fn)]

    def publish(self, topic: str, payload: Any, *, nbytes: int = 256,
                src: str = "") -> Message:
        msg = Message(topic, payload, nbytes, src, next(self._seq))
        self._deliver(msg)
        return msg

    def _deliver(self, msg: Message) -> None:
        for pattern, fn in list(self._subs):
            if fnmatch.fnmatch(msg.topic, pattern):
                self.delivered += 1
                fn(msg)


class Bridge:
    """Long-lasting EC<->CC topic bridge over the WAN model (Fig. 2 link ②).

    Topics matching ``up_patterns`` published on the EC broker are forwarded
    to the CC broker (and vice versa for ``down_patterns``), incurring the
    WAN transfer time. Loop suppression via a bridge marker on the message
    source.
    """

    def __init__(self, ec_broker: Broker, cc_broker: Broker,
                 network: Optional[NetworkModel],
                 up_patterns: List[str], down_patterns: List[str]):
        self.ec = ec_broker
        self.cc = cc_broker
        self.network = network
        self._marker = f"bridge:{ec_broker.cluster}"
        for p in up_patterns:
            self.ec.subscribe(p, self._up)
        for p in down_patterns:
            self.cc.subscribe(p, self._down)

    def _up(self, msg: Message) -> None:
        if msg.src == self._marker:
            return
        self._forward(msg, self.ec.cluster, self.cc.cluster, self.cc)

    def _down(self, msg: Message) -> None:
        # forward CC traffic to this EC unless it originated here (loop
        # guard); traffic bridged up from ANOTHER EC does flow down — that
        # is how edge-edge collaboration transits the CC (paper §4.3.1)
        if msg.src == self._marker:
            return
        self._forward(msg, self.cc.cluster, self.ec.cluster, self.ec)

    def _forward(self, msg: Message, src: ClusterId, dst: ClusterId,
                 target: Broker) -> None:
        def deliver():
            target.publish(msg.topic, msg.payload, nbytes=msg.nbytes,
                           src=self._marker)
        if self.network is None:
            deliver()
        else:
            self.network.send(src, dst, msg.nbytes, deliver)


class MessageService:
    """The E2E resource-level message service: one broker per cluster plus
    bridges EC<->CC. Clients address only their local broker."""

    def __init__(self, clusters: List[ClusterId], clock: SimClock,
                 network: Optional[NetworkModel] = None,
                 bridged_topics: Optional[List[str]] = None):
        self.clock = clock
        self.network = network
        self.brokers: Dict[str, Broker] = {
            str(c): Broker(c, clock) for c in clusters}
        self.bridges: List[Bridge] = []
        cc = [c for c in clusters if c.is_cloud]
        assert len(cc) == 1, "exactly one CC required (paper §4.3.1)"
        self.cc_cluster = cc[0]
        patterns = bridged_topics if bridged_topics is not None else ["*"]
        for c in clusters:
            if not c.is_cloud:
                self.bridges.append(Bridge(
                    self.brokers[str(c)], self.brokers[str(cc[0])],
                    network, up_patterns=patterns, down_patterns=patterns))

    def broker(self, cluster: ClusterId) -> Broker:
        return self.brokers[str(cluster)]
