"""Platform-layer API server (paper §4.2.1): uniform APIs for querying and
manipulating ACE entities (users, infrastructures, clusters, nodes,
applications, deployments) used by the other platform-manager components.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

from repro.core.ids import ClusterId, IdAllocator, InfraId, NodeId
from repro.core.topology import Resources, Topology


@dataclasses.dataclass
class NodeRecord:
    node_id: NodeId
    labels: List[str]
    capacity: Resources
    allocated: Resources = dataclasses.field(
        default_factory=lambda: Resources(cpu=0.0, memory_mb=0))
    status: str = "ready"        # ready | failed | shielded

    @property
    def cluster(self) -> ClusterId:
        return self.node_id.cluster

    def free(self) -> Resources:
        return Resources(
            cpu=self.capacity.cpu - self.allocated.cpu,
            memory_mb=self.capacity.memory_mb - self.allocated.memory_mb,
            accelerator=self.capacity.accelerator)

    def allocate(self, req: Resources) -> None:
        self.allocated = Resources(
            cpu=self.allocated.cpu + req.cpu,
            memory_mb=self.allocated.memory_mb + req.memory_mb,
            accelerator=self.allocated.accelerator)

    def release(self, req: Resources) -> None:
        self.allocated = Resources(
            cpu=max(0.0, self.allocated.cpu - req.cpu),
            memory_mb=max(0, self.allocated.memory_mb - req.memory_mb),
            accelerator=self.allocated.accelerator)


@dataclasses.dataclass
class InfraRecord:
    infra_id: InfraId
    user: str
    clusters: List[ClusterId] = dataclasses.field(default_factory=list)
    nodes: Dict[str, NodeRecord] = dataclasses.field(default_factory=dict)

    @property
    def cc(self) -> ClusterId:
        return next(c for c in self.clusters if c.is_cloud)

    @property
    def ecs(self) -> List[ClusterId]:
        return [c for c in self.clusters if not c.is_cloud]

    def nodes_in(self, cluster: ClusterId) -> List[NodeRecord]:
        return [n for n in self.nodes.values() if n.cluster == cluster]


@dataclasses.dataclass
class AppRecord:
    app: str
    user: str
    infra_id: InfraId
    topology: Topology
    status: str = "submitted"    # submitted | planned | deployed | removed
    plan: Optional[Any] = None   # DeploymentPlan


class ApiServer:
    """In-memory entity store with a uniform query/manipulate API."""

    def __init__(self):
        self.ids = IdAllocator()
        self.users: Dict[str, dict] = {}
        self.infras: Dict[str, InfraRecord] = {}
        self.apps: Dict[str, AppRecord] = {}

    # -- users ----------------------------------------------------------------
    def register_user(self, name: str) -> dict:
        if name in self.users:
            raise ValueError(f"user {name!r} already registered")
        self.users[name] = {"name": name, "infras": [], "apps": []}
        return self.users[name]

    def delete_user(self, name: str) -> None:
        user = self.users.pop(name)
        for iid in user["infras"]:
            self.infras.pop(iid, None)
        for app in user["apps"]:
            self.apps.pop(app, None)

    # -- infrastructure ---------------------------------------------------------
    def register_infra(self, user: str) -> InfraRecord:
        assert user in self.users, f"unknown user {user!r}"
        infra = InfraRecord(self.ids.new_infra(), user)
        self.infras[str(infra.infra_id)] = infra
        self.users[user]["infras"].append(str(infra.infra_id))
        return infra

    def register_cluster(self, infra: InfraRecord, kind: str) -> ClusterId:
        cid = self.ids.new_cluster(infra.infra_id, kind)
        if kind == "cc" and any(c.is_cloud for c in infra.clusters):
            raise ValueError("an infrastructure has exactly one CC")
        infra.clusters.append(cid)
        return cid

    def register_node(self, infra: InfraRecord, cluster: ClusterId,
                      labels: Optional[List[str]] = None,
                      capacity: Optional[Resources] = None) -> NodeRecord:
        nid = self.ids.new_node(cluster)
        rec = NodeRecord(nid, labels or [],
                         capacity or Resources(cpu=4.0, memory_mb=4096))
        infra.nodes[str(nid)] = rec
        return rec

    def shield_node(self, infra: InfraRecord, node_id: str) -> None:
        """Controller shields failed nodes (paper §4.2.1)."""
        infra.nodes[node_id].status = "shielded"

    # -- applications -------------------------------------------------------
    def submit_app(self, user: str, infra_id: str, topo: Topology) -> AppRecord:
        key = f"{user}/{topo.app}"
        rec = AppRecord(topo.app, user, self.infras[infra_id].infra_id, topo)
        self.apps[key] = rec
        self.users[user]["apps"].append(key)
        return rec

    def get_app(self, user: str, app: str) -> AppRecord:
        return self.apps[f"{user}/{app}"]

    def remove_app(self, user: str, app: str) -> None:
        rec = self.apps[f"{user}/{app}"]
        rec.status = "removed"

    # -- queries --------------------------------------------------------------
    def query_nodes(self, infra: InfraRecord, *, placement: str = "any",
                    labels: Optional[List[str]] = None,
                    min_free: Optional[Resources] = None) -> List[NodeRecord]:
        out = []
        for n in infra.nodes.values():
            if n.status != "ready":
                continue
            if placement == "edge" and n.cluster.is_cloud:
                continue
            if placement == "cloud" and not n.cluster.is_cloud:
                continue
            if labels and not set(labels).issubset(set(n.labels)):
                continue
            if min_free and not min_free.fits(n.free()):
                continue
            out.append(n)
        return out
