"""Discrete-event simulation kernel.

Drives the validation-testbed network model (paper §4.2.2) and the Fig. 5
experiment: every transmission, queue and inference occupies simulated time.
Also usable in instant mode (``InstantClock``) where events fire inline —
that is what the platform/integration tests use.
"""
from __future__ import annotations

import heapq
import itertools
from typing import Callable, Optional


class SimClock:
    def __init__(self):
        self.now = 0.0
        self._q = []
        self._seq = itertools.count()

    def schedule(self, delay: float, fn: Callable[[], None]) -> None:
        assert delay >= 0, delay
        heapq.heappush(self._q, (self.now + delay, next(self._seq), fn))

    def schedule_at(self, t: float, fn: Callable[[], None]) -> None:
        self.schedule(max(0.0, t - self.now), fn)

    def run(self, until: Optional[float] = None, max_events: int = 10_000_000) -> int:
        """Process events (optionally up to simulated time ``until``)."""
        n = 0
        while self._q and n < max_events:
            t, _, fn = self._q[0]
            if until is not None and t > until:
                break
            heapq.heappop(self._q)
            self.now = t
            fn()
            n += 1
        if until is not None and self.now < until:
            self.now = until
        return n

    def empty(self) -> bool:
        return not self._q


class InstantClock(SimClock):
    """Clock whose events run inline at schedule time (zero-latency mode)."""

    def schedule(self, delay: float, fn: Callable[[], None]) -> None:
        fn()
