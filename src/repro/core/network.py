"""Edge-cloud network model (the 'validation testbed' of paper §4.2.2).

Models the paper's §5.1.1 setup: each EC has a 100 Mbps WLAN; each EC↔CC WAN
path has software-limited bandwidth (20 Mbps up / 40 Mbps down) and a one-way
delay (0 ms ideal / 50 ms practical). Links are serializing FIFO pipes —
transfers queue behind each other, which is what produces the CI queue
backlog the paper observes under high system load.
"""
from __future__ import annotations

import dataclasses
import random
from typing import Dict, Optional, Tuple

from repro.core.ids import ClusterId
from repro.core.sim import SimClock


@dataclasses.dataclass
class Link:
    """``fault_plan`` (a ``repro.serving.faults.FaultPlan``, duck-typed —
    anything with ``fire(seam)``) injects WAN pathologies per transfer:
    seam ``wan_spike`` adds ``spike_s`` one-way latency to that transfer,
    seam ``wan_outage`` takes the link down for ``outage_s`` first (the
    transfer — and everything queued behind it — starts after the outage
    window, matching a dead-then-recovered pipe)."""
    bandwidth_mbps: float
    delay_s: float = 0.0
    jitter_s: float = 0.0
    _busy_until: float = 0.0
    bytes_sent: int = 0
    fault_plan: Optional[object] = None
    spike_s: float = 0.25
    outage_s: float = 1.0
    outages: int = 0
    spikes: int = 0

    def transfer(self, clock: SimClock, nbytes: int,
                 rng: Optional[random.Random] = None) -> float:
        """Enqueue a transfer; returns the arrival time."""
        tx = nbytes * 8.0 / (self.bandwidth_mbps * 1e6)
        start = max(clock.now, self._busy_until)
        extra = 0.0
        if self.fault_plan is not None:
            if self.fault_plan.fire("wan_outage"):
                self.outages += 1
                start += self.outage_s
            if self.fault_plan.fire("wan_spike"):
                self.spikes += 1
                extra = self.spike_s
        self._busy_until = start + tx
        jitter = rng.uniform(0, self.jitter_s) if (rng and self.jitter_s) else 0.0
        self.bytes_sent += nbytes
        return self._busy_until + self.delay_s + jitter + extra

    @property
    def queue_s(self) -> float:
        return max(0.0, self._busy_until)


class NetworkModel:
    """Routes (src_cluster -> dst_cluster) over LAN/WAN links and meters
    edge-cloud bandwidth consumption (the paper's BWC metric)."""

    def __init__(self, clock: SimClock, *, lan_mbps: float = 100.0,
                 uplink_mbps: float = 20.0, downlink_mbps: float = 40.0,
                 wan_delay_s: float = 0.0, jitter_s: float = 0.0,
                 seed: int = 0, fault_plan: Optional[object] = None):
        self.clock = clock
        self.rng = random.Random(seed)
        self.lan_mbps = lan_mbps
        self.uplink_mbps = uplink_mbps
        self.downlink_mbps = downlink_mbps
        self.wan_delay_s = wan_delay_s
        self.jitter_s = jitter_s
        # WAN chaos: spikes/outages apply to cross-boundary links only
        # (the LAN inside a cluster is not the fragile part of the story)
        self.fault_plan = fault_plan
        self._links: Dict[Tuple[str, str], Link] = {}

    def link(self, src: ClusterId, dst: ClusterId) -> Link:
        key = (str(src), str(dst))
        if key not in self._links:
            if src == dst:
                l = Link(self.lan_mbps, 0.0)
            elif dst.is_cloud and not src.is_cloud:
                l = Link(self.uplink_mbps, self.wan_delay_s, self.jitter_s,
                         fault_plan=self.fault_plan)
            elif src.is_cloud and not dst.is_cloud:
                l = Link(self.downlink_mbps, self.wan_delay_s, self.jitter_s,
                         fault_plan=self.fault_plan)
            else:  # EC <-> EC goes through the CC in the paper's topology
                l = Link(self.uplink_mbps, 2 * self.wan_delay_s, self.jitter_s,
                         fault_plan=self.fault_plan)
            self._links[key] = l
        return self._links[key]

    def send(self, src: ClusterId, dst: ClusterId, nbytes: int, fn) -> None:
        """Deliver ``fn`` at the simulated arrival time of the transfer."""
        if src == dst:
            # same-cluster LAN hop
            arrival = self.link(src, dst).transfer(self.clock, nbytes, self.rng)
        else:
            arrival = self.link(src, dst).transfer(self.clock, nbytes, self.rng)
        self.clock.schedule_at(arrival, fn)

    # -- metering ------------------------------------------------------------
    def wan_bytes(self) -> int:
        """Total bytes crossing any EC<->CC boundary (the BWC metric)."""
        total = 0
        for (src, dst), link in self._links.items():
            if (".cc-" in src) != (".cc-" in dst):
                total += link.bytes_sent
        return total
