"""Hierarchical IDs (paper §4.3.1).

ACE assigns a unique infrastructure ID per user, a second-layer ID per EC /
CC affiliated to it, and a third-layer ID per node affiliated to its
cluster:  ``infra-3 / infra-3.ec-1 / infra-3.ec-1.n-2``.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Optional


@dataclasses.dataclass(frozen=True)
class InfraId:
    num: int

    def __str__(self):
        return f"infra-{self.num}"


@dataclasses.dataclass(frozen=True)
class ClusterId:
    infra: InfraId
    kind: str        # "ec" | "cc"
    num: int

    def __str__(self):
        return f"{self.infra}.{self.kind}-{self.num}"

    @property
    def is_cloud(self) -> bool:
        return self.kind == "cc"


@dataclasses.dataclass(frozen=True)
class NodeId:
    cluster: ClusterId
    num: int

    def __str__(self):
        return f"{self.cluster}.n-{self.num}"


class IdAllocator:
    """Monotonic allocator for the three ID layers."""

    def __init__(self):
        self._infra = itertools.count(1)
        self._clusters = {}
        self._nodes = {}

    def new_infra(self) -> InfraId:
        return InfraId(next(self._infra))

    def new_cluster(self, infra: InfraId, kind: str) -> ClusterId:
        assert kind in ("ec", "cc")
        key = (infra, kind)
        self._clusters.setdefault(key, itertools.count(1))
        return ClusterId(infra, kind, next(self._clusters[key]))

    def new_node(self, cluster: ClusterId) -> NodeId:
        self._nodes.setdefault(cluster, itertools.count(1))
        return NodeId(cluster, next(self._nodes[cluster]))


def parse_node_id(s: str) -> Optional[dict]:
    """'infra-1.ec-2.n-3' -> {'infra': 1, 'kind': 'ec', 'cluster': 2, 'node': 3}."""
    parts = s.split(".")
    if len(parts) != 3:
        return None
    try:
        infra = int(parts[0].split("-")[1])
        kind, cnum = parts[1].split("-")
        node = int(parts[2].split("-")[1])
        return {"infra": infra, "kind": kind, "cluster": int(cnum),
                "node": node}
    except (IndexError, ValueError):
        return None
