"""Trip-count-aware HLO cost model.

XLA's ``compiled.cost_analysis()`` counts a ``while`` body ONCE, so for
scan-over-layers models every per-device number is low by ~num_layers
(verified: a 10-iteration scanned matmul reports 1x the flops). This module
re-derives per-device costs from the optimized HLO text, weighting each
computation by its loop trip count:

  * dot flops        2 * prod(result dims) * K   (K from contracting dims)
  * collective bytes result bytes of all-gather/all-reduce/reduce-scatter/
                     all-to-all/collective-permute (start/done deduped)
  * hbm bytes        proxy: result bytes of top-level ops (fusion internals
                     excluded), counted once written + once read

Trip counts come from the largest integer literal in the while condition
computation — exact for ``lax.scan``/``fori_loop`` lowerings.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 0.125, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2,
    "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_HDR = re.compile(r"^(?:ENTRY )?%?([\w\.\-_]+)\s*\((.*)\)\s*->")
_CALLS_RE = re.compile(r"(?:calls|to_apply|body|condition|branch_computations)="
                       r"\{?%?([\w\.\-_,% ]+)\}?")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_list(text: str) -> List[Tuple[str, List[int]]]:
    out = []
    for dtype, dims in _SHAPE_RE.findall(text):
        if dtype in _DTYPE_BYTES:
            out.append((dtype, [int(d) for d in dims.split(",") if d]))
    return out


def _shape_bytes(text: str) -> float:
    total = 0.0
    for dtype, dims in _shape_list(text):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclasses.dataclass
class CompCost:
    dot_flops: float = 0.0
    transcendental: float = 0.0
    coll_bytes: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in _COLLECTIVES})
    coll_counts: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in _COLLECTIVES})
    result_bytes: float = 0.0
    # (callee, weight, kind): weight multiplied in when resolving
    calls: List[Tuple[str, str]] = dataclasses.field(default_factory=list)
    whiles: List[Tuple[str, str, Optional[int]]] = dataclasses.field(default_factory=list)
    max_const: int = 0
    is_fusion: bool = False


def _parse_computations(hlo: str) -> Tuple[Dict[str, CompCost], Optional[str]]:
    comps: Dict[str, CompCost] = {}
    entry: Optional[str] = None
    cur: Optional[CompCost] = None
    cur_name = None
    symbols: Dict[str, List[int]] = {}        # %name -> dims (module-wide)
    for raw in hlo.splitlines():
        line = raw.rstrip()
        if not line:
            continue
        if not raw.startswith(" "):           # computation header / close
            m = _COMP_HDR.match(line.lstrip())
            if m and "{" in line:
                cur_name = m.group(1)
                cur = comps.setdefault(cur_name, CompCost())
                cur.is_fusion = cur_name.startswith(("fused_", "wide."))
                if line.lstrip().startswith("ENTRY"):
                    entry = cur_name
                # header params: "name: f32[..]" pairs
                for pname, ptype in re.findall(r"([\w\.\-_]+):\s*(\S+)",
                                               m.group(2)):
                    shapes = _shape_list(ptype)
                    if len(shapes) == 1:
                        symbols[pname] = shapes[0][1]
                continue
            if line.startswith("}"):
                cur = None
                continue
        if cur is None:
            continue
        stripped = line.strip()
        if "=" not in stripped:
            continue
        # constants (trip-count candidates)
        for c in _CONST_RE.findall(stripped):
            cur.max_const = max(cur.max_const, int(c))
        lhs, _, rhs = stripped.partition(" = ")
        # opcode = first token after result type(s)
        m_op = re.search(
            r"\)?\s([a-z][a-z0-9\-]*)\(", rhs)
        opcode = m_op.group(1) if m_op else ""
        result_clause = rhs[:m_op.start()] if m_op else rhs
        shapes = _shape_list(result_clause)
        if len(shapes) == 1:
            symbols[lhs.strip().lstrip("%")] = shapes[0][1]
        rb = _shape_bytes(result_clause)
        if not cur.is_fusion:
            # fusion-internal intermediates never touch HBM
            cur.result_bytes += rb
        if opcode == "dot":
            cur.dot_flops += _dot_flops(rhs, result_clause, symbols)
        elif opcode in ("exponential", "tanh", "log", "rsqrt", "power",
                        "sine", "cosine"):
            shapes = _shape_list(result_clause)
            cur.transcendental += sum(
                float(_prod(d)) for _, d in shapes)
        else:
            for kind in _COLLECTIVES:
                if opcode in (kind, kind + "-start"):
                    cur.coll_bytes[kind] += rb
                    cur.coll_counts[kind] += 1
                    break
        if opcode == "while":
            mt = re.search(r'"known_trip_count":\{"n":"(\d+)"\}', rhs)
            trip = int(mt.group(1)) if mt else None
            m = re.search(r"condition=%?([\w\.\-_]+), body=%?([\w\.\-_]+)",
                          rhs)
            if not m:
                m = re.search(r"body=%?([\w\.\-_]+), condition=%?([\w\.\-_]+)",
                              rhs)
                if m:
                    cur.whiles.append((m.group(2), m.group(1), trip))
            else:
                cur.whiles.append((m.group(1), m.group(2), trip))
        else:
            mc = _CALLS_RE.search(rhs)
            if mc and opcode not in ("while",):
                for callee in re.split(r"[ ,]+", mc.group(1)):
                    callee = callee.strip().lstrip("%")
                    if callee:
                        cur.calls.append((callee, opcode))
    return comps, entry


def _prod(dims: List[int]) -> int:
    n = 1
    for d in dims:
        n *= d
    return n


def _dot_flops(rhs: str, result_clause: str,
               symbols: Dict[str, List[int]]) -> float:
    shapes = _shape_list(result_clause)
    out_elems = sum(_prod(d) for _, d in shapes)
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", rhs)
    # lhs operand: inline shape literal, or symbol lookup of operand name
    after = rhs.split("dot(", 1)[1] if "dot(" in rhs else ""
    lhs_shapes = _shape_list(after.split(",")[0])
    if lhs_shapes:
        lhs_dims = lhs_shapes[0][1]
    else:
        opname = after.split(",")[0].split(")")[0].strip().lstrip("%")
        lhs_dims = symbols.get(opname, [])
    k = 1
    if m and lhs_dims:
        for i in m.group(1).split(","):
            if i and int(i) < len(lhs_dims):
                k *= lhs_dims[int(i)]
    return 2.0 * out_elems * k


def analyze(hlo: str) -> dict:
    """Whole-module per-device costs with loop weighting."""
    comps, entry = _parse_computations(hlo)
    if entry is None:
        raise ValueError("no ENTRY computation found")

    memo: Dict[str, dict] = {}

    def resolve(name: str, stack=()) -> dict:
        if name in memo:
            return memo[name]
        if name not in comps or name in stack:
            return _zero()
        c = comps[name]
        total = {
            "dot_flops": c.dot_flops,
            "transcendental": c.transcendental,
            "result_bytes": c.result_bytes,
            "coll_bytes": dict(c.coll_bytes),
            "coll_counts": dict(c.coll_counts),
        }
        for callee, kind in c.calls:
            sub = resolve(callee, stack + (name,))
            _acc(total, sub, 1.0)
        for cond, body, known in c.whiles:
            trip = known if known is not None else max(
                comps.get(cond, CompCost()).max_const, 1)
            sub = resolve(body, stack + (name,))
            _acc(total, sub, float(trip))
            _acc(total, resolve(cond, stack + (name,)), float(trip))
        memo[name] = total
        return total

    out = resolve(entry)
    out["collective_bytes_total"] = sum(out["coll_bytes"].values())
    # HBM proxy: write + read of every materialized result
    out["hbm_bytes"] = 2.0 * out["result_bytes"]
    return out


def _zero() -> dict:
    return {"dot_flops": 0.0, "transcendental": 0.0, "result_bytes": 0.0,
            "coll_bytes": {k: 0.0 for k in _COLLECTIVES},
            "coll_counts": {k: 0.0 for k in _COLLECTIVES}}


def _acc(total: dict, sub: dict, w: float) -> None:
    total["dot_flops"] += w * sub["dot_flops"]
    total["transcendental"] += w * sub["transcendental"]
    total["result_bytes"] += w * sub["result_bytes"]
    for k in _COLLECTIVES:
        total["coll_bytes"][k] += w * sub["coll_bytes"][k]
        total["coll_counts"][k] += w * sub["coll_counts"][k]
