"""Roofline analysis (deliverable g).

For each dry-run record, derive the three roofline terms (TPU v5e):

    compute    = HLO_FLOPs_per_device / peak_FLOP/s           (197 TF bf16)
    memory     = HLO_bytes_per_device / HBM_bw                (819 GB/s)
    collective = collective_bytes_per_device / link_bw        (~50 GB/s/link)

``cost_analysis()`` on the SPMD module reports *per-device* flops/bytes, and
the HLO shape inventory (``collectives`` in the record) likewise sums
per-device result bytes — so all three terms are per-device seconds and the
chip count in the brief's formulas is already folded in. Collective bytes
count each op's result once (ring-algorithm factors ~2(n-1)/n are not
modelled; noted in EXPERIMENTS.md).

Also reported: MODEL_FLOPS = 6*N*D (6*N_active*D for MoE) and the usefulness
ratio MODEL_FLOPS / (HLO_FLOPs * devices) which exposes remat/redundant
compute.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional

import numpy as np

from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16


def _param_counts(arch: str) -> Dict[str, float]:
    """(total, active) parameter counts from the abstract param tree."""
    import jax
    from repro.launch.specs import resolved_config
    from repro.models.model import LM
    cfg = resolved_config(arch, "train_4k")
    lm = LM(cfg)
    params, axes = lm.abstract()
    total = active = 0.0
    flat_p = jax.tree.leaves(params)
    flat_a = jax.tree.leaves(axes, is_leaf=lambda x: isinstance(x, tuple))
    frac = 1.0
    if cfg.moe is not None:
        frac = cfg.moe.num_experts_per_tok / cfg.moe.num_experts
    for leaf, ax in zip(flat_p, flat_a):
        n = float(np.prod(leaf.shape))
        total += n
        active += n * (frac if "expert" in ax else 1.0)
    return {"total": total, "active": active}


_COUNT_CACHE: Dict[str, Dict[str, float]] = {}


def param_counts(arch: str) -> Dict[str, float]:
    if arch not in _COUNT_CACHE:
        _COUNT_CACHE[arch] = _param_counts(arch)
    return _COUNT_CACHE[arch]


def roofline_from_record(rec: dict, counts: Optional[dict] = None) -> dict:
    w = rec.get("weighted") or {}
    if "dot_flops" in w:
        # trip-count-weighted HLO costs (preferred; XLA's module-level
        # numbers count scan bodies once)
        flops_dev = w["dot_flops"]
        bytes_dev = w["hbm_bytes"]
        coll_dev = w["collective_bytes_total"]
    else:
        flops_dev = rec["cost"].get("flops", 0.0) or 0.0
        bytes_dev = rec["cost"].get("bytes accessed", 0.0) or 0.0
        coll_dev = sum(v["bytes"] for v in rec["collectives"].values())
    t_compute = flops_dev / PEAK_FLOPS_BF16
    t_memory = bytes_dev / HBM_BW
    t_collective = coll_dev / ICI_BW
    terms = {"compute": t_compute, "memory": t_memory,
             "collective": t_collective}
    dominant = max(terms, key=terms.get)

    counts = counts or param_counts(rec["arch"])
    # tokens processed by this step
    if rec["mode"] == "train":
        tokens = rec["seq_len"] * rec["global_batch"]
        mult = 3.0          # fwd + bwd (2x)
    elif rec["mode"] == "prefill":
        tokens = rec["seq_len"] * rec["global_batch"]
        mult = 1.0
    else:
        tokens = rec["global_batch"]          # one token per sequence
        mult = 1.0
    model_flops = 2.0 * counts["active"] * tokens * mult
    hlo_total = flops_dev * rec["devices"]
    useful = model_flops / hlo_total if hlo_total else 0.0

    hbm_gib = None
    mem = rec.get("memory", {})
    if mem.get("temp_bytes_per_device") is not None:
        hbm_gib = (mem["temp_bytes_per_device"]
                   + (mem.get("argument_bytes_per_device") or 0)) / 2 ** 30

    suggestion = {
        "compute": "raise arithmetic efficiency: larger fused matmul tiles /"
                   " fewer remat passes",
        "memory": "cut HBM traffic: smaller f32 transients (attention/moe"
                  " chunks), fuse elementwise chains, bf16 logits",
        "collective": "reshard to cut boundary bytes: bigger per-shard"
                      " blocks, overlap FSDP gathers, all-to-all dispatch",
    }[dominant]
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "mode": rec["mode"],
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_collective_s": t_collective, "dominant": dominant,
        "model_flops": model_flops, "hlo_flops_total": hlo_total,
        "useful_ratio": useful,
        "hbm_gib_per_device": hbm_gib,
        "suggestion": suggestion,
    }


def roofline_table(dryrun_dir: str = "results/dryrun",
                   mesh: str = "pod16x16") -> List[dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, f"*__{mesh}.json"))):
        with open(path) as f:
            rec = json.load(f)
        if rec["arch"].startswith("cascade-"):
            continue      # cascade records are reported in §Perf
        rows.append(roofline_from_record(rec))
    return rows


def format_table(rows: List[dict]) -> str:
    hdr = (f"{'arch':22s} {'shape':12s} {'compute':>10s} {'memory':>10s} "
           f"{'collect':>10s} {'dominant':>10s} {'useful':>7s} {'HBM GiB':>8s}")
    lines = [hdr, "-" * len(hdr)]
    for r in rows:
        lines.append(
            f"{r['arch']:22s} {r['shape']:12s} "
            f"{r['t_compute_s']*1e3:9.2f}m {r['t_memory_s']*1e3:9.2f}m "
            f"{r['t_collective_s']*1e3:9.2f}m {r['dominant']:>10s} "
            f"{r['useful_ratio']:7.2f} "
            f"{(r['hbm_gib_per_device'] or 0):8.1f}")
    return "\n".join(lines)


if __name__ == "__main__":
    import sys
    mesh = sys.argv[1] if len(sys.argv) > 1 else "pod16x16"
    print(format_table(roofline_table(mesh=mesh)))
