"""Re-run the HLO cost model over persisted dry-run HLO (no recompilation).

    PYTHONPATH=src python -m repro.analysis.reanalyze [results/dryrun]
"""
import glob
import gzip
import json
import sys

from repro.analysis.hlo_cost import analyze


def main() -> None:
    d = sys.argv[1] if len(sys.argv) > 1 else "results/dryrun"
    for path in sorted(glob.glob(f"{d}/*.json")):
        hpath = path.replace(".json", ".hlo.gz")
        try:
            with gzip.open(hpath, "rt") as f:
                hlo = f.read()
        except FileNotFoundError:
            print(f"skip (no hlo): {path}")
            continue
        with open(path) as f:
            rec = json.load(f)
        try:
            rec["weighted"] = analyze(hlo)
        except Exception as e:  # noqa: BLE001
            rec["weighted"] = {"error": repr(e)}
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
        print(f"reanalyzed {path}")


if __name__ == "__main__":
    main()
