from repro.analysis.roofline import roofline_from_record, roofline_table

__all__ = ["roofline_from_record", "roofline_table"]
