"""AdamW and SGD, pytree-native.

Optimizer state dtype is configurable: f32 (default) or bf16 — the bf16
option matters at deepseek-v3 scale where f32 moments alone exceed the
per-chip HBM budget on a single pod (see EXPERIMENTS.md §Roofline).
States are sharded like their parameters (the launcher applies the same
PartitionSpecs), i.e. ZeRO-style by construction.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    mu: Any
    nu: Any


def adamw_init(params, state_dtype=jnp.float32) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, state_dtype)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      mu=jax.tree.map(zeros, params),
                      nu=jax.tree.map(zeros, params))


def adamw_update(params, grads, state: AdamWState, *, lr, b1: float = 0.9,
                 b2: float = 0.95, eps: float = 1e-8,
                 weight_decay: float = 0.0,
                 grad_clip: Optional[float] = 1.0):
    """Returns (new_params, new_state). ``lr`` may be a scalar or a
    schedule value already resolved for this step."""
    step = state.step + 1
    if grad_clip is not None:
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                             for g in jax.tree.leaves(grads)))
        scale = jnp.minimum(1.0, grad_clip / jnp.maximum(gnorm, 1e-9))
        grads = jax.tree.map(lambda g: g * scale.astype(g.dtype), grads)

    sdt = jax.tree.leaves(state.mu)[0].dtype

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m32 = m.astype(jnp.float32)
        v32 = v.astype(jnp.float32)
        m_new = b1 * m32 + (1 - b1) * g32
        v_new = b2 * v32 + (1 - b2) * jnp.square(g32)
        mhat = m_new / (1 - b1 ** step.astype(jnp.float32))
        vhat = v_new / (1 - b2 ** step.astype(jnp.float32))
        delta = mhat / (jnp.sqrt(vhat) + eps)
        if weight_decay:
            delta = delta + weight_decay * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr * delta
        return p_new.astype(p.dtype), m_new.astype(sdt), v_new.astype(sdt)

    out = jax.tree.map(upd, params, grads, state.mu, state.nu)
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_mu = jax.tree.map(lambda t: t[1], out,
                          is_leaf=lambda x: isinstance(x, tuple))
    new_nu = jax.tree.map(lambda t: t[2], out,
                          is_leaf=lambda x: isinstance(x, tuple))
    return new_params, AdamWState(step=step, mu=new_mu, nu=new_nu)


class SGDState(NamedTuple):
    step: jnp.ndarray
    momentum: Any


def sgd_init(params, state_dtype=jnp.float32) -> SGDState:
    return SGDState(step=jnp.zeros((), jnp.int32),
                    momentum=jax.tree.map(
                        lambda p: jnp.zeros(p.shape, state_dtype), params))


def sgd_update(params, grads, state: SGDState, *, lr, momentum: float = 0.9):
    def upd(p, g, m):
        m_new = momentum * m.astype(jnp.float32) + g.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * m_new).astype(p.dtype), \
            m_new.astype(m.dtype)

    out = jax.tree.map(upd, params, grads, state.momentum)
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    return new_params, SGDState(step=state.step + 1, momentum=new_m)
