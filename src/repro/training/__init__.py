from repro.training.train_loop import Trainer, make_train_step
from repro.training.federated import FederatedTrainer

__all__ = ["Trainer", "make_train_step", "FederatedTrainer"]
