"""Tensor-level federated trainer (ECC training pattern, paper §2).

Mirrors the component-level ``core.patterns.training`` but at mesh scale:
each EC maps to a slice of the ``data`` axis; local steps run independently
per slice (no gradient sync), and every ``sync_every`` steps a FedAvg
all-reduce over the EC axis averages the diverged replicas — the WAN round.
Implemented with ``shard_map`` so the local steps are truly independent (no
cross-EC collectives inside the local phase).
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as PS
try:                             # moved to the jax top level in newer jax
    from jax import shard_map
except ImportError:              # pragma: no cover - jax<0.5 fallback
    from jax.experimental.shard_map import shard_map

# the replication-check kwarg was renamed check_rep -> check_vma
import inspect as _inspect
_SM_CHECK = ({"check_vma": False}
             if "check_vma" in _inspect.signature(shard_map).parameters
             else {"check_rep": False})

from repro.optim import sgd_init, sgd_update


class FederatedTrainer:
    """FedAvg over the mesh's ``data`` axis (each shard = one EC)."""

    def __init__(self, loss_fn: Callable, mesh: Mesh, *, lr: float = 0.05,
                 local_steps: int = 4, axis: str = "data"):
        self.loss_fn = loss_fn
        self.mesh = mesh
        self.lr = lr
        self.local_steps = local_steps
        self.axis = axis
        self._round = self._build()

    def _build(self):
        axis = self.axis
        loss_fn = self.loss_fn
        lr = self.lr
        local_steps = self.local_steps

        def fed_round(params, opt, batch):
            """params are per-EC replicas stacked on a leading axis that is
            sharded over the EC mesh axis; batch likewise."""
            def local(params, opt, batch):
                # strip the leading local axis of size 1 inside the shard
                p = jax.tree.map(lambda x: x[0], params)
                o = jax.tree.map(lambda x: x[0], opt)
                b = jax.tree.map(lambda x: x[0], batch)

                def one_step(carry, xs):
                    p, o = carry
                    loss, g = jax.value_and_grad(loss_fn)(p, b)
                    p, o = sgd_update(p, g, o, lr=lr)
                    return (p, o), loss

                (p, o), losses = jax.lax.scan(
                    one_step, (p, o), None, length=local_steps)
                # FedAvg: all-reduce mean over the EC axis (the WAN round)
                p = jax.tree.map(
                    functools.partial(jax.lax.pmean, axis_name=axis), p)
                mean_loss = jax.lax.pmean(losses[-1], axis_name=axis)
                add = lambda x: x[None]
                return (jax.tree.map(add, p), jax.tree.map(add, o),
                        mean_loss[None])

            spec_leading = PS(self.axis)
            return shard_map(
                local, mesh=self.mesh,
                in_specs=(spec_leading, spec_leading, spec_leading),
                out_specs=(spec_leading, spec_leading, spec_leading),
                **_SM_CHECK,
            )(params, opt, batch)

        return jax.jit(fed_round)

    # -- host API ---------------------------------------------------------------
    def replicate(self, params):
        """Stack one replica per EC along a leading sharded axis."""
        n = self.mesh.shape[self.axis]
        stacked = jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (n,) + x.shape), params)
        sharding = NamedSharding(self.mesh, PS(self.axis))
        return jax.device_put(stacked, sharding)

    def init_opt(self, replicated_params):
        # one optimizer state per EC, every leaf (incl. the scalar step)
        # stacked on the sharded leading axis
        local = jax.tree.map(lambda x: x[0], replicated_params)
        return self.replicate(sgd_init(local))

    def round(self, params, opt, batch):
        """batch: leading axis = num ECs (local datasets, non-IID allowed)."""
        return self._round(params, opt, batch)

    def unreplicate(self, params):
        return jax.tree.map(lambda x: x[0], params)
