"""Training loop substrate: train_step factory (grads + AdamW update, remat
inside the model's scanned stages) and a host-side Trainer driver with
checkpointing and metric logging.
"""
from __future__ import annotations

import time
from typing import Any, Callable, Dict, Iterator, Optional

import jax
import jax.numpy as jnp

from repro.checkpoint import latest_step, load_checkpoint, save_checkpoint
from repro.models.model import LM
from repro.optim import adamw_init, adamw_update


def make_train_step(lm: LM, lr_schedule: Callable,
                    weight_decay: float = 0.01,
                    grad_clip: float = 1.0) -> Callable:
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics).

    This is the function the dry-run lowers for ``train_4k``: forward (+MoE
    aux, +MTP), backward through the rematerialized scanned stages, global
    grad-norm clip, AdamW."""

    def train_step(params, opt_state, batch):
        def loss_fn(p):
            loss, metrics = lm.loss(p, batch, train=True)
            return loss, metrics

        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params)
        lr = lr_schedule(opt_state.step)
        params_new, opt_new = adamw_update(
            params, grads, opt_state, lr=lr, weight_decay=weight_decay,
            grad_clip=grad_clip)
        out = {"loss": loss, "lr": lr}
        out.update(metrics)
        return params_new, opt_new, out

    return train_step


def make_eval_step(lm: LM) -> Callable:
    def eval_step(params, batch):
        loss, metrics = lm.loss(params, batch, train=False)
        return {"loss": loss, **metrics}
    return eval_step


class Trainer:
    """Host driver: jit once, iterate batches, checkpoint, log."""

    def __init__(self, lm: LM, lr_schedule, *, ckpt_dir: Optional[str] = None,
                 opt_state_dtype=jnp.float32, weight_decay: float = 0.01,
                 log_every: int = 10, ckpt_every: int = 100,
                 donate: bool = True):
        self.lm = lm
        self.ckpt_dir = ckpt_dir
        self.log_every = log_every
        self.ckpt_every = ckpt_every
        self.opt_state_dtype = opt_state_dtype
        step_fn = make_train_step(lm, lr_schedule, weight_decay)
        self.train_step = jax.jit(
            step_fn, donate_argnums=(0, 1) if donate else ())
        self.history: list = []

    def init_state(self, rng):
        params, _ = self.lm.init(rng)
        opt = adamw_init(params, self.opt_state_dtype)
        return params, opt

    def restore_or_init(self, rng):
        params, opt = self.init_state(rng)
        if self.ckpt_dir and latest_step(self.ckpt_dir) is not None:
            (params, opt), step = load_checkpoint(self.ckpt_dir, (params, opt))
            print(f"[trainer] restored step {step} from {self.ckpt_dir}")
        return params, opt

    def fit(self, params, opt, batches: Iterator[Dict[str, Any]],
            num_steps: int, echo: bool = True):
        t0 = time.time()
        for i in range(num_steps):
            batch = next(batches)
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            params, opt, metrics = self.train_step(params, opt, batch)
            if i % self.log_every == 0 or i == num_steps - 1:
                m = {k: float(v) for k, v in metrics.items()}
                m["step"] = i
                m["wall_s"] = round(time.time() - t0, 2)
                self.history.append(m)
                if echo:
                    print(f"[trainer] step {i:5d} loss {m['loss']:.4f} "
                          f"lr {m['lr']:.2e} ({m['wall_s']}s)")
            if (self.ckpt_dir and self.ckpt_every
                    and (i + 1) % self.ckpt_every == 0):
                save_checkpoint(self.ckpt_dir, i + 1, (params, opt))
        return params, opt
