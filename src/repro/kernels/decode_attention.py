"""Cached-attention Pallas kernels — decode tokens or prompt chunks against
a ring KV cache or a paged (block-table) KV pool (causal, sliding-window,
GQA).

This is the memory-bound half of serving: every decode step streams the
whole cache through the core once per layer, so the kernel's job is to keep
that stream at HBM bandwidth while the MXU work stays tiny. TPU mapping:
grid ``(B, KV, num_kv_blocks)``; the last axis is the sequential
("arbitrary") reduction over cache blocks with the streaming-softmax carry
(acc, m, l) held in VMEM scratch. GQA is handled by folding the query group
into the head tile: each (batch, kv-head) program attends with a
``(group, head_dim)`` q tile against shared ``(block_k, head_dim)`` k/v
tiles, so KV blocks are fetched once per group rather than once per q head.

Queries generalize from one decode token to a ``T``-token prompt chunk
(chunked prefill): the q tile becomes ``(T x group, head_dim)`` with a
per-query-token position vector, and the validity mask broadcasts over the
group — the streaming carry and the block skip are shape-agnostic. The
chunk's own K/V are appended to the cache before the call, so intra-chunk
causality is ordinary position masking.

Positions are data, not geometry: the cache is a ring (slot = pos % width),
so causal/window masking reads the per-slot ``k_pos`` array (−1 = empty
slot) instead of assuming contiguous layout. Blocks whose every slot is
masked (empty ring tail, outside the window) skip the MXU work entirely via
``pl.when`` — on a cold cache only the written prefix costs anything.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30

# renamed TPUCompilerParams -> CompilerParams across jax versions
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))


def _chunk_positions(q_pos, b: int, t: int) -> jnp.ndarray:
    """(B,) start positions or (B, T) per-token positions -> (B, T); the
    normalization rule is shared with the oracle (``ref.query_positions``)
    so kernel and reference can never disagree about chunk geometry."""
    from repro.kernels.ref import query_positions
    return query_positions(q_pos, t).reshape(b, t)


def _kernel(q_ref, k_ref, v_ref, qpos_ref, kpos_ref, o_ref,
            acc_ref, m_ref, l_ref, *, scale: float, window: Optional[int],
            num_k: int, q_tokens: int, group: int):
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0]                           # (T*G, hd)
    k = k_ref[0, :, 0, :]                     # (bk, hd)
    v = v_ref[0, :, 0, :]
    qp = qpos_ref[0]                          # (T,) query-token positions
    kp = kpos_ref[0:1, :]                     # (1, bk) ring-slot positions

    valid = (kp >= 0) & (kp <= qp[:, None])   # (T, bk): empties + causality
    if window is not None:
        valid &= kp > (qp[:, None] - window)

    # data-dependent block skip: a ring cache is mostly empty early on, and
    # a sliding window masks all but ~window/block_k blocks
    @pl.when(jnp.any(valid))
    def _compute():
        bk = k.shape[0]
        s = jax.lax.dot_general(
            q.astype(jnp.float32), k.astype(jnp.float32),
            (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale       # (T*G, bk)
        mask = jnp.broadcast_to(valid[:, None, :],
                                (q_tokens, group, bk)).reshape(-1, bk)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        pv = jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * alpha + pv
        m_ref[...] = m_new

    @pl.when(ik == num_k - 1)
    def _flush():
        out = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = out.astype(o_ref.dtype)


def decode_attention(q, k, v, q_pos, k_pos, *, window: Optional[int] = None,
                     scale: Optional[float] = None, block_k: int = 128,
                     interpret: bool = False):
    """q: (B, T, H, hd) or (B, H, hd) (T = 1); k, v: (B, W, KV, hd) ring
    cache; q_pos: (B,) int32 chunk start positions (per-token positions are
    start + i) or (B, T) explicit positions; k_pos: (B, W) int32 cache-slot
    positions (−1 = empty). Returns attention output shaped like q.
    """
    no_time = q.ndim == 3
    if no_time:
        q = q[:, None]
    b, t, h, hd = q.shape
    w, kv = k.shape[1], k.shape[2]
    assert h % kv == 0
    g = h // kv
    scale = scale if scale is not None else hd ** -0.5
    block_k = min(block_k, w)

    pad = (-w) % block_k
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, ((0, 0), (0, pad)), constant_values=-1)
    nk = k.shape[1] // block_k

    # fold (token, group) into one q-row axis: row i = token i//g, head i%g
    qg = jnp.moveaxis(q.reshape(b, t, kv, g, hd), 2, 1).reshape(
        b, kv, t * g, hd)
    qp = _chunk_positions(q_pos, b, t)
    kp = jnp.asarray(k_pos, jnp.int32)

    kernel = functools.partial(_kernel, scale=scale, window=window, num_k=nk,
                               q_tokens=t, group=g)
    out = pl.pallas_call(
        kernel,
        grid=(b, kv, nk),
        in_specs=[
            pl.BlockSpec((1, 1, t * g, hd),
                         lambda b_, h_, ik: (b_, h_, 0, 0)),
            pl.BlockSpec((1, block_k, 1, hd),
                         lambda b_, h_, ik: (b_, ik, h_, 0)),
            pl.BlockSpec((1, block_k, 1, hd),
                         lambda b_, h_, ik: (b_, ik, h_, 0)),
            pl.BlockSpec((1, t), lambda b_, h_, ik: (b_, 0)),
            pl.BlockSpec((1, block_k), lambda b_, h_, ik: (b_, ik)),
        ],
        out_specs=pl.BlockSpec((1, 1, t * g, hd),
                               lambda b_, h_, ik: (b_, h_, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, kv, t * g, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((t * g, hd), jnp.float32),
            pltpu.VMEM((t * g, 1), jnp.float32),
            pltpu.VMEM((t * g, 1), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qg, k, v, qp, kp)
    out = jnp.moveaxis(out.reshape(b, kv, t, g, hd), 1, 2).reshape(
        b, t, h, hd)
    return out[:, 0] if no_time else out


# ---------------------------------------------------------------------------
# Paged (block-table) variant
# ---------------------------------------------------------------------------

def _paged_kernel(bt_ref, q_ref, k_ref, v_ref, qpos_ref, kpos_ref, o_ref,
                  acc_ref, m_ref, l_ref, *, scale: float,
                  window: Optional[int], num_k: int, q_tokens: int,
                  group: int):
    ib, ik = pl.program_id(0), pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0]                           # (T*G, hd)
    k = k_ref[0, :, 0, :]                     # (bs, hd) — gathered pool block
    v = v_ref[0, :, 0, :]
    qp = qpos_ref[0]                          # (T,) query-token positions
    kp = kpos_ref[0:1, :]                     # (1, bs) per-token positions
    blk = bt_ref[ib, ik]                      # physical block id; −1 = hole

    valid = (kp >= 0) & (kp <= qp[:, None]) & (blk >= 0)    # (T, bs)
    if window is not None:
        valid &= kp > (qp[:, None] - window)

    # skip unallocated table entries and fully-masked blocks entirely: a
    # slot's table only covers its live tokens, so grid steps past the
    # allocated prefix cost no MXU work
    @pl.when(jnp.any(valid))
    def _compute():
        bs = k.shape[0]
        s = jax.lax.dot_general(
            q.astype(jnp.float32), k.astype(jnp.float32),
            (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale       # (T*G, bs)
        mask = jnp.broadcast_to(valid[:, None, :],
                                (q_tokens, group, bs)).reshape(-1, bs)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        pv = jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * alpha + pv
        m_ref[...] = m_new

    @pl.when(ik == num_k - 1)
    def _flush():
        out = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = out.astype(o_ref.dtype)


def paged_decode_attention(q, k, v, q_pos, k_pos, block_tables, *,
                           window: Optional[int] = None,
                           scale: Optional[float] = None,
                           interpret: bool = False):
    """Paged cached attention: gather K/V through a block table per grid step.

    q: (B, T, H, hd) or (B, H, hd) (T = 1); k, v: (N, bs, KV, hd) global
    block pool (block 0 is the engines' trash block); q_pos: (B,) chunk
    start positions or (B, T) per-token positions; k_pos: (N, bs) per-token
    positions (−1 = never written); block_tables: (B, M) int32 physical
    block ids per slot (−1 = unallocated). Returns output shaped like q.

    Same streaming-softmax carry, GQA group folding and masked-block skip as
    the ring kernel; the only difference is that the KV tile for grid step
    ``ik`` is DMA'd from pool block ``block_tables[b, ik]`` (scalar-prefetch
    index map) instead of a contiguous slice of a per-slot ring.
    """
    no_time = q.ndim == 3
    if no_time:
        q = q[:, None]
    b, t, h, hd = q.shape
    n, bs, kv = k.shape[0], k.shape[1], k.shape[2]
    assert h % kv == 0
    g = h // kv
    m = block_tables.shape[1]
    scale = scale if scale is not None else hd ** -0.5

    qg = jnp.moveaxis(q.reshape(b, t, kv, g, hd), 2, 1).reshape(
        b, kv, t * g, hd)
    qp = _chunk_positions(q_pos, b, t)
    kp = jnp.asarray(k_pos, jnp.int32)
    bt = jnp.asarray(block_tables, jnp.int32)

    kernel = functools.partial(_paged_kernel, scale=scale, window=window,
                               num_k=m, q_tokens=t, group=g)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, kv, m),
        in_specs=[
            pl.BlockSpec((1, 1, t * g, hd),
                         lambda b_, h_, ik, bt_: (b_, h_, 0, 0)),
            pl.BlockSpec((1, bs, 1, hd),
                         lambda b_, h_, ik, bt_: (
                             jnp.maximum(bt_[b_, ik], 0), 0, h_, 0)),
            pl.BlockSpec((1, bs, 1, hd),
                         lambda b_, h_, ik, bt_: (
                             jnp.maximum(bt_[b_, ik], 0), 0, h_, 0)),
            pl.BlockSpec((1, t), lambda b_, h_, ik, bt_: (b_, 0)),
            pl.BlockSpec((1, bs), lambda b_, h_, ik, bt_: (
                jnp.maximum(bt_[b_, ik], 0), 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, t * g, hd),
                               lambda b_, h_, ik, bt_: (b_, h_, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((t * g, hd), jnp.float32),
            pltpu.VMEM((t * g, 1), jnp.float32),
            pltpu.VMEM((t * g, 1), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, kv, t * g, hd), q.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(bt, qg, k, v, qp, kp)
    out = jnp.moveaxis(out.reshape(b, kv, t, g, hd), 1, 2).reshape(
        b, t, h, hd)
    return out[:, 0] if no_time else out
