"""Decode-attention Pallas kernels — single-token queries against a ring
KV cache or a paged (block-table) KV pool (causal, sliding-window, GQA).

This is the memory-bound half of serving: every decode step streams the
whole cache through the core once per layer, so the kernel's job is to keep
that stream at HBM bandwidth while the MXU work stays tiny. TPU mapping:
grid ``(B, KV, num_kv_blocks)``; the last axis is the sequential
("arbitrary") reduction over cache blocks with the streaming-softmax carry
(acc, m, l) held in VMEM scratch. GQA is handled by folding the query group
into the head tile: each (batch, kv-head) program attends with a
``(group, head_dim)`` q tile against shared ``(block_k, head_dim)`` k/v
tiles, so KV blocks are fetched once per group rather than once per q head.

Positions are data, not geometry: the cache is a ring (slot = pos % width),
so causal/window masking reads the per-slot ``k_pos`` array (−1 = empty
slot) instead of assuming contiguous layout. Blocks whose every slot is
masked (empty ring tail, outside the window) skip the MXU work entirely via
``pl.when`` — on a cold cache only the written prefix costs anything.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30

# renamed TPUCompilerParams -> CompilerParams across jax versions
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))


def _kernel(q_ref, k_ref, v_ref, qpos_ref, kpos_ref, o_ref,
            acc_ref, m_ref, l_ref, *, scale: float, window: Optional[int],
            num_k: int):
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0]                           # (G, hd)
    k = k_ref[0, :, 0, :]                     # (bk, hd)
    v = v_ref[0, :, 0, :]
    qp = qpos_ref[0, 0]                       # scalar: this request's position
    kp = kpos_ref[0:1, :]                     # (1, bk) ring-slot positions

    valid = (kp >= 0) & (kp <= qp)            # empty slots + causality
    if window is not None:
        valid &= kp > (qp - window)

    # data-dependent block skip: a ring cache is mostly empty early on, and
    # a sliding window masks all but ~window/block_k blocks
    @pl.when(jnp.any(valid))
    def _compute():
        s = jax.lax.dot_general(
            q.astype(jnp.float32), k.astype(jnp.float32),
            (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale       # (G, bk)
        s = jnp.where(valid, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        pv = jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * alpha + pv
        m_ref[...] = m_new

    @pl.when(ik == num_k - 1)
    def _flush():
        out = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = out.astype(o_ref.dtype)


def decode_attention(q, k, v, q_pos, k_pos, *, window: Optional[int] = None,
                     scale: Optional[float] = None, block_k: int = 128,
                     interpret: bool = False):
    """q: (B, 1, H, hd) or (B, H, hd); k, v: (B, W, KV, hd) ring cache;
    q_pos: (B,) int32 current positions; k_pos: (B, W) int32 cache-slot
    positions (−1 = empty). Returns attention output shaped like q.
    """
    squeeze = q.ndim == 4
    if squeeze:
        assert q.shape[1] == 1, "decode kernel takes a single query token"
        q = q[:, 0]
    b, h, hd = q.shape
    w, kv = k.shape[1], k.shape[2]
    assert h % kv == 0
    g = h // kv
    scale = scale if scale is not None else hd ** -0.5
    block_k = min(block_k, w)

    pad = (-w) % block_k
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, ((0, 0), (0, pad)), constant_values=-1)
    nk = k.shape[1] // block_k

    qg = q.reshape(b, kv, g, hd)
    qp = jnp.asarray(q_pos, jnp.int32).reshape(b, 1)
    kp = jnp.asarray(k_pos, jnp.int32)

    kernel = functools.partial(_kernel, scale=scale, window=window, num_k=nk)
    out = pl.pallas_call(
        kernel,
        grid=(b, kv, nk),
        in_specs=[
            pl.BlockSpec((1, 1, g, hd), lambda b_, h_, ik: (b_, h_, 0, 0)),
            pl.BlockSpec((1, block_k, 1, hd),
                         lambda b_, h_, ik: (b_, ik, h_, 0)),
            pl.BlockSpec((1, block_k, 1, hd),
                         lambda b_, h_, ik: (b_, ik, h_, 0)),
            pl.BlockSpec((1, 1), lambda b_, h_, ik: (b_, 0)),
            pl.BlockSpec((1, block_k), lambda b_, h_, ik: (b_, ik)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, hd),
                               lambda b_, h_, ik: (b_, h_, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, kv, g, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g, hd), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qg, k, v, qp, kp)
    out = out.reshape(b, h, hd)
    return out[:, None] if squeeze else out


# ---------------------------------------------------------------------------
# Paged (block-table) variant
# ---------------------------------------------------------------------------

def _paged_kernel(bt_ref, q_ref, k_ref, v_ref, qpos_ref, kpos_ref, o_ref,
                  acc_ref, m_ref, l_ref, *, scale: float,
                  window: Optional[int], num_k: int):
    ib, ik = pl.program_id(0), pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0]                           # (G, hd)
    k = k_ref[0, :, 0, :]                     # (bs, hd) — gathered pool block
    v = v_ref[0, :, 0, :]
    qp = qpos_ref[0, 0]                       # scalar: this request's position
    kp = kpos_ref[0:1, :]                     # (1, bs) per-token positions
    blk = bt_ref[ib, ik]                      # physical block id; −1 = hole

    valid = (kp >= 0) & (kp <= qp) & (blk >= 0)
    if window is not None:
        valid &= kp > (qp - window)

    # skip unallocated table entries and fully-masked blocks entirely: a
    # slot's table only covers its live tokens, so grid steps past the
    # allocated prefix cost no MXU work
    @pl.when(jnp.any(valid))
    def _compute():
        s = jax.lax.dot_general(
            q.astype(jnp.float32), k.astype(jnp.float32),
            (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale       # (G, bs)
        s = jnp.where(valid, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        pv = jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * alpha + pv
        m_ref[...] = m_new

    @pl.when(ik == num_k - 1)
    def _flush():
        out = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = out.astype(o_ref.dtype)


def paged_decode_attention(q, k, v, q_pos, k_pos, block_tables, *,
                           window: Optional[int] = None,
                           scale: Optional[float] = None,
                           interpret: bool = False):
    """Paged decode attention: gather K/V through a block table per grid step.

    q: (B, 1, H, hd) or (B, H, hd); k, v: (N, bs, KV, hd) global block pool
    (block 0 is the engines' trash block); k_pos: (N, bs) per-token positions
    (−1 = never written); block_tables: (B, M) int32 physical block ids per
    slot (−1 = unallocated). Returns attention output shaped like q.

    Same streaming-softmax carry, GQA group folding and masked-block skip as
    the ring kernel; the only difference is that the KV tile for grid step
    ``ik`` is DMA'd from pool block ``block_tables[b, ik]`` (scalar-prefetch
    index map) instead of a contiguous slice of a per-slot ring.
    """
    squeeze = q.ndim == 4
    if squeeze:
        assert q.shape[1] == 1, "decode kernel takes a single query token"
        q = q[:, 0]
    b, h, hd = q.shape
    n, bs, kv = k.shape[0], k.shape[1], k.shape[2]
    assert h % kv == 0
    g = h // kv
    m = block_tables.shape[1]
    scale = scale if scale is not None else hd ** -0.5

    qg = q.reshape(b, kv, g, hd)
    qp = jnp.asarray(q_pos, jnp.int32).reshape(b, 1)
    kp = jnp.asarray(k_pos, jnp.int32)
    bt = jnp.asarray(block_tables, jnp.int32)

    kernel = functools.partial(_paged_kernel, scale=scale, window=window,
                               num_k=m)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, kv, m),
        in_specs=[
            pl.BlockSpec((1, 1, g, hd), lambda b_, h_, ik, bt_: (b_, h_, 0, 0)),
            pl.BlockSpec((1, bs, 1, hd),
                         lambda b_, h_, ik, bt_: (
                             jnp.maximum(bt_[b_, ik], 0), 0, h_, 0)),
            pl.BlockSpec((1, bs, 1, hd),
                         lambda b_, h_, ik, bt_: (
                             jnp.maximum(bt_[b_, ik], 0), 0, h_, 0)),
            pl.BlockSpec((1, 1), lambda b_, h_, ik, bt_: (b_, 0)),
            pl.BlockSpec((1, bs), lambda b_, h_, ik, bt_: (
                jnp.maximum(bt_[b_, ik], 0), 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, hd),
                               lambda b_, h_, ik, bt_: (b_, h_, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, hd), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, kv, g, hd), q.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(bt, qg, k, v, qp, kp)
    out = out.reshape(b, h, hd)
    return out[:, None] if squeeze else out
