"""Jit'd dispatch wrappers over the Pallas kernels.

On TPU the Pallas kernels run natively; on CPU (this container) the default
is the jnp oracle (running full models through interpret mode would be
pathologically slow), while tests force ``use_kernel=True`` with
``interpret=True`` to exercise the kernel bodies.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax

from repro.kernels import ref as _ref
from repro.kernels.cascade_gate import cascade_gate as _gate_kernel
from repro.kernels.decode_attention import decode_attention as _da_kernel
from repro.kernels.decode_attention import (paged_decode_attention
                                            as _pda_kernel)
from repro.kernels.flash_attention import flash_attention as _fa_kernel
from repro.kernels.rglru_scan import rglru_scan as _rglru_kernel


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "window",
                                             "use_kernel", "interpret"))
def attention(q, k, v, *, causal: bool = True,
              window: Optional[int] = None,
              use_kernel: Optional[bool] = None,
              interpret: Optional[bool] = None):
    use = _on_tpu() if use_kernel is None else use_kernel
    if use:
        return _fa_kernel(q, k, v, causal=causal, window=window,
                          interpret=not _on_tpu() if interpret is None
                          else interpret)
    return _ref.flash_attention_ref(q, k, v, causal=causal, window=window)


@functools.partial(jax.jit, static_argnames=("window", "scale",
                                             "use_kernel", "interpret"))
def decode_attn(q, k, v, q_pos, k_pos, *, window: Optional[int] = None,
                scale: Optional[float] = None,
                use_kernel: Optional[bool] = None,
                interpret: Optional[bool] = None):
    use = _on_tpu() if use_kernel is None else use_kernel
    if use:
        return _da_kernel(q, k, v, q_pos, k_pos, window=window, scale=scale,
                          interpret=not _on_tpu() if interpret is None
                          else interpret)
    return _ref.decode_attention_ref(q, k, v, q_pos, k_pos, window=window,
                                     scale=scale)


@functools.partial(jax.jit, static_argnames=("window", "scale",
                                             "use_kernel", "interpret"))
def paged_decode_attn(q, k, v, q_pos, k_pos, block_tables, *,
                      window: Optional[int] = None,
                      scale: Optional[float] = None,
                      use_kernel: Optional[bool] = None,
                      interpret: Optional[bool] = None):
    """Paged-pool variant of ``decode_attn``: k/v/k_pos are the global block
    pool (N, bs, ...) and ``block_tables`` (B, M) maps each slot's logical
    blocks to physical pool blocks (−1 = unallocated)."""
    use = _on_tpu() if use_kernel is None else use_kernel
    if use:
        return _pda_kernel(q, k, v, q_pos, k_pos, block_tables,
                           window=window, scale=scale,
                           interpret=not _on_tpu() if interpret is None
                           else interpret)
    return _ref.paged_decode_attention_ref(q, k, v, q_pos, k_pos,
                                           block_tables, window=window,
                                           scale=scale)


@functools.partial(jax.jit, static_argnames=("use_kernel", "interpret"))
def rglru(a, b, h0, *, use_kernel: Optional[bool] = None,
          interpret: Optional[bool] = None):
    use = _on_tpu() if use_kernel is None else use_kernel
    if use:
        return _rglru_kernel(a, b, h0,
                             interpret=not _on_tpu() if interpret is None
                             else interpret)
    return _ref.rglru_scan_ref(a, b, h0)


@functools.partial(jax.jit, static_argnames=("hi", "lo", "use_kernel",
                                             "interpret"))
def gate(logits, *, hi: float = 0.8, lo: float = 0.1,
         use_kernel: Optional[bool] = None,
         interpret: Optional[bool] = None):
    use = _on_tpu() if use_kernel is None else use_kernel
    if use:
        return _gate_kernel(logits, hi=hi, lo=lo,
                            interpret=not _on_tpu() if interpret is None
                            else interpret)
    from repro.cascade.gate import GateThresholds
    import jax.numpy as jnp
    out = _ref.cascade_gate_ref(
        logits, GateThresholds(jnp.float32(hi), jnp.float32(lo)))
    return out["conf"], out["routes"], out["counts"]
