"""Pallas TPU kernels for the compute hot-spots of the workloads ACE hosts
and of the cascade itself (DESIGN.md §3).

  flash_attention  — blockwise causal/sliding-window attention (GQA)
  decode_attention — single-token decode attention over a ring KV cache,
                     plus the paged (block-table) variant used by the
                     serving engine's paged KV backend
  rglru_scan       — blocked RG-LRU linear-recurrence scan
  cascade_gate     — fused confidence-gate + route-count reduction

Each has a pure-jnp oracle in ``ref.py`` and a jit'd dispatch wrapper in
``ops.py``. On CPU (this container) kernels run in interpret mode; the
BlockSpecs describe the intended TPU VMEM tiling.
"""
