"""Blockwise (flash) attention Pallas kernel — causal, sliding-window, GQA.

TPU mapping: grid (B, H, num_q_blocks, num_k_blocks); the last axis is the
sequential ("arbitrary") reduction over KV blocks with the streaming-softmax
carry (acc, m, l) held in VMEM scratch. Per-step working set is
``(block_q x head_dim) + 2 x (block_k x head_dim)`` tiles — sized so that
q/k/v/o tiles plus the f32 accumulator fit VMEM (block 128/128 with hd<=256:
< 1 MiB). MXU work is the (block_q x hd) @ (hd x block_k) score matmul and
the (block_q x block_k) @ (block_k x hd) value matmul — both 128-aligned.

GQA folds the query-group into the head grid axis: the k/v index map selects
head ``h // group`` so KV tiles are reused across the group's q heads.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30

# renamed TPUCompilerParams -> CompilerParams across jax versions
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            scale: float, causal: bool, window: Optional[int],
            sq: int, sk: int, block_q: int, block_k: int, num_k: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, :, 0, :]                     # (bq, hd)
    k = k_ref[0, :, 0, :]                     # (bk, hd)
    v = v_ref[0, :, 0, :]

    qpos = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, 1), 0)
    qpos = qpos + (sk - sq)                   # right-aligned queries
    kpos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, (1, block_k), 1)

    run = True
    if causal:
        # whole block masked out when its first k position is past the last q
        run = (ik * block_k) <= (iq * block_q + block_q - 1 + (sk - sq))
    if window is not None:
        run = jnp.logical_and(
            run, (ik * block_k + block_k - 1)
            > (iq * block_q + (sk - sq) - window))

    @pl.when(run)
    def _compute():
        s = jax.lax.dot_general(
            q.astype(jnp.float32), k.astype(jnp.float32),
            (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # (bq, bk)
        valid = jnp.ones_like(s, dtype=jnp.bool_)
        valid &= kpos < sk                                # tail padding
        if causal:
            valid &= kpos <= qpos
        if window is not None:
            valid &= kpos > (qpos - window)
        s = jnp.where(valid, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        pv = jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * alpha + pv
        m_ref[...] = m_new

    @pl.when(ik == num_k - 1)
    def _flush():
        out = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, :, 0, :] = out.astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None,
                    scale: Optional[float] = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = False):
    """q: (B, Sq, H, hd); k, v: (B, Sk, KV, hd) -> (B, Sq, H, hd).

    Queries are right-aligned against keys (q position i attends to keys up
    to ``i + Sk - Sq``), matching decode/prefill semantics.
    """
    b, sq, h, hd = q.shape
    sk, kv = k.shape[1], k.shape[2]
    assert h % kv == 0
    g = h // kv
    scale = scale if scale is not None else hd ** -0.5
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)

    pad_q = (-sq) % block_q
    pad_k = (-sk) % block_k
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    nq = q.shape[1] // block_q
    nk = k.shape[1] // block_k

    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, window=window, sq=sq, sk=sk,
        block_q=block_q, block_k=block_k, num_k=nk)

    out = pl.pallas_call(
        kernel,
        grid=(b, h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, 1, hd),
                         lambda b_, h_, iq, ik: (b_, iq, h_, 0)),
            pl.BlockSpec((1, block_k, 1, hd),
                         lambda b_, h_, iq, ik, g_=g: (b_, ik, h_ // g_, 0)),
            pl.BlockSpec((1, block_k, 1, hd),
                         lambda b_, h_, iq, ik, g_=g: (b_, ik, h_ // g_, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, 1, hd),
                               lambda b_, h_, iq, ik: (b_, iq, h_, 0)),
        out_shape=jax.ShapeDtypeStruct((b, q.shape[1], h, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, hd), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q, k, v)
    return out[:, :sq]
