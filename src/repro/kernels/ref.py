"""Pure-jnp oracles for every kernel (the correctness contracts)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.cascade.gate import GateThresholds
from repro.models.attention import blockwise_attention


def flash_attention_ref(q, k, v, *, causal: bool = True,
                        window: Optional[int] = None,
                        scale: Optional[float] = None) -> jnp.ndarray:
    """q: (B, Sq, H, hd); k, v: (B, Sk, KV, hd). Dense reference."""
    b, sq, h, hd = q.shape
    sk, kv = k.shape[1], k.shape[2]
    g = h // kv
    scale = scale if scale is not None else hd ** -0.5
    qg = q.reshape(b, sq, kv, g, hd).astype(jnp.float32)
    s = jnp.einsum("bqkgd,bckd->bkgqc", qg,
                   k.astype(jnp.float32)) * scale
    qpos = jnp.arange(sq)[:, None] + (sk - sq)   # right-aligned queries
    kpos = jnp.arange(sk)[None, :]
    valid = jnp.ones((sq, sk), bool)
    if causal:
        valid &= kpos <= qpos
    if window is not None:
        valid &= kpos > (qpos - window)
    s = jnp.where(valid[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqc,bckd->bqkgd", p, v.astype(jnp.float32))
    return o.reshape(b, sq, h, hd).astype(q.dtype)


def flash_attention_streaming_ref(q, k, v, *, causal: bool = True,
                                  window: Optional[int] = None,
                                  kv_chunk: int = 128) -> jnp.ndarray:
    """The streaming-softmax formulation shared with the model code."""
    b, sq = q.shape[0], q.shape[1]
    sk = k.shape[1]
    qpos = jnp.broadcast_to(jnp.arange(sq) + (sk - sq), (b, sq))
    kpos = jnp.broadcast_to(jnp.arange(sk), (b, sk))
    if not causal:
        raise NotImplementedError("oracle is causal-only")
    return blockwise_attention(q, k, v, qpos, kpos, window=window,
                               scale=q.shape[-1] ** -0.5, kv_chunk=kv_chunk)


def query_positions(q_pos, t: int) -> jnp.ndarray:
    """Normalize query positions to (B, T): a (B,) vector is treated as the
    *start* position of a T-token chunk (per-token positions start + i); a
    (B, T) array is taken as-is."""
    qp = jnp.asarray(q_pos, jnp.int32)
    if qp.ndim == 1:
        qp = qp[:, None] + jnp.arange(t, dtype=jnp.int32)[None, :]
    return qp


def decode_attention_ref(q, k, v, q_pos, k_pos, *,
                         window: Optional[int] = None,
                         scale: Optional[float] = None) -> jnp.ndarray:
    """Dense cached attention over a ring KV cache: one decode token or a
    T-token prompt chunk per slot.

    q: (B, T, H, hd) or (B, H, hd) (T = 1); k, v: (B, W, KV, hd);
    q_pos: (B,) chunk start positions or (B, T) per-token positions;
    k_pos: (B, W) with −1 marking empty cache slots. The chunk's own K/V
    are expected to already be appended to the cache (append-then-attend),
    so intra-chunk causality falls out of position masking.
    """
    no_time = q.ndim == 3
    if no_time:
        q = q[:, None]
    b, t, h, hd = q.shape
    kv = k.shape[2]
    g = h // kv
    scale = scale if scale is not None else hd ** -0.5
    qp = query_positions(q_pos, t)
    qg = q.reshape(b, t, kv, g, hd).astype(jnp.float32)
    s = jnp.einsum("btkgd,bckd->btkgc", qg, k.astype(jnp.float32)) * scale
    valid = (k_pos[:, None, :] >= 0) & (k_pos[:, None, :] <= qp[:, :, None])
    if window is not None:
        valid &= k_pos[:, None, :] > (qp[:, :, None] - window)
    s = jnp.where(valid[:, :, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("btkgc,bckd->btkgd", p, v.astype(jnp.float32))
    o = o.reshape(b, t, h, hd).astype(q.dtype)
    return o[:, 0] if no_time else o


def gather_paged_kv(pool, pos, block_tables):
    """Flatten a paged pool into per-slot contiguous context.

    pool: (N, bs, ...) block pool; pos: (N, bs) per-token positions;
    block_tables: (B, M) physical ids (−1 = unallocated). Returns
    (ctx (B, M*bs, ...), ctx_pos (B, M*bs)) where unallocated table entries
    carry pos −1 (fully masked), so downstream attention over the gathered
    context is exact regardless of holes in the table.
    """
    bt = jnp.asarray(block_tables, jnp.int32)
    safe = jnp.maximum(bt, 0)
    b, m = bt.shape
    bs = pool.shape[1]
    ctx = pool[safe]                                  # (B, M, bs, ...)
    ctx = ctx.reshape((b, m * bs) + pool.shape[2:])
    ctx_pos = jnp.where(bt[:, :, None] >= 0, pos[safe], -1)
    return ctx, ctx_pos.reshape(b, m * bs)


def paged_decode_attention_ref(q, k, v, q_pos, k_pos, block_tables, *,
                               window: Optional[int] = None,
                               scale: Optional[float] = None) -> jnp.ndarray:
    """Dense cached attention over a paged KV pool (decode or prompt chunk).

    q: (B, T, H, hd) or (B, H, hd) (T = 1); k, v: (N, bs, KV, hd) global
    block pool; q_pos: (B,) chunk starts or (B, T) per-token positions;
    k_pos: (N, bs) with −1 marking never-written tokens; block_tables:
    (B, M) with −1 marking unallocated entries. The contract: gathering
    each slot's blocks into a contiguous cache and running the ring oracle
    must equal the paged Pallas kernel.
    """
    kc, pc = gather_paged_kv(k, k_pos, block_tables)
    vc, _ = gather_paged_kv(v, k_pos, block_tables)
    out = decode_attention_ref(q, kc, vc, q_pos, pc, window=window,
                               scale=scale)
    # a freed slot's table is all −1: nothing is valid, and the kernel's
    # streaming accumulator stays zero — pin the oracle to the same value
    # instead of the dense softmax's uniform-over-garbage row
    t = 1 if q.ndim == 3 else q.shape[1]
    qp = query_positions(q_pos, t)                       # (B, T)
    valid = (pc[:, None, :] >= 0) & (pc[:, None, :] <= qp[:, :, None])
    if window is not None:
        valid &= pc[:, None, :] > (qp[:, :, None] - window)
    any_valid = jnp.any(valid, axis=2)                   # (B, T)
    if q.ndim == 3:
        any_valid = any_valid[:, 0]
    shape = any_valid.shape + (1,) * (out.ndim - any_valid.ndim)
    return jnp.where(any_valid.reshape(shape), out, 0).astype(out.dtype)


def rglru_scan_ref(a, b, h0) -> tuple:
    """h_t = a_t * h_{t-1} + b_t. a, b: (B, S, W) f32; h0: (B, W).
    Returns (h (B,S,W), h_last (B,W))."""
    def step(h, ab):
        a_t, b_t = ab
        h = a_t * h + b_t
        return h, h

    (h_last, hs) = jax.lax.scan(
        step, h0, (jnp.moveaxis(a, 1, 0), jnp.moveaxis(b, 1, 0)))
    return jnp.moveaxis(hs, 0, 1), h_last


def cascade_gate_ref(logits, th: GateThresholds) -> dict:
    """logits: (T, V) -> conf (T,), routes (T,), counts (3,)."""
    logits = logits.astype(jnp.float32)
    m = jnp.max(logits, axis=-1)
    lse = m + jnp.log(jnp.sum(jnp.exp(logits - m[:, None]), axis=-1))
    conf = jnp.exp(m - lse)
    routes = jnp.where(conf >= th.hi, 0,
                       jnp.where(conf < th.lo, 1, 2)).astype(jnp.int32)
    counts = jnp.stack([jnp.sum(routes == i) for i in range(3)]).astype(
        jnp.int32)
    return {"conf": conf, "routes": routes, "counts": counts}
