"""Blocked RG-LRU linear-recurrence scan Pallas kernel.

Computes h_t = a_t * h_{t-1} + b_t over the time axis.

TPU mapping: grid (B, num_width_blocks, num_time_blocks); the time axis is
sequential with the running state h carried in VMEM scratch. Within a
(block_t x block_w) tile the recurrence is solved with a Hillis–Steele
doubling scan (log2(block_t) shifted elementwise passes) — numerically safe
(only products of a in (0,1], no divisions), VPU-friendly, and keeps the
tile resident in VMEM. block_w is lane-aligned (multiples of 128) so each
pass is a full-width vector op.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# renamed TPUCompilerParams -> CompilerParams across jax versions
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))


def _kernel(a_ref, b_ref, h0_ref, h_ref, hlast_ref, carry_ref, *,
            block_t: int, num_t: int):
    it = pl.program_id(2)

    @pl.when(it == 0)
    def _init():
        carry_ref[...] = h0_ref[0]            # (bw,)

    a = a_ref[0].astype(jnp.float32)          # (bt, bw)
    b = b_ref[0].astype(jnp.float32)

    # Hillis–Steele inclusive scan of the affine maps (a, b):
    # compose (a2,b2) o (a1,b1) = (a1*a2, a2*b1 + b2)
    d = 1
    while d < block_t:
        # out-of-range neighbours are the identity map (A=1, B=0)
        a_sh = jnp.pad(a, ((d, 0), (0, 0)), constant_values=1.0)[:block_t]
        b_sh = jnp.pad(b, ((d, 0), (0, 0)))[:block_t]
        b = b + a * b_sh
        a = a * a_sh
        d *= 2
    # fold in the carried state: h_t = A_t * h_carry + B_t
    h = a * carry_ref[...][None, :] + b
    h_ref[0] = h.astype(h_ref.dtype)
    carry_ref[...] = h[block_t - 1]

    @pl.when(it == num_t - 1)
    def _flush():
        hlast_ref[0] = carry_ref[...].astype(hlast_ref.dtype)


def rglru_scan(a, b, h0, *, block_t: int = 128, block_w: int = 128,
               interpret: bool = False):
    """a, b: (B, S, W) f32; h0: (B, W) f32 -> (h (B,S,W), h_last (B,W))."""
    bsz, s, w = a.shape
    block_t = min(block_t, s)
    block_w = min(block_w, w)
    pad_t = (-s) % block_t
    pad_w = (-w) % block_w
    if pad_t or pad_w:
        # pad with identity steps (a=1, b=0) so the carry passes through
        a = jnp.pad(a, ((0, 0), (0, pad_t), (0, pad_w)),
                    constant_values=1.0)
        b = jnp.pad(b, ((0, 0), (0, pad_t), (0, pad_w)))
    if pad_w:
        h0 = jnp.pad(h0, ((0, 0), (0, pad_w)))
    nt = a.shape[1] // block_t
    nw = a.shape[2] // block_w

    kernel = functools.partial(_kernel, block_t=block_t, num_t=nt)
    h, hlast = pl.pallas_call(
        kernel,
        grid=(bsz, nw, nt),
        in_specs=[
            pl.BlockSpec((1, block_t, block_w),
                         lambda b_, iw, it: (b_, it, iw)),
            pl.BlockSpec((1, block_t, block_w),
                         lambda b_, iw, it: (b_, it, iw)),
            pl.BlockSpec((1, block_w), lambda b_, iw, it: (b_, iw)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_t, block_w),
                         lambda b_, iw, it: (b_, it, iw)),
            pl.BlockSpec((1, block_w), lambda b_, iw, it: (b_, iw)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, a.shape[1], a.shape[2]), jnp.float32),
            jax.ShapeDtypeStruct((bsz, a.shape[2]), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((block_w,), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(a, b, h0)
    return h[:, :s, :w], hlast[:, :w]
