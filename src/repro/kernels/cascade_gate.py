"""Fused confidence-gate Pallas kernel (the paper's per-item gate at
LM-token scale).

Given a (T, V) logits block, computes in one pass over VMEM tiles:
max-softmax confidence (via streaming max/logsumexp over vocab tiles),
the BP route code (accept/drop/escalate), and per-block route counts —
avoiding the full softmax materialization the naive path pays at
vocab 100k+ x 500k tokens.

TPU mapping: grid (num_token_blocks, num_vocab_blocks); vocab is the
sequential axis with (m, lse) carried in VMEM scratch; the route decision
and counts are emitted on the last vocab step.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# renamed TPUCompilerParams -> CompilerParams across jax versions
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))

NEG_INF = -1e30


def _kernel(logits_ref, conf_ref, routes_ref, counts_ref, m_ref, s_ref, *,
            hi: float, lo: float, num_v: int, vocab: int, block_v: int,
            tokens: int, block_t: int):
    it = pl.program_id(0)
    iv = pl.program_id(1)

    @pl.when(iv == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        s_ref[...] = jnp.zeros_like(s_ref)

    x = logits_ref[...].astype(jnp.float32)      # (bt, bv)
    vpos = iv * block_v + jax.lax.broadcasted_iota(
        jnp.int32, x.shape, 1)
    x = jnp.where(vpos < vocab, x, NEG_INF)      # vocab-padding mask
    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(x, axis=1, keepdims=True))
    s_ref[...] = (s_ref[...] * jnp.exp(m_prev - m_new)
                  + jnp.sum(jnp.exp(x - m_new), axis=1, keepdims=True))
    m_ref[...] = m_new

    @pl.when(iv == num_v - 1)
    def _flush():
        # conf = exp(m - lse) = 1 / sum(exp(x - m))
        conf = 1.0 / jnp.maximum(s_ref[...], 1e-30)  # (bt, 1)
        conf_ref[...] = conf
        routes = jnp.where(conf >= hi, 0,
                           jnp.where(conf < lo, 1, 2)).astype(jnp.int32)
        routes_ref[...] = routes
        # count only real (non-padded) token rows
        tpos = it * block_t + jax.lax.broadcasted_iota(
            jnp.int32, routes.shape, 0)
        live = tpos < tokens
        counts_ref[0, 0] = jnp.sum(((routes == 0) & live).astype(jnp.int32))
        counts_ref[0, 1] = jnp.sum(((routes == 1) & live).astype(jnp.int32))
        counts_ref[0, 2] = jnp.sum(((routes == 2) & live).astype(jnp.int32))


def cascade_gate(logits, *, hi: float = 0.8, lo: float = 0.1,
                 block_t: int = 256, block_v: int = 2048,
                 interpret: bool = False):
    """logits: (T, V) -> (conf (T,), routes (T,) int32, counts (3,) int32)."""
    t, v = logits.shape
    block_t = min(block_t, t)
    block_v = min(block_v, v)
    pad_t = (-t) % block_t
    pad_v = (-v) % block_v
    if pad_t or pad_v:
        logits = jnp.pad(logits, ((0, pad_t), (0, pad_v)),
                         constant_values=NEG_INF)
    nt = logits.shape[0] // block_t
    nv = logits.shape[1] // block_v

    kernel = functools.partial(_kernel, hi=hi, lo=lo, num_v=nv, vocab=v,
                               block_v=block_v, tokens=t, block_t=block_t)
    conf, routes, counts = pl.pallas_call(
        kernel,
        grid=(nt, nv),
        in_specs=[pl.BlockSpec((block_t, block_v),
                               lambda it, iv: (it, iv))],
        out_specs=[
            pl.BlockSpec((block_t, 1), lambda it, iv: (it, 0)),
            pl.BlockSpec((block_t, 1), lambda it, iv: (it, 0)),
            pl.BlockSpec((1, 3), lambda it, iv: (it, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((logits.shape[0], 1), jnp.float32),
            jax.ShapeDtypeStruct((logits.shape[0], 1), jnp.int32),
            jax.ShapeDtypeStruct((nt, 3), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_t, 1), jnp.float32),
            pltpu.VMEM((block_t, 1), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(logits)
    return conf[:t, 0], routes[:t, 0], jnp.sum(counts, axis=0)
