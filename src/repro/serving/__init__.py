from repro.serving.engine import (DrainBatchEngine, Request, ServingEngine,
                                  enable_compile_cache, load_snapshot,
                                  save_snapshot, validate_prompt)
from repro.serving.cascade_engine import (CascadeEngine, CascadeServingEngine,
                                          CircuitBreaker)
from repro.serving.faults import FaultError, FaultPlan, SeamSpec
from repro.serving.gateway import (BACKPRESSURE_POLICIES, EngineWedgedError,
                                   RequestHandle, ServingGateway,
                                   recover_engine)
from repro.serving.journal import RequestJournal
from repro.serving.kv_cache import (KVCacheBackend, PagedCache, PagedLayout,
                                    RING, RingCache, RingLayout, make_backend)
from repro.serving.sampler import (accepted_prefix_length, request_keys,
                                   sample_logits, sample_logits_batch,
                                   sample_logits_keyed)
from repro.serving.scheduler import (ChunkTask, PrefillProgress, Scheduler,
                                     StepPlan, bucket_for, chunk_buckets,
                                     prompt_buckets, request_rank,
                                     slots_for_hbm)
from repro.serving.sharding import (assert_cache_placement, cache_shardings,
                                    place_params, serving_rules)

__all__ = ["ServingEngine", "DrainBatchEngine", "Request", "CascadeEngine",
           "CascadeServingEngine", "CircuitBreaker",
           "FaultPlan", "FaultError", "SeamSpec",
           "ServingGateway", "RequestHandle", "BACKPRESSURE_POLICIES",
           "EngineWedgedError", "recover_engine", "RequestJournal",
           "save_snapshot", "load_snapshot",
           "sample_logits", "sample_logits_batch",
           "sample_logits_keyed", "request_keys", "accepted_prefix_length",
           "prompt_buckets", "bucket_for", "chunk_buckets",
           "validate_prompt", "Scheduler", "StepPlan", "ChunkTask",
           "PrefillProgress", "request_rank",
           "KVCacheBackend", "RingCache", "PagedCache", "RingLayout",
           "PagedLayout", "RING", "make_backend",
           "enable_compile_cache", "slots_for_hbm", "serving_rules",
           "place_params", "cache_shardings", "assert_cache_placement"]
