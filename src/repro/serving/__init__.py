from repro.serving.engine import (DrainBatchEngine, Request, ServingEngine,
                                  bucket_for, prompt_buckets, validate_prompt)
from repro.serving.cascade_engine import CascadeEngine, CascadeServingEngine
from repro.serving.kv_cache import (KVCacheBackend, PagedCache, PagedLayout,
                                    RING, RingCache, RingLayout, make_backend)
from repro.serving.sampler import sample_logits, sample_logits_batch

__all__ = ["ServingEngine", "DrainBatchEngine", "Request", "CascadeEngine",
           "CascadeServingEngine", "sample_logits", "sample_logits_batch",
           "prompt_buckets", "bucket_for", "validate_prompt",
           "KVCacheBackend", "RingCache", "PagedCache", "RingLayout",
           "PagedLayout", "RING", "make_backend"]
