from repro.serving.engine import (DrainBatchEngine, Request, ServingEngine,
                                  bucket_for, prompt_buckets)
from repro.serving.cascade_engine import CascadeEngine, CascadeServingEngine
from repro.serving.sampler import sample_logits, sample_logits_batch

__all__ = ["ServingEngine", "DrainBatchEngine", "Request", "CascadeEngine",
           "CascadeServingEngine", "sample_logits", "sample_logits_batch",
           "prompt_buckets", "bucket_for"]
