from repro.serving.engine import ServingEngine
from repro.serving.cascade_engine import CascadeEngine
from repro.serving.sampler import sample_logits

__all__ = ["ServingEngine", "CascadeEngine", "sample_logits"]
