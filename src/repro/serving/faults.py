"""Deterministic, seeded fault injection for the serving stack.

ACE's claim of user-transparent edge-cloud service is only as strong as
the serving loop's behavior when something breaks: a failed KV swap, a
poisoned device dispatch, a flaky WAN hop, an edge engine that stops
answering, a client that hangs up mid-generation. This module provides
the *injection* half of that story — a ``FaultPlan`` that trips named
seams on a reproducible schedule — so the recovery paths in
``ServingEngine`` / ``CascadeServingEngine`` / ``core.network`` can be
exercised deterministically in tests and benchmarks (see
``tests/test_faults.py`` and ``benchmarks/bench_serving.py``'s
``chaos_recovery`` section).

Named seams (the consumer documents which it consults):

====================  =====================================================
seam                  trips
====================  =====================================================
``step``              the single-step decode dispatch (``_step_impl``)
``scan``              the multi-step decode dispatch (``_scan_impl``)
``draft``             the speculative draft+verify dispatch
                      (``_spec_impl``) — the engine serves the round
                      through the plain decode path instead (token
                      streams are unchanged; throughput degrades)
``swap_out``          ``PagedCache.swap_out`` during preemption/rollback
``swap_in``           ``PagedCache.swap_in`` during a swap-path resume
``pool``              transient block-pool exhaustion at admission
``hang``              a *non-raising* stall at the decode dispatch: the
                      consulting site sleeps ``hang_s`` seconds instead
                      of raising, so no exception-based recovery path
                      ever sees it — only the gateway's wall-clock
                      watchdog can (see ``ServingGateway``)
``cancel``            cancellation of a random in-flight request
``edge``              edge-engine outage at the cascade gate
``wan_spike``         a latency spike on a ``core.network.Link`` transfer
``wan_outage``        a dead window on a ``core.network.Link``
====================  =====================================================

Determinism: each seam owns an independent ``numpy`` generator seeded
from ``(seed, crc32(seam))``, and faults fire by *opportunity index* —
the Nth consultation of a seam always resolves the same way for a given
plan, regardless of what any other seam did. A schedule can be given
explicitly (``at=(2, 5)`` — fire on those opportunity indices) or
probabilistically (``prob=0.05``), optionally bounded (``max_fires``) so
chaos runs provably terminate. Both forms can mix.

Injected failures surface as ``FaultError`` (a ``RuntimeError`` carrying
the seam name); consumers that *check* rather than *raise* use
``fire()`` directly (e.g. the pool seam makes admission answer "no
blocks" instead of raising).
"""
from __future__ import annotations

import dataclasses
import zlib
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np


class FaultError(RuntimeError):
    """An injected failure, carrying the seam it came from."""

    def __init__(self, seam: str, detail: str = ""):
        self.seam = seam
        super().__init__(f"injected fault at seam {seam!r}"
                         + (f": {detail}" if detail else ""))


@dataclasses.dataclass(frozen=True)
class SeamSpec:
    """Schedule for one seam: explicit opportunity indices (``at``), a
    per-opportunity probability (``prob``), or both; ``max_fires`` caps
    total fires (None = unbounded — prefer a bound in drain loops so
    termination doesn't rest on probability alone)."""
    prob: float = 0.0
    at: Tuple[int, ...] = ()
    max_fires: Optional[int] = None

    def __post_init__(self):
        if not (0.0 <= self.prob <= 1.0):
            raise ValueError(f"prob must be in [0, 1] (got {self.prob})")


SpecLike = Union[SeamSpec, float, dict, Sequence[int]]


def _coerce(seam: str, spec: SpecLike) -> SeamSpec:
    if isinstance(spec, SeamSpec):
        return spec
    if isinstance(spec, (int, float)) and not isinstance(spec, bool):
        return SeamSpec(prob=float(spec))
    if isinstance(spec, dict):
        d = dict(spec)
        if "at" in d:
            d["at"] = tuple(d["at"])
        return SeamSpec(**d)
    if isinstance(spec, (list, tuple)):
        return SeamSpec(at=tuple(int(i) for i in spec))
    raise TypeError(f"seam {seam!r}: cannot build a SeamSpec from "
                    f"{spec!r} (want SeamSpec, float prob, index list, "
                    f"or kwargs dict)")


class FaultPlan:
    """A seeded, per-seam fault schedule.

    >>> plan = FaultPlan(seed=7, step={"prob": 0.2, "max_fires": 3},
    ...                  swap_in=[1])        # fire on the 2nd swap_in
    >>> plan.fire("step")                   # consult one opportunity
    False

    The same ``(seed, specs)`` always yields the same schedule; replaying
    a run with the same plan injects the same faults at the same
    opportunities, which is what makes the chaos tests' token-exactness
    assertions meaningful.
    """

    def __init__(self, seed: int = 0, hang_s: float = 0.25,
                 **seams: SpecLike):
        self.seed = seed
        # stall duration for the non-raising ``hang`` seam: how long the
        # consulting dispatch site sleeps when it fires. Long enough to
        # trip a watchdog deadline, short enough that chaos runs finish.
        self.hang_s = float(hang_s)
        self._specs: Dict[str, SeamSpec] = {
            name: _coerce(name, spec) for name, spec in seams.items()}
        self._rng: Dict[str, np.random.Generator] = {}
        self._opportunities: Dict[str, int] = {}
        self._fired: Dict[str, int] = {}
        # (seam, opportunity_index) in firing order — the audit trail the
        # bench's chaos report and the tests' determinism checks read
        self.log: List[Tuple[str, int]] = []

    def _seam_rng(self, seam: str) -> np.random.Generator:
        if seam not in self._rng:
            self._rng[seam] = np.random.default_rng(
                [self.seed, zlib.crc32(seam.encode())])
        return self._rng[seam]

    # -- consultation ---------------------------------------------------------
    def fire(self, seam: str) -> bool:
        """Consume one opportunity at ``seam``; True = inject a fault."""
        idx = self._opportunities.get(seam, 0)
        self._opportunities[seam] = idx + 1
        spec = self._specs.get(seam)
        if spec is None:
            return False
        # always draw when a probability is set, so the schedule at
        # opportunity N never depends on max_fires having been hit earlier
        drew = (spec.prob > 0.0
                and float(self._seam_rng(seam).random()) < spec.prob)
        hit = idx in spec.at or drew
        if not hit:
            return False
        if spec.max_fires is not None \
                and self._fired.get(seam, 0) >= spec.max_fires:
            return False
        self._fired[seam] = self._fired.get(seam, 0) + 1
        self.log.append((seam, idx))
        return True

    def check(self, seam: str, detail: str = "") -> None:
        """Raise ``FaultError`` when the seam fires (the raising seams)."""
        if self.fire(seam):
            raise FaultError(seam, detail)

    def pick(self, seam: str, items: Sequence):
        """Deterministic victim choice for a seam that just fired (e.g.
        which in-flight request the ``cancel`` seam kills)."""
        if not items:
            raise ValueError(f"pick({seam!r}): no candidates")
        i = int(self._seam_rng(seam + ".pick").integers(len(items)))
        return items[i]

    # -- accounting -----------------------------------------------------------
    def fired(self, seam: Optional[str] = None):
        """Fire count for one seam, or the per-seam dict (copy)."""
        if seam is not None:
            return self._fired.get(seam, 0)
        return dict(self._fired)

    def opportunities(self, seam: str) -> int:
        return self._opportunities.get(seam, 0)

    def total_fired(self) -> int:
        return sum(self._fired.values())
