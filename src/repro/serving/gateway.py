"""Async serving gateway: the service layer over the serving engine.

``ServingGateway`` owns a ``ServingEngine`` (or ``CascadeServingEngine``)
and runs its ``step()`` loop as a single asyncio driver task, exposing
the transport the engine never had:

- ``await gateway.submit(prompt, ...) -> RequestHandle``
- ``async for token in handle.stream()`` — tokens surface as each
  step's host sync lands (the engine's per-round token tap)
- ``await handle.result()`` — the terminal ``Request`` in any status
- ``await gateway.cancel(rid)`` — cancellation in every phase, gateway
  queue included; an abandoned stream iterator cancels implicitly
- ``await gateway.drain()`` — graceful shutdown that quiesces streams
  and leaves the paged pool's invariants intact

Threading model: the asyncio loop thread owns every engine mutation
(make_request / enqueue / cancel / take_done); the jitted ``step()``
itself runs in the default executor so token streams, submissions and
cancels stay live while the device works. The engine's ``on_tokens``
tap fires on the executor thread and only appends to a plain list; the
driver dispatches it to handles after the step returns, so handles and
events are touched by the loop thread alone.

Backpressure: the gateway's bounded inbox is the real queue — the
engine's own queue is kept shallow (``forward_depth``) so load shedding
still has something to shed. Three policies on a full inbox:

- ``block``            submitters wait for room (open-loop clients
                       become closed-loop under overload)
- ``reject``           newcomer refused immediately
                       (``gateway_overload``)
- ``shed``             the worst-ranked queued request is evicted iff
                       it ranks strictly worse than the newcomer
                       (class desc -> EDF -> FIFO, the scheduler's own
                       ordering); otherwise the newcomer is refused

Gateway-level refusals are stamped terminal by the gateway and never
reach the engine's counters; engine-level admission control (deadline
feasibility, PR 6) still runs at forward time with the gateway queue
priced in via ``ahead_extra``.

Durability (ISSUE 9): three optional hooks make the gateway crash-
restartable with token-exact survivors —

- a write-ahead ``RequestJournal``: every accepted submit is journaled
  *before* it is acknowledged, first-token and terminal transitions
  after; a journaled duplicate id is refused
- ``step_timeout_s``: a wall-clock watchdog on each jitted dispatch. A
  stall raises nothing (the ``hang`` fault seam sleeps), so the driver
  times the executor future itself: timeout → bounded grace wait → a
  late-completing step is rolled back through ``engine.note_hang()``
  (the PR 6 retry ladder); a still-stuck one raises
  ``EngineWedgedError`` so a supervisor can restart from snapshot
- ``snapshot_dir`` + ``snapshot_every``: periodic engine snapshots
  between steps, each followed by journal compaction (records covered
  by the snapshot are dropped)

``recover_engine`` is the restart half: restore the newest snapshot
into a cold engine, then replay the journal to re-queue acknowledged
submissions the snapshot missed.
"""

from __future__ import annotations

import asyncio
import time
from collections import deque
from typing import Dict, List, Optional, Tuple

import numpy as np

from .engine import load_snapshot, save_snapshot
from .journal import RequestJournal
from .scheduler import request_rank

_DONE = object()        # stream sentinel: the handle reached a terminal state

_POLICY_ALIASES = {
    "reject-overload": "reject",
    "shed-lowest-class": "shed",
}
BACKPRESSURE_POLICIES = ("block", "reject", "shed")


class EngineWedgedError(RuntimeError):
    """The watchdog's terminal verdict: a dispatch blew its wall-clock
    deadline *and* its grace window — the engine thread is presumed
    stuck, so in-process recovery (which needs that thread back) is off
    the table. The driver refuses every open handle and re-raises this;
    a supervisor restarts from snapshot + journal (``recover_engine``,
    ``launch/serve.py --supervise``)."""


class RequestHandle:
    """Client-side view of one submitted request: a token stream plus a
    terminal-result future. Created by ``ServingGateway.submit``; all
    mutation happens on the gateway's loop thread."""

    def __init__(self, gateway: "ServingGateway", request) -> None:
        self._gw = gateway
        self.request = request
        self._chunks: deque = deque()       # np arrays, then _DONE
        self._new = asyncio.Event()
        self._terminal = asyncio.Event()
        self._first_s: Optional[float] = None
        self._last_s: Optional[float] = None
        self.streamed = 0                   # tokens delivered to _chunks

    @property
    def request_id(self) -> int:
        return self.request.request_id

    @property
    def status(self) -> str:
        return self.request.status

    def _push(self, arr: np.ndarray) -> None:
        now = time.perf_counter()
        if self._first_s is None:
            self._first_s = now
        self._last_s = now
        self.streamed += int(arr.shape[0])
        self._chunks.append(arr)
        self._new.set()

    def _finish(self) -> None:
        self._chunks.append(_DONE)
        self._terminal.set()
        self._new.set()

    async def stream(self):
        """Async-iterate generated token ids as each engine step's host
        sync lands. Leaving the iterator before it is exhausted (client
        disconnect, ``break``, task cancellation) cancels the request so
        an abandoned stream stops burning decode budget. The stream ends
        at the terminal state whatever its status — a quarantined or
        cancelled request's stream simply stops after its partial
        output; inspect ``(await handle.result()).status``."""
        try:
            while True:
                if self._chunks:
                    arr = self._chunks.popleft()
                    if arr is _DONE:
                        return
                    for t in arr.tolist():
                        yield int(t)
                    continue
                self._new.clear()
                if self._chunks:
                    continue
                await self._new.wait()
        finally:
            if not self._terminal.is_set():
                # fire-and-forget: GeneratorExit forbids awaiting here
                asyncio.ensure_future(self._gw.cancel(self.request_id))

    async def result(self):
        """Wait for (and return) the terminal ``Request`` — any status:
        done / failed / rejected / cancelled."""
        await self._terminal.wait()
        return self.request


class ServingGateway:
    """Asyncio front end owning one engine and its driver loop. See the
    module docstring for the model; typical use::

        async with ServingGateway(engine, max_queue=64,
                                  policy="shed") as gw:
            h = await gw.submit(prompt, max_new_tokens=32)
            async for tok in h.stream():
                ...
            r = await h.result()
    """

    def __init__(self, engine, *, max_queue: int = 64,
                 policy: str = "block",
                 forward_depth: Optional[int] = None,
                 journal: Optional[RequestJournal] = None,
                 step_timeout_s: Optional[float] = None,
                 hang_grace: float = 1.0,
                 snapshot_dir: Optional[str] = None,
                 snapshot_every: int = 0) -> None:
        policy = _POLICY_ALIASES.get(policy, policy)
        if policy not in BACKPRESSURE_POLICIES:
            raise ValueError(
                f"policy must be one of {BACKPRESSURE_POLICIES} "
                f"(or aliases {tuple(_POLICY_ALIASES)}), got {policy!r}")
        if max_queue < 1:
            raise ValueError(f"max_queue must be >= 1 (got {max_queue})")
        if step_timeout_s is not None and step_timeout_s <= 0:
            raise ValueError(
                f"step_timeout_s must be positive (got {step_timeout_s})")
        self.engine = engine
        self.policy = policy
        self.max_queue = max_queue
        self.forward_depth = (
            forward_depth if forward_depth is not None
            else max(1, getattr(engine, "batch_slots", 1)))
        # durability knobs (all optional; see module docstring)
        self._journal = journal
        self.step_timeout_s = step_timeout_s
        self.hang_grace = hang_grace
        self.snapshot_dir = snapshot_dir
        self.snapshot_every = snapshot_every
        self._inbox: deque = deque()    # made Requests awaiting the engine
        self._handles: Dict[int, RequestHandle] = {}
        self._cancels: List[Tuple[int, asyncio.Future]] = []
        self._tap_buf: List[Tuple[int, np.ndarray]] = []
        self._wake: Optional[asyncio.Event] = None
        self._room: Optional[asyncio.Condition] = None
        self._draining = False
        self._task: Optional[asyncio.Task] = None
        # service counters (bench + tests read these)
        self.submitted = 0
        self.shed_count = 0
        self.reject_count = 0
        self.peak_queue = 0
        self.watchdog_timeouts = 0      # dispatches past step_timeout_s
        self.snapshots_taken = 0
        self.steps_driven = 0
        engine.on_tokens = self._tap

    # -- lifecycle ------------------------------------------------------------

    async def start(self) -> None:
        """Start the driver task (idempotent; ``submit`` calls this)."""
        if self._wake is None:
            self._wake = asyncio.Event()
            self._room = asyncio.Condition()
        if self._task is None and not self._draining:
            self._task = asyncio.get_running_loop().create_task(
                self._drive())

    async def drain(self) -> None:
        """Graceful shutdown: refuse new submits, wake blocked
        submitters (they are rejected ``gateway_draining``), serve
        everything already accepted to its terminal state, then stop the
        driver. The engine drains through its normal step loop, so
        slot/pool invariants (free list full, zero ledger gaps) hold
        afterwards."""
        self._draining = True
        if self._wake is None:
            return
        async with self._room:
            self._room.notify_all()
        self._wake.set()
        if self._task is not None:
            task, self._task = self._task, None
            await task

    async def __aenter__(self) -> "ServingGateway":
        await self.start()
        return self

    async def __aexit__(self, *exc) -> None:
        await self.drain()

    # -- client API -----------------------------------------------------------

    async def submit(self, prompt, max_new_tokens: int = 16,
                     temperature: float = 0.0, priority: int = 0,
                     deadline_s: Optional[float] = None) -> RequestHandle:
        """Submit one request and return its handle immediately (or, for
        policy ``block`` on a full queue, after room opens up). A
        refused request still gets a handle — its ``result()`` resolves
        with status ``rejected`` and a machine-readable reason — so
        open-loop drivers account every arrival uniformly. Request ids
        are allocated here, in submission order, which keeps sampled
        outputs replayable against a closed-loop engine run."""
        await self.start()
        r = self.engine.make_request(
            np.asarray(prompt, np.int32), max_new_tokens, temperature,
            priority=priority, deadline_s=deadline_s)
        h = RequestHandle(self, r)
        self._handles[r.request_id] = h
        self.submitted += 1
        if self._draining:
            self._refuse(h, "rejected",
                         "gateway_draining: drain() in progress")
            return h
        if self._task is not None and self._task.done():
            # the driver died (EngineWedgedError or a real bug): nothing
            # will ever drive this request, so fail it now instead of
            # handing back a handle that never resolves. Not journaled —
            # it was never acknowledged, so the supervisor's replay
            # rightly skips it (the client saw the failure)
            self._refuse(h, "failed",
                         "gateway_down: driver task terminated")
            return h
        if len(self._inbox) >= self.max_queue:
            if self.policy == "block":
                async with self._room:
                    await self._room.wait_for(
                        lambda: len(self._inbox) < self.max_queue
                        or self._draining)
                if self._draining:
                    self._refuse(h, "rejected",
                                 "gateway_draining: drain() in progress")
                    return h
            elif self.policy == "reject":
                self.reject_count += 1
                self._refuse(h, "rejected",
                             "gateway_overload: submit queue full")
                return h
            else:   # shed: evict strictly-worse-ranked queued work
                victim = max(self._inbox, key=request_rank)
                if request_rank(victim) > request_rank(r):
                    self._inbox.remove(victim)
                    self.shed_count += 1
                    self._refuse(
                        self._handles[victim.request_id], "rejected",
                        f"shed_overload: displaced by better-ranked "
                        f"request {r.request_id}", journal=True)
                else:
                    self.reject_count += 1
                    self._refuse(
                        h, "rejected",
                        "gateway_overload: queue full of "
                        "better-or-equal-ranked work")
                    return h
        if self._journal is not None and not self._journal.record_submit(r):
            # write-ahead: journaled before the ack, so a crash after this
            # point can never lose an acknowledged request. A duplicate id
            # (possible after a restart replays the id space) is refused —
            # serving it twice would corrupt the journal's id -> outcome map
            self._refuse(h, "rejected",
                         f"duplicate_rid: request id {r.request_id} is "
                         f"already journaled")
            return h
        self._inbox.append(r)
        self.peak_queue = max(self.peak_queue,
                              len(self._inbox) + self.engine.queue_depth())
        self._wake.set()
        return h

    async def cancel(self, request_id: int) -> bool:
        """Cancel wherever the request lives — gateway queue, engine
        queue, mid-prefill or mid-decode. Returns False when it is not
        in flight (already terminal, or unknown)."""
        h = self._handles.get(request_id)
        if h is None or h._terminal.is_set():
            return False
        for q in self._inbox:
            if q.request_id == request_id:
                self._inbox.remove(q)
                self._refuse(h, "cancelled", "cancelled: in gateway queue",
                             journal=True)
                async with self._room:
                    self._room.notify(1)
                return True
        fut = asyncio.get_running_loop().create_future()
        self._cancels.append((request_id, fut))
        self._wake.set()
        return await fut

    def queue_depth(self) -> int:
        """Total waiting line: gateway inbox + engine queue."""
        return len(self._inbox) + self.engine.queue_depth()

    def stats(self) -> Dict[str, object]:
        """Service-level counters, with the owned engine's fault/retry/
        breaker accounting and the durability counters merged in — one
        call answers both "how is the service doing" and "how hard is
        the engine fighting underneath it"."""
        s: Dict[str, object] = {
            "policy": self.policy,
            "submitted": self.submitted,
            "queue_depth": self.queue_depth(),
            "peak_queue": self.peak_queue,
            "shed": self.shed_count,
            "rejected_overload": self.reject_count,
            "watchdog_timeouts": self.watchdog_timeouts,
            "snapshots_taken": self.snapshots_taken,
        }
        if self._journal is not None:
            s["journal"] = self._journal.stats()
        eng = self.engine
        keys = ("retries_total", "fault_recoveries", "quarantined",
                "preemptions", "restores", "hang_recoveries")
        if hasattr(eng, "engine_metrics"):     # cascade: breaker + legs
            m = eng.engine_metrics()
            s["engine"] = {
                "breaker": m["breaker"],
                "rerouted": m["rerouted"],
                "edge_failures": m["edge_failures"],
                "restores": m.get("restores", 0),
                "hang_recoveries": m.get("hang_recoveries", 0),
                "edge": {k: m["edge"].get(k, 0) for k in keys},
                "cloud": {k: m["cloud"].get(k, 0) for k in keys},
            }
        elif callable(getattr(eng, "metrics", None)):
            m = eng.metrics()
            s["engine"] = {k: m.get(k, 0) for k in keys}
        return s

    # -- internals (loop thread unless noted) ---------------------------------

    def _tap(self, events: List[Tuple[int, np.ndarray]]) -> None:
        # executor thread: append only; the driver dispatches after the
        # step returns so handles see loop-thread-only mutation
        self._tap_buf.extend(events)

    def _dispatch_taps(self) -> None:
        buf, self._tap_buf = self._tap_buf, []
        for rid, arr in buf:
            h = self._handles.get(rid)
            if h is not None and not h._terminal.is_set():
                if (h.streamed == 0 and self._journal is not None
                        and self._journal.seen(rid)):
                    self._journal.record_first_token(rid)
                h._push(arr)

    def _refuse(self, h: RequestHandle, status: str, reason: str,
                journal: bool = False) -> None:
        """Gateway-level terminal stamp (never reaches engine counters).
        ``journal`` closes out the request's journal entry too — only for
        deliberate per-request refusals of *accepted* work (shed victims,
        gateway-queue cancels). Crash-path refusals must leave the journal
        open: those are exactly the submissions replay re-queues."""
        r = h.request
        r.status = status
        r.failure_reason = reason
        if r.output is None:
            r.output = np.zeros((0,), np.int32)
        r.finish_s = time.perf_counter()
        r.latency_s = r.finish_s - r.submit_s
        if journal and self._journal is not None \
                and self._journal.seen(r.request_id):
            self._journal.record_terminal(r.request_id, status, reason)
        h._finish()

    def _resolve(self, done: Dict) -> None:
        for rid, r in done.items():
            if self._journal is not None and self._journal.seen(rid):
                self._journal.record_terminal(rid, r.status,
                                              r.failure_reason)
            h = self._handles.get(rid)
            if h is None or h._terminal.is_set():
                continue
            if r.status == "done" and h._first_s is not None:
                # stream-boundary accounting: TTFT/latency are what the
                # client observed (submit -> token surfaced on the
                # loop), not the engine's internal completion stamp
                r.ttft_s = h._first_s - r.submit_s
                r.finish_s = h._last_s
                r.latency_s = h._last_s - r.submit_s
            h.request = r
            h._finish()

    async def _step_watched(self, loop, eng) -> None:
        """One engine step under the wall-clock watchdog. A hang raises
        nothing inside the engine (the ``hang`` seam *sleeps*), so the
        deadline lives out here, on the executor future:

        - on time: nothing to do
        - late but within the grace window: the step's work is real, but
          the dispatch broke its latency contract — escalate through
          ``note_hang()``, which rolls every slot back to its checkpoint
          and re-queues through the retry/backoff/quarantine ladder
          (token-exact, so the only cost is redone compute)
        - still stuck after grace: the engine thread is presumed wedged;
          raise ``EngineWedgedError`` for the supervisor. The future is
          shielded, never cancelled — a cancelled jitted dispatch would
          leave donated buffers in an unknown state."""
        fut = loop.run_in_executor(None, eng.step)
        if self.step_timeout_s is None:
            await fut
            return
        try:
            await asyncio.wait_for(asyncio.shield(fut),
                                   self.step_timeout_s)
            return
        except asyncio.TimeoutError:
            pass
        self.watchdog_timeouts += 1
        done, _ = await asyncio.wait(
            {fut}, timeout=self.step_timeout_s * self.hang_grace)
        if not done:
            raise EngineWedgedError(
                f"engine step exceeded step_timeout_s="
                f"{self.step_timeout_s}s plus grace "
                f"({self.step_timeout_s * self.hang_grace:.3f}s); "
                f"restart from snapshot + journal")
        fut.result()       # surface a real exception from the late step
        if hasattr(eng, "note_hang"):
            eng.note_hang()

    def _checkpoint(self) -> None:
        """Periodic durability point (loop thread, engine idle): persist
        an engine snapshot, then compact the journal down to records the
        snapshot does not cover. No awaits between the two, so the
        snapshot/journal pair is consistent by construction."""
        save_snapshot(self.snapshot_dir, self.engine.snapshot(),
                      step=self.steps_driven)
        self.snapshots_taken += 1
        if self._journal is not None:
            self._journal.compact(self.engine.known_request_ids())

    async def _drive(self) -> None:
        loop = asyncio.get_running_loop()
        eng = self.engine
        try:
            if self.step_timeout_s is not None \
                    and hasattr(eng, "warm_compile"):
                # arm the watchdog only after the compile set is warm: a
                # first-step XLA compile (seconds) is indistinguishable
                # from a hang by wall-clock alone, and a watchdog that
                # trips on it would roll back (or declare wedged) a
                # perfectly healthy engine at startup
                await loop.run_in_executor(None, eng.warm_compile)
            while True:
                # cancels first: the engine is idle on this thread
                # between steps, so these apply atomically
                cancels, self._cancels = self._cancels, []
                for rid, fut in cancels:
                    ok = eng.cancel(rid)
                    if not fut.done():
                        fut.set_result(ok)
                # forward inbox -> engine while its queue is shallow;
                # admission control prices the better-ranked gateway
                # tail via ahead_extra
                forwarded = False
                while (self._inbox
                       and eng.queue_depth() < self.forward_depth):
                    r = self._inbox.popleft()
                    mine = request_rank(r)
                    ahead = sum(1 for q in self._inbox
                                if request_rank(q) <= mine)
                    eng.enqueue(r, ahead_extra=ahead)
                    forwarded = True
                if forwarded:
                    async with self._room:
                        self._room.notify_all()
                self._resolve(eng.take_done())
                if eng.pending:
                    await self._step_watched(loop, eng)
                    self._dispatch_taps()
                    self._resolve(eng.take_done())
                    self.steps_driven += 1
                    if (self.snapshot_dir is not None and self.snapshot_every
                            and self.steps_driven % self.snapshot_every == 0):
                        self._checkpoint()
                    continue
                if self._inbox or self._cancels:
                    continue
                if self._draining:
                    break
                self._wake.clear()
                if self._inbox or self._cancels or self._draining:
                    continue
                await self._wake.wait()
        except BaseException as e:
            # never wedge a stream: every unresolved handle terminates.
            # Deliberately NOT journaled as terminal — these are exactly
            # the acknowledged submissions a restart must replay
            for h in list(self._handles.values()):
                if not h._terminal.is_set():
                    self._refuse(h, "failed", f"gateway_error: {e!r}")
            raise


def recover_engine(engine, *, snapshot_dir: Optional[str] = None,
                   journal: Optional[RequestJournal] = None
                   ) -> Dict[str, object]:
    """Crash-restart recovery, in dependency order: restore the newest
    snapshot into the cold ``engine`` (live requests re-queue with their
    token-exact resume checkpoints, terminal ones keep their results),
    then replay the write-ahead ``journal`` to re-queue acknowledged
    submissions the snapshot never saw. Either half is optional — no
    snapshot directory yet (crash before the first checkpoint) degrades
    to journal-only recovery; no journal degrades to snapshot-only.
    Returns what happened, for logs/tests."""
    info: Dict[str, object] = {
        "restored": {"live": 0, "terminal": 0},
        "replayed": {"replayed": 0, "covered": 0, "duplicates": 0},
    }
    if snapshot_dir is not None:
        try:
            snap, step = load_snapshot(snapshot_dir)
        except FileNotFoundError:
            snap = None
        if snap is not None:
            info["restored"] = engine.restore(snap)
            info["snapshot_step"] = step
    if journal is not None:
        info["replayed"] = journal.replay(engine)
    return info
