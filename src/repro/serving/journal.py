"""Write-ahead request journal: the gateway's durability log.

An engine snapshot (``ServingEngine.snapshot``) captures requests the
*engine* owns at one instant. A crash between a client's acknowledged
``submit`` and the next snapshot would silently lose the request — the
client holds a handle for work no recovered engine knows about. The
journal closes that window: the gateway appends a ``submit`` record
*before* acknowledging, a ``first_token`` record when the stream starts,
and a ``terminal`` record at resolution. On restart, ``replay`` walks
the log and re-queues every acknowledged-but-unfinished request the
snapshot missed (under its original id, so handles and terminal records
still line up), refusing duplicate ids along the way.

Format: JSON lines, one record per line, append-only. A torn final line
(crash mid-write) is skipped at replay — everything before it is intact
because records are written with a single ``write`` + flush. Compaction
(``compact``) drops records fully covered by a newer snapshot via an
atomic rewrite, bounding log growth; the gateway runs it right after
each periodic snapshot.
"""
from __future__ import annotations

import json
import os
import tempfile
import time
from typing import Dict, Iterator, Optional, Set

import numpy as np


class RequestJournal:
    """Append-only JSON-lines journal keyed by request id.

    ``fsync=True`` makes every append durable against host power loss;
    the default (flush only) survives process crashes — the failure mode
    the serving stack's chaos tests model — without paying a disk sync
    per request.
    """

    def __init__(self, path: str, fsync: bool = False):
        self.path = path
        self.fsync = fsync
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        self._seen: Set[int] = set()     # rids with a submit record
        self._terminal: Set[int] = set()
        for rec in self._scan():
            if rec.get("kind") == "submit":
                self._seen.add(int(rec["rid"]))
            elif rec.get("kind") == "terminal":
                self._terminal.add(int(rec["rid"]))
        self._f = open(path, "a", encoding="utf-8")
        # counters (surfaced through ServingGateway.stats())
        self.appended = 0
        self.duplicates_refused = 0
        self.compactions = 0
        self.replayed = 0

    # -- append side ----------------------------------------------------------
    def _append(self, rec: dict) -> None:
        self._f.write(json.dumps(rec) + "\n")
        self._f.flush()
        if self.fsync:
            os.fsync(self._f.fileno())
        self.appended += 1

    def record_submit(self, r) -> bool:
        """Journal one acknowledged submission *before* the ack. Returns
        False — and writes nothing — when the id is already journaled
        (a duplicate submission must be refused, not double-served)."""
        rid = int(r.request_id)
        if rid in self._seen:
            self.duplicates_refused += 1
            return False
        self._seen.add(rid)
        self._append({
            "kind": "submit", "rid": rid, "t": time.time(),
            "prompt": np.asarray(r.prompt, np.int32).tolist(),
            "max_new_tokens": int(r.max_new_tokens),
            "temperature": float(r.temperature),
            "priority": int(r.priority),
            "deadline_s": r.deadline_s})
        return True

    def record_first_token(self, rid: int) -> None:
        self._append({"kind": "first_token", "rid": int(rid),
                      "t": time.time()})

    def record_terminal(self, rid: int, status: str,
                        reason: Optional[str] = None) -> None:
        rid = int(rid)
        self._terminal.add(rid)
        self._append({"kind": "terminal", "rid": rid, "t": time.time(),
                      "status": status, "reason": reason})

    def seen(self, rid: int) -> bool:
        return int(rid) in self._seen

    # -- recovery side --------------------------------------------------------
    def _scan(self) -> Iterator[dict]:
        if not os.path.exists(self.path):
            return
        with open(self.path, "r", encoding="utf-8") as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                try:
                    yield json.loads(line)
                except json.JSONDecodeError:
                    # torn tail from a crash mid-append: everything after
                    # it is unreadable by construction — stop here
                    return

    def unfinished(self) -> Dict[int, dict]:
        """Submit records with no terminal record, submission order."""
        subs: Dict[int, dict] = {}
        terminal: Set[int] = set()
        for rec in self._scan():
            kind = rec.get("kind")
            if kind == "submit":
                subs.setdefault(int(rec["rid"]), rec)
            elif kind == "terminal":
                terminal.add(int(rec["rid"]))
        return {rid: rec for rid, rec in subs.items()
                if rid not in terminal}

    def replay(self, engine) -> Dict[str, int]:
        """Re-queue every journaled-but-unfinished request the recovered
        ``engine`` cannot account for (``known_request_ids`` — i.e. the
        snapshot predates the submit, or there was no snapshot at all).
        Requests the snapshot *does* cover are left alone: their resume
        checkpoints are strictly better than a from-scratch re-queue.
        Duplicate submit records for one id count once."""
        counts = {"replayed": 0, "covered": 0, "duplicates": 0}
        seen_here: Set[int] = set()
        known = engine.known_request_ids()
        for rid, rec in sorted(self.unfinished().items()):
            if rid in seen_here:
                counts["duplicates"] += 1
                continue
            seen_here.add(rid)
            if rid in known:
                counts["covered"] += 1
                continue
            engine.requeue_lost(
                rid, np.asarray(rec["prompt"], np.int32),
                max_new_tokens=rec["max_new_tokens"],
                temperature=rec["temperature"],
                priority=rec["priority"],
                deadline_s=rec["deadline_s"])
            counts["replayed"] += 1
        self.replayed += counts["replayed"]
        return counts

    # -- maintenance ----------------------------------------------------------
    def compact(self, covered_rids) -> Dict[str, int]:
        """Atomically drop records for ids a just-written snapshot fully
        covers (live *or* terminal there): replay would route them through
        the snapshot anyway, so the log only needs the ids submitted after
        it. Keeps the journal O(snapshot interval), not O(uptime)."""
        covered = {int(x) for x in covered_rids}
        kept = dropped = 0
        self._f.close()
        d = os.path.dirname(self.path) or "."
        fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp")
        with os.fdopen(fd, "w", encoding="utf-8") as out:
            for rec in self._scan():
                if int(rec.get("rid", -1)) in covered:
                    dropped += 1
                    continue
                out.write(json.dumps(rec) + "\n")
                kept += 1
        os.replace(tmp, self.path)
        self._f = open(self.path, "a", encoding="utf-8")
        self.compactions += 1
        return {"kept": kept, "dropped": dropped}

    def stats(self) -> Dict[str, int]:
        return {"appended": self.appended,
                "duplicates_refused": self.duplicates_refused,
                "compactions": self.compactions,
                "replayed": self.replayed}

    def close(self) -> None:
        if not self._f.closed:
            self._f.close()

    def __enter__(self) -> "RequestJournal":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
