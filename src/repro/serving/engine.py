"""Serving engines: continuous batching over per-slot request state.

``ServingEngine`` is the production path. It owns a fixed pool of
``batch_slots`` decode slots sharing one device-resident KV cache; requests
are admitted into free slots as others finish (continuous batching), so a
long generation never stalls the short ones behind it. Prompt lengths are
bucketed to a small set of power-of-two shapes, bounding prefill
recompilation to ``len(buckets)`` variants regardless of traffic. The decode
inner step is one fused jitted call — sample → cache-append →
done-detection all on device — and the Python loop performs a single small
host sync per round (the (B,) active mask) for EOS/slot management; logits
never leave the device.

With ``max_decode_steps=K`` the engine goes further: pure-decode rounds
``lax.scan`` up to K fused steps inside one jit, paying one dispatch and
one host sync per K generated tokens (multi-step decode). Everything the
step needs — sampling keys folded from the carried ``(request_id, steps)``,
per-slot positions, the EOS/budget active mask — already lives in the
on-device carry, so the scan is exactly K repetitions of the single-step
program and outputs are token-for-token identical at every K. The
scheduler collapses the horizon to 1 whenever prefill work is pending (or
a request was just admitted), preserving chunked-prefill TTFT behavior,
and caps it by the smallest active slot's remaining budget. Paged slots
get a look-ahead block reservation (``reserve_lookahead`` →
``begin_slot``) before each scan so every in-scan append lands in an
allocated block.

With a ``draft_model`` and ``speculative_tokens=k`` the engine decodes
**speculatively**: a small draft LM (the natural choice is the cascade's
edge model — the ACE edge/cloud split is exactly a draft/verify pair)
proposes k tokens per slot autoregressively on its own ring cache, and
the target verifies all of them in *one* chunked decode dispatch —
paying one target dispatch and one host sync per ``1 + accepted`` tokens
instead of per token. Verification is key-coupled (see ``_spec_impl``):
draft and target sample through the same per-(request, step) folded
keys, a proposal is accepted iff it equals the token the target samples
there, so speculative streams are **token-for-token identical to the
non-speculative engine at every temperature** — acceptance rate is the
only thing draft quality affects. The scheduler picks the draft depth
per plan beside its decode horizon, collapsing to non-speculative while
prefill work is pending or while the acceptance EWMA says drafting
loses, and the paged look-ahead reservation covers the k-token worst
case so a verify append never faults mid-dispatch.

Scheduling policy lives in ``repro.serving.scheduler``: each step the
``Scheduler`` composes a mixed batch under a token budget — decode tokens
for the active slots plus prompt *chunks* for admitting requests — and the
engine merely executes the plan. With ``chunk_tokens=None`` (default) the
plan degenerates to the legacy admit-whole-bucket-then-decode behavior;
with chunking enabled a long prompt prefills incrementally across steps
(``LM.prefill_chunk``), so a burst of arrivals no longer stalls in-flight
decodes for a monolithic prefill. Either way outputs are token-exact.

Prompts are right-padded to their bucket. With the ring cache this is
*exact*: pad entries sit at positions ≥ the prompt length, causal masking
hides them until the decode stream overwrites their ring slot at that same
position, so bucketing never changes a single output token. Chunk shapes
are bucketed the same way, and chunk pads are masked out of the cache
entirely (``valid``), so chunking is exact too.

The KV cache itself is pluggable (``repro.serving.kv_cache``): admission
grants a slot *plus* whatever device memory the backend needs for it. The
``ring`` backend (default) pins a ``max_seq_len`` cache line per slot; the
``paged`` backend reserves ``ceil((prompt + budget) / block_size)`` pool
blocks per request and returns them at completion, so concurrency is
bounded by live tokens rather than worst-case sequence length — and, with
chunked prefill, requests sharing a full-block prompt prefix share the
physical blocks (refcounted, copy-on-write) and skip recomputing them.

Sampling keys are derived per request (``request_id`` × decode step), so
temperature > 0 outputs are a pure function of the request: co-scheduling,
admission order and chunking never change a sampled stream.

Scheduling is **SLO-aware**: ``submit`` takes a priority class and an
optional relative deadline, the scheduler serves classes strictly
(class, then earliest deadline, then FIFO — see
``scheduler.request_rank``), and when a higher-class request is blocked on
resources the engine **preempts** the worst-ranked active slot: its decode
state (generated tokens, step counter, next-sample logits) is
checkpointed on the host, its cache is swapped out
(``PagedCache.swap_out`` returns the blocks to the pool) or simply freed
(ring — the K/V is rebuilt at resume by re-prefilling prompt + generated
tokens), and the request re-enters the queue to resume later
**token-for-token** (sampling keys fold the restored step counter; the
saved logits make the first resumed token bit-exact). First-admission
timing is sticky across preemption, so ``admit_s``/``ttft_s`` keep
measuring the request's real service experience.

The stack is **chaos-hardened**: with a ``fault_plan``
(``repro.serving.faults.FaultPlan``) armed, named seams — the decode
dispatch, both KV-swap directions, pool admission, mid-flight
cancellation — inject deterministic faults, and recovery reuses the
preemption machinery: faulted slots roll back to their host checkpoint
and requeue with bounded step-indexed exponential backoff; a request
exceeding ``max_retries`` is quarantined with terminal status ``failed``
instead of wedging the loop. Every request ends in exactly one terminal
status (``done``/``failed``/``rejected``/``cancelled``) with a
machine-readable ``failure_reason``; oversized requests are rejected at
admission rather than raising, ``cancel()`` works in every phase, and
``metrics()`` snapshots the full health picture for
``core.monitoring``. Survivors of any fault schedule finish
token-for-token identical to the fault-free run (``tests/test_faults.py``).

``DrainBatchEngine`` preserves the previous drain-the-queue batcher (pad
the batch to its longest prompt, run everyone for the longest budget,
round-trip logits to the host each token) as the measured baseline for
``benchmarks/bench_serving.py``.
"""
from __future__ import annotations

import collections
import dataclasses
import os
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.io import (json_leaf, json_unleaf,
                                 load_checkpoint_tree, save_checkpoint)
from repro.models.model import LM
from repro.serving.faults import FaultError, FaultPlan
from repro.serving.kv_cache import (RingCache, RingLayout, make_backend,
                                    resolve_swap_caches)
from repro.serving.sharding import (assert_cache_placement, cache_shardings,
                                    place_params, serving_rules)
from repro.serving.sampler import (accepted_prefix_length, request_keys,
                                   sample_logits_batch, sample_logits_keyed)
from repro.serving.scheduler import (MONOLITHIC, PrefillProgress, Scheduler,
                                     bucket_for, prompt_buckets,
                                     request_rank)
from repro.utils.tree import flat_paths


@dataclasses.dataclass
class Request:
    request_id: int
    prompt: np.ndarray           # (S_prompt,) int32
    max_new_tokens: int = 16
    temperature: float = 0.0
    priority: int = 0            # SLO class: higher = more critical
    deadline_s: Optional[float] = None   # relative SLO deadline (from submit)
    output: Optional[np.ndarray] = None
    submit_s: float = 0.0        # wall-clock at submit()
    admit_s: float = 0.0         # wall-clock at *first* slot grant (sticky:
    #                              preempt/resume never restamps it)
    finish_s: float = 0.0        # wall-clock at completion
    latency_s: float = 0.0       # finish - submit (queue + service)
    ttft_s: float = 0.0          # submit -> first generated token exists
    preemptions: int = 0         # times swapped out under SLO pressure
    resume: Optional["_ResumeState"] = dataclasses.field(
        default=None, repr=False)     # checkpoint while preempted
    # terminal disposition: "queued"/"active" while live, then exactly one
    # of done | failed (retry budget exhausted) | rejected (admission
    # refused it) | cancelled. ``failure_reason`` is machine-readable: a
    # code, optionally ": detail" for humans.
    status: str = "queued"
    failure_reason: Optional[str] = None
    retries: int = 0             # fault-triggered rollbacks so far
    last_fault: Optional[str] = None  # seam of the most recent fault
    downgraded: bool = False     # deadline stripped by admission control
    not_before_step: int = 0     # backoff: ineligible before this step
    fault_s: float = 0.0         # wall-clock of last fault requeue (recovery
    #                              latency = next slot grant - fault_s)
    enqueue_s: float = 0.0       # wall-clock at *engine* queue entry. Equal
    #                              to submit_s on the direct submit() path;
    #                              later when a gateway held the request in
    #                              its bounded queue first — latency/TTFT are
    #                              always measured from submit_s (the service
    #                              boundary), never from here


@dataclasses.dataclass
class _ResumeState:
    """Everything a preempted request needs to resume token-for-token:
    the host-side decode checkpoint (generated tokens, step count, the
    logits the next sample reads) plus, on the swap path, the backend's
    opaque K/V checkpoint. ``kv`` is None on the recompute path — the
    engine rebuilds the cache by re-prefilling prompt + generated tokens
    (position-masked attention makes the rebuilt K/V identical, and the
    saved ``last`` logits are restored verbatim, so the next sampled token
    is bit-exact either way)."""
    steps: int
    tokens: np.ndarray           # (steps,) generated so far
    last: np.ndarray             # (V,) f32 logits to sample the next token
    kv: Optional[object] = None  # PagedCache.swap_out checkpoint


def _next_pow2(n: int) -> int:
    return 1 << max(n - 1, 1).bit_length()


def _has_windowed_blocks(lm: LM) -> bool:
    return any(bdef.window is not None
               for stage in lm.cfg.stages for bdef in stage.blocks)


def validate_prompt(prompt: np.ndarray, max_new_tokens: int,
                    max_seq_len: int, truncate: bool) -> np.ndarray:
    """Shared submit-time guard: prompt + budget must fit the cache.

    Historically an over-long prompt fell into the top bucket and silently
    relied on ring wraparound (the oldest tokens were overwritten mid-
    prefill — wrong outputs, no error). Now the engines either raise here
    with an actionable message or, when ``truncate`` is set, explicitly keep
    the trailing ``max_seq_len - max_new_tokens`` prompt tokens."""
    prompt = np.asarray(prompt, np.int32)
    assert prompt.ndim == 1
    room = max_seq_len - max_new_tokens
    if room <= 0:
        raise ValueError(
            f"max_new_tokens ({max_new_tokens}) leaves no room for a prompt "
            f"within max_seq_len ({max_seq_len})")
    if len(prompt) > room:
        if not truncate:
            raise ValueError(
                f"prompt ({len(prompt)}) + max_new_tokens ({max_new_tokens})"
                f" exceeds max_seq_len ({max_seq_len}); the output buffer"
                f" and cache are sized for max_seq_len — shorten the prompt,"
                f" raise max_seq_len, or construct the engine with"
                f" truncate_prompts=True to keep the prompt tail")
        prompt = prompt[-room:]
    return prompt


def enable_compile_cache(cache_dir: str) -> None:
    """Arm JAX's persistent on-disk executable cache under ``cache_dir``.

    ``warm_compile`` pre-runs every chunk bucket × scan horizon × backend
    variant per process; with this cache keyed under the serving state dir
    (``launch/serve.py --compile-cache``), a supervised
    restart-from-snapshot replays the whole executable family from disk
    instead of recompiling it — the restarted engine is hot in seconds.
    Thresholds are dropped to zero so the small CPU-smoke executables are
    cached too, not just the multi-second TPU compiles."""
    os.makedirs(cache_dir, exist_ok=True)
    jax.config.update("jax_compilation_cache_dir", cache_dir)
    jax.config.update("jax_persistent_cache_min_entry_size_bytes", -1)
    jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.0)


class ServingEngine:
    """Continuous-batching autoregressive serving."""

    def __init__(self, lm: LM, params, *, batch_slots: int = 8,
                 max_seq_len: int = 512, seed: int = 0,
                 eos_id: Optional[int] = None, min_bucket: int = 16,
                 cache_backend="ring", block_size: int = 16,
                 num_pool_blocks: Optional[int] = None,
                 truncate_prompts: bool = False,
                 chunk_tokens: Optional[int] = None,
                 token_budget: Optional[int] = None,
                 prefix_sharing: bool = True,
                 max_decode_steps: int = 1,
                 preempt_mode: str = "auto",
                 fault_plan: Optional[FaultPlan] = None,
                 max_retries: int = 3,
                 backoff_base_steps: int = 1,
                 backoff_cap_steps: int = 8,
                 admission_policy: Optional[str] = None,
                 draft_model: Optional[LM] = None,
                 draft_params=None,
                 speculative_tokens: int = 0,
                 mesh=None, rules=None):
        if lm.cfg.frontend.kind == "audio":
            raise NotImplementedError("engine serves text-token streams")
        self.lm = lm
        self.params = params
        # mesh-aware serving: with a mesh, params commit to the decode-mode
        # NamedShardings (attention/KV heads, MLP, vocab on 'model') and
        # every model call below runs under the logical-axis rule context,
        # so GSPMD partitions the jitted step family across the mesh. All
        # scheduling and allocator state stays host-global. mesh=None takes
        # every one of today's single-device code paths unchanged (the
        # rules context is a literal no-op and no jit signature changes).
        self.mesh = mesh
        self.rules = None
        if mesh is not None:
            self.rules = dict(rules) if rules is not None \
                else serving_rules(mesh)
            self.params = place_params(mesh, lm, self.params)
        self.batch_slots = batch_slots
        self.max_seq_len = max_seq_len
        self.eos_id = eos_id
        self.truncate_prompts = truncate_prompts
        self.buckets = prompt_buckets(max_seq_len, min_bucket)
        self._windowed = _has_windowed_blocks(lm)
        self._queue: List[Request] = []
        self._next_id = 0
        self._base_key = jax.random.PRNGKey(seed)
        # serving state (step() advances it; run() drains it)
        self._slots: Dict[int, Request] = {}
        self._free: List[int] = list(range(batch_slots))
        self._prefilling: Dict[int, PrefillProgress] = \
            collections.OrderedDict()
        self._done: Dict[int, Request] = {}
        # host-side mirror of each live slot's completed decode steps: a
        # slot active at a sync advanced exactly the scanned step count, so
        # this is exact for live slots and gives the scheduler its budget
        # headroom (and the look-ahead reservation its positions) without
        # an extra device pull
        self._scanned: Dict[int, int] = {}
        # perf counters (dispatch/occupancy/sharing for bench_serving):
        # decode_steps counts *token* rounds (a K-scan adds K), host_syncs
        # counts active-mask transfers (a K-scan adds 1)
        self.decode_steps = 0
        self.host_syncs = 0
        self.generated_tokens = 0
        self.peak_active_slots = 0
        self.prefill_tokens_total = 0
        self.prefill_tokens_skipped = 0
        # scheduled-vs-useful token-slot accounting (see ``occupancy``)
        self.planned_token_slots = 0
        self.useful_prefill_tokens = 0
        # SLO scheduling: engine-level preemption count (per-request counts
        # live on ``Request.preemptions``) and look-ahead reservation
        # dispatch count (coalesced: one per decode round with top-ups)
        self.preemptions = 0
        self.lookahead_dispatches = 0
        # fault tolerance: injected-fault recovery rolls affected slots back
        # to their last host checkpoint and requeues them with bounded
        # exponential backoff (measured in engine steps, so recovery is
        # deterministic under test); a request exceeding ``max_retries``
        # fault rollbacks is quarantined with terminal status "failed"
        # instead of wedging the drain loop
        self._faults = fault_plan
        self.max_retries = max_retries
        self.backoff_base_steps = backoff_base_steps
        self.backoff_cap_steps = backoff_cap_steps
        self._step_count = 0
        self.fault_recoveries = 0     # decode rounds rolled back
        self.retries_total = 0        # per-request retries, summed
        self.recovery_latencies: List[float] = []  # fault -> re-grant, s
        # durability: restore()s applied to this engine and watchdog-
        # escalated hang recoveries (note_hang); deferred swap-out D2H
        # transfers are parked here and materialized after the *next*
        # scheduler plan, so the copy overlaps host planning work
        self.restores = 0
        self.hang_recoveries = 0
        # wall time of the last warm_compile() (None until called): cold
        # process vs snapshot-restart with the persistent compile cache
        self.warm_compile_s: Optional[float] = None
        self._pending_swaps: List[object] = []
        self._status_counts = collections.Counter()  # terminal dispositions
        # per-step token tap (the gateway's streaming feed): when set, every
        # decode round's host sync is followed by a call with the round's
        # newly generated tokens, [(request_id, np.ndarray), ...]. Emission
        # is monotone per request — preemption/fault rollback checkpoints
        # every generated token, so a resumed stream continues exactly
        # where it stopped and a streamed token is never retracted
        self.on_tokens = None
        self._emitted: Dict[int, int] = {}     # rid -> tokens already tapped

        if chunk_tokens is not None:
            self._validate_chunk_mixers(chunk_tokens)
        self.backend = make_backend(
            cache_backend, lm, params, batch_slots=batch_slots,
            max_seq_len=max_seq_len, proto_len=self.buckets[0],
            block_size=block_size, num_blocks=num_pool_blocks,
            prefix_sharing=prefix_sharing)
        if chunk_tokens is not None:
            self._validate_chunk_layout()
        # speculative decoding: a draft LM proposes k tokens per slot on its
        # own lightweight ring cache; the target verifies all of them in one
        # chunked decode dispatch (see _spec_impl). speculative_tokens=0 (or
        # no draft model) leaves every code path below bit-identical to the
        # non-speculative engine.
        if speculative_tokens > 0 and draft_model is None:
            raise ValueError("speculative_tokens > 0 needs a draft_model")
        self.speculative = draft_model is not None and speculative_tokens > 0
        if self.speculative:
            if draft_params is None:
                raise ValueError("draft_model needs draft_params")
            if draft_model.cfg.frontend.kind == "audio":
                raise NotImplementedError(
                    "draft model must serve text-token streams")
            if draft_model.cfg.padded_vocab != lm.cfg.padded_vocab:
                raise ValueError(
                    f"draft vocab ({draft_model.cfg.padded_vocab}) must "
                    f"match the target's ({lm.cfg.padded_vocab}): "
                    f"verification compares token ids")
            bad = lm.chunk_incompatible_mixer()
            if bad is not None:
                raise NotImplementedError(
                    f"speculative verification is a multi-token chunk query;"
                    f" the target's {bad!r} mixer folds tokens sequentially "
                    f"— use speculative_tokens=0")
        self.scheduler = Scheduler(
            batch_slots=batch_slots, chunk_tokens=chunk_tokens,
            token_budget=token_budget, max_decode_steps=max_decode_steps,
            admission_policy=admission_policy,
            speculative_tokens=speculative_tokens if self.speculative else 0)
        # prefix sharing hashes prompt tokens at admission; only meaningful
        # with chunked install (monolithic prefill recomputes everything)
        self._admit_with_tokens = (
            self.scheduler.chunked
            and getattr(self.backend, "prefix_sharing", False))
        self._cache_state = self.backend.init()
        if mesh is not None:
            # commit the KV pool to the mesh (K/V leaves split on the
            # KV-head dim, tables/positions replicated) and tell the
            # backend so its per-device byte accounting matches
            self._cache_state = jax.device_put(
                self._cache_state, cache_shardings(mesh, self._cache_state))
            self.backend.note_placement(mesh)
        b, v = batch_slots, lm.cfg.padded_vocab
        self._state = {
            "last": jnp.zeros((b, v), jnp.float32),     # logits to sample next
            "pos": jnp.zeros((b,), jnp.int32),
            "steps": jnp.zeros((b,), jnp.int32),
            "budget": jnp.zeros((b,), jnp.int32),
            "temp": jnp.zeros((b,), jnp.float32),
            "rid": jnp.zeros((b,), jnp.int32),
            "active": jnp.zeros((b,), jnp.bool_),
            "out": jnp.zeros((b, max_seq_len), jnp.int32),
        }
        # cache/state buffers are engine-owned and reassigned from every
        # call's output: donate them so XLA updates in place instead of
        # copying the whole KV cache per step/chunk/admission
        self._admit_fn = jax.jit(self._admit_impl,
                                 donate_argnums=(1, 2))  # retraces per bucket
        self._step_fn = jax.jit(self._step_impl, donate_argnums=(1, 2))
        self._scan_fn = jax.jit(self._scan_impl, donate_argnums=(1, 2),
                                static_argnums=(4,))     # per horizon K
        self._chunk_fn = jax.jit(self._chunk_impl, donate_argnums=(1, 2),
                                 static_argnums=(12,))   # per (bucket, ctx)
        self._begin_fn = jax.jit(self.backend.begin_slot, donate_argnums=0)
        if hasattr(self.backend, "begin_slots"):
            # coalesced look-ahead reservation: one device update for every
            # slot crossing a block boundary in the same plan (inputs are
            # padded to batch_slots, so this compiles exactly once)
            self._begin_many_fn = jax.jit(self.backend.begin_slots,
                                          donate_argnums=0)
        if hasattr(self.backend, "copy_block"):
            self._copy_fn = jax.jit(self.backend.copy_block, donate_argnums=0)
        if preempt_mode not in ("auto", "swap", "recompute"):
            raise ValueError(f"preempt_mode must be 'auto', 'swap' or "
                             f"'recompute' (got {preempt_mode!r})")
        if preempt_mode == "swap" and not hasattr(self.backend, "swap_out"):
            raise ValueError(
                "preempt_mode='swap' needs a backend with swap_out/swap_in "
                "(paged); the ring backend resumes by recompute")
        self._preempt_swap = (preempt_mode in ("auto", "swap")
                              and hasattr(self.backend, "swap_out"))
        # speculative accounting (zeroed even without a draft so metrics()
        # keeps a uniform shape): drafted = proposals issued (slots × k),
        # accepted = proposals the target kept, committed = accepted + the
        # anchor token every speculative round banks per slot
        self.spec_rounds = 0
        self.spec_slot_rounds = 0            # Σ active slots over spec rounds
        self.spec_drafted_tokens = 0
        self.spec_accepted_tokens = 0
        self.spec_committed_tokens = 0
        self.spec_fallbacks = 0              # draft-seam faults served plain
        self._spec_class: Dict[int, tuple] = {}  # priority -> (drafted, acc)
        if self.speculative:
            self.draft_lm = draft_model
            self.draft_params = draft_params
            self._draft_windowed = _has_windowed_blocks(draft_model)
            # the draft always rides a ring cache, whatever the target's
            # backend: it never pages, never swaps, never shares prefixes —
            # a fixed max_seq_len line per slot is its whole state
            self._draft_backend = RingCache(
                draft_model, draft_params, batch_slots=batch_slots,
                max_seq_len=max_seq_len, proto_len=self.buckets[0])
            self._draft_state = self._draft_backend.init()
            if mesh is not None:
                # the draft rides the same mesh: its params/ring shard by
                # the same decode rules (leaves whose dims don't divide
                # simply replicate). Draft numerics only steer acceptance —
                # key-coupled verification keeps outputs exact regardless.
                self.draft_params = place_params(mesh, draft_model,
                                                 self.draft_params)
                self._draft_state = jax.device_put(
                    self._draft_state,
                    cache_shardings(mesh, self._draft_state))
                self._draft_backend.note_placement(mesh)
            # slots whose draft cache missed tokens (generated by plain
            # decode rounds while speculation was collapsed): re-synced by
            # a draft prefill before the next speculative round reads them
            self._draft_dirty: set = set()
            self._spec_fn = jax.jit(self._spec_impl,
                                    donate_argnums=(2, 3, 4),
                                    static_argnums=(6,))  # per draft depth k
            self._draft_fill_fn = jax.jit(self._draft_fill_impl,
                                          donate_argnums=(1,))  # per bucket

    def _validate_chunk_mixers(self, chunk_tokens: int) -> None:
        if not (1 <= chunk_tokens <= self.max_seq_len):
            raise ValueError(f"chunk_tokens ({chunk_tokens}) must be in "
                             f"[1, max_seq_len={self.max_seq_len}]")
        bad = self.lm.chunk_incompatible_mixer()
        if bad is not None:
            raise NotImplementedError(
                f"chunked prefill needs attention mixers (got "
                f"{bad!r}); recurrent state folds tokens "
                f"sequentially — use chunk_tokens=None")

    def _validate_chunk_layout(self) -> None:
        if not isinstance(self.backend.layout, RingLayout):
            return
        for stage in self.lm.cfg.stages:
            for bdef in stage.blocks:
                if bdef.window is not None:
                    raise NotImplementedError(
                        "chunked prefill over windowed layers needs the "
                        "paged backend: a window-wide ring evicts tokens "
                        "the chunk's own queries still attend to")

    # -- queue API ------------------------------------------------------------
    def submit(self, prompt: np.ndarray, max_new_tokens: int = 16,
               temperature: float = 0.0, priority: int = 0,
               deadline_s: Optional[float] = None) -> int:
        """Queue a request. ``priority`` is its SLO class (higher = more
        latency-critical: admitted first, given chunk budget first, and
        never preempted by a lower class); ``deadline_s`` orders within a
        class (earliest deadline first, relative to submit time). Both
        default to the old FIFO behavior.

        With an ``admission_policy`` set ("reject" | "downgrade"), a
        deadline-carrying submit is feasibility-checked against the
        measured per-class service rate and the work ranked ahead of it
        (see ``Scheduler.deadline_feasible``): an infeasible deadline is
        either terminally rejected here (status "rejected", reason
        ``deadline_infeasible`` — still returned from ``run``) or
        downgraded to best-effort (deadline stripped, ``downgraded``
        flagged) rather than admitted to miss."""
        r = self.make_request(prompt, max_new_tokens, temperature,
                              priority=priority, deadline_s=deadline_s)
        self.enqueue(r)
        return r.request_id

    def make_request(self, prompt: np.ndarray, max_new_tokens: int = 16,
                     temperature: float = 0.0, priority: int = 0,
                     deadline_s: Optional[float] = None) -> Request:
        """Validate and stamp a request *without* queueing it. The async
        gateway uses this seam to stamp ``submit_s`` at the service
        boundary — time a request spends in the gateway's bounded submit
        queue then counts toward its latency/TTFT/deadline, which the old
        submit-at-grant path silently dropped. ``submit()`` is exactly
        ``make_request`` + ``enqueue``."""
        prompt = validate_prompt(prompt, max_new_tokens, self.max_seq_len,
                                 self.truncate_prompts)
        rid = self._next_id
        self._next_id += 1
        r = Request(rid, prompt, max_new_tokens, temperature,
                    priority=priority, deadline_s=deadline_s)
        r.submit_s = time.perf_counter()
        return r

    def enqueue(self, r: Request, *, ahead_extra: int = 0) -> None:
        """Admission-control gate + engine-queue insert for a made request.
        ``ahead_extra`` counts work queued *upstream* of the engine (the
        gateway's bounded submit queue) so deadline feasibility prices the
        whole line, not just the engine-visible tail; the deadline budget
        is likewise shrunk by the time already spent since ``submit_s``."""
        policy = self.scheduler.admission_policy
        if policy is not None and r.deadline_s is not None:
            mine = request_rank(r)
            ahead = (len(self._slots) + len(self._prefilling) + ahead_extra
                     + sum(1 for q in self._queue if request_rank(q) <= mine))
            remaining = r.deadline_s - (time.perf_counter() - r.submit_s)
            if not self.scheduler.deadline_feasible(
                    deadline_s=remaining, ahead=ahead,
                    priority=r.priority):
                if policy == "reject":
                    self._terminal(
                        r, "rejected",
                        f"deadline_infeasible: {ahead} requests ahead at "
                        f"the measured class service rate cannot finish "
                        f"within {remaining:.3f}s")
                    return
                r.deadline_s = None          # downgrade: serve best-effort
                r.downgraded = True
        r.enqueue_s = time.perf_counter()
        self._queue.append(r)

    def queue_depth(self) -> int:
        """Requests waiting in the engine's own queue (resumes included)."""
        return len(self._queue)

    def warm_compile(self) -> None:
        """Pre-compile every chunk-program variant and every decode-scan
        horizon. Chunk programs retrace per (chunk bucket × context bucket)
        and the scan per horizon in the scheduler's ``k_schedule`` — small
        static sets — and an XLA compile landing mid-traffic (~1 s) would
        dominate some request's TTFT (or a multi-K-token stall). Each chunk
        variant runs once against slot 0 with ``max_new = 0`` and no table
        row installed; each scan variant runs once with every slot inactive
        — so nothing observable changes (masked appends land out of bounds
        or in the trash block, outputs and positions stay untouched, and
        the junk ``last`` logits are re-armed by any real admission). Call
        while idle — before serving traffic — never mid-run.

        Wall time lands in ``warm_compile_s`` (and ``metrics()``): with the
        persistent executable cache armed (``enable_compile_cache``) a
        restarted process replays every compile from disk, so cold-vs-warm
        wall time is the observable the compile cache is judged by."""
        t0 = time.perf_counter()
        if self.scheduler.chunked:
            for bucket in self.scheduler.buckets:
                ctxs = set()
                ctx = _next_pow2(bucket)
                while ctx < self.max_seq_len:
                    ctxs.add(ctx)
                    ctx *= 2
                ctxs.add(self.max_seq_len)
                for ctx in sorted(ctxs):
                    self._cache_state, self._state = self._chunk_fn(
                        self.params, self._cache_state, self._state,
                        jnp.zeros((1, bucket), jnp.int32), jnp.int32(0),
                        jnp.int32(1), jnp.int32(0), jnp.int32(1),
                        jnp.int32(0), jnp.float32(0.0), jnp.int32(0),
                        jnp.bool_(False), ctx)
        if hasattr(self, "_copy_fn"):
            # copying the trash block onto itself is a no-op by definition
            self._cache_state = self._copy_fn(self._cache_state,
                                              jnp.int32(0), jnp.int32(0))
        if hasattr(self, "_begin_many_fn"):
            # all-(-1) rows with covered 0 wipe nothing, and an idle
            # engine's slot-0 table row is already -1: a pure no-op
            b, m = self.batch_slots, self.backend.blocks_per_slot
            self._cache_state = self._begin_many_fn(
                self._cache_state, jnp.zeros((b,), jnp.int32),
                jnp.full((b, m), -1, jnp.int32), jnp.zeros((b,), jnp.int32))
        if self._preempt_swap and hasattr(self.backend, "warm_swap"):
            # a first preemption mid-traffic must not pay the swap
            # gather/scatter compiles
            self._cache_state = self.backend.warm_swap(self._cache_state)
        # decode executables: the single step plus every scan horizon the
        # scheduler may pick, so first-request latency never pays scan
        # compilation (all slots inactive -> the run is a pure no-op)
        self._cache_state, self._state = self._step_fn(
            self.params, self._cache_state, self._state, self._base_key)
        for k in self.scheduler.k_schedule:
            if k > 1:
                self._cache_state, self._state = self._scan_fn(
                    self.params, self._cache_state, self._state,
                    self._base_key, k)
        if self.speculative:
            # speculative executables: the draft-fill prefill per prompt
            # bucket (junk K/V written into idle slot 0 sits behind the
            # same pad/overwrite argument as target prefill pads) and the
            # fused propose/verify program per draft depth (all slots
            # inactive -> masked appends, untouched outputs: a pure no-op)
            for bucket in self.buckets:
                self._draft_state = self._draft_fill_fn(
                    self.draft_params, self._draft_state,
                    jnp.zeros((1, bucket), jnp.int32), jnp.int32(0),
                    jnp.int32(0))
            for k in self.scheduler.spec_schedule:
                (self._cache_state, self._draft_state,
                 self._state) = self._spec_fn(
                    self.params, self.draft_params, self._cache_state,
                    self._draft_state, self._state, self._base_key, k)
        jax.block_until_ready(self._state["active"])
        self.warm_compile_s = time.perf_counter() - t0

    @property
    def pending(self) -> bool:
        """Work outstanding: queued, prefilling, or decoding requests."""
        return bool(self._queue or self._slots or self._prefilling)

    def step(self) -> None:
        """Execute one scheduler plan: admissions and prompt chunks first,
        then the decode round. Public so drivers can interleave arrivals
        with serving (see ``benchmarks/bench_serving.py``); ``run`` is just
        this in a drain loop."""
        self._step_count += 1
        slots, free, prefilling = self._slots, self._free, self._prefilling
        if self._faults is not None and self._faults.fire("cancel"):
            # chaos cancellation: a deterministic in-flight victim hangs up
            live = sorted([r.request_id for r in self._queue]
                          + [pp.request.request_id
                             for pp in prefilling.values()]
                          + [r.request_id for r in slots.values()])
            if live:
                self.cancel(self._faults.pick("cancel", live))
        min_headroom = min(
            (r.max_new_tokens - self._scanned.get(s, 0)
             for s, r in slots.items()), default=None)
        plan = self.scheduler.plan_step(
            n_active=len(slots), prefilling=prefilling,
            try_admit=lambda: self._try_admit(slots, free, prefilling),
            min_headroom=min_headroom,
            try_preempt=lambda: self._try_preempt(slots))
        for c in plan.chunks:
            self._run_chunk(c, prefilling, slots)
        if self._pending_swaps:
            # rollback-path swap-outs started their D2H copies
            # asynchronously; the planning/chunk work above overlapped
            # them — materialize before anything can consume a checkpoint
            for h in self._pending_swaps:
                h.resolve()
            self._pending_swaps.clear()
        # occupancy peak counts prefill-only steps too: a step where every
        # live request is still prefilling used to be invisible here
        if slots or prefilling:
            self.peak_active_slots = max(self.peak_active_slots,
                                         len(slots) + len(prefilling))
        if slots:
            try:
                if plan.spec_tokens > 0 and self.speculative:
                    try:
                        self._spec_round(slots, free, self._done,
                                         plan.spec_tokens)
                    except FaultError as e:
                        if e.seam != "draft":
                            raise
                        # the draft dispatch is down: serve this round
                        # without speculation. Commits are target samples
                        # under the baseline key schedule either way, so
                        # the token streams are unchanged — degraded
                        # throughput, never degraded output
                        self.spec_fallbacks += 1
                        self._decode_round(slots, free, self._done,
                                           plan.decode_steps)
                else:
                    self._decode_round(slots, free, self._done,
                                       plan.decode_steps)
            except FaultError as e:
                # the decode dispatch was poisoned *before* touching device
                # state (launch failure semantics), so every active slot
                # still holds its pre-round state: roll them all back to a
                # host checkpoint and requeue with backoff
                self._recover_decode_fault(e.seam)
        # a request too big for the whole pool is terminally rejected in
        # _try_admit; a step where everything waiting is merely backing off
        # (or transiently starved by an injected pool fault) just advances
        # the step counter toward backoff expiry — never a wedge, never an
        # engine-aborting raise

    def run(self) -> Dict[int, Request]:
        """Serve until the queue and all slots drain; returns every request
        completed since the last ``run`` (``step`` completions included)."""
        while self.pending:
            self.step()
        return self.take_done()

    def take_done(self) -> Dict[int, Request]:
        """Drain the terminal-request buffer accumulated since the last
        call (every status: done/failed/rejected/cancelled). The gateway
        polls this after each ``step()`` to resolve handles and close
        streams; ``run()`` is a drain loop ending in one ``take_done``."""
        done, self._done = self._done, {}
        return done

    # -- device-side programs -------------------------------------------------
    def _admit_impl(self, params, cache_state, state, tokens, length, slot,
                    max_new, temp, rid, table_row):
        """Prefill one bucketed prompt and install it into ``slot``.
        True lengths are threaded only for windowed models, where the
        window-wide cache would otherwise keep the padded bucket's trailing
        window and evict live tokens; unwindowed installs keep the cheaper
        contiguous write (pad entries are overwritten before visibility)."""
        logits, one_caches = self.lm.prefill(
            params, {"tokens": tokens}, cache_width=self.max_seq_len,
            lengths=jnp.reshape(length, (1,)) if self._windowed else None,
            mesh=self.mesh, rules=self.rules)
        last = jax.lax.dynamic_index_in_dim(logits[0], length - 1, axis=0,
                                            keepdims=False)
        cache_state = self.backend.prefill_fill(cache_state, one_caches,
                                                slot, length, table_row)
        state = dict(state)
        state["last"] = state["last"].at[slot].set(last.astype(jnp.float32))
        state["pos"] = state["pos"].at[slot].set(length)
        state["steps"] = state["steps"].at[slot].set(0)
        state["budget"] = state["budget"].at[slot].set(max_new)
        state["temp"] = state["temp"].at[slot].set(temp)
        state["rid"] = state["rid"].at[slot].set(rid)
        state["active"] = state["active"].at[slot].set(max_new > 0)
        return cache_state, state

    def _chunk_impl(self, params, cache_state, state, tokens, start, length,
                    slot, prompt_len, max_new, temp, rid, final, ctx):
        """Run one prompt chunk for ``slot`` (scheduler-planned): install
        the chunk's K/V through the slot's cache view and, on the final
        chunk, arm the slot for decode with the last real token's logits.
        ``ctx`` (static) truncates the visible cache to the live prefix —
        the chunk attends to nothing at or above its own padded end."""
        view, tables = self.backend.slot_view(cache_state, slot, ctx)
        t = tokens.shape[1]
        valid = (jnp.arange(t, dtype=jnp.int32) < length)[None, :]
        logits, view = self.lm.prefill_chunk(
            params, view, tokens, jnp.reshape(start, (1,)),
            layout=self.backend.layout, block_tables=tables, valid=valid,
            logits_index=jnp.reshape(length - 1, (1,)),
            mesh=self.mesh, rules=self.rules)
        cache_state = self.backend.slot_update(cache_state, slot, view)
        last = logits[0, 0]
        state = dict(state)
        state["last"] = state["last"].at[slot].set(
            jnp.where(final, last.astype(jnp.float32), state["last"][slot]))
        state["pos"] = state["pos"].at[slot].set(prompt_len)
        state["steps"] = state["steps"].at[slot].set(0)
        state["budget"] = state["budget"].at[slot].set(max_new)
        state["temp"] = state["temp"].at[slot].set(temp)
        state["rid"] = state["rid"].at[slot].set(rid)
        state["active"] = state["active"].at[slot].set(final & (max_new > 0))
        return cache_state, state

    def _step_impl(self, params, cache_state, state, base_key):
        """Fused decode step: sample → append → done-detect, on device.
        Sampling keys fold (request_id, step) into ``base_key``, so a
        request's stream is independent of its co-scheduled neighbors."""
        active = state["active"]
        keys = request_keys(base_key, state["rid"], state["steps"])
        nxt = sample_logits_keyed(keys, state["last"], state["temp"])
        rows = jnp.arange(self.batch_slots)
        idx = jnp.clip(state["steps"], 0, self.max_seq_len - 1)
        out = state["out"].at[rows, idx].set(
            jnp.where(active, nxt, state["out"][rows, idx]))
        steps = state["steps"] + active.astype(jnp.int32)
        feed = jnp.where(active, nxt, 0)[:, None]
        # inactive rows (free slots, mid-prefill slots) must not write their
        # junk token into the cache: valid-masked append drops them
        logits, caches = self.lm.decode_step(
            params, cache_state["caches"], feed, state["pos"],
            layout=self.backend.layout,
            block_tables=cache_state["tables"],
            valid=active[:, None], mesh=self.mesh, rules=self.rules)
        finished = steps >= state["budget"]
        if self.eos_id is not None:
            finished |= nxt == self.eos_id
        state = {
            "last": logits[:, 0, :].astype(jnp.float32),
            "pos": state["pos"] + active.astype(jnp.int32),
            "steps": steps,
            "budget": state["budget"],
            "temp": state["temp"],
            "rid": state["rid"],
            "active": active & ~finished,
            "out": out,
        }
        return {"caches": caches, "tables": cache_state["tables"]}, state

    def _scan_impl(self, params, cache_state, state, base_key, k):
        """Multi-step decode: ``lax.scan`` ``k`` (static) fused decode
        steps inside one jit — one dispatch and one host sync per ``k``
        tokens. The carry is exactly the single-step program's
        (caches, state): sampling keys fold the *carried* (request_id,
        steps), positions and the active mask advance on device, and rows
        that finish mid-scan (EOS / budget) go inactive and no-op through
        the remaining iterations (masked appends, unwritten outputs) — so
        outputs are token-for-token the K=1 engine's at every k. Block
        tables are scan-invariant (the host reserves look-ahead blocks
        before dispatch), so they ride as a closure constant, not carry."""
        tables = cache_state["tables"]

        def body(carry, _):
            caches, st = carry
            new_cache, st = self._step_impl(
                params, {"caches": caches, "tables": tables}, st, base_key)
            return (new_cache["caches"], st), None

        (caches, state), _ = jax.lax.scan(
            body, (cache_state["caches"], state), xs=None, length=k)
        return {"caches": caches, "tables": tables}, state

    def _draft_fill_impl(self, draft_params, draft_state, tokens, length,
                         slot):
        """Install one bucketed token stream into the draft ring — the
        draft-side analogue of ``_admit_impl`` minus sampling state (the
        speculative program derives everything it needs from the target's
        carry). ``prefill_fill`` replaces the whole slot row, so pad
        entries and any previous tenant's K/V vanish together."""
        _, one_caches = self.draft_lm.prefill(
            draft_params, {"tokens": tokens}, cache_width=self.max_seq_len,
            last_only=True,
            lengths=jnp.reshape(length, (1,)) if self._draft_windowed
            else None, mesh=self.mesh, rules=self.rules)
        return self._draft_backend.prefill_fill(draft_state, one_caches,
                                                slot, length, None)

    def _spec_impl(self, params, draft_params, cache_state, draft_state,
                   state, base_key, k):
        """One fused propose-k/verify round: draft scan → one chunked
        target dispatch → accept → commit, all on device.

        Verification is **key-coupled**: the anchor token ``t0`` is
        sampled from the carried ``last`` logits with exactly the key the
        plain step would fold, the draft proposes ``d_1..d_k`` with the
        keys of the *following* steps, the target attends the whole
        (k+1)-token chunk ``[t0, d_1..d_k]`` in one ``prefill_chunk``
        call, and ``s_i`` — sampled from the target's verify logits with
        the same folded key as ``d_i`` — is precisely the token the
        baseline engine would emit at that step. A proposal is accepted
        iff it *equals* its baseline token, so every committed token is a
        baseline token: speculative streams are token-for-token identical
        to K=1 at every temperature (greedy included — argmax is the
        temperature-0 case of the same coupling). On a rejection the
        corrected token is not committed here; it re-emerges as the next
        round's anchor — same key, same logits, same token.

        The draft scan runs k+1 iterations: the last consumes ``d_k`` so
        the draft cache stays contiguous through a fully-accepted round
        (its sampled output is discarded). Both caches mask appends to
        ``i < headroom`` — a token at or past the budget edge can never
        commit, and the mask keeps every append inside the slot's
        reservation (ring width / paged look-ahead)."""
        b = self.batch_slots
        active = state["active"]
        rid, steps, temp, pos = (state["rid"], state["steps"],
                                 state["temp"], state["pos"])
        headroom = state["budget"] - steps       # >= 1 on active rows
        t0 = sample_logits_keyed(
            request_keys(base_key, rid, steps), state["last"], temp)

        def draft_body(carry, i):
            dcaches, tok = carry
            ok = active & (i < headroom)
            feed = jnp.where(active, tok, 0)[:, None]
            dlogits, dcaches = self.draft_lm.decode_step(
                draft_params, dcaches, feed, pos + i,
                layout=self._draft_backend.layout, block_tables=None,
                valid=ok[:, None], mesh=self.mesh, rules=self.rules)
            nxt = sample_logits_keyed(
                request_keys(base_key, rid, steps + i + 1),
                dlogits[:, 0, :].astype(jnp.float32), temp)
            return (dcaches, nxt), nxt

        (dcaches, _), drafted = jax.lax.scan(
            draft_body, (draft_state["caches"], t0),
            jnp.arange(k + 1, dtype=jnp.int32))
        proposals = jnp.moveaxis(drafted, 0, 1)[:, :k]          # (B, k)

        chunk = jnp.concatenate([t0[:, None], proposals], axis=1)
        offs = jnp.arange(k + 1, dtype=jnp.int32)
        ok = active[:, None] & (offs[None, :] < headroom[:, None])
        logits, caches = self.lm.prefill_chunk(
            params, cache_state["caches"], chunk, pos,
            layout=self.backend.layout, block_tables=cache_state["tables"],
            valid=ok, mesh=self.mesh, rules=self.rules)
        logits = logits.astype(jnp.float32)                 # (B, k+1, V)

        # s_i reads logits row i-1: the target's distribution after the
        # first i chunk tokens, i.e. the baseline ``last`` at step steps+i.
        # All k verifications fold keys and sample as one flattened batch:
        # per-element results are identical to k separate calls, but the
        # program carries one fold/categorical op pair instead of k — on
        # a small-model host the op count, not the FLOPs, is the cost
        ksteps = (steps[:, None] + offs[None, 1:]).reshape(-1)   # (B*k,)
        krid = jnp.broadcast_to(rid[:, None], (b, k)).reshape(-1)
        ktemp = jnp.broadcast_to(temp[:, None], (b, k)).reshape(-1)
        target_toks = sample_logits_keyed(
            request_keys(base_key, krid, ksteps),
            logits[:, :k, :].reshape(b * k, logits.shape[-1]),
            ktemp).reshape(b, k)                             # (B, k)
        j = accepted_prefix_length(proposals, target_toks)  # (B,) in [0,k]
        commit = jnp.minimum(1 + j, headroom)
        eos_hit = jnp.zeros((b,), jnp.bool_)
        if self.eos_id is not None:
            is_eos = chunk == self.eos_id
            has_eos = jnp.any(is_eos, axis=1)
            eos_idx = jnp.argmax(is_eos, axis=1)    # first EOS in the chunk
            commit = jnp.where(has_eos,
                               jnp.minimum(commit, eos_idx + 1), commit)
            eos_hit = has_eos & (eos_idx < commit)

        rows = jnp.arange(b)
        write = ok & (offs[None, :] < commit[:, None])
        idx = jnp.clip(steps[:, None] + offs[None, :], 0,
                       self.max_seq_len - 1)
        out = state["out"].at[rows[:, None], idx].set(
            jnp.where(write, chunk, state["out"][rows[:, None], idx]))
        # logits row commit-1 is the distribution for the step after the
        # last committed token — exactly the ``last`` the baseline carry
        # would hold there
        last = jnp.take_along_axis(
            logits, jnp.clip(commit - 1, 0, k)[:, None, None], axis=1)[:, 0]
        last = jnp.where(active[:, None], last, state["last"])
        dcommit = jnp.where(active, commit, 0)
        new_steps = steps + dcommit
        finished = (new_steps >= state["budget"]) | eos_hit
        state = {
            "last": last,
            "pos": pos + dcommit,
            "steps": new_steps,
            "budget": state["budget"],
            "temp": temp,
            "rid": rid,
            "active": active & ~finished,
            "out": out,
        }
        return ({"caches": caches, "tables": cache_state["tables"]},
                {"caches": dcaches, "tables": draft_state["tables"]},
                state)

    # -- host-side management -------------------------------------------------
    def _try_admit(self, slots, free, prefilling):
        """Scheduler admission callback: grant the *best-ranked* waiting
        request (class, then deadline, then submission order) a slot plus
        its cache reservation, or return None. Ordering is strict — a
        lower-class request never backfills in front of a blocked
        higher-class one, because its blocks could stall the critical
        request for a whole generation. Chunked admissions return a
        ``PrefillProgress`` (the scheduler plans their chunks); legacy,
        swap-resumed and recompute-resumed-monolithic admissions return
        MONOLITHIC (nothing left to chunk).

        Requests under fault backoff (``not_before_step``) are skipped
        until their backoff expires — a retrying request must not block
        the queue during its own cool-down. Requests that could never fit
        even in an idle pool (``can_ever_admit``) are terminally rejected
        here with a machine-readable reason rather than raising: one bad
        submit never aborts ``run()`` for everyone else."""
        if not free:
            return None
        while True:
            eligible = [q for q in self._queue
                        if q.not_before_step <= self._step_count]
            if not eligible:
                return None
            r = min(eligible, key=request_rank)
            if not self.backend.can_ever_admit(len(r.prompt),
                                               r.max_new_tokens):
                self._queue.remove(r)
                self._terminal(
                    r, "rejected",
                    f"exceeds_pool_capacity: prompt {len(r.prompt)} + "
                    f"budget {r.max_new_tokens} needs more KV blocks than "
                    f"the whole pool holds; enlarge num_pool_blocks")
                continue
            break
        if self._faults is not None and self._faults.fire("pool"):
            # transient block-pool exhaustion: admission simply answers
            # "no blocks" this step and retries on the next one
            return None
        if r.resume is not None and r.resume.kv is not None:
            # swap path: restore the checkpointed blocks, no prefill at all
            if not self.backend.can_resume(len(r.prompt), r.max_new_tokens):
                return None
            if self._faults is not None and self._faults.fire("swap_in"):
                # the K/V checkpoint failed to restore (fires before the
                # backend draws blocks, so nothing to unwind): drop it and
                # fall back to the recompute-resume path — the host
                # checkpoint (tokens + last logits) rebuilds the cache
                # exactly, so the stream stays token-for-token identical
                r.resume.kv = None
                self._record_retry(r, "swap_in")
                return None
            self._queue.remove(r)
            slot = free.pop()
            self._cache_state = self.backend.swap_in(
                self._cache_state, slot, r.resume.kv, len(r.prompt),
                r.max_new_tokens)
            self._note_grant(r)
            self._arm_resumed(r, slot, slots)
            return MONOLITHIC
        # fresh admission, or recompute-resume (re-prefill prompt + already
        # generated tokens; the decode checkpoint is restored at arming)
        tokens = r.prompt if r.resume is None else np.concatenate(
            [r.prompt, r.resume.tokens]).astype(np.int32)
        remaining = r.max_new_tokens - (r.resume.steps if r.resume else 0)
        key = tokens if (self._admit_with_tokens and r.resume is None) \
            else len(tokens)
        if not self.backend.can_admit(key, remaining):
            return None
        self._queue.remove(r)
        slot = free.pop()
        if not self.scheduler.chunked:
            self._admit(r, slot, slots, tokens, remaining)
            return MONOLITHIC
        table_row = self.backend.alloc_slot(slot, key, remaining)
        start = self.backend.shared_prefill_start(slot)
        shared_blocks = self.backend.shared_block_count(slot)
        for src, dst in self.backend.take_pending_copies():
            self._cache_state = self._copy_fn(
                self._cache_state, jnp.int32(src), jnp.int32(dst))
        self._cache_state = self._begin_fn(
            self._cache_state, jnp.int32(slot), jnp.asarray(table_row),
            jnp.int32(shared_blocks))
        self._note_grant(r)
        self.prefill_tokens_total += len(tokens)
        self.prefill_tokens_skipped += start
        pp = PrefillProgress(request=r, slot=slot, next=start,
                             total=len(tokens),
                             tokens=tokens if r.resume is not None else None)
        prefilling[slot] = pp
        return pp

    def _run_chunk(self, c, prefilling, slots):
        pp = prefilling[c.slot]
        r = pp.request
        src = pp.tokens if pp.tokens is not None else r.prompt
        self.planned_token_slots += c.bucket
        self.useful_prefill_tokens += c.length
        tokens = np.zeros((1, c.bucket), np.int32)
        tokens[0, :c.length] = src[c.start:c.start + c.length]
        # static context bound: next power of two covering the padded chunk
        # end (bounded retrace set: |chunk buckets| x |context buckets|)
        ctx = min(self.max_seq_len, _next_pow2(c.start + c.bucket))
        self._cache_state, self._state = self._chunk_fn(
            self.params, self._cache_state, self._state, jnp.asarray(tokens),
            jnp.int32(c.start), jnp.int32(c.length), jnp.int32(c.slot),
            jnp.int32(len(src)), jnp.int32(r.max_new_tokens),
            jnp.float32(r.temperature), jnp.int32(r.request_id),
            jnp.bool_(c.final), ctx)
        pp.next = c.start + c.length
        if c.final:
            del prefilling[c.slot]
            if self.speculative:
                # arm the draft cache with the slot's whole visible stream
                # (prompt, or prompt + generated on a recompute-resume)
                self._draft_fill(c.slot, np.asarray(src, np.int32))
            if r.resume is None:
                # the slot's full prompt blocks now hold real K/V: publish
                # them for prefix sharing by later admissions (a resumed
                # request's token stream includes generated tokens — never
                # published as a "prompt")
                self.backend.register_prefix(c.slot, r.prompt)
                self._scanned[c.slot] = 0
            else:
                self._restore_checkpoint(r, c.slot)
            slots[c.slot] = r

    def _admit(self, r: Request, slot: int, slots: Dict[int, Request],
               tokens_1d: np.ndarray, remaining: int):
        """Monolithic (unchunked) admission: prefill ``tokens_1d`` — the
        prompt, or prompt + generated for a recompute-resume — into the
        slot and arm it for decode. ``remaining`` sizes the cache
        reservation (decode tokens still to come)."""
        length = len(tokens_1d)
        bucket = bucket_for(length, self.buckets)
        tokens = np.zeros((1, bucket), np.int32)
        tokens[0, :length] = tokens_1d                   # right-pad (exact)
        table_row = self.backend.alloc_slot(slot, length, remaining)
        self._cache_state, self._state = self._admit_fn(
            self.params, self._cache_state, self._state, jnp.asarray(tokens),
            jnp.int32(length), jnp.int32(slot), jnp.int32(r.max_new_tokens),
            jnp.float32(r.temperature), jnp.int32(r.request_id),
            jnp.asarray(table_row))
        self._note_grant(r)
        self.prefill_tokens_total += length
        self.planned_token_slots += bucket
        self.useful_prefill_tokens += length
        if self.speculative:
            self._draft_fill(slot, tokens_1d)
        if r.resume is None:
            self._scanned[slot] = 0
        else:
            self._restore_checkpoint(r, slot)
        slots[slot] = r

    def _edit_state(self, **rows) -> None:
        """Host-side single-slot state edit: whole-array device↔host
        round-trips instead of eager sliced updates. A sliced jnp edit
        (``x.at[slot, :steps].set``) compiles a fresh executable per
        (slot, steps) shape — a preemption would stall ~100 ms on XLA
        every time it saw a new checkpoint size. Plain transfers never
        compile, and the state arrays are a few KB."""
        st = dict(self._state)
        for key, (slot, value) in rows.items():
            arr = np.array(st[key])          # device→host copy, no compile
            arr[slot] = value
            st[key] = jnp.asarray(arr)       # host→device, no compile
        self._state = st

    def _restore_checkpoint(self, r: Request, slot: int) -> None:
        """Re-arm a resumed slot's decode state from the preemption
        checkpoint: step counter, generated-token buffer and — crucially —
        the saved ``last`` logits, so the next sampled token is bit-exact
        regardless of how the K/V came back (swap or recompute). Sampling
        keys fold (request_id, steps), so the stream continues exactly
        where it stopped."""
        rs = r.resume
        out = np.zeros((self.max_seq_len,), np.int32)
        out[:rs.steps] = rs.tokens
        self._edit_state(steps=(slot, rs.steps), last=(slot, rs.last),
                         out=(slot, out))
        self._scanned[slot] = rs.steps
        r.resume = None

    def _arm_resumed(self, r: Request, slot: int, slots) -> None:
        """Swap-path resume: the K/V blocks are already restored, so the
        whole slot state (position, budget, temperature, active) is armed
        host-side — no prefill runs at all."""
        rs = r.resume
        self._edit_state(pos=(slot, len(r.prompt) + rs.steps),
                         budget=(slot, r.max_new_tokens),
                         temp=(slot, r.temperature),
                         rid=(slot, r.request_id),
                         # a budget-0 slot is reaped, never decoded — the
                         # same admission-time rule the prefill paths apply
                         active=(slot, rs.steps < r.max_new_tokens))
        if self.speculative:
            # the swap checkpoint restores only the target's K/V; the
            # draft cache is rebuilt from the host token stream
            self._draft_fill(slot, np.concatenate(
                [r.prompt, rs.tokens]).astype(np.int32))
        self._restore_checkpoint(r, slot)
        slots[slot] = r

    def _rollback_slot(self, slot: int) -> Request:
        """Evict ``slot`` back to a host checkpoint — the shared primitive
        under SLO preemption *and* fault recovery. Decode state (generated
        tokens, step count, next-sample logits) is checkpointed on the
        host; the cache either rides along (``PagedCache.swap_out`` —
        blocks return to the pool) or is rebuilt at resume by
        re-prefilling prompt + generated tokens (ring /
        ``preempt_mode='recompute'``). A ``swap_out`` seam fault degrades
        to the recompute path — strictly slower, never less exact. The
        caller decides what the eviction *means* (preemption vs retry)
        and where the request goes next."""
        r = self._slots.pop(slot)
        st = self._state
        steps = int(np.asarray(st["steps"])[slot])   # transfer, no compile
        r.resume = _ResumeState(
            steps=steps,
            tokens=np.array(np.asarray(st["out"])[slot, :steps]),
            last=np.array(np.asarray(st["last"])[slot]))
        self._edit_state(active=(slot, False))
        swap = self._preempt_swap
        if swap and self._faults is not None \
                and self._faults.fire("swap_out"):
            r.last_fault = "swap_out"    # checkpoint transport failed:
            swap = False                 # recompute resume instead (exact)
        if swap:
            # deferred D2H: the gather lands in a fresh device buffer, the
            # host copy streams in the background and is resolved after
            # the next scheduler plan (see step()) — the rollback path no
            # longer stalls the step loop on the transfer
            r.resume.kv, self._cache_state = self.backend.swap_out(
                self._cache_state, slot, defer=True)
            self._pending_swaps.append(r.resume.kv["caches"])
        else:
            self._cache_state = self.backend.free_slot(self._cache_state,
                                                       slot)
        self._scanned.pop(slot, None)
        if self.speculative:
            self._draft_dirty.discard(slot)
        self._free.append(slot)
        return r

    def preempt(self, slot: int) -> None:
        """Swap the request decoding in ``slot`` out and requeue it (see
        ``_rollback_slot``). Resumption is token-exact. Called by the
        scheduler under SLO pressure; public so drivers and tests can
        force arbitrary preemption schedules."""
        r = self._rollback_slot(slot)
        r.preemptions += 1
        self.preemptions += 1
        self._queue.append(r)

    # -- fault tolerance ------------------------------------------------------
    def _recover_decode_fault(self, seam: str) -> None:
        """A decode dispatch was poisoned before mutating device state:
        roll every active slot back to a host checkpoint and requeue with
        bounded exponential backoff; requests exceeding the retry budget
        are quarantined (terminal "failed") instead of wedging the loop."""
        self.fault_recoveries += 1
        for slot in list(self._slots):
            r = self._rollback_slot(slot)
            self._record_retry(r, seam, in_queue=False)

    def _record_retry(self, r: Request, seam: str,
                      in_queue: bool = True) -> None:
        """Account one fault-triggered retry for ``r`` and route it:
        backoff + requeue within budget, quarantine beyond it.
        ``in_queue`` says whether ``r`` currently sits in the queue (a
        swap-in fault) or was just rolled out of a slot."""
        r.retries += 1
        r.last_fault = seam
        r.fault_s = time.perf_counter()
        self.retries_total += 1
        if r.retries > self.max_retries:
            if in_queue:
                self._queue.remove(r)
            self._quarantine(r, seam)
            return
        r.not_before_step = self._step_count + min(
            self.backoff_cap_steps,
            self.backoff_base_steps << (r.retries - 1))
        if not in_queue:
            self._queue.append(r)

    def _quarantine(self, r: Request, seam: str) -> None:
        """Terminal failure: the request exhausted its retry budget. Its
        partial output (tokens generated before the last fault) is kept;
        its checkpoint (and any host K/V) is dropped."""
        out = (r.resume.tokens if r.resume is not None
               else np.zeros((0,), np.int32))
        r.resume = None
        self._terminal(
            r, "failed",
            f"retry_budget_exhausted: {r.retries} retries > "
            f"max_retries={self.max_retries} (last fault: {seam})",
            output=out)

    def _terminal(self, r: Request, status: str, reason: Optional[str],
                  output: Optional[np.ndarray] = None) -> None:
        """Move ``r`` to a terminal disposition and into ``_done`` (the
        caller has already detached it from queue/slots/prefilling).
        ``output`` defaults to empty so downstream accounting never trips
        on None."""
        r.status = status
        r.failure_reason = reason
        if r.output is None:
            r.output = output if output is not None \
                else np.zeros((0,), np.int32)
        r.finish_s = time.perf_counter()
        r.latency_s = r.finish_s - r.submit_s
        self._emitted.pop(r.request_id, None)
        if status == "failed" and r.deadline_s is not None:
            # quarantine is a deadline miss: the client asked for a result
            # by a time and will never get one. Cancelled/rejected requests
            # are *not* counted — the client withdrew / was never admitted.
            self.scheduler.observe_deadline(r.priority, False)
        self._status_counts[status] += 1
        self._done[r.request_id] = r

    def _note_grant(self, r: Request) -> None:
        """Slot-grant bookkeeping shared by every admission path: sticky
        first-admission stamp (resume never restamps) and, after a fault
        requeue, the recovery latency (fault -> re-grant)."""
        if r.admit_s == 0.0:
            r.admit_s = time.perf_counter()
        if r.fault_s:
            self.recovery_latencies.append(time.perf_counter() - r.fault_s)
            r.fault_s = 0.0

    def cancel(self, request_id: int) -> bool:
        """Cancel an in-flight request wherever it currently lives —
        queued (preempted included), mid-prefill, or mid-decode. Its
        resources (slot, pool blocks) are released immediately, partial
        output is kept, and it lands in ``run()``'s results with terminal
        status "cancelled". Returns False when the id isn't in flight
        (already finished, or never submitted)."""
        for r in self._queue:
            if r.request_id == request_id:
                self._queue.remove(r)
                out = (r.resume.tokens if r.resume is not None
                       else np.zeros((0,), np.int32))
                r.resume = None
                self._terminal(r, "cancelled", "cancelled: while queued",
                               output=out)
                return True
        for slot, pp in list(self._prefilling.items()):
            if pp.request.request_id == request_id:
                del self._prefilling[slot]
                # the installed chunks are abandoned: blocks return to the
                # pool, stale cache entries are wiped by the next tenant's
                # begin_slot
                self._cache_state = self.backend.free_slot(
                    self._cache_state, slot)
                self._free.append(slot)
                r = pp.request
                r.resume = None
                self._terminal(r, "cancelled", "cancelled: mid-prefill")
                return True
        for slot, r in list(self._slots.items()):
            if r.request_id == request_id:
                self._slots.pop(slot)
                steps = int(np.asarray(self._state["steps"])[slot])
                out = np.array(
                    np.asarray(self._state["out"])[slot, :steps])
                self._edit_state(active=(slot, False))
                self._cache_state = self.backend.free_slot(
                    self._cache_state, slot)
                self._scanned.pop(slot, None)
                if self.speculative:
                    self._draft_dirty.discard(slot)
                self._free.append(slot)
                self._terminal(r, "cancelled", "cancelled: mid-decode",
                               output=out)
                return True
        return False

    def metrics(self) -> Dict[str, object]:
        """Monitoring snapshot: live/terminal request counts, fault and
        recovery accounting, and the core serving counters — the payload
        ``core.monitoring.MonitoringService.record_serving`` ingests."""
        lat = sorted(self.recovery_latencies)

        def pct(p: float) -> float:
            return lat[min(len(lat) - 1, int(p * len(lat)))] if lat else 0.0

        return {
            "live": {"queued": len(self._queue),
                     "prefilling": len(self._prefilling),
                     "decoding": len(self._slots)},
            "terminal": dict(self._status_counts),
            "quarantined": self._status_counts.get("failed", 0),
            "retries_total": self.retries_total,
            "fault_recoveries": self.fault_recoveries,
            "faults_injected": (self._faults.fired()
                                if self._faults is not None else {}),
            "recovery": {"count": len(lat), "p50_s": pct(0.50),
                         "p99_s": pct(0.99)},
            "preemptions": self.preemptions,
            "generated_tokens": self.generated_tokens,
            "host_syncs": self.host_syncs,
            "occupancy": self.occupancy(),
            "deadline_hits": self.scheduler.deadline_hit_rates(),
            "speculative": self.speculative_metrics(),
            "restores": self.restores,
            "hang_recoveries": self.hang_recoveries,
            "warm_compile_s": self.warm_compile_s,
            "mesh_devices": self.mesh.size if self.mesh is not None else 1,
        }

    def speculative_metrics(self) -> Dict[str, object]:
        """Speculation accounting: drafted vs accepted proposals overall
        and per SLO class, plus committed tokens per speculative dispatch
        (1 + per-slot acceptance — the quantity that has to beat a plain
        step's guaranteed 1 for drafting to pay). All-zero, same shape,
        on an engine without a draft model."""
        drafted, accepted = self.spec_drafted_tokens, self.spec_accepted_tokens
        return {
            "enabled": self.speculative,
            "rounds": self.spec_rounds,
            "slot_rounds": self.spec_slot_rounds,
            "fallbacks": self.spec_fallbacks,
            "drafted_tokens": drafted,
            "accepted_tokens": accepted,
            "committed_tokens": self.spec_committed_tokens,
            "acceptance_rate": accepted / drafted if drafted else 0.0,
            "committed_per_dispatch": (
                self.spec_committed_tokens / self.spec_slot_rounds
                if self.spec_slot_rounds else 0.0),
            "per_class": {
                p: {"drafted": d, "accepted": a,
                    "rate": a / d if d else 0.0}
                for p, (d, a) in sorted(self._spec_class.items())},
        }

    def _try_preempt(self, slots) -> bool:
        """Scheduler preemption callback: when the best-ranked waiting
        request is blocked on resources, swap out the worst-ranked active
        slot — strictly lower class only (deadlines order service, they
        never justify eviction; equal-class preemption would thrash).
        Mid-prefill slots are not victims: their checkpoint would be pure
        waste (no decode state yet) and they release the pool soonest."""
        if not self._queue or not slots:
            return False
        blocked = min(self._queue, key=request_rank)
        if hasattr(self.backend, "blocks_needed"):
            # feasibility first: eviction only helps if the blocks it can
            # ever recover — the uncommitted free list plus everything
            # held by strictly-lower-class slots — cover the blocked
            # request's worst case. Without this, an oversized (or merely
            # over-contended) request would swap out the whole lower-class
            # active set one host round-trip at a time for nothing.
            # worst-case demand (a shared-prefix admission may need less;
            # the guard then errs toward letting the high-class request
            # wait rather than toward evicting in vain)
            worst = self.backend.blocks_needed(len(blocked.prompt),
                                               blocked.max_new_tokens)
            recoverable = self.backend.available_blocks() + sum(
                self.backend.slot_commitment(s)
                for s, req in slots.items()
                if req.priority < blocked.priority)
            if worst > recoverable:
                return False
        victim = max(slots, key=lambda s: request_rank(slots[s]))
        if slots[victim].priority >= blocked.priority:
            return False
        self.preempt(victim)
        return True

    def _reserve_lookahead(self, slots, k: int) -> None:
        """Top every active slot's cache reservation up to ``pos + k``
        tokens before a decode round: inside a K-scan the host cannot
        intervene, so each append the scan will perform must already have
        an allocated block. The allocator's admission-time commitment
        guarantees the draw succeeds; the new rows replay through the
        ``begin_slots`` seam — every slot that crossed a block boundary in
        this plan lands in *one* coalesced device update (padded to
        ``batch_slots`` by repetition, so it compiles exactly once)
        instead of one small dispatch per crossing slot."""
        ups = []
        for slot, r in slots.items():
            row, covered = self.backend.reserve_lookahead(
                slot, len(r.prompt) + self._scanned[slot] + k)
            if row is not None:
                ups.append((slot, row, covered))
        if not ups:
            return
        self.lookahead_dispatches += 1
        if not hasattr(self, "_begin_many_fn"):
            for slot, row, covered in ups:       # backend without batching
                self._cache_state = self._begin_fn(
                    self._cache_state, jnp.int32(slot), jnp.asarray(row),
                    jnp.int32(covered))
            return
        while len(ups) < self.batch_slots:       # pad by repeating: the
            ups.append(ups[0])                   # duplicate writes agree
        s, rows, cov = zip(*ups)
        self._cache_state = self._begin_many_fn(
            self._cache_state, jnp.asarray(s, jnp.int32),
            jnp.asarray(np.stack(rows)), jnp.asarray(cov, jnp.int32))

    def _decode_round(self, slots, free, done, k: int = 1):
        if not slots:
            return
        self._reserve_lookahead(slots, k)
        if self._faults is not None:
            if self._faults.fire("hang"):
                # a hung dispatch: stall without raising — no exception
                # path ever sees this, only the gateway's wall-clock
                # watchdog around the step (which then escalates through
                # note_hang -> the ordinary rollback/retry ladder)
                time.sleep(self._faults.hang_s)
            # a poisoned dispatch fails at launch, before the donated
            # buffers are touched — device state is intact, which is what
            # lets _recover_decode_fault checkpoint from it (the look-ahead
            # reservation above already landed; rollback returns it through
            # the ordinary free/swap path)
            self._faults.check("scan" if k > 1 else "step",
                               f"decode round over {len(slots)} slots")
        if k == 1:
            self._cache_state, self._state = self._step_fn(
                self.params, self._cache_state, self._state, self._base_key)
        else:
            self._cache_state, self._state = self._scan_fn(
                self.params, self._cache_state, self._state, self._base_key,
                k)
        self.decode_steps += k
        self.host_syncs += 1
        self.planned_token_slots += len(slots) * k
        for slot in slots:
            self._scanned[slot] += k
        if self.speculative:
            # the draft cache saw none of this round's tokens: mark the
            # slots so the next speculative round re-syncs them first
            self._draft_dirty.update(slots.keys())
        self._finish_round(slots, free, done)

    def _spec_round(self, slots, free, done, k: int):
        """One speculative propose-k/verify round (see ``_spec_impl``).
        The look-ahead reservation covers the worst case — the anchor plus
        all k proposals accepted — so the verify append can never fault
        mid-dispatch; rejected tails were masked out of the cache and cost
        only the token-slots ``occupancy`` charges for them."""
        if self._faults is not None:
            # the draft seam fails the whole speculative dispatch at
            # launch, before any state is touched: step() serves the round
            # through the plain decode path instead (exact either way)
            self._faults.check(
                "draft", f"speculative round over {len(slots)} slots, k={k}")
        self._resync_draft(slots)
        self._reserve_lookahead(slots, k + 1)
        before = dict(self._scanned)
        self._cache_state, self._draft_state, self._state = self._spec_fn(
            self.params, self.draft_params, self._cache_state,
            self._draft_state, self._state, self._base_key, k)
        self.host_syncs += 1
        self.planned_token_slots += len(slots) * (k + 1)
        self.spec_rounds += 1
        steps_h = np.asarray(self._state["steps"])
        accepted_total = 0
        for slot, r in slots.items():
            committed = int(steps_h[slot]) - before[slot]
            self._scanned[slot] = int(steps_h[slot])
            self.decode_steps += committed
            self.spec_slot_rounds += 1
            self.spec_drafted_tokens += k
            self.spec_committed_tokens += committed
            acc = max(0, committed - 1)   # anchor token is never "accepted"
            self.spec_accepted_tokens += acc
            accepted_total += acc
            d, a = self._spec_class.get(r.priority, (0, 0))
            self._spec_class[r.priority] = (d + k, a + acc)
        self.scheduler.observe_speculation(len(slots), len(slots) * k,
                                           accepted_total)
        self._finish_round(slots, free, done, steps_h=steps_h)

    def _resync_draft(self, slots) -> None:
        """Rebuild the draft cache for slots that advanced through plain
        decode rounds (the draft saw none of those tokens): one bucketed
        draft prefill of prompt + generated per dirty slot. Transitions
        are rare — a burst of prefill work collapses speculation for its
        duration, then each affected slot pays this once."""
        dirty = [s for s in slots if s in self._draft_dirty]
        if not dirty:
            return
        steps_h = np.asarray(self._state["steps"])
        out_h = np.asarray(self._state["out"])
        for slot in dirty:
            r = slots[slot]
            n = int(steps_h[slot])
            self._draft_fill(slot, np.concatenate(
                [r.prompt, out_h[slot, :n]]).astype(np.int32))

    def _draft_fill(self, slot: int, tokens_1d: np.ndarray) -> None:
        """Prefill the draft cache for ``slot`` with its full visible
        stream (prompt, plus generated tokens on resume / re-sync),
        bucketed like target prefill so the retrace set stays
        ``|buckets|``."""
        length = len(tokens_1d)
        bucket = bucket_for(length, self.buckets)
        tokens = np.zeros((1, bucket), np.int32)
        tokens[0, :length] = tokens_1d
        self._draft_state = self._draft_fill_fn(
            self.draft_params, self._draft_state, jnp.asarray(tokens),
            jnp.int32(length), jnp.int32(slot))
        self._draft_dirty.discard(slot)

    def _finish_round(self, slots, free, done, steps_h=None, out_h=None):
        """Post-dispatch bookkeeping shared by plain and speculative
        decode rounds: TTFT stamps, the stream tap, completion handling.
        Device reads are whole-array pulls sliced host-side — an eager
        per-completion ``state["out"][slot, :n]`` compiles a fresh tiny
        executable per (slot, n) shape, which is exactly the cold-probe
        capacity cost the open-loop bench used to dodge with a throwaway
        warm pass."""
        active = np.asarray(self._state["active"])       # the one host sync
        now = time.perf_counter()
        for r in slots.values():
            # every budget>0 member banked >= 1 token in the round above;
            # budget-0 requests never produce one and get no TTFT
            if r.ttft_s == 0.0 and r.max_new_tokens > 0:
                r.ttft_s = now - r.submit_s
        finished = [s for s in slots if not active[s]]
        if self.on_tokens is not None or finished:
            if steps_h is None:
                steps_h = np.asarray(self._state["steps"])
            if out_h is None:
                out_h = np.asarray(self._state["out"])
        if self.on_tokens is not None:
            # stream tap: surface this round's new tokens per live request
            # (the host sync above already landed, so the arrays are final
            # for the round; a row that finished mid-round stopped at its
            # true step count). Rides the same sync — no extra round-trip
            # boundary, just two host pulls the gateway opted into.
            events = []
            for slot, r in slots.items():
                n = int(steps_h[slot])
                seen = self._emitted.get(r.request_id, 0)
                if n > seen:
                    events.append((r.request_id,
                                   np.array(out_h[slot, seen:n])))
                    self._emitted[r.request_id] = n
            if events:
                self.on_tokens(events)
        for slot in finished:
            r = slots.pop(slot)
            self._scanned.pop(slot, None)
            self._emitted.pop(r.request_id, None)
            if self.speculative:
                self._draft_dirty.discard(slot)
            n = int(steps_h[slot])
            r.output = np.array(out_h[slot, :n])
            r.status = "done"
            r.finish_s = time.perf_counter()
            r.latency_s = r.finish_s - r.submit_s
            self.generated_tokens += n
            self._status_counts["done"] += 1
            self.scheduler.observe_service(r.priority,
                                           r.finish_s - r.admit_s)
            if r.deadline_s is not None:
                self.scheduler.observe_deadline(
                    r.priority, r.latency_s <= r.deadline_s)
            self._cache_state = self.backend.free_slot(self._cache_state,
                                                       slot)
            free.append(slot)
            done[r.request_id] = r

    # -- stats ----------------------------------------------------------------
    def occupancy(self) -> float:
        """Useful tokens per *scheduled* token-slot across executed plans:
        decode rounds schedule ``len(slots) × K`` token-slots (tokens a
        finished-mid-scan row doesn't produce are waste), prompt work
        schedules its padded bucket (pad columns are waste). The old
        ``occupied / (steps × batch_slots)`` denominator charged the engine
        for slots the workload (or a block-starved pool) could never fill —
        paged runs with a widened slot range misreported badly. Exact once
        the engine has drained (in-flight tokens count only at
        completion)."""
        useful = self.generated_tokens + self.useful_prefill_tokens
        return useful / max(self.planned_token_slots, 1)

    def hbm_bytes(self) -> int:
        """Device-resident KV-cache footprint of this engine (draft-model
        cache included when speculation is on — its ring lines are real
        HBM the operator pays for)."""
        total = self.backend.hbm_bytes()
        if self.speculative:
            total += self._draft_backend.hbm_bytes()
        return total

    def hbm_bytes_per_device(self) -> int:
        """Per-device KV footprint: on a mesh the pools split their KV-head
        dim ``kv_shards`` ways, so each device pays ``1/kv_shards`` of the
        K/V bytes (position slots and tables replicate). Equals
        ``hbm_bytes()`` without a mesh — and it's the quantity the
        ``sharded_decode`` bench holds fixed while scaling slots."""
        total = self.backend.hbm_bytes_per_device()
        if self.speculative:
            total += self._draft_backend.hbm_bytes_per_device()
        return total

    def assert_invariants(self) -> None:
        """Engine-level invariant sweep (tests call this mid-traffic):
        backend allocator accounting — extended to the live device pool,
        so per-shard byte conservation is checked against the host-global
        ledger — plus, on a mesh, placement coherence of the whole cache
        state (every leaf carries exactly the prescribed sharding, one
        equal-size shard per device)."""
        if hasattr(self.backend, "assert_invariants"):
            self.backend.assert_invariants(self._cache_state)
        if self.mesh is not None:
            assert_cache_placement(self.mesh, self._cache_state)
            if self.speculative:
                assert_cache_placement(self.mesh, self._draft_state)

    # -- durability -----------------------------------------------------------
    def note_hang(self) -> None:
        """Watchdog escalation: a dispatch exceeded its wall-clock deadline
        and the grace wait also expired-or-recovered-late. The stall raised
        nothing, so no exception path ran — synthesize the same recovery
        the raising seams get: roll every active slot back to its host
        checkpoint and requeue through the retry/backoff ladder. If the
        stalled dispatch did eventually land, the rollback discards real
        work, but the checkpoint (tokens + ``last`` logits + step counter)
        makes the resumed stream token-exact either way — wasted compute,
        never wrong tokens."""
        self.hang_recoveries += 1
        self._recover_decode_fault("hang")

    def _live_requests(self) -> List[Request]:
        """Every non-terminal request the engine owns, de-duplicated:
        queued (preempted/resuming included), mid-prefill, mid-decode."""
        live = list(self._queue)
        live.extend(pp.request for pp in self._prefilling.values())
        live.extend(self._slots.values())
        return live

    def known_request_ids(self) -> set:
        """Request ids this engine can account for — live or terminal.
        The gateway's journal replay consults this to decide which logged
        submissions were lost in a crash and must be re-queued."""
        ids = {r.request_id for r in self._live_requests()}
        ids.update(self._done.keys())
        return ids

    def snapshot(self) -> Dict[str, object]:
        """Serialize every request the engine owns — live and terminal —
        into a nested string-keyed dict fit for ``save_snapshot`` (flat
        key-path .npz via ``checkpoint.io``). Non-destructive: device
        state, slots and the block pool are untouched; live decode slots
        are checkpointed exactly the way preemption checkpoints them
        (generated tokens, step counter, ``last`` logits, and — on a
        paged backend — the slot's K/V blocks via ``checkpoint_slot``),
        so ``restore`` on a cold engine resumes token-for-token.

        Wall-clock stamps cross a process boundary, so ages are stored
        relative (``age_s = now - submit_s``) and re-anchored at restore.
        Stream-emission watermarks (``_emitted``) are deliberately *not*
        captured: after a crash-restart the gateway replays each stream
        from token zero."""
        now = time.perf_counter()
        requests: Dict[str, Dict[str, object]] = {}

        def base_meta(r: Request, phase: str, steps: int) -> dict:
            return {"rid": r.request_id, "phase": phase, "steps": steps,
                    "max_new_tokens": r.max_new_tokens,
                    "temperature": r.temperature, "priority": r.priority,
                    "deadline_s": r.deadline_s,
                    "age_s": now - r.submit_s if r.submit_s else 0.0,
                    "ttft_s": r.ttft_s, "preemptions": r.preemptions,
                    "status": r.status, "failure_reason": r.failure_reason,
                    "retries": r.retries, "last_fault": r.last_fault,
                    "downgraded": r.downgraded, "latency_s": r.latency_s}

        def record(r: Request, phase: str, steps: int,
                   tokens: Optional[np.ndarray],
                   last: Optional[np.ndarray], kv) -> None:
            rec: Dict[str, object] = {
                "meta": json_leaf(base_meta(r, phase, steps)),
                "prompt": np.asarray(r.prompt, np.int32)}
            if tokens is not None and len(tokens):
                rec["tokens"] = np.asarray(tokens, np.int32)
            if last is not None:
                rec["last"] = np.asarray(last, np.float32)
            if kv is not None:
                rec["kv"] = {"n_blocks": np.int32(kv["n_blocks"]),
                             "caches": resolve_swap_caches(kv)}
            requests[f"r{r.request_id:08d}"] = rec

        # live decode slots: host-pull the decode checkpoint wholesale
        if self._slots:
            steps_h = np.asarray(self._state["steps"])
            out_h = np.asarray(self._state["out"])
            last_h = np.asarray(self._state["last"])
            can_kv = self._preempt_swap and hasattr(self.backend,
                                                    "checkpoint_slot")
            for slot, r in self._slots.items():
                steps = int(steps_h[slot])
                kv = (self.backend.checkpoint_slot(self._cache_state, slot)
                      if can_kv else None)
                record(r, "live", steps, np.array(out_h[slot, :steps]),
                       np.array(last_h[slot]), kv)
        # mid-prefill and queued: the installed chunks are abandoned (the
        # restored engine re-prefills), but a carried resume checkpoint —
        # preempted or fault-requeued work — is preserved verbatim
        for r in list(self._queue) + [pp.request
                                      for pp in self._prefilling.values()]:
            rs = r.resume
            if rs is not None:
                record(r, "live", rs.steps, rs.tokens, rs.last, rs.kv)
            else:
                record(r, "live", 0, None, None, None)
        for r in self._done.values():
            rec: Dict[str, object] = {
                "meta": json_leaf(base_meta(r, "terminal", 0)),
                "prompt": np.asarray(r.prompt, np.int32)}
            if r.output is not None and len(r.output):
                rec["output"] = np.asarray(r.output, np.int32)
            requests[f"r{r.request_id:08d}"] = rec

        engine_meta = {"kind": type(self).__name__,
                       "backend": type(self.backend).__name__,
                       "next_id": self._next_id,
                       "step_count": self._step_count,
                       "status_counts": dict(self._status_counts),
                       "batch_slots": self.batch_slots,
                       "max_seq_len": self.max_seq_len,
                       "vocab": self.lm.cfg.padded_vocab}
        return {"engine": json_leaf(engine_meta), "requests": requests}

    def restore(self, snap: Dict[str, object]) -> Dict[str, int]:
        """Load a ``snapshot`` into this (cold) engine. Live requests
        re-enter the queue carrying their decode checkpoint as a
        ``_ResumeState`` — admission then resumes them through the exact
        swap/recompute machinery preemption uses, so survivors continue
        token-for-token (the same construction ``seed`` is required:
        sampling keys fold the base key with ``(rid, steps)``). A K/V
        checkpoint is kept only when this engine's backend can swap it
        back in; otherwise it is dropped and the recompute path rebuilds
        the cache from the host token stream — still exact. Terminal
        requests land straight in the done map so results survive the
        restart. Scheduler estimates are reset: pre-crash service-rate
        and deadline-hit history describes a process that no longer
        exists."""
        if self._slots or self._prefilling or self._queue or self._done:
            raise RuntimeError("restore() needs a cold engine: this one "
                               "already owns requests")
        eng = json_unleaf(snap["engine"])
        if eng.get("vocab") != self.lm.cfg.padded_vocab:
            raise ValueError(
                f"snapshot vocab {eng.get('vocab')} != engine vocab "
                f"{self.lm.cfg.padded_vocab}: the saved logits checkpoints "
                f"cannot be restored into this model")
        if eng.get("max_seq_len") != self.max_seq_len:
            raise ValueError(
                f"snapshot max_seq_len {eng.get('max_seq_len')} != engine "
                f"max_seq_len {self.max_seq_len}")
        now = time.perf_counter()
        can_kv = hasattr(self.backend, "swap_in")
        kv_template = (self._cache_state.get("caches")
                       if can_kv and isinstance(self._cache_state, dict)
                       else None)
        live = terminal = 0
        for key in sorted(snap["requests"]):
            rec = snap["requests"][key]
            meta = json_unleaf(rec["meta"])
            r = Request(int(meta["rid"]),
                        np.asarray(rec["prompt"], np.int32),
                        int(meta["max_new_tokens"]),
                        float(meta["temperature"]),
                        priority=int(meta["priority"]),
                        deadline_s=meta["deadline_s"])
            r.submit_s = now - float(meta["age_s"])
            r.ttft_s = float(meta["ttft_s"])
            r.preemptions = int(meta["preemptions"])
            r.retries = int(meta["retries"])
            r.last_fault = meta["last_fault"]
            r.downgraded = bool(meta["downgraded"])
            if meta["phase"] == "terminal":
                r.status = meta["status"]
                r.failure_reason = meta["failure_reason"]
                r.latency_s = float(meta["latency_s"])
                r.finish_s = now
                out = rec.get("output")
                r.output = (np.asarray(out, np.int32) if out is not None
                            else np.zeros((0,), np.int32))
                self._done[r.request_id] = r
                terminal += 1
                continue
            steps = int(meta["steps"])
            if steps > 0:
                kv = None
                if can_kv and "kv" in rec and kv_template is not None:
                    kv = {"n_blocks": int(np.asarray(
                              rec["kv"]["n_blocks"])),
                          "caches": _rebuild_like(kv_template,
                                                  rec["kv"]["caches"])}
                tokens = rec.get("tokens")
                r.resume = _ResumeState(
                    steps=steps,
                    tokens=(np.asarray(tokens, np.int32)
                            if tokens is not None
                            else np.zeros((0,), np.int32)),
                    last=np.asarray(rec["last"], np.float32),
                    kv=kv)
            r.enqueue_s = now
            self._queue.append(r)
            live += 1
        self._queue.sort(key=request_rank)
        self._next_id = max(self._next_id, int(eng["next_id"]))
        self._step_count = max(self._step_count, int(eng["step_count"]))
        self._status_counts.update(eng["status_counts"])
        self.scheduler.reset_estimates()
        self.restores += 1
        return {"live": live, "terminal": terminal}

    def requeue_lost(self, request_id: int, prompt: np.ndarray,
                     max_new_tokens: int = 16, temperature: float = 0.0,
                     priority: int = 0,
                     deadline_s: Optional[float] = None) -> Request:
        """Journal replay: re-queue a submission the crash lost (it was
        acknowledged but appears in no snapshot), under its *original*
        request id so the client's handle and the journal's terminal
        record still line up. Generation starts over from the prompt —
        nothing survived to resume from."""
        prompt = validate_prompt(prompt, max_new_tokens, self.max_seq_len,
                                 self.truncate_prompts)
        r = Request(int(request_id), prompt, max_new_tokens, temperature,
                    priority=priority, deadline_s=deadline_s)
        r.submit_s = time.perf_counter()
        r.enqueue_s = r.submit_s
        self._next_id = max(self._next_id, int(request_id) + 1)
        self._queue.append(r)
        return r


def _rebuild_like(template, loaded):
    """Rebuild ``loaded`` (nested string-keyed dicts from
    ``load_checkpoint_tree``) into the pytree *structure* of ``template``.
    ``flat_paths`` spells a list index and a same-named dict key
    identically ("caches/0/..."), so matching the flat paths and
    unflattening against the template's treedef recovers the original
    container types — which the jitted swap-in scatter was traced
    against."""
    tpl = flat_paths(template)
    got = flat_paths(loaded)
    missing = set(tpl) - set(got)
    if missing:
        raise ValueError(f"snapshot K/V missing paths: "
                         f"{sorted(missing)[:5]}")
    return jax.tree.unflatten(jax.tree.structure(template),
                              [got[k] for k in tpl])


def save_snapshot(directory: str, snapshot: Dict[str, object],
                  step: int = 0, keep: int = 3) -> str:
    """Persist an engine snapshot through the checkpoint envelope (atomic
    rename, bounded retention)."""
    return save_checkpoint(directory, step, snapshot, keep=keep)


def load_snapshot(directory: str, step: Optional[int] = None):
    """Load a persisted engine snapshot (template-free): returns
    ``(snapshot_tree, step)``."""
    return load_checkpoint_tree(directory, step)


class DrainBatchEngine:
    """The previous static batcher, kept as the measured baseline: drain the
    queue in fixed batches padded to the longest prompt (one prefill compile
    per distinct length), decode everyone for the longest budget, and sample
    on the host every token."""

    def __init__(self, lm: LM, params, *, batch_slots: int = 8,
                 max_seq_len: int = 512, seed: int = 0,
                 truncate_prompts: bool = False):
        if lm.cfg.frontend.kind == "audio":
            raise NotImplementedError("engine serves text-token streams")
        self.lm = lm
        self.params = params
        self.batch_slots = batch_slots
        self.max_seq_len = max_seq_len
        self.truncate_prompts = truncate_prompts
        self.rng = jax.random.PRNGKey(seed)
        self._queue: List[Request] = []
        self._next_id = 0
        self.generated_tokens = 0
        self.host_syncs = 0     # one logits round-trip per decoded token

        windowed = _has_windowed_blocks(lm)

        def prefill(params, batch, lengths):
            # lengths matter only when a window-wide cache could keep pad
            # rows of the batch's longest-prompt padding (see _admit_impl)
            return lm.prefill(params, batch, cache_width=max_seq_len,
                              lengths=lengths if windowed else None)

        self.prefill_fn = jax.jit(prefill)
        self.decode_fn = jax.jit(lm.decode_step)

    def submit(self, prompt: np.ndarray, max_new_tokens: int = 16,
               temperature: float = 0.0, priority: int = 0,
               deadline_s: Optional[float] = None) -> int:
        """Queue a request. ``priority``/``deadline_s`` are recorded for
        per-class reporting but the drain batcher stays strictly FIFO —
        it is the measured baseline, not an SLO policy."""
        prompt = validate_prompt(prompt, max_new_tokens, self.max_seq_len,
                                 self.truncate_prompts)
        rid = self._next_id
        self._next_id += 1
        r = Request(rid, prompt, max_new_tokens, temperature,
                    priority=priority, deadline_s=deadline_s)
        r.submit_s = time.perf_counter()
        self._queue.append(r)
        return rid

    def run(self) -> Dict[int, Request]:
        done: Dict[int, Request] = {}
        while self._queue:
            batch = self._queue[:self.batch_slots]
            self._queue = self._queue[self.batch_slots:]
            self._serve_batch(batch)
            for r in batch:
                done[r.request_id] = r
        return done

    def _serve_batch(self, requests: List[Request]) -> None:
        b = self.batch_slots
        admit = time.perf_counter()          # batch enters service together
        for r in requests:
            r.admit_s = admit
        plen = max(len(r.prompt) for r in requests)
        lens = np.array([len(r.prompt) for r in requests]
                        + [plen] * (b - len(requests)), np.int32)
        tokens = np.zeros((b, plen), np.int32)
        for i, r in enumerate(requests):
            tokens[i, :len(r.prompt)] = r.prompt         # right-pad (exact)
        logits, caches = self.prefill_fn(self.params,
                                         {"tokens": jnp.asarray(tokens)},
                                         jnp.asarray(lens))
        last = jnp.take_along_axis(
            logits, jnp.asarray(lens)[:, None, None] - 1, axis=1)[:, 0, :]
        max_new = max(r.max_new_tokens for r in requests)
        outs = np.zeros((b, max_new), np.int32)
        pos = jnp.asarray(lens)
        temp = jnp.asarray([r.temperature for r in requests]
                           + [0.0] * (b - len(requests)), jnp.float32)
        for t in range(max_new):
            self.rng, k = jax.random.split(self.rng)
            nxt = sample_logits_batch(k, last, temp)
            outs[:, t] = np.asarray(nxt)[:b]             # per-token host trip
            self.host_syncs += 1
            if t == 0:
                first = time.perf_counter()
                for r in requests:
                    r.ttft_s = first - r.submit_s
            logits1, caches = self.decode_fn(self.params, caches,
                                             nxt[:, None], pos)
            pos = pos + 1
            last = logits1[:, 0, :]
        finish = time.perf_counter()
        for i, r in enumerate(requests):
            r.output = outs[i, :r.max_new_tokens]
            r.status = "done"
            r.finish_s = finish
            r.latency_s = finish - r.submit_s
            self.generated_tokens += r.max_new_tokens
