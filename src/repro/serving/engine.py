"""Serving engines: continuous batching over per-slot request state.

``ServingEngine`` is the production path. It owns a fixed pool of
``batch_slots`` decode slots sharing one device-resident KV cache; requests
are admitted into free slots as others finish (continuous batching), so a
long generation never stalls the short ones behind it. Prompt lengths are
bucketed to a small set of power-of-two shapes, bounding prefill
recompilation to ``len(buckets)`` variants regardless of traffic. The decode
inner step is one fused jitted call — sample → cache-append →
done-detection all on device — and the Python loop performs a single small
host sync per step (the (B,) active mask) for EOS/slot management; logits
never leave the device.

Prompts are right-padded to their bucket. With the ring cache this is
*exact*: pad entries sit at positions ≥ the prompt length, causal masking
hides them until the decode stream overwrites their ring slot at that same
position, so bucketing never changes a single output token.

The KV cache itself is pluggable (``repro.serving.kv_cache``): admission
grants a slot *plus* whatever device memory the backend needs for it. The
``ring`` backend (default) pins a ``max_seq_len`` cache line per slot; the
``paged`` backend reserves ``ceil((prompt + budget) / block_size)`` pool
blocks per request and returns them at completion, so concurrency is
bounded by live tokens rather than worst-case sequence length.

``DrainBatchEngine`` preserves the previous drain-the-queue batcher (pad
the batch to its longest prompt, run everyone for the longest budget,
round-trip logits to the host each token) as the measured baseline for
``benchmarks/bench_serving.py``.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import LM
from repro.serving.kv_cache import make_backend
from repro.serving.sampler import sample_logits, sample_logits_batch


@dataclasses.dataclass
class Request:
    request_id: int
    prompt: np.ndarray           # (S_prompt,) int32
    max_new_tokens: int = 16
    temperature: float = 0.0
    output: Optional[np.ndarray] = None
    submit_s: float = 0.0        # wall-clock at submit()
    admit_s: float = 0.0         # wall-clock when a slot was granted
    finish_s: float = 0.0        # wall-clock at completion
    latency_s: float = 0.0       # finish - submit (queue + service)


def prompt_buckets(max_seq_len: int, min_bucket: int = 16) -> List[int]:
    """Power-of-two prefill shapes: [min_bucket, ..., max_seq_len]."""
    buckets = []
    b = min_bucket
    while b < max_seq_len:
        buckets.append(b)
        b *= 2
    buckets.append(max_seq_len)
    return buckets


def bucket_for(n: int, buckets: List[int]) -> int:
    for b in buckets:
        if n <= b:
            return b
    raise ValueError(
        f"prompt length {n} exceeds the largest prefill bucket "
        f"{buckets[-1]} (= max_seq_len); engines validate this at submit() "
        f"— either raise max_seq_len or submit with truncation enabled")


def validate_prompt(prompt: np.ndarray, max_new_tokens: int,
                    max_seq_len: int, truncate: bool) -> np.ndarray:
    """Shared submit-time guard: prompt + budget must fit the cache.

    Historically an over-long prompt fell into the top bucket and silently
    relied on ring wraparound (the oldest tokens were overwritten mid-
    prefill — wrong outputs, no error). Now the engines either raise here
    with an actionable message or, when ``truncate`` is set, explicitly keep
    the trailing ``max_seq_len - max_new_tokens`` prompt tokens."""
    prompt = np.asarray(prompt, np.int32)
    assert prompt.ndim == 1
    room = max_seq_len - max_new_tokens
    if room <= 0:
        raise ValueError(
            f"max_new_tokens ({max_new_tokens}) leaves no room for a prompt "
            f"within max_seq_len ({max_seq_len})")
    if len(prompt) > room:
        if not truncate:
            raise ValueError(
                f"prompt ({len(prompt)}) + max_new_tokens ({max_new_tokens})"
                f" exceeds max_seq_len ({max_seq_len}); the output buffer"
                f" and cache are sized for max_seq_len — shorten the prompt,"
                f" raise max_seq_len, or construct the engine with"
                f" truncate_prompts=True to keep the prompt tail")
        prompt = prompt[-room:]
    return prompt


class ServingEngine:
    """Continuous-batching autoregressive serving."""

    def __init__(self, lm: LM, params, *, batch_slots: int = 8,
                 max_seq_len: int = 512, seed: int = 0,
                 eos_id: Optional[int] = None, min_bucket: int = 16,
                 cache_backend="ring", block_size: int = 16,
                 num_pool_blocks: Optional[int] = None,
                 truncate_prompts: bool = False):
        if lm.cfg.frontend.kind == "audio":
            raise NotImplementedError("engine serves text-token streams")
        self.lm = lm
        self.params = params
        self.batch_slots = batch_slots
        self.max_seq_len = max_seq_len
        self.eos_id = eos_id
        self.truncate_prompts = truncate_prompts
        self.buckets = prompt_buckets(max_seq_len, min_bucket)
        self._queue: List[Request] = []
        self._next_id = 0
        self._rng = jax.random.PRNGKey(seed)
        # perf counters (slot occupancy for bench_serving)
        self.decode_steps = 0
        self.occupied_slot_steps = 0
        self.generated_tokens = 0
        self.peak_active_slots = 0

        self.backend = make_backend(
            cache_backend, lm, params, batch_slots=batch_slots,
            max_seq_len=max_seq_len, proto_len=self.buckets[0],
            block_size=block_size, num_blocks=num_pool_blocks)
        self._cache_state = self.backend.init()
        b, v = batch_slots, lm.cfg.padded_vocab
        self._state = {
            "last": jnp.zeros((b, v), jnp.float32),     # logits to sample next
            "pos": jnp.zeros((b,), jnp.int32),
            "steps": jnp.zeros((b,), jnp.int32),
            "budget": jnp.zeros((b,), jnp.int32),
            "temp": jnp.zeros((b,), jnp.float32),
            "active": jnp.zeros((b,), jnp.bool_),
            "out": jnp.zeros((b, max_seq_len), jnp.int32),
        }
        self._admit_fn = jax.jit(self._admit_impl)      # retraces per bucket
        self._step_fn = jax.jit(self._step_impl)

    # -- queue API ------------------------------------------------------------
    def submit(self, prompt: np.ndarray, max_new_tokens: int = 16,
               temperature: float = 0.0) -> int:
        prompt = validate_prompt(prompt, max_new_tokens, self.max_seq_len,
                                 self.truncate_prompts)
        rid = self._next_id
        self._next_id += 1
        r = Request(rid, prompt, max_new_tokens, temperature)
        r.submit_s = time.perf_counter()
        self._queue.append(r)
        return rid

    def run(self) -> Dict[int, Request]:
        """Serve until the queue and all slots drain."""
        done: Dict[int, Request] = {}
        slots: Dict[int, Request] = {}
        free = list(range(self.batch_slots))
        while self._queue or slots:
            # admit FIFO while a slot AND its cache reservation are available
            while free and self._queue:
                nxt = self._queue[0]
                if not self.backend.can_admit(len(nxt.prompt),
                                              nxt.max_new_tokens):
                    break
                self._admit(self._queue.pop(0), free.pop(), slots)
            if not slots:
                # nothing running and the head of the queue can never fit
                nxt = self._queue[0]
                raise RuntimeError(
                    f"request {nxt.request_id} (prompt {len(nxt.prompt)} + "
                    f"budget {nxt.max_new_tokens}) needs more KV blocks than "
                    f"the whole pool holds; enlarge num_pool_blocks")
            self.peak_active_slots = max(self.peak_active_slots, len(slots))
            self._decode_round(slots, free, done)
        return done

    # -- device-side programs -------------------------------------------------
    def _admit_impl(self, params, cache_state, state, tokens, length, slot,
                    max_new, temp, table_row):
        """Prefill one bucketed prompt and install it into ``slot``."""
        logits, one_caches = self.lm.prefill(
            params, {"tokens": tokens}, cache_width=self.max_seq_len)
        last = jax.lax.dynamic_index_in_dim(logits[0], length - 1, axis=0,
                                            keepdims=False)
        cache_state = self.backend.prefill_fill(cache_state, one_caches,
                                                slot, length, table_row)
        state = dict(state)
        state["last"] = state["last"].at[slot].set(last.astype(jnp.float32))
        state["pos"] = state["pos"].at[slot].set(length)
        state["steps"] = state["steps"].at[slot].set(0)
        state["budget"] = state["budget"].at[slot].set(max_new)
        state["temp"] = state["temp"].at[slot].set(temp)
        state["active"] = state["active"].at[slot].set(max_new > 0)
        return cache_state, state

    def _step_impl(self, params, cache_state, state, rng):
        """Fused decode step: sample → append → done-detect, on device."""
        active = state["active"]
        nxt = sample_logits_batch(rng, state["last"], state["temp"])
        rows = jnp.arange(self.batch_slots)
        idx = jnp.clip(state["steps"], 0, self.max_seq_len - 1)
        out = state["out"].at[rows, idx].set(
            jnp.where(active, nxt, state["out"][rows, idx]))
        steps = state["steps"] + active.astype(jnp.int32)
        feed = jnp.where(active, nxt, 0)[:, None]
        logits, caches = self.lm.decode_step(
            params, cache_state["caches"], feed, state["pos"],
            layout=self.backend.layout,
            block_tables=cache_state["tables"])
        finished = steps >= state["budget"]
        if self.eos_id is not None:
            finished |= nxt == self.eos_id
        state = {
            "last": logits[:, 0, :].astype(jnp.float32),
            "pos": state["pos"] + active.astype(jnp.int32),
            "steps": steps,
            "budget": state["budget"],
            "temp": state["temp"],
            "active": active & ~finished,
            "out": out,
        }
        return {"caches": caches, "tables": cache_state["tables"]}, state

    # -- host-side management -------------------------------------------------
    def _admit(self, r: Request, slot: int, slots: Dict[int, Request]):
        length = len(r.prompt)
        bucket = bucket_for(length, self.buckets)
        tokens = np.zeros((1, bucket), np.int32)
        tokens[0, :length] = r.prompt                    # right-pad (exact)
        table_row = self.backend.alloc_slot(slot, length, r.max_new_tokens)
        self._cache_state, self._state = self._admit_fn(
            self.params, self._cache_state, self._state, jnp.asarray(tokens),
            jnp.int32(length), jnp.int32(slot), jnp.int32(r.max_new_tokens),
            jnp.float32(r.temperature), jnp.asarray(table_row))
        r.admit_s = time.perf_counter()
        slots[slot] = r

    def _decode_round(self, slots, free, done):
        if not slots:
            return
        self._rng, k = jax.random.split(self._rng)
        self._cache_state, self._state = self._step_fn(
            self.params, self._cache_state, self._state, k)
        self.decode_steps += 1
        self.occupied_slot_steps += len(slots)
        active = np.asarray(self._state["active"])       # the one host sync
        for slot in [s for s, _ in slots.items() if not active[s]]:
            r = slots.pop(slot)
            n = int(self._state["steps"][slot])
            r.output = np.asarray(self._state["out"][slot, :n])
            r.finish_s = time.perf_counter()
            r.latency_s = r.finish_s - r.submit_s
            self.generated_tokens += n
            self._cache_state = self.backend.free_slot(self._cache_state,
                                                       slot)
            free.append(slot)
            done[r.request_id] = r

    # -- stats ----------------------------------------------------------------
    def occupancy(self) -> float:
        return self.occupied_slot_steps / max(
            self.decode_steps * self.batch_slots, 1)

    def hbm_bytes(self) -> int:
        """Device-resident KV-cache footprint of this engine."""
        return self.backend.hbm_bytes()


class DrainBatchEngine:
    """The previous static batcher, kept as the measured baseline: drain the
    queue in fixed batches padded to the longest prompt (one prefill compile
    per distinct length), decode everyone for the longest budget, and sample
    on the host every token."""

    def __init__(self, lm: LM, params, *, batch_slots: int = 8,
                 max_seq_len: int = 512, seed: int = 0,
                 truncate_prompts: bool = False):
        if lm.cfg.frontend.kind == "audio":
            raise NotImplementedError("engine serves text-token streams")
        self.lm = lm
        self.params = params
        self.batch_slots = batch_slots
        self.max_seq_len = max_seq_len
        self.truncate_prompts = truncate_prompts
        self.rng = jax.random.PRNGKey(seed)
        self._queue: List[Request] = []
        self._next_id = 0
        self.generated_tokens = 0

        def prefill(params, batch):
            return lm.prefill(params, batch, cache_width=max_seq_len)

        self.prefill_fn = jax.jit(prefill)
        self.decode_fn = jax.jit(lm.decode_step)

    def submit(self, prompt: np.ndarray, max_new_tokens: int = 16,
               temperature: float = 0.0) -> int:
        prompt = validate_prompt(prompt, max_new_tokens, self.max_seq_len,
                                 self.truncate_prompts)
        rid = self._next_id
        self._next_id += 1
        r = Request(rid, prompt, max_new_tokens, temperature)
        r.submit_s = time.perf_counter()
        self._queue.append(r)
        return rid

    def run(self) -> Dict[int, Request]:
        done: Dict[int, Request] = {}
        while self._queue:
            batch = self._queue[:self.batch_slots]
            self._queue = self._queue[self.batch_slots:]
            self._serve_batch(batch)
            for r in batch:
                done[r.request_id] = r
        return done

    def _serve_batch(self, requests: List[Request]) -> None:
        b = self.batch_slots
        plen = max(len(r.prompt) for r in requests)
        lens = np.array([len(r.prompt) for r in requests]
                        + [plen] * (b - len(requests)), np.int32)
        tokens = np.zeros((b, plen), np.int32)
        for i, r in enumerate(requests):
            tokens[i, :len(r.prompt)] = r.prompt         # right-pad (exact)
        logits, caches = self.prefill_fn(self.params,
                                         {"tokens": jnp.asarray(tokens)})
        last = jnp.take_along_axis(
            logits, jnp.asarray(lens)[:, None, None] - 1, axis=1)[:, 0, :]
        max_new = max(r.max_new_tokens for r in requests)
        outs = np.zeros((b, max_new), np.int32)
        pos = jnp.asarray(lens)
        temp = jnp.asarray([r.temperature for r in requests]
                           + [0.0] * (b - len(requests)), jnp.float32)
        for t in range(max_new):
            self.rng, k = jax.random.split(self.rng)
            nxt = sample_logits_batch(k, last, temp)
            outs[:, t] = np.asarray(nxt)[:b]             # per-token host trip
            logits1, caches = self.decode_fn(self.params, caches,
                                             nxt[:, None], pos)
            pos = pos + 1
            last = logits1[:, 0, :]
        finish = time.perf_counter()
        for i, r in enumerate(requests):
            r.output = outs[i, :r.max_new_tokens]
            r.finish_s = finish
            r.latency_s = finish - r.submit_s
            self.generated_tokens += r.max_new_tokens
