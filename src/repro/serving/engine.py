"""Serving engines: continuous batching over per-slot request state.

``ServingEngine`` is the production path. It owns a fixed pool of
``batch_slots`` decode slots sharing one device-resident KV cache; requests
are admitted into free slots as others finish (continuous batching), so a
long generation never stalls the short ones behind it. Prompt lengths are
bucketed to a small set of power-of-two shapes, bounding prefill
recompilation to ``len(buckets)`` variants regardless of traffic. The decode
inner step is one fused jitted call — sample → cache-append →
done-detection all on device — and the Python loop performs a single small
host sync per step (the (B,) active mask) for EOS/slot management; logits
never leave the device.

Prompts are right-padded to their bucket. With the ring cache this is
*exact*: pad entries sit at positions ≥ the prompt length, causal masking
hides them until the decode stream overwrites their ring slot at that same
position, so bucketing never changes a single output token.

``DrainBatchEngine`` preserves the previous drain-the-queue batcher (pad
the batch to its longest prompt, run everyone for the longest budget,
round-trip logits to the host each token) as the measured baseline for
``benchmarks/bench_serving.py``.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import LM
from repro.serving.sampler import sample_logits, sample_logits_batch


@dataclasses.dataclass
class Request:
    request_id: int
    prompt: np.ndarray           # (S_prompt,) int32
    max_new_tokens: int = 16
    temperature: float = 0.0
    output: Optional[np.ndarray] = None
    submit_s: float = 0.0        # wall-clock at submit()
    admit_s: float = 0.0         # wall-clock when a slot was granted
    finish_s: float = 0.0        # wall-clock at completion
    latency_s: float = 0.0       # finish - submit (queue + service)


def prompt_buckets(max_seq_len: int, min_bucket: int = 16) -> List[int]:
    """Power-of-two prefill shapes: [min_bucket, ..., max_seq_len]."""
    buckets = []
    b = min_bucket
    while b < max_seq_len:
        buckets.append(b)
        b *= 2
    buckets.append(max_seq_len)
    return buckets


def bucket_for(n: int, buckets: List[int]) -> int:
    for b in buckets:
        if n <= b:
            return b
    raise ValueError(f"prompt length {n} exceeds the largest bucket "
                     f"{buckets[-1]}")


def _path_endswith(path, name: str) -> bool:
    return len(path) > 0 and getattr(path[-1], "key", None) == name


class ServingEngine:
    """Continuous-batching autoregressive serving."""

    def __init__(self, lm: LM, params, *, batch_slots: int = 8,
                 max_seq_len: int = 512, seed: int = 0,
                 eos_id: Optional[int] = None, min_bucket: int = 16):
        if lm.cfg.frontend.kind == "audio":
            raise NotImplementedError("engine serves text-token streams")
        self.lm = lm
        self.params = params
        self.batch_slots = batch_slots
        self.max_seq_len = max_seq_len
        self.eos_id = eos_id
        self.buckets = prompt_buckets(max_seq_len, min_bucket)
        self._queue: List[Request] = []
        self._next_id = 0
        self._rng = jax.random.PRNGKey(seed)
        # perf counters (slot occupancy for bench_serving)
        self.decode_steps = 0
        self.occupied_slot_steps = 0
        self.generated_tokens = 0

        b, v = batch_slots, lm.cfg.padded_vocab
        self._caches = self._empty_caches()
        self._state = {
            "last": jnp.zeros((b, v), jnp.float32),     # logits to sample next
            "pos": jnp.zeros((b,), jnp.int32),
            "steps": jnp.zeros((b,), jnp.int32),
            "budget": jnp.zeros((b,), jnp.int32),
            "temp": jnp.zeros((b,), jnp.float32),
            "active": jnp.zeros((b,), jnp.bool_),
            "out": jnp.zeros((b, max_seq_len), jnp.int32),
        }
        self._admit_fn = jax.jit(self._admit_impl)      # retraces per bucket
        self._step_fn = jax.jit(self._step_impl)

    # -- queue API ------------------------------------------------------------
    def submit(self, prompt: np.ndarray, max_new_tokens: int = 16,
               temperature: float = 0.0) -> int:
        prompt = np.asarray(prompt, np.int32)
        assert prompt.ndim == 1
        if len(prompt) + max_new_tokens > self.max_seq_len:
            raise ValueError(
                f"prompt ({len(prompt)}) + max_new_tokens ({max_new_tokens})"
                f" exceeds max_seq_len ({self.max_seq_len}); the output"
                f" buffer and cache are sized for max_seq_len")
        rid = self._next_id
        self._next_id += 1
        r = Request(rid, prompt, max_new_tokens, temperature)
        r.submit_s = time.perf_counter()
        self._queue.append(r)
        return rid

    def run(self) -> Dict[int, Request]:
        """Serve until the queue and all slots drain."""
        done: Dict[int, Request] = {}
        slots: Dict[int, Request] = {}
        free = list(range(self.batch_slots))
        while self._queue or slots:
            while free and self._queue:
                self._admit(self._queue.pop(0), free.pop(), slots)
            self._decode_round(slots, free, done)
        return done

    # -- device-side programs -------------------------------------------------
    def _admit_impl(self, params, caches, state, tokens, length, slot,
                    max_new, temp):
        """Prefill one bucketed prompt and install it into ``slot``."""
        logits, one_caches = self.lm.prefill(
            params, {"tokens": tokens}, cache_width=self.max_seq_len)
        last = jax.lax.dynamic_index_in_dim(logits[0], length - 1, axis=0,
                                            keepdims=False)
        caches = jax.tree.map(
            lambda g, c: jax.lax.dynamic_update_index_in_dim(
                g, c[:, 0], slot, axis=1),
            caches, one_caches)
        state = dict(state)
        state["last"] = state["last"].at[slot].set(last.astype(jnp.float32))
        state["pos"] = state["pos"].at[slot].set(length)
        state["steps"] = state["steps"].at[slot].set(0)
        state["budget"] = state["budget"].at[slot].set(max_new)
        state["temp"] = state["temp"].at[slot].set(temp)
        state["active"] = state["active"].at[slot].set(max_new > 0)
        return caches, state

    def _step_impl(self, params, caches, state, rng):
        """Fused decode step: sample → append → done-detect, on device."""
        active = state["active"]
        nxt = sample_logits_batch(rng, state["last"], state["temp"])
        rows = jnp.arange(self.batch_slots)
        idx = jnp.clip(state["steps"], 0, self.max_seq_len - 1)
        out = state["out"].at[rows, idx].set(
            jnp.where(active, nxt, state["out"][rows, idx]))
        steps = state["steps"] + active.astype(jnp.int32)
        feed = jnp.where(active, nxt, 0)[:, None]
        logits, caches = self.lm.decode_step(params, caches, feed,
                                             state["pos"])
        finished = steps >= state["budget"]
        if self.eos_id is not None:
            finished |= nxt == self.eos_id
        state = {
            "last": logits[:, 0, :].astype(jnp.float32),
            "pos": state["pos"] + active.astype(jnp.int32),
            "steps": steps,
            "budget": state["budget"],
            "temp": state["temp"],
            "active": active & ~finished,
            "out": out,
        }
        return caches, state

    # -- host-side management -------------------------------------------------
    def _admit(self, r: Request, slot: int, slots: Dict[int, Request]):
        length = len(r.prompt)
        bucket = bucket_for(length, self.buckets)
        tokens = np.zeros((1, bucket), np.int32)
        tokens[0, :length] = r.prompt                    # right-pad (exact)
        self._caches, self._state = self._admit_fn(
            self.params, self._caches, self._state, jnp.asarray(tokens),
            jnp.int32(length), jnp.int32(slot), jnp.int32(r.max_new_tokens),
            jnp.float32(r.temperature))
        r.admit_s = time.perf_counter()
        slots[slot] = r

    def _decode_round(self, slots, free, done):
        if not slots:
            return
        self._rng, k = jax.random.split(self._rng)
        self._caches, self._state = self._step_fn(
            self.params, self._caches, self._state, k)
        self.decode_steps += 1
        self.occupied_slot_steps += len(slots)
        active = np.asarray(self._state["active"])       # the one host sync
        for slot in [s for s, _ in slots.items() if not active[s]]:
            r = slots.pop(slot)
            n = int(self._state["steps"][slot])
            r.output = np.asarray(self._state["out"][slot, :n])
            r.finish_s = time.perf_counter()
            r.latency_s = r.finish_s - r.submit_s
            self.generated_tokens += n
            free.append(slot)
            done[r.request_id] = r

    def _empty_caches(self):
        """A batch_slots-wide cache pytree structurally identical to what
        ``prefill`` returns (so admission can tree.map-scatter into it)."""
        proto = jax.eval_shape(
            lambda p, t: self.lm.prefill(p, {"tokens": t},
                                         cache_width=self.max_seq_len)[1],
            self.params,
            jax.ShapeDtypeStruct((1, self.buckets[0]), jnp.int32))
        b = self.batch_slots

        def leaf(path, a):
            shape = (a.shape[0], b) + a.shape[2:]
            if _path_endswith(path, "pos"):
                return jnp.full(shape, -1, a.dtype)      # -1 = empty slot
            return jnp.zeros(shape, a.dtype)

        return jax.tree_util.tree_map_with_path(leaf, proto)

    # -- stats ----------------------------------------------------------------
    def occupancy(self) -> float:
        return self.occupied_slot_steps / max(
            self.decode_steps * self.batch_slots, 1)


class DrainBatchEngine:
    """The previous static batcher, kept as the measured baseline: drain the
    queue in fixed batches padded to the longest prompt (one prefill compile
    per distinct length), decode everyone for the longest budget, and sample
    on the host every token."""

    def __init__(self, lm: LM, params, *, batch_slots: int = 8,
                 max_seq_len: int = 512, seed: int = 0):
        if lm.cfg.frontend.kind == "audio":
            raise NotImplementedError("engine serves text-token streams")
        self.lm = lm
        self.params = params
        self.batch_slots = batch_slots
        self.max_seq_len = max_seq_len
        self.rng = jax.random.PRNGKey(seed)
        self._queue: List[Request] = []
        self._next_id = 0
        self.generated_tokens = 0

        def prefill(params, batch):
            return lm.prefill(params, batch, cache_width=max_seq_len)

        self.prefill_fn = jax.jit(prefill)
        self.decode_fn = jax.jit(lm.decode_step)

    def submit(self, prompt: np.ndarray, max_new_tokens: int = 16,
               temperature: float = 0.0) -> int:
        rid = self._next_id
        self._next_id += 1
        r = Request(rid, np.asarray(prompt, np.int32), max_new_tokens,
                    temperature)
        r.submit_s = time.perf_counter()
        self._queue.append(r)
        return rid

    def run(self) -> Dict[int, Request]:
        done: Dict[int, Request] = {}
        while self._queue:
            batch = self._queue[:self.batch_slots]
            self._queue = self._queue[self.batch_slots:]
            self._serve_batch(batch)
            for r in batch:
                done[r.request_id] = r
        return done

    def _serve_batch(self, requests: List[Request]) -> None:
        b = self.batch_slots
        plen = max(len(r.prompt) for r in requests)
        lens = np.array([len(r.prompt) for r in requests]
                        + [plen] * (b - len(requests)), np.int32)
        tokens = np.zeros((b, plen), np.int32)
        for i, r in enumerate(requests):
            tokens[i, :len(r.prompt)] = r.prompt         # right-pad (exact)
        logits, caches = self.prefill_fn(self.params,
                                         {"tokens": jnp.asarray(tokens)})
        last = jnp.take_along_axis(
            logits, jnp.asarray(lens)[:, None, None] - 1, axis=1)[:, 0, :]
        max_new = max(r.max_new_tokens for r in requests)
        outs = np.zeros((b, max_new), np.int32)
        pos = jnp.asarray(lens)
        temp = jnp.asarray([r.temperature for r in requests]
                           + [0.0] * (b - len(requests)), jnp.float32)
        for t in range(max_new):
            self.rng, k = jax.random.split(self.rng)
            nxt = sample_logits_batch(k, last, temp)
            outs[:, t] = np.asarray(nxt)[:b]             # per-token host trip
            logits1, caches = self.decode_fn(self.params, caches,
                                             nxt[:, None], pos)
            pos = pos + 1
            last = logits1[:, 0, :]
        finish = time.perf_counter()
        for i, r in enumerate(requests):
            r.output = outs[i, :r.max_new_tokens]
            r.finish_s = finish
            r.latency_s = finish - r.submit_s
            self.generated_tokens += r.max_new_tokens
