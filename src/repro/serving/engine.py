"""Serving engine: static-slot batched prefill + decode with KV caches.

The engine owns the jitted ``prefill`` and ``decode_step`` callables (the
latter is what the dry-run lowers for the decode shapes) and a simple
request queue filled into fixed batch slots — the deployment-grade pattern
(static shapes, no per-request recompilation).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import LM
from repro.serving.sampler import sample_logits


@dataclasses.dataclass
class Request:
    request_id: int
    prompt: np.ndarray           # (S_prompt,) int32
    max_new_tokens: int = 16
    temperature: float = 0.0
    output: Optional[np.ndarray] = None
    latency_s: float = 0.0


class ServingEngine:
    def __init__(self, lm: LM, params, *, batch_slots: int = 8,
                 max_seq_len: int = 512, seed: int = 0):
        self.lm = lm
        self.params = params
        self.batch_slots = batch_slots
        self.max_seq_len = max_seq_len
        self.rng = jax.random.PRNGKey(seed)
        self._queue: List[Request] = []
        self._next_id = 0

        def prefill(params, batch):
            return lm.prefill(params, batch, cache_width=max_seq_len)

        def decode(params, caches, tokens, cur_pos):
            return lm.decode_step(params, caches, tokens, cur_pos)

        self.prefill_fn = jax.jit(prefill)
        self.decode_fn = jax.jit(decode)

    # -- queue API --------------------------------------------------------------
    def submit(self, prompt: np.ndarray, max_new_tokens: int = 16,
               temperature: float = 0.0) -> int:
        rid = self._next_id
        self._next_id += 1
        self._queue.append(Request(rid, np.asarray(prompt, np.int32),
                                   max_new_tokens, temperature))
        return rid

    def run(self) -> Dict[int, Request]:
        """Drain the queue in batches of ``batch_slots``."""
        done: Dict[int, Request] = {}
        while self._queue:
            batch = self._queue[:self.batch_slots]
            self._queue = self._queue[self.batch_slots:]
            self._serve_batch(batch)
            for r in batch:
                done[r.request_id] = r
        return done

    # -- internals ----------------------------------------------------------------
    def _serve_batch(self, requests: List[Request]) -> None:
        t0 = time.time()
        b = self.batch_slots
        plen = max(len(r.prompt) for r in requests)
        tokens = np.zeros((b, plen), np.int32)
        for i, r in enumerate(requests):
            tokens[i, plen - len(r.prompt):] = r.prompt   # left-pad
        logits, caches = self.prefill_fn(self.params, {"tokens": jnp.asarray(tokens)})
        last = logits[:, -1, :]
        max_new = max(r.max_new_tokens for r in requests)
        outs = np.zeros((b, max_new), np.int32)
        temp = requests[0].temperature
        for t in range(max_new):
            self.rng, k = jax.random.split(self.rng)
            nxt = sample_logits(k, last, temperature=temp)
            outs[:, t] = np.asarray(nxt)[:b]
            step_tokens = jnp.asarray(nxt)[:, None]
            logits1, caches = self.decode_fn(self.params, caches, step_tokens,
                                             jnp.int32(plen + t))
            last = logits1[:, 0, :]
        dt = time.time() - t0
        for i, r in enumerate(requests):
            r.output = outs[i, :r.max_new_tokens]
            r.latency_s = dt
