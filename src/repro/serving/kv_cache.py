"""Pluggable KV-cache backends for the serving engines.

The cache seam has two levels, both defined here:

**Layouts** (``RingLayout`` / ``PagedLayout``) are stateless, hashable
objects the *model* programs against: ``append`` writes one decode step's
K/V (or MLA latents) into a layer's cache arrays, ``attend`` runs
single-token GQA attention over them, and ``context`` materializes a
per-slot contiguous view for mixers that attend in plain jnp (MLA's
absorbed form). ``attn_decode`` / ``mla_decode`` / ``LM.decode_step`` take a
layout plus an optional ``block_tables`` array and never touch cache-dict
internals directly.

**Backends** (``RingCache`` / ``PagedCache``) are what the *engine* owns:
device cache state, slot admission (``alloc_slot`` → ``prefill_fill``),
completion (``free_slot``) and accounting (``hbm_bytes``). ``RingCache`` is
the original behavior extracted: every slot pins a ``max_seq_len``-wide
ring, so HBM per slot is worst-case. ``PagedCache`` is vLLM-style: one
global pool of fixed-size blocks per layer plus a per-slot block table,
with a host-side free-block allocator — admission *commits* to the
worst-case ``ceil((prompt + budget) / block_size)`` blocks (so decode can
never starve mid-flight: the commitment ledger guarantees every look-ahead
top-up succeeds) but physically allocates lazily: blocks covering the
prompt at admission, then ``reserve_lookahead`` tops the slot's table up
to ``pos + K`` tokens before each K-step decode scan. Blocks the request
never reaches (early EOS, unspent budget tail) are never drawn from the
free list at all, and whatever was drawn returns at ``free_slot``.

Paged conventions (shared by the Pallas kernel, the jnp oracle, and the
engine):

- pool block 0 is a reserved **trash block**, never allocated; writes on
  behalf of free / finished slots land there;
- block-table entries are physical block ids ≥ 1 when allocated and −1
  when not; attention fully masks −1 entries;
- per-token ``pos`` in the pool is −1 until written, and pad positions are
  installed as −1 at prefill, so a slot's visible context is exactly its
  real tokens.

``PagedCache`` also supports **preemption**: ``swap_out`` checkpoints a
slot's drawn blocks (every layer's K/V + per-token positions) to host
memory and releases them through the ordinary ``free_slot`` accounting;
``swap_in`` later draws fresh private blocks, scatters the checkpoint back
byte-for-byte, and re-commits the undrawn budget tail to the ledger — so
an SLO-blocked engine can evict a low-priority request's cache and restore
it token-exactly when pressure clears (``can_resume`` gates the restore
against the uncommitted free list).

Freed prefix blocks are **retained**: a refcount-0 block whose content is
registered in the prefix-hash index stays in the index and parks at the
*back* of the free list (LRU order), so templated traffic shares prompt
blocks across bursts, not just across concurrent requests — a later
admission matching the prefix revives the block from the free list with
its K/V intact. Cached free blocks are reclaimed last (plain free blocks
first, then least-recently-freed cached ones), and eviction simply drops
the index entry before the block is wiped for its new tenant.
"""
from __future__ import annotations

import collections
import dataclasses
import math
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np


def _pos1d(cur_pos, batch: int) -> jnp.ndarray:
    return jnp.broadcast_to(jnp.asarray(cur_pos, jnp.int32), (batch,))


def _map_kv_dicts(fn, tree, other=None):
    """Apply ``fn`` at each per-block cache dict (the ones holding "pos"),
    preserving the list/tuple nesting the model builds around them."""
    if isinstance(tree, dict):
        if "pos" not in tree:
            raise NotImplementedError(
                f"cache dict without positions (keys={sorted(tree)}) — "
                "paged layout supports attention caches only")
        return fn(tree) if other is None else fn(tree, other)
    if isinstance(tree, (list, tuple)):
        if other is None:
            sub = [_map_kv_dicts(fn, x) for x in tree]
        else:
            sub = [_map_kv_dicts(fn, x, y) for x, y in zip(tree, other)]
        return type(tree)(sub)
    raise NotImplementedError(f"unsupported cache node: {type(tree)}")


# ---------------------------------------------------------------------------
# Layouts: the layer-level contract the attention code programs against
# ---------------------------------------------------------------------------

def _chunk_index(cur_pos, updates, valid, batch: int):
    """Shared append bookkeeping: per-token positions (B, T) for a chunk
    starting at ``cur_pos`` plus the write-validity mask (True = real token;
    False = right-pad / inactive slot, must not land in the cache). ``valid``
    is assumed to be a contiguous prefix per row (chunks are dense)."""
    t = next(iter(updates.values())).shape[1]
    start = _pos1d(cur_pos, batch)
    pos = start[:, None] + jnp.arange(t, dtype=jnp.int32)[None, :]
    ok = (jnp.ones((batch, t), bool) if valid is None
          else jnp.broadcast_to(jnp.asarray(valid, bool), (batch, t)))
    return start, pos, ok


@dataclasses.dataclass(frozen=True)
class RingLayout:
    """Per-slot ring: cache arrays are (B, W, ...); token at position ``p``
    lives at slot ``p % W`` and ``pos`` records which position each slot
    currently holds (−1 = empty)."""

    def append(self, cache: Dict[str, jnp.ndarray], updates, cur_pos,
               block_tables=None, valid=None) -> Dict[str, jnp.ndarray]:
        """Write a T-token chunk (T = 1 for decode) at positions
        ``cur_pos + i``. Invalid tokens are routed to ring index ``width``
        — out of bounds, so the scatter drops them (JAX's default scatter
        mode) and the cache is untouched. When a chunk is longer than the
        ring (windowed layers), only each ring slot's newest token is kept
        (the older ones would be overwritten within this same scatter, and
        scatter order with duplicate indices is undefined).

        Scan-carry clean: every index derives from the traced ``cur_pos``
        and the carried cache's static shape — no per-step host constants —
        so engines may ``lax.scan`` K appends with the cache as carry
        (multi-step decode), windowed ring widths included."""
        b, width = cache["pos"].shape
        start, pos, ok = _chunk_index(cur_pos, updates, valid, b)
        length = jnp.sum(ok.astype(jnp.int32), axis=1, keepdims=True)
        keep = ok & (pos + width > start[:, None] + length - 1)
        slot = jnp.where(keep, pos % width, width)       # width = dropped
        rows = jnp.arange(b)[:, None]
        new = {k: cache[k].at[rows, slot].set(u)
               for k, u in updates.items()}
        new["pos"] = cache["pos"].at[rows, slot].set(pos)
        return new

    def attend(self, q, cache, q_pos, block_tables=None, *,
               window: Optional[int], scale: float,
               use_kernel: Optional[bool] = None,
               interpret: Optional[bool] = None):
        from repro.kernels.ops import decode_attn
        return decode_attn(q, cache["k"], cache["v"], q_pos, cache["pos"],
                           window=window, scale=scale, use_kernel=use_kernel,
                           interpret=interpret)

    def context(self, cache, block_tables=None) -> Dict[str, jnp.ndarray]:
        """Per-slot contiguous view (identity for the ring)."""
        return cache


@dataclasses.dataclass(frozen=True)
class PagedLayout:
    """Global block pool: cache arrays are (N, block_size, ...) shared by
    every slot; ``block_tables`` (B, M) maps a slot's logical block
    ``pos // block_size`` to a physical pool block."""
    block_size: int

    def append(self, cache: Dict[str, jnp.ndarray], updates, cur_pos,
               block_tables=None, valid=None) -> Dict[str, jnp.ndarray]:
        """Write a T-token chunk (T = 1 for decode) at positions
        ``cur_pos + i``. Free / never-admitted slots have no blocks and
        invalid (pad / inactive) tokens must not write: both are parked in
        the trash block (0) with pos −1. Scan-carry clean like the ring:
        all routing is traced (``block_tables`` may be a scan-invariant
        closure constant), so K decode appends scan with the pool as carry
        — the engine's look-ahead reservation guarantees every in-scan
        position is covered by an allocated block."""
        assert block_tables is not None, "paged layout needs block tables"
        b, m = block_tables.shape
        _, pos, ok = _chunk_index(cur_pos, updates, valid, b)
        logical = jnp.clip(pos // self.block_size, 0, m - 1)
        row = jnp.take_along_axis(block_tables, logical, axis=1)   # (B, T)
        ok = ok & (row >= 0)
        phys = jnp.where(ok, row, 0)
        off = jnp.where(ok, pos % self.block_size, 0)
        new = {k: cache[k].at[phys, off].set(u)
               for k, u in updates.items()}
        new["pos"] = cache["pos"].at[phys, off].set(
            jnp.where(ok, pos, -1))
        return new

    def attend(self, q, cache, q_pos, block_tables=None, *,
               window: Optional[int], scale: float,
               use_kernel: Optional[bool] = None,
               interpret: Optional[bool] = None):
        from repro.kernels.ops import paged_decode_attn
        return paged_decode_attn(q, cache["k"], cache["v"], q_pos,
                                 cache["pos"], block_tables, window=window,
                                 scale=scale, use_kernel=use_kernel,
                                 interpret=interpret)

    def context(self, cache, block_tables=None) -> Dict[str, jnp.ndarray]:
        """Gather each slot's blocks into a contiguous (B, M*bs, ...) view;
        unallocated table entries surface as pos −1 (fully masked)."""
        from repro.kernels.ref import gather_paged_kv
        out = {}
        pos = None
        for key, leaf in cache.items():
            if key == "pos":
                continue
            out[key], pos = gather_paged_kv(leaf, cache["pos"], block_tables)
        out["pos"] = pos
        return out


RING = RingLayout()


# ---------------------------------------------------------------------------
# Backends: the engine-level contract
# ---------------------------------------------------------------------------

class KVCacheBackend:
    """Engine-side cache owner.

    ``init`` returns the device cache state (a dict with "caches" — the
    model's cache pytree — and "tables", the (B, M) block tables or None).
    Admission is two-phase: the host calls ``alloc_slot`` (reserve blocks,
    may refuse), then passes the returned table row into ``prefill_fill``
    *inside* the jitted admit program. ``free_slot`` returns the blocks at
    completion. ``hbm_bytes`` is the device-resident KV footprint.

    Chunked prefill adds a second admission shape: ``begin_slot`` (wipe the
    slot's stale positions and install its table row, once per admission)
    followed by any number of ``slot_view`` → model chunk → ``slot_update``
    round-trips, each traced inside the engine's per-chunk program.
    ``alloc_slot`` may be given the prompt *tokens* instead of a length;
    backends that share prefix cache (``PagedCache``) then report via
    ``shared_prefill_start`` how many leading tokens are already installed.
    """

    layout: Any

    def init(self) -> Dict[str, Any]:
        raise NotImplementedError

    def can_admit(self, prompt, max_new: int) -> bool:
        """``prompt``: length (int) or the token array itself (enables
        prefix-aware accounting in sharing backends)."""
        raise NotImplementedError

    def can_ever_admit(self, prompt_len: int, max_new: int) -> bool:
        """Whether the request could fit with the backend completely idle
        (the *capacity* test, vs ``can_admit``'s availability test). False
        means waiting can never help: the engine terminally rejects the
        request instead of letting it block the queue forever."""
        return True

    def alloc_slot(self, slot: int, prompt, max_new: int) -> np.ndarray:
        """Host-side reservation; returns the slot's block-table row (a
        dummy for backends without tables). ``prompt`` is a length or the
        token array (see ``can_admit``). Must only be called after
        ``can_admit`` said yes."""
        raise NotImplementedError

    def prefill_fill(self, cache_state, one_caches, slot, length, table_row):
        """Install a single-request prefilled cache into ``slot`` (traced
        inside the engine's admit program)."""
        raise NotImplementedError

    def free_slot(self, cache_state, slot: int) -> Dict[str, Any]:
        raise NotImplementedError

    # -- chunked-prefill admission seam --------------------------------------
    def begin_slot(self, cache_state, slot, table_row, shared_blocks):
        """Prepare ``slot`` for incremental (chunked) install: wipe stale
        per-token positions so the previous tenant can't alias into the new
        request's causal mask, and install the table row. ``shared_blocks``
        leading blocks hold live shared-prefix content and are left alone.
        Traced (jit-safe in ``slot``/``table_row``/``shared_blocks``)."""
        raise NotImplementedError

    def slot_view(self, cache_state, slot, ctx=None):
        """(caches_view, tables_view) for running a single-slot model chunk:
        the ring slices the slot's cache line (batch 1); the paged pool is
        global, so the view is the pool plus the slot's (1, M) table row.
        ``ctx`` (static) bounds the visible context to the first ``ctx``
        positions — the chunk only ever attends to positions below its own
        end, so slicing skips the dense attend over the empty cache tail
        (the host-path analog of the TPU kernels' masked-block skip)."""
        raise NotImplementedError

    def slot_update(self, cache_state, slot, view_caches):
        """Write a ``slot_view`` caches pytree back (no-op for the paged
        pool, whose view aliases the global state)."""
        raise NotImplementedError

    def reserve_lookahead(self, slot: int, tokens: int):
        """Top up ``slot``'s physical reservation to cover ``tokens`` total
        tokens (multi-step decode look-ahead: the engine calls this with
        ``pos + K`` before scanning K fused decode steps, so every append
        inside the scan lands in an allocated block). Returns
        ``(new_table_row, previously_covered_entries)`` when blocks were
        added — the engine replays it through the ``begin_slot`` seam,
        which wipes only the new blocks' stale positions — or
        ``(None, 0)`` when the slot is already covered (always, for
        backends like the ring whose slots pin worst-case storage)."""
        return None, 0

    def shared_prefill_start(self, slot: int) -> int:
        """First prompt position the engine must actually compute for
        ``slot`` (> 0 when a shared prefix is already installed)."""
        return 0

    def shared_block_count(self, slot: int) -> int:
        """Leading table entries of ``slot`` whose content is already live
        (shared or copied) — ``begin_slot`` must not wipe them."""
        return 0

    def register_prefix(self, slot: int, prompt) -> None:
        """Called by the engine when ``slot``'s prefill completes: the
        slot's full prompt blocks now hold real K/V and may be shared."""

    def take_pending_copies(self) -> List:
        """Drain (src, dst) physical block copies the allocator scheduled
        (copy-on-write); the engine replays them on device."""
        return []

    def hbm_bytes(self) -> int:
        raise NotImplementedError

    def hbm_bytes_per_slot(self) -> float:
        raise NotImplementedError

    # -- mesh placement (tensor-parallel decode) -----------------------------
    # K/V pools shard their KV-head dim over the mesh's 'model' axis; the
    # block tables, free list and commitment ledger stay host-global. The
    # backend only *accounts* for the split (kv_shards) — placement itself
    # is jax.device_put with the shardings() tree, done by the engine.
    kv_shards: int = 1

    def shardings(self, mesh):
        """NamedSharding tree matching ``init()``'s state pytree."""
        from repro.serving.sharding import cache_shardings
        return cache_shardings(mesh, jax.eval_shape(self.init))

    def note_placement(self, mesh) -> None:
        """Record the KV-head split for per-device accounting. Leaves whose
        KV dim isn't divisible by the split stay replicated — the byte
        walkers below apply the same per-leaf divisibility rule that
        ``serving.sharding.cache_pspecs`` uses for placement."""
        from repro.serving.sharding import model_axis_size
        self.kv_shards = model_axis_size(mesh)

    def hbm_bytes_per_device(self) -> int:
        """Per-device KV footprint (== ``hbm_bytes`` without a mesh)."""
        return self.hbm_bytes()


def _kv_shard_divisor(path, shape, kv_shards: int) -> int:
    """Ways a pool leaf's bytes split across devices: K/V leaves with a
    divisible KV-head dim (dim 3 of 5) split ``kv_shards`` ways, everything
    else is replicated. Mirrors ``serving.sharding.cache_pspecs``."""
    name = path[-1].key if hasattr(path[-1], "key") else ""
    if name in ("k", "v") and len(shape) == 5 \
            and shape[3] % max(kv_shards, 1) == 0:
        return max(kv_shards, 1)
    return 1


def _cache_proto(lm, params, max_seq_len: int, proto_len: int):
    """Abstract per-request cache structure, as ``prefill`` returns it."""
    return jax.eval_shape(
        lambda p, t: lm.prefill(p, {"tokens": t},
                                cache_width=max_seq_len)[1],
        params, jax.ShapeDtypeStruct((1, proto_len), jnp.int32))


def _path_endswith(path, name: str) -> bool:
    return len(path) > 0 and getattr(path[-1], "key", None) == name


def _prompt_spec(prompt):
    """Normalize the ``prompt`` admission argument: length (int) or token
    array -> (length, tokens_or_None)."""
    if isinstance(prompt, (int, np.integer)):
        return int(prompt), None
    tokens = np.asarray(prompt, np.int32)
    return int(tokens.shape[0]), tokens


class RingCache(KVCacheBackend):
    """The original per-slot ring caches, extracted behind the API: every
    slot owns a full ``max_seq_len``-wide cache line in each layer."""

    def __init__(self, lm, params, *, batch_slots: int, max_seq_len: int,
                 proto_len: int = 16):
        self.layout = RING
        self.batch_slots = batch_slots
        self.max_seq_len = max_seq_len
        self._proto = _cache_proto(lm, params, max_seq_len, proto_len)

    def init(self) -> Dict[str, Any]:
        b = self.batch_slots

        def leaf(path, a):
            shape = (a.shape[0], b) + a.shape[2:]
            if _path_endswith(path, "pos"):
                return jnp.full(shape, -1, a.dtype)      # -1 = empty slot
            return jnp.zeros(shape, a.dtype)

        caches = jax.tree_util.tree_map_with_path(leaf, self._proto)
        return {"caches": caches, "tables": None}

    def can_admit(self, prompt, max_new: int) -> bool:
        return True                       # a granted slot is the only gate

    def alloc_slot(self, slot, prompt, max_new) -> np.ndarray:
        return np.zeros((1,), np.int32)   # no tables: fixed dummy row

    def prefill_fill(self, cache_state, one_caches, slot, length, table_row):
        caches = jax.tree.map(
            lambda g, c: jax.lax.dynamic_update_index_in_dim(
                g, c[:, 0], slot, axis=1),
            cache_state["caches"], one_caches)
        return {"caches": caches, "tables": cache_state["tables"]}

    def free_slot(self, cache_state, slot):
        return cache_state                # rings are reused in place

    # -- chunked-prefill admission seam --------------------------------------
    def begin_slot(self, cache_state, slot, table_row, shared_blocks):
        """Wipe the slot's per-token positions: unlike monolithic admission
        (which overwrites the whole cache line), chunked install only writes
        the chunk's positions, so the previous tenant's stale entries would
        otherwise sit inside the new request's causal mask."""

        def wipe(path, g):
            if _path_endswith(path, "pos"):
                return g.at[:, slot].set(-1)
            return g

        caches = jax.tree_util.tree_map_with_path(wipe,
                                                  cache_state["caches"])
        return {"caches": caches, "tables": cache_state["tables"]}

    def slot_view(self, cache_state, slot, ctx=None):
        """Chunked prefill requires unwindowed layers (engine-validated),
        so every cache line is ``max_seq_len`` wide and position ``p``
        lives at ring index ``p`` — the first ``ctx`` columns are exactly
        the positions below ``ctx``, making the prefix slice exact."""

        def view(g):
            width = g.shape[2] if ctx is None else min(ctx, g.shape[2])
            starts = (0, slot) + (0,) * (g.ndim - 2)
            return jax.lax.dynamic_slice(
                g, starts, (g.shape[0], 1, width) + g.shape[3:])

        return jax.tree.map(view, cache_state["caches"]), None

    def slot_update(self, cache_state, slot, view_caches):
        def upd(g, c):
            starts = (0, slot) + (0,) * (g.ndim - 2)
            return jax.lax.dynamic_update_slice(g, c, starts)

        caches = jax.tree.map(upd, cache_state["caches"], view_caches)
        return {"caches": caches, "tables": cache_state["tables"]}

    def hbm_bytes(self) -> int:
        total = 0
        for leaf in jax.tree.leaves(self._proto):
            n = math.prod((leaf.shape[0], self.batch_slots) + leaf.shape[2:])
            total += n * leaf.dtype.itemsize
        return total

    def hbm_bytes_per_slot(self) -> float:
        return self.hbm_bytes() / self.batch_slots

    def hbm_bytes_per_device(self) -> int:
        total = 0
        for path, leaf in jax.tree_util.tree_flatten_with_path(
                self._proto)[0]:
            shape = (leaf.shape[0], self.batch_slots) + leaf.shape[2:]
            n = math.prod(shape) // _kv_shard_divisor(
                path, shape, self.kv_shards)
            total += n * leaf.dtype.itemsize
        return total


class HostSwapHandle:
    """Deferred device→host K/V transfer for the swap/checkpoint path.

    ``swap_out(..., defer=True)`` gathers the slot's blocks into a fresh
    device buffer (so the pool can be scribbled over immediately), starts
    the D2H copy asynchronously, and hands the engine this handle instead
    of blocking on ``jax.device_get`` — the transfer then overlaps the
    next scheduler plan on the host. ``resolve()`` (idempotent) completes
    the copy and returns the numpy pytree; every consumer of a swap
    checkpoint's ``caches`` goes through ``resolve_swap_caches``."""

    def __init__(self, dev_caches):
        for leaf in jax.tree.leaves(dev_caches):
            if hasattr(leaf, "copy_to_host_async"):
                leaf.copy_to_host_async()
        self._dev = dev_caches
        self._host = None

    def resolve(self):
        if self._host is None:
            self._host = jax.device_get(self._dev)
            self._dev = None                    # drop the device buffers
        return self._host


def resolve_swap_caches(host_kv):
    """Materialize a swap checkpoint's ``caches`` in place (no-op when the
    transfer was eager or already resolved) and return the numpy pytree."""
    caches = host_kv["caches"]
    if isinstance(caches, HostSwapHandle):
        caches = caches.resolve()
        host_kv["caches"] = caches
    return caches


class PagedCache(KVCacheBackend):
    """Block-table backend: a global pool of ``num_blocks`` blocks of
    ``block_size`` tokens per layer, committed per request at admission and
    returned at completion. Slot count is bounded by live tokens in the
    pool, not by ``batch_slots × max_seq_len``.

    Allocation is **lazy with worst-case commitment**: admission debits the
    full ``ceil((prompt + budget) / block_size)`` from a commitment ledger
    (``can_admit`` checks fresh-worst-case ≤ free − outstanding
    commitments, so a look-ahead top-up can never fail mid-decode — no
    preemption needed), but only draws blocks covering the *prompt* from
    the free list; ``reserve_lookahead`` draws the rest just ahead of the
    decode scan that will write them. Budget a request never reaches
    (early EOS, unspent tail) is released without its blocks ever leaving
    the free list.

    Blocks are **refcounted**: requests whose prompts share a full-block
    prefix point their leading table entries at the same physical blocks
    (``prefix_sharing``), skipping both the HBM and the prefill compute for
    those tokens. A prefix-hash index maps ``tokens[:k*bs]`` (full blocks
    only, registered once the owning request's prefill completes) to the
    pool block holding block ``k-1``. ``free_slot`` decrements; at
    refcount 0 an *indexed* block is retained — it keeps its index entry
    and parks at the LRU tail of the free list, so a later admission
    (same burst or a new one) can revive it with its K/V intact
    (cross-run prefix persistence); unindexed blocks return to the plain
    free list. Reclaim order is plain blocks first, then cached blocks
    least-recently-freed first; eviction drops the index entry.
    If a new request must *write* inside a shared block (its prompt is
    entirely covered by shared blocks, so the engine recomputes the final
    prompt token for its logits), the allocator schedules a copy-on-write:
    a fresh block replaces the shared one in this slot's table and the
    engine replays the device-side copy before the first chunk."""

    def __init__(self, lm, params, *, batch_slots: int, max_seq_len: int,
                 block_size: int = 16, num_blocks: Optional[int] = None,
                 proto_len: int = 16, prefix_sharing: bool = True,
                 retain_prefix_blocks: Optional[bool] = None):
        for stage in lm.cfg.stages:
            for bdef in stage.blocks:
                if bdef.mixer not in ("attn", "mla"):
                    raise NotImplementedError(
                        f"paged KV backend supports attention mixers only "
                        f"(got {bdef.mixer!r}); use cache_backend='ring'")
        self.layout = PagedLayout(block_size)
        self.batch_slots = batch_slots
        self.max_seq_len = max_seq_len
        self.block_size = block_size
        self.prefix_sharing = prefix_sharing
        self.retain_prefix_blocks = (prefix_sharing
                                     if retain_prefix_blocks is None
                                     else retain_prefix_blocks
                                     and prefix_sharing)
        self.blocks_per_slot = -(-max_seq_len // block_size)   # table width M
        if num_blocks is None:
            # default to ring-equivalent capacity (+ the trash block)
            num_blocks = batch_slots * self.blocks_per_slot + 1
        if num_blocks < 2:
            raise ValueError("paged pool needs ≥ 2 blocks (block 0 is trash)")
        self.num_blocks = num_blocks
        self._proto = _cache_proto(lm, params, max_seq_len, proto_len)
        # free blocks, two tiers: plain blocks (no cached content) are
        # reclaimed first; refcount-0 blocks retaining indexed prefix K/V
        # sit in freed order and are reclaimed LRU-first, i.e. last overall
        self._free_plain: List[int] = list(range(1, num_blocks))  # 0 = trash
        self._free_cached: "collections.OrderedDict[int, None]" = \
            collections.OrderedDict()
        self._slot_blocks: Dict[int, List[int]] = {}
        self._ref: Dict[int, int] = {}                # block -> refcount
        self._index: Dict[bytes, int] = {}            # prefix hash -> block
        self._block_key: Dict[int, bytes] = {}        # reverse index
        self._slot_shared: Dict[int, int] = {}        # slot -> live blocks
        self._slot_start: Dict[int, int] = {}         # slot -> prefill start
        self._slot_cap: Dict[int, int] = {}           # slot -> max entries
        self._slot_gap: Dict[int, int] = {}           # committed, not drawn
        self._gap_total = 0                           # sum of _slot_gap
        self._pending_copies: List = []               # (src, dst) for COW
        # accounting for the bench / capacity planning
        self.admitted = 0
        self.blocks_allocated_total = 0
        self.peak_blocks_in_use = 0
        self.cow_copies = 0
        self.lookahead_topups = 0
        self.retained_block_hits = 0
        self.swap_outs = 0
        self.swap_ins = 0
        self.preempt_swap_bytes = 0      # host<->device bytes moved by swaps

    @property
    def _free(self) -> List[int]:
        """All reclaimable blocks, in reclaim order (read-only view kept
        for accounting/tests; mutate the underlying tiers instead)."""
        return self._free_plain + list(self._free_cached)

    # -- device state --------------------------------------------------------
    def init(self) -> Dict[str, Any]:
        n, bs = self.num_blocks, self.block_size

        def pool(d):
            out = {}
            for key, a in d.items():
                # proto leaves are (L, 1, W, ...): swap the per-request
                # (1, W) cache line for the (N, bs) pool
                shape = (a.shape[0], n, bs) + a.shape[3:]
                if key == "pos":
                    shape = (a.shape[0], n, bs)
                    out[key] = jnp.full(shape, -1, a.dtype)
                else:
                    out[key] = jnp.zeros(shape, a.dtype)
            return out

        caches = _map_kv_dicts(pool, self._proto)
        tables = jnp.full((self.batch_slots, self.blocks_per_slot), -1,
                          jnp.int32)
        return {"caches": caches, "tables": tables}

    # -- host-side allocator -------------------------------------------------
    def blocks_needed(self, prompt_len: int, max_new: int) -> int:
        return max(1, -(-(prompt_len + max_new) // self.block_size))

    def _plan(self, prompt, max_new: int):
        """(total_blocks, shared_blocks, fresh_worst, prefill_start) for a
        prospective admission. Sharing matches the longest chain of full
        prompt blocks already registered in the prefix index — refcount-0
        retained blocks included (their K/V survives in the pool until
        eviction); the engine always recomputes at least the final prompt
        token (its logits seed decode), and when that token's block is
        shared the plan commits one extra block for the copy-on-write.
        ``fresh_worst`` is the worst-case fresh-block draw over the
        request's whole lifetime (full budget, no early EOS)."""
        length, tokens = _prompt_spec(prompt)
        total = self.blocks_needed(length, max_new)
        shared = []
        if self.prefix_sharing and tokens is not None:
            bs = self.block_size
            while (len(shared) + 1) * bs <= length:
                blk = self._index.get(tokens[:(len(shared) + 1) * bs]
                                      .tobytes())
                if blk is None:
                    break
                shared.append(blk)
        k = len(shared)
        prefill_start = k * self.block_size
        cow = 0
        if prefill_start >= length:            # fully covered, block-aligned
            prefill_start = length - 1
            cow = 1                            # last block must go private
        return total, shared, total - k + cow, prefill_start

    def _revivals(self, shared) -> int:
        """Shared blocks currently parked refcount-0 in the free list: a
        revival takes them out of the free list without counting as a
        fresh draw."""
        return sum(1 for blk in shared if blk not in self._ref)

    def _available(self) -> int:
        """Free blocks not spoken for by outstanding worst-case
        commitments of already-admitted requests."""
        return (len(self._free_plain) + len(self._free_cached)
                - self._gap_total)

    def can_admit(self, prompt, max_new: int) -> bool:
        _, shared, fresh_worst, _ = self._plan(prompt, max_new)
        return fresh_worst + self._revivals(shared) <= self._available()

    def can_ever_admit(self, prompt_len: int, max_new: int) -> bool:
        # block 0 is the trash block: usable pool is num_blocks - 1
        return self.blocks_needed(prompt_len, max_new) <= self.num_blocks - 1

    def _take_free(self, n: int, exclude=()) -> List[int]:
        """Draw ``n`` blocks: plain free blocks first, then retained
        (cached) blocks least-recently-freed first, evicting their index
        entries. ``exclude`` protects retained blocks the caller is about
        to *revive* as shared entries of the same admission — evicting one
        of those would hand the same physical block out twice. Callers
        stay within the commitment ledger (which counts revivals), so the
        free list always covers the draw."""
        out: List[int] = []
        while self._free_plain and len(out) < n:
            out.append(self._free_plain.pop())
        if len(out) < n:
            for blk in list(self._free_cached):              # LRU eviction
                if len(out) >= n:
                    break
                if blk in exclude:
                    continue
                del self._free_cached[blk]
                key = self._block_key.pop(blk, None)
                if key is not None and self._index.get(key) == blk:
                    del self._index[key]
                out.append(blk)
        assert len(out) == n, "commitment ledger violated: free list short"
        return out

    def _release_block(self, blk: int) -> None:
        """Park a refcount-0 block in the free list: retained (index entry
        kept, LRU tail) when it holds registered prefix K/V, plain
        otherwise."""
        key = self._block_key.get(blk)
        if key is not None and self.retain_prefix_blocks:
            self._free_cached[blk] = None     # most-recent = reclaimed last
            return
        if key is not None:
            del self._block_key[blk]
            if self._index.get(key) == blk:
                del self._index[key]
        self._free_plain.append(blk)

    def alloc_slot(self, slot, prompt, max_new) -> np.ndarray:
        length, _ = _prompt_spec(prompt)
        total, shared, fresh_worst, prefill_start = self._plan(prompt,
                                                               max_new)
        revive = self._revivals(shared)
        if fresh_worst + revive > self._available():
            raise RuntimeError(
                f"paged pool exhausted: need {fresh_worst + revive} blocks, "
                f"{self._available()} available")
        if slot in self._slot_blocks:
            raise RuntimeError(f"slot {slot} already holds blocks")
        k = len(shared)
        cow = 1 if (shared and prefill_start < k * self.block_size) else 0
        # physical draw now: blocks covering the prompt (decode blocks are
        # drawn by reserve_lookahead just ahead of the scan that fills them)
        entries_now = max(1, -(-length // self.block_size))
        fresh_now = cow + max(0, entries_now - k)
        fresh = self._take_free(fresh_now, exclude=set(shared))
        for blk in shared:
            if blk in self._free_cached:      # revive a retained block
                del self._free_cached[blk]
                self.retained_block_hits += 1
            self._ref[blk] = self._ref.get(blk, 0) + 1
        for blk in fresh:
            self._ref[blk] = 1
        blocks = list(shared)
        if cow:
            # copy-on-write: the final prompt token lives in the last shared
            # block; hand this slot a private copy instead
            src = blocks[-1]
            dst = fresh[0]
            blocks[-1] = dst
            self._ref[src] -= 1                # undo the share of that block
            if self._ref[src] == 0:            # was a revived retained block
                del self._ref[src]
                self._release_block(src)
            self._pending_copies.append((src, dst))
            self.cow_copies += 1
            blocks.extend(fresh[1:])
        else:
            blocks.extend(fresh)
        self._slot_blocks[slot] = blocks
        self._slot_shared[slot] = k             # content-live leading blocks
        self._slot_start[slot] = prefill_start
        self._slot_cap[slot] = total
        self._slot_gap[slot] = fresh_worst - fresh_now
        self._gap_total += fresh_worst - fresh_now
        row = np.full((self.blocks_per_slot,), -1, np.int32)
        row[:len(blocks)] = blocks
        self.admitted += 1
        self.blocks_allocated_total += fresh_now
        self.peak_blocks_in_use = max(self.peak_blocks_in_use,
                                      self.blocks_in_use)
        return row

    def reserve_lookahead(self, slot, tokens: int):
        """Top the slot's table up to cover ``tokens`` total tokens ahead
        of a decode scan. Draws at most the slot's remaining commitment
        (the admission-time worst case), so the ledger guarantees the free
        list can satisfy it; returns ``(row, previously_covered)`` for the
        engine's ``begin_slot`` replay, or ``(None, 0)`` when covered."""
        blocks = self._slot_blocks.get(slot)
        if blocks is None:
            return None, 0
        need = min(max(1, -(-tokens // self.block_size)),
                   self._slot_cap[slot])
        have = len(blocks)
        if need <= have:
            return None, 0
        take = need - have
        assert take <= self._slot_gap[slot], (
            f"look-ahead past slot {slot}'s committed budget "
            f"({take} > {self._slot_gap[slot]})")
        fresh = self._take_free(take)
        for blk in fresh:
            self._ref[blk] = 1
        blocks.extend(fresh)
        self._slot_gap[slot] -= take
        self._gap_total -= take
        self.blocks_allocated_total += take
        self.lookahead_topups += 1
        self.peak_blocks_in_use = max(self.peak_blocks_in_use,
                                      self.blocks_in_use)
        row = np.full((self.blocks_per_slot,), -1, np.int32)
        row[:len(blocks)] = blocks
        return row, have

    def shared_prefill_start(self, slot: int) -> int:
        return self._slot_start.get(slot, 0)

    def shared_block_count(self, slot: int) -> int:
        return self._slot_shared.get(slot, 0)

    def register_prefix(self, slot: int, prompt) -> None:
        """Publish the slot's full prompt blocks into the prefix index.
        Called when the slot's prefill *completes* — earlier registration
        would let a concurrent admission share blocks whose K/V hasn't been
        installed yet (pos −1, silently masked: wrong outputs)."""
        if not self.prefix_sharing:
            return
        length, tokens = _prompt_spec(prompt)
        if tokens is None:
            return
        blocks = self._slot_blocks.get(slot)
        if blocks is None:
            return
        bs = self.block_size
        for i in range(length // bs):
            key = tokens[:(i + 1) * bs].tobytes()
            blk = blocks[i]
            if key in self._index or blk in self._block_key:
                continue
            self._index[key] = blk
            self._block_key[blk] = key

    def take_pending_copies(self) -> List:
        copies, self._pending_copies = self._pending_copies, []
        return copies

    def copy_block(self, cache_state, src, dst):
        """Device-side block copy (COW): every layer's pool rows ``src`` →
        ``dst``, per-token positions included. Traced (jit-safe)."""

        def copy(c):
            return {key: leaf.at[:, dst].set(leaf[:, src])
                    for key, leaf in c.items()}

        caches = _map_kv_dicts(copy, cache_state["caches"])
        return {"caches": caches, "tables": cache_state["tables"]}

    @property
    def blocks_in_use(self) -> int:
        """Blocks held by live slots (retained refcount-0 cache blocks are
        reclaimable, so they count as free)."""
        return (self.num_blocks - 1) - len(self._free_plain) \
            - len(self._free_cached)

    def reset_stats(self) -> None:
        """Zero the admission accounting (e.g. after bench warm-up) so
        ``hbm_bytes_per_slot`` averages only the measured traffic."""
        self.admitted = 0
        self.blocks_allocated_total = 0
        self.peak_blocks_in_use = self.blocks_in_use
        self.cow_copies = 0
        self.lookahead_topups = 0
        self.retained_block_hits = 0
        self.swap_outs = 0
        self.swap_ins = 0
        self.preempt_swap_bytes = 0

    def free_slot(self, cache_state, slot):
        blocks = self._slot_blocks.pop(slot, None)
        if blocks is None:
            return cache_state
        self._slot_shared.pop(slot, None)
        self._slot_start.pop(slot, None)
        self._slot_cap.pop(slot, None)
        # release the never-drawn commitment (over-reserved look-ahead the
        # request finished without: early EOS / unspent budget tail)
        self._gap_total -= self._slot_gap.pop(slot, 0)
        for blk in blocks:
            self._ref[blk] = self._ref.get(blk, 1) - 1
            if self._ref[blk] > 0:
                continue                      # still shared by another slot
            del self._ref[blk]
            self._release_block(blk)
        tables = cache_state["tables"].at[slot].set(-1)
        return {"caches": cache_state["caches"], "tables": tables}

    # -- preemption: host K/V swap -------------------------------------------
    def _swap_fns(self):
        """Jitted fixed-shape gather/scatter for the swap path: both take a
        full ``blocks_per_slot``-wide index vector (gather pads with the
        trash block — harmless reads; scatter pads with ``num_blocks`` —
        out of bounds, dropped), so each compiles exactly once and a first
        swap landing mid-traffic never pays an XLA compile."""
        if not hasattr(self, "_gather_fn"):
            def gather(caches, idx):
                return _map_kv_dicts(
                    lambda c: {k: jnp.take(leaf, idx, axis=1)
                               for k, leaf in c.items()}, caches)

            def scatter(caches, host, phys):
                def one(c, h):
                    return {k: leaf.at[:, phys].set(h[k])
                            for k, leaf in c.items()}

                return _map_kv_dicts(one, caches, host)

            self._gather_fn = jax.jit(gather)
            # donate the pool: without it every swap_in materializes a
            # second full copy of the paged KV cache — transiently doubling
            # KV HBM in exactly the memory-pressure regime preemption
            # exists to serve (the gather must NOT donate: its input pool
            # stays live)
            self._scatter_fn = jax.jit(scatter, donate_argnums=(0,))
        return self._gather_fn, self._scatter_fn

    def warm_swap(self, cache_state):
        """Pre-compile the swap gather/scatter as no-ops (gather from the
        trash block, scatter fully out of bounds); call while idle."""
        gather_fn, scatter_fn = self._swap_fns()
        m = self.blocks_per_slot
        host = jax.device_get(gather_fn(cache_state["caches"],
                                        jnp.zeros((m,), jnp.int32)))
        caches = scatter_fn(cache_state["caches"], host,
                            jnp.full((m,), self.num_blocks, jnp.int32))
        return {"caches": caches, "tables": cache_state["tables"]}

    def swap_out(self, cache_state, slot, *, defer: bool = False):
        """Checkpoint ``slot``'s drawn blocks to the host and release them:
        gathers every layer's K/V (and per-token positions) for the slot's
        block list into numpy arrays, then returns the blocks through the
        ordinary ``free_slot`` path — refcounts, the commitment ledger and
        prefix retention all behave exactly as if the request completed.
        Shared-prefix blocks are *copied*, not stolen: other holders keep
        them, and the resumed slot gets private replicas at ``swap_in``.
        Returns ``(host_kv, new_cache_state)``; ``host_kv`` is the cache
        pytree restricted to the slot's (padded) block row plus the live
        block count, opaque to the engine.

        With ``defer=True`` the D2H copy is started asynchronously and
        ``host_kv["caches"]`` is a ``HostSwapHandle`` the caller resolves
        later (the gather lands in a fresh device buffer either way, so
        the released blocks may be reused immediately) — the fault-
        recovery rollback uses this to overlap the transfer with the next
        scheduler plan instead of stalling the step loop on it."""
        blocks = self._slot_blocks.get(slot)
        if blocks is None:
            raise RuntimeError(f"slot {slot} holds no blocks to swap out")
        gather_fn, _ = self._swap_fns()
        idx = np.zeros((self.blocks_per_slot,), np.int32)   # pad: trash
        idx[:len(blocks)] = blocks
        gathered = gather_fn(cache_state["caches"], jnp.asarray(idx))
        host = {"n_blocks": len(blocks),
                "caches": (HostSwapHandle(gathered) if defer
                           else jax.device_get(gathered))}
        self.swap_outs += 1
        self.preempt_swap_bytes += len(blocks) * self.block_bytes()
        return host, self.free_slot(cache_state, slot)

    def checkpoint_slot(self, cache_state, slot):
        """Non-destructive host checkpoint of a live slot's drawn blocks —
        ``swap_out``'s wire format without the release (refcounts, ledger
        and table row untouched), so an engine snapshot can persist every
        active slot's K/V while the engine keeps serving. Restores through
        the ordinary ``swap_in`` path on a cold engine."""
        blocks = self._slot_blocks.get(slot)
        if blocks is None:
            raise RuntimeError(f"slot {slot} holds no blocks to checkpoint")
        gather_fn, _ = self._swap_fns()
        idx = np.zeros((self.blocks_per_slot,), np.int32)   # pad: trash
        idx[:len(blocks)] = blocks
        return {"n_blocks": len(blocks),
                "caches": jax.device_get(
                    gather_fn(cache_state["caches"], jnp.asarray(idx)))}

    def available_blocks(self) -> int:
        """Free blocks not spoken for by outstanding commitments (the
        quantity ``can_admit``/``can_resume`` gate on), public for the
        engine's preemption-feasibility check."""
        return self._available()

    def slot_commitment(self, slot: int) -> int:
        """Upper bound on the blocks admission would recover if ``slot``
        were preempted: its drawn blocks plus its undrawn ledger gap
        (shared blocks another slot still refcounts are counted — the
        bound is optimistic, which only risks a preemption that recovers
        less than hoped, never a refused feasible one)."""
        return (len(self._slot_blocks.get(slot, ()))
                + self._slot_gap.get(slot, 0))

    def can_resume(self, prompt_len: int, max_new: int) -> bool:
        """Whether a swapped-out request fits back in: its blocks return as
        *private* (worst-case commitment, no sharing discount), so resume
        demand is the full ``blocks_needed`` against the uncommitted free
        list."""
        return self.blocks_needed(prompt_len, max_new) <= self._available()

    def swap_in(self, cache_state, slot, host_kv, prompt_len: int,
                max_new: int):
        """Restore a swapped-out request into ``slot``: draw fresh private
        blocks for the checkpointed content, scatter the host K/V back
        byte-for-byte, and re-commit the undrawn budget tail to the ledger
        (look-ahead top-ups resume exactly where they left off). Only call
        after ``can_resume`` said yes."""
        total = self.blocks_needed(prompt_len, max_new)
        n_now = host_kv["n_blocks"]
        if total > self._available():
            raise RuntimeError(
                f"paged pool exhausted on resume: need {total} blocks, "
                f"{self._available()} available")
        if slot in self._slot_blocks:
            raise RuntimeError(f"slot {slot} already holds blocks")
        fresh = self._take_free(n_now)
        for blk in fresh:
            self._ref[blk] = 1
        self._slot_blocks[slot] = fresh
        self._slot_shared[slot] = 0
        self._slot_start[slot] = prompt_len
        self._slot_cap[slot] = total
        self._slot_gap[slot] = total - n_now
        self._gap_total += total - n_now
        self.blocks_allocated_total += n_now
        self.peak_blocks_in_use = max(self.peak_blocks_in_use,
                                      self.blocks_in_use)
        self.swap_ins += 1
        self.preempt_swap_bytes += n_now * self.block_bytes()
        _, scatter_fn = self._swap_fns()
        phys = np.full((self.blocks_per_slot,), self.num_blocks, np.int32)
        phys[:n_now] = fresh                    # pad: OOB, writes dropped
        caches = scatter_fn(cache_state["caches"],
                            resolve_swap_caches(host_kv),
                            jnp.asarray(phys))
        # whole-array host round-trip: a sliced eager update would compile
        # per slot index (see ServingEngine._edit_state)
        tables = np.array(cache_state["tables"])
        tables[slot] = -1
        tables[slot, :n_now] = fresh
        return {"caches": caches, "tables": jnp.asarray(tables)}

    def assert_invariants(self, cache_state=None) -> None:
        """Allocator accounting invariants (tests call this after runs and
        mid-traffic): block conservation across slots/tiers, ledger
        consistency, and index/retention coherence. With ``cache_state``
        (the live device state) the sweep extends to sharded pools:
        per-shard byte conservation must agree with the host-global
        ledger's view of the pool."""
        held = [b for blocks in self._slot_blocks.values() for b in blocks]
        # every non-trash block is either held by exactly the slots that
        # refcount it, or parked in exactly one free tier
        assert sorted(held + list(self._free_plain)
                      + list(self._free_cached)) == sorted(
            list(range(1, self.num_blocks)) + [
                b for b, r in self._ref.items() for _ in range(r - 1)])
        assert all(r > 0 for r in self._ref.values())
        assert set(self._ref) == set(held)
        # ledger: outstanding commitments never exceed the free list
        assert self._gap_total == sum(self._slot_gap.values())
        assert 0 <= self._gap_total <= (len(self._free_plain)
                                        + len(self._free_cached))
        # per-slot ledger bounds (preemption swaps slots in and out of the
        # pool mid-flight, so check every live slot, not just the sums):
        # drawn blocks never exceed the admission-time worst case, undrawn
        # commitments stay non-negative, and drawn + undrawn covers the
        # worst case (equality modulo the COW block, which draws one block
        # beyond the shared plan)
        for slot, blocks in self._slot_blocks.items():
            cap = self._slot_cap[slot]
            gap = self._slot_gap[slot]
            assert 0 <= gap and cap >= 1
            assert len(blocks) <= cap + 1, (slot, len(blocks), cap)  # +COW
            assert len(blocks) + gap >= cap, (slot, len(blocks), gap, cap)
        # retention: every cached free block is indexed, and the index's
        # reverse map agrees
        for blk in self._free_cached:
            assert self._block_key.get(blk) is not None
        for key, blk in self._index.items():
            assert self._block_key.get(blk) == key
        for blk, key in self._block_key.items():
            assert self._index.get(key) == blk
        if cache_state is not None:
            self._assert_pool_placement(cache_state)

    def _assert_pool_placement(self, cache_state) -> None:
        """Sharded-pool accounting: the device pool must still be the
        ledger's pool (width = ``num_blocks``), every K/V leaf must be
        split exactly ``kv_shards`` ways on its KV-head dim (or replicated
        when not divisible), each device must hold one equal-size shard,
        and the summed per-device bytes must equal
        ``hbm_bytes_per_device()`` — per-shard byte conservation agreeing
        with the host-global ledger."""
        per_dev_total = 0
        for path, leaf in jax.tree_util.tree_flatten_with_path(
                cache_state["caches"])[0]:
            if not hasattr(leaf, "sharding"):
                continue
            assert leaf.shape[1] == self.num_blocks, (
                f"pool width {leaf.shape[1]} != ledger's {self.num_blocks}")
            shard = leaf.sharding.shard_shape(leaf.shape)
            shard_elems = math.prod(shard)
            total_elems = math.prod(leaf.shape)
            assert shard_elems and total_elems % shard_elems == 0
            want = _kv_shard_divisor(path, leaf.shape, self.kv_shards)
            assert total_elems // shard_elems == want, (
                f"pool leaf {jax.tree_util.keystr(path)}: split "
                f"{total_elems // shard_elems} ways, ledger expects {want}")
            shard_bytes = shard_elems * leaf.dtype.itemsize
            assert all(s.data.nbytes == shard_bytes
                       for s in leaf.addressable_shards)
            per_dev_total += shard_bytes
        if per_dev_total:
            assert per_dev_total == self.hbm_bytes_per_device(), (
                per_dev_total, self.hbm_bytes_per_device())

    # -- chunked-prefill admission seam --------------------------------------
    def begin_slot(self, cache_state, slot, table_row, shared_blocks):
        """Wipe per-token positions of the row's *fresh* blocks (they may be
        reused from a finished tenant whose stale positions would alias into
        the new request's causal mask) and install the table row. The
        ``shared_blocks`` leading entries hold live shared-prefix (or COW
        copy) content and must be left intact. Singleton delegation to
        ``begin_slots`` — one wipe implementation to keep correct."""
        return self.begin_slots(cache_state,
                                jnp.reshape(slot, (1,)),
                                jnp.reshape(table_row,
                                            (1, self.blocks_per_slot)),
                                jnp.reshape(shared_blocks, (1,)))

    def begin_slots(self, cache_state, slots, table_rows, shared_blocks):
        """Batched ``begin_slot``: apply many slots' table top-ups in one
        traced update (one dispatch when several slots cross a block
        boundary in the same plan, instead of one replay per slot).
        ``slots`` (S,), ``table_rows`` (S, M), ``shared_blocks`` (S,);
        callers pad to a fixed S by *repeating* entries — duplicate rows
        write identical values, so the scatter stays well-defined."""
        n = self.num_blocks
        idx = jnp.arange(self.blocks_per_slot)[None, :]
        wipe = (idx >= shared_blocks[:, None]) & (table_rows >= 0)
        phys = jnp.where(wipe, table_rows, n)         # n = OOB -> dropped

        def clear(c):
            return {key: (leaf.at[:, phys].set(-1) if key == "pos" else leaf)
                    for key, leaf in c.items()}

        caches = _map_kv_dicts(clear, cache_state["caches"])
        tables = cache_state["tables"].at[slots].set(table_rows)
        return {"caches": caches, "tables": tables}

    def slot_view(self, cache_state, slot, ctx=None):
        tables = jax.lax.dynamic_slice_in_dim(cache_state["tables"], slot, 1,
                                              axis=0)
        if ctx is not None:
            # visible context = the leading table entries covering positions
            # below ctx; later entries hold no position the chunk may see
            m = min(-(-ctx // self.block_size), self.blocks_per_slot)
            tables = tables[:, :m]
        return cache_state["caches"], tables

    def slot_update(self, cache_state, slot, view_caches):
        # the view *is* the global pool: chunk writes already landed there
        return {"caches": view_caches, "tables": cache_state["tables"]}

    # -- admission-time install ---------------------------------------------
    def prefill_fill(self, cache_state, one_caches, slot, length, table_row):
        """Scatter a prefilled per-request cache into the slot's blocks.

        Tokens are routed by their *position* (block ``pos // bs``, offset
        ``pos % bs``), so ring-wrapped prefill caches (windowed layers with
        window < bucket) install correctly, and right-pad entries
        (pos ≥ length) are parked in the trash block with pos −1 — unlike
        the ring, the paged cache never exposes pad K/V at all.

        The row's blocks may be reused from a completed request, so their
        per-token positions are wiped to −1 first: a stale position from the
        previous tenant can land inside the new request's causal mask, and
        unlike the ring (which overwrites the whole cache line at admission)
        the paged install only writes the new prompt's prefix."""
        bs = self.block_size
        row_safe = jnp.where(table_row >= 0, table_row, 0)

        def fill(c, o):
            src_pos = o["pos"][0, 0]                      # (W,) layer-0 row
            valid = (src_pos >= 0) & (src_pos < length)
            logical = jnp.clip(src_pos, 0, self.max_seq_len - 1) // bs
            row_phys = jnp.take(table_row, logical)
            phys = jnp.where(valid & (row_phys >= 0), row_phys, 0)
            off = jnp.where(valid, src_pos % bs, 0)
            new = {}
            for key, leaf in c.items():
                if key == "pos":
                    cleared = leaf.at[:, row_safe, :].set(-1)
                    new[key] = cleared.at[:, phys, off].set(
                        jnp.where(valid, src_pos, -1)[None, :])
                else:
                    new[key] = leaf.at[:, phys, off].set(o[key][:, 0])
            return new

        caches = _map_kv_dicts(fill, cache_state["caches"], one_caches)
        tables = cache_state["tables"].at[slot].set(table_row)
        return {"caches": caches, "tables": tables}

    # -- accounting ----------------------------------------------------------
    def block_bytes(self) -> int:
        """Bytes one pool block costs across all layers."""
        total = 0
        for leaf in jax.tree.leaves(self._proto):
            per_tok = math.prod(leaf.shape[:1] + leaf.shape[3:])
            total += per_tok * self.block_size * leaf.dtype.itemsize
        return total

    def block_bytes_per_device(self) -> int:
        """Per-device bytes of one pool block: K/V leaves split their
        KV-head dim ``kv_shards`` ways when divisible; the per-token
        position leaf is replicated on every device."""
        total = 0
        for path, leaf in jax.tree_util.tree_flatten_with_path(
                self._proto)[0]:
            per_tok = math.prod(leaf.shape[:1] + leaf.shape[3:])
            per_tok //= _kv_shard_divisor(path, leaf.shape, self.kv_shards)
            total += per_tok * self.block_size * leaf.dtype.itemsize
        return total

    def hbm_bytes(self) -> int:
        return self.block_bytes() * self.num_blocks

    def hbm_bytes_per_device(self) -> int:
        return self.block_bytes_per_device() * self.num_blocks

    def hbm_bytes_per_slot(self) -> float:
        """Average bytes actually *drawn* per admitted request (the ring
        equivalent is a constant ``max_seq_len`` line). Lazy allocation
        makes this live-token-accurate: committed-but-undrawn budget
        blocks (unreached look-ahead) don't count."""
        if self.admitted == 0:
            return float(self.block_bytes() * self.blocks_per_slot)
        return self.block_bytes() * self.blocks_allocated_total / self.admitted


def make_backend(kind, lm, params, *, batch_slots: int, max_seq_len: int,
                 proto_len: int = 16, block_size: int = 16,
                 num_blocks: Optional[int] = None,
                 prefix_sharing: bool = True) -> KVCacheBackend:
    if isinstance(kind, KVCacheBackend):
        return kind
    if kind == "ring":
        return RingCache(lm, params, batch_slots=batch_slots,
                         max_seq_len=max_seq_len, proto_len=proto_len)
    if kind == "paged":
        return PagedCache(lm, params, batch_slots=batch_slots,
                          max_seq_len=max_seq_len, proto_len=proto_len,
                          block_size=block_size, num_blocks=num_blocks,
                          prefix_sharing=prefix_sharing)
    raise ValueError(f"unknown cache backend {kind!r} "
                     "(expected 'ring' or 'paged')")
