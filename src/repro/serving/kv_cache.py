"""Pluggable KV-cache backends for the serving engines.

The cache seam has two levels, both defined here:

**Layouts** (``RingLayout`` / ``PagedLayout``) are stateless, hashable
objects the *model* programs against: ``append`` writes one decode step's
K/V (or MLA latents) into a layer's cache arrays, ``attend`` runs
single-token GQA attention over them, and ``context`` materializes a
per-slot contiguous view for mixers that attend in plain jnp (MLA's
absorbed form). ``attn_decode`` / ``mla_decode`` / ``LM.decode_step`` take a
layout plus an optional ``block_tables`` array and never touch cache-dict
internals directly.

**Backends** (``RingCache`` / ``PagedCache``) are what the *engine* owns:
device cache state, slot admission (``alloc_slot`` → ``prefill_fill``),
completion (``free_slot``) and accounting (``hbm_bytes``). ``RingCache`` is
the original behavior extracted: every slot pins a ``max_seq_len``-wide
ring, so HBM per slot is worst-case. ``PagedCache`` is vLLM-style: one
global pool of fixed-size blocks per layer plus a per-slot block table,
with a host-side free-block allocator — admission reserves exactly
``ceil((prompt + budget) / block_size)`` blocks, so concurrent slots are
bounded by *live tokens*, not worst-case sequence length.

Paged conventions (shared by the Pallas kernel, the jnp oracle, and the
engine):

- pool block 0 is a reserved **trash block**, never allocated; writes on
  behalf of free / finished slots land there;
- block-table entries are physical block ids ≥ 1 when allocated and −1
  when not; attention fully masks −1 entries;
- per-token ``pos`` in the pool is −1 until written, and pad positions are
  installed as −1 at prefill, so a slot's visible context is exactly its
  real tokens.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np


def _pos1d(cur_pos, batch: int) -> jnp.ndarray:
    return jnp.broadcast_to(jnp.asarray(cur_pos, jnp.int32), (batch,))


def _map_kv_dicts(fn, tree, other=None):
    """Apply ``fn`` at each per-block cache dict (the ones holding "pos"),
    preserving the list/tuple nesting the model builds around them."""
    if isinstance(tree, dict):
        if "pos" not in tree:
            raise NotImplementedError(
                f"cache dict without positions (keys={sorted(tree)}) — "
                "paged layout supports attention caches only")
        return fn(tree) if other is None else fn(tree, other)
    if isinstance(tree, (list, tuple)):
        if other is None:
            sub = [_map_kv_dicts(fn, x) for x in tree]
        else:
            sub = [_map_kv_dicts(fn, x, y) for x, y in zip(tree, other)]
        return type(tree)(sub)
    raise NotImplementedError(f"unsupported cache node: {type(tree)}")


# ---------------------------------------------------------------------------
# Layouts: the layer-level contract the attention code programs against
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class RingLayout:
    """Per-slot ring: cache arrays are (B, W, ...); token at position ``p``
    lives at slot ``p % W`` and ``pos`` records which position each slot
    currently holds (−1 = empty)."""

    def append(self, cache: Dict[str, jnp.ndarray], updates, cur_pos,
               block_tables=None) -> Dict[str, jnp.ndarray]:
        b, width = cache["pos"].shape
        cur = _pos1d(cur_pos, b)
        slot = cur % width
        rows = jnp.arange(b)
        new = {k: cache[k].at[rows, slot].set(u[:, 0])
               for k, u in updates.items()}
        new["pos"] = cache["pos"].at[rows, slot].set(cur)
        return new

    def attend(self, q, cache, q_pos, block_tables=None, *,
               window: Optional[int], scale: float,
               use_kernel: Optional[bool] = None,
               interpret: Optional[bool] = None):
        from repro.kernels.ops import decode_attn
        return decode_attn(q, cache["k"], cache["v"], q_pos, cache["pos"],
                           window=window, scale=scale, use_kernel=use_kernel,
                           interpret=interpret)

    def context(self, cache, block_tables=None) -> Dict[str, jnp.ndarray]:
        """Per-slot contiguous view (identity for the ring)."""
        return cache


@dataclasses.dataclass(frozen=True)
class PagedLayout:
    """Global block pool: cache arrays are (N, block_size, ...) shared by
    every slot; ``block_tables`` (B, M) maps a slot's logical block
    ``pos // block_size`` to a physical pool block."""
    block_size: int

    def append(self, cache: Dict[str, jnp.ndarray], updates, cur_pos,
               block_tables=None) -> Dict[str, jnp.ndarray]:
        assert block_tables is not None, "paged layout needs block tables"
        b, m = block_tables.shape
        cur = _pos1d(cur_pos, b)
        logical = jnp.clip(cur // self.block_size, 0, m - 1)
        row = block_tables[jnp.arange(b), logical]
        # free / never-admitted slots have no blocks: park their writes in
        # the trash block (0) and keep its positions masked
        phys = jnp.where(row >= 0, row, 0)
        off = cur % self.block_size
        new = {k: cache[k].at[phys, off].set(u[:, 0])
               for k, u in updates.items()}
        new["pos"] = cache["pos"].at[phys, off].set(
            jnp.where(row >= 0, cur, -1))
        return new

    def attend(self, q, cache, q_pos, block_tables=None, *,
               window: Optional[int], scale: float,
               use_kernel: Optional[bool] = None,
               interpret: Optional[bool] = None):
        from repro.kernels.ops import paged_decode_attn
        return paged_decode_attn(q, cache["k"], cache["v"], q_pos,
                                 cache["pos"], block_tables, window=window,
                                 scale=scale, use_kernel=use_kernel,
                                 interpret=interpret)

    def context(self, cache, block_tables=None) -> Dict[str, jnp.ndarray]:
        """Gather each slot's blocks into a contiguous (B, M*bs, ...) view;
        unallocated table entries surface as pos −1 (fully masked)."""
        from repro.kernels.ref import gather_paged_kv
        out = {}
        pos = None
        for key, leaf in cache.items():
            if key == "pos":
                continue
            out[key], pos = gather_paged_kv(leaf, cache["pos"], block_tables)
        out["pos"] = pos
        return out


RING = RingLayout()


# ---------------------------------------------------------------------------
# Backends: the engine-level contract
# ---------------------------------------------------------------------------

class KVCacheBackend:
    """Engine-side cache owner.

    ``init`` returns the device cache state (a dict with "caches" — the
    model's cache pytree — and "tables", the (B, M) block tables or None).
    Admission is two-phase: the host calls ``alloc_slot`` (reserve blocks,
    may refuse), then passes the returned table row into ``prefill_fill``
    *inside* the jitted admit program. ``free_slot`` returns the blocks at
    completion. ``hbm_bytes`` is the device-resident KV footprint.
    """

    layout: Any

    def init(self) -> Dict[str, Any]:
        raise NotImplementedError

    def can_admit(self, prompt_len: int, max_new: int) -> bool:
        raise NotImplementedError

    def alloc_slot(self, slot: int, prompt_len: int,
                   max_new: int) -> np.ndarray:
        """Host-side reservation; returns the slot's block-table row (a
        dummy for backends without tables). Must only be called after
        ``can_admit`` said yes."""
        raise NotImplementedError

    def prefill_fill(self, cache_state, one_caches, slot, length, table_row):
        """Install a single-request prefilled cache into ``slot`` (traced
        inside the engine's admit program)."""
        raise NotImplementedError

    def free_slot(self, cache_state, slot: int) -> Dict[str, Any]:
        raise NotImplementedError

    def hbm_bytes(self) -> int:
        raise NotImplementedError

    def hbm_bytes_per_slot(self) -> float:
        raise NotImplementedError


def _cache_proto(lm, params, max_seq_len: int, proto_len: int):
    """Abstract per-request cache structure, as ``prefill`` returns it."""
    return jax.eval_shape(
        lambda p, t: lm.prefill(p, {"tokens": t},
                                cache_width=max_seq_len)[1],
        params, jax.ShapeDtypeStruct((1, proto_len), jnp.int32))


def _path_endswith(path, name: str) -> bool:
    return len(path) > 0 and getattr(path[-1], "key", None) == name


class RingCache(KVCacheBackend):
    """The original per-slot ring caches, extracted behind the API: every
    slot owns a full ``max_seq_len``-wide cache line in each layer."""

    def __init__(self, lm, params, *, batch_slots: int, max_seq_len: int,
                 proto_len: int = 16):
        self.layout = RING
        self.batch_slots = batch_slots
        self.max_seq_len = max_seq_len
        self._proto = _cache_proto(lm, params, max_seq_len, proto_len)

    def init(self) -> Dict[str, Any]:
        b = self.batch_slots

        def leaf(path, a):
            shape = (a.shape[0], b) + a.shape[2:]
            if _path_endswith(path, "pos"):
                return jnp.full(shape, -1, a.dtype)      # -1 = empty slot
            return jnp.zeros(shape, a.dtype)

        caches = jax.tree_util.tree_map_with_path(leaf, self._proto)
        return {"caches": caches, "tables": None}

    def can_admit(self, prompt_len: int, max_new: int) -> bool:
        return True                       # a granted slot is the only gate

    def alloc_slot(self, slot, prompt_len, max_new) -> np.ndarray:
        return np.zeros((1,), np.int32)   # no tables: fixed dummy row

    def prefill_fill(self, cache_state, one_caches, slot, length, table_row):
        caches = jax.tree.map(
            lambda g, c: jax.lax.dynamic_update_index_in_dim(
                g, c[:, 0], slot, axis=1),
            cache_state["caches"], one_caches)
        return {"caches": caches, "tables": cache_state["tables"]}

    def free_slot(self, cache_state, slot):
        return cache_state                # rings are reused in place

    def hbm_bytes(self) -> int:
        total = 0
        for leaf in jax.tree.leaves(self._proto):
            n = math.prod((leaf.shape[0], self.batch_slots) + leaf.shape[2:])
            total += n * leaf.dtype.itemsize
        return total

    def hbm_bytes_per_slot(self) -> float:
        return self.hbm_bytes() / self.batch_slots


class PagedCache(KVCacheBackend):
    """Block-table backend: a global pool of ``num_blocks`` blocks of
    ``block_size`` tokens per layer, allocated per request at admission and
    returned at completion. Slot count is bounded by live tokens in the
    pool, not by ``batch_slots × max_seq_len``."""

    def __init__(self, lm, params, *, batch_slots: int, max_seq_len: int,
                 block_size: int = 16, num_blocks: Optional[int] = None,
                 proto_len: int = 16):
        for stage in lm.cfg.stages:
            for bdef in stage.blocks:
                if bdef.mixer not in ("attn", "mla"):
                    raise NotImplementedError(
                        f"paged KV backend supports attention mixers only "
                        f"(got {bdef.mixer!r}); use cache_backend='ring'")
        self.layout = PagedLayout(block_size)
        self.batch_slots = batch_slots
        self.max_seq_len = max_seq_len
        self.block_size = block_size
        self.blocks_per_slot = -(-max_seq_len // block_size)   # table width M
        if num_blocks is None:
            # default to ring-equivalent capacity (+ the trash block)
            num_blocks = batch_slots * self.blocks_per_slot + 1
        if num_blocks < 2:
            raise ValueError("paged pool needs ≥ 2 blocks (block 0 is trash)")
        self.num_blocks = num_blocks
        self._proto = _cache_proto(lm, params, max_seq_len, proto_len)
        self._free: List[int] = list(range(1, num_blocks))     # 0 = trash
        self._slot_blocks: Dict[int, List[int]] = {}
        # accounting for the bench / capacity planning
        self.admitted = 0
        self.blocks_allocated_total = 0
        self.peak_blocks_in_use = 0

    # -- device state --------------------------------------------------------
    def init(self) -> Dict[str, Any]:
        n, bs = self.num_blocks, self.block_size

        def pool(d):
            out = {}
            for key, a in d.items():
                # proto leaves are (L, 1, W, ...): swap the per-request
                # (1, W) cache line for the (N, bs) pool
                shape = (a.shape[0], n, bs) + a.shape[3:]
                if key == "pos":
                    shape = (a.shape[0], n, bs)
                    out[key] = jnp.full(shape, -1, a.dtype)
                else:
                    out[key] = jnp.zeros(shape, a.dtype)
            return out

        caches = _map_kv_dicts(pool, self._proto)
        tables = jnp.full((self.batch_slots, self.blocks_per_slot), -1,
                          jnp.int32)
        return {"caches": caches, "tables": tables}

    # -- host-side allocator -------------------------------------------------
    def blocks_needed(self, prompt_len: int, max_new: int) -> int:
        return max(1, -(-(prompt_len + max_new) // self.block_size))

    def can_admit(self, prompt_len: int, max_new: int) -> bool:
        return self.blocks_needed(prompt_len, max_new) <= len(self._free)

    def alloc_slot(self, slot, prompt_len, max_new) -> np.ndarray:
        need = self.blocks_needed(prompt_len, max_new)
        if need > len(self._free):
            raise RuntimeError(f"paged pool exhausted: need {need} blocks, "
                               f"{len(self._free)} free")
        if slot in self._slot_blocks:
            raise RuntimeError(f"slot {slot} already holds blocks")
        blocks, self._free = self._free[:need], self._free[need:]
        self._slot_blocks[slot] = blocks
        row = np.full((self.blocks_per_slot,), -1, np.int32)
        row[:need] = blocks
        self.admitted += 1
        self.blocks_allocated_total += need
        self.peak_blocks_in_use = max(self.peak_blocks_in_use,
                                      self.blocks_in_use)
        return row

    @property
    def blocks_in_use(self) -> int:
        return (self.num_blocks - 1) - len(self._free)

    def reset_stats(self) -> None:
        """Zero the admission accounting (e.g. after bench warm-up) so
        ``hbm_bytes_per_slot`` averages only the measured traffic."""
        self.admitted = 0
        self.blocks_allocated_total = 0
        self.peak_blocks_in_use = self.blocks_in_use

    def free_slot(self, cache_state, slot):
        blocks = self._slot_blocks.pop(slot, None)
        if blocks is None:
            return cache_state
        self._free.extend(blocks)
        tables = cache_state["tables"].at[slot].set(-1)
        return {"caches": cache_state["caches"], "tables": tables}

    # -- admission-time install ---------------------------------------------
    def prefill_fill(self, cache_state, one_caches, slot, length, table_row):
        """Scatter a prefilled per-request cache into the slot's blocks.

        Tokens are routed by their *position* (block ``pos // bs``, offset
        ``pos % bs``), so ring-wrapped prefill caches (windowed layers with
        window < bucket) install correctly, and right-pad entries
        (pos ≥ length) are parked in the trash block with pos −1 — unlike
        the ring, the paged cache never exposes pad K/V at all.

        The row's blocks may be reused from a completed request, so their
        per-token positions are wiped to −1 first: a stale position from the
        previous tenant can land inside the new request's causal mask, and
        unlike the ring (which overwrites the whole cache line at admission)
        the paged install only writes the new prompt's prefix."""
        bs = self.block_size
        row_safe = jnp.where(table_row >= 0, table_row, 0)

        def fill(c, o):
            src_pos = o["pos"][0, 0]                      # (W,) layer-0 row
            valid = (src_pos >= 0) & (src_pos < length)
            logical = jnp.clip(src_pos, 0, self.max_seq_len - 1) // bs
            row_phys = jnp.take(table_row, logical)
            phys = jnp.where(valid & (row_phys >= 0), row_phys, 0)
            off = jnp.where(valid, src_pos % bs, 0)
            new = {}
            for key, leaf in c.items():
                if key == "pos":
                    cleared = leaf.at[:, row_safe, :].set(-1)
                    new[key] = cleared.at[:, phys, off].set(
                        jnp.where(valid, src_pos, -1)[None, :])
                else:
                    new[key] = leaf.at[:, phys, off].set(o[key][:, 0])
            return new

        caches = _map_kv_dicts(fill, cache_state["caches"], one_caches)
        tables = cache_state["tables"].at[slot].set(table_row)
        return {"caches": caches, "tables": tables}

    # -- accounting ----------------------------------------------------------
    def block_bytes(self) -> int:
        """Bytes one pool block costs across all layers."""
        total = 0
        for leaf in jax.tree.leaves(self._proto):
            per_tok = math.prod(leaf.shape[:1] + leaf.shape[3:])
            total += per_tok * self.block_size * leaf.dtype.itemsize
        return total

    def hbm_bytes(self) -> int:
        return self.block_bytes() * self.num_blocks

    def hbm_bytes_per_slot(self) -> float:
        """Average bytes actually reserved per admitted request (the ring
        equivalent is a constant ``max_seq_len`` line)."""
        if self.admitted == 0:
            return float(self.block_bytes() * self.blocks_per_slot)
        return self.block_bytes() * self.blocks_allocated_total / self.admitted


def make_backend(kind, lm, params, *, batch_slots: int, max_seq_len: int,
                 proto_len: int = 16, block_size: int = 16,
                 num_blocks: Optional[int] = None) -> KVCacheBackend:
    if isinstance(kind, KVCacheBackend):
        return kind
    if kind == "ring":
        return RingCache(lm, params, batch_slots=batch_slots,
                         max_seq_len=max_seq_len, proto_len=proto_len)
    if kind == "paged":
        return PagedCache(lm, params, batch_slots=batch_slots,
                          max_seq_len=max_seq_len, proto_len=proto_len,
                          block_size=block_size, num_blocks=num_blocks)
    raise ValueError(f"unknown cache backend {kind!r} "
                     "(expected 'ring' or 'paged')")
