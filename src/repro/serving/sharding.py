"""Mesh placement for the serving stack (tensor-parallel decode).

What shards, what stays host-global: params split per the decode-mode
``launch/sharding_rules`` (attention/KV heads, MLP and vocab projections on
the mesh's ``model`` axis); KV pools — ring lines ``(L, B, W, KV, hd)`` and
paged pools ``(L, N, bs, KV, hd)`` — split on the KV-head dim (dim 3) when
divisible. Everything the host mutates or reasons about stays replicated:
block tables, position slots, MLA latent caches (no head dim), the free
list and commitment ledger (plain Python on the host already).

This module deliberately imports only ``jax``, ``repro.sharding`` and
``repro.launch.sharding_rules`` so the serving package can pull it in from
``kv_cache``/``engine`` without an import cycle.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as PS

from repro.launch import sharding_rules as sr


def model_axis_size(mesh: Optional[Mesh]) -> int:
    """Ways the 'model' mesh axis splits KV heads (1 without a mesh)."""
    if mesh is None:
        return 1
    return int(dict(mesh.shape).get("model", 1))


def serving_rules(mesh: Mesh):
    """Default activation rules for mesh-aware decode: the decode-mode
    logical-axis rules the training dry-runs already validated."""
    return sr.act_rules(mesh, "decode")


def param_shardings(mesh: Mesh, lm):
    """NamedSharding tree for ``lm``'s params under decode-mode rules."""
    abstract, axes = lm.abstract()
    return sr.named(mesh, sr.param_pspecs(mesh, abstract, axes,
                                          mode="decode"))


def place_params(mesh: Mesh, lm, params):
    """Commit params to the mesh (KV/attention heads, MLP, vocab on
    'model'; output-side embed dims replicated — decode rules)."""
    return jax.device_put(params, param_shardings(mesh, lm))


def _kv_pool_leaf(path, leaf) -> bool:
    """True for the K/V pool leaves both backends store: ring lines
    (L, B, W, KV, hd) and paged pools (L, N, bs, KV, hd). MLA latents
    (``ckv``/``krope``, no head dim) and ``pos`` slots stay replicated."""
    name = path[-1].key if hasattr(path[-1], "key") else ""
    return name in ("k", "v") and getattr(leaf, "ndim", 0) == 5


def cache_pspecs(mesh: Mesh, cache_state):
    """PartitionSpec tree matching a serving cache state pytree: K/V pool
    leaves split dim 3 (KV heads) on 'model' when divisible, everything
    else — tables, pos, latents — replicated (host-global semantics)."""
    msize = model_axis_size(mesh)

    def spec(path, leaf):
        dims = [None] * leaf.ndim
        if _kv_pool_leaf(path, leaf) and leaf.shape[3] % msize == 0:
            dims[3] = "model"
        return PS(*dims)

    return jax.tree_util.tree_map_with_path(spec, cache_state)


def cache_shardings(mesh: Mesh, cache_state):
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        cache_pspecs(mesh, cache_state),
                        is_leaf=lambda x: isinstance(x, PS))


def place_cache_state(mesh: Mesh, cache_state):
    """Commit a backend's cache state to the mesh."""
    return jax.device_put(cache_state, cache_shardings(mesh, cache_state))


def assert_cache_placement(mesh: Mesh, cache_state) -> None:
    """Placement-coherence sweep: every device-array leaf must carry
    exactly the spec :func:`cache_pspecs` prescribes, split into
    equal-size shards whose bytes conserve the global leaf (one shard
    per device, shard_bytes x distinct_shards == leaf bytes)."""
    expected = cache_shardings(mesh, cache_state)
    ndev = mesh.size

    def check(path, leaf, want):
        if not hasattr(leaf, "sharding"):
            return
        assert leaf.sharding.is_equivalent_to(want, leaf.ndim), (
            f"cache leaf {jax.tree_util.keystr(path)}: sharding "
            f"{leaf.sharding} != expected {want}")
        shard_elems = math.prod(leaf.sharding.shard_shape(leaf.shape))
        total = math.prod(leaf.shape)
        assert shard_elems and total % shard_elems == 0, (
            f"cache leaf {jax.tree_util.keystr(path)}: shard shape "
            f"does not tile the global shape")
        per_dev = [s.data.nbytes for s in leaf.addressable_shards]
        shard_bytes = shard_elems * leaf.dtype.itemsize
        assert len(per_dev) == ndev and \
            all(b == shard_bytes for b in per_dev), (
                f"cache leaf {jax.tree_util.keystr(path)}: expected one "
                f"{shard_bytes}-byte shard per device, got {per_dev}")

    jax.tree_util.tree_map_with_path(check, cache_state, expected)
