"""Cascade serving engines: the ACE edge/cloud LM cascade over the serving
layer.

``CascadeEngine`` answers batched one-shot queries (single forward, the
video-query analog). ``CascadeServingEngine`` is the generative version on
the continuous-batching ``ServingEngine``: the edge draft prefills each
prompt once and its confidence gate routes the request — accepted prompts
generate on the edge engine, escalated ones on the cloud engine, dropped
ones are answered by the edge's greedy token alone. Both engines run
continuous batching internally, so a burst of escalations doesn't stall
the edge stream (the paper's bounded-cloud-compute property, now with
autoregressive workloads)."""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.cascade.ecc_infer import CascadeLM


@dataclasses.dataclass
class CascadeMetrics:
    queries: int = 0
    escalated: int = 0
    accepted: int = 0
    dropped: int = 0
    wan_bytes: int = 0
    agreement: float = 0.0      # edge-vs-final agreement rate (running)
    edge_failures: int = 0      # edge attempts that faulted/timed out
    rerouted: int = 0           # requests failed over edge -> cloud


@dataclasses.dataclass
class CircuitBreaker:
    """Classic three-state breaker guarding the edge path.

    closed: every request may try the edge. ``failure_threshold``
    *consecutive* edge failures trip it open (one success resets the
    count). open: requests go straight to the cloud without touching the
    edge; after ``cooldown`` denials the breaker goes half-open and lets
    the next request through as a probe. half-open: the probe's outcome
    decides — success closes the breaker, failure re-opens it (and
    restarts the cooldown). Counting in *requests*, not wall-clock,
    keeps chaos tests deterministic."""
    failure_threshold: int = 3
    cooldown: int = 4
    state: str = "closed"            # closed | open | half_open
    consecutive_failures: int = 0
    trips: int = 0                   # closed/half-open -> open transitions
    _denied: int = 0

    def allow(self) -> bool:
        """May this request try the edge? (Consumes one cooldown tick
        while open.)"""
        if self.state == "closed":
            return True
        if self.state == "open":
            self._denied += 1
            if self._denied >= self.cooldown:
                self.state = "half_open"
                return True          # this request is the probe
            return False
        return True                  # half-open: probe in flight

    def success(self) -> None:
        self.consecutive_failures = 0
        self.state = "closed"

    def failure(self) -> None:
        self.consecutive_failures += 1
        if (self.state == "half_open"
                or self.consecutive_failures >= self.failure_threshold):
            if self.state != "open":
                self.trips += 1
            self.state = "open"
            self._denied = 0


class CascadeEngine:
    def __init__(self, cascade: CascadeLM, edge_params, cloud_params, *,
                 compact: bool = True):
        self.cascade = cascade
        self.edge_params = edge_params
        self.cloud_params = cloud_params
        self.metrics = CascadeMetrics()
        fn = cascade.serve_step if compact else cascade.lockstep_step
        self._step = jax.jit(
            lambda ep, cp, batch: fn(ep, cp, batch))

    def query(self, tokens: np.ndarray, extra: Dict = None) -> dict:
        """tokens: (B, S) one-shot queries -> predictions + route info."""
        batch = {"tokens": jnp.asarray(tokens, jnp.int32)}
        if extra:
            batch.update({k: jnp.asarray(v) for k, v in extra.items()})
        t0 = time.time()
        out = self._step(self.edge_params, self.cloud_params, batch)
        out = {k: np.asarray(v) for k, v in out.items()}
        out["latency_s"] = time.time() - t0
        m = self.metrics
        b = tokens.shape[0]
        agree = float(np.mean(out["pred"] == out["edge_pred"]))
        m.agreement = ((m.agreement * m.queries + agree * b)
                       / max(m.queries + b, 1))
        m.queries += b
        m.escalated += int(out["escalate"])
        m.accepted += int(out["accept"])
        m.dropped += int(out["drop"])
        m.wan_bytes += int(out["wan_bytes"])
        return out


@dataclasses.dataclass
class CascadeRequest:
    request_id: int
    prompt: np.ndarray
    route: str = ""                  # accept | escalate | drop | failover
    conf: float = 0.0
    priority: int = 0                # SLO class, forwarded to the routed engine
    deadline_s: Optional[float] = None   # relative to *cascade* submit time
    submit_s: float = 0.0
    output: Optional[np.ndarray] = None
    latency_s: float = 0.0
    status: str = "queued"           # terminal: done|failed|rejected|cancelled
    failure_reason: Optional[str] = None


class CascadeServingEngine:
    """Generative ACE cascade on continuous-batching engines.

    One edge prefill gates every prompt (max-softmax confidence against the
    BP thresholds); generation then runs on the routed engine. The WAN cost
    model matches ``CascadeLM.serve_step``: escalations ship their token ids
    up and their generated ids down.
    """

    def __init__(self, cascade: CascadeLM, edge_params, cloud_params, *,
                 batch_slots: int = 8, max_seq_len: int = 256,
                 eos_id: Optional[int] = None, seed: int = 0,
                 cache_backend="ring", block_size: int = 16,
                 num_pool_blocks: Optional[int] = None,
                 truncate_prompts: bool = False,
                 chunk_tokens: Optional[int] = None,
                 token_budget: Optional[int] = None,
                 prefix_sharing: bool = True,
                 max_decode_steps: int = 1,
                 fault_plan=None,
                 breaker_failure_threshold: int = 3,
                 breaker_cooldown: int = 4,
                 admission_policy: Optional[str] = None):
        from repro.serving.engine import ServingEngine
        self.cascade = cascade
        self.max_seq_len = max_seq_len
        self.truncate_prompts = truncate_prompts
        self.metrics = CascadeMetrics()
        # fault tolerance: the ``edge`` seam of ``fault_plan`` models an
        # edge-engine outage at the gate; the breaker converts repeated
        # outages into wholesale cloud failover (no per-request edge
        # timeout while the edge is known-dead), and ``_degradation_s``
        # tracks an EWMA of the wall-clock each failed edge attempt burned
        # — failover deadlines are shrunk by it on top of the ordinary
        # gate-delay shrink, so the cloud engine sees the SLO budget the
        # degraded edge path actually left it
        self._faults = fault_plan
        self.breaker = CircuitBreaker(
            failure_threshold=breaker_failure_threshold,
            cooldown=breaker_cooldown)
        self._degradation_s = 0.0
        # both engines execute the same scheduler policy (token budget /
        # chunked prefill / prefix sharing / multi-step decode horizons
        # flow straight through); on a weak edge host the decode scan is
        # the bigger lever — the per-token host round-trip it removes is
        # exactly the edge-side overhead ACE's optimization layer targets
        engine_kw = dict(batch_slots=batch_slots, max_seq_len=max_seq_len,
                         eos_id=eos_id, cache_backend=cache_backend,
                         block_size=block_size,
                         num_pool_blocks=num_pool_blocks,
                         chunk_tokens=chunk_tokens, token_budget=token_budget,
                         prefix_sharing=prefix_sharing,
                         max_decode_steps=max_decode_steps,
                         admission_policy=admission_policy)
        self.edge_engine = ServingEngine(cascade.edge, edge_params,
                                         seed=seed, **engine_kw)
        self.cloud_engine = ServingEngine(cascade.cloud, cloud_params,
                                          seed=seed + 1, **engine_kw)

        def gate(params, tokens, length):
            # bucketed like engine prefill: right-padded, gate on the last
            # real position — bounds recompiles to the bucket set
            from repro.cascade.gate import (basic_gate,
                                            confidence_from_logits)
            logits, _, _, _ = cascade.edge.forward(params,
                                                   {"tokens": tokens})
            last = jax.lax.dynamic_index_in_dim(logits[0], length - 1,
                                                axis=0, keepdims=True)
            conf = confidence_from_logits(last)
            return conf[0], basic_gate(conf, cascade.thresholds)[0]

        self._gate = jax.jit(gate)
        self._edge_params = edge_params
        self._requests: List[CascadeRequest] = []
        self._next_id = 0

    def submit(self, prompt: np.ndarray, max_new_tokens: int = 16,
               temperature: float = 0.0, priority: int = 0,
               deadline_s: Optional[float] = None) -> int:
        from repro.serving.engine import validate_prompt
        # validate here (not at gate time): the gate prefills through the
        # same buckets, so an over-long prompt must fail fast with the
        # engine-level message, not deep inside bucket_for
        prompt = validate_prompt(prompt, max_new_tokens, self.max_seq_len,
                                 self.truncate_prompts)
        rid = self._next_id
        self._next_id += 1
        r = CascadeRequest(rid, prompt, priority=priority,
                           deadline_s=deadline_s)
        r.submit_s = time.perf_counter()
        r._gen = (max_new_tokens, temperature)
        self._requests.append(r)
        return rid

    def _inner_deadline(self, r: CascadeRequest) -> Optional[float]:
        """Deadline for the routed engine, shrunk by the time the request
        already spent queued at the gate: the inner engine stamps its own
        submit time, so forwarding the raw relative deadline would extend
        the SLO by the gate delay. May go negative — EDF then simply ranks
        the already-late request first in its class."""
        if r.deadline_s is None:
            return None
        return r.deadline_s - (time.perf_counter() - r.submit_s)

    def _failover_deadline(self, r: CascadeRequest) -> Optional[float]:
        """Deadline forwarded on the edge→cloud failover path: the gate
        delay already elapsed (``_inner_deadline``) *plus* the observed
        edge degradation — the EWMA of wall-clock burned per failed edge
        attempt. The cloud engine's EDF/admission then sees the budget
        the degraded path actually left, instead of an optimistic one."""
        d = self._inner_deadline(r)
        if d is None:
            return None
        return d - self._degradation_s

    def run(self) -> Dict[int, CascadeRequest]:
        """Gate every pending request, generate on the routed engine.

        The circuit breaker guards the edge attempt: while it is open,
        requests skip the gate entirely and fail over to the cloud
        (route "failover") with a deadline shrunk by the observed
        degradation; an injected edge outage (``FaultPlan`` seam
        ``edge``) feeds the breaker's failure count, and a half-open
        probe closes it again once the edge recovers."""
        from repro.cascade.gate import ACCEPT, ESCALATE
        from repro.serving.faults import FaultError
        pending, self._requests = self._requests, []
        routed: Dict[int, CascadeRequest] = {}
        edge_ids, cloud_ids = {}, {}
        t0 = time.perf_counter()
        from repro.serving.engine import bucket_for
        for r in pending:
            max_new, temp = r._gen
            m = self.metrics
            m.queries += 1
            routed[r.request_id] = r
            conf = route = None
            if self.breaker.allow():
                attempt0 = time.perf_counter()
                try:
                    if self._faults is not None:
                        self._faults.check("edge", "edge gate prefill")
                    bucket = bucket_for(len(r.prompt),
                                        self.edge_engine.buckets)
                    tokens = np.zeros((1, bucket), np.int32)
                    tokens[0, :len(r.prompt)] = r.prompt
                    conf, route = self._gate(self._edge_params,
                                             jnp.asarray(tokens),
                                             jnp.int32(len(r.prompt)))
                    self.breaker.success()
                except FaultError:
                    self.breaker.failure()
                    m.edge_failures += 1
                    lost = time.perf_counter() - attempt0
                    a = 0.25
                    self._degradation_s = lost if m.edge_failures == 1 \
                        else (1.0 - a) * self._degradation_s + a * lost
            if route is None:
                # breaker open, or this edge attempt failed: cloud failover
                r.route = "failover"
                m.rerouted += 1
                m.wan_bytes += len(r.prompt) * 4 + max_new * 4
                cloud_ids[self.cloud_engine.submit(
                    r.prompt, max_new, temp, priority=r.priority,
                    deadline_s=self._failover_deadline(r))] = r
                continue
            r.conf = float(conf)
            code = int(route)
            if code == int(ESCALATE):
                r.route = "escalate"
                m.escalated += 1
                # token ids up + generated ids down (cf. serve_step)
                m.wan_bytes += len(r.prompt) * 4 + max_new * 4
                cloud_ids[self.cloud_engine.submit(
                    r.prompt, max_new, temp, priority=r.priority,
                    deadline_s=self._inner_deadline(r))] = r
            elif code == int(ACCEPT):
                r.route = "accept"
                m.accepted += 1
                edge_ids[self.edge_engine.submit(
                    r.prompt, max_new, temp, priority=r.priority,
                    deadline_s=self._inner_deadline(r))] = r
            else:
                r.route = "drop"
                m.dropped += 1
                r.output = np.zeros((0,), np.int32)
                r.status = "done"
                r.latency_s = time.perf_counter() - t0   # answered at gate
        for ids, eng in ((edge_ids, self.edge_engine),
                         (cloud_ids, self.cloud_engine)):
            for rid, served in eng.run().items():
                if rid in ids:
                    ids[rid].output = served.output
                    ids[rid].latency_s = served.latency_s
                    ids[rid].status = served.status
                    ids[rid].failure_reason = served.failure_reason
        return routed

    def engine_metrics(self) -> Dict[str, object]:
        """Monitoring snapshot across the cascade: routing/WAN counters,
        breaker state, and both inner engines' ``metrics()``."""
        m = self.metrics
        return {
            "queries": m.queries, "accepted": m.accepted,
            "escalated": m.escalated, "dropped": m.dropped,
            "rerouted": m.rerouted, "edge_failures": m.edge_failures,
            "wan_bytes": m.wan_bytes,
            "breaker": {"state": self.breaker.state,
                        "trips": self.breaker.trips,
                        "consecutive_failures":
                            self.breaker.consecutive_failures},
            "degradation_s": self._degradation_s,
            "edge": self.edge_engine.metrics(),
            "cloud": self.cloud_engine.metrics(),
        }
