"""Cascade serving engines: the ACE edge/cloud LM cascade over the serving
layer.

``CascadeEngine`` answers batched one-shot queries (single forward, the
video-query analog). ``CascadeServingEngine`` is the generative version on
the continuous-batching ``ServingEngine``: the edge draft prefills each
prompt once and its confidence gate routes the request — accepted prompts
generate on the edge engine, escalated ones on the cloud engine, dropped
ones are answered by the edge's greedy token alone. Both engines run
continuous batching internally, so a burst of escalations doesn't stall
the edge stream (the paper's bounded-cloud-compute property, now with
autoregressive workloads)."""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.cascade.ecc_infer import CascadeLM


@dataclasses.dataclass
class CascadeMetrics:
    queries: int = 0
    escalated: int = 0
    accepted: int = 0
    dropped: int = 0
    wan_bytes: int = 0
    agreement: float = 0.0      # edge-vs-final agreement rate (running)
    edge_failures: int = 0      # edge attempts that faulted/timed out
    rerouted: int = 0           # requests failed over edge -> cloud


@dataclasses.dataclass
class CircuitBreaker:
    """Classic three-state breaker guarding the edge path.

    closed: every request may try the edge. ``failure_threshold``
    *consecutive* edge failures trip it open (one success resets the
    count). open: requests go straight to the cloud without touching the
    edge; after ``cooldown`` denials the breaker goes half-open and lets
    the next request through as a probe. half-open: the probe's outcome
    decides — success closes the breaker, failure re-opens it (and
    restarts the cooldown). Counting in *requests*, not wall-clock,
    keeps chaos tests deterministic."""
    failure_threshold: int = 3
    cooldown: int = 4
    state: str = "closed"            # closed | open | half_open
    consecutive_failures: int = 0
    trips: int = 0                   # closed/half-open -> open transitions
    _denied: int = 0

    def allow(self) -> bool:
        """May this request try the edge? (Consumes one cooldown tick
        while open.)"""
        if self.state == "closed":
            return True
        if self.state == "open":
            self._denied += 1
            if self._denied >= self.cooldown:
                self.state = "half_open"
                return True          # this request is the probe
            return False
        return True                  # half-open: probe in flight

    def success(self) -> None:
        self.consecutive_failures = 0
        self.state = "closed"

    def failure(self) -> None:
        self.consecutive_failures += 1
        if (self.state == "half_open"
                or self.consecutive_failures >= self.failure_threshold):
            if self.state != "open":
                self.trips += 1
            self.state = "open"
            self._denied = 0


class CascadeEngine:
    def __init__(self, cascade: CascadeLM, edge_params, cloud_params, *,
                 compact: bool = True):
        self.cascade = cascade
        self.edge_params = edge_params
        self.cloud_params = cloud_params
        self.metrics = CascadeMetrics()
        fn = cascade.serve_step if compact else cascade.lockstep_step
        self._step = jax.jit(
            lambda ep, cp, batch: fn(ep, cp, batch))

    def query(self, tokens: np.ndarray, extra: Dict = None) -> dict:
        """tokens: (B, S) one-shot queries -> predictions + route info."""
        batch = {"tokens": jnp.asarray(tokens, jnp.int32)}
        if extra:
            batch.update({k: jnp.asarray(v) for k, v in extra.items()})
        t0 = time.time()
        out = self._step(self.edge_params, self.cloud_params, batch)
        out = {k: np.asarray(v) for k, v in out.items()}
        out["latency_s"] = time.time() - t0
        m = self.metrics
        b = tokens.shape[0]
        agree = float(np.mean(out["pred"] == out["edge_pred"]))
        m.agreement = ((m.agreement * m.queries + agree * b)
                       / max(m.queries + b, 1))
        m.queries += b
        m.escalated += int(out["escalate"])
        m.accepted += int(out["accept"])
        m.dropped += int(out["drop"])
        m.wan_bytes += int(out["wan_bytes"])
        return out


@dataclasses.dataclass
class CascadeRequest:
    request_id: int
    prompt: np.ndarray
    route: str = ""                  # accept | escalate | drop | failover
    conf: float = 0.0
    priority: int = 0                # SLO class, forwarded to the routed engine
    deadline_s: Optional[float] = None   # relative to *cascade* submit time
    submit_s: float = 0.0
    enqueue_s: float = 0.0           # cascade-queue entry (gateway forward)
    output: Optional[np.ndarray] = None
    ttft_s: float = 0.0              # from cascade submit (gate wait included)
    finish_s: float = 0.0
    latency_s: float = 0.0
    status: str = "queued"           # terminal: done|failed|rejected|cancelled
    failure_reason: Optional[str] = None


class CascadeServingEngine:
    """Generative ACE cascade on continuous-batching engines.

    One edge prefill gates every prompt (max-softmax confidence against the
    BP thresholds); generation then runs on the routed engine. The WAN cost
    model matches ``CascadeLM.serve_step``: escalations ship their token ids
    up and their generated ids down.
    """

    def __init__(self, cascade: CascadeLM, edge_params, cloud_params, *,
                 batch_slots: int = 8, max_seq_len: int = 256,
                 eos_id: Optional[int] = None, seed: int = 0,
                 cache_backend="ring", block_size: int = 16,
                 num_pool_blocks: Optional[int] = None,
                 truncate_prompts: bool = False,
                 chunk_tokens: Optional[int] = None,
                 token_budget: Optional[int] = None,
                 prefix_sharing: bool = True,
                 max_decode_steps: int = 1,
                 fault_plan=None,
                 breaker_failure_threshold: int = 3,
                 breaker_cooldown: int = 4,
                 admission_policy: Optional[str] = None,
                 speculative_tokens: int = 0,
                 mesh=None, rules=None):
        from repro.serving.engine import ServingEngine
        self.cascade = cascade
        self.max_seq_len = max_seq_len
        self.truncate_prompts = truncate_prompts
        self.metrics = CascadeMetrics()
        # fault tolerance: the ``edge`` seam of ``fault_plan`` models an
        # edge-engine outage at the gate; the breaker converts repeated
        # outages into wholesale cloud failover (no per-request edge
        # timeout while the edge is known-dead), and ``_degradation_s``
        # tracks an EWMA of the wall-clock each failed edge attempt burned
        # — failover deadlines are shrunk by it on top of the ordinary
        # gate-delay shrink, so the cloud engine sees the SLO budget the
        # degraded edge path actually left it
        self._faults = fault_plan
        self.breaker = CircuitBreaker(
            failure_threshold=breaker_failure_threshold,
            cooldown=breaker_cooldown)
        self._degradation_s = 0.0
        # both engines execute the same scheduler policy (token budget /
        # chunked prefill / prefix sharing / multi-step decode horizons
        # flow straight through); on a weak edge host the decode scan is
        # the bigger lever — the per-token host round-trip it removes is
        # exactly the edge-side overhead ACE's optimization layer targets
        engine_kw = dict(batch_slots=batch_slots, max_seq_len=max_seq_len,
                         eos_id=eos_id, cache_backend=cache_backend,
                         block_size=block_size,
                         num_pool_blocks=num_pool_blocks,
                         chunk_tokens=chunk_tokens, token_budget=token_budget,
                         prefix_sharing=prefix_sharing,
                         max_decode_steps=max_decode_steps,
                         admission_policy=admission_policy,
                         # mesh-aware serving: both legs ride the same mesh
                         # (each engine places its own params/pool; leaves
                         # whose dims don't divide simply replicate)
                         mesh=mesh, rules=rules)
        self.edge_engine = ServingEngine(cascade.edge, edge_params,
                                         seed=seed, **engine_kw)
        # speculative cloud decode with the cascade's own edge model as the
        # draft: the ACE edge/cloud split *is* a draft/verify pair — the
        # same small model that gates prompts proposes tokens the big one
        # verifies in a single chunked dispatch. The edge engine itself
        # never speculates (it has no smaller model to draft for it).
        self.cloud_engine = ServingEngine(
            cascade.cloud, cloud_params, seed=seed + 1,
            draft_model=cascade.edge if speculative_tokens > 0 else None,
            draft_params=edge_params if speculative_tokens > 0 else None,
            speculative_tokens=speculative_tokens, **engine_kw)

        def gate(params, tokens, length):
            # bucketed like engine prefill: right-padded, gate on the last
            # real position — bounds recompiles to the bucket set
            from repro.cascade.gate import (basic_gate,
                                            confidence_from_logits)
            logits, _, _, _ = cascade.edge.forward(params,
                                                   {"tokens": tokens})
            last = jax.lax.dynamic_index_in_dim(logits[0], length - 1,
                                                axis=0, keepdims=True)
            conf = confidence_from_logits(last)
            return conf[0], basic_gate(conf, cascade.thresholds)[0]

        self._gate = jax.jit(gate)
        self._edge_params = edge_params
        self.batch_slots = batch_slots
        self._requests: List[CascadeRequest] = []
        self._next_id = 0
        # gateway protocol state: routed-but-live requests by *inner*
        # request id, terminal requests awaiting take_done, and the
        # optional per-step token tap (translated to cascade ids)
        self._edge_map: Dict[int, CascadeRequest] = {}
        self._cloud_map: Dict[int, CascadeRequest] = {}
        self._done: Dict[int, CascadeRequest] = {}
        self._on_tokens = None
        # durability counters (cascade-level; the legs keep their own)
        self.restores = 0
        self.hang_recoveries = 0

    def submit(self, prompt: np.ndarray, max_new_tokens: int = 16,
               temperature: float = 0.0, priority: int = 0,
               deadline_s: Optional[float] = None) -> int:
        r = self.make_request(prompt, max_new_tokens, temperature,
                              priority=priority, deadline_s=deadline_s)
        self.enqueue(r)
        return r.request_id

    def make_request(self, prompt: np.ndarray, max_new_tokens: int = 16,
                     temperature: float = 0.0, priority: int = 0,
                     deadline_s: Optional[float] = None) -> CascadeRequest:
        """Validate and stamp a request without queueing it — the async
        gateway's seam for boundary-accurate ``submit_s`` (same contract
        as ``ServingEngine.make_request``)."""
        from repro.serving.engine import validate_prompt
        # validate here (not at gate time): the gate prefills through the
        # same buckets, so an over-long prompt must fail fast with the
        # engine-level message, not deep inside bucket_for
        prompt = validate_prompt(prompt, max_new_tokens, self.max_seq_len,
                                 self.truncate_prompts)
        rid = self._next_id
        self._next_id += 1
        r = CascadeRequest(rid, prompt, priority=priority,
                           deadline_s=deadline_s)
        r.submit_s = time.perf_counter()
        r._gen = (max_new_tokens, temperature)
        return r

    def enqueue(self, r: CascadeRequest, *, ahead_extra: int = 0) -> None:
        """Queue a made request for the next gate round. Cascade-level
        admission is the *inner* engines' job at route time (their
        deadline budgets are already shrunk by gate wait), so this never
        refuses; ``ahead_extra`` is accepted for protocol parity."""
        del ahead_extra
        r.enqueue_s = time.perf_counter()
        self._requests.append(r)

    def queue_depth(self) -> int:
        return (len(self._requests) + self.edge_engine.queue_depth()
                + self.cloud_engine.queue_depth())

    @property
    def on_tokens(self):
        return self._on_tokens

    @on_tokens.setter
    def on_tokens(self, cb) -> None:
        """Install a per-step token tap; inner-engine request ids are
        translated to cascade ids through the live routing maps."""
        self._on_tokens = cb
        if cb is None:
            self.edge_engine.on_tokens = None
            self.cloud_engine.on_tokens = None
            return

        def translated(mapping):
            def tap(events):
                out = [(mapping[rid].request_id, arr)
                       for rid, arr in events if rid in mapping]
                if out:
                    cb(out)
            return tap

        self.edge_engine.on_tokens = translated(self._edge_map)
        self.cloud_engine.on_tokens = translated(self._cloud_map)

    def _inner_deadline(self, r: CascadeRequest) -> Optional[float]:
        """Deadline for the routed engine, shrunk by the time the request
        already spent queued at the gate: the inner engine stamps its own
        submit time, so forwarding the raw relative deadline would extend
        the SLO by the gate delay. May go negative — EDF then simply ranks
        the already-late request first in its class."""
        if r.deadline_s is None:
            return None
        return r.deadline_s - (time.perf_counter() - r.submit_s)

    def _failover_deadline(self, r: CascadeRequest) -> Optional[float]:
        """Deadline forwarded on the edge→cloud failover path: the gate
        delay already elapsed (``_inner_deadline``) *plus* the observed
        edge degradation — the EWMA of wall-clock burned per failed edge
        attempt. The cloud engine's EDF/admission then sees the budget
        the degraded path actually left, instead of an optimistic one."""
        d = self._inner_deadline(r)
        if d is None:
            return None
        return d - self._degradation_s

    def _route_pending(self) -> None:
        """Gate every queued request and hand it to its routed engine.

        The circuit breaker guards the edge attempt: while it is open,
        requests skip the gate entirely and fail over to the cloud
        (route "failover") with a deadline shrunk by the observed
        degradation; an injected edge outage (``FaultPlan`` seam
        ``edge``) feeds the breaker's failure count, and a half-open
        probe closes it again once the edge recovers."""
        from repro.cascade.gate import ACCEPT, ESCALATE
        from repro.serving.engine import bucket_for
        from repro.serving.faults import FaultError
        pending, self._requests = self._requests, []
        for r in pending:
            max_new, temp = r._gen
            m = self.metrics
            m.queries += 1
            conf = route = None
            if self.breaker.allow():
                attempt0 = time.perf_counter()
                try:
                    if self._faults is not None:
                        self._faults.check("edge", "edge gate prefill")
                    bucket = bucket_for(len(r.prompt),
                                        self.edge_engine.buckets)
                    tokens = np.zeros((1, bucket), np.int32)
                    tokens[0, :len(r.prompt)] = r.prompt
                    conf, route = self._gate(self._edge_params,
                                             jnp.asarray(tokens),
                                             jnp.int32(len(r.prompt)))
                    self.breaker.success()
                except FaultError:
                    self.breaker.failure()
                    m.edge_failures += 1
                    lost = time.perf_counter() - attempt0
                    a = 0.25
                    self._degradation_s = lost if m.edge_failures == 1 \
                        else (1.0 - a) * self._degradation_s + a * lost
            if route is None:
                # breaker open, or this edge attempt failed: cloud failover
                r.route = "failover"
                m.rerouted += 1
                m.wan_bytes += len(r.prompt) * 4 + max_new * 4
                self._cloud_map[self.cloud_engine.submit(
                    r.prompt, max_new, temp, priority=r.priority,
                    deadline_s=self._failover_deadline(r))] = r
                continue
            r.conf = float(conf)
            code = int(route)
            if code == int(ESCALATE):
                r.route = "escalate"
                m.escalated += 1
                # token ids up + generated ids down (cf. serve_step)
                m.wan_bytes += len(r.prompt) * 4 + max_new * 4
                self._cloud_map[self.cloud_engine.submit(
                    r.prompt, max_new, temp, priority=r.priority,
                    deadline_s=self._inner_deadline(r))] = r
            elif code == int(ACCEPT):
                r.route = "accept"
                m.accepted += 1
                self._edge_map[self.edge_engine.submit(
                    r.prompt, max_new, temp, priority=r.priority,
                    deadline_s=self._inner_deadline(r))] = r
            else:
                r.route = "drop"
                m.dropped += 1
                r.output = np.zeros((0,), np.int32)
                r.status = "done"
                r.finish_s = time.perf_counter()   # answered at the gate
                r.latency_s = r.finish_s - r.submit_s
                self._done[r.request_id] = r

    def _collect(self) -> None:
        """Translate inner-engine terminal requests to cascade terms.
        Latency/TTFT re-baseline onto the *cascade* submit stamp so gate
        wait (and breaker cooldown) counts toward the client-visible
        numbers, not just routed-engine service."""
        for ids, eng in ((self._edge_map, self.edge_engine),
                         (self._cloud_map, self.cloud_engine)):
            for rid, served in eng.take_done().items():
                r = ids.pop(rid, None)
                if r is None:
                    continue
                r.output = served.output
                r.status = served.status
                r.failure_reason = served.failure_reason
                if served.ttft_s > 0.0:
                    r.ttft_s = (served.submit_s - r.submit_s
                                + served.ttft_s)
                r.finish_s = (served.finish_s if served.finish_s
                              else time.perf_counter())
                r.latency_s = r.finish_s - r.submit_s
                self._done[r.request_id] = r

    @property
    def pending(self) -> bool:
        """Work outstanding anywhere in the cascade: ungated requests,
        routed-but-uncollected ones, or live inner-engine work."""
        return bool(self._requests or self._edge_map or self._cloud_map
                    or self.edge_engine.pending or self.cloud_engine.pending)

    def step(self) -> None:
        """One cascade round: gate whatever queued since the last round,
        advance each inner engine one step, collect terminals. Public for
        the async gateway's driver loop; ``run`` is this in a drain loop."""
        self._route_pending()
        for eng in (self.edge_engine, self.cloud_engine):
            if eng.pending:
                eng.step()
        self._collect()

    def take_done(self) -> Dict[int, CascadeRequest]:
        """Drain terminal cascade requests accumulated since last call."""
        done, self._done = self._done, {}
        return done

    def cancel(self, request_id: int) -> bool:
        """Cancel a cascade request wherever it lives: awaiting the gate,
        or in flight on its routed engine (any phase — the inner engine
        handles queued/prefill/decode)."""
        for r in self._requests:
            if r.request_id == request_id:
                self._requests.remove(r)
                r.output = np.zeros((0,), np.int32)
                r.status = "cancelled"
                r.failure_reason = "cancelled: awaiting gate"
                r.finish_s = time.perf_counter()
                r.latency_s = r.finish_s - r.submit_s
                self._done[r.request_id] = r
                return True
        for ids, eng in ((self._edge_map, self.edge_engine),
                         (self._cloud_map, self.cloud_engine)):
            for irid, r in list(ids.items()):
                if r.request_id == request_id:
                    ok = eng.cancel(irid)
                    self._collect()   # surface the terminal immediately
                    return ok
        return False

    def run(self) -> Dict[int, CascadeRequest]:
        """Drain loop: gate + generate until nothing is in flight."""
        while self.pending:
            self.step()
        return self.take_done()

    def engine_metrics(self) -> Dict[str, object]:
        """Monitoring snapshot across the cascade: routing/WAN counters,
        breaker state, durability counters, and both inner engines'
        ``metrics()``."""
        m = self.metrics
        return {
            "queries": m.queries, "accepted": m.accepted,
            "escalated": m.escalated, "dropped": m.dropped,
            "rerouted": m.rerouted, "edge_failures": m.edge_failures,
            "wan_bytes": m.wan_bytes,
            "breaker": {"state": self.breaker.state,
                        "trips": self.breaker.trips,
                        "consecutive_failures":
                            self.breaker.consecutive_failures},
            "degradation_s": self._degradation_s,
            "restores": self.restores,
            "hang_recoveries": self.hang_recoveries,
            "edge": self.edge_engine.metrics(),
            "cloud": self.cloud_engine.metrics(),
        }

    def warm_compile(self) -> None:
        """Pre-compile both legs (the gateway's watchdog warm-up seam —
        see ``ServingEngine.warm_compile``). The gate's prefill shares
        the edge engine's bucket set, so it is warmed implicitly."""
        self.edge_engine.warm_compile()
        self.cloud_engine.warm_compile()

    # -- durability -----------------------------------------------------------
    def note_hang(self) -> None:
        """Watchdog escalation across the cascade. A cascade ``step``
        interleaves the gate and both legs, and the wall-clock deadline
        cannot tell which leg stalled — roll both back (token-exact, so
        correctness never depends on pinpointing the stall)."""
        self.hang_recoveries += 1
        for eng in (self.edge_engine, self.cloud_engine):
            if eng._slots:
                eng.note_hang()

    def _live_cascade_requests(self) -> List[CascadeRequest]:
        return (list(self._requests) + list(self._edge_map.values())
                + list(self._cloud_map.values()))

    def known_request_ids(self) -> set:
        ids = {r.request_id for r in self._live_cascade_requests()}
        ids.update(self._done.keys())
        return ids

    def snapshot(self) -> Dict[str, object]:
        """Serialize the whole cascade: both legs' engine snapshots (so
        routed requests resume token-exact on their original leg) plus
        the cascade's own request table, routing maps, breaker state and
        running metrics. Same contract as ``ServingEngine.snapshot`` —
        non-destructive, nested string-keyed dicts, ``save_snapshot``-
        ready."""
        from repro.checkpoint.io import json_leaf
        now = time.perf_counter()
        requests: Dict[str, Dict[str, object]] = {}

        def record(r: CascadeRequest, phase: str, leg: Optional[str],
                   inner_rid: Optional[int]) -> None:
            max_new, temp = r._gen
            rec: Dict[str, object] = {"meta": json_leaf({
                "rid": r.request_id, "phase": phase, "leg": leg,
                "inner_rid": inner_rid, "route": r.route,
                "conf": r.conf, "priority": r.priority,
                "deadline_s": r.deadline_s,
                "age_s": now - r.submit_s if r.submit_s else 0.0,
                "ttft_s": r.ttft_s, "status": r.status,
                "failure_reason": r.failure_reason,
                "latency_s": r.latency_s,
                "max_new_tokens": max_new, "temperature": temp}),
                "prompt": np.asarray(r.prompt, np.int32)}
            if phase == "terminal" and r.output is not None \
                    and len(r.output):
                rec["output"] = np.asarray(r.output, np.int32)
            requests[f"r{r.request_id:08d}"] = rec

        for r in self._requests:
            record(r, "pending", None, None)
        for leg, mapping in (("edge", self._edge_map),
                             ("cloud", self._cloud_map)):
            for inner_rid, r in mapping.items():
                record(r, "routed", leg, inner_rid)
        for r in self._done.values():
            record(r, "terminal", None, None)

        meta = {"kind": type(self).__name__, "next_id": self._next_id,
                "degradation_s": self._degradation_s,
                "breaker": {"state": self.breaker.state,
                            "consecutive_failures":
                                self.breaker.consecutive_failures,
                            "trips": self.breaker.trips,
                            "denied": self.breaker._denied},
                "metrics": dataclasses.asdict(self.metrics)}
        return {"engine": json_leaf(meta), "requests": requests,
                "edge": self.edge_engine.snapshot(),
                "cloud": self.cloud_engine.snapshot()}

    def restore(self, snap: Dict[str, object]) -> Dict[str, int]:
        """Load a cascade ``snapshot`` into this (cold) engine: the legs
        restore their own requests first (checkpoints intact), then the
        cascade table re-links routed requests to them by inner id.
        Breaker state, degradation EWMA and routing metrics carry over —
        a breaker that was open stays open across the restart."""
        from repro.checkpoint.io import json_unleaf
        if (self._requests or self._edge_map or self._cloud_map
                or self._done):
            raise RuntimeError("restore() needs a cold cascade engine")
        inner = {"edge": self.edge_engine.restore(snap["edge"]),
                 "cloud": self.cloud_engine.restore(snap["cloud"])}
        eng = json_unleaf(snap["engine"])
        now = time.perf_counter()
        live = terminal = 0
        for key in sorted(snap.get("requests", {})):
            rec = snap["requests"][key]
            meta = json_unleaf(rec["meta"])
            r = CascadeRequest(int(meta["rid"]),
                               np.asarray(rec["prompt"], np.int32),
                               route=meta["route"] or "",
                               conf=float(meta["conf"]),
                               priority=int(meta["priority"]),
                               deadline_s=meta["deadline_s"])
            r.submit_s = now - float(meta["age_s"])
            r.enqueue_s = now
            r.ttft_s = float(meta["ttft_s"])
            r._gen = (int(meta["max_new_tokens"]),
                      float(meta["temperature"]))
            if meta["phase"] == "terminal":
                r.status = meta["status"]
                r.failure_reason = meta["failure_reason"]
                r.latency_s = float(meta["latency_s"])
                r.finish_s = now
                out = rec.get("output")
                r.output = (np.asarray(out, np.int32) if out is not None
                            else np.zeros((0,), np.int32))
                self._done[r.request_id] = r
                terminal += 1
                continue
            if meta["phase"] == "routed":
                mapping = (self._edge_map if meta["leg"] == "edge"
                           else self._cloud_map)
                mapping[int(meta["inner_rid"])] = r
            else:
                self._requests.append(r)
            live += 1
        self._next_id = max(self._next_id, int(eng["next_id"]))
        self._degradation_s = float(eng["degradation_s"])
        bk = eng["breaker"]
        self.breaker.state = bk["state"]
        self.breaker.consecutive_failures = bk["consecutive_failures"]
        self.breaker.trips = bk["trips"]
        self.breaker._denied = bk["denied"]
        self.metrics = CascadeMetrics(**eng["metrics"])
        self.restores += 1
        return {"live": live, "terminal": terminal, "inner": inner}

    def requeue_lost(self, request_id: int, prompt: np.ndarray,
                     max_new_tokens: int = 16, temperature: float = 0.0,
                     priority: int = 0,
                     deadline_s: Optional[float] = None) -> CascadeRequest:
        """Journal replay (same contract as the flat engine): re-queue a
        crash-lost submission under its original id, back at the gate —
        it re-routes from scratch."""
        from repro.serving.engine import validate_prompt
        prompt = validate_prompt(prompt, max_new_tokens, self.max_seq_len,
                                 self.truncate_prompts)
        r = CascadeRequest(int(request_id), prompt, priority=priority,
                           deadline_s=deadline_s)
        r.submit_s = time.perf_counter()
        r.enqueue_s = r.submit_s
        r._gen = (max_new_tokens, temperature)
        self._next_id = max(self._next_id, int(request_id) + 1)
        self._requests.append(r)
        return r
