"""Cascade serving engine: batched one-shot queries through the ACE
edge/cloud LM cascade, with running BWC/escalation metrics — the serving
analog of the video-query application."""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.cascade.ecc_infer import CascadeLM


@dataclasses.dataclass
class CascadeMetrics:
    queries: int = 0
    escalated: int = 0
    accepted: int = 0
    dropped: int = 0
    wan_bytes: int = 0
    agreement: float = 0.0      # edge-vs-final agreement rate (running)


class CascadeEngine:
    def __init__(self, cascade: CascadeLM, edge_params, cloud_params, *,
                 compact: bool = True):
        self.cascade = cascade
        self.edge_params = edge_params
        self.cloud_params = cloud_params
        self.metrics = CascadeMetrics()
        fn = cascade.serve_step if compact else cascade.lockstep_step
        self._step = jax.jit(
            lambda ep, cp, batch: fn(ep, cp, batch))

    def query(self, tokens: np.ndarray, extra: Dict = None) -> dict:
        """tokens: (B, S) one-shot queries -> predictions + route info."""
        batch = {"tokens": jnp.asarray(tokens, jnp.int32)}
        if extra:
            batch.update({k: jnp.asarray(v) for k, v in extra.items()})
        t0 = time.time()
        out = self._step(self.edge_params, self.cloud_params, batch)
        out = {k: np.asarray(v) for k, v in out.items()}
        out["latency_s"] = time.time() - t0
        m = self.metrics
        b = tokens.shape[0]
        agree = float(np.mean(out["pred"] == out["edge_pred"]))
        m.agreement = ((m.agreement * m.queries + agree * b)
                       / max(m.queries + b, 1))
        m.queries += b
        m.escalated += int(out["escalate"])
        m.accepted += int(out["accept"])
        m.dropped += int(out["drop"])
        m.wan_bytes += int(out["wan_bytes"])
        return out
