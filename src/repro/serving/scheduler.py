"""Token-budget step scheduler: the serving-engine policy layer.

Each engine step used to be "admit every queued prompt that fits (one
monolithic prefill each), then run one decode round" — a burst of long
prompts stalls every in-flight decode for the whole burst's prefill time,
exactly the tail-latency behavior ACE's performance-optimization layer is
meant to remove. The ``Scheduler`` pulls that policy out of
``ServingEngine.run()`` and composes each step as a *mixed batch* under a
configurable token budget:

- one decode token for every active slot (decode always proceeds), plus
- one or more *prompt chunks* for admitting requests, consuming whatever
  budget the decodes left.

Chunks are bucketed to a small power-of-two shape set (bounding retraces),
and in-flight prefills are continued before new admissions so a request's
time-to-first-token is never starved by later arrivals. With
``chunk_tokens=None`` the scheduler degenerates to the legacy policy
(whole-bucket admission), which stays the default; engines *execute*
scheduler decisions either way — they no longer decide anything.

Ordering is **SLO-aware**, not FIFO: every request carries a priority
*class* (higher = more latency-critical) and an optional relative
deadline, and ``request_rank`` orders by class first, earliest absolute
deadline second (EDF within a class), submission order last — so with no
priorities or deadlines set the policy is exactly the old FIFO. The rank
governs *both* levers the scheduler holds: which queued request is offered
admission (the engine's ``try_admit`` considers the best-ranked waiting
request, strictly — no lower-class backfill in front of a blocked
higher-class request) and which in-flight prefill gets chunk budget first.
When the best-ranked waiting request cannot be admitted (no free slot, or
the paged pool is out of blocks), ``plan_step`` asks the engine to
**preempt** via the ``try_preempt`` callback: the engine swaps out its
worst-ranked active slot — strictly lower class than the blocked request,
never a peer — and retries admission with the freed resources.

The scheduler also picks the **decode horizon**: how many fused decode
steps the engine scans per host sync (``StepPlan.decode_steps``). With
``max_decode_steps=K`` the engine pays one dispatch and one ``active``-mask
sync per K generated tokens instead of per token — the dominant residual
cost on weak hosts once the per-op compute is kernel-bound. The horizon is
dynamic: it collapses to 1 whenever prefill work is pending or a request
was just admitted (so chunked-prefill TTFT wins — and every request's
*first* token — are never delayed by a long scan), and is otherwise capped
by the smallest remaining per-slot budget headroom (a slot finishing its
budget mid-scan would occupy its slot as dead weight until the sync).
Horizons are rounded down to a power-of-two schedule (``k_schedule``) so
the engine compiles at most ``log2(K)`` scan variants.

Chunking is output-exact: a chunk attends to previously installed chunks
through the cache layout with ordinary position masking, so the logits at
the final prompt token — the only ones sampling ever reads — are identical
to the monolithic prefill's (``tests/test_scheduler.py`` pins this
token-for-token against the unchunked engine, shared prefixes and
copy-on-write divergence included).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, List, Optional, Tuple

# sentinel returned by an engine's try_admit for legacy whole-prompt
# admissions (nothing to chunk; the engine already ran the prefill)
MONOLITHIC = object()


def prompt_buckets(max_seq_len: int, min_bucket: int = 16) -> List[int]:
    """Power-of-two prefill shapes: [min_bucket, ..., max_seq_len]."""
    buckets = []
    b = min_bucket
    while b < max_seq_len:
        buckets.append(b)
        b *= 2
    buckets.append(max_seq_len)
    return buckets


def bucket_for(n: int, buckets: List[int]) -> int:
    for b in buckets:
        if n <= b:
            return b
    raise ValueError(
        f"prompt length {n} exceeds the largest prefill bucket "
        f"{buckets[-1]} (= max_seq_len); engines validate this at submit() "
        f"— either raise max_seq_len or submit with truncation enabled")


def request_rank(r) -> Tuple:
    """Scheduling rank: smaller = served first. Class descending (higher
    ``priority`` wins), then earliest absolute deadline (``submit_s +
    deadline_s``; no deadline sorts after every deadline in its class),
    then submission order — so with neither priorities nor deadlines set
    the policy degenerates to exactly the old FIFO. ``None`` (plan-only
    unit tests) ranks constant: a stable sort preserves FIFO."""
    if r is None:
        return (0, math.inf, 0.0, -1)
    deadline = getattr(r, "deadline_s", None)
    abs_deadline = (r.submit_s + deadline) if deadline is not None \
        else math.inf
    return (-getattr(r, "priority", 0), abs_deadline, r.submit_s,
            r.request_id)


@dataclasses.dataclass
class PrefillProgress:
    """A request mid-prefill: ``next`` is the first prompt position not yet
    computed (> 0 at admission when a shared prefix was already installed).
    ``tokens`` overrides the token source (a resumed request re-prefills
    its prompt *plus* the tokens it already generated; the engine restores
    its decode state when the final chunk lands)."""
    request: Any
    slot: int
    next: int
    total: int
    tokens: Optional[Any] = None

    @property
    def done(self) -> bool:
        return self.next >= self.total


@dataclasses.dataclass(frozen=True)
class ChunkTask:
    """One prompt chunk to run this step: ``length`` real tokens starting at
    prompt position ``start``, padded to ``bucket`` (a compile shape), for
    the request prefilling in ``slot``. ``final`` marks the chunk that
    completes the prompt (its last-token logits seed decode)."""
    slot: int
    start: int
    length: int
    bucket: int
    final: bool


@dataclasses.dataclass(frozen=True)
class StepPlan:
    """Chunks to execute this step plus admission count. Whether a decode
    round follows is the *engine's* call at execution time: a final chunk
    in this very plan can activate a slot, so any decode flag computed at
    plan time would already be stale. ``decode_steps`` is the decode
    horizon: how many fused decode steps the engine scans before its next
    host sync (1 unless multi-step decode is enabled and no prefill work
    is pending). ``spec_tokens`` is the speculative draft depth: > 0 asks
    a draft-equipped engine to run one propose-k/verify round instead of
    the scan (``decode_steps`` is then its non-speculative fallback)."""
    chunks: Tuple[ChunkTask, ...]
    admitted: int         # requests granted a slot this step
    decode_steps: int = 1  # fused decode steps per host sync this round
    spec_tokens: int = 0   # draft depth k for a speculative decode round


def chunk_buckets(chunk_tokens: int, min_bucket: int = 8) -> List[int]:
    """Power-of-two chunk shapes: [min_bucket, ..., chunk_tokens]."""
    return prompt_buckets(chunk_tokens, min(min_bucket, chunk_tokens))


def slots_for_hbm(hbm_bytes_per_device: int, slot_bytes: float,
                  mesh_size: int = 1,
                  cap: Optional[int] = None) -> int:
    """Concurrent-slot budget from a *per-device* KV HBM budget.

    A pool sharded over ``mesh_size`` devices on the KV-head axis holds
    ``mesh_size ×`` the per-device budget in global K/V bytes, so at fixed
    per-device HBM the slot count scales linearly with the mesh —
    ``slot_bytes`` is the request's *global* footprint (e.g.
    ``blocks_needed × PagedCache.block_bytes()``). This is the sizing
    rule behind ``BENCH_serving.json``'s ``sharded_decode`` section."""
    total = int(hbm_bytes_per_device) * max(int(mesh_size), 1)
    slots = int(total // max(int(slot_bytes), 1))
    return min(slots, cap) if cap is not None else slots


class Scheduler:
    """Per-step admission + chunk policy under a token budget.

    ``token_budget`` is the target tokens *computed* per engine step:
    active-slot decodes count 1 each, prompt chunks their real length.
    Defaults to ``batch_slots + chunk_tokens`` (decodes never crowd out
    prefill entirely, and vice versa). Must exceed ``batch_slots`` so a
    fully decoding engine still advances the head prefill every step.

    ``max_decode_steps`` enables multi-step decode: each pure-decode step
    may scan up to that many fused decode steps per host sync (see
    ``StepPlan.decode_steps`` and ``_decode_horizon``).

    ``admission_policy`` enables submit-time deadline-feasibility control:
    the engine reports completed requests' service times per class
    (``observe_service``, an EWMA), and a deadline-carrying submit is
    checked against the measured rate and the work ranked ahead of it
    (``deadline_feasible``). "reject" turns an infeasible submit into a
    terminal rejection, "downgrade" strips its deadline (best-effort
    within its class); ``None`` (default) admits everything, exactly the
    old behavior.
    """

    def __init__(self, *, batch_slots: int, chunk_tokens: Optional[int] = None,
                 token_budget: Optional[int] = None, min_bucket: int = 8,
                 max_decode_steps: int = 1,
                 admission_policy: Optional[str] = None,
                 service_ewma_alpha: float = 0.25,
                 deadline_margin_target: float = 0.95,
                 deadline_margin_min_obs: int = 4,
                 deadline_margin_cap: float = 4.0,
                 speculative_tokens: int = 0,
                 spec_min_commit: float = 1.25,
                 spec_probe_every: int = 32):
        self.batch_slots = batch_slots
        self.chunk_tokens = chunk_tokens
        if admission_policy not in (None, "reject", "downgrade"):
            raise ValueError(
                f"admission_policy must be None, 'reject' or 'downgrade' "
                f"(got {admission_policy!r})")
        self.admission_policy = admission_policy
        self._ewma_alpha = service_ewma_alpha
        self._service_s: dict = {}      # priority class -> EWMA service s
        self._deadline_obs: dict = {}   # priority class -> [hits, total]
        # measured-outcome feedback on feasibility (see
        # ``deadline_safety_margin``): below-target observed hit rates
        # inflate the admission estimate, bounded by the cap
        self.deadline_margin_target = deadline_margin_target
        self.deadline_margin_min_obs = deadline_margin_min_obs
        self.deadline_margin_cap = deadline_margin_cap
        if max_decode_steps < 1:
            raise ValueError(
                f"max_decode_steps must be >= 1 (got {max_decode_steps})")
        self.max_decode_steps = max_decode_steps
        # horizons the engine may be asked to run (hence must compile):
        # powers of two up to — and always including — the max
        ks: List[int] = []
        k = 1
        while k < max_decode_steps:
            ks.append(k)
            k *= 2
        ks.append(max_decode_steps)
        self.k_schedule = ks
        # speculative draft depths the engine may be asked to run: same
        # pow2-up-to-and-including-max shape as k_schedule, empty when the
        # engine carries no draft model
        if speculative_tokens < 0:
            raise ValueError(
                f"speculative_tokens must be >= 0 (got {speculative_tokens})")
        self.speculative_tokens = speculative_tokens
        sk: List[int] = []
        k = 1
        while k < speculative_tokens:
            sk.append(k)
            k *= 2
        if speculative_tokens > 0:
            sk.append(speculative_tokens)
        self.spec_schedule = sk
        self.spec_min_commit = spec_min_commit
        self.spec_probe_every = max(1, spec_probe_every)
        self._spec_ewma: Optional[float] = None  # accepted proposals / slot-round
        self._spec_suppressed = 0
        if chunk_tokens is None:
            self.token_budget = None
            self.buckets: List[int] = []
            return
        if chunk_tokens < 1:
            raise ValueError(f"chunk_tokens must be >= 1 (got {chunk_tokens})")
        if token_budget is None:
            token_budget = batch_slots + chunk_tokens
        if token_budget <= batch_slots:
            raise ValueError(
                f"token_budget ({token_budget}) must exceed batch_slots "
                f"({batch_slots}): a saturated decode batch would starve "
                f"prefill forever")
        self.token_budget = token_budget
        self.buckets = chunk_buckets(chunk_tokens, min_bucket)

    @property
    def chunked(self) -> bool:
        return self.chunk_tokens is not None

    # -- deadline-feasibility admission control -------------------------------
    def observe_service(self, priority: int, service_s: float) -> None:
        """Fold one completed request's service time (first slot grant →
        finish) into its class's EWMA. The engine calls this at every
        completion; the estimate then prices future admissions."""
        prev = self._service_s.get(priority)
        a = self._ewma_alpha
        self._service_s[priority] = service_s if prev is None \
            else (1.0 - a) * prev + a * service_s

    def service_estimate(self, priority: int) -> Optional[float]:
        """Expected service seconds for one request of ``priority``:
        the class EWMA, falling back to the mean across observed classes
        (a new class is better priced by neighbors than not at all), or
        None before any completion (cold start: admission cannot judge,
        so it admits)."""
        if priority in self._service_s:
            return self._service_s[priority]
        if self._service_s:
            return sum(self._service_s.values()) / len(self._service_s)
        return None

    def reset_estimates(self) -> None:
        """Drop the service EWMAs and deadline observations — for
        drivers that warm/compile through real requests before the
        measured (or served) traffic begins. A warm-up completion's
        service time is dominated by XLA compiles that steady-state
        serving never pays again; pricing admission with it would refuse
        perfectly feasible deadlines (cold start admits instead)."""
        self._service_s.clear()
        self._deadline_obs.clear()

    def observe_deadline(self, priority: int, hit: bool) -> None:
        """Record one deadline outcome for ``priority``: completion within
        the deadline counts as a hit, completion after it (or quarantine)
        as a miss. Cancelled/rejected requests are never recorded — the
        hit *rate* is the feedback signal that tells us whether
        ``deadline_feasible``'s first-order admission estimate is honest,
        and refusals are its output, not its ground truth."""
        hits, total = self._deadline_obs.get(priority, (0, 0))
        self._deadline_obs[priority] = (hits + (1 if hit else 0), total + 1)

    def deadline_hit_rates(self) -> dict:
        """Per-class deadline outcomes: ``{priority: {"hits", "total",
        "rate"}}`` over every deadlined request that reached a counted
        terminal state (done or quarantined)."""
        return {
            p: {"hits": h, "total": t, "rate": (h / t if t else 0.0)}
            for p, (h, t) in sorted(self._deadline_obs.items())
        }

    def absorb_deadline_hits(self, table: Optional[dict]) -> None:
        """Seed the per-class deadline observations from an externally
        measured table — ``MonitoringService.deadline_hit_rates``'s
        ``{priority: {"hits", "total", ...}}`` shape — closing the loop
        between monitored outcomes and the admission estimator (and, on a
        restart, letting a recovered engine inherit the previous
        incarnation's evidence instead of cold-starting the margin).
        Absorbed counts *replace* the class's local tally: the monitoring
        table is the superset view."""
        if not table:
            return
        for p, row in table.items():
            self._deadline_obs[int(p)] = (int(row["hits"]),
                                          int(row["total"]))

    def deadline_safety_margin(self, priority: int) -> float:
        """Multiplier on the feasibility estimate from *measured* deadline
        outcomes: 1.0 while the class's observed hit rate meets
        ``deadline_margin_target`` (or while fewer than
        ``deadline_margin_min_obs`` outcomes exist — too little evidence
        to second-guess the EWMA), otherwise ``target / rate`` capped at
        ``deadline_margin_cap``. A class that keeps missing in practice —
        preemption churn, fault retries, estimator bias — thus needs
        proportionally more headroom before "feasible", so admission
        tracks observed per-class outcomes, not just the service-time
        EWMA. Cleared with ``reset_estimates`` (restarts included)."""
        hits, total = self._deadline_obs.get(priority, (0, 0))
        if total < self.deadline_margin_min_obs:
            return 1.0
        rate = hits / total
        if rate >= self.deadline_margin_target:
            return 1.0
        floor = self.deadline_margin_target / self.deadline_margin_cap
        return self.deadline_margin_target / max(rate, floor)

    def deadline_feasible(self, *, deadline_s: float, ahead: int,
                          priority: int) -> bool:
        """Whether a submit with ``deadline_s`` can plausibly meet it:
        ``ahead`` requests (active + queued at better-or-equal rank) must
        drain through ``batch_slots`` concurrent slots at the measured
        class service rate before this one finishes, with the estimate
        inflated by the class's measured-outcome safety margin
        (``deadline_safety_margin``). Deliberately first-order — the
        point is refusing submits that are *hopeless* at the observed
        rate, not shaving the marginal ones."""
        s = self.service_estimate(priority)
        if s is None:
            return True
        wait = ahead * s / self.batch_slots
        return (wait + s) * self.deadline_safety_margin(priority) \
            <= deadline_s

    # -- speculative draft-depth policy ---------------------------------------
    def observe_speculation(self, slot_rounds: int, drafted: int,
                            accepted: int) -> None:
        """Fold one speculative round's outcome into the acceptance EWMA.
        ``slot_rounds`` is how many active slots the round covered,
        ``drafted`` the proposals issued (slots × k), ``accepted`` how
        many of them the target kept. The tracked quantity is accepted
        proposals per slot-round: a speculative dispatch commits
        ``1 + that`` tokens per slot, which is what ``_spec_horizon``
        compares against a plain step's guaranteed 1."""
        if slot_rounds <= 0:
            return
        m = accepted / slot_rounds
        a = self._ewma_alpha
        self._spec_ewma = m if self._spec_ewma is None \
            else (1.0 - a) * self._spec_ewma + a * m

    def speculative_acceptance(self) -> Optional[float]:
        """Current acceptance EWMA (accepted proposals per slot-round),
        or None before any speculative round ran."""
        return self._spec_ewma

    def _spec_horizon(self, busy_prefill: bool,
                      min_headroom: Optional[int]) -> int:
        """Draft depth k for this round, 0 meaning run non-speculative.
        Collapses while prefill work is pending (same TTFT argument as
        ``_decode_horizon``), when the smallest active budget leaves no
        room to commit more than the anchor token, and when the
        acceptance EWMA says a speculative dispatch commits fewer than
        ``spec_min_commit`` tokens per slot — drafting then costs draft
        FLOPs for less than a plain step delivers. Suppression re-probes
        every ``spec_probe_every`` suppressed plans so a workload shift
        (e.g. the repetitive tail of a trace) can win speculation back."""
        if not self.spec_schedule or busy_prefill:
            return 0
        cap = self.speculative_tokens
        if min_headroom is not None:
            # committing k proposals + the anchor never overruns the
            # tightest budget: clamp k to headroom - 1
            cap = min(cap, min_headroom - 1)
        if cap < 1:
            return 0
        if self._spec_ewma is not None \
                and 1.0 + self._spec_ewma < self.spec_min_commit:
            self._spec_suppressed += 1
            if self._spec_suppressed % self.spec_probe_every:
                return 0
        return max(k for k in self.spec_schedule if k <= cap)

    def _decode_horizon(self, busy_prefill: bool,
                        min_headroom: Optional[int]) -> int:
        """Fused decode steps for this round. Collapses to 1 while prefill
        work is pending (or a request was just admitted) so a scan never
        delays anyone's first token; otherwise the largest schedule entry
        within the smallest active slot's remaining budget — a slot never
        finishes its budget mid-scan and then squats on its slot waiting
        for the sync."""
        if busy_prefill or self.max_decode_steps == 1:
            return 1
        cap = self.max_decode_steps
        if min_headroom is not None:
            cap = max(1, min(cap, min_headroom))
        return max(k for k in self.k_schedule if k <= cap)

    # -- the per-step decision ------------------------------------------------
    def plan_step(self, *, n_active: int, prefilling,
                  try_admit: Callable[[], Any],
                  min_headroom: Optional[int] = None,
                  try_preempt: Optional[Callable[[], bool]] = None
                  ) -> StepPlan:
        """Compose one step. ``prefilling`` maps slot -> PrefillProgress;
        ``try_admit`` is the engine's admission effect: it grants the
        best-``request_rank``ed waiting request a slot (plus cache
        reservation) and returns its PrefillProgress, MONOLITHIC for legacy
        (and resumed) admissions, or None when nothing further can be
        admitted. ``try_preempt`` is the engine's preemption effect: swap
        out one active slot strictly lower-class than the best-ranked
        waiting request and return True (False when no eligible victim) —
        it is consulted only when admission is blocked, and every success
        retries admission with the freed slot/blocks. ``min_headroom`` is
        the smallest remaining decode budget across the engine's active
        slots (None when none are active) — it caps the multi-step decode
        horizon. The engine executes the returned chunks in order, then
        scans ``decode_steps`` fused decode rounds over whatever is
        active."""
        admitted = 0
        if not self.chunked:
            while True:
                if try_admit() is not None:
                    admitted += 1
                    continue
                if try_preempt is not None and try_preempt():
                    continue                 # freed a slot: retry admission
                break
            return StepPlan((), admitted,
                            self._decode_horizon(admitted > 0, min_headroom),
                            self._spec_horizon(admitted > 0, min_headroom))

        budget = self.token_budget
        spent = n_active                     # decode tokens this step
        chunks: List[ChunkTask] = []

        def plan_for(pp: PrefillProgress, spent: int) -> int:
            at = pp.next
            while at < pp.total and spent < budget:
                room = budget - spent
                t = min(self.chunk_tokens, pp.total - at)
                if t > room and chunks:
                    # no runt chunks: a truncated chunk costs a full device
                    # dispatch for a sliver of tokens — leave the budget's
                    # tail unspent and let the next step issue a full chunk
                    # (the first chunk of a step always proceeds, so an
                    # over-budget decode load can't starve prefill)
                    break
                chunks.append(ChunkTask(
                    slot=pp.slot, start=at, length=t,
                    bucket=bucket_for(t, self.buckets),
                    final=at + t >= pp.total))
                at += t
                spent += t
            return spent

        # continue in-flight prefills first, best rank first (class, then
        # deadline, then admission order — a latency-critical prefill gets
        # chunk budget ahead of bulk work; the sort is stable, so untagged
        # traffic keeps the old FIFO order)
        for pp in sorted(prefilling.values(),
                         key=lambda pp: request_rank(pp.request)):
            spent = plan_for(pp, spent)
        # admit new requests into the remaining budget; when the best-
        # ranked waiting request is blocked on resources, try preempting a
        # lower-class slot and retry
        while spent < budget:
            pp = try_admit()
            if pp is None:
                if try_preempt is not None and try_preempt():
                    continue
                break
            admitted += 1
            if pp is MONOLITHIC:
                continue
            spent = plan_for(pp, spent)
        busy_prefill = bool(chunks) or bool(prefilling) or admitted > 0
        return StepPlan(tuple(chunks), admitted,
                        self._decode_horizon(busy_prefill, min_headroom),
                        self._spec_horizon(busy_prefill, min_headroom))
