"""Token-budget step scheduler: the serving-engine policy layer.

Each engine step used to be "admit every queued prompt that fits (one
monolithic prefill each), then run one decode round" — a burst of long
prompts stalls every in-flight decode for the whole burst's prefill time,
exactly the tail-latency behavior ACE's performance-optimization layer is
meant to remove. The ``Scheduler`` pulls that policy out of
``ServingEngine.run()`` and composes each step as a *mixed batch* under a
configurable token budget:

- one decode token for every active slot (decode always proceeds), plus
- one or more *prompt chunks* for admitting requests, consuming whatever
  budget the decodes left.

Chunks are bucketed to a small power-of-two shape set (bounding retraces),
and in-flight prefills are continued FIFO before new admissions so a
request's time-to-first-token is never starved by later arrivals. With
``chunk_tokens=None`` the scheduler degenerates to the legacy policy
(whole-bucket admission), which stays the default; engines *execute*
scheduler decisions either way — they no longer decide anything.

Chunking is output-exact: a chunk attends to previously installed chunks
through the cache layout with ordinary position masking, so the logits at
the final prompt token — the only ones sampling ever reads — are identical
to the monolithic prefill's (``tests/test_scheduler.py`` pins this
token-for-token against the unchunked engine, shared prefixes and
copy-on-write divergence included).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, List, Optional, Tuple

# sentinel returned by an engine's try_admit for legacy whole-prompt
# admissions (nothing to chunk; the engine already ran the prefill)
MONOLITHIC = object()


def prompt_buckets(max_seq_len: int, min_bucket: int = 16) -> List[int]:
    """Power-of-two prefill shapes: [min_bucket, ..., max_seq_len]."""
    buckets = []
    b = min_bucket
    while b < max_seq_len:
        buckets.append(b)
        b *= 2
    buckets.append(max_seq_len)
    return buckets


def bucket_for(n: int, buckets: List[int]) -> int:
    for b in buckets:
        if n <= b:
            return b
    raise ValueError(
        f"prompt length {n} exceeds the largest prefill bucket "
        f"{buckets[-1]} (= max_seq_len); engines validate this at submit() "
        f"— either raise max_seq_len or submit with truncation enabled")


@dataclasses.dataclass
class PrefillProgress:
    """A request mid-prefill: ``next`` is the first prompt position not yet
    computed (> 0 at admission when a shared prefix was already installed)."""
    request: Any
    slot: int
    next: int
    total: int

    @property
    def done(self) -> bool:
        return self.next >= self.total


@dataclasses.dataclass(frozen=True)
class ChunkTask:
    """One prompt chunk to run this step: ``length`` real tokens starting at
    prompt position ``start``, padded to ``bucket`` (a compile shape), for
    the request prefilling in ``slot``. ``final`` marks the chunk that
    completes the prompt (its last-token logits seed decode)."""
    slot: int
    start: int
    length: int
    bucket: int
    final: bool


@dataclasses.dataclass(frozen=True)
class StepPlan:
    """Chunks to execute this step plus admission count. Whether a decode
    round follows is the *engine's* call at execution time: a final chunk
    in this very plan can activate a slot, so any decode flag computed at
    plan time would already be stale."""
    chunks: Tuple[ChunkTask, ...]
    admitted: int         # requests granted a slot this step


def chunk_buckets(chunk_tokens: int, min_bucket: int = 8) -> List[int]:
    """Power-of-two chunk shapes: [min_bucket, ..., chunk_tokens]."""
    return prompt_buckets(chunk_tokens, min(min_bucket, chunk_tokens))


class Scheduler:
    """Per-step admission + chunk policy under a token budget.

    ``token_budget`` is the target tokens *computed* per engine step:
    active-slot decodes count 1 each, prompt chunks their real length.
    Defaults to ``batch_slots + chunk_tokens`` (decodes never crowd out
    prefill entirely, and vice versa). Must exceed ``batch_slots`` so a
    fully decoding engine still advances the head prefill every step.
    """

    def __init__(self, *, batch_slots: int, chunk_tokens: Optional[int] = None,
                 token_budget: Optional[int] = None, min_bucket: int = 8):
        self.batch_slots = batch_slots
        self.chunk_tokens = chunk_tokens
        if chunk_tokens is None:
            self.token_budget = None
            self.buckets: List[int] = []
            return
        if chunk_tokens < 1:
            raise ValueError(f"chunk_tokens must be >= 1 (got {chunk_tokens})")
        if token_budget is None:
            token_budget = batch_slots + chunk_tokens
        if token_budget <= batch_slots:
            raise ValueError(
                f"token_budget ({token_budget}) must exceed batch_slots "
                f"({batch_slots}): a saturated decode batch would starve "
                f"prefill forever")
        self.token_budget = token_budget
        self.buckets = chunk_buckets(chunk_tokens, min_bucket)

    @property
    def chunked(self) -> bool:
        return self.chunk_tokens is not None

    # -- the per-step decision ------------------------------------------------
    def plan_step(self, *, n_active: int, prefilling,
                  try_admit: Callable[[], Any]) -> StepPlan:
        """Compose one step. ``prefilling`` maps slot -> PrefillProgress in
        admission order; ``try_admit`` is the engine's admission effect: it
        grants the queue head a slot (plus cache reservation) and returns
        its PrefillProgress, MONOLITHIC for legacy admissions, or None when
        nothing further can be admitted. The engine executes the returned
        chunks in order, then decodes whatever is active."""
        admitted = 0
        if not self.chunked:
            while try_admit() is not None:
                admitted += 1
            return StepPlan((), admitted)

        budget = self.token_budget
        spent = n_active                     # decode tokens this step
        chunks: List[ChunkTask] = []

        def plan_for(pp: PrefillProgress, spent: int) -> int:
            at = pp.next
            while at < pp.total and spent < budget:
                room = budget - spent
                t = min(self.chunk_tokens, pp.total - at)
                if t > room and chunks:
                    # no runt chunks: a truncated chunk costs a full device
                    # dispatch for a sliver of tokens — leave the budget's
                    # tail unspent and let the next step issue a full chunk
                    # (the first chunk of a step always proceeds, so an
                    # over-budget decode load can't starve prefill)
                    break
                chunks.append(ChunkTask(
                    slot=pp.slot, start=at, length=t,
                    bucket=bucket_for(t, self.buckets),
                    final=at + t >= pp.total))
                at += t
                spent += t
            return spent

        # continue in-flight prefills first (FIFO: earlier admissions
        # reach their first token before later ones get budget)
        for pp in list(prefilling.values()):
            spent = plan_for(pp, spent)
        # admit new requests into the remaining budget
        while spent < budget:
            pp = try_admit()
            if pp is None:
                break
            admitted += 1
            if pp is MONOLITHIC:
                continue
            spent = plan_for(pp, spent)
        return StepPlan(tuple(chunks), admitted)
