"""Token sampling."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def sample_logits(rng, logits, *, temperature: float = 0.0,
                  top_k: int = 0) -> jnp.ndarray:
    """logits (..., V) -> token ids. temperature 0 = greedy."""
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits.astype(jnp.float32) / temperature
    if top_k and top_k < logits.shape[-1]:
        kth = jax.lax.top_k(logits, top_k)[0][..., -1:]
        logits = jnp.where(logits < kth, -1e30, logits)
    return jax.random.categorical(rng, logits, axis=-1).astype(jnp.int32)


def request_keys(base, request_ids, steps) -> jnp.ndarray:
    """Per-row PRNG keys derived from (request_id, step): sampling becomes a
    pure function of the request and its decode depth, so temperature > 0
    outputs no longer depend on which requests happen to be co-scheduled in
    the batch (or on how a scheduler interleaved their admission).

    This is also what makes multi-step decode exact for sampled streams:
    the engine's K-step ``lax.scan`` re-derives each row's key from the
    *carried* ``steps`` at every scanned iteration, so the keys a K-scan
    consumes are exactly the ones K single-step rounds would have drawn —
    no per-step host key splitting, nothing baked at trace time.

    base: a PRNGKey; request_ids, steps: (B,) int32. Returns (B, ...) keys.
    """
    def one(rid, step):
        return jax.random.fold_in(jax.random.fold_in(base, rid), step)

    return jax.vmap(one)(jnp.asarray(request_ids, jnp.uint32),
                         jnp.asarray(steps, jnp.uint32))


def sample_logits_keyed(keys, logits, temperature, *,
                        top_k: int = 0) -> jnp.ndarray:
    """Like ``sample_logits_batch`` but with an explicit per-row key
    (see ``request_keys``). logits: (B, V); temperature: (B,)."""
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits.astype(jnp.float32)
    if top_k and top_k < logits.shape[-1]:
        kth = jax.lax.top_k(logits, top_k)[0][..., -1:]
        logits = jnp.where(logits < kth, -1e30, logits)
    scaled = logits / jnp.maximum(temperature, 1e-6)[:, None]
    sampled = jax.vmap(jax.random.categorical)(keys, scaled).astype(jnp.int32)
    return jnp.where(temperature > 0.0, sampled, greedy)


def accepted_prefix_length(proposed, target) -> jnp.ndarray:
    """Longest accepted prefix for key-coupled speculative verification.

    ``proposed`` and ``target`` are (B, k) int32: the draft's proposals
    and the tokens the target model samples at the same (request, step)
    keys off its own verify logits. Because draft and target share the
    folded key schedule, acceptance is simply agreement — a proposal is
    right iff it equals the token the baseline engine would have sampled
    there — and the accepted prefix ends at the first disagreement.
    Returns (B,) int32 in [0, k]: cumprod turns the boolean match row
    into 1s up to the first 0, and the sum counts them.
    """
    match = (proposed == target).astype(jnp.int32)
    return jnp.sum(jnp.cumprod(match, axis=-1), axis=-1).astype(jnp.int32)


def sample_logits_batch(rng, logits, temperature, *,
                        top_k: int = 0) -> jnp.ndarray:
    """Vectorized sampling with per-row temperature (continuous batching
    serves requests with different temperatures in one step).

    logits: (B, V); temperature: (B,) with 0 = greedy per row. Traced-safe
    (no python branching on temperature), so it lives inside the engine's
    fused decode step.
    """
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits.astype(jnp.float32)
    if top_k and top_k < logits.shape[-1]:
        kth = jax.lax.top_k(logits, top_k)[0][..., -1:]
        logits = jnp.where(logits < kth, -1e30, logits)
    scaled = logits / jnp.maximum(temperature, 1e-6)[:, None]
    sampled = jax.random.categorical(rng, scaled, axis=-1).astype(jnp.int32)
    return jnp.where(temperature > 0.0, sampled, greedy)
