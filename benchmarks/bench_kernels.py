"""Kernel micro-benchmarks: wall-clock of the jnp oracle paths on this host
(the Pallas kernels target TPU; interpret-mode timing is not meaningful), plus
derived arithmetic intensity so the TPU projection is visible."""
from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp

from repro.kernels import ref


def _time(fn, *args, iters: int = 5) -> float:
    warm = fn(*args)                                     # evaluate once
    (warm[0] if isinstance(warm, tuple) else warm).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
        (out[0] if isinstance(out, tuple) else out).block_until_ready()
    return (time.perf_counter() - t0) / iters * 1e6


def run() -> List[tuple]:
    rows = []
    ks = jax.random.split(jax.random.PRNGKey(0), 3)

    b, s, h, kv, hd = 1, 1024, 8, 2, 64
    q = jax.random.normal(ks[0], (b, s, h, hd), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, kv, hd), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, kv, hd), jnp.float32)
    fa = jax.jit(lambda q, k, v: ref.flash_attention_streaming_ref(q, k, v))
    us = _time(fa, q, k, v)
    flops = 4 * b * s * s * h * hd
    rows.append((f"kernel/flash_attention/b{b}s{s}h{h}", us,
                 f"gflops_s={flops/us/1e3:.1f}"))

    bb, ss, w = 2, 2048, 512
    a = jax.random.uniform(ks[0], (bb, ss, w), jnp.float32, 0.9, 0.999)
    x = jax.random.normal(ks[1], (bb, ss, w), jnp.float32)
    h0 = jnp.zeros((bb, w))
    sc = jax.jit(lambda a, x, h0: ref.rglru_scan_ref(a, x, h0))
    us = _time(sc, a, x, h0)
    gbytes = 3 * bb * ss * w * 4 / 1e9
    rows.append((f"kernel/rglru_scan/b{bb}s{ss}w{w}", us,
                 f"gb_s={gbytes/(us/1e6):.1f}"))

    from repro.cascade.gate import make_thresholds
    t, vcb = 4096, 32768
    logits = jax.random.normal(ks[2], (t, vcb), jnp.float32)
    th = make_thresholds()
    g = jax.jit(lambda l: ref.cascade_gate_ref(l, th)["conf"])
    us = _time(g, logits)
    gbytes = t * vcb * 4 / 1e9
    rows.append((f"kernel/cascade_gate/t{t}v{vcb}", us,
                 f"gb_s={gbytes/(us/1e6):.1f}"))
    return rows
