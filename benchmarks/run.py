"""Benchmark harness (deliverable d) — one module per paper table/figure:

  bench_video_query  paper Fig. 5 (F1/BWC/EIL x load x delay x paradigm)
  bench_roofline     §Roofline terms per (arch x shape) from the dry-run
  bench_cascade      LM cascade: lockstep (paper) vs compacted (beyond)
  bench_partition    intra-model split-point policy (Principle Four)
  bench_kernels      kernel micro-benchmarks (host oracle timing)
  bench_serving      continuous batching vs drain-batch baseline

Prints ``name,us_per_call,derived`` CSV; the serving suite also dumps its
baseline-vs-new comparison to ``BENCH_serving.json``.

    PYTHONPATH=src python -m benchmarks.run [--quick]
"""
import argparse
import json
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="shorter video-query simulations")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    from benchmarks import (bench_cascade, bench_kernels, bench_partition,
                            bench_roofline, bench_serving,
                            bench_video_query)

    suites = {
        "video_query": lambda: bench_video_query.run(
            duration_s=8.0 if args.quick else 20.0),
        "roofline": bench_roofline.run,
        "partition": bench_partition.run,
        "kernels": bench_kernels.run,
        "cascade": bench_cascade.run,
        "serving": bench_serving.run,
    }
    print("name,us_per_call,derived")
    failures = []
    vq_rows = None
    for name, fn in suites.items():
        if args.only and name != args.only:
            continue
        try:
            rows = fn()
            if name == "video_query":
                vq_rows = rows
            if name == "serving":
                with open("BENCH_serving.json", "w") as f:
                    json.dump(bench_serving.run.last_result, f, indent=2)
                print("# wrote BENCH_serving.json", file=sys.stderr)
            for row in rows:
                print(f"{row[0]},{row[1]:.1f},{row[2]}")
            sys.stdout.flush()
        except Exception as e:  # noqa: BLE001
            traceback.print_exc()
            failures.append((name, repr(e)))
    if vq_rows is not None:
        bad = bench_video_query.check(vq_rows)
        for b in bad:
            print(f"CLAIM-VIOLATION,{b}", file=sys.stderr)
        if not bad:
            print("# all paper Fig.5 qualitative claims hold", file=sys.stderr)
    if failures:
        print(f"# FAILURES: {failures}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
