"""LM cascade serving (the paper's technique on LM workloads): lockstep
(paper-faithful) vs compacted escalation (beyond-paper) — accuracy-identical
within capacity, boundary-bytes and cloud-compute differ."""
from __future__ import annotations

import time
from typing import List

import jax
import numpy as np

from repro.cascade.ecc_infer import CascadeLM, edge_variant
from repro.configs import get_config
from repro.models.model import LM
from repro.serving import CascadeEngine


def run() -> List[tuple]:
    rows = []
    cloud_cfg = get_config("smollm-135m").reduced()
    edge_cfg = edge_variant(cloud_cfg, layers=1)
    cloud, edge = LM(cloud_cfg, kv_chunk=32), LM(edge_cfg, kv_chunk=32)
    cp, _ = cloud.init(jax.random.PRNGKey(0))
    ep, _ = edge.init(jax.random.PRNGKey(1))
    rng = np.random.default_rng(0)
    tokens = rng.integers(0, cloud_cfg.vocab_size, size=(16, 32))

    for mode, compact in (("lockstep", False), ("compact", True)):
        cascade = CascadeLM(edge, cloud, capacity_frac=0.5)
        eng = CascadeEngine(cascade, ep, cp, compact=compact)
        eng.query(tokens)                         # compile
        t0 = time.perf_counter()
        iters = 3
        for _ in range(iters):
            eng.query(tokens)
        us = (time.perf_counter() - t0) / iters * 1e6
        m = eng.metrics
        rows.append((f"cascade/{mode}/b16s32", us,
                     f"wan_bytes_per_query={m.wan_bytes / m.queries:.0f};"
                     f"escalated_frac={m.escalated / m.queries:.2f};"
                     f"agreement={m.agreement:.2f}"))
    return rows
