"""Roofline terms per (arch x shape) from the dry-run artifacts
(deliverable g). Emits one row per single-pod baseline."""
from __future__ import annotations

import os
from typing import List

from repro.analysis.roofline import roofline_table

DRYRUN_DIR = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "results", "dryrun")


def run() -> List[tuple]:
    rows = []
    if not os.path.isdir(DRYRUN_DIR):
        return [("roofline/SKIPPED", 0.0, "run repro.launch.dryrun first")]
    for r in roofline_table(DRYRUN_DIR, mesh="pod16x16"):
        name = f"roofline/{r['arch']}/{r['shape']}"
        dom_t = {"compute": r["t_compute_s"], "memory": r["t_memory_s"],
                 "collective": r["t_collective_s"]}[r["dominant"]]
        derived = (f"dominant={r['dominant']};"
                   f"tc_ms={r['t_compute_s']*1e3:.2f};"
                   f"tm_ms={r['t_memory_s']*1e3:.2f};"
                   f"tx_ms={r['t_collective_s']*1e3:.2f};"
                   f"useful={r['useful_ratio']:.2f};"
                   f"hbm_gib={(r['hbm_gib_per_device'] or 0):.1f}")
        rows.append((name, dom_t * 1e6, derived))
    return rows
